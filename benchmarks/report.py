"""Render EXPERIMENTS.md tables from the dry-run / perf artifacts,
and the perf-trend report from the benchmark history store.

    PYTHONPATH=src python -m benchmarks.report
        [--section dryrun|roofline|perf|trend]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
ART = ROOT / "artifacts" / "dryrun"
PERF = ROOT / "artifacts" / "perf"

SHAPE_ORDER = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def _cells(directory: Path, glob: str):
    for f in sorted(directory.glob(glob)):
        yield json.loads(f.read_text())


def dryrun_table() -> str:
    lines = [
        "| arch | shape | mesh | status | compile_s | args GB/dev |"
        " peak GB/dev |",
        "|---|---|---|---|---|---|---|",
    ]
    recs = list(_cells(ART, "*.json"))
    recs.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"]),
                             r["mesh"]))
    for r in recs:
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"skipped (sub-quadratic gate) | — | — | — |")
            continue
        mem = r.get("memory_analysis", {})
        args_gb = mem.get("argument_size_in_bytes", 0) / 1e9
        peak_gb = mem.get("peak_memory_in_bytes", 0) / 1e9
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['status']} | "
            f"{r.get('compile_s', '—')} | {args_gb:.2f} | {peak_gb:.3f} |")
    return "\n".join(lines)


def roofline_table() -> str:
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | bottleneck"
        " | MODEL_FLOPS/HLO | one-line lever |",
        "|---|---|---|---|---|---|---|---|",
    ]
    recs = [r for r in _cells(ART, "*--single.json")
            if r.get("status") == "ok" and "roofline" in r]
    recs.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])))
    for r in recs:
        t = r["roofline"]
        lever = _lever(r)
        ratio = r.get("useful_flops_ratio")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3e} | "
            f"{t['memory_s']:.3e} | {t['collective_s']:.3e} | "
            f"{t['bottleneck']} | {ratio:.3f} | {lever} |")
    return "\n".join(lines)


def _lever(r) -> str:
    b = r["roofline"]["bottleneck"]
    arch, shape = r["arch"], r["shape"]
    coll = r.get("collective_bytes_per_device", {})
    if b == "collective":
        top = max(coll, key=coll.get) if coll else "?"
        if "moe" in arch or arch.startswith("deepseek"):
            return f"einsum-dispatch MoE kills the {top} combine"
        return f"reshard to cut {top} (dp layout for small dims)"
    if b == "memory":
        if shape.startswith("decode") or shape.startswith("long"):
            return "int8 KV cache + cache donation"
        return "remat policy + fused/bf16 elementwise (CPU f32-legalization inflates this term)"
    return "MXU-aligned tiling / larger per-device batch"


def perf_log() -> str:
    lines = [
        "| cell | tag | compute_s | memory_s | collective_s | bottleneck |",
        "|---|---|---|---|---|---|",
    ]
    if not PERF.is_dir():
        return "(no perf artifacts)"
    recs = list(_cells(PERF, "*.json"))
    recs.sort(key=lambda r: (r["arch"], r["shape"], r.get("tag", "")))
    for r in recs:
        if r.get("status") != "ok" or "roofline" not in r:
            continue
        t = r["roofline"]
        lines.append(
            f"| {r['arch']}.{r['shape']} | {r.get('tag') or 'baseline'} | "
            f"{t['compute_s']:.3e} | {t['memory_s']:.3e} | "
            f"{t['collective_s']:.3e} | {t['bottleneck']} |")
    return "\n".join(lines)


# ------------------------------------------------------ perf trend

_SPARK_LEVELS = "▁▂▃▄▅▆▇█"


def spark(values, width: int = 16) -> str:
    """Unicode sparkline over the last ``width`` values (min-max
    normalized; a flat series renders mid-level)."""
    import math

    vals = [v for v in values if v is not None
            and math.isfinite(float(v))][-width:]
    if not vals:
        return ""
    lo, hi = min(vals), max(vals)
    if hi == lo:
        return _SPARK_LEVELS[3] * len(vals)
    n = len(_SPARK_LEVELS) - 1
    return "".join(_SPARK_LEVELS[round((v - lo) / (hi - lo) * n)]
                   for v in vals)


def _fmt_num(v: float) -> str:
    import math

    if v is None or not math.isfinite(v):
        return "—"
    if v == int(v) and abs(v) < 1e6:
        return str(int(v))
    return f"{v:.4g}"


def trend_report(history, findings_by_module, *,
                 include_smoke: bool = False) -> str:
    """The markdown trend report the gate writes: per module, a
    provenance header for the gated run and a per-metric table —
    history depth, EWMA baseline, newest value, delta vs threshold,
    verdict, sparkline of the trajectory, and the attribution line for
    confirmed regressions. Rendered entirely from the history store
    (``benchmarks/history.py``) + the gate's findings."""
    lines = ["# Perf trend report", ""]
    lines.append("Verdicts come from `repro.obs.regress`: EWMA "
                 "baselines (fleet-drift fold semantics) over prior "
                 "non-smoke hardware-matched runs, thresholds widened "
                 "to the calibrated noise floor (series scatter + the "
                 "A/A null row), direction-aware. See README "
                 "\"Perf regression gate\".")
    for module in sorted(findings_by_module):
        findings = findings_by_module[module]
        run = history.latest_run(module)
        if run is None:
            continue
        info = history.run_info(run)
        lines += ["", f"## {module}", ""]
        lines.append(
            f"Gated run: `{info['git_sha']}`"
            f"{' (dirty)' if info['dirty'] else ''} · "
            f"unix_time {info['unix_time']:.0f} · "
            f"{info['device_count']} device(s) / "
            f"{info['cpu_cores']} core(s) / {info['backend']} · "
            f"{'smoke' if info['smoke'] else 'full'} run"
            f"{' · **ERROR row present**' if info['error'] else ''}")
        lines += ["", "| metric | n | baseline | latest | Δ% | "
                      "thr% | verdict | trend | attribution |",
                  "|---|---|---|---|---|---|---|---|---|"]
        for f in findings:
            _, series_vals = history.series(
                module, f.metric, include_smoke=include_smoke)
            trend = spark(list(series_vals))
            if f.verdict in ("info", "no-baseline"):
                delta = thr = "—"
                base = "—"
            else:
                delta = f"{f.delta_pct:+.1f}"
                thr = f"{f.threshold_pct:.1f}"
                base = _fmt_num(f.baseline)
            verdict = (f"**{f.verdict}**" if f.regressed
                       else f.verdict)
            attribution = "; ".join(f.attribution) or "—"
            lines.append(
                f"| {f.metric} | {f.n_baseline} | {base} | "
                f"{_fmt_num(f.value)} | {delta} | {thr} | {verdict} "
                f"| {trend} | {attribution} |")
    lines.append("")
    return "\n".join(lines)


def write_trend_report(path, history, findings_by_module, *,
                       include_smoke: bool = False) -> None:
    Path(path).write_text(trend_report(
        history, findings_by_module, include_smoke=include_smoke))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--section", default="all",
                    choices=["all", "dryrun", "roofline", "perf",
                             "trend"])
    ap.add_argument("--history", default="BENCH_history.npz",
                    help="history store for --section trend")
    ap.add_argument("--include-smoke", action="store_true")
    args = ap.parse_args()
    if args.section == "trend":
        from benchmarks import gate
        from benchmarks.history import BenchHistory

        history = BenchHistory.load(args.history)
        findings = gate.evaluate_history(
            history, include_smoke=args.include_smoke)
        print(trend_report(history, findings,
                           include_smoke=args.include_smoke))
        return
    if args.section in ("all", "dryrun"):
        print("## Dry-run matrix\n")
        print(dryrun_table())
        print()
    if args.section in ("all", "roofline"):
        print("## Roofline (single-pod, per device)\n")
        print(roofline_table())
        print()
    if args.section in ("all", "perf"):
        print("## Perf iterations\n")
        print(perf_log())


if __name__ == "__main__":
    main()
