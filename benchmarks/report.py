"""Render EXPERIMENTS.md tables from the dry-run / perf artifacts.

    PYTHONPATH=src python -m benchmarks.report [--section dryrun|roofline|perf]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
ART = ROOT / "artifacts" / "dryrun"
PERF = ROOT / "artifacts" / "perf"

SHAPE_ORDER = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def _cells(directory: Path, glob: str):
    for f in sorted(directory.glob(glob)):
        yield json.loads(f.read_text())


def dryrun_table() -> str:
    lines = [
        "| arch | shape | mesh | status | compile_s | args GB/dev |"
        " peak GB/dev |",
        "|---|---|---|---|---|---|---|",
    ]
    recs = list(_cells(ART, "*.json"))
    recs.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"]),
                             r["mesh"]))
    for r in recs:
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"skipped (sub-quadratic gate) | — | — | — |")
            continue
        mem = r.get("memory_analysis", {})
        args_gb = mem.get("argument_size_in_bytes", 0) / 1e9
        peak_gb = mem.get("peak_memory_in_bytes", 0) / 1e9
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['status']} | "
            f"{r.get('compile_s', '—')} | {args_gb:.2f} | {peak_gb:.3f} |")
    return "\n".join(lines)


def roofline_table() -> str:
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | bottleneck"
        " | MODEL_FLOPS/HLO | one-line lever |",
        "|---|---|---|---|---|---|---|---|",
    ]
    recs = [r for r in _cells(ART, "*--single.json")
            if r.get("status") == "ok" and "roofline" in r]
    recs.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])))
    for r in recs:
        t = r["roofline"]
        lever = _lever(r)
        ratio = r.get("useful_flops_ratio")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3e} | "
            f"{t['memory_s']:.3e} | {t['collective_s']:.3e} | "
            f"{t['bottleneck']} | {ratio:.3f} | {lever} |")
    return "\n".join(lines)


def _lever(r) -> str:
    b = r["roofline"]["bottleneck"]
    arch, shape = r["arch"], r["shape"]
    coll = r.get("collective_bytes_per_device", {})
    if b == "collective":
        top = max(coll, key=coll.get) if coll else "?"
        if "moe" in arch or arch.startswith("deepseek"):
            return f"einsum-dispatch MoE kills the {top} combine"
        return f"reshard to cut {top} (dp layout for small dims)"
    if b == "memory":
        if shape.startswith("decode") or shape.startswith("long"):
            return "int8 KV cache + cache donation"
        return "remat policy + fused/bf16 elementwise (CPU f32-legalization inflates this term)"
    return "MXU-aligned tiling / larger per-device batch"


def perf_log() -> str:
    lines = [
        "| cell | tag | compute_s | memory_s | collective_s | bottleneck |",
        "|---|---|---|---|---|---|",
    ]
    if not PERF.is_dir():
        return "(no perf artifacts)"
    recs = list(_cells(PERF, "*.json"))
    recs.sort(key=lambda r: (r["arch"], r["shape"], r.get("tag", "")))
    for r in recs:
        if r.get("status") != "ok" or "roofline" not in r:
            continue
        t = r["roofline"]
        lines.append(
            f"| {r['arch']}.{r['shape']} | {r.get('tag') or 'baseline'} | "
            f"{t['compute_s']:.3e} | {t['memory_s']:.3e} | "
            f"{t['collective_s']:.3e} | {t['bottleneck']} |")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--section", default="all",
                    choices=["all", "dryrun", "roofline", "perf"])
    args = ap.parse_args()
    if args.section in ("all", "dryrun"):
        print("## Dry-run matrix\n")
        print(dryrun_table())
        print()
    if args.section in ("all", "roofline"):
        print("## Roofline (single-pod, per device)\n")
        print(roofline_table())
        print()
    if args.section in ("all", "perf"):
        print("## Perf iterations\n")
        print(perf_log())


if __name__ == "__main__":
    main()
