"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Modules:
  bench_fingerprint — paper §IV-C quality table
  bench_tuning      — paper §IV-D Fig. 5 (CherryPick/Arrow +- Perona)
                      + HPO engine (sequential vs vmapped) wall-clock
  bench_workflows   — paper §IV-E Table III (Lotaru) + Tarema groups
  bench_kernels     — kernel-path microbenchmarks
  bench_roofline    — dry-run roofline summary (deliverable g)

The tuning module's rows are also written to ``BENCH_tuning.json`` so
the training/HPO perf trajectory is tracked across PRs.

Usage: PYTHONPATH=src python -m benchmarks.run [--only <module-substr>]
"""

import argparse
import json
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--quick", action="store_true",
                    help="reduced workload counts for smoke usage")
    ap.add_argument("--json-out", default="BENCH_tuning.json",
                    help="where to write the tuning rows as JSON")
    args = ap.parse_args()

    from benchmarks import (bench_fingerprint, bench_kernels,
                            bench_roofline, bench_tuning, bench_workflows)

    n_workloads = 6 if args.quick else 18
    hpo_trials = 8 if args.quick else 32
    hpo_epochs = 8 if args.quick else 25
    modules = [
        ("fingerprint", lambda rows: bench_fingerprint.run(rows)),
        ("tuning", lambda rows: bench_tuning.run(
            rows, n_workloads=n_workloads, hpo_trials=hpo_trials,
            hpo_epochs=hpo_epochs)),
        ("workflows", lambda rows: bench_workflows.run(rows)),
        ("kernels", lambda rows: bench_kernels.run(rows)),
        ("roofline", lambda rows: bench_roofline.run(rows)),
    ]

    rows = [("name", "us_per_call", "derived")]
    for name, fn in modules:
        if args.only and args.only not in name:
            continue
        start = len(rows)
        t0 = time.time()
        try:
            fn(rows)
            rows.append((f"{name}.wall_s", "", f"{time.time() - t0:.1f}"))
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            rows.append((f"{name}.ERROR", "", repr(e)))
        if name == "tuning" and args.json_out:
            payload = {
                "module": name,
                "unix_time": time.time(),
                # record the run parameters so quick smoke numbers are
                # never mistaken for the tracked full-run trajectory
                "quick": args.quick,
                "hpo_trials": hpo_trials,
                "hpo_epochs": hpo_epochs,
                "n_workloads": n_workloads,
                "rows": [{"name": n, "us_per_call": u, "derived": d}
                         for n, u, d in rows[start:]],
            }
            with open(args.json_out, "w") as f:
                json.dump(payload, f, indent=2)
                f.write("\n")
    for r in rows:
        print(",".join(str(x) for x in r))


if __name__ == "__main__":
    main()
