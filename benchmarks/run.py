"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Modules:
  bench_fingerprint — paper §IV-C quality table
  bench_tuning      — paper §IV-D Fig. 5 (CherryPick/Arrow +- Perona)
                      + HPO engine (sequential vs vmapped) wall-clock
  bench_workflows   — paper §IV-E Table III (Lotaru) + Tarema groups
  bench_fleet       — fleet service throughput (loop vs micro-batched
                      vs sharded requests/s) + amortized-append check
  bench_optimizer   — §IV-D scenario-matrix replay: sequential numpy
                      searches vs the batched vmapped lane engine
  bench_kernels     — kernel-path microbenchmarks
  bench_roofline    — dry-run roofline summary (deliverable g)

The tuning module's rows are written to ``BENCH_tuning.json``, the
fleet module's to ``BENCH_fleet.json`` and the optimizer module's to
``BENCH_optimizer.json`` so the perf trajectories are tracked across
PRs.

Every tracked payload is stamped with provenance — ``git_sha``,
``dirty``, and hostname-free hardware descriptors (``device_count``,
``cpu_cores``, ``backend``) — so history rows are comparable across
machines. ``--history PATH`` ingests the payloads into the append-only
``benchmarks.history.BenchHistory`` store; ``--gate`` additionally
runs the noise-aware regression gate (``benchmarks.gate``) over the
updated history, writes the markdown trend report, and exits nonzero
on confirmed regressions — the record->detect->enforce loop in one
command.

Usage: PYTHONPATH=src python -m benchmarks.run [--only <module-substr>]
``--quick`` shrinks workload counts; ``--smoke`` (the CI step) shrinks
them further so every module imports and runs in a few minutes (smoke
payloads ingest *tagged* and never anchor gate baselines).
"""

import argparse
import json
import os
import subprocess
import sys
import time
import traceback


def provenance() -> dict:
    """The comparability stamp every tracked payload carries: which
    code produced the numbers (git SHA + dirty working tree flag) and
    what hardware class ran them (device/core counts, jax backend —
    deliberately hostname-free)."""

    def _git(*argv):
        try:
            out = subprocess.run(
                ["git", *argv], capture_output=True, text=True,
                timeout=10,
                cwd=os.path.dirname(os.path.abspath(__file__)))
            return out.stdout.strip() if out.returncode == 0 else ""
        except OSError:
            return ""

    import jax  # after any --devices XLA_FLAGS mutation

    return {
        "git_sha": _git("rev-parse", "--short=12", "HEAD")
        or "unknown",
        "dirty": bool(_git("status", "--porcelain")),
        "device_count": jax.device_count(),
        "cpu_cores": os.cpu_count() or 0,
        "backend": jax.default_backend(),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--quick", action="store_true",
                    help="reduced workload counts")
    ap.add_argument("--smoke", action="store_true",
                    help="minimal counts: the CI import-and-run check")
    ap.add_argument("--json-out", default="BENCH_tuning.json",
                    help="where to write the tuning rows as JSON")
    ap.add_argument("--fleet-json-out", default="BENCH_fleet.json",
                    help="where to write the fleet rows as JSON")
    ap.add_argument("--optimizer-json-out",
                    default="BENCH_optimizer.json",
                    help="where to write the optimizer rows as JSON")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N virtual host devices (sets "
                         "--xla_force_host_platform_device_count "
                         "before jax initializes; exercises the "
                         "sharded/pipelined multi-device rows on CPU)")
    ap.add_argument("--history", default=None,
                    help="ingest the written payloads into this "
                         "BenchHistory .npz (appended, atomic)")
    ap.add_argument("--gate", action="store_true",
                    help="after ingesting (default history: "
                         "BENCH_history.npz), run the regression gate "
                         "+ trend report and exit nonzero on "
                         "confirmed regressions")
    ap.add_argument("--report", default="TREND_REPORT.md",
                    help="trend report path for --gate")
    args = ap.parse_args()
    quick = args.quick or args.smoke
    if args.devices > 0:
        # must land in XLA_FLAGS before the first jax import
        flags = os.environ.get("XLA_FLAGS", "")
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count="
            f"{args.devices}").strip()

    from benchmarks import (bench_fingerprint, bench_fleet,
                            bench_kernels, bench_optimizer,
                            bench_roofline, bench_tuning,
                            bench_workflows)
    from repro import obs

    n_workloads = (3 if args.smoke else 6) if quick else 18
    hpo_trials = (4 if args.smoke else 8) if quick else 32
    hpo_epochs = (4 if args.smoke else 8) if quick else 25
    fp_runs = 25 if args.smoke else 100
    fp_epochs = 15 if args.smoke else 100
    wf_runs = 4 if args.smoke else 10
    wf_epochs = 10 if args.smoke else 40
    modules = [
        ("fingerprint", lambda rows: bench_fingerprint.run(
            rows, runs_per_type=fp_runs, epochs=fp_epochs)),
        ("tuning", lambda rows: bench_tuning.run(
            rows, n_workloads=n_workloads, hpo_trials=hpo_trials,
            hpo_epochs=hpo_epochs)),
        ("workflows", lambda rows: bench_workflows.run(
            rows, runs_per_type=wf_runs, epochs=wf_epochs)),
        ("fleet", lambda rows: bench_fleet.run(rows, quick=quick)),
        ("optimizer", lambda rows: bench_optimizer.run(rows,
                                                       quick=quick)),
        ("kernels", lambda rows: bench_kernels.run(rows)),
        ("roofline", lambda rows: bench_roofline.run(rows)),
    ]
    json_out = {"tuning": args.json_out, "fleet": args.fleet_json_out,
                "optimizer": args.optimizer_json_out}

    rows = [("name", "us_per_call", "derived")]
    written = []
    prov = None
    for name, fn in modules:
        if args.only and args.only not in name:
            continue
        start = len(rows)
        t0 = time.time()
        params = None
        try:
            params = fn(rows)
            rows.append((f"{name}.wall_s", "", f"{time.time() - t0:.1f}"))
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            rows.append((f"{name}.ERROR", "", repr(e)))
        if name in json_out and json_out[name]:
            # record the module's actual workload parameters so quick
            # smoke numbers are never mistaken for the tracked
            # full-run trajectory (modules may return their own dict)
            if params is None and name == "tuning":
                params = {"hpo_trials": hpo_trials,
                          "hpo_epochs": hpo_epochs,
                          "n_workloads": n_workloads}
            if prov is None:
                prov = provenance()
            payload = {
                "module": name,
                "unix_time": time.time(),
                "quick": quick,
                "smoke": args.smoke,
                # provenance: which code / what hardware class —
                # history rows must be comparable across machines
                **prov,
                "params": params,
                # telemetry snapshot at write time (jit traces /
                # dispatches / compile seconds, daemon ladder + queue
                # latency, ...): each tracked perf trajectory carries
                # its own diagnostics
                "metrics": obs.registry().snapshot(),
                "rows": [{"name": n, "us_per_call": u, "derived": d}
                         for n, u, d in rows[start:]],
            }
            with open(json_out[name], "w") as f:
                json.dump(payload, f, indent=2)
                f.write("\n")
            written.append(json_out[name])
    for r in rows:
        print(",".join(str(x) for x in r))
    if args.smoke:
        # CI contract: every tracked BENCH_*.json written by the smoke
        # run must carry a non-empty telemetry snapshot and the
        # provenance stamp the history store keys comparability on
        for path in written:
            with open(path) as f:
                payload = json.load(f)
            assert payload.get("metrics"), (
                f"{path}: bench payload is missing its telemetry "
                "'metrics' snapshot")
            for key in ("git_sha", "dirty", "device_count",
                        "cpu_cores", "backend"):
                assert key in payload, (
                    f"{path}: bench payload is missing provenance "
                    f"field {key!r}")

    if (args.gate or args.history) and written:
        hist_path = args.history or "BENCH_history.npz"
        from benchmarks.history import BenchHistory

        hist = BenchHistory.load_or_new(hist_path)
        for path in written:
            with open(path) as f:
                hist.append(json.load(f))
        hist.save(hist_path)
        print(f"history: ingested {len(written)} payload(s) -> "
              f"{hist_path} ({len(hist)} runs, "
              f"{hist.n_samples} samples)")
        if args.gate:
            from benchmarks import gate, report

            findings = gate.evaluate_history(hist)
            if args.report:
                report.write_trend_report(args.report, hist, findings)
                print(f"gate: trend report -> {args.report}")
            failures = gate.gate_verdict(hist, findings)
            if failures:
                print(f"gate: FAIL — {len(failures)} confirmed "
                      "regression(s):", file=sys.stderr)
                for line in failures:
                    print(f"  {line}", file=sys.stderr)
                sys.exit(1)
            print("gate: PASS — no confirmed regressions")


if __name__ == "__main__":
    # support `python benchmarks/run.py` (script dir on sys.path, repo
    # root not): make the `benchmarks` package importable
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    main()
