"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Modules:
  bench_fingerprint — paper §IV-C quality table
  bench_tuning      — paper §IV-D Fig. 5 (CherryPick/Arrow +- Perona)
  bench_workflows   — paper §IV-E Table III (Lotaru) + Tarema groups
  bench_kernels     — kernel-path microbenchmarks
  bench_roofline    — dry-run roofline summary (deliverable g)

Usage: PYTHONPATH=src python -m benchmarks.run [--only <module-substr>]
"""

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--quick", action="store_true",
                    help="reduced workload counts for smoke usage")
    args = ap.parse_args()

    from benchmarks import (bench_fingerprint, bench_kernels,
                            bench_roofline, bench_tuning, bench_workflows)

    modules = [
        ("fingerprint", lambda rows: bench_fingerprint.run(rows)),
        ("tuning", lambda rows: bench_tuning.run(
            rows, n_workloads=(6 if args.quick else 18))),
        ("workflows", lambda rows: bench_workflows.run(rows)),
        ("kernels", lambda rows: bench_kernels.run(rows)),
        ("roofline", lambda rows: bench_roofline.run(rows)),
    ]

    rows = [("name", "us_per_call", "derived")]
    for name, fn in modules:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            fn(rows)
            rows.append((f"{name}.wall_s", "", f"{time.time() - t0:.1f}"))
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            rows.append((f"{name}.ERROR", "", repr(e)))
    for r in rows:
        print(",".join(str(x) for x in r))


if __name__ == "__main__":
    main()
