"""Noise-aware perf-regression gate over the benchmark history.

The *enforce* stage of the record->detect->enforce loop: load the
:class:`benchmarks.history.BenchHistory`, judge the newest run of each
module against EWMA baselines over its prior (non-smoke,
hardware-matched) runs via ``repro.obs.regress``, attribute confirmed
regressions by diffing the companion telemetry snapshots, write the
markdown trend report, and exit nonzero when a regression (or a
bench-module ERROR row) is confirmed.

    PYTHONPATH=src python -m benchmarks.gate [--history BENCH_history.npz]
        [--module fleet] [--report TREND_REPORT.md] [--alpha 0.3]
        [--include-smoke] [--any-hardware] [--check-schema]

``--check-schema`` is the CI fast-lane mode: assert the history loads,
its schema version is readable, every run's snapshot/params JSON
parses, and the trend report renders — no perf verdicts, exit 0 unless
the artifact itself is broken. The full gate (no ``--check-schema``)
is the release-lane job: it enforces the verdicts.
"""

from __future__ import annotations

import argparse
import importlib
import sys
from typing import Dict, List, Optional

from benchmarks.history import BenchHistory

#: the A/A null row bench_fleet ships: two identical disabled-plane
#: daemon runs measured against each other — the same-code noise of
#: the very machine the run executed on (percent)
AA_NOISE_METRIC = "fleet.daemon.obs.noise_pct"


def module_policies(module: str):
    """The bench module's explicit ``POLICIES`` table (normalized), or
    None when the module doesn't declare one."""
    from repro.obs import regress
    try:
        mod = importlib.import_module(f"benchmarks.bench_{module}")
    except ImportError:
        return None
    raw = getattr(mod, "POLICIES", None)
    return None if raw is None else regress.policy_table(raw)


def evaluate_module(history: BenchHistory, module: str, *,
                    run: Optional[int] = None, alpha: float = 0.3,
                    include_smoke: bool = False,
                    match_hardware: bool = True) -> List:
    """Findings for one module's candidate run (newest by default):
    one per metric the run carries, judged against the EWMA fold of
    the prior runs; regressions carry snapshot-diff attribution."""
    import dataclasses

    from repro.obs import metrics, regress

    if run is None:
        run = history.latest_run(module)
    if run is None:
        return []
    overrides = module_policies(module)
    aa_noise = history.value(run, AA_NOISE_METRIC) or 0.0
    findings = []
    baseline_runs = history.run_indices(
        module, include_smoke=include_smoke,
        hardware=(history.hardware_key(run) if match_hardware
                  else None),
        before_run=run)
    attribution = ()
    if len(baseline_runs):
        # attribute against the newest baseline run's snapshot: both
        # runs executed the same workload, so counter families that
        # moved name the regression class
        delta = metrics.registry().snapshot_delta(
            history.snapshot(int(baseline_runs[-1])),
            history.snapshot(run))
        attribution = regress.attribute_delta(delta)
    for metric in history.metrics_for(module, run):
        value = history.value(run, metric)
        base = history.baseline_series(
            module, metric, before_run=run,
            include_smoke=include_smoke,
            match_hardware=match_hardware)
        f = regress.evaluate_series(module, metric, base, value,
                                    overrides=overrides, alpha=alpha,
                                    aa_noise_pct=aa_noise)
        if f.regressed and attribution:
            f = dataclasses.replace(f, attribution=attribution)
        findings.append(f)
    return findings


def evaluate_history(history: BenchHistory, *,
                     module: Optional[str] = None, alpha: float = 0.3,
                     include_smoke: bool = False,
                     match_hardware: bool = True) -> Dict[str, List]:
    """Findings for the newest run of every module (or one module)."""
    modules = [module] if module else history.modules()
    return {m: evaluate_module(history, m, alpha=alpha,
                               include_smoke=include_smoke,
                               match_hardware=match_hardware)
            for m in modules}


def gate_verdict(history: BenchHistory,
                 findings_by_module: Dict[str, List]) -> List[str]:
    """The failures that make the gate exit nonzero: confirmed
    regressions plus bench-module ERROR rows on each module's newest
    run."""
    failures = []
    for module, findings in findings_by_module.items():
        run = history.latest_run(module)
        if run is not None and history.run_info(run)["error"]:
            failures.append(f"{module}: bench module recorded an "
                            "ERROR row on the gated run")
        failures.extend(f.describe() for f in findings if f.regressed)
    return failures


def check_schema(history_path: str) -> BenchHistory:
    """CI fast-lane mode: the history artifact must load and every
    run's JSON columns must parse. Raises on any violation."""
    history = BenchHistory.load(history_path)
    for run in range(len(history)):
        info = history.run_info(run)
        assert info["module"], f"run {run}: empty module name"
        history.params(run)
        snap = history.snapshot(run)
        assert isinstance(snap, dict), f"run {run}: bad snapshot"
    return history


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--history", default="BENCH_history.npz")
    ap.add_argument("--module", default=None,
                    help="gate one module only (default: all)")
    ap.add_argument("--report", default="TREND_REPORT.md",
                    help="markdown trend report path ('' to skip)")
    ap.add_argument("--alpha", type=float, default=0.3,
                    help="EWMA baseline fold factor")
    ap.add_argument("--include-smoke", action="store_true",
                    help="let smoke runs into the baselines (and the "
                         "report trajectory)")
    ap.add_argument("--any-hardware", action="store_true",
                    help="compare across hardware descriptors")
    ap.add_argument("--check-schema", action="store_true",
                    help="only assert history loadability + report "
                         "generation (the CI fast-lane smoke)")
    args = ap.parse_args(argv)

    try:
        history = check_schema(args.history)
    except (OSError, ValueError, KeyError, AssertionError) as e:
        print(f"gate: history artifact {args.history} is broken: {e}",
              file=sys.stderr)
        return 2
    findings = evaluate_history(history, module=args.module,
                                alpha=args.alpha,
                                include_smoke=args.include_smoke,
                                match_hardware=not args.any_hardware)
    if args.report:
        from benchmarks import report
        report.write_trend_report(args.report, history, findings,
                                  include_smoke=args.include_smoke)
        print(f"gate: trend report -> {args.report}")
    if args.check_schema:
        print(f"gate: schema ok — {len(history)} runs, "
              f"{history.n_samples} samples, "
              f"modules {history.modules()}")
        return 0
    for module, fs in sorted(findings.items()):
        for f in fs:
            print(f"  {f.describe()}")
    failures = gate_verdict(history, findings)
    if failures:
        print(f"gate: FAIL — {len(failures)} confirmed "
              "regression(s):", file=sys.stderr)
        for line in failures:
            print(f"  {line}", file=sys.stderr)
        return 1
    print("gate: PASS — no confirmed regressions")
    return 0


if __name__ == "__main__":
    import os
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    sys.exit(main())
