"""Fleet service throughput: per-request loop vs micro-batched vs
sharded scoring (requests/s), written to ``BENCH_fleet.json``.

Three paths score identical streaming per-node re-fingerprinting
rounds (round timestamps follow the stored history) and produce the
same new-row scores:

- ``loop``    — one ``FingerprintEngine.score`` dispatch per request,
  rescoring a per-node history window (the pre-fleet serving path:
  per-request Python preprocessing + one device dispatch each);
- ``batched`` — ``FleetScoringService`` micro-batches every request of
  a round into one stacked dispatch per shape bucket, gathers context
  from the store's feature cache, and scores only the model's exact
  receptive field (P x tag_hops rows per chain — bit-identical to the
  window rescore for streaming rounds, see tests/test_fleet.py);
- ``sharded`` — the same service over all available devices
  (``shard_map`` over the request axis; run under
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to see >1).

A fourth section drives the streaming :class:`IngestionDaemon` over
seeded bursty telemetry (``fleet.daemon.*`` rows): sustained req/s and
p99 queue latency on a clean burst stream, then a fault storm
(dropout, duplicates, reordering, NaN/Inf corruption) against a tight
staging ring — asserting the robustness invariants: ring memory stays
bounded, duplicates and corrupt rows are dropped/quarantined exactly,
and no non-finite value ever reaches the scorer or the store.

A fifth section (``fleet.daemon.obs.*``) reruns the clean daemon
workload with the telemetry plane enabled vs ``obs.disabled()`` and
asserts always-on observability costs <2% sustained req/s.

A sixth section (``fleet.swap.*``) measures the model plane's
zero-downtime hot swap: the same seeded stream runs once steady-state
and once with an identical candidate canaried + promoted mid-stream,
reporting total flush wall time for both (the swap run's canary
shadow scoring and warm dispatches land inside its timed flush
windows) and asserting the stored scores stay bit-identical — the
swap must be invisible in results, and its wall cost explicit. Both
runs pin ``service_time_scale=0`` so flush partitioning is
event-deterministic and the bit-parity check is exact.

Scoring throughput does not depend on the parameter values, so the
model stays untrained (init only).
"""

from __future__ import annotations

import time

DAY = 86400.0

#: Regression-gate policy for this module's tracked rows, consumed by
#: ``benchmarks.gate`` (values: direction or (direction, threshold %);
#: kept as plain literals — bench modules import nothing at module
#: scope). Throughput rows gate at 10% because shared-runner timing
#: noise routinely reaches several percent (the in-run A/A null row
#: ``fleet.daemon.obs.noise_pct`` widens the threshold further on
#: loaded machines); ratio rows that an in-bench assert already
#: bounds, config echoes, and counters are informational.
POLICIES = {
    "fleet.loop.requests_per_s": ("higher", 10.0),
    "fleet.batched_per_round.requests_per_s": ("higher", 10.0),
    "fleet.batched.requests_per_s": ("higher", 10.0),
    "fleet.sharded.requests_per_s": ("higher", 10.0),
    "fleet.batched_speedup": ("higher", 15.0),
    "fleet.sharded_speedup": ("higher", 15.0),
    "fleet.append.rows_per_s": ("higher", 20.0),
    "fleet.append.late_vs_early": "info",  # asserted in-bench (< 6x)
    "fleet.daemon.sustained_req_per_s": ("higher", 10.0),
    "fleet.daemon.p99_queue_latency_s": ("lower", 15.0),
    "fleet.daemon.obs.enabled_req_per_s": ("higher", 10.0),
    "fleet.daemon.obs.disabled_req_per_s": ("higher", 10.0),
    "fleet.daemon.obs.overhead_pct": "info",  # asserted in-bench (<2%+noise)
    "fleet.daemon.obs.noise_pct": "info",  # the A/A null itself
    "fleet.daemon.faulty.peak_staged_rows": "info",
    "fleet.swap.steady_flush_wall_s": ("lower", 25.0),
    "fleet.swap.hotswap_flush_wall_s": ("lower", 25.0),
    "fleet.swap.wall_ratio": "info",  # asserted bit-equal in-bench
    "fleet.wall_s": "info",  # whole-module wall incl. compiles
}


def _setup(n_nodes: int, context_runs: int, seed: int = 0):
    import jax

    from repro.core.graph_data import build_graphs
    from repro.core.model import PeronaConfig, PeronaModel
    from repro.core.preprocess import Preprocessor
    from repro.fingerprint.runner import SuiteRunner

    runner = SuiteRunner(seed=seed)
    machines = {f"fleet-{i}": "e2-medium" for i in range(n_nodes)}
    history = runner.run_frame(machines, runs_per_type=context_runs,
                               stress_fraction=0.2)
    pre = Preprocessor().fit(history)
    batch = build_graphs(history, pre)
    cfg = PeronaConfig(feature_dim=pre.feature_dim,
                       edge_dim=batch.edge.shape[-1])
    model = PeronaModel(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    return machines, history, pre, model, params


def _rounds(machines, n_rounds: int, seed: int = 1):
    """Streaming rounds: round k's timestamps land in day k+1, after
    the day-0 history."""
    from repro.fingerprint.runner import SuiteRunner

    runner = SuiteRunner(seed=seed)
    return [runner.run_frame(machines, runs_per_type=1,
                             t_offset=(k + 1) * DAY)
            for k in range(n_rounds)]


def _split_by_node(frame):
    import numpy as np

    return [(frame.machines[c],
             frame.select(np.nonzero(frame.machine_code == c)[0]))
            for c in np.unique(frame.machine_code)]


def _run_loop(model, params, pre, history, rounds, per_chain: int):
    """Per-request baseline: store-assembled context, one engine
    dispatch per node request. Returns (warm seconds, n_requests)."""
    from repro.fleet import FingerprintStore
    from repro.serving.engine import FingerprintEngine

    engine = FingerprintEngine(model, params, pre)
    store = FingerprintStore()
    store.append(history)

    def one_round(frame):
        n = 0
        first = store.append(frame)
        f = store.frame
        for node, _ in _split_by_node(frame):
            sel, _ = store.context_with_new(first, per_chain,
                                            node=node)
            engine.score(f.select(sel))
            n += 1
        store.compact(per_chain)
        return n

    one_round(rounds[0])  # warm (compile)
    n = 0
    t0 = time.perf_counter()
    for frame in rounds[1:]:
        n += one_round(frame)
    return time.perf_counter() - t0, n


def _run_service(model, params, pre, history, rounds, sharded: bool,
                 burst: int = 1):
    """Micro-batched service path (receptive-field-exact context).
    ``burst`` rounds are queued per flush — the saturated-queue regime
    micro-batching exists for: per-node rounds of one burst coalesce
    into one request, so context is assembled and scored once per
    burst instead of once per round (ancestry closure keeps the scores
    identical to round-by-round flushing). Returns
    (warm seconds, n_node_rounds, svc)."""
    from repro.fleet import FleetScoringService

    svc = FleetScoringService(model, params, pre, sharded=sharded)
    svc.seed_history(history)
    svc.score_round(rounds[0])  # warm (compile)
    n = 0
    t0 = time.perf_counter()
    for i in range(1, len(rounds), burst):
        chunk = rounds[i:i + burst]
        for frame in chunk:
            svc.submit(frame)
        n += len(svc.flush()) * len(chunk)
    return time.perf_counter() - t0, n, svc


def _run_append_throughput(rows, n_rounds: int = 240,
                           n_nodes: int = 3, seed: int = 9):
    """Amortized-append assertion: with growable column buffers and
    incremental per-chain index merges, an append-then-read round must
    stay O(chunk) as the store grows — the per-round cost of the last
    rounds must not drift meaningfully above the early rounds (the old
    consolidate-and-rebuild store was O(total rows) per round, ~10x+
    over this horizon)."""
    import numpy as np

    from repro.fingerprint.runner import SuiteRunner
    from repro.fleet import FingerprintStore

    runner = SuiteRunner(seed=seed)
    machines = {f"ap-{i}": "e2-medium" for i in range(n_nodes)}
    chunks = [runner.run_frame(machines, runs_per_type=1,
                               t_offset=k * DAY)
              for k in range(n_rounds)]
    store = FingerprintStore()
    times = []
    t_all0 = time.perf_counter()
    for chunk in chunks:
        t0 = time.perf_counter()
        first = store.append(chunk)  # append-read cadence: one flush
        store.context_with_new(first, 6)  # + one indexed read
        times.append(time.perf_counter() - t0)
    t_all = time.perf_counter() - t_all0
    early = float(np.median(times[5:25]))
    late = float(np.median(times[-20:]))
    ratio = late / max(early, 1e-9)
    rps = len(store) / max(t_all, 1e-9)
    rows.append(("fleet.append.rows_per_s", "", f"{rps:.0f}"))
    rows.append(("fleet.append.late_vs_early", "", f"{ratio:.2f}x"))
    # amortized appends measure ~1x; the old consolidate-and-rebuild
    # store measured ~7-20x over this horizon. The threshold leaves
    # generous headroom for noisy shared CI runners (the timed rounds
    # are microseconds-scale) while still catching an O(total) return.
    assert ratio < 6.0, (
        f"append round cost grew {ratio:.1f}x over {n_rounds} rounds — "
        "store appends are no longer amortized O(chunk)")


def _run_daemon(rows, machines, history, pre, model, params,
                quick: bool):
    """Streaming-daemon section: sustained req/s + p99 queue latency
    under seeded bursty arrivals, and the fault-path counters (shed /
    degraded / quarantined) under an injected fault storm with a tight
    staging ring. Asserts the robustness invariants the daemon exists
    for: bounded ring memory and zero corrupt rows reaching the
    scorer."""
    import numpy as np

    from repro.fleet import (FaultPlan, FleetScoringService,
                             IngestionDaemon, fleet_telemetry,
                             inject_faults)

    n_rounds = 6 if quick else 10

    # clean, bursty arrivals: honest queue latencies via the virtual
    # clock folding in measured flush durations
    svc = FleetScoringService(model, params, pre, sharded=False)
    svc.seed_history(history)
    svc.score_round(fleet_telemetry(  # warm (compile)
        machines, rounds=1, runs_per_type=1, seed=90)[0].frame)
    daemon = IngestionDaemon(svc, capacity_rows=64 * len(machines),
                             flush_interval=0.25, min_flush_gap=0.02)
    events = fleet_telemetry(machines, rounds=n_rounds,
                             runs_per_type=1, seed=91, interval=1.0,
                             jitter=0.3)
    bursty, _ = inject_faults(events, FaultPlan(seed=92, burst=0.3,
                                                burst_window=2.0))
    daemon.run(bursty)
    st = daemon.stats()
    req_s = st["events_seen"] / max(st["run_wall_s"], 1e-9)
    rows.append(("fleet.daemon.sustained_req_per_s",
                 f"{st['run_wall_s'] / max(st['events_seen'], 1) * 1e6:.0f}",
                 f"{req_s:.1f}"))
    rows.append(("fleet.daemon.p99_queue_latency_s", "",
                 f"{st['latency_p99']:.4f}"))
    rows.append(("fleet.daemon.events", "", st["events_seen"]))
    assert st["peak_staged_rows"] <= st["capacity_rows"]

    # fault storm against a tight ring: the backpressure ladder and
    # the quarantine must hold the line
    svc_f = FleetScoringService(model, params, pre, sharded=False)
    svc_f.seed_history(history)
    capacity = 4 * len(machines)
    # overload regime: row trigger off, long deadline, gated consumer
    # -> arrivals outrun the scorer and the ladder must hold the ring
    daemon_f = IngestionDaemon(svc_f, capacity_rows=capacity,
                               flush_interval=1.5,
                               flush_rows=1 << 30,
                               min_flush_gap=1.0, degrade_after=3)
    faulty, log = inject_faults(
        fleet_telemetry(machines, rounds=n_rounds, runs_per_type=2,
                        seed=93, interval=0.2, jitter=0.1),
        FaultPlan(seed=94, dropout=0.05, delay=0.2, duplicate=0.25,
                  reorder=0.2, corrupt=0.2, burst=0.3,
                  burst_window=1.0))
    daemon_f.run(faulty)
    st_f = daemon_f.stats()
    rows.append(("fleet.daemon.faulty.peak_staged_rows", "",
                 f"{st_f['peak_staged_rows']}/{capacity}"))
    rows.append(("fleet.daemon.faulty.shed_rows", "",
                 st_f["shed_rows"]))
    rows.append(("fleet.daemon.faulty.degraded_flushes", "",
                 st_f["degraded_flushes"]))
    rows.append(("fleet.daemon.faulty.duplicates_dropped", "",
                 st_f["duplicates_dropped"]))
    rows.append(("fleet.daemon.faulty.quarantined_rows", "",
                 svc_f.stats["quarantined_rows"]))
    # robustness invariants (the acceptance criteria of the daemon)
    assert st_f["peak_staged_rows"] <= capacity, (
        "staging ring exceeded its bound under the fault storm")
    assert st_f["duplicates_dropped"] == len(log.duplicated)
    assert svc_f.stats["quarantined_rows"] == log.corrupted_rows
    f = svc_f.store.frame
    assert np.isfinite(np.where(f.metrics_present, f.metrics,
                                0.0)).all(), (
        "corrupt rows reached the scorer/store")
    return {"daemon_rounds": n_rounds, "daemon_capacity": capacity,
            "fault_counts": log.counts()}


def _run_obs_overhead(rows, machines, history, pre, model, params,
                      quick: bool):
    """Telemetry-plane overhead: the same clean daemon workload with
    the obs plane enabled (default) vs ``obs.disabled()``, asserting
    enabled sustained req/s stays within 2% of disabled.

    Intake pays no per-event registry cost by design (daemon mirrors
    delta-sync at flush boundaries), so the enabled plane adds only
    per-flush work — one span, one batched latency observe, a handful
    of counter adds. Measuring that at a 2% bound on shared runners
    (where identical runs vary by >5%) needs three defenses:

    - **aggregate rates over many short interleaved reps** (order
      rotated every rep, GC collected before each and disabled during)
      so scheduler phases and store growth hit every variant equally;
    - **a second disabled variant as an A/A null**: the gap between
      the two same-code aggregates is the measured noise floor of this
      very run, and the assertion bound widens by exactly that gap —
      tight on quiet CI runners, honest on loaded ones (the gap is
      reported as ``fleet.daemon.obs.noise_pct``);
    - ``service_time_scale=0`` pins the flush cadence (see one_run).
    """
    import dataclasses
    import gc

    from repro import obs
    from repro.fleet import (FleetScoringService, IngestionDaemon,
                             fleet_telemetry)

    n_rounds = 10 if quick else 16
    svc = FleetScoringService(model, params, pre, sharded=False)
    svc.seed_history(history)
    svc.score_round(fleet_telemetry(  # warm (compile)
        machines, rounds=1, runs_per_type=1, seed=80)[0].frame)
    base = fleet_telemetry(machines, rounds=n_rounds, runs_per_type=1,
                           seed=81, interval=0.5, jitter=0.2)
    uid_offset = 0

    def one_run():
        # fresh uids per repetition: the shared store dedups by uid,
        # so replaying the same telemetry would drop every event
        nonlocal uid_offset
        uid_offset += 1_000_000
        events = [dataclasses.replace(e, uid=e.uid + uid_offset)
                  for e in base]
        # service_time_scale=0: the virtual clock advances on arrivals
        # only, so the flush cadence — and therefore the pow2 scoring
        # buckets — is IDENTICAL across reps. With measured flush
        # durations folded in (the default), a slow flush shifts the
        # next deadline, changes a bucket size, and triggers a fresh
        # compile inside the measured window of whichever variant got
        # there first — swamping a 2% comparison.
        daemon = IngestionDaemon(svc,
                                 capacity_rows=64 * len(machines),
                                 flush_interval=0.25,
                                 min_flush_gap=0.02,
                                 service_time_scale=0.0)
        daemon.run(events)
        return daemon.stats()["run_wall_s"]

    def disabled_run():
        with obs.disabled():
            return one_run()

    one_run()  # warm the append/flush path on the shared store
    wall = {"on": 0.0, "off": 0.0, "null": 0.0}
    variant = {"on": one_run, "off": disabled_run,
               "null": disabled_run}
    order = ["on", "off", "null"]
    reps = 12 if quick else 18
    gc.collect()
    gc.disable()
    try:
        for rep in range(reps):
            gc.collect()
            for key in order[rep % 3:] + order[:rep % 3]:
                wall[key] += variant[key]()
    finally:
        gc.enable()
    rate = {k: reps * len(base) / max(w, 1e-9)
            for k, w in wall.items()}
    noise = (abs(rate["off"] - rate["null"])
             / max(rate["off"], rate["null"], 1e-9) * 100.0)
    overhead = (1.0 - rate["on"] / max(rate["off"], 1e-9)) * 100.0
    rows.append(("fleet.daemon.obs.enabled_req_per_s", "",
                 f"{rate['on']:.1f}"))
    rows.append(("fleet.daemon.obs.disabled_req_per_s", "",
                 f"{rate['off']:.1f}"))
    rows.append(("fleet.daemon.obs.overhead_pct", "",
                 f"{overhead:.2f}"))
    rows.append(("fleet.daemon.obs.noise_pct", "", f"{noise:.2f}"))
    assert overhead < 2.0 + noise, (
        f"telemetry plane costs {overhead:.2f}% sustained daemon "
        f"req/s (enabled vs disabled; A/A noise floor {noise:.2f}%) "
        "— budget is <2% above the measured noise floor")


def _run_swap(rows, machines, history, pre, model, params,
              quick: bool):
    """Hot-swap cost: total flush wall time of the same seeded stream
    steady-state vs with a mid-stream canary + promote (identical
    candidate). The swap run pays shadow scoring + warm dispatches
    inside the daemon's timed flush windows — that cost shows up in
    its flush wall total — while the stored scores must stay
    bit-identical to the steady run. ``service_time_scale=0`` pins
    the virtual clock so flush partitioning (and therefore per-row
    scoring context) is a pure function of the event stream: wall
    noise can't shift flush boundaries between the two runs."""
    import tempfile

    import numpy as np

    from repro.fleet import (FleetScoringService, IngestionDaemon,
                             ModelPlane, fleet_telemetry)

    n_rounds = 6 if quick else 10

    def one_run(with_swap: bool):
        svc = FleetScoringService(model, params, pre, sharded=False)
        svc.seed_history(history)
        svc.score_round(fleet_telemetry(  # warm (compile)
            machines, rounds=1, runs_per_type=1, seed=70)[0].frame)
        daemon = IngestionDaemon(svc,
                                 capacity_rows=64 * len(machines),
                                 flush_interval=0.25,
                                 min_flush_gap=0.02,
                                 service_time_scale=0.0)
        events = fleet_telemetry(machines, rounds=n_rounds,
                                 runs_per_type=1, seed=71,
                                 interval=1.0, jitter=0.3)
        if not with_swap:
            daemon.run(events)
        else:
            plane = ModelPlane(
                svc, tempfile.mkdtemp(prefix="bench-registry-"),
                daemon=daemon, canary_flushes=1, watch_flushes=2,
                min_health_shift=1.0, latency_budget=100.0,
                # the candidate is the incumbent, so the canary gate
                # must not reject on the model's own baseline alarm
                # rate; likewise the drift-retrain loop would submit
                # its own candidate mid-stream and break both the
                # promotions==1 contract and the bit-parity assert
                fp_budget=1.0, drift_flag_flushes=10**9)
            plane.bootstrap(params)
            k = len(events) // 2
            daemon.run(events[:k], drain=False)
            plane.submit_candidate(params, source="bench")
            daemon.run(events[k:], drain=True)
            assert plane.status()["promotions"] == 1, (
                "bench candidate was not promoted")
        return daemon.stats(), svc

    st_a, svc_a = one_run(False)
    st_b, svc_b = one_run(True)
    # the swap must be invisible in the data plane
    assert st_a["events_seen"] == st_b["events_seen"]
    assert len(svc_a.store) == len(svc_b.store), (
        "hot-swap run scored a different number of rows")
    assert np.array_equal(svc_a.store.anomaly, svc_b.store.anomaly,
                          equal_nan=True), (
        "hot-swap run's stored scores diverged from steady state")
    wall_a, wall_b = st_a["flush_wall_s"], st_b["flush_wall_s"]
    rows.append(("fleet.swap.steady_flush_wall_s", "", f"{wall_a:.4f}"))
    rows.append(("fleet.swap.hotswap_flush_wall_s", "",
                 f"{wall_b:.4f}"))
    rows.append(("fleet.swap.wall_ratio", "",
                 f"{wall_b / max(wall_a, 1e-9):.2f}x"))


def run(rows, n_nodes: int = 32, context_runs: int = 16,
        n_rounds: int = 4, quick: bool = False):
    import jax

    if quick:
        n_nodes, n_rounds = 8, 5
    window = 16  # per-chain history window of the per-request loop
    burst = 4  # queued rounds per flush in the saturated regime
    machines, history, pre, model, params = _setup(n_nodes,
                                                   context_runs)

    t_loop, n_loop = _run_loop(model, params, pre, history,
                               _rounds(machines, n_rounds), window)
    t_rr, n_rr, _ = _run_service(model, params, pre, history,
                                 _rounds(machines, n_rounds),
                                 sharded=False, burst=1)
    t_bat, n_bat, svc = _run_service(model, params, pre, history,
                                     _rounds(machines,
                                             n_rounds * burst),
                                     sharded=False, burst=burst)
    t_shd, n_shd, svc_s = _run_service(model, params, pre, history,
                                       _rounds(machines,
                                               n_rounds * burst),
                                       sharded=True, burst=burst)

    rps_loop = n_loop / max(t_loop, 1e-9)
    rps_rr = n_rr / max(t_rr, 1e-9)
    rps_bat = n_bat / max(t_bat, 1e-9)
    rps_shd = n_shd / max(t_shd, 1e-9)
    rows.append(("fleet.loop.requests_per_s",
                 f"{t_loop / max(n_loop, 1) * 1e6:.0f}",
                 f"{rps_loop:.1f}"))
    rows.append(("fleet.batched_per_round.requests_per_s",
                 f"{t_rr / max(n_rr, 1) * 1e6:.0f}",
                 f"{rps_rr:.1f}"))
    rows.append(("fleet.batched.requests_per_s",
                 f"{t_bat / max(n_bat, 1) * 1e6:.0f}",
                 f"{rps_bat:.1f}"))
    rows.append(("fleet.sharded.requests_per_s",
                 f"{t_shd / max(n_shd, 1) * 1e6:.0f}",
                 f"{rps_shd:.1f}"))
    rows.append(("fleet.batched_speedup", "",
                 f"{rps_bat / max(rps_loop, 1e-9):.1f}x"))
    rows.append(("fleet.sharded_speedup", "",
                 f"{rps_shd / max(rps_loop, 1e-9):.1f}x"))
    rows.append(("fleet.burst_rounds", "", burst))
    rows.append(("fleet.devices", "", jax.device_count()))
    rows.append(("fleet.requests", "", n_bat))
    rows.append(("fleet.batched.dispatches", "",
                 svc.stats["dispatches"]))
    rows.append(("fleet.batched.traces", "", svc.trace_count))
    rows.append(("fleet.store_rows", "", svc.stats["store_rows"]))
    _run_append_throughput(rows, n_rounds=120 if quick else 240)
    daemon_params = _run_daemon(rows, machines, history, pre, model,
                                params, quick)
    _run_obs_overhead(rows, machines, history, pre, model, params,
                      quick)
    _run_swap(rows, machines, history, pre, model, params, quick)
    # workload parameters, recorded into BENCH_fleet.json by run.py
    return {"n_nodes": n_nodes, "context_runs": context_runs,
            "n_rounds": n_rounds, "burst": burst, "window": window,
            "devices": jax.device_count(), **daemon_params}
