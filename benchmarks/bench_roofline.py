"""Roofline summary from the dry-run artifacts (deliverable g)."""

from __future__ import annotations

import json
import os
from pathlib import Path

ART = Path(__file__).resolve().parent.parent / "artifacts" / "dryrun"


def run(rows):
    if not ART.is_dir():
        rows.append(("roofline", "", "artifacts missing; run "
                     "python -m repro.launch.dryrun --all"))
        return
    cells = []
    for f in sorted(ART.glob("*--single.json")):
        rec = json.loads(f.read_text())
        if rec.get("status") != "ok" or "roofline" not in rec:
            continue
        cells.append(rec)
    for rec in cells:
        r = rec["roofline"]
        name = f"roofline.{rec['arch']}.{rec['shape']}"
        derived = (f"bottleneck={r['bottleneck']};"
                   f"compute={r['compute_s']:.3e}s;"
                   f"memory={r['memory_s']:.3e}s;"
                   f"collective={r['collective_s']:.3e}s;"
                   f"useful_flops_ratio="
                   f"{rec.get('useful_flops_ratio') or 0:.3f}")
        rows.append((name, "", derived))
    rated = [c for c in cells if c.get("useful_flops_ratio")]
    if rated:
        worst = min(rated, key=lambda c: c["useful_flops_ratio"])
        rows.append(("roofline.worst_useful_ratio", "",
                     f"{worst['arch']}.{worst['shape']}"))
