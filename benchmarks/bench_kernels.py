"""Kernel micro-benchmarks: us/call of the jitted oracle path on CPU
(wall-time of the Pallas kernels is only meaningful on TPU; here the
kernels are *validated* in interpret mode — see tests/test_kernels.py —
and the oracle timing tracks the compute the kernel replaces)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _time(fn, *args, iters: int = 5) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run(rows):
    from repro.kernels.edge_softmax import ref as es_ref
    from repro.kernels.flash_attention import ref as fa_ref
    from repro.kernels.mlstm import ref as ml_ref
    from repro.kernels.rg_lru import ref as lru_ref

    ks = jax.random.split(jax.random.PRNGKey(0), 8)

    B, H, S, D = 1, 8, 1024, 64
    q = jax.random.normal(ks[0], (B, H, S, D), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, H, S, D), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, H, S, D), jnp.bfloat16)
    fa = jax.jit(lambda q, k, v: fa_ref.attention(q, k, v, causal=True))
    rows.append(("kernel.flash_attention.b1h8s1024d64",
                 f"{_time(fa, q, k, v):.0f}", "interpret-validated"))

    a = jax.random.uniform(ks[3], (4, 2048, 1024), jnp.float32, 0.5, 0.99)
    b = jax.random.normal(ks[4], (4, 2048, 1024), jnp.float32)
    lru = jax.jit(lambda a, b: lru_ref.linear_scan(a, b))
    rows.append(("kernel.rg_lru.b4s2048c1024",
                 f"{_time(lru, a, b):.0f}", "interpret-validated"))

    BH, S2, hd = 8, 1024, 128
    q2 = jax.random.normal(ks[5], (BH, S2, hd))
    k2 = jax.random.normal(ks[6], (BH, S2, hd)) / jnp.sqrt(hd)
    v2 = jax.random.normal(ks[7], (BH, S2, hd))
    li = jnp.zeros((BH, S2))
    lf = jnp.full((BH, S2), -0.05)
    ml = jax.jit(lambda *a: ml_ref.mlstm_chunkwise(*a, chunk=64)[0])
    rows.append(("kernel.mlstm.bh8s1024hd128",
                 f"{_time(ml, q2, k2, v2, li, lf):.0f}",
                 "interpret-validated"))

    N, P, F = 4096, 3, 32
    qg = jax.random.normal(ks[0], (N, F))
    kg = jax.random.normal(ks[1], (N, P, F))
    vg = jax.random.normal(ks[2], (N, P, F))
    mask = jnp.ones((N, P), bool)
    es = jax.jit(lambda *a: es_ref.edge_softmax_aggregate(*a)[0])
    rows.append(("kernel.edge_softmax.n4096p3f32",
                 f"{_time(es, qg, kg, vg, mask):.0f}",
                 "interpret-validated"))
