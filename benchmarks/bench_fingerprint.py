"""Paper §IV-C: fingerprinting quality table (MSE, type acc, outlier F1)
plus pipeline throughput: columnar acquisition vs the seed record loop,
and batched scoring through the jit'd FingerprintEngine."""

from __future__ import annotations

import time


def _acquisition_rows(rows, runs_per_type: int = 100):
    from repro.fingerprint.runner import SuiteRunner

    machines = {f"node-{i}": "e2-medium" for i in range(1, 4)}

    t0 = time.time()
    ref = SuiteRunner(seed=0).run_reference(machines,
                                            runs_per_type=runs_per_type,
                                            stress_fraction=0.2)
    t_ref = time.time() - t0
    t0 = time.time()
    frame = SuiteRunner(seed=0).run_frame(machines,
                                          runs_per_type=runs_per_type,
                                          stress_fraction=0.2)
    t_col = time.time() - t0
    n = len(frame)
    assert n == len(ref)
    rows.append(("fingerprint.acquire_record_loop",
                 f"{t_ref * 1e6:.0f}", f"{n / max(t_ref, 1e-9):.0f}/s"))
    rows.append(("fingerprint.acquire_columnar",
                 f"{t_col * 1e6:.0f}", f"{n / max(t_col, 1e-9):.0f}/s"))
    rows.append(("fingerprint.acquire_speedup", "",
                 f"{t_ref / max(t_col, 1e-9):.1f}x"))
    return frame


def _scoring_rows(rows, model, params, pre, frame):
    from repro.serving.engine import FingerprintEngine

    engine = FingerprintEngine(model, params, pre)
    t0 = time.time()
    engine.score(frame)  # includes the one compile
    t_first = time.time() - t0
    reps = 5
    t0 = time.time()
    for _ in range(reps):
        engine.score(frame)
    t_warm = (time.time() - t0) / reps
    n = len(frame)
    rows.append(("fingerprint.score_first_round",
                 f"{t_first * 1e6:.0f}", f"{n / max(t_first, 1e-9):.0f}/s"))
    rows.append(("fingerprint.score_warm_round",
                 f"{t_warm * 1e6:.0f}", f"{n / max(t_warm, 1e-9):.0f}/s"))
    rows.append(("fingerprint.score_traces", "", engine.trace_count))


def run(rows, runs_per_type: int = 100, epochs: int = 100):
    from repro.core.graph_data import build_graphs, chronological_split
    from repro.core.model import PeronaConfig, PeronaModel
    from repro.core.preprocess import Preprocessor
    from repro.core.trainer import evaluate, train_perona

    frame = _acquisition_rows(rows, runs_per_type)
    train_r, val_r, test_r = chronological_split(frame)
    pre = Preprocessor().fit(train_r)
    tb, vb, teb = (build_graphs(r, pre) for r in (train_r, val_r, test_r))
    cfg = PeronaConfig(feature_dim=pre.feature_dim,
                       edge_dim=tb.edge.shape[-1])
    model = PeronaModel(cfg)
    t0 = time.time()
    res = train_perona(model, tb, vb, epochs=epochs, seed=0)
    train_us = (time.time() - t0) * 1e6
    m = evaluate(model, res.params, teb)
    rows.append(("fingerprint.metrics_raw", "", pre.raw_feature_count))
    rows.append(("fingerprint.metrics_selected", "", pre.n_selected))
    rows.append(("fingerprint.train", f"{train_us:.0f}", "paper<=100ep"))
    rows.append(("fingerprint.test_mse", "", f"{m['mse']:.4f}"))
    rows.append(("fingerprint.type_accuracy", "",
                 f"{m['type_accuracy']:.4f}"))
    rows.append(("fingerprint.f1_normal", "", f"{m['f1_normal']:.4f}"))
    rows.append(("fingerprint.f1_outlier", "", f"{m['f1_outlier']:.4f}"))
    rows.append(("fingerprint.weighted_accuracy", "",
                 f"{m['weighted_accuracy']:.4f}"))
    _scoring_rows(rows, model, res.params, pre, frame)
