"""Paper §IV-C: fingerprinting quality table (MSE, type acc, outlier F1)."""

from __future__ import annotations

import time


def run(rows):
    from repro.core.graph_data import build_graphs, chronological_split
    from repro.core.model import PeronaConfig, PeronaModel
    from repro.core.preprocess import Preprocessor
    from repro.core.trainer import evaluate, train_perona
    from repro.fingerprint.runner import paper_acquisition

    records = paper_acquisition(seed=0)
    train_r, val_r, test_r = chronological_split(records)
    pre = Preprocessor().fit(train_r)
    tb, vb, teb = (build_graphs(r, pre) for r in (train_r, val_r, test_r))
    cfg = PeronaConfig(feature_dim=pre.feature_dim,
                       edge_dim=tb.edge.shape[-1])
    model = PeronaModel(cfg)
    t0 = time.time()
    res = train_perona(model, tb, vb, epochs=100, seed=0)
    train_us = (time.time() - t0) * 1e6
    m = evaluate(model, res.params, teb)
    rows.append(("fingerprint.metrics_raw", "", pre.raw_feature_count))
    rows.append(("fingerprint.metrics_selected", "", pre.n_selected))
    rows.append(("fingerprint.train", f"{train_us:.0f}", "paper<=100ep"))
    rows.append(("fingerprint.test_mse", "", f"{m['mse']:.4f}"))
    rows.append(("fingerprint.type_accuracy", "",
                 f"{m['type_accuracy']:.4f}"))
    rows.append(("fingerprint.f1_normal", "", f"{m['f1_normal']:.4f}"))
    rows.append(("fingerprint.f1_outlier", "", f"{m['f1_outlier']:.4f}"))
    rows.append(("fingerprint.weighted_accuracy", "",
                 f"{m['weighted_accuracy']:.4f}"))
