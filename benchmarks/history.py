"""Append-only columnar benchmark-history store (ALOJA-style).

ALOJA built its value on a persistent repository of benchmark
executions with predictive analytics on top; this is that repository
for the repo's *own* perf trajectory. Every ``BENCH_*.json`` payload
``benchmarks/run.py`` writes — headline rows, workload params, the
attached ``obs`` registry snapshot, and the provenance stamp (git SHA,
dirty flag, device/core counts, backend) — ingests into one
:class:`BenchHistory`, keyed by (module, metric, run).

Same struct-of-arrays idiom as ``fleet.store.FingerprintStore``:
interned vocabularies (modules, metric names), a capacity-doubling
sample buffer for the (run, metric, value) triples — the axis that
grows by hundreds of rows per ingested run — and plain per-run lists
for the low-cardinality provenance/JSON columns. Series reads are pure
gathers; persistence is one compressed ``.npz`` via the store's
``atomic_savez`` (a crash mid-save never corrupts the previous
history).

Smoke runs (``run.py --smoke``) ingest *tagged* and are excluded from
gate baselines by default — CI's minimal-workload numbers must never
anchor the trajectory a full run is judged against. Baselines also
filter to the candidate's hardware descriptor (device_count,
cpu_cores, backend) so a laptop run is never judged against the CI
fleet's numbers.
"""

from __future__ import annotations

import json
import os
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.fleet.store import atomic_savez

_MIN_CAP = 256

#: schema version stamped into every saved history
SCHEMA_VERSION = 1

#: per-run scalar columns, in save order: (payload key, dtype, default)
_RUN_FIELDS: Tuple[Tuple[str, type, object], ...] = (
    ("unix_time", float, 0.0),
    ("git_sha", str, "unknown"),
    ("dirty", bool, False),
    ("smoke", bool, False),
    ("quick", bool, False),
    ("device_count", int, 0),
    ("cpu_cores", int, 0),
    ("backend", str, "unknown"),
)


def parse_value(raw) -> Optional[float]:
    """Best-effort numeric parse of a bench row value: plain numbers
    pass through, ``"14.3x"`` speedups drop the suffix, ``"432/432"``
    parity / occupancy fractions become their ratio, anything
    non-numeric (an ERROR repr, an empty cell) is None."""
    if isinstance(raw, bool):
        return float(raw)
    if isinstance(raw, (int, float)):
        v = float(raw)
        return v if np.isfinite(v) else None
    if not isinstance(raw, str):
        return None
    s = raw.strip()
    if not s:
        return None
    if s.endswith(("x", "×")):
        s = s[:-1]
    if "/" in s:
        num, _, den = s.partition("/")
        try:
            d = float(den)
            return float(num) / d if d else None
        except ValueError:
            return None
    try:
        v = float(s)
    except ValueError:
        return None
    return v if np.isfinite(v) else None


class _F64Vec:
    """Growable float64 column (amortized O(1) extend) — the same
    capacity-doubling idiom as the fingerprint store's buffers."""

    __slots__ = ("a", "n")

    def __init__(self, dtype=np.float64):
        self.a = np.empty(_MIN_CAP, dtype)
        self.n = 0

    def view(self) -> np.ndarray:
        return self.a[: self.n]

    def extend(self, vals) -> None:
        vals = np.asarray(vals, self.a.dtype)
        need = self.n + len(vals)
        if need > len(self.a):
            grown = np.empty(max(2 * len(self.a), need), self.a.dtype)
            grown[: self.n] = self.a[: self.n]
            self.a = grown
        self.a[self.n: need] = vals
        self.n = need


class BenchHistory:
    """Append-only history of benchmark runs, columnar over samples."""

    def __init__(self):
        # vocabularies (grow in place; code -> name)
        self._modules: List[str] = []
        self._mod_idx: Dict[str, int] = {}
        self._metrics: List[str] = []
        self._met_idx: Dict[str, int] = {}
        # per-run columns (low cardinality: plain lists)
        self._run_module = _F64Vec(np.int32)
        self._run_fields: Dict[str, list] = {k: []
                                             for k, _, _ in _RUN_FIELDS}
        self._run_error: List[bool] = []
        self._params_json: List[str] = []
        self._snapshot_json: List[str] = []
        # sample columns (the growing axis: SoA buffers)
        self._s_run = _F64Vec(np.int32)
        self._s_metric = _F64Vec(np.int32)
        self._s_value = _F64Vec(np.float64)

    # ------------------------------------------------------------ basics
    def __len__(self) -> int:
        return len(self._params_json)

    @property
    def n_samples(self) -> int:
        return self._s_value.n

    def modules(self) -> List[str]:
        return sorted(self._modules)

    @staticmethod
    def _intern(name: str, vocab: List[str], idx: Dict[str, int]) -> int:
        code = idx.get(name)
        if code is None:
            code = len(vocab)
            vocab.append(name)
            idx[name] = code
        return code

    # ------------------------------------------------------------ append
    def append(self, payload: Dict[str, object], *,
               smoke: Optional[bool] = None) -> int:
        """Ingest one ``BENCH_*.json`` payload; returns the run index.
        Provenance fields come from the payload top level (stamped by
        ``run.py``); ``smoke`` overrides the payload's own tag (tests
        and backfills of pre-provenance artifacts)."""
        run = len(self)
        module = str(payload.get("module", "unknown"))
        self._run_module.extend([self._intern(module, self._modules,
                                              self._mod_idx)])
        for key, typ, default in _RUN_FIELDS:
            val = payload.get(key, default)
            if key == "smoke" and smoke is not None:
                val = smoke
            self._run_fields[key].append(typ(val))
        rows = payload.get("rows") or []
        error = False
        codes, values = [], []
        for row in rows:
            name = str(row.get("name", ""))
            if name.endswith(".ERROR"):
                error = True
                continue
            v = parse_value(row.get("derived"))
            if v is None:
                v = parse_value(row.get("us_per_call"))
            if v is None:
                continue
            codes.append(self._intern(name, self._metrics,
                                      self._met_idx))
            values.append(v)
        self._run_error.append(error)
        self._params_json.append(json.dumps(payload.get("params"),
                                            sort_keys=True))
        self._snapshot_json.append(json.dumps(payload.get("metrics")
                                              or {}, sort_keys=True))
        self._s_run.extend(np.full(len(codes), run, np.int32))
        self._s_metric.extend(codes)
        self._s_value.extend(values)
        return run

    # -------------------------------------------------------------- reads
    def run_info(self, run: int) -> Dict[str, object]:
        """Provenance + tags of one run."""
        info: Dict[str, object] = {
            "module": self._modules[int(self._run_module.view()[run])],
            "error": self._run_error[run],
        }
        for key, _, _ in _RUN_FIELDS:
            info[key] = self._run_fields[key][run]
        return info

    def params(self, run: int) -> object:
        return json.loads(self._params_json[run])

    def snapshot(self, run: int) -> Dict[str, object]:
        """The obs registry snapshot attached to the run's payload
        (the attribution pass diffs these)."""
        return json.loads(self._snapshot_json[run])

    def hardware_key(self, run: int) -> Tuple[int, int, str]:
        """Hostname-free hardware descriptor runs are compared
        within."""
        return (self._run_fields["device_count"][run],
                self._run_fields["cpu_cores"][run],
                self._run_fields["backend"][run])

    def run_indices(self, module: Optional[str] = None, *,
                    include_smoke: bool = True,
                    hardware: Optional[Tuple[int, int, str]] = None,
                    before_run: Optional[int] = None) -> np.ndarray:
        """Run indices, chronological by (unix_time, run). Filters:
        module, smoke exclusion, hardware descriptor, and append order
        (``before_run`` — "history as of that run")."""
        n = len(self)
        sel = np.ones(n, bool)
        if module is not None:
            code = self._mod_idx.get(module)
            if code is None:
                return np.zeros(0, np.int64)
            sel &= self._run_module.view() == code
        if not include_smoke:
            sel &= ~np.asarray(self._run_fields["smoke"], bool)
        if hardware is not None:
            hw = np.asarray([self.hardware_key(r) == hardware
                             for r in range(n)], bool)
            sel &= hw
        runs = np.nonzero(sel)[0]
        if before_run is not None:
            runs = runs[runs < before_run]
        times = np.asarray(self._run_fields["unix_time"],
                           np.float64)[runs]
        return runs[np.lexsort((runs, times))].astype(np.int64)

    def latest_run(self, module: Optional[str] = None, *,
                   include_smoke: bool = True) -> Optional[int]:
        runs = self.run_indices(module, include_smoke=include_smoke)
        return int(runs[-1]) if len(runs) else None

    def metrics_for(self, module: str, run: Optional[int] = None
                    ) -> List[str]:
        """Metric names recorded for a module (or for one run of it),
        in first-seen order."""
        runs = (self.run_indices(module) if run is None
                else np.asarray([run]))
        mask = np.isin(self._s_run.view(), runs)
        codes = np.unique(self._s_metric.view()[mask])
        return [self._metrics[c] for c in sorted(codes)]

    def value(self, run: int, metric: str) -> Optional[float]:
        """One (run, metric) cell (None when the run lacks the row)."""
        code = self._met_idx.get(metric)
        if code is None:
            return None
        mask = ((self._s_run.view() == run)
                & (self._s_metric.view() == code))
        hits = np.nonzero(mask)[0]
        return float(self._s_value.view()[hits[-1]]) if len(hits) \
            else None

    def series(self, module: str, metric: str, *,
               include_smoke: bool = False,
               hardware: Optional[Tuple[int, int, str]] = None,
               before_run: Optional[int] = None
               ) -> Tuple[np.ndarray, np.ndarray]:
        """(run indices, values), chronological, of one metric's
        trajectory — smoke runs excluded by default."""
        runs = self.run_indices(module, include_smoke=include_smoke,
                                hardware=hardware,
                                before_run=before_run)
        code = self._met_idx.get(metric)
        if code is None or len(runs) == 0:
            return np.zeros(0, np.int64), np.zeros(0)
        mask = (self._s_metric.view() == code) \
            & np.isin(self._s_run.view(), runs)
        s_runs = self._s_run.view()[mask].astype(np.int64)
        s_vals = self._s_value.view()[mask]
        # order samples like `runs` (chronological), keep runs that
        # actually carry the metric
        pos = {int(r): i for i, r in enumerate(runs)}
        order = np.argsort([pos[int(r)] for r in s_runs],
                           kind="stable")
        return s_runs[order], s_vals[order]

    def baseline_series(self, module: str, metric: str, *,
                        before_run: int,
                        include_smoke: bool = False,
                        match_hardware: bool = True) -> np.ndarray:
        """The values a candidate run is judged against: every earlier
        run of the module carrying the metric — smoke runs excluded by
        default, filtered to the candidate's hardware descriptor
        unless ``match_hardware=False``."""
        hardware = (self.hardware_key(before_run) if match_hardware
                    else None)
        _, vals = self.series(module, metric,
                              include_smoke=include_smoke,
                              hardware=hardware, before_run=before_run)
        return vals

    # ---------------------------------------------------------- save/load
    def save(self, path: str) -> None:
        """Durable one-file snapshot (compressed .npz, atomic)."""
        payload: Dict[str, np.ndarray] = {
            "version": np.asarray(SCHEMA_VERSION),
            "modules": np.asarray(self._modules, dtype=str),
            "metric_names": np.asarray(self._metrics, dtype=str),
            "run_module": self._run_module.view(),
            "run_error": np.asarray(self._run_error, bool),
            "params_json": np.asarray(self._params_json, dtype=str),
            "snapshot_json": np.asarray(self._snapshot_json,
                                        dtype=str),
            "s_run": self._s_run.view(),
            "s_metric": self._s_metric.view(),
            "s_value": self._s_value.view(),
        }
        for key, typ, _ in _RUN_FIELDS:
            dtype = {float: np.float64, bool: bool, int: np.int64,
                     str: str}[typ]
            payload[f"run_{key}"] = np.asarray(self._run_fields[key],
                                               dtype=dtype)
        atomic_savez(path, **payload)

    @classmethod
    def load(cls, path: str) -> "BenchHistory":
        with np.load(path, allow_pickle=False) as z:
            version = int(z["version"])
            if version > SCHEMA_VERSION:
                raise ValueError(
                    f"{path}: history schema v{version} is newer than "
                    f"this reader (v{SCHEMA_VERSION})")
            hist = cls()
            hist._modules = [str(x) for x in z["modules"]]
            hist._mod_idx = {m: i for i, m
                             in enumerate(hist._modules)}
            hist._metrics = [str(x) for x in z["metric_names"]]
            hist._met_idx = {m: i for i, m
                             in enumerate(hist._metrics)}
            hist._run_module.extend(z["run_module"])
            hist._run_error = [bool(x) for x in z["run_error"]]
            hist._params_json = [str(x) for x in z["params_json"]]
            hist._snapshot_json = [str(x) for x in z["snapshot_json"]]
            for key, typ, _ in _RUN_FIELDS:
                hist._run_fields[key] = [typ(x)
                                         for x in z[f"run_{key}"]]
            hist._s_run.extend(z["s_run"])
            hist._s_metric.extend(z["s_metric"])
            hist._s_value.extend(z["s_value"])
            return hist

    @classmethod
    def load_or_new(cls, path: str) -> "BenchHistory":
        """Load when the file exists, else a fresh empty history (the
        ``run.py --gate`` first-run path)."""
        return cls.load(path) if os.path.exists(path) else cls()
