"""Paper §IV-E: Lotaru Table III analogue + Tarema node grouping."""

from __future__ import annotations


def run(rows, runs_per_type: int = 10, epochs: int = 40):
    from repro.tuning import lotaru, tarema
    from repro.tuning.perona_weights import (calibrate_scores,
                                             fingerprint_machine_scores)

    gcp = ("e2-medium", "n1-standard-4", "n2-standard-4", "c2-standard-4")
    scores, proxies = fingerprint_machine_scores(
        gcp, runs_per_type=runs_per_type, epochs=epochs,
        return_calibration=True)
    cal = calibrate_scores(scores, proxies)
    tab = lotaru.evaluate_predictors(cal)
    for method in ("naive", "online_m", "online_p", "lotaru", "perona"):
        v = tab[method]
        rows.append((f"tableIII.{method}.median", "", f"{v['median']:.4f}"))
        rows.append((f"tableIII.{method}.p90", "", f"{v['p90']:.4f}"))
        rows.append((f"tableIII.{method}.p95", "", f"{v['p95']:.4f}"))

    machines = {"a": "n1-standard-4", "b": "n1-standard-4",
                "c": "n2-standard-4", "d": "c2-standard-4",
                "e": "e2-medium"}
    same = tarema.same_grouping(
        tarema.groups_from_microbenchmarks(machines),
        tarema.groups_from_perona(machines, cal))
    rows.append(("tarema.same_groups", "", str(same)))
