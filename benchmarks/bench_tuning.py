"""Paper §IV-D Fig. 5: cheapest valid cloud configuration per profiling
run, CherryPick / Arrow with and without the Perona extension, median
over the 18 scout workloads."""

from __future__ import annotations

import numpy as np


def run(rows, n_workloads: int = 18, max_runs: int = 9):
    from repro.core.ranking import machine_score_vector
    from repro.tuning.arrow import Arrow
    from repro.tuning.cherrypick import CherryPick
    from repro.tuning.perona_weights import (PeronaAcquisitionWeighter,
                                             fingerprint_machine_scores)
    from repro.tuning.scout import VM_TYPES, ScoutDataset, WORKLOAD_NAMES

    ds = ScoutDataset(seed=0)
    scores = fingerprint_machine_scores(VM_TYPES, runs_per_type=20,
                                        epochs=60)
    weighter = PeronaAcquisitionWeighter(ds, scores)
    low_fn = lambda wl, c: machine_score_vector(scores, c.vm_type)

    methods = {
        "cherrypick": lambda limit: CherryPick(ds, limit, seed=2,
                                               max_runs=max_runs),
        "cherrypick+perona": lambda limit: CherryPick(
            ds, limit, seed=2, max_runs=max_runs,
            acquisition_weighter=weighter),
        "arrow": lambda limit: Arrow(ds, limit, seed=2, max_runs=max_runs),
        "arrow+perona": lambda limit: Arrow(
            ds, limit, seed=2, max_runs=max_runs, low_level_fn=low_fn,
            acquisition_weighter=weighter),
    }

    curves = {m: [] for m in methods}
    search_costs = {m: [] for m in methods}
    for wl in WORKLOAD_NAMES[:n_workloads]:
        rts = [ds.runtime_s(wl, c) for c in ds.configs]
        limit = float(np.percentile(rts, 40))
        for name, mk in methods.items():
            trace = mk(limit).search(wl)
            curve = trace.best_valid_cost
            curve = curve + [curve[-1]] * (max_runs - len(curve))
            curves[name].append(curve)
            search_costs[name].append(trace.search_cost)

    for name in methods:
        arr = np.asarray(curves[name])
        for run_idx in (2, 4, 8):
            col = arr[:, run_idx]
            valid = col[np.isfinite(col)]
            med = float(np.median(valid)) if len(valid) else float("inf")
            rows.append((f"fig5.{name}.run{run_idx + 1}", "",
                         f"{med:.4f} (n_valid={len(valid)})"))
        rows.append((f"fig5.{name}.search_cost", "",
                     f"{np.median(search_costs[name]):.3f}"))
