"""Paper §IV-D Fig. 5 plus the training/HPO engine microbenchmarks.

Fig. 5: cheapest valid cloud configuration per profiling run,
CherryPick / Arrow with and without the Perona extension, median over
the 18 scout workloads.

HPO engine: wall-clock of a 32-trial Table-II search — the legacy
sequential per-trial loop (``train_perona_reference``, one jit compile
+ 2 dispatches *per epoch* per trial) vs the vmapped bucketed engine
(``hpo.search``, <=8 compiled calls total). The vmapped row is measured
warm (compile caches populated by an identical search), matching the
steady state asserted by the trace-count tests; the one-time compile
cost is reported separately.
"""

from __future__ import annotations

import time

import numpy as np


def _hpo_setup(seed: int = 7):
    from repro.core.graph_data import build_graphs, chronological_split
    from repro.core.model import PeronaConfig
    from repro.core.preprocess import Preprocessor
    from repro.fingerprint.runner import SuiteRunner

    runner = SuiteRunner(seed=seed)
    machines = {"m0": "e2-medium", "m1": "n2-standard-4",
                "m2": "c2-standard-4"}
    frame = runner.run_frame(machines, runs_per_type=10,
                             stress_fraction=0.2)
    tr, va, _ = chronological_split(frame, (0.7, 0.3, 0.0))
    pre = Preprocessor().fit(tr)
    tb, vb = build_graphs(tr, pre), build_graphs(va, pre)
    cfg = PeronaConfig(feature_dim=pre.feature_dim,
                       edge_dim=tb.edge.shape[-1])
    return cfg, tb, vb


def run_hpo(rows, n_trials: int = 32, epochs: int = 25,
            seed: int = 0) -> None:
    from repro.core.model import PeronaModel
    from repro.core.trainer import train_perona, train_perona_reference
    from repro.tuning import hpo

    cfg, tb, vb = _hpo_setup()
    model = PeronaModel(cfg)

    # --- scanned trainer throughput (one dispatch per run) ------------
    train_perona(model, tb, vb, epochs=epochs, seed=seed)  # compile
    t0 = time.time()
    train_perona(model, tb, vb, epochs=epochs, seed=seed + 1)
    dt = time.time() - t0
    rows.append(("trainer.epochs_per_sec", "",
                 f"{epochs / max(dt, 1e-9):.1f}"))

    # --- vmapped engine: warm the per-bucket compile caches ----------
    t0 = time.time()
    hpo.search(cfg, tb, vb, n_trials=n_trials, epochs=epochs, seed=seed)
    t_compile = time.time() - t0
    t0 = time.time()
    _, _, stats = hpo.search(cfg, tb, vb, n_trials=n_trials,
                             epochs=epochs, seed=seed, return_stats=True)
    t_vm = time.time() - t0
    rows.append(("hpo.vmapped.wall_s", "", f"{t_vm:.2f}"))
    rows.append(("hpo.vmapped.trials_per_s", "",
                 f"{n_trials / max(t_vm, 1e-9):.2f}"))
    rows.append(("hpo.vmapped.compile_s", "",
                 f"{t_compile - t_vm:.2f} ({stats.n_buckets} buckets)"))

    # --- legacy sequential per-trial loop ----------------------------
    t0 = time.time()
    hpo.search_sequential(cfg, tb, vb, n_trials=n_trials, epochs=epochs,
                          seed=seed, train_fn=train_perona_reference)
    t_seq = time.time() - t0
    rows.append(("hpo.sequential.wall_s", "", f"{t_seq:.2f}"))
    rows.append(("hpo.sequential.trials_per_s", "",
                 f"{n_trials / max(t_seq, 1e-9):.2f}"))
    rows.append(("hpo.speedup", "", f"{t_seq / max(t_vm, 1e-9):.1f}x "
                 f"({n_trials} trials, {epochs} epochs)"))


def run_fig5(rows, n_workloads: int = 18, max_runs: int = 9):
    from repro.core.ranking import machine_score_vector
    from repro.tuning.arrow import Arrow
    from repro.tuning.cherrypick import CherryPick
    from repro.tuning.perona_weights import (PeronaAcquisitionWeighter,
                                             fingerprint_machine_scores)
    from repro.tuning.scout import VM_TYPES, ScoutDataset, WORKLOAD_NAMES

    ds = ScoutDataset(seed=0)
    scores = fingerprint_machine_scores(VM_TYPES, runs_per_type=20,
                                        epochs=60)
    weighter = PeronaAcquisitionWeighter(ds, scores)
    low_fn = lambda wl, c: machine_score_vector(scores, c.vm_type)

    methods = {
        "cherrypick": lambda limit: CherryPick(ds, limit, seed=2,
                                               max_runs=max_runs),
        "cherrypick+perona": lambda limit: CherryPick(
            ds, limit, seed=2, max_runs=max_runs,
            acquisition_weighter=weighter),
        "arrow": lambda limit: Arrow(ds, limit, seed=2, max_runs=max_runs),
        "arrow+perona": lambda limit: Arrow(
            ds, limit, seed=2, max_runs=max_runs, low_level_fn=low_fn,
            acquisition_weighter=weighter),
    }

    curves = {m: [] for m in methods}
    search_costs = {m: [] for m in methods}
    for wl in WORKLOAD_NAMES[:n_workloads]:
        rts = [ds.runtime_s(wl, c) for c in ds.configs]
        limit = float(np.percentile(rts, 40))
        for name, mk in methods.items():
            trace = mk(limit).search(wl)
            curve = trace.best_valid_cost
            curve = curve + [curve[-1]] * (max_runs - len(curve))
            curves[name].append(curve)
            search_costs[name].append(trace.search_cost)

    for name in methods:
        arr = np.asarray(curves[name])
        for run_idx in (2, 4, 8):
            col = arr[:, run_idx]
            valid = col[np.isfinite(col)]
            med = float(np.median(valid)) if len(valid) else float("inf")
            rows.append((f"fig5.{name}.run{run_idx + 1}", "",
                         f"{med:.4f} (n_valid={len(valid)})"))
        rows.append((f"fig5.{name}.search_cost", "",
                     f"{np.median(search_costs[name]):.3f}"))


def run(rows, n_workloads: int = 18, max_runs: int = 9,
        hpo_trials: int = 32, hpo_epochs: int = 25):
    run_fig5(rows, n_workloads=n_workloads, max_runs=max_runs)
    run_hpo(rows, n_trials=hpo_trials, epochs=hpo_epochs)
