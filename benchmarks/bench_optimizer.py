"""Batched BO replay vs sequential numpy search (paper §IV-D grid),
written to ``BENCH_optimizer.json``.

Both paths run the *same* scenario matrix — workload x seed x tuner
variant (CherryPick/Arrow, +-Perona weighting) x fleet condition
(healthy + a drift-derived degraded fleet) — over one shared scout
dataset, so every lane must reproduce its sequential trace exactly
(asserted here and in tests/test_optimizer.py):

- ``sequential`` — one ``CherryPick.search``/``Arrow.search`` per
  scenario (scipy GP per BO round, Python loops);
- ``batched``    — ``optimizer.replay``: all lanes advanced per round
  inside one scanned, vmapped, donated-carry device dispatch. The warm
  row is measured with compile caches populated (one prior replay of
  the same shapes), matching the steady state the trace-count tests
  assert; compile time is reported separately;
- ``sharded``    — the same dispatch with the lane axis partitioned
  over the 1-D device mesh (``common.mesh``); bit-identical picks;
- ``pipelined``  — ``optimizer.replay_pipelined`` on the *large*
  fleet-sweep matrix (12 seeds x 4 fleet conditions, the degraded ones
  derived through the real store path and DEFERRED so the drift
  simulation runs inside the overlap window): fixed-size lane blocks
  round-robined over the devices, block N+1's tables built on the
  host while earlier blocks scan on device. Its wall clock *includes*
  all host work, so the honest baseline is
  ``large.unpipelined.wall_s`` = the serial ``replay_scenarios`` path
  on one device. Both are measured rep-interleaved and reported as
  medians (ambient load hits both paths equally);
- ``seeded``     — the same scanned program fed the compact
  ``SeededLaneSpec`` instead of host-materialized lane tables: every
  stochastic table cell is re-derived *inside* the compiled program
  from counter-based fold-in keys (``common.rng``), bit-identical
  picks asserted against the host-table replay. ``huge.*`` scales the
  fleet sweep to a 10^4-lane matrix where host table construction
  dominates: ``huge.lane_tables_s`` vs ``huge.spec_s`` is the
  O(L*C*D) -> O(W*C + L) host-work drop, and the seeded pipelined
  end-to-end wall clock is compared against the host-table pipeline
  on the identical matrix (rep-interleaved).

Run under ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (or
``benchmarks/run.py --devices N``) to exercise the multi-device rows
on a CPU-only machine.

Machine scores come from a deterministic profile-derived stand-in
(scoring inputs, not model quality, are under test — the fingerprint
training path is benchmarked by bench_tuning/bench_fingerprint).
"""

from __future__ import annotations

import os
import time

import numpy as np

#: Regression-gate policy for this module's tracked rows (see
#: ``benchmarks.gate`` / bench_fleet.POLICIES for the rationale).
#: Trace-parity rows gate at threshold 0: ANY drop below the all-lanes
#: ratio of 1.0 is a correctness regression, not noise. Compile-time
#: rows gate loosely (compiles are one-off and XLA-version dependent);
#: the pipelined-speedup ratio is informational because it is
#: hardware-ceiling-bound on small CI runners (see README
#: "Multi-device replay").
POLICIES = {
    "optimizer.sequential.searches_per_s": ("higher", 10.0),
    "optimizer.batched.searches_per_s": ("higher", 10.0),
    "optimizer.sharded.searches_per_s": ("higher", 10.0),
    "optimizer.seeded.searches_per_s": ("higher", 10.0),
    "optimizer.large.pipelined.searches_per_s": ("higher", 10.0),
    "optimizer.speedup": ("higher", 15.0),
    "optimizer.trace_parity": ("higher", 0.0),
    "optimizer.seeded.trace_parity": ("higher", 0.0),
    "optimizer.batched.compile_s": ("lower", 25.0),
    "optimizer.seeded.compile_s": ("lower", 25.0),
    "optimizer.seeded.spec_s": ("lower", 50.0),
    "optimizer.lane_tables_s": "info",  # ~0 on quick matrices
    "optimizer.large.unpipelined.wall_s": ("lower", 15.0),
    "optimizer.large.pipelined.wall_s": ("lower", 15.0),
    "optimizer.large.pipelined.speedup": "info",
    "optimizer.large.pipelined.seeded.wall_s": ("lower", 15.0),
    "optimizer.large.pipelined.seeded.speedup": "info",
    "optimizer.mean_runs_per_search": "info",
    "optimizer.wall_s": "info",
}


def _profile_scores(vm_types):
    """Deterministic fingerprint-score stand-in: per-aspect capability
    scaled off the machine profiles (ordered like real scores)."""
    from repro.fingerprint.machines import MACHINE_PROFILES

    scores = {}
    for vm in vm_types:
        p = MACHINE_PROFILES[vm]
        scores[vm] = {
            "cpu": p.cpu / 1000.0,
            "memory": p.memory / 10000.0,
            "disk": p.disk_iops / 5000.0,
            "network": p.net_gbps,
        }
    return scores


def _conditions(seed: int = 0):
    """Healthy plus one degraded fleet derived through the real
    fleet-drift path (store + EWMA analytics on a simulated fleet
    whose c4 nodes lose cpu quality)."""
    from repro.optimizer import HEALTHY, drifted_condition

    degraded = drifted_condition(
        ("c4.large", "c4.xlarge", "c4.2xlarge"),
        name="c4-cpu-degraded", seed=seed)
    return (HEALTHY, degraded)


def _best_of(fn, reps: int = 3):
    """Min wall clock over ``reps`` runs (the 2-core CI boxes are
    noisy); returns (seconds, last result)."""
    best, out = float("inf"), None
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def _assert_parity(ref_traces, got_traces):
    """Lane-for-lane trace equality (evaluated keys + best-cost curve)."""
    assert len(ref_traces) == len(got_traces)
    for a, b in zip(ref_traces, got_traces):
        assert [c.key for c in a.evaluated] == \
            [c.key for c in b.evaluated], "seeded lane diverged"
        assert a.best_valid_cost == b.best_valid_cost


def _interleaved_medians(fns, reps: int = 5):
    """Median wall clock per callable, measured round-robin so ambient
    load hits every path equally; returns (medians, last results)."""
    import statistics

    times = [[] for _ in fns]
    outs = [None] * len(fns)
    for _ in range(reps):
        for i, fn in enumerate(fns):
            t0 = time.perf_counter()
            outs[i] = fn()
            times[i].append(time.perf_counter() - t0)
    return [statistics.median(t) for t in times], outs


def _large_matrix(ds, n_seeds: int, workloads=None):
    """The scaled fleet-sweep matrix: every seed replayed under the
    healthy fleet plus drift-derived degraded fleets whose conditions
    are DEFERRED — the store-path simulation runs during lane-table
    construction, i.e. inside the pipelined overlap window.
    Condition-major order keeps each lane block on few conditions."""
    from repro.optimizer import HEALTHY, build_scenarios, \
        drifted_condition

    conds = tuple(
        drifted_condition((vm,), aspects=(aspect,), seed=i,
                          name=f"sweep-{vm}-{aspect}", deferred=True)
        for i, (vm, aspect) in enumerate(
            (("c4.large", "cpu"), ("m4.xlarge", "memory"),
             ("r4.large", "disk"))))
    return build_scenarios(ds, workloads=workloads,
                           seeds=tuple(range(n_seeds)),
                           conditions=(HEALTHY,) + conds,
                           condition_major=True)


def run(rows, n_workloads: int = 18, n_seeds: int = 3,
        quick: bool = False, block_lanes: int = 128):
    import jax

    from repro.common.mesh import pow2_devices, shard_size
    from repro.optimizer import (build_scenarios, lane_spec,
                                 lane_tables, reference_search, replay,
                                 replay_pipelined, replay_scenarios,
                                 replay_seeded, traces_from_result,
                                 traces_from_spec, REPLAY_TRACES,
                                 ReplayConfig)
    from repro.tuning.scout import (ScoutDataset, VM_TYPES,
                                    WORKLOAD_NAMES)

    if quick:
        n_workloads, n_seeds = 3, 1
    cfg = ReplayConfig()
    ds = ScoutDataset(seed=0)
    scores = _profile_scores(VM_TYPES)
    scens = build_scenarios(ds, workloads=WORKLOAD_NAMES[:n_workloads],
                            seeds=tuple(range(n_seeds)),
                            conditions=_conditions())
    devices = pow2_devices(jax.devices())
    n_dev = len(devices)
    block = min(block_lanes, shard_size(len(scens), n_dev))

    # --- batched replay: compile, then the warm steady state ---------
    t0 = time.perf_counter()
    tab = lane_tables(ds, scens, scores, cfg)
    t_tables = time.perf_counter() - t0
    t0 = time.perf_counter()
    replay(tab, cfg)
    t_compile = time.perf_counter() - t0
    traces0 = REPLAY_TRACES.count
    t_bat, result = _best_of(lambda: replay(tab, cfg))
    batched = traces_from_result(tab, result, ds.configs)
    assert REPLAY_TRACES.count == traces0  # warm: no retracing

    # --- seeded replay: tables generated inside the program ----------
    t0 = time.perf_counter()
    spec = lane_spec(ds, scens, scores, cfg)
    t_spec = time.perf_counter() - t0
    t0 = time.perf_counter()
    replay_seeded(spec, cfg)
    t_seed_compile = time.perf_counter() - t0
    t_seed, seed_result = _best_of(lambda: replay_seeded(spec, cfg))
    assert np.array_equal(seed_result.chosen, result.chosen)
    assert np.array_equal(seed_result.count, result.count)
    seeded_traces = traces_from_spec(spec, seed_result, ds.configs)

    # --- sharded whole-matrix dispatch (lane axis over the mesh) -----
    replay(tab, cfg, devices=devices)  # compile
    t_shard, shard_result = _best_of(
        lambda: replay(tab, cfg, devices=devices))
    assert np.array_equal(shard_result.chosen, result.chosen)
    assert np.array_equal(shard_result.count, result.count)

    # --- pipelined parity on the evaluation matrix -------------------
    pipelined = replay_pipelined(ds, scens, scores, cfg,
                                 block_lanes=block, devices=devices)

    # --- large fleet-sweep matrix: pipelined vs unpipelined ----------
    # (the multi-device acceptance measurement; deferred store-path
    # conditions resolve inside the overlap window, so each rep builds
    # a fresh matrix)
    large_seeds = 1 if quick else 12
    large_wls = WORKLOAD_NAMES[:n_workloads] if quick else None

    def large():
        return _large_matrix(ds, large_seeds, workloads=large_wls)

    n_large = len(large())
    large_block = min(512, shard_size(n_large, n_dev))
    replay_scenarios(ds, large(), scores, cfg)
    replay_pipelined(ds, large(), scores, cfg,
                     block_lanes=large_block, devices=devices)  # warm
    replay_pipelined(ds, large(), scores, cfg, seeded=True,
                     block_lanes=large_block, devices=devices)  # warm
    ((t_unpipe, t_pipe, t_pipe_seed),
     (large_ref, large_piped, large_seeded)) = _interleaved_medians(
        (lambda: replay_scenarios(ds, large(), scores, cfg),
         lambda: replay_pipelined(ds, large(), scores, cfg,
                                  block_lanes=large_block,
                                  devices=devices),
         lambda: replay_pipelined(ds, large(), scores, cfg,
                                  seeded=True,
                                  block_lanes=large_block,
                                  devices=devices)),
        reps=2 if quick else 5)

    # --- huge fleet sweep: the matrix host tables can't keep up with -
    # (spec build is O(W*C + L); lane-table build is O(L*C*D) and
    # dominates the host side at this scale)
    huge = {}
    if not quick:
        huge_scens = _large_matrix(ds, 35)  # 18 x 35 x 4 x 4 = 10080
        t0 = time.perf_counter()
        huge_tab = lane_tables(ds, huge_scens, scores, cfg)
        t_huge_tab = time.perf_counter() - t0
        t0 = time.perf_counter()
        huge_spec = lane_spec(ds, huge_scens, scores, cfg)
        t_huge_spec = time.perf_counter() - t0
        del huge_tab, huge_spec
        # parity spot-check on the first block before the timed runs
        spot = huge_scens[:large_block]
        _assert_parity(replay_scenarios(ds, spot, scores, cfg),
                       replay_scenarios(ds, spot, scores, cfg,
                                        seeded=True))
        # warm both pipelines over the full sweep: the huge matrix's
        # condition-boundary blocks hit (block, n_conds, device)
        # signatures the large phase never compiled
        replay_pipelined(ds, huge_scens, scores, cfg,
                         block_lanes=large_block, devices=devices)
        replay_pipelined(ds, huge_scens, scores, cfg, seeded=True,
                         block_lanes=large_block, devices=devices)
        ((t_huge_host, t_huge_seed),
         (huge_host_traces, huge_seeded_traces)) = _interleaved_medians(
            (lambda: replay_pipelined(ds, huge_scens, scores, cfg,
                                      block_lanes=large_block,
                                      devices=devices),
             lambda: replay_pipelined(ds, huge_scens, scores, cfg,
                                      seeded=True,
                                      block_lanes=large_block,
                                      devices=devices)),
            reps=1)
        _assert_parity(huge_host_traces, huge_seeded_traces)
        huge = {"lanes": len(huge_scens), "lane_tables_s": t_huge_tab,
                "spec_s": t_huge_spec, "host_wall_s": t_huge_host,
                "seeded_wall_s": t_huge_seed}

    # --- sequential reference loop -----------------------------------
    t0 = time.perf_counter()
    sequential = [reference_search(ds, sc, scores, cfg)
                  for sc in scens]
    t_seq = time.perf_counter() - t0

    # --- per-seed trace parity (the acceptance criterion) ------------
    def diverged(ref, got):
        return ([c.key for c in ref.evaluated]
                != [c.key for c in got.evaluated]
                or ref.best_valid_cost != got.best_valid_cost)

    mismatches = sum(1 for st, bt in zip(sequential, batched)
                     if diverged(st, bt))
    assert mismatches == 0, \
        f"{mismatches}/{len(scens)} lanes diverged from sequential"
    seed_mismatches = sum(1 for st, bt in zip(sequential, seeded_traces)
                          if diverged(st, bt))
    assert seed_mismatches == 0, \
        f"{seed_mismatches}/{len(scens)} seeded lanes diverged"
    assert not any(diverged(st, pt)
                   for st, pt in zip(sequential, pipelined)), \
        "pipelined lanes diverged from sequential"
    assert not any(diverged(rt, pt)
                   for rt, pt in zip(large_ref, large_piped)), \
        "pipelined large-matrix lanes diverged from unpipelined"
    assert not any(diverged(rt, pt)
                   for rt, pt in zip(large_ref, large_seeded)), \
        "seeded pipelined large-matrix lanes diverged"

    n = len(scens)
    sps_seq = n / max(t_seq, 1e-9)
    sps_bat = n / max(t_bat, 1e-9)
    rows.append(("optimizer.scenarios", "", n))
    rows.append(("optimizer.sequential.searches_per_s",
                 f"{t_seq / n * 1e6:.0f}", f"{sps_seq:.1f}"))
    rows.append(("optimizer.batched.searches_per_s",
                 f"{t_bat / n * 1e6:.0f}", f"{sps_bat:.1f}"))
    rows.append(("optimizer.speedup", "",
                 f"{sps_bat / max(sps_seq, 1e-9):.1f}x"))
    rows.append(("optimizer.batched.compile_s", "", f"{t_compile:.2f}"))
    rows.append(("optimizer.lane_tables_s", "", f"{t_tables:.2f}"))
    rows.append(("optimizer.seeded.searches_per_s",
                 f"{t_seed / n * 1e6:.0f}",
                 f"{n / max(t_seed, 1e-9):.1f}"))
    rows.append(("optimizer.seeded.compile_s", "",
                 f"{t_seed_compile:.2f}"))
    rows.append(("optimizer.seeded.spec_s", "", f"{t_spec:.3f}"))
    rows.append(("optimizer.seeded.trace_parity", "",
                 f"{n - seed_mismatches}/{n}"))
    rows.append(("optimizer.batched.dispatches", "", result.dispatches))
    rows.append(("optimizer.batched.traces", "", REPLAY_TRACES.count))
    rows.append(("optimizer.trace_parity", "",
                 f"{n - mismatches}/{n}"))
    mean_runs = float(np.mean(result.count))
    rows.append(("optimizer.mean_runs_per_search", "",
                 f"{mean_runs:.2f}"))
    # --- multi-device / pipelined rows -------------------------------
    rows.append(("optimizer.device_count", "", n_dev))
    rows.append(("optimizer.lanes_per_device", "",
                 shard_size(n, n_dev) // n_dev))
    rows.append(("optimizer.sharded.searches_per_s",
                 f"{t_shard / n * 1e6:.0f}",
                 f"{n / max(t_shard, 1e-9):.1f}"))
    rows.append(("optimizer.large.lanes", "", n_large))
    rows.append(("optimizer.large.unpipelined.wall_s", "",
                 f"{t_unpipe:.3f}"))
    rows.append(("optimizer.large.pipelined.wall_s", "",
                 f"{t_pipe:.3f}"))
    rows.append(("optimizer.large.pipelined.searches_per_s", "",
                 f"{n_large / max(t_pipe, 1e-9):.1f}"))
    rows.append(("optimizer.large.block_lanes", "", large_block))
    rows.append(("optimizer.large.pipelined.speedup", "",
                 f"{t_unpipe / max(t_pipe, 1e-9):.2f}x"))
    rows.append(("optimizer.large.pipelined.seeded.wall_s", "",
                 f"{t_pipe_seed:.3f}"))
    rows.append(("optimizer.large.pipelined.seeded.speedup", "",
                 f"{t_unpipe / max(t_pipe_seed, 1e-9):.2f}x"))
    if huge:
        rows.append(("optimizer.huge.lanes", "", huge["lanes"]))
        rows.append(("optimizer.huge.lane_tables_s", "",
                     f"{huge['lane_tables_s']:.2f}"))
        rows.append(("optimizer.huge.spec_s", "",
                     f"{huge['spec_s']:.3f}"))
        rows.append(("optimizer.huge.table_build_speedup", "",
                     f"{huge['lane_tables_s'] / max(huge['spec_s'], 1e-9):.0f}x"))
        rows.append(("optimizer.huge.pipelined.wall_s", "",
                     f"{huge['host_wall_s']:.2f}"))
        rows.append(("optimizer.huge.pipelined.seeded.wall_s", "",
                     f"{huge['seeded_wall_s']:.2f}"))
        rows.append(("optimizer.huge.pipelined.seeded.searches_per_s",
                     "",
                     f"{huge['lanes'] / max(huge['seeded_wall_s'], 1e-9):.1f}"))
    return {"n_workloads": n_workloads, "n_seeds": n_seeds,
            "variants": 4, "conditions": 2, "lanes": n,
            "max_runs": cfg.max_runs, "device_count": n_dev,
            "cpu_cores": os.cpu_count(),
            "lanes_per_device": shard_size(n, n_dev) // n_dev,
            "large_lanes": n_large, "large_block_lanes": large_block,
            **{f"huge_{k}": v for k, v in huge.items()}}
