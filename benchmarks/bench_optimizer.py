"""Batched BO replay vs sequential numpy search (paper §IV-D grid),
written to ``BENCH_optimizer.json``.

Both paths run the *same* scenario matrix — workload x seed x tuner
variant (CherryPick/Arrow, +-Perona weighting) x fleet condition
(healthy + a drift-derived degraded fleet) — over one shared scout
dataset, so every lane must reproduce its sequential trace exactly
(asserted here and in tests/test_optimizer.py):

- ``sequential`` — one ``CherryPick.search``/``Arrow.search`` per
  scenario (scipy GP per BO round, Python loops);
- ``batched``    — ``optimizer.replay``: all lanes advanced per round
  inside one scanned, vmapped, donated-carry device dispatch. The warm
  row is measured with compile caches populated (one prior replay of
  the same shapes), matching the steady state the trace-count tests
  assert; compile time is reported separately.

Machine scores come from a deterministic profile-derived stand-in
(scoring inputs, not model quality, are under test — the fingerprint
training path is benchmarked by bench_tuning/bench_fingerprint).
"""

from __future__ import annotations

import time

import numpy as np


def _profile_scores(vm_types):
    """Deterministic fingerprint-score stand-in: per-aspect capability
    scaled off the machine profiles (ordered like real scores)."""
    from repro.fingerprint.machines import MACHINE_PROFILES

    scores = {}
    for vm in vm_types:
        p = MACHINE_PROFILES[vm]
        scores[vm] = {
            "cpu": p.cpu / 1000.0,
            "memory": p.memory / 10000.0,
            "disk": p.disk_iops / 5000.0,
            "network": p.net_gbps,
        }
    return scores


def _conditions(seed: int = 0):
    """Healthy plus one degraded fleet derived through the real
    fleet-drift path (store + EWMA analytics on a simulated fleet
    whose c4 nodes lose cpu quality)."""
    from repro.optimizer import HEALTHY, drifted_condition

    degraded = drifted_condition(
        ("c4.large", "c4.xlarge", "c4.2xlarge"),
        name="c4-cpu-degraded", seed=seed)
    return (HEALTHY, degraded)


def run(rows, n_workloads: int = 18, n_seeds: int = 3,
        quick: bool = False):
    from repro.optimizer import (build_scenarios, lane_tables,
                                 reference_search, replay,
                                 traces_from_result, REPLAY_TRACES,
                                 ReplayConfig)
    from repro.tuning.scout import (ScoutDataset, VM_TYPES,
                                    WORKLOAD_NAMES)

    if quick:
        n_workloads, n_seeds = 3, 1
    cfg = ReplayConfig()
    ds = ScoutDataset(seed=0)
    scores = _profile_scores(VM_TYPES)
    scens = build_scenarios(ds, workloads=WORKLOAD_NAMES[:n_workloads],
                            seeds=tuple(range(n_seeds)),
                            conditions=_conditions())

    # --- batched replay: compile, then the warm steady state ---------
    t0 = time.perf_counter()
    tab = lane_tables(ds, scens, scores, cfg)
    t_tables = time.perf_counter() - t0
    t0 = time.perf_counter()
    replay(tab, cfg)
    t_compile = time.perf_counter() - t0
    traces0 = REPLAY_TRACES.count
    t0 = time.perf_counter()
    result = replay(tab, cfg)
    batched = traces_from_result(tab, result, ds.configs)
    t_bat = time.perf_counter() - t0
    assert REPLAY_TRACES.count == traces0  # warm: no retracing

    # --- sequential reference loop -----------------------------------
    t0 = time.perf_counter()
    sequential = [reference_search(ds, sc, scores, cfg)
                  for sc in scens]
    t_seq = time.perf_counter() - t0

    # --- per-seed trace parity (the acceptance criterion) ------------
    mismatches = sum(
        1 for st, bt in zip(sequential, batched)
        if [c.key for c in st.evaluated] != [c.key for c in bt.evaluated]
        or st.best_valid_cost != bt.best_valid_cost)
    assert mismatches == 0, \
        f"{mismatches}/{len(scens)} lanes diverged from sequential"

    n = len(scens)
    sps_seq = n / max(t_seq, 1e-9)
    sps_bat = n / max(t_bat, 1e-9)
    rows.append(("optimizer.scenarios", "", n))
    rows.append(("optimizer.sequential.searches_per_s",
                 f"{t_seq / n * 1e6:.0f}", f"{sps_seq:.1f}"))
    rows.append(("optimizer.batched.searches_per_s",
                 f"{t_bat / n * 1e6:.0f}", f"{sps_bat:.1f}"))
    rows.append(("optimizer.speedup", "",
                 f"{sps_bat / max(sps_seq, 1e-9):.1f}x"))
    rows.append(("optimizer.batched.compile_s", "", f"{t_compile:.2f}"))
    rows.append(("optimizer.lane_tables_s", "", f"{t_tables:.2f}"))
    rows.append(("optimizer.batched.dispatches", "", result.dispatches))
    rows.append(("optimizer.batched.traces", "", REPLAY_TRACES.count))
    rows.append(("optimizer.trace_parity", "",
                 f"{n - mismatches}/{n}"))
    mean_runs = float(np.mean(result.count))
    rows.append(("optimizer.mean_runs_per_search", "",
                 f"{mean_runs:.2f}"))
    return {"n_workloads": n_workloads, "n_seeds": n_seeds,
            "variants": 4, "conditions": 2, "lanes": n,
            "max_runs": cfg.max_runs}
