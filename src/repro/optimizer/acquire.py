"""Acquisition functions as pure array ops (paper §IV-D).

Expected improvement mirrors ``tuning.gp.expected_improvement`` and the
Perona acquisition weighting mirrors ``tuning.perona_weights.
PeronaAcquisitionWeighter.__call__`` — both are the numpy references
the parity tests pin against. Inputs arrive precomputed as matrices
(normalized machine-score rows per candidate configuration, observed
utilization per evaluated run), so a weighting step is two matvecs.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax.scipy.stats import norm


def expected_improvement(mu: jnp.ndarray, sigma: jnp.ndarray,
                         best, xi: float = 0.01) -> jnp.ndarray:
    """EI for *minimization*; clipped at 0 (EI is non-negative by
    definition — the clip removes float underflow artifacts)."""
    imp = best - mu - xi
    z = imp / jnp.maximum(sigma, 1e-9)
    ei = imp * norm.cdf(z) + sigma * norm.pdf(z)
    return jnp.maximum(ei, 0.0)


def perona_weight_factors(util: jnp.ndarray, norm_scores: jnp.ndarray,
                          prices: jnp.ndarray, any_valid,
                          strength: float = 0.3,
                          per_dollar: bool = True) -> jnp.ndarray:
    """Multiplicative acquisition factors of the §IV-D weighting.

    ``util`` (4,) mean observed per-aspect utilization of the runs so
    far; ``norm_scores`` (C, 4) normalized fingerprint score vector of
    each candidate's machine type; ``prices`` (C,) on-demand $/h.
    Two-phase prior: capability while no valid configuration is known
    (``any_valid`` False), capability per dollar once one exists."""
    util = util / jnp.maximum(jnp.sum(util), 1e-9)
    w = norm_scores @ util
    w = jnp.where(jnp.logical_and(per_dollar, any_valid), w / prices, w)
    w = w / jnp.maximum(jnp.mean(w), 1e-9)
    return 1.0 + strength * (w - 1.0)
