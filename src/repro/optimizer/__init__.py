"""Batched Bayesian-optimization replay engine (paper §IV-D at scale).

The sequential reference tuners live in ``repro.tuning`` (CherryPick /
Arrow, one numpy GP search at a time). This package replays *many*
configuration searches as parallel vmapped lanes on device:

- :mod:`repro.optimizer.gp` — batched masked RBF GP (fit + predict as
  pure jnp ops, pinned against ``tuning/gp.py``);
- :mod:`repro.optimizer.acquire` — expected improvement and the §IV-D
  Perona acquisition weighting as pure array ops;
- :mod:`repro.optimizer.replay` — full BO search loops as one
  ``lax.scan`` over rounds, every lane advanced per round; the lane
  axis optionally sharded over a 1-D device mesh (``common.mesh``),
  bit-identical to the single-device scan;
- :mod:`repro.optimizer.scenarios` — the §IV-D scenario matrix
  (workload x seed x tuner variant x fleet condition) over the scout
  simulator, including degraded-node fleets from ``fleet.drift``, plus
  ``replay_pipelined``: fixed-size lane blocks whose host-side table
  construction overlaps the previous block's device scan. The seeded
  path (``lane_spec`` / ``replay_seeded``) ships only the compact
  deterministic grid + per-lane ids and re-derives every stochastic
  table cell inside the compiled program from counter-based
  ``fold_in`` keys — bit-identical to the host tables.
"""

from repro.optimizer.replay import (REPLAY_TRACES, BatchReplayResult,
                                    PendingReplay, ReplayConfig,
                                    SeededLaneSpec, replay,
                                    replay_async, replay_seeded,
                                    replay_seeded_async,
                                    traces_from_result,
                                    traces_from_spec)
from repro.optimizer.scenarios import (HEALTHY, DeferredFleetCondition,
                                       FleetCondition, Scenario,
                                       build_scenarios,
                                       condition_from_drift,
                                       degrade_scores, drifted_condition,
                                       lane_spec, lane_tables,
                                       reference_search,
                                       replay_pipelined,
                                       replay_scenarios,
                                       resolve_condition,
                                       simulate_degraded_fleet)

__all__ = [
    "REPLAY_TRACES", "BatchReplayResult", "PendingReplay",
    "ReplayConfig", "SeededLaneSpec", "replay", "replay_async",
    "replay_seeded", "replay_seeded_async", "traces_from_result",
    "traces_from_spec",
    "HEALTHY", "DeferredFleetCondition", "FleetCondition", "Scenario",
    "build_scenarios", "condition_from_drift", "degrade_scores",
    "drifted_condition", "lane_spec", "lane_tables",
    "reference_search", "replay_pipelined", "replay_scenarios",
    "resolve_condition", "simulate_degraded_fleet",
]
