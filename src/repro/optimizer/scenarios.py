"""§IV-D scenario matrix over the scout simulator.

A *scenario* is one configuration search: (workload, seed, tuner
variant, fleet condition). The matrix spans the paper's evaluation grid
— 18 workloads x seeds x {cherrypick, arrow} x {vanilla,
perona-weighted} — extended with *fleet conditions*: degraded-node
fleets derived from ``fleet.drift`` analytics, so fingerprint-aware
search is exercised under exactly the degradation the paper motivates
(a degraded machine type's fingerprint scores drop, steering the
weighted acquisition away from it).

``lane_tables`` lowers a scenario list to the stacked arrays the replay
engine consumes; ``reference_search`` runs the identically-configured
sequential tuner (the parity baseline). Both paths must share one
``ScoutDataset`` instance: ``build_scenarios`` materializes the
simulator's runtime cache in canonical (workload, config) order while
computing runtime limits, which pins the contention-noise draws for
every later consumer.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.ranking import machine_score_matrix, \
    machine_score_vector
from repro.optimizer.replay import (LaneTables, ReplayConfig, replay,
                                    traces_from_result)
from repro.tuning.scout import PRICES, ScoutDataset

VARIANTS = ("cherrypick", "cherrypick+perona", "arrow", "arrow+perona")


@dataclasses.dataclass(frozen=True)
class FleetCondition:
    """A fleet health state: relative fingerprint-score drops per
    (machine type, resource aspect). The healthy fleet has none."""

    name: str
    score_drop: Mapping[str, Mapping[str, float]] = \
        dataclasses.field(default_factory=dict)


HEALTHY = FleetCondition("healthy")


def degrade_scores(machine_scores: Dict[str, Dict[str, float]],
                   condition: FleetCondition
                   ) -> Dict[str, Dict[str, float]]:
    """Apply a condition's relative drops to a machine-score dict."""
    out = {m: dict(per) for m, per in machine_scores.items()}
    for vm, aspects in condition.score_drop.items():
        if vm not in out:
            continue
        for aspect, drop in aspects.items():
            if aspect in out[vm]:
                out[vm][aspect] *= (1.0 - drop)
    return out


def condition_from_drift(name: str, report: Dict[str, "NodeDrift"],
                         node_types: Mapping[str, str],
                         rel_drop: float = 0.2) -> FleetCondition:
    """Build a condition from ``fleet.drift.drift_report`` output:
    every drop ``fleet.drift.degradation_factors`` reports for a node
    votes for its machine type; drops average per type."""
    from repro.fleet.drift import degradation_factors

    acc: Dict[str, Dict[str, List[float]]] = {}
    for node, drops in degradation_factors(report, rel_drop).items():
        vm = node_types.get(node)
        if vm is None:
            continue
        for aspect, frac in drops.items():
            acc.setdefault(vm, {}).setdefault(aspect, []).append(frac)
    return FleetCondition(name, {
        vm: {a: float(np.mean(v)) for a, v in per.items()}
        for vm, per in acc.items()})


def simulate_degraded_fleet(machine_types: Sequence[str],
                            degraded: Mapping[str, Sequence[str]],
                            *, severity: float = 0.9, rounds: int = 10,
                            healthy_rounds: int = 3, seed: int = 0):
    """Run one simulated node per machine type through streaming
    benchmark rounds, attach synthetic quality scores that decay on the
    ``degraded`` types' aspects over the later rounds, and return the
    resulting ``fleet.drift`` report plus the node->type map.

    This exercises the real fleet path (store appends, chain views,
    EWMA analytics) without model training: attached codes are unit
    vectors scaled so ``core.ranking.code_scores`` equals the intended
    quality directly."""
    from repro.core.ranking import ASPECT_OF_TYPE
    from repro.fingerprint.runner import SuiteRunner
    from repro.fleet.drift import drift_report
    from repro.fleet.store import FingerprintStore

    day = 86400.0
    runner = SuiteRunner(seed=seed)
    machines = {f"{vm}-0": vm for vm in machine_types}
    store = FingerprintStore()
    for k in range(rounds):
        frame = runner.run_frame(machines, runs_per_type=1,
                                 t_offset=k * day)
        first = store.append(frame)
        n = len(frame)
        codes = np.zeros((n, 4), np.float32)
        anomaly = np.full(n, 0.05, np.float32)
        ramp = max(0.0, (k - healthy_rounds + 1)
                   / max(rounds - healthy_rounds, 1))
        for j in range(n):
            vm = frame.machine_types[frame.machine_type_code[j]]
            aspect = ASPECT_OF_TYPE[
                frame.benchmark_types[frame.type_code[j]]]
            quality = 1.0
            if aspect in degraded.get(vm, ()):
                quality = 1.0 - severity * ramp
                anomaly[j] = 0.05 + 0.9 * ramp
            codes[j, 0] = quality
        store.attach(np.arange(first, first + n), anomaly, codes)
    return drift_report(store), machines


def drifted_condition(machine_types: Sequence[str],
                      aspects: Sequence[str] = ("cpu",),
                      name: Optional[str] = None,
                      seed: int = 0) -> FleetCondition:
    """The canonical degraded-fleet condition used by the benchmark and
    the example: simulate the given machine types losing quality on the
    given aspects, run the fleet drift analytics, and turn the report
    into a condition."""
    report, node_types = simulate_degraded_fleet(
        machine_types, degraded={vm: tuple(aspects)
                                 for vm in machine_types}, seed=seed)
    if name is None:
        name = f"{'/'.join(machine_types)}-{'/'.join(aspects)}-degraded"
    return condition_from_drift(name, report, node_types)


@dataclasses.dataclass(frozen=True)
class Scenario:
    workload: str
    seed: int
    variant: str  # one of VARIANTS
    condition: FleetCondition
    limit: float  # runtime constraint (seconds)


def build_scenarios(ds: ScoutDataset, *,
                    workloads: Optional[Sequence[str]] = None,
                    seeds: Sequence[int] = (0,),
                    variants: Sequence[str] = VARIANTS,
                    conditions: Sequence[FleetCondition] = (HEALTHY,),
                    limit_percentile: float = 40.0) -> List[Scenario]:
    """Cartesian scenario matrix. Computing the per-workload runtime
    limits materializes the simulator cache in canonical order (see
    module docstring)."""
    workloads = list(ds.workloads) if workloads is None else workloads
    limits = {}
    for wl in workloads:
        rts, _, _ = ds.workload_arrays(wl)
        limits[wl] = float(np.percentile(rts, limit_percentile))
    return [Scenario(wl, seed, variant, cond, limits[wl])
            for wl in workloads for seed in seeds
            for variant in variants for cond in conditions]


def _scenario_scores(scenario: Scenario, machine_scores):
    return degrade_scores(machine_scores, scenario.condition)


def reference_search(ds: ScoutDataset, scenario: Scenario,
                     machine_scores: Dict[str, Dict[str, float]],
                     cfg: Optional[ReplayConfig] = None):
    """The sequential numpy tuner for one scenario — the parity and
    wall-clock baseline the batched lanes are pinned against."""
    from repro.tuning.arrow import Arrow
    from repro.tuning.cherrypick import CherryPick
    from repro.tuning.perona_weights import PeronaAcquisitionWeighter

    cfg = ReplayConfig() if cfg is None else cfg
    scores = _scenario_scores(scenario, machine_scores)
    weighter = None
    if scenario.variant.endswith("+perona"):
        weighter = PeronaAcquisitionWeighter(
            ds, scores, strength=cfg.strength, per_dollar=cfg.per_dollar)
    kw = dict(max_runs=cfg.max_runs, n_init=cfg.n_init,
              ei_threshold=cfg.ei_threshold, seed=scenario.seed,
              acquisition_weighter=weighter)
    if scenario.variant.startswith("arrow"):
        low_fn = None
        if scenario.variant == "arrow+perona":
            low_fn = (lambda wl, c:
                      machine_score_vector(scores, c.vm_type))
        tuner = Arrow(ds, scenario.limit, low_level_fn=low_fn, **kw)
    else:
        tuner = CherryPick(ds, scenario.limit, **kw)
    return tuner.search(scenario.workload)


def lane_tables(ds: ScoutDataset, scenarios: Sequence[Scenario],
                machine_scores: Dict[str, Dict[str, float]],
                cfg: Optional[ReplayConfig] = None) -> LaneTables:
    """Lower scenarios to the replay engine's stacked lane tables.

    Feature layout is unified across variants at D = 6 base + 4
    low-level dims; variants that do not use a block hold it constant,
    which leaves the reference GP's kernel unchanged exactly (constant
    dimensions median to zero pairwise distance and are floored out of
    the length scales). Arrow's candidate rows keep the low-level block
    at its search-start value (zeros): the sequential implementation
    computes candidate features once, before any run is observed."""
    from repro.tuning.perona_weights import normalized_machine_scores

    cfg = ReplayConfig() if cfg is None else cfg
    configs = ds.configs
    n_cand = len(configs)
    x_base = np.stack([ds.config_features(c) for c in configs])
    prices = np.asarray([PRICES[c.vm_type] for c in configs])

    workload_cache: Dict[str, Tuple] = {}

    def workload_tables(wl: str):
        if wl not in workload_cache:
            workload_cache[wl] = ds.workload_arrays(wl)
        return workload_cache[wl]

    # keyed by object identity: distinct conditions may share a name
    cond_cache: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}

    def condition_tables(cond: FleetCondition):
        if id(cond) not in cond_cache:
            scores = degrade_scores(machine_scores, cond)
            norm = normalized_machine_scores(scores)
            ns = np.stack([norm.get(c.vm_type, np.ones(4))
                           for c in configs])
            fp_low = machine_score_matrix(
                scores, [c.vm_type for c in configs])
            cond_cache[id(cond)] = (ns, fp_low)
        return cond_cache[id(cond)]

    dim = x_base.shape[1] + 4
    n_lanes = len(scenarios)
    tab = LaneTables(
        x_train=np.zeros((n_lanes, n_cand, dim)),
        x_cand=np.zeros((n_lanes, n_cand, dim)),
        y=np.zeros((n_lanes, n_cand)),
        runtime=np.zeros((n_lanes, n_cand)),
        cost=np.zeros((n_lanes, n_cand)),
        limit=np.zeros(n_lanes),
        price=np.tile(prices, (n_lanes, 1)),
        norm_scores=np.zeros((n_lanes, n_cand, 4)),
        util_low=np.zeros((n_lanes, n_cand, 4)),
        use_weighter=np.zeros(n_lanes, bool),
        init_idx=np.zeros((n_lanes, cfg.n_init), np.int32))

    base_dim = x_base.shape[1]
    for lane, sc in enumerate(scenarios):
        runtimes, costs, lows = workload_tables(sc.workload)
        ns, fp_low = condition_tables(sc.condition)
        tab.x_train[lane, :, :base_dim] = x_base
        tab.x_cand[lane, :, :base_dim] = x_base
        if sc.variant == "arrow":
            # evaluated runs carry their observed low-level metrics;
            # candidates keep the search-start zeros block
            tab.x_train[lane, :, base_dim:] = lows
        elif sc.variant == "arrow+perona":
            # fingerprint scores exist before any run: both sides
            tab.x_train[lane, :, base_dim:] = fp_low
            tab.x_cand[lane, :, base_dim:] = fp_low
        tab.runtime[lane] = runtimes
        tab.cost[lane] = costs
        tab.y[lane] = np.where(runtimes <= sc.limit, costs, costs * 5.0)
        tab.limit[lane] = sc.limit
        tab.norm_scores[lane] = ns
        tab.util_low[lane] = lows
        tab.use_weighter[lane] = sc.variant.endswith("+perona")
        tab.init_idx[lane] = np.random.default_rng(sc.seed).choice(
            n_cand, cfg.n_init, replace=False)
    return tab


def replay_scenarios(ds: ScoutDataset, scenarios: Sequence[Scenario],
                     machine_scores: Dict[str, Dict[str, float]],
                     cfg: Optional[ReplayConfig] = None,
                     return_result: bool = False):
    """End to end: lower the matrix, run the batched replay, return the
    per-scenario :class:`SearchTrace` list (order matches input)."""
    cfg = ReplayConfig() if cfg is None else cfg
    tab = lane_tables(ds, scenarios, machine_scores, cfg)
    result = replay(tab, cfg)
    traces = traces_from_result(tab, result, ds.configs)
    if return_result:
        return traces, result
    return traces
