"""§IV-D scenario matrix over the scout simulator.

A *scenario* is one configuration search: (workload, seed, tuner
variant, fleet condition). The matrix spans the paper's evaluation grid
— 18 workloads x seeds x {cherrypick, arrow} x {vanilla,
perona-weighted} — extended with *fleet conditions*: degraded-node
fleets derived from ``fleet.drift`` analytics, so fingerprint-aware
search is exercised under exactly the degradation the paper motivates
(a degraded machine type's fingerprint scores drop, steering the
weighted acquisition away from it).

``lane_tables`` lowers a scenario list to the stacked arrays the replay
engine consumes; ``reference_search`` runs the identically-configured
sequential tuner (the parity baseline). Both paths must share one
``ScoutDataset`` instance: ``build_scenarios`` materializes the
simulator's runtime cache in canonical (workload, config) order while
computing runtime limits, which pins the contention-noise draws for
every later consumer.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.common.bucketing import next_pow2
from repro.core.ranking import machine_score_matrix, \
    machine_score_vector
from repro.obs import trace as obs_trace
from repro.optimizer.replay import (LaneTables, ReplayConfig,
                                    SeededLaneSpec, replay,
                                    replay_async, replay_seeded_async,
                                    traces_from_result,
                                    traces_from_spec)
from repro.tuning.scout import LOW_CAPS, PRICES, ScoutDataset

VARIANTS = ("cherrypick", "cherrypick+perona", "arrow", "arrow+perona")


@dataclasses.dataclass(frozen=True)
class FleetCondition:
    """A fleet health state: relative fingerprint-score drops per
    (machine type, resource aspect). The healthy fleet has none."""

    name: str
    score_drop: Mapping[str, Mapping[str, float]] = \
        dataclasses.field(default_factory=dict)


HEALTHY = FleetCondition("healthy")


class DeferredFleetCondition:
    """A fleet condition whose score drops are derived on first use —
    typically through the real store path (``simulate_degraded_fleet``
    -> ``fleet.drift`` EWMAs -> ``condition_from_drift``), which costs
    real host time. ``replay_pipelined`` exploits the laziness: with a
    condition-major scenario order (``build_scenarios(
    condition_major=True)``) each block's conditions are derived on the
    host while the previous block's scan runs on device."""

    def __init__(self, name: str, factory):
        self.name = name
        self._factory = factory
        self._resolved: Optional[FleetCondition] = None
        self._lock = threading.Lock()

    @property
    def resolved(self) -> bool:
        return self._resolved is not None

    def resolve(self) -> FleetCondition:
        # double-checked: concurrent resolvers (pipelined per-device
        # workers touching a shared condition) must not run the
        # factory twice — beyond the wasted store-path simulation, two
        # FleetCondition objects would split the replay engine's
        # id()-keyed condition caches
        if self._resolved is None:
            with self._lock:
                if self._resolved is None:
                    cond = self._factory()
                    self._resolved = FleetCondition(self.name,
                                                    cond.score_drop)
        return self._resolved


def resolve_condition(condition) -> FleetCondition:
    """An eager :class:`FleetCondition` as-is; a deferred one derived
    (cached on the deferred object)."""
    if isinstance(condition, DeferredFleetCondition):
        return condition.resolve()
    return condition


def degrade_scores(machine_scores: Dict[str, Dict[str, float]],
                   condition: FleetCondition
                   ) -> Dict[str, Dict[str, float]]:
    """Apply a condition's relative drops to a machine-score dict."""
    condition = resolve_condition(condition)
    out = {m: dict(per) for m, per in machine_scores.items()}
    for vm, aspects in condition.score_drop.items():
        if vm not in out:
            continue
        for aspect, drop in aspects.items():
            if aspect in out[vm]:
                out[vm][aspect] *= (1.0 - drop)
    return out


def condition_from_drift(name: str, report: Dict[str, "NodeDrift"],
                         node_types: Mapping[str, str],
                         rel_drop: float = 0.2) -> FleetCondition:
    """Build a condition from ``fleet.drift.drift_report`` output:
    every drop ``fleet.drift.degradation_factors`` reports for a node
    votes for its machine type; drops average per type."""
    from repro.fleet.drift import degradation_factors

    acc: Dict[str, Dict[str, List[float]]] = {}
    for node, drops in degradation_factors(report, rel_drop).items():
        vm = node_types.get(node)
        if vm is None:
            continue
        for aspect, frac in drops.items():
            acc.setdefault(vm, {}).setdefault(aspect, []).append(frac)
    return FleetCondition(name, {
        vm: {a: float(np.mean(v)) for a, v in per.items()}
        for vm, per in acc.items()})


def simulate_degraded_fleet(machine_types: Sequence[str],
                            degraded: Mapping[str, Sequence[str]],
                            *, severity: float = 0.9, rounds: int = 10,
                            healthy_rounds: int = 3, seed: int = 0):
    """Run one simulated node per machine type through streaming
    benchmark rounds, attach synthetic quality scores that decay on the
    ``degraded`` types' aspects over the later rounds, and return the
    resulting ``fleet.drift`` report plus the node->type map.

    This exercises the real fleet path (store appends, chain views,
    EWMA analytics) without model training: attached codes are unit
    vectors scaled so ``core.ranking.code_scores`` equals the intended
    quality directly."""
    from repro.core.ranking import ASPECT_OF_TYPE
    from repro.fingerprint.runner import SuiteRunner
    from repro.fleet.drift import drift_report
    from repro.fleet.store import FingerprintStore

    day = 86400.0
    runner = SuiteRunner(seed=seed)
    machines = {f"{vm}-0": vm for vm in machine_types}
    store = FingerprintStore()
    for k in range(rounds):
        frame = runner.run_frame(machines, runs_per_type=1,
                                 t_offset=k * day)
        first = store.append(frame)
        n = len(frame)
        codes = np.zeros((n, 4), np.float32)
        anomaly = np.full(n, 0.05, np.float32)
        ramp = max(0.0, (k - healthy_rounds + 1)
                   / max(rounds - healthy_rounds, 1))
        for j in range(n):
            vm = frame.machine_types[frame.machine_type_code[j]]
            aspect = ASPECT_OF_TYPE[
                frame.benchmark_types[frame.type_code[j]]]
            quality = 1.0
            if aspect in degraded.get(vm, ()):
                quality = 1.0 - severity * ramp
                anomaly[j] = 0.05 + 0.9 * ramp
            codes[j, 0] = quality
        store.attach(np.arange(first, first + n), anomaly, codes)
    return drift_report(store), machines


def drifted_condition(machine_types: Sequence[str],
                      aspects: Sequence[str] = ("cpu",),
                      name: Optional[str] = None,
                      seed: int = 0, deferred: bool = False):
    """The canonical degraded-fleet condition used by the benchmark and
    the example: simulate the given machine types losing quality on the
    given aspects, run the fleet drift analytics, and turn the report
    into a condition.

    ``deferred=True`` returns a :class:`DeferredFleetCondition` that
    runs the store-path simulation on first use instead of now — the
    pipelined replay then overlaps that host work with device scans."""
    if name is None:
        name = f"{'/'.join(machine_types)}-{'/'.join(aspects)}-degraded"

    def derive() -> FleetCondition:
        report, node_types = simulate_degraded_fleet(
            machine_types, degraded={vm: tuple(aspects)
                                     for vm in machine_types}, seed=seed)
        return condition_from_drift(name, report, node_types)

    if deferred:
        return DeferredFleetCondition(name, derive)
    return derive()


@dataclasses.dataclass(frozen=True)
class Scenario:
    workload: str
    seed: int
    variant: str  # one of VARIANTS
    condition: FleetCondition  # or DeferredFleetCondition
    limit: float  # runtime constraint (seconds)


def build_scenarios(ds: ScoutDataset, *,
                    workloads: Optional[Sequence[str]] = None,
                    seeds: Sequence[int] = (0,),
                    variants: Sequence[str] = VARIANTS,
                    conditions: Sequence[FleetCondition] = (HEALTHY,),
                    limit_percentile: float = 40.0,
                    condition_major: bool = False) -> List[Scenario]:
    """Cartesian scenario matrix. Computing the per-workload runtime
    limits materializes the simulator cache in canonical order (see
    module docstring).

    ``condition_major=True`` orders the matrix condition-outermost, so
    every contiguous lane block touches as few conditions as possible
    — with deferred (store-path-derived) conditions, the pipelined
    replay then derives each block's conditions while the previous
    block runs on device. Building the matrix never resolves deferred
    conditions."""
    workloads = list(ds.workloads) if workloads is None else workloads
    limits = {}
    for wl in workloads:
        rts, _, _ = ds.workload_arrays(wl)
        limits[wl] = float(np.percentile(rts, limit_percentile))
    if condition_major:
        return [Scenario(wl, seed, variant, cond, limits[wl])
                for cond in conditions for wl in workloads
                for seed in seeds for variant in variants]
    return [Scenario(wl, seed, variant, cond, limits[wl])
            for wl in workloads for seed in seeds
            for variant in variants for cond in conditions]


def _scenario_scores(scenario: Scenario, machine_scores):
    return degrade_scores(machine_scores, scenario.condition)


def reference_search(ds: ScoutDataset, scenario: Scenario,
                     machine_scores: Dict[str, Dict[str, float]],
                     cfg: Optional[ReplayConfig] = None):
    """The sequential numpy tuner for one scenario — the parity and
    wall-clock baseline the batched lanes are pinned against."""
    from repro.tuning.arrow import Arrow
    from repro.tuning.cherrypick import CherryPick
    from repro.tuning.perona_weights import PeronaAcquisitionWeighter

    cfg = ReplayConfig() if cfg is None else cfg
    scores = _scenario_scores(scenario, machine_scores)
    weighter = None
    if scenario.variant.endswith("+perona"):
        weighter = PeronaAcquisitionWeighter(
            ds, scores, strength=cfg.strength, per_dollar=cfg.per_dollar)
    kw = dict(max_runs=cfg.max_runs, n_init=cfg.n_init,
              ei_threshold=cfg.ei_threshold, seed=scenario.seed,
              acquisition_weighter=weighter)
    if scenario.variant.startswith("arrow"):
        low_fn = None
        if scenario.variant == "arrow+perona":
            low_fn = (lambda wl, c:
                      machine_score_vector(scores, c.vm_type))
        tuner = Arrow(ds, scenario.limit, low_level_fn=low_fn, **kw)
    else:
        tuner = CherryPick(ds, scenario.limit, **kw)
    return tuner.search(scenario.workload)


def lane_tables(ds: ScoutDataset, scenarios: Sequence[Scenario],
                machine_scores: Dict[str, Dict[str, float]],
                cfg: Optional[ReplayConfig] = None) -> LaneTables:
    """Lower scenarios to the replay engine's stacked lane tables.

    Feature layout is unified across variants at D = 6 base + 4
    low-level dims; variants that do not use a block hold it constant,
    which leaves the reference GP's kernel unchanged exactly (constant
    dimensions median to zero pairwise distance and are floored out of
    the length scales). Arrow's candidate rows keep the low-level block
    at its search-start value (zeros): the sequential implementation
    computes candidate features once, before any run is observed."""
    from repro.tuning.perona_weights import normalized_machine_scores

    cfg = ReplayConfig() if cfg is None else cfg
    configs = ds.configs
    n_cand = len(configs)
    x_base = np.stack([ds.config_features(c) for c in configs])
    prices = np.asarray([PRICES[c.vm_type] for c in configs])

    workload_cache: Dict[str, Tuple] = {}

    def workload_tables(wl: str):
        if wl not in workload_cache:
            workload_cache[wl] = ds.workload_arrays(wl)
        return workload_cache[wl]

    # keyed by object identity: distinct conditions may share a name
    cond_cache: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}

    def condition_tables(cond: FleetCondition):
        if id(cond) not in cond_cache:
            scores = degrade_scores(machine_scores, cond)
            norm = normalized_machine_scores(scores)
            ns = np.stack([norm.get(c.vm_type, np.ones(4))
                           for c in configs])
            fp_low = machine_score_matrix(
                scores, [c.vm_type for c in configs])
            cond_cache[id(cond)] = (ns, fp_low)
        return cond_cache[id(cond)]

    dim = x_base.shape[1] + 4
    n_lanes = len(scenarios)
    tab = LaneTables(
        x_train=np.zeros((n_lanes, n_cand, dim)),
        x_cand=np.zeros((n_lanes, n_cand, dim)),
        y=np.zeros((n_lanes, n_cand)),
        runtime=np.zeros((n_lanes, n_cand)),
        cost=np.zeros((n_lanes, n_cand)),
        limit=np.zeros(n_lanes),
        price=np.tile(prices, (n_lanes, 1)),
        norm_scores=np.zeros((n_lanes, n_cand, 4)),
        util_low=np.zeros((n_lanes, n_cand, 4)),
        use_weighter=np.zeros(n_lanes, bool),
        init_idx=np.zeros((n_lanes, cfg.n_init), np.int32))

    base_dim = x_base.shape[1]
    tab.x_train[:, :, :base_dim] = x_base
    tab.x_cand[:, :, :base_dim] = x_base
    # lanes sharing (workload, condition, variant, limit) get identical
    # rows: assign per group (one fancy-index write each) instead of
    # per lane — the python work is O(groups + lanes), which keeps
    # table construction cheap enough to overlap with device scans
    groups: Dict[Tuple, List[int]] = {}
    for lane, sc in enumerate(scenarios):
        groups.setdefault(
            (sc.workload, id(sc.condition), sc.variant, sc.limit),
            []).append(lane)
    for (wl, _, variant, limit), lanes in groups.items():
        sc = scenarios[lanes[0]]
        rows = np.asarray(lanes)
        runtimes, costs, lows = workload_tables(wl)
        ns, fp_low = condition_tables(sc.condition)
        if variant == "arrow":
            # evaluated runs carry their observed low-level metrics;
            # candidates keep the search-start zeros block
            tab.x_train[rows, :, base_dim:] = lows
        elif variant == "arrow+perona":
            # fingerprint scores exist before any run: both sides
            tab.x_train[rows, :, base_dim:] = fp_low
            tab.x_cand[rows, :, base_dim:] = fp_low
        tab.runtime[rows] = runtimes
        tab.cost[rows] = costs
        tab.y[rows] = np.where(runtimes <= limit, costs, costs * 5.0)
        tab.limit[rows] = limit
        tab.norm_scores[rows] = ns
        tab.util_low[rows] = lows
        tab.use_weighter[rows] = variant.endswith("+perona")
    init_cache: Dict[int, np.ndarray] = {}
    for lane, sc in enumerate(scenarios):
        if sc.seed not in init_cache:
            init_cache[sc.seed] = np.random.default_rng(sc.seed).choice(
                n_cand, cfg.n_init, replace=False).astype(np.int32)
        tab.init_idx[lane] = init_cache[sc.seed]
    return tab


def lane_spec(ds: ScoutDataset, scenarios: Sequence[Scenario],
              machine_scores: Dict[str, Dict[str, float]],
              cfg: Optional[ReplayConfig] = None) -> SeededLaneSpec:
    """Lower scenarios to the *seeded* replay inputs: the shared
    deterministic grid (``ds.grid``), one score matrix per distinct
    fleet condition, and per-lane ids. O(W*C + K*C + L) host work and
    memory — the O(L*C*D) lane tables are generated inside the
    compiled program instead (``replay.replay_seeded_async``), with
    the contention noise re-drawn on device from ``ds.grid.noise_key``
    counter-based keys."""
    from repro.tuning.perona_weights import normalized_machine_scores

    cfg = ReplayConfig() if cfg is None else cfg
    configs = ds.configs
    n_cand = len(configs)
    grid = ds.grid
    n_lanes = len(scenarios)

    # one score-matrix pair per distinct condition object (identity
    # keyed: distinct conditions may share a name); resolving a
    # deferred condition happens here, on the host, thread-safely
    cond_rows: Dict[int, int] = {}
    ns_rows: List[np.ndarray] = []
    fp_rows: List[np.ndarray] = []
    condition_id = np.empty(n_lanes, np.int32)
    workload_id = np.empty(n_lanes, np.int32)
    variant_id = np.empty(n_lanes, np.int32)
    limit = np.empty(n_lanes, np.float64)
    init_idx = np.zeros((n_lanes, cfg.n_init), np.int32)
    init_cache: Dict[int, np.ndarray] = {}
    for lane, sc in enumerate(scenarios):
        row = cond_rows.get(id(sc.condition))
        if row is None:
            scores = degrade_scores(machine_scores, sc.condition)
            norm = normalized_machine_scores(scores)
            ns_rows.append(np.stack([norm.get(c.vm_type, np.ones(4))
                                     for c in configs]))
            fp_rows.append(machine_score_matrix(
                scores, [c.vm_type for c in configs]))
            row = cond_rows[id(sc.condition)] = len(ns_rows) - 1
        condition_id[lane] = row
        workload_id[lane] = ds.workload_id(sc.workload)
        variant_id[lane] = VARIANTS.index(sc.variant)
        limit[lane] = sc.limit
        if sc.seed not in init_cache:
            init_cache[sc.seed] = np.random.default_rng(sc.seed).choice(
                n_cand, cfg.n_init, replace=False).astype(np.int32)
        init_idx[lane] = init_cache[sc.seed]

    from repro.tuning.scout import CONTENTION_SCALE

    return SeededLaneSpec(
        base_runtime=grid.base_runtime, low_num=grid.low_num,
        low_caps=np.asarray(LOW_CAPS, np.float64),
        x_base=grid.x_base, price=grid.price,
        count=grid.count.astype(np.float64, copy=False),
        config_uid=grid.config_uid,
        norm_scores=np.stack(ns_rows), fp_low=np.stack(fp_rows),
        noise_key=grid.noise_key, noise_scale=CONTENTION_SCALE,
        workload_id=workload_id, condition_id=condition_id,
        variant_id=variant_id, limit=limit, init_idx=init_idx,
        runtime=grid.runtime, cost=grid.cost)


def replay_scenarios(ds: ScoutDataset, scenarios: Sequence[Scenario],
                     machine_scores: Dict[str, Dict[str, float]],
                     cfg: Optional[ReplayConfig] = None,
                     return_result: bool = False, *,
                     devices: Optional[Sequence] = None,
                     seeded: bool = False):
    """End to end: lower the matrix, run the batched replay (sharded
    over ``devices`` when given), return the per-scenario
    :class:`SearchTrace` list (order matches input).

    ``seeded=True`` lowers to the compact :class:`SeededLaneSpec` and
    generates the lane tables inside the compiled program instead of
    materializing them on host — bit-identical traces."""
    cfg = ReplayConfig() if cfg is None else cfg
    if seeded:
        spec = lane_spec(ds, scenarios, machine_scores, cfg)
        result = replay_seeded_async(spec, cfg,
                                     devices=devices).result()
        traces = traces_from_spec(spec, result, ds.configs)
    else:
        tab = lane_tables(ds, scenarios, machine_scores, cfg)
        result = replay(tab, cfg, devices=devices)
        traces = traces_from_result(tab, result, ds.configs)
    if return_result:
        return traces, result
    return traces


def replay_pipelined(ds: ScoutDataset, scenarios: Sequence[Scenario],
                     machine_scores: Dict[str, Dict[str, float]],
                     cfg: Optional[ReplayConfig] = None, *,
                     block_lanes: int = 128,
                     devices: Optional[Sequence] = None,
                     shard_blocks: bool = False,
                     seeded: bool = False,
                     return_stats: bool = False):
    """Host-pipelined replay of a large scenario matrix over per-device
    lane buckets.

    The matrix is chunked into fixed-size lane blocks; block N+1's
    tables — workload arrays, deferred (store-path-derived) fleet
    conditions, condition score matrices, seeded init draws — are
    built on the host *while earlier blocks run on device*. Blocks are
    round-robined over ``devices`` as independent single-program
    dispatches (``replay_async(device=...)``), one worker thread per
    device, up to ``len(devices)`` dispatches in flight: devices
    execute different lane buckets concurrently while the main thread
    keeps building tables and materializing finished blocks' traces (a
    double-buffered loop generalized to mesh depth; XLA releases the
    GIL during execution).

    Every block pads its lane axis to the same ``block_lanes`` bucket
    (lane padding repeats lane 0, masked out), so ONE traced program
    serves any matrix size — replaying 100-, 200- and 432-lane matrices
    reuses a single trace (``REPLAY_TRACES``; asserted in
    tests/test_optimizer.py). Results are identical to the unpipelined
    ``replay_scenarios`` lane-for-lane: blocks never interact, and a
    lane's math does not depend on which device runs it.

    ``shard_blocks=True`` instead partitions each block's lane axis
    over ALL the devices with one ``shard_map`` dispatch in flight
    (the whole-matrix sharded layout, blocked for table overlap):
    prefer it when a single block saturates the mesh; the default
    round-robin keeps devices busy on independent blocks.

    ``seeded=True`` lowers each block to the compact
    :class:`SeededLaneSpec` (O(block) host work per block instead of
    O(block x candidates x dims)) and generates the lane tables inside
    the compiled program — same traces, far less host table time, so
    the pipeline stays device-bound at matrix sizes where host table
    construction would otherwise dominate.

    Returns the per-scenario trace list; with ``return_stats`` also a
    dict of pipeline counters (blocks, dispatches, device count, host
    table seconds).
    """
    from concurrent.futures import ThreadPoolExecutor

    from repro.common.mesh import pow2_devices

    cfg = ReplayConfig() if cfg is None else cfg
    if shard_blocks and devices is None:
        raise ValueError("shard_blocks=True needs devices= (the mesh "
                         "to partition each block over)")
    block = next_pow2(max(block_lanes, 1))
    devs = pow2_devices(devices) if devices is not None else [None]
    devs = devs or [None]  # empty device list -> default placement
    if shard_blocks:
        devs = [None]  # one shard_map dispatch in flight at a time
    traces: List = []
    stats = {"blocks": 0, "dispatches": 0, "block_lanes": block,
             "devices": (len(pow2_devices(devices))
                         if devices is not None else 1),
             "table_s": 0.0}

    dispatch = replay_seeded_async if seeded else replay_async

    def run_block(tab, dev, block_idx):
        # worker thread: dispatch + device wait (GIL released inside
        # XLA); per-device workers keep each device's blocks in order.
        # The span lands on the worker's own timeline track — its
        # overlap with the main thread's replay.build_tables spans IS
        # the pipelining (asserted in tests/test_obs.py).
        with obs_trace.span("replay.block_scan",
                            cat=obs_trace.CAT_DEVICE,
                            args={"block": block_idx,
                                  "lanes": len(tab)}):
            if shard_blocks:
                return dispatch(tab, cfg, devices=devices,
                                lanes_floor=block).result()
            return dispatch(tab, cfg, device=dev,
                            lanes_floor=block).result()

    def collect(tab, future):
        result = future.result()
        stats["dispatches"] += result.dispatches
        with obs_trace.span("replay.materialize_traces",
                            args={"lanes": len(tab)}):
            if seeded:
                traces.extend(
                    traces_from_spec(tab, result, ds.configs))
            else:
                traces.extend(
                    traces_from_result(tab, result, ds.configs))

    in_flight: List = []  # (tables, future), submission order
    # one single-worker pool per device: a device's blocks dispatch in
    # order from its own thread, and a long-running block on one
    # device never steals the worker a later block needs for another
    pools = [ThreadPoolExecutor(max_workers=1) for _ in devs]
    try:
        for i, start in enumerate(range(0, len(scenarios), block)):
            chunk = scenarios[start:start + block]
            t0 = time.perf_counter()  # host work, overlapped with the
            with obs_trace.span("replay.build_tables",
                                args={"block": i,
                                      "lanes": len(chunk)}):
                if seeded:
                    tab = lane_spec(ds, chunk, machine_scores, cfg)
                else:
                    tab = lane_tables(ds, chunk, machine_scores, cfg)
            stats["table_s"] += time.perf_counter() - t0
            d = i % len(devs)
            in_flight.append(
                (tab, pools[d].submit(run_block, tab, devs[d], i)))
            stats["blocks"] += 1
            # drain finished blocks (in order) without blocking, and
            # cap the queue at one block per device
            while in_flight and (in_flight[0][1].done()
                                 or len(in_flight) > len(devs)):
                collect(*in_flight.pop(0))
        for pending in in_flight:
            collect(*pending)
    finally:
        for pool in pools:
            pool.shutdown(wait=True)
    if return_stats:
        return traces, stats
    return traces
