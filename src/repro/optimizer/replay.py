"""Vmapped BO search lanes as one ``lax.scan`` over rounds.

Replays many CherryPick/Arrow-style configuration searches (paper
§IV-D) in parallel: every *lane* is one (workload, seed, tuner variant,
fleet condition) scenario over the same candidate grid; one scan step
advances every still-active lane by one BO round (masked GP fit on the
lane's evaluated set, EI + optional Perona weighting, stopping rules,
argmax selection). The whole search is a single device dispatch —
carries are donated, lanes and observation slots are pow2-padded
(``common.bucketing.next_pow2``) so repeated replays of similar
matrices reuse one compiled program (``REPLAY_TRACES`` counts
tracings; tests assert amortization).

All math runs in float64 (``jax.experimental.enable_x64`` around the
dispatch) so batched lanes reproduce the sequential scipy traces
bit-for-bit on identical seeds: same evaluated configs, same
best-valid-cost curves (see tests/test_optimizer.py).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional

import numpy as np

from repro.common.bucketing import next_pow2
from repro.core.trainer import TraceCount

#: Ticked once per tracing of the scanned replay program.
REPLAY_TRACES = TraceCount()


@dataclasses.dataclass(frozen=True)
class ReplayConfig:
    """Search hyperparameters, matching the sequential defaults
    (``CherryPick.__init__`` / ``GP`` / ``PeronaAcquisitionWeighter``)."""

    max_runs: int = 9
    n_init: int = 3
    ei_threshold: float = 0.1
    noise: float = 1e-3
    xi: float = 0.01
    strength: float = 0.3
    per_dollar: bool = True


@dataclasses.dataclass
class LaneTables:
    """Per-lane constant tables (numpy, lane-stacked; L lanes over a
    shared candidate grid of C configurations, feature dim D)."""

    x_train: np.ndarray  # (L, C, D) GP features of *evaluated* configs
    x_cand: np.ndarray  # (L, C, D) GP features of candidates (Arrow's
    #                      imputation quirk makes these differ, see
    #                      scenarios.lane_tables)
    y: np.ndarray  # (L, C) constraint-penalized objective
    runtime: np.ndarray  # (L, C) runtimes (constraint checks)
    cost: np.ndarray  # (L, C) raw execution cost (trace reporting)
    limit: np.ndarray  # (L,) runtime constraint
    price: np.ndarray  # (L, C) $/h of the candidate's machine type
    norm_scores: np.ndarray  # (L, C, 4) normalized fingerprint scores
    util_low: np.ndarray  # (L, C, 4) per-run utilization metrics
    use_weighter: np.ndarray  # (L,) Perona-weighted lane flag
    init_idx: np.ndarray  # (L, n_init) seeded init draws

    def __len__(self) -> int:
        return len(self.y)


@dataclasses.dataclass
class BatchReplayResult:
    chosen: np.ndarray  # (L, max_runs) evaluated config indices, -1 pad
    count: np.ndarray  # (L,) evaluations performed per lane
    dispatches: int  # device dispatches of this replay (always 1)


def _lane_step(sel, count, active, xt, xc, y_tab, r_tab, ulow, ns,
               price, limit, use_w, *, cfg: ReplayConfig, slots: int):
    """One BO round of one lane (vmapped over lanes by the caller)."""
    import jax.numpy as jnp

    from repro.optimizer.acquire import (expected_improvement,
                                         perona_weight_factors)
    from repro.optimizer.gp import gp_fit, gp_predict

    n_cand = y_tab.shape[0]
    idx = jnp.maximum(sel, 0)
    omask = jnp.arange(cfg.max_runs) < count
    # pad the observation axis to the pow2 slot count
    idx_p = jnp.zeros(slots, sel.dtype).at[: cfg.max_runs].set(idx)
    mask_p = jnp.arange(slots) < count

    x_obs = xt[idx_p]
    y_obs = y_tab[idx_p]
    state = gp_fit(x_obs, y_obs, mask_p, noise=cfg.noise,
                   median_rows=cfg.max_runs)
    mu, sigma = gp_predict(state, xc)
    best = jnp.min(jnp.where(mask_p, y_obs, jnp.inf))
    ei = expected_improvement(mu, sigma, best, xi=cfg.xi)

    util = jnp.sum(jnp.where(mask_p[:, None], ulow[idx_p], 0.0),
                   axis=0) / count
    any_valid = jnp.any(mask_p & (r_tab[idx_p] <= limit))
    factor = perona_weight_factors(util, ns, price, any_valid,
                                   strength=cfg.strength,
                                   per_dollar=cfg.per_dollar)
    ei = jnp.where(use_w, ei * factor, ei)

    seen = jnp.zeros(n_cand, jnp.int32).at[idx].add(
        omask.astype(jnp.int32)) > 0
    ei = jnp.where(seen, -jnp.inf, ei)
    # float32-rounded selection grid, shared with the sequential
    # reference (see CherryPick.search): deterministic tie-breaks on
    # ulp-close candidates regardless of backend rounding
    ei = ei.astype(jnp.float32).astype(jnp.float64)

    mx = jnp.max(ei)
    stop_flat = mx <= 0.0
    stop_converged = ((mx / jnp.maximum(best, 1e-9) < cfg.ei_threshold)
                      & (count >= cfg.n_init + 2))
    advance = active & ~stop_flat & ~stop_converged
    pick = jnp.argmax(ei).astype(sel.dtype)
    sel = sel.at[count].set(jnp.where(advance, pick, sel[count]))
    count = count + advance.astype(count.dtype)
    return sel, count, advance


@functools.lru_cache(maxsize=32)
def _replay_fn(cfg: ReplayConfig, lanes: int, slots: int, n_cand: int,
               dim: int, rounds: int):
    """Jitted scan program for one (config, shape) signature."""
    import jax

    step = functools.partial(_lane_step, cfg=cfg, slots=slots)
    step_v = jax.vmap(step)

    def run(carry, tables):
        REPLAY_TRACES.tick()

        def scan_step(c, _):
            sel, count, active = c
            sel, count, active = step_v(sel, count, active, *tables)
            return (sel, count, active), None

        (sel, count, _), _ = jax.lax.scan(scan_step, carry, None,
                                          length=rounds)
        return sel, count

    return jax.jit(run, donate_argnums=(0,))


def replay(tables: LaneTables,
           cfg: Optional[ReplayConfig] = None) -> BatchReplayResult:
    """Run every lane's full search as one scanned device dispatch."""
    import jax
    from jax.experimental import enable_x64

    cfg = ReplayConfig() if cfg is None else cfg
    n_lanes = len(tables)
    if n_lanes == 0:
        return BatchReplayResult(
            chosen=np.zeros((0, cfg.max_runs), np.int32),
            count=np.zeros(0, np.int32), dispatches=0)
    lanes = next_pow2(n_lanes)
    slots = next_pow2(cfg.max_runs)
    n_cand, dim = tables.x_train.shape[1:]
    rounds = cfg.max_runs - cfg.n_init

    def pad(a):  # pad the lane axis by repeating lane 0 (masked out)
        if len(a) == lanes:
            return a
        reps = np.repeat(a[:1], lanes - len(a), axis=0)
        return np.concatenate([a, reps], axis=0)

    sel0 = np.full((lanes, cfg.max_runs), -1, np.int32)
    sel0[:, : cfg.n_init] = pad(tables.init_idx)
    count0 = np.full(lanes, cfg.n_init, np.int32)
    active0 = np.ones(lanes, bool)

    from repro.serving.engine import silence_unusable_donation

    fn = _replay_fn(cfg, lanes, slots, n_cand, dim, rounds)
    with enable_x64(), silence_unusable_donation():
        jnp_tables = tuple(
            jax.numpy.asarray(pad(a)) for a in (
                tables.x_train.astype(np.float64),
                tables.x_cand.astype(np.float64),
                tables.y.astype(np.float64),
                tables.runtime.astype(np.float64),
                tables.util_low.astype(np.float64),
                tables.norm_scores.astype(np.float64),
                tables.price.astype(np.float64),
                tables.limit.astype(np.float64),
                tables.use_weighter.astype(bool)))
        carry0 = (jax.numpy.asarray(sel0), jax.numpy.asarray(count0),
                  jax.numpy.asarray(active0))
        sel, count = fn(carry0, jnp_tables)
        sel, count = np.asarray(sel), np.asarray(count)
    return BatchReplayResult(chosen=sel[:n_lanes], count=count[:n_lanes],
                             dispatches=1)


def traces_from_result(tables: LaneTables, result: BatchReplayResult,
                       configs) -> List["SearchTrace"]:
    """Materialize per-lane :class:`tuning.cherrypick.SearchTrace`
    objects (identical field-for-field to the sequential traces when
    the lane reproduced the sequential decisions)."""
    from repro.tuning.cherrypick import SearchTrace

    out = []
    for lane in range(len(tables)):
        k = int(result.count[lane])
        picks = result.chosen[lane, :k]
        costs = [float(tables.cost[lane, i]) for i in picks]
        runtimes = [float(tables.runtime[lane, i]) for i in picks]
        limit = float(tables.limit[lane])
        best_curve = []
        for j in range(k):
            valid = [c for c, r in zip(costs[: j + 1], runtimes[: j + 1])
                     if r <= limit]
            best_curve.append(min(valid) if valid else np.inf)
        out.append(SearchTrace(
            evaluated=[configs[int(i)] for i in picks], costs=costs,
            runtimes=runtimes, best_valid_cost=best_curve,
            search_cost=float(np.sum(costs))))
    return out
