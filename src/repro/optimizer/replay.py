"""Vmapped BO search lanes as one ``lax.scan`` over rounds — optionally
sharded over a 1-D device mesh.

Replays many CherryPick/Arrow-style configuration searches (paper
§IV-D) in parallel: every *lane* is one (workload, seed, tuner variant,
fleet condition) scenario over the same candidate grid; one scan step
advances every still-active lane by one BO round (masked GP fit on the
lane's evaluated set, EI + optional Perona weighting, stopping rules,
argmax selection). The whole search is a single device dispatch —
carries are donated, lanes and observation slots are pow2-padded
(``common.mesh.shard_size``) so repeated replays of similar matrices
reuse one compiled program (``REPLAY_TRACES`` counts tracings; tests
assert amortization).

Pass ``devices=`` to partition the lane axis across a device mesh
(``common.mesh`` plumbing, the ``fleet.shard`` pattern):
``shard_map(vmap(step))`` gives every device its own lane bucket, the
scan runs once per device over local lanes, and carries stay donated.
Lanes never interact, so sharded replay is *bit-identical* to the
single-device scan — and therefore to the sequential scipy traces
(asserted under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
in tests/test_optimizer.py).

``replay_async`` dispatches and defers the host fetch
(:class:`PendingReplay`) — a real overlap window on asynchronous
backends (GPU/TPU dispatch returns before compute finishes). XLA:CPU
executes synchronously, so there ``scenarios.replay_pipelined``
produces the overlap instead: per-device worker threads run this same
entry point while the main thread builds the next lane block's
tables.

All math runs in float64 (``jax.experimental.enable_x64`` around the
dispatch) so batched lanes reproduce the sequential scipy traces
bit-for-bit on identical seeds: same evaluated configs, same
best-valid-cost curves (see tests/test_optimizer.py).
"""

from __future__ import annotations

import dataclasses
import functools
import threading
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.common.mesh import (axis_specs, build_mesh, pad_lanes,
                               pow2_devices, shard_map_1d, shard_size)
from repro.obs.jaxstat import JitSite

#: Ticked once per tracing of the scanned replay program — a
#: registry-backed :class:`repro.obs.jaxstat.JitSite` whose
#: ``dispatch()`` wrapper additionally books per-dispatch wall time
#: into compile-vs-run registry counters and records a device span.
REPLAY_TRACES = JitSite("optimizer.replay")


@dataclasses.dataclass(frozen=True)
class ReplayConfig:
    """Search hyperparameters, matching the sequential defaults
    (``CherryPick.__init__`` / ``GP`` / ``PeronaAcquisitionWeighter``)."""

    max_runs: int = 9
    n_init: int = 3
    ei_threshold: float = 0.1
    noise: float = 1e-3
    xi: float = 0.01
    strength: float = 0.3
    per_dollar: bool = True


@dataclasses.dataclass
class LaneTables:
    """Per-lane constant tables (numpy, lane-stacked; L lanes over a
    shared candidate grid of C configurations, feature dim D)."""

    x_train: np.ndarray  # (L, C, D) GP features of *evaluated* configs
    x_cand: np.ndarray  # (L, C, D) GP features of candidates (Arrow's
    #                      imputation quirk makes these differ, see
    #                      scenarios.lane_tables)
    y: np.ndarray  # (L, C) constraint-penalized objective
    runtime: np.ndarray  # (L, C) runtimes (constraint checks)
    cost: np.ndarray  # (L, C) raw execution cost (trace reporting)
    limit: np.ndarray  # (L,) runtime constraint
    price: np.ndarray  # (L, C) $/h of the candidate's machine type
    norm_scores: np.ndarray  # (L, C, 4) normalized fingerprint scores
    util_low: np.ndarray  # (L, C, 4) per-run utilization metrics
    use_weighter: np.ndarray  # (L,) Perona-weighted lane flag
    init_idx: np.ndarray  # (L, n_init) seeded init draws

    def __len__(self) -> int:
        return len(self.y)


@dataclasses.dataclass
class BatchReplayResult:
    chosen: np.ndarray  # (L, max_runs) evaluated config indices, -1 pad
    count: np.ndarray  # (L,) evaluations performed per lane
    dispatches: int  # device dispatches of this replay (always 1)


@dataclasses.dataclass
class SeededLaneSpec:
    """Seeded replay program inputs: O(W*C + K*C + L) instead of the
    O(L*C*D) materialized :class:`LaneTables`.

    The shared grid tables (deterministic, workload/config/condition
    indexed) are replicated across devices; the per-lane arrays are
    just ids + the runtime limit + seeded init draws. The compiled
    program re-derives every stochastic table cell in-program from
    ``noise_key`` (counter-based ``fold_in(key, workload_id,
    config_uid)`` draws, see ``common.rng``), bit-identical to the
    host grid — lane tables are never materialized on host.

    ``runtime``/``cost`` are the host copies of the (W, C) grids used
    only to materialize traces after the fetch; they are not shipped
    to the device."""

    # shared grid tables (replicated)
    base_runtime: np.ndarray  # (W, C) noise-free runtime component
    low_num: np.ndarray  # (W, C, 4) utilization-metric numerators
    low_caps: np.ndarray  # (4,) utilization metric caps
    x_base: np.ndarray  # (C, B) base feature block
    price: np.ndarray  # (C,) USD/h per candidate
    count: np.ndarray  # (C,) node counts
    config_uid: np.ndarray  # (C,) fold-in uids (noise counters)
    norm_scores: np.ndarray  # (K, C, 4) per-condition weighter scores
    fp_low: np.ndarray  # (K, C, 4) per-condition fingerprint features
    noise_key: np.ndarray  # (2,) uint32 contention stream key
    noise_scale: float  # lognormal noise scale
    # per-lane (partitioned over devices)
    workload_id: np.ndarray  # (L,) int32
    condition_id: np.ndarray  # (L,) int32 row into norm_scores/fp_low
    variant_id: np.ndarray  # (L,) int32 index into scenarios.VARIANTS
    limit: np.ndarray  # (L,) runtime constraint
    init_idx: np.ndarray  # (L, n_init) seeded init draws
    # host-only trace tables
    runtime: np.ndarray  # (W, C)
    cost: np.ndarray  # (W, C)

    def __len__(self) -> int:
        return len(self.workload_id)


def _lane_step(sel, count, active, xt, xc, y_tab, r_tab, ulow, ns,
               price, limit, use_w, *, cfg: ReplayConfig, slots: int):
    """One BO round of one lane (vmapped over lanes by the caller)."""
    import jax.numpy as jnp

    from repro.optimizer.acquire import (expected_improvement,
                                         perona_weight_factors)
    from repro.optimizer.gp import gp_fit, gp_predict

    n_cand = y_tab.shape[0]
    idx = jnp.maximum(sel, 0)
    omask = jnp.arange(cfg.max_runs) < count
    # pad the observation axis to the pow2 slot count
    idx_p = jnp.zeros(slots, sel.dtype).at[: cfg.max_runs].set(idx)
    mask_p = jnp.arange(slots) < count

    x_obs = xt[idx_p]
    y_obs = y_tab[idx_p]
    state = gp_fit(x_obs, y_obs, mask_p, noise=cfg.noise,
                   median_rows=cfg.max_runs)
    mu, sigma = gp_predict(state, xc)
    best = jnp.min(jnp.where(mask_p, y_obs, jnp.inf))
    ei = expected_improvement(mu, sigma, best, xi=cfg.xi)

    util = jnp.sum(jnp.where(mask_p[:, None], ulow[idx_p], 0.0),
                   axis=0) / count
    any_valid = jnp.any(mask_p & (r_tab[idx_p] <= limit))
    factor = perona_weight_factors(util, ns, price, any_valid,
                                   strength=cfg.strength,
                                   per_dollar=cfg.per_dollar)
    ei = jnp.where(use_w, ei * factor, ei)

    seen = jnp.zeros(n_cand, jnp.int32).at[idx].add(
        omask.astype(jnp.int32)) > 0
    ei = jnp.where(seen, -jnp.inf, ei)
    # float32-rounded selection grid, shared with the sequential
    # reference (see CherryPick.search): deterministic tie-breaks on
    # ulp-close candidates regardless of backend rounding
    ei = ei.astype(jnp.float32).astype(jnp.float64)

    mx = jnp.max(ei)
    stop_flat = mx <= 0.0
    stop_converged = ((mx / jnp.maximum(best, 1e-9) < cfg.ei_threshold)
                      & (count >= cfg.n_init + 2))
    advance = active & ~stop_flat & ~stop_converged
    pick = jnp.argmax(ei).astype(sel.dtype)
    sel = sel.at[count].set(jnp.where(advance, pick, sel[count]))
    count = count + advance.astype(count.dtype)
    return sel, count, advance


#: Number of stacked lane-table arrays a replay dispatch consumes.
N_TABLES = 9

#: Replicated grid tables of a seeded dispatch (incl. the noise key).
N_GRID_TABLES = 10

#: Per-lane arrays of a seeded dispatch (ids + limit).
N_LANE_ARGS = 4

# first call per program signature traces + compiles; concurrent cold
# calls from the pipelined per-device workers would each do so (jax
# does not dedupe concurrent first-call tracing) — serialize only the
# cold call, warm dispatches stay lock-free
_COMPILED_SIGNATURES: set = set()
_COMPILE_LOCK = threading.Lock()


@functools.lru_cache(maxsize=32)
def _replay_fn(cfg: ReplayConfig, lanes: int, slots: int, n_cand: int,
               dim: int, rounds: int,
               devices: Optional[Tuple] = None):
    """Jitted scan program for one (config, shape, mesh) signature.

    ``devices=None`` is the single-device program. A device tuple
    shards the lane axis: each device scans its own
    ``lanes/len(devices)`` lane bucket (``shard_map`` around the
    vmapped step), one dispatch total.
    """
    import jax

    step = functools.partial(_lane_step, cfg=cfg, slots=slots)
    step_v = jax.vmap(step)

    def run(carry, tables):
        REPLAY_TRACES.tick()

        def scan_step(c, _):
            sel, count, active = c
            sel, count, active = step_v(sel, count, active, *tables)
            return (sel, count, active), None

        (sel, count, _), _ = jax.lax.scan(scan_step, carry, None,
                                          length=rounds)
        return sel, count

    if devices is not None and len(devices) > 1:
        mesh = build_mesh("lanes", devices)
        lane = axis_specs("lanes", 1)[0]
        run = shard_map_1d(run, mesh,
                           in_specs=((lane,) * 3, (lane,) * N_TABLES),
                           out_specs=(lane, lane))
    return jax.jit(run, donate_argnums=(0,))


@functools.lru_cache(maxsize=32)
def _seeded_replay_fn(cfg: ReplayConfig, lanes: int, slots: int,
                      n_cand: int, base_dim: int, rounds: int,
                      n_workloads: int, n_conds: int,
                      noise_scale: float,
                      devices: Optional[Tuple] = None):
    """Jitted scan program that *generates* its lane tables in-program.

    Same scanned search as :func:`_replay_fn`, but the per-lane tables
    are expanded on device from the replicated grid + the lane's
    ``(workload_id, condition_id, variant_id, limit)`` ids: the
    contention noise is re-drawn from counter-based
    ``fold_in(noise_key, workload_id, config_uid)`` keys
    (``common.rng.lognormal_noise_row``), bit-identical to the host
    grid, and every derived table (objective, penalized cost,
    utilization metrics, feature blocks) follows the exact op order of
    ``tuning.scout._build_grid`` / ``scenarios.lane_tables`` so the
    f32-rounded argmax selections match the host-table program
    bit-for-bit. Nothing of size O(lanes x candidates) ever exists on
    host."""
    import jax
    import jax.numpy as jnp

    from repro.common.rng import lognormal_noise_row

    step = functools.partial(_lane_step, cfg=cfg, slots=slots)
    step_v = jax.vmap(step)

    def expand(noise_key, grid, wid, cid, vid, limit):
        (base, low_num, low_caps, x_base, price, count, uid,
         ns, fp) = grid
        # same op order as the host grid: one multiply for runtime,
        # left-to-right cost chain, capped utilization ratios
        noise = lognormal_noise_row(noise_key, wid, uid, noise_scale)
        rt = base[wid] * noise
        cost = rt / 3600.0 * price * count
        y = jnp.where(rt <= limit, cost, cost * 5.0)
        rtm = jnp.maximum(rt, 1e-6)
        denom = jnp.stack([rtm, jnp.ones_like(rtm), rtm, rtm], axis=-1)
        lows = jnp.minimum(low_caps, low_num[wid] / denom)
        zeros = jnp.zeros_like(lows)
        # variant feature blocks (scenarios.VARIANTS order): arrow
        # trains on observed lows (candidates imputed to zero),
        # arrow+perona uses the fingerprint lows on both sides
        low_train = jnp.where(vid == 2, lows,
                              jnp.where(vid == 3, fp[cid], zeros))
        low_cand = jnp.where(vid == 3, fp[cid], zeros)
        xt = jnp.concatenate([x_base, low_train], axis=1)
        xc = jnp.concatenate([x_base, low_cand], axis=1)
        return (xt, xc, y, rt, lows, ns[cid],
                jnp.broadcast_to(price, rt.shape), limit,
                (vid % 2) == 1)

    def run(carry, lane_args, grid_args):
        REPLAY_TRACES.tick()
        noise_key = grid_args[-1]
        grid = grid_args[:-1]
        wid, cid, vid, limit = lane_args
        tables = jax.vmap(
            lambda w, k, v, l: expand(noise_key, grid, w, k, v, l)
        )(wid, cid, vid, limit)

        def scan_step(c, _):
            sel, count, active = c
            sel, count, active = step_v(sel, count, active, *tables)
            return (sel, count, active), None

        (sel, count, _), _ = jax.lax.scan(scan_step, carry, None,
                                          length=rounds)
        return sel, count

    if devices is not None and len(devices) > 1:
        mesh = build_mesh("lanes", devices)
        lane = axis_specs("lanes", 1)[0]
        run = shard_map_1d(
            run, mesh,
            in_specs=((lane,) * 3, (lane,) * N_LANE_ARGS,
                      axis_specs("lanes", 0, N_GRID_TABLES)),
            out_specs=(lane, lane))
    return jax.jit(run, donate_argnums=(0,))


@dataclasses.dataclass
class PendingReplay:
    """A dispatched-but-not-fetched replay: ``sel``/``count`` may still
    be device arrays (jax async dispatch); :meth:`result` blocks."""

    n_lanes: int
    dispatches: int
    _sel: object
    _count: object

    def result(self) -> BatchReplayResult:
        sel = np.asarray(self._sel)[: self.n_lanes]
        count = np.asarray(self._count)[: self.n_lanes]
        return BatchReplayResult(chosen=sel, count=count,
                                 dispatches=self.dispatches)


def replay_async(tables: LaneTables,
                 cfg: Optional[ReplayConfig] = None, *,
                 devices: Optional[Sequence] = None,
                 device=None,
                 lanes_floor: int = 1) -> PendingReplay:
    """Dispatch every lane's full search as one (optionally sharded)
    scanned device call and return without blocking on the outputs.

    ``devices``: shard the lane axis over these devices (pow2 prefix;
    ``None`` keeps the single-device program). ``device``: place the
    single-device program's inputs on that device instead of the
    default — ``replay_pipelined`` round-robins lane blocks over the
    devices this way, so blocks execute concurrently as independent
    per-device dispatches. ``lanes_floor``: minimum padded lane-bucket
    size (a power of two) — fixed-size lane blocks let differing
    matrix sizes reuse one compiled program (see
    ``scenarios.replay_pipelined``).
    """
    import jax
    from jax.experimental import enable_x64

    cfg = ReplayConfig() if cfg is None else cfg
    if devices is not None and device is not None:
        raise ValueError("pass either devices= (shard_map) or "
                         "device= (placement), not both")
    n_lanes = len(tables)
    if n_lanes == 0:
        return PendingReplay(
            n_lanes=0, dispatches=0,
            _sel=np.zeros((0, cfg.max_runs), np.int32),
            _count=np.zeros(0, np.int32))
    devs = tuple(pow2_devices(devices)) if devices is not None else None
    if devs is not None and len(devs) <= 1:
        devs = None  # same un-sharded program: share its cache entry
    n_dev = len(devs) if devs else 1
    lanes = shard_size(n_lanes, n_dev, floor=lanes_floor)
    slots = shard_size(cfg.max_runs)
    n_cand, dim = tables.x_train.shape[1:]
    rounds = cfg.max_runs - cfg.n_init

    def pad(a):  # pad the lane axis by repeating lane 0 (masked out)
        return pad_lanes(a, lanes)

    sel0 = np.full((lanes, cfg.max_runs), -1, np.int32)
    sel0[:, : cfg.n_init] = pad(tables.init_idx)
    count0 = np.full(lanes, cfg.n_init, np.int32)
    active0 = np.ones(lanes, bool)

    from repro.serving.engine import silence_unusable_donation

    fn = _replay_fn(cfg, lanes, slots, n_cand, dim, rounds, devs)

    def to_dev(a):
        if device is not None:
            return jax.device_put(a, device)
        return jax.numpy.asarray(a)

    with enable_x64(), silence_unusable_donation():
        # copy=False: lane_tables already builds f64 columns, so the
        # dtype casts are no-ops for the common path
        jnp_tables = tuple(
            to_dev(pad(a)) for a in (
                tables.x_train.astype(np.float64, copy=False),
                tables.x_cand.astype(np.float64, copy=False),
                tables.y.astype(np.float64, copy=False),
                tables.runtime.astype(np.float64, copy=False),
                tables.util_low.astype(np.float64, copy=False),
                tables.norm_scores.astype(np.float64, copy=False),
                tables.price.astype(np.float64, copy=False),
                tables.limit.astype(np.float64, copy=False),
                tables.use_weighter.astype(bool, copy=False)))
        carry0 = (to_dev(sel0), to_dev(count0), to_dev(active0))
        # keyed on placement too: each device's first call compiles
        # its own executable and must take the serialized branch
        sig = (cfg, lanes, slots, n_cand, dim, rounds, devs, device)
        with REPLAY_TRACES.dispatch(
                "replay.dispatch",
                args={"lanes": n_lanes, "padded": lanes,
                      "rounds": rounds}):
            if sig in _COMPILED_SIGNATURES:
                sel, count = fn(carry0, jnp_tables)
            else:
                with _COMPILE_LOCK:
                    sel, count = fn(carry0, jnp_tables)
                    _COMPILED_SIGNATURES.add(sig)
    return PendingReplay(n_lanes=n_lanes, dispatches=1,
                         _sel=sel, _count=count)


def replay(tables: LaneTables,
           cfg: Optional[ReplayConfig] = None, *,
           devices: Optional[Sequence] = None,
           lanes_floor: int = 1) -> BatchReplayResult:
    """Run every lane's full search as one scanned device dispatch
    (sharded over ``devices`` when given) and fetch the result."""
    return replay_async(tables, cfg, devices=devices,
                        lanes_floor=lanes_floor).result()


def replay_seeded_async(spec: SeededLaneSpec,
                        cfg: Optional[ReplayConfig] = None, *,
                        devices: Optional[Sequence] = None,
                        device=None,
                        lanes_floor: int = 1) -> PendingReplay:
    """Dispatch a seeded replay: lane tables are generated *inside*
    the compiled program from ``spec``'s grid + per-lane ids, so the
    host ships O(W*C + K*C + L) arrays instead of the O(L*C*D)
    :class:`LaneTables`. Options mirror :func:`replay_async`.

    The condition axis is pow2-padded so matrices with different
    condition counts reuse one compiled program."""
    import jax
    from jax.experimental import enable_x64

    cfg = ReplayConfig() if cfg is None else cfg
    if devices is not None and device is not None:
        raise ValueError("pass either devices= (shard_map) or "
                         "device= (placement), not both")
    n_lanes = len(spec)
    if n_lanes == 0:
        return PendingReplay(
            n_lanes=0, dispatches=0,
            _sel=np.zeros((0, cfg.max_runs), np.int32),
            _count=np.zeros(0, np.int32))
    devs = tuple(pow2_devices(devices)) if devices is not None else None
    if devs is not None and len(devs) <= 1:
        devs = None  # same un-sharded program: share its cache entry
    n_dev = len(devs) if devs else 1
    lanes = shard_size(n_lanes, n_dev, floor=lanes_floor)
    slots = shard_size(cfg.max_runs)
    n_cand, base_dim = spec.x_base.shape
    rounds = cfg.max_runs - cfg.n_init
    n_workloads = spec.base_runtime.shape[0]
    # pad the condition axis to pow2: fleet sweeps with differing
    # condition counts then share one compiled program
    n_conds = shard_size(len(spec.norm_scores))
    ns, fp = spec.norm_scores, spec.fp_low
    if n_conds > len(ns):
        extra = n_conds - len(ns)
        ns = np.concatenate([ns, np.zeros((extra,) + ns.shape[1:])], 0)
        fp = np.concatenate([fp, np.zeros((extra,) + fp.shape[1:])], 0)

    def pad(a):  # pad the lane axis by repeating lane 0 (masked out)
        return pad_lanes(a, lanes)

    sel0 = np.full((lanes, cfg.max_runs), -1, np.int32)
    sel0[:, : cfg.n_init] = pad(spec.init_idx)
    count0 = np.full(lanes, cfg.n_init, np.int32)
    active0 = np.ones(lanes, bool)

    from repro.serving.engine import silence_unusable_donation

    fn = _seeded_replay_fn(cfg, lanes, slots, n_cand, base_dim, rounds,
                           n_workloads, n_conds,
                           float(spec.noise_scale), devs)

    def to_dev(a):
        if device is not None:
            return jax.device_put(a, device)
        return jax.numpy.asarray(a)

    with enable_x64(), silence_unusable_donation():
        lane_args = tuple(
            to_dev(pad(a)) for a in (
                spec.workload_id.astype(np.int32, copy=False),
                spec.condition_id.astype(np.int32, copy=False),
                spec.variant_id.astype(np.int32, copy=False),
                spec.limit.astype(np.float64, copy=False)))
        grid_args = tuple(
            to_dev(a) for a in (
                spec.base_runtime.astype(np.float64, copy=False),
                spec.low_num.astype(np.float64, copy=False),
                spec.low_caps.astype(np.float64, copy=False),
                spec.x_base.astype(np.float64, copy=False),
                spec.price.astype(np.float64, copy=False),
                spec.count.astype(np.float64, copy=False),
                spec.config_uid.astype(np.int32, copy=False),
                ns.astype(np.float64, copy=False),
                fp.astype(np.float64, copy=False),
                spec.noise_key))
        carry0 = (to_dev(sel0), to_dev(count0), to_dev(active0))
        sig = ("seeded", cfg, lanes, slots, n_cand, base_dim, rounds,
               n_workloads, n_conds, devs, device)
        with REPLAY_TRACES.dispatch(
                "replay.dispatch_seeded",
                args={"lanes": n_lanes, "padded": lanes,
                      "rounds": rounds}):
            if sig in _COMPILED_SIGNATURES:
                sel, count = fn(carry0, lane_args, grid_args)
            else:
                with _COMPILE_LOCK:
                    sel, count = fn(carry0, lane_args, grid_args)
                    _COMPILED_SIGNATURES.add(sig)
    return PendingReplay(n_lanes=n_lanes, dispatches=1,
                         _sel=sel, _count=count)


def replay_seeded(spec: SeededLaneSpec,
                  cfg: Optional[ReplayConfig] = None, *,
                  devices: Optional[Sequence] = None,
                  lanes_floor: int = 1) -> BatchReplayResult:
    """Run a seeded replay (tables generated in-program) and fetch."""
    return replay_seeded_async(spec, cfg, devices=devices,
                               lanes_floor=lanes_floor).result()


def traces_from_result(tables: LaneTables, result: BatchReplayResult,
                       configs) -> List["SearchTrace"]:
    """Materialize per-lane :class:`tuning.cherrypick.SearchTrace`
    objects (identical field-for-field to the sequential traces when
    the lane reproduced the sequential decisions).

    Vectorized across lanes (one gather + running-min per field): the
    per-lane python work is just the object construction, which keeps
    trace materialization cheap enough to overlap with device scans in
    the pipelined path."""
    n = len(tables)
    if n == 0:
        return []
    picks_all = result.chosen[:n]
    idx = np.maximum(picks_all, 0)
    costs_all = np.take_along_axis(tables.cost, idx, axis=1)
    runtimes_all = np.take_along_axis(tables.runtime, idx, axis=1)
    return _materialize_traces(picks_all, result.count[:n], costs_all,
                               runtimes_all, tables.limit[:n], configs)


def traces_from_spec(spec: SeededLaneSpec, result: BatchReplayResult,
                     configs) -> List["SearchTrace"]:
    """Materialize seeded-replay traces: per-lane costs/runtimes are
    gathered from the spec's host-side (W, C) grid tables via the
    lane's workload row — no per-lane tables needed."""
    n = len(spec)
    if n == 0:
        return []
    picks_all = result.chosen[:n]
    idx = np.maximum(picks_all, 0)
    wid = spec.workload_id[:n, None]
    costs_all = spec.cost[wid, idx]
    runtimes_all = spec.runtime[wid, idx]
    return _materialize_traces(picks_all, result.count[:n], costs_all,
                               runtimes_all, spec.limit[:n], configs)


def _materialize_traces(picks_all, counts, costs_all, runtimes_all,
                        limits, configs) -> List["SearchTrace"]:
    from repro.tuning.cherrypick import SearchTrace

    valid = runtimes_all <= limits[:, None]
    # running min over valid runs only; lanes with no valid run yet
    # stay at +inf (the sequential bookkeeping)
    best_all = np.minimum.accumulate(
        np.where(valid, costs_all, np.inf), axis=1)

    out = []
    for lane in range(len(counts)):
        k = int(counts[lane])
        out.append(SearchTrace(
            evaluated=[configs[int(i)] for i in picks_all[lane, :k]],
            costs=costs_all[lane, :k].tolist(),
            runtimes=runtimes_all[lane, :k].tolist(),
            best_valid_cost=best_all[lane, :k].tolist(),
            search_cost=float(np.sum(costs_all[lane, :k]))))
    return out
