"""Batched RBF Gaussian process as pure jnp ops (masked + padded).

Mirrors ``repro.tuning.gp.GP`` (the scipy reference the parity tests
pin against) op for op: per-dimension median-heuristic length scales,
y standardization, noise jitter, exact Cholesky inference. Observation
sets are carried padded to a fixed slot count (``common.bucketing.
next_pow2`` of the run budget) with a validity mask, so one compiled
program serves every lane at every BO round; callers ``jax.vmap`` these
functions over a leading lane axis.

Masking convention: padded observation rows contribute an identity
block to the kernel matrix (diagonal 1 + noise, zero cross terms) and a
zero target, so their Cholesky/solve contributions vanish exactly —
fit/predict on a masked set equals fit/predict on the dense subset.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import numpy as np

import jax.numpy as jnp
from jax.scipy.linalg import cho_solve, solve_triangular


class GPState(NamedTuple):
    """Posterior state of one fitted lane (a pytree; vmap-friendly)."""

    chol: jnp.ndarray  # (P, P) lower Cholesky of K + noise*I
    alpha: jnp.ndarray  # (P,) K^-1 y_standardized
    x: jnp.ndarray  # (P, D) padded observations
    mask: jnp.ndarray  # (P,) observation validity
    scales: jnp.ndarray  # (D,) median-heuristic length scales
    y_mean: jnp.ndarray  # ()
    y_std: jnp.ndarray  # ()


def median_scales(x: jnp.ndarray, mask: jnp.ndarray, m: jnp.ndarray,
                  rows: Optional[int] = None) -> jnp.ndarray:
    """Per-dimension median of |x_i - x_j| over all valid pairs
    (self-pairs included, as in the reference), floored at 1.0 for
    near-constant dimensions.

    The |x_i - x_j| matrix is symmetric with a zero diagonal, so the
    m^2-multiset's order statistics are recovered from the unique
    pairs alone: the m smallest entries are the diagonal zeros (every
    pair distance is >= 0), and the k-th smallest for k >= m is the
    (k - m)//2-th smallest pair value (each pair appears twice). Only
    the r(r-1)/2 upper-triangle pairs are built — pass ``rows`` when
    valid observations are known to live in a prefix of the padded
    slots (the replay engine's run budget). Invalid pairs sort to the
    back as +inf; the sort runs along the last (pair) axis, which XLA's
    CPU backend handles markedly faster than leading-axis sorts."""
    r = x.shape[0] if rows is None else rows
    iu, ju = np.triu_indices(r, 1)
    u = jnp.abs(x[iu] - x[ju])  # (T, D)
    pair_ok = mask[iu] & mask[ju]
    u = jnp.where(pair_ok[:, None], u, jnp.inf).T  # (D, T)
    u = jnp.sort(u, axis=-1)

    def stat(k):  # k-th smallest of the m*m masked-median multiset
        return jnp.where(k < m, 0.0,
                         u[:, jnp.maximum((k - m) // 2, 0)])

    med = 0.5 * (stat((m * m - 1) // 2) + stat((m * m) // 2))
    return jnp.where(med > 1e-9, med, 1.0)


def _kernel(a: jnp.ndarray, b: jnp.ndarray,
            scales: jnp.ndarray) -> jnp.ndarray:
    """RBF kernel via the matmul expansion |a'|^2 + |b'|^2 - 2 a'.b'
    of the scaled squared distance (BLAS-friendly; clipped at 0 so
    self-distances stay exactly zero under rounding)."""
    a = a / scales
    b = b / scales
    na = jnp.sum(a * a, axis=-1)
    nb = jnp.sum(b * b, axis=-1)
    sq = jnp.maximum(na[:, None] + nb[None, :] - 2.0 * (a @ b.T), 0.0)
    return jnp.exp(-0.5 * sq)


def gp_fit(x: jnp.ndarray, y: jnp.ndarray, mask: jnp.ndarray,
           noise: float = 1e-3,
           median_rows: Optional[int] = None) -> GPState:
    """Fit one lane's GP on its masked observation set.

    ``x`` (P, D), ``y`` (P,), ``mask`` (P,) — padded rows are ignored
    exactly (see module docstring). Constant-y sets fall back to unit
    std (the reference's degenerate-input guard). ``median_rows``
    bounds the slots the length-scale median looks at (see
    :func:`median_scales`)."""
    m = jnp.sum(mask)
    y_mean = jnp.sum(jnp.where(mask, y, 0.0)) / m
    var = jnp.sum(jnp.where(mask, (y - y_mean) ** 2, 0.0)) / m
    y_std = jnp.sqrt(var)
    y_std = jnp.where(
        y_std <= 1e-12 * jnp.maximum(1.0, jnp.abs(y_mean)), 1.0, y_std)
    yn = jnp.where(mask, (y - y_mean) / y_std, 0.0)
    scales = median_scales(x, mask, m, rows=median_rows)
    pmask = mask[:, None] & mask[None, :]
    k = jnp.where(pmask, _kernel(x, x, scales), 0.0)
    k = k + jnp.diag(jnp.where(mask, noise, 1.0 + noise))
    chol = jnp.linalg.cholesky(k)
    alpha = cho_solve((chol, True), yn[:, None])[:, 0]
    return GPState(chol=chol, alpha=alpha, x=x, mask=mask,
                   scales=scales, y_mean=y_mean, y_std=y_std)


def gp_predict(state: GPState, xs: jnp.ndarray):
    """Posterior (mu, sigma) at candidate points ``xs`` (C, D).

    The predictive variance 1 - k* K^-1 k*^T is computed as
    1 - ||L^-1 k*^T||^2, with L^-1 materialized once per fit state (a
    P x P triangular solve) so the per-candidate work is one matmul
    (equal to the reference's cho_solve form up to rounding; the
    selection grid in the replay engine absorbs the ulp difference)."""
    ks = _kernel(xs, state.x, state.scales) * state.mask[None, :]
    mu = ks @ state.alpha
    p = state.chol.shape[0]
    l_inv = solve_triangular(state.chol, jnp.eye(p, dtype=ks.dtype),
                             lower=True)
    w = l_inv @ ks.T
    var = jnp.clip(1.0 - jnp.sum(w * w, axis=0), 1e-9, None)
    return (mu * state.y_std + state.y_mean,
            jnp.sqrt(var) * state.y_std)


def gp_fit_predict(x, y, mask, xs, noise: float = 1e-3):
    """Convenience fused fit+predict (one lane); vmap for batches."""
    return gp_predict(gp_fit(x, y, mask, noise), xs)
