"""Batched fingerprint scoring engine.

``FingerprintEngine`` wraps Perona's inference path — feature
normalization/orientation/imputation, edge-attribute assembly, the GNN
forward pass and the sigmoid anomaly head — in ONE ``jax.jit``-compiled
function over shape-bucketed inputs. Frames are padded to the next
bucket size (powers of two), so repeated scoring rounds of similar size
reuse one compiled executable instead of re-tracing per round; the
``trace_count`` property exposes how many tracings actually happened
(asserted by the regression tests).

Only the statistics-free graph topology (chain membership, predecessor
indices, raw gauge gathering) stays in numpy — everything numeric runs
in the compiled call.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.common.bucketing import next_pow2
from repro.core.graph_data import P_PREDECESSORS, graph_structure
from repro.core.model import PeronaModel
from repro.core.preprocess import Preprocessor
from repro.fingerprint.frame import FrameOrRecords, as_frame

MIN_BUCKET = 64


def bucket_size(n: int, min_bucket: int = MIN_BUCKET) -> int:
    """Smallest power-of-two bucket >= n (>= min_bucket)."""
    return next_pow2(n, min_bucket)


@dataclasses.dataclass
class ScoreResult:
    anomaly_prob: np.ndarray  # (N,) sigmoid of the anomaly head
    type_logits: np.ndarray  # (N, T) benchmark-type probe
    codes: np.ndarray  # (N, K) fingerprint codes
    n_padded: int  # bucket the batch was padded to


class FingerprintEngine:
    """preprocess -> forward -> sigmoid in a single jit'd call."""

    def __init__(self, model: PeronaModel, params,
                 preproc: Preprocessor, min_bucket: int = MIN_BUCKET):
        import jax
        import jax.numpy as jnp

        self.model = model
        self.params = params
        self.preproc = preproc
        self.min_bucket = min_bucket
        self._trace_count = 0

        lo = jnp.asarray(preproc.lo, jnp.float32)
        hi = jnp.asarray(preproc.hi, jnp.float32)
        maximize = jnp.asarray(preproc.maximize)
        fill = jnp.asarray(preproc.fill_mean, jnp.float32)
        elo = jnp.asarray(preproc.edge_lo, jnp.float32)
        ehi = jnp.asarray(preproc.edge_hi, jnp.float32)
        n_types = len(preproc.benchmark_types)

        def _score(params, raw, present, type_ids, nbr, nbr_mask,
                   edge_raw, dt, t_src):
            self._trace_count += 1  # runs at trace time only
            # §III-B normalization / orientation / imputation / one-hot
            norm = jnp.clip((raw - lo) / (hi - lo), 0.0, 1.0)
            norm = jnp.where(maximize, norm, 1.0 - norm)
            norm = jnp.where(present, norm, fill)
            onehot = jax.nn.one_hot(type_ids, n_types, dtype=jnp.float32)
            x = jnp.concatenate([norm, onehot], axis=1)
            # edge attributes: scaled source-run gauges + time encodings
            efeat = jnp.clip((edge_raw - elo) / (ehi - elo), 0.0, 1.0)
            hod = (t_src / 3600.0) % 24.0
            ang = 2 * jnp.pi * hod / 24
            enc = jnp.stack([
                jnp.log1p(dt) / 12.0,
                jnp.minimum(dt / 3600.0, 1.0),
                0.5 + 0.5 * jnp.sin(ang),
                0.5 + 0.5 * jnp.cos(ang),
            ], axis=-1)
            edge = jnp.concatenate([efeat, enc], axis=-1)
            edge = jnp.where(nbr_mask[..., None], edge, 0.0)
            batch = {"x": x, "nbr": nbr, "nbr_mask": nbr_mask,
                     "edge": edge}
            out = self.model.forward(params, batch, train=False)
            return {
                "anomaly_prob": jax.nn.sigmoid(out["anom_logit"]),
                "type_logits": out["type_logits"],
                "codes": out["codes"],
            }

        self._score = jax.jit(_score)

    @property
    def trace_count(self) -> int:
        """Number of jit tracings so far (1 per distinct bucket)."""
        return self._trace_count

    def score(self, data: FrameOrRecords) -> ScoreResult:
        """Score one batch of benchmark executions (frame or records)."""
        import jax.numpy as jnp

        frame = as_frame(data)
        n = len(frame)
        gs = graph_structure(frame)
        raw, present = self.preproc.raw_features(frame)
        edge_raw = self.preproc.raw_edges(frame)
        type_ids = self.preproc.type_ids(frame)

        b = bucket_size(n, self.min_bucket)
        pad = b - n
        p = P_PREDECESSORS

        def padf(arr, fillv=0.0):
            w = [(0, pad)] + [(0, 0)] * (arr.ndim - 1)
            return np.pad(arr, w, constant_values=fillv)

        nbr = padf(gs.nbr, -1)
        # gather source-run gauges after padding (index -1 -> row 0,
        # masked out inside the jit like the model's neighbor gather)
        src = np.maximum(nbr, 0)
        out = self._score(
            self.params,
            jnp.asarray(padf(raw), jnp.float32),
            jnp.asarray(padf(present)),
            jnp.asarray(padf(type_ids)),
            jnp.asarray(nbr),
            jnp.asarray(nbr >= 0),
            jnp.asarray(padf(edge_raw), jnp.float32)[src],
            jnp.asarray(padf(gs.dt), jnp.float32),
            jnp.asarray(padf(gs.t_src), jnp.float32),
        )
        return ScoreResult(
            anomaly_prob=np.asarray(out["anomaly_prob"])[:n],
            type_logits=np.asarray(out["type_logits"])[:n],
            codes=np.asarray(out["codes"])[:n],
            n_padded=b)
