"""Batched fingerprint scoring engine.

``FingerprintEngine`` wraps Perona's inference path — feature
normalization/orientation/imputation (paper §III-B), edge-attribute
assembly, the GNN forward pass (§III-C) and the sigmoid anomaly head —
in ONE ``jax.jit``-compiled function over shape-bucketed inputs. Frames
are padded to the next bucket size (powers of two), so repeated scoring
rounds of similar size reuse one compiled executable instead of
re-tracing per round; the ``trace_count`` property exposes how many
tracings actually happened (asserted by the regression tests).

The padded input buffers are *donated* to the compiled call
(``donate_argnums``): they are freshly materialized per ``score()``
call and never reused, so XLA may overwrite them in place instead of
allocating output buffers alongside them.

Only the statistics-free graph topology (chain membership, predecessor
indices, raw gauge gathering) stays in numpy — everything numeric runs
in the compiled call. The pure scoring function is exposed as
:func:`make_score_fn` and the numpy input assembly as
:func:`prepare_inputs` so the fleet layer (``repro.fleet.shard``) can
vmap/shard the very same computation across devices.
"""

from __future__ import annotations

import contextlib
import dataclasses
import warnings
from typing import Callable, Dict, Optional

import numpy as np


@contextlib.contextmanager
def silence_unusable_donation():
    """The scoring outputs (N,), (N,T), (N,K) are all smaller than the
    donated padded inputs, so XLA can never alias them input-to-output
    and notes the donation as unusable on every compile. That is
    expected here (donation still releases the inputs eagerly) —
    suppress the note around the compiling call only, so other
    donation sites in the process keep their diagnostics."""
    with warnings.catch_warnings():
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable",
            category=UserWarning)
        yield

from repro.common.bucketing import next_pow2
from repro.core.graph_data import graph_structure
from repro.core.model import PeronaModel
from repro.core.preprocess import Preprocessor
from repro.fingerprint.frame import BenchmarkFrame, FrameOrRecords, as_frame
from repro.obs.jaxstat import JitSite, instance_site

MIN_BUCKET = 64

# positional argument order of the pure scoring function (after params)
ARG_NAMES = ("raw", "present", "type_ids", "nbr", "nbr_mask",
             "edge_src", "dt", "t_src")


def bucket_size(n: int, min_bucket: int = MIN_BUCKET) -> int:
    """Smallest power-of-two bucket >= n (>= min_bucket)."""
    return next_pow2(n, min_bucket)


@dataclasses.dataclass
class ScoreResult:
    anomaly_prob: np.ndarray  # (N,) sigmoid of the anomaly head
    type_logits: np.ndarray  # (N, T) benchmark-type probe
    codes: np.ndarray  # (N, K) fingerprint codes
    n_padded: int  # bucket the batch was padded to


def make_score_fn(model: PeronaModel, preproc: Preprocessor,
                  on_trace: Optional[Callable[[], None]] = None):
    """Pure (params, *ARG_NAMES arrays) -> dict scoring function.

    Implements §III-B normalization / orientation / imputation / one-hot
    enrichment and the §III-C forward + sigmoid anomaly head for one
    padded batch. Preprocessor statistics are closed over as constants;
    ``on_trace`` (if given) is invoked at trace time only — the
    trace-count hook shared by the engine and the sharded fleet scorer.
    """
    import jax
    import jax.numpy as jnp

    lo = jnp.asarray(preproc.lo, jnp.float32)
    hi = jnp.asarray(preproc.hi, jnp.float32)
    maximize = jnp.asarray(preproc.maximize)
    fill = jnp.asarray(preproc.fill_mean, jnp.float32)
    elo = jnp.asarray(preproc.edge_lo, jnp.float32)
    ehi = jnp.asarray(preproc.edge_hi, jnp.float32)
    n_types = len(preproc.benchmark_types)

    def _score(params, raw, present, type_ids, nbr, nbr_mask,
               edge_src, dt, t_src):
        if on_trace is not None:
            on_trace()  # runs at trace time only
        # §III-B normalization / orientation / imputation / one-hot
        norm = jnp.clip((raw - lo) / (hi - lo), 0.0, 1.0)
        norm = jnp.where(maximize, norm, 1.0 - norm)
        norm = jnp.where(present, norm, fill)
        onehot = jax.nn.one_hot(type_ids, n_types, dtype=jnp.float32)
        x = jnp.concatenate([norm, onehot], axis=1)
        # edge attributes: scaled source-run gauges + time encodings
        efeat = jnp.clip((edge_src - elo) / (ehi - elo), 0.0, 1.0)
        hod = (t_src / 3600.0) % 24.0
        ang = 2 * jnp.pi * hod / 24
        enc = jnp.stack([
            jnp.log1p(dt) / 12.0,
            jnp.minimum(dt / 3600.0, 1.0),
            0.5 + 0.5 * jnp.sin(ang),
            0.5 + 0.5 * jnp.cos(ang),
        ], axis=-1)
        edge = jnp.concatenate([efeat, enc], axis=-1)
        edge = jnp.where(nbr_mask[..., None], edge, 0.0)
        batch = {"x": x, "nbr": nbr, "nbr_mask": nbr_mask,
                 "edge": edge}
        out = model.forward(params, batch, train=False)
        return {
            "anomaly_prob": jax.nn.sigmoid(out["anom_logit"]),
            "type_logits": out["type_logits"],
            "codes": out["codes"],
        }

    return _score


def prepare_features(preproc: Preprocessor, frame: BenchmarkFrame
                     ) -> Dict[str, np.ndarray]:
    """Per-row feature columns of a frame, ready for the scoring call
    (un-padded; row-aligned with the frame). This is the expensive,
    Python-dict-driven part of input assembly — the fleet store caches
    its output per row so request assembly is a pure array gather."""
    raw, present = preproc.raw_features(frame)
    return {
        "raw": raw.astype(np.float32),
        "present": present,
        "type_ids": preproc.type_ids(frame),
        "edge_raw": preproc.raw_edges(frame).astype(np.float32),
    }


def assemble_inputs(features: Dict[str, np.ndarray], nbr: np.ndarray,
                    dt: np.ndarray, t_src: np.ndarray, bucket: int
                    ) -> Dict[str, np.ndarray]:
    """Pad per-row features + graph topology to ``bucket`` rows and
    gather the per-edge source-run gauges: the numpy dict of ARG_NAMES
    arrays consumed by the compiled scoring call."""
    n = nbr.shape[0]

    def padf(arr, dtype=None, fillv=0.0):
        # preallocate + slice-assign (np.pad's python path is slow
        # enough to show up at fleet request rates)
        out = np.full((bucket,) + arr.shape[1:],
                      fillv, dtype or arr.dtype)
        out[:n] = arr
        return out

    nbr_p = padf(nbr, fillv=-1)
    # gather source-run gauges after padding (index -1 -> row 0,
    # masked out inside the jit like the model's neighbor gather)
    src = np.maximum(nbr_p, 0)
    return {
        "raw": padf(features["raw"], np.float32),
        "present": padf(features["present"]),
        "type_ids": padf(features["type_ids"]),
        "nbr": nbr_p,
        "nbr_mask": nbr_p >= 0,
        "edge_src": padf(features["edge_raw"], np.float32)[src],
        "dt": padf(dt, np.float32),
        "t_src": padf(t_src, np.float32),
    }


def prepare_inputs(preproc: Preprocessor, frame: BenchmarkFrame,
                   bucket: int) -> Dict[str, np.ndarray]:
    """Full numpy input assembly for one frame (features + topology)."""
    gs = graph_structure(frame)
    return assemble_inputs(prepare_features(preproc, frame),
                           gs.nbr, gs.dt, gs.t_src, bucket)


class FingerprintEngine:
    """preprocess -> forward -> sigmoid in a single jit'd call."""

    def __init__(self, model: PeronaModel, params,
                 preproc: Preprocessor, min_bucket: int = MIN_BUCKET):
        import jax

        self.model = model
        self.params = params
        self.preproc = preproc
        self.min_bucket = min_bucket
        # per-instance jit accounting on the obs registry (tracings,
        # dispatches, compile/run wall split); trace_count stays a
        # thin read of the same counter
        self.jit = JitSite(instance_site("serving.engine"))

        # donate the padded input buffers (everything but params): they
        # are rebuilt from numpy on every call and never reused
        self.donate_argnums = tuple(range(1, 1 + len(ARG_NAMES)))
        self._score = jax.jit(
            make_score_fn(model, preproc, on_trace=self.jit.tick),
            donate_argnums=self.donate_argnums)

    @property
    def trace_count(self) -> int:
        """Number of jit tracings so far (1 per distinct bucket)."""
        return self.jit.count

    def prepare(self, frame: BenchmarkFrame):
        """Device-ready (donatable) jnp inputs in ARG_NAMES order."""
        import jax.numpy as jnp

        b = bucket_size(len(frame), self.min_bucket)
        inputs = prepare_inputs(self.preproc, frame, b)
        return tuple(jnp.asarray(inputs[k]) for k in ARG_NAMES), b

    def score(self, data: FrameOrRecords) -> ScoreResult:
        """Score one batch of benchmark executions (frame or records)."""
        frame = as_frame(data)
        n = len(frame)
        args, b = self.prepare(frame)
        with silence_unusable_donation(), \
                self.jit.dispatch("engine.score",
                                  args={"rows": n, "bucket": b}):
            out = self._score(self.params, *args)
        return ScoreResult(
            anomaly_prob=np.asarray(out["anomaly_prob"])[:n],
            type_logits=np.asarray(out["type_logits"])[:n],
            codes=np.asarray(out["codes"])[:n],
            n_padded=b)
