"""Serving layer: batched, compile-cached fingerprint scoring."""

from repro.serving.engine import FingerprintEngine, ScoreResult

__all__ = ["FingerprintEngine", "ScoreResult"]
