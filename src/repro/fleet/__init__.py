"""Fleet-scale fingerprint service (paper §III-C at fleet traffic).

- ``store``   — append-only columnar :class:`FingerprintStore` with
  per-(node x benchmark type) time-windowed views and atomic .npz
  durability;
- ``shard``   — :class:`ShardedScorer`, shard_map'd scoring across a
  1-D device mesh reusing the engine's pure score function;
- ``service`` — :class:`FleetScoringService`, micro-batched request
  queue dispatching one sharded call per shape bucket, with NaN/Inf
  and unknown-type quarantine at intake;
- ``drift``   — store-backed per-node / per-aspect EWMA degradation
  analytics (batch ``drift_report`` and incremental ``RollingDrift``)
  consumed by ``runtime.watchdog.PeronaWatchdog``;
- ``ingest``  — :class:`IngestionDaemon`, the long-lived streaming
  front-end: bounded ring staging, deadline/pow2 flush triggers, an
  explicit backpressure ladder and crash-safe shutdown;
- ``faults``  — deterministic seeded fault injection over telemetry
  streams (dropout, stalls, delays, duplicates, reordering, NaN/Inf
  corruption, burst storms) for robustness tests and benchmarks;
- ``modelplane`` — :class:`ModelRegistry` (versioned, crash-safe
  parameter checkpoints) and :class:`ModelPlane` (canary-gated
  zero-downtime promote/rollback on the live service, with the
  drift-triggered retrain loop).
"""

from repro.fleet.drift import (EwmaMean, NodeDrift, RollingDrift,
                               degradation_factors, degrading_nodes,
                               drift_report, ewma_series)
from repro.fleet.faults import (FaultLog, FaultPlan, TelemetryEvent,
                                corrupt_frame, fleet_telemetry,
                                inject_faults)
from repro.fleet.ingest import IngestionDaemon, load_staging, save_staging
from repro.fleet.service import FleetResult, FleetScoringService
from repro.fleet.shard import ShardedScorer
from repro.fleet.store import FingerprintStore, atomic_savez
# last: modelplane leans on repro.obs.regress, which imports
# repro.fleet.drift — already initialized by this point
from repro.fleet.modelplane import ModelPlane, ModelRegistry

__all__ = [
    "FingerprintStore", "ShardedScorer", "FleetScoringService",
    "EwmaMean", "FleetResult", "NodeDrift", "RollingDrift", "drift_report",
    "degradation_factors", "degrading_nodes", "ewma_series",
    "IngestionDaemon", "save_staging", "load_staging",
    "TelemetryEvent", "FaultPlan", "FaultLog", "fleet_telemetry",
    "inject_faults", "corrupt_frame", "atomic_savez",
    "ModelPlane", "ModelRegistry",
]
