"""Fleet-scale fingerprint service (paper §III-C at fleet traffic).

- ``store``   — append-only columnar :class:`FingerprintStore` with
  per-(node x benchmark type) time-windowed views and .npz durability;
- ``shard``   — :class:`ShardedScorer`, shard_map'd scoring across a
  1-D device mesh reusing the engine's pure score function;
- ``service`` — :class:`FleetScoringService`, micro-batched request
  queue dispatching one sharded call per shape bucket;
- ``drift``   — store-backed per-node / per-aspect EWMA degradation
  analytics consumed by ``runtime.watchdog.PeronaWatchdog``.
"""

from repro.fleet.drift import (NodeDrift, degradation_factors,
                               degrading_nodes, drift_report,
                               ewma_series)
from repro.fleet.service import FleetResult, FleetScoringService
from repro.fleet.shard import ShardedScorer
from repro.fleet.store import FingerprintStore

__all__ = [
    "FingerprintStore", "ShardedScorer", "FleetScoringService",
    "FleetResult", "NodeDrift", "drift_report", "degradation_factors",
    "degrading_nodes", "ewma_series",
]
