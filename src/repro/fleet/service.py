"""Fleet scoring service: micro-batched, store-backed, sharded.

``FleetScoringService`` is the request front-end of the fleet
subsystem: per-node scoring requests (``submit``) are coalesced into
shape-bucketed micro-batches (power-of-two row buckets via
``common.bucketing.next_pow2``, the ``FingerprintEngine`` policy) and
dispatched as ONE sharded call per (bucket, flush) through
:class:`repro.fleet.shard.ShardedScorer` — instead of one device
dispatch per request. Context assembly ("previous executions of this
node", paper §III-C) is a pure array gather from the
:class:`repro.fleet.store.FingerprintStore` feature cache; scored rows
are appended back to the store, which makes the history durable
(``store.save``) and feeds the drift analytics
(``repro.fleet.drift``).

Flush flow:

1. all pending request rows are preprocessed once (one vectorized
   §III-B pass) and appended to the store with their feature columns;
2. per node, the scoring context (the newest ``context_per_chain``
   rows of each of the node's chains *as of before the round*, plus
   every new row) is gathered from the store and padded to its row
   bucket;
3. requests sharing a bucket are stacked (request axis padded to a
   power of two divisible by the device mesh) and scored in one
   sharded dispatch;
4. new-row scores are attached to the store and returned per node.

The default context depth exploits the model's bounded receptive
field: the §III-C graph chains executions to their P=3 immediate
predecessors, the TransformerConv aggregates 1 hop and the TAGConv
``tag_hops`` hops, so a new execution's score depends on at most
``P * max(1, tag_hops)`` preceding chain rows. With streaming rounds
(timestamps after the stored history) the minimal context therefore
produces *bit-identical* scores to rescoring the full history
(asserted in ``tests/test_fleet.py``) at a fraction of the compute.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.common.bucketing import next_pow2
from repro.common.mesh import stack_padded
from repro.common.rng import STREAM_RETRY, folded_generator
from repro.core.graph_data import chain_structure
from repro.core.model import PeronaModel
from repro.core.preprocess import Preprocessor
from repro.fingerprint.frame import FrameOrRecords, as_frame, concat_frames
from repro.fleet.shard import ShardedScorer
from repro.fleet.store import FEATURE_KEYS, FingerprintStore
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.serving.engine import (MIN_BUCKET, assemble_inputs,
                                  prepare_features)


@dataclasses.dataclass
class FleetResult:
    """Scores for one node's new executions (chronological order)."""

    node: str
    anomaly_prob: np.ndarray  # (n_new,)
    type_logits: np.ndarray  # (n_new, T)
    codes: np.ndarray  # (n_new, K)
    row_ids: np.ndarray  # (n_new,) global store row ids
    context_row_ids: np.ndarray  # history rows scored alongside
    bucket: int  # row bucket the request padded to

    @property
    def n_context(self) -> int:
        return len(self.context_row_ids)


class FleetScoringService:
    """Accepts per-node requests, flushes shape-bucketed micro-batches
    through one sharded dispatch per bucket, persists to the store."""

    def __init__(self, model: PeronaModel, params,
                 preproc: Preprocessor, *,
                 store: Optional[FingerprintStore] = None,
                 context_per_chain: Optional[int] = None,
                 min_bucket: int = MIN_BUCKET,
                 sharded: bool = True,
                 devices: Optional[Sequence] = None,
                 on_invalid: str = "quarantine",
                 dispatch_retries: int = 2,
                 retry_backoff_s: float = 0.05,
                 retry_seed: int = 0):
        import jax

        from repro.core.graph_data import P_PREDECESSORS

        self.model = model
        self.params = params
        self.preproc = preproc
        self.store = store if store is not None else FingerprintStore()
        # None -> the model's exact receptive field (see module doc)
        self.context_per_chain = (
            context_per_chain if context_per_chain is not None
            else P_PREDECESSORS * max(1, model.cfg.tag_hops))
        self.min_bucket = min_bucket
        if on_invalid not in ("quarantine", "raise", "off"):
            raise ValueError(f"unknown on_invalid policy {on_invalid!r}")
        self.on_invalid = on_invalid
        if devices is None:
            devices = jax.devices() if sharded else jax.devices()[:1]
        self.scorer = ShardedScorer(model, preproc, devices=devices)
        self.dispatch_retries = dispatch_retries
        self.retry_backoff_s = retry_backoff_s
        self.retry_seed = retry_seed
        # re-entrant: model-plane swaps land at flush boundaries by
        # taking this lock, and a flush hook promoting from inside a
        # flush re-enters it on the same thread
        self._lock = threading.RLock()
        self._pending: List[object] = []  # frames queued for flush
        self._quarantine: List[object] = []  # rejected rows, as frames
        # stacked-shape signatures seen so far, so warm() can compile
        # + device-place a candidate before a hot swap
        self._stack_sigs: Dict[Tuple[int, int],
                               Dict[str, Tuple[tuple, object]]] = {}
        self._requests_served = 0
        self._rows_scored = 0
        self._flushes = 0
        self._dispatches = 0
        self._shadow_dispatches = 0
        self._scorer_retries = 0
        self._swaps = 0
        self._warm_dispatches = 0
        self._quarantined_nonfinite = 0
        self._quarantined_unknown_type = 0
        self._wall_s = 0.0
        # registry mirrors (program logic keeps the plain ints above —
        # they must stay correct under obs.disable())
        reg = obs_metrics.registry()
        site = self.scorer.jit.site
        self._m_quarantined = {
            "nonfinite": reg.counter("fleet.quarantined",
                                     kind="nonfinite", site=site),
            "unknown_type": reg.counter("fleet.quarantined",
                                        kind="unknown_type", site=site),
        }
        self._m_flushes = reg.counter("fleet.flushes", site=site)
        self._m_rows = reg.counter("fleet.rows_scored", site=site)
        self._m_retries = reg.counter("fleet.scorer_retries", site=site)
        self._m_swaps = reg.counter("fleet.param_swaps", site=site)
        # per-flush wall-clock histogram: the model plane's canary gate
        # reads its quantiles as the incumbent latency baseline
        self._h_flush = reg.histogram("fleet.flush_wall_s", site=site)

    # --------------------------------------------------------- validation
    def validate_frame(self, frame) -> Dict[str, np.ndarray]:
        """Row masks of telemetry that must never reach the scorer:
        ``nonfinite`` (NaN/Inf in a present metric/gauge cell or the
        timestamp — they would poison the normalized feature cache and
        every padded batch they share a dispatch with) and
        ``unknown_type`` (benchmark types the preprocessor was never
        fitted on — unscorable, and ``type_ids`` would raise)."""
        known = set(self.preproc.benchmark_types or ())
        bad_type_codes = [c for c, name
                          in enumerate(frame.benchmark_types)
                          if name not in known]
        unknown = np.isin(frame.type_code, bad_type_codes)
        nonfinite = (
            ~np.isfinite(np.where(frame.metrics_present,
                                  frame.metrics, 0.0)).all(axis=1)
            | ~np.isfinite(np.where(frame.node_metrics_present,
                                    frame.node_metrics, 0.0)).all(axis=1)
            | ~np.isfinite(frame.t))
        return {"nonfinite": nonfinite, "unknown_type": unknown}

    def _admit(self, frame):
        """Apply the ``on_invalid`` policy; returns the clean subset
        (or the frame untouched when validation is off)."""
        if self.on_invalid == "off":
            return frame
        masks = self.validate_frame(frame)
        bad = masks["nonfinite"] | masks["unknown_type"]
        if not bad.any():
            return frame
        n_nf = int(masks["nonfinite"].sum())
        n_ut = int((masks["unknown_type"] & ~masks["nonfinite"]).sum())
        if self.on_invalid == "raise":
            raise ValueError(
                f"rejected {int(bad.sum())} telemetry rows: {n_nf} "
                f"with NaN/Inf metric values, {n_ut} with benchmark "
                "types the preprocessor was not fitted on")
        self._quarantined_nonfinite += n_nf
        self._quarantined_unknown_type += n_ut
        self._m_quarantined["nonfinite"].inc(n_nf)
        self._m_quarantined["unknown_type"].inc(n_ut)
        self._quarantine.append(frame.select(np.nonzero(bad)[0]))
        return frame.select(np.nonzero(~bad)[0])

    @property
    def quarantine(self) -> List[object]:
        """Quarantined (rejected) rows, as frames, in intake order."""
        return list(self._quarantine)

    # ------------------------------------------------------------- intake
    def submit(self, data: FrameOrRecords) -> None:
        """Queue new executions for the next flush. Rows are grouped
        into per-node requests by their machine column at flush time,
        so a frame may carry one node's round or a whole fleet round.
        Rows with NaN/Inf metrics or unfitted benchmark types are
        quarantined (or rejected, per ``on_invalid``) — they never
        reach the store or the jitted scorer."""
        frame = self._admit(as_frame(data))
        if len(frame):
            with self._lock:
                self._pending.append(frame)

    def seed_history(self, data: FrameOrRecords) -> None:
        """Append unscored context rows (e.g. a prior acquisition) with
        their cached feature columns (validated like submissions —
        poisoned context would contaminate every later request)."""
        frame = self._admit(as_frame(data))
        if len(frame):
            self.store.append(
                frame, features=prepare_features(self.preproc, frame))

    def score_round(self, data: FrameOrRecords
                    ) -> Dict[str, "FleetResult"]:
        """Convenience: queue a whole (multi-node) re-fingerprinting
        round and flush once; one request per node in the round."""
        self.submit(data)
        return self.flush()

    # -------------------------------------------------------------- flush
    def flush(self) -> Dict[str, FleetResult]:
        """Score every pending request in shape-bucketed micro-batches
        (one sharded dispatch per distinct row bucket). Holds the
        service lock end to end, so parameter swaps
        (:meth:`swap_params`) only ever land at flush boundaries."""
        with self._lock:
            if not self._pending:
                return {}
            t0 = time.perf_counter()
            span_args: Dict[str, object] = {}
            with obs_trace.span("fleet.flush", args=span_args):
                results = self._flush_locked(t0, span_args)
            return results

    def _flush_locked(self, t0: float,
                      span_args: Dict[str, object]
                      ) -> Dict[str, FleetResult]:
        pending, self._pending = self._pending, []

        # one vectorized preprocessing pass over all new rows, appended
        # to the store before assembly so context gathers see them
        new_all = (concat_frames(pending) if len(pending) > 1
                   else pending[0])
        first_id = self.store.append(
            new_all, features=prepare_features(self.preproc, new_all))

        requests = self._assemble_requests(first_id)
        results, n_buckets = self._dispatch_requests(
            self.params, requests, attach=True)
        self._requests_served += len(requests)
        self._flushes += 1
        dt = time.perf_counter() - t0
        self._wall_s += dt
        self._m_flushes.inc()
        self._h_flush.observe(dt)
        self._m_rows.inc(sum(len(r.row_ids) for r in results.values()))
        span_args.update(requests=len(requests), buckets=n_buckets,
                         rows=int(len(new_all)))
        return results

    def _assemble_requests(self, first_id: int) -> List[dict]:
        """Per-node context gather + input assembly (pure numpy) for
        every store row with id >= ``first_id`` ("the round")."""
        frame = self.store.frame
        feats = self.store.features
        n_types = max(len(frame.benchmark_types), 1)
        key_all = (frame.machine_code.astype(np.int64) * n_types
                   + frame.type_code)
        requests = []
        row_id = self.store.row_id
        new_codes = frame.machine_code[row_id >= first_id]
        for m_code in np.unique(new_codes):
            node = frame.machines[m_code]
            # context rule shared with the watchdog + benchmarks:
            # before-round window per chain + every new row of the node
            idx, is_new = self.store.context_with_new(
                first_id, self.context_per_chain, node=node)
            gs = chain_structure(key_all[idx], frame.t[idx])
            bucket = next_pow2(len(idx), self.min_bucket)
            inputs = assemble_inputs(
                {k: feats[k][idx] for k in FEATURE_KEYS},
                gs.nbr, gs.dt, gs.t_src, bucket)
            requests.append(
                {"node": node, "idx": idx, "is_new": is_new,
                 "bucket": bucket, "inputs": inputs})
        return requests

    def _dispatch_requests(self, params, requests: List[dict], *,
                           attach: bool
                           ) -> Tuple[Dict[str, FleetResult], int]:
        """Bucket-grouped stacked dispatches of assembled requests
        with the given ``params``. ``attach=True`` is the live flush
        path (scores written to the store, throughput counters);
        ``attach=False`` is read-only shadow scoring (canary gates) —
        the store is never touched."""
        results: Dict[str, FleetResult] = {}
        buckets: Dict[int, List[dict]] = {}
        for req in requests:
            buckets.setdefault(req["bucket"], []).append(req)
        for bucket, group in buckets.items():
            with obs_trace.span("fleet.stack",
                                args={"bucket": bucket,
                                      "requests": len(group)}):
                stack = stack_padded(
                    [req["inputs"] for req in group],
                    self.scorer.pad_requests(len(group)))
            r_pad = stack[next(iter(stack))].shape[0]
            self._stack_sigs[(r_pad, bucket)] = {
                k: (v.shape, v.dtype) for k, v in stack.items()}
            out = self._dispatch_with_retry(params, stack)
            if attach:
                self._dispatches += 1
            else:
                self._shadow_dispatches += 1
            for r, req in enumerate(group):
                idx, is_new = req["idx"], req["is_new"]
                m = len(idx)
                prob = out["anomaly_prob"][r, :m]
                codes = out["codes"][r, :m]
                logits = out["type_logits"][r, :m]
                if attach:
                    self.store.attach(idx[is_new], prob[is_new],
                                      codes[is_new])
                    self._rows_scored += int(is_new.sum())
                results[req["node"]] = FleetResult(
                    node=req["node"],
                    anomaly_prob=prob[is_new],
                    type_logits=logits[is_new],
                    codes=codes[is_new],
                    row_ids=self.store.row_id[idx[is_new]],
                    context_row_ids=self.store.row_id[idx[~is_new]],
                    bucket=bucket)
        return results, len(buckets)

    def _dispatch_with_retry(self, params, stack):
        """One sharded dispatch with bounded retry-with-backoff for
        transient scorer failures (seeded jitter via ``common.rng`` so
        backoff schedules replay deterministically). The stacked numpy
        buffers stay valid across attempts — only the device copies
        are donated — so a retry re-runs the identical dispatch."""
        for attempt in range(self.dispatch_retries + 1):
            try:
                return self.scorer.score_stack(params, stack)
            except Exception:
                if attempt >= self.dispatch_retries:
                    raise
                self._scorer_retries += 1
                self._m_retries.inc()
                base = self.retry_backoff_s * (2 ** attempt)
                jitter = folded_generator(
                    self.retry_seed, STREAM_RETRY,
                    self._scorer_retries).uniform(0.0, base)
                time.sleep(min(base + jitter, 1.0))

    # ----------------------------------------------------- model plane
    def swap_params(self, new_params):
        """Atomically replace the scoring parameters; returns the old
        ones. Taken under the service lock, so the swap lands at a
        flush boundary — every request of one flush is scored by
        exactly one parameter set, and nothing pending is dropped or
        rescored (``repro.fleet.modelplane`` hot-swap path)."""
        with self._lock:
            old, self.params = self.params, new_params
            self._swaps += 1
            self._m_swaps.inc()
            return old

    def warm(self, params) -> int:
        """Pre-dispatch ``params`` through every stacked program shape
        seen so far (zero-filled inputs, outputs discarded): any
        compile and the host->device parameter transfer happen here,
        off the request path, so the subsequent :meth:`swap_params`
        costs no request latency. Returns the number of shapes
        warmed."""
        with self._lock:
            sigs = list(self._stack_sigs.items())
        for _, sig in sigs:
            stack = {k: np.zeros(shape, dtype)
                     for k, (shape, dtype) in sig.items()}
            self._dispatch_with_retry(params, stack)
            self._warm_dispatches += 1
        return len(sigs)

    def rescore(self, first_id: int, params=None, *,
                attach: bool = False) -> Dict[str, FleetResult]:
        """Re-score every store row with id >= ``first_id`` through
        the exact flush path (same per-node context windows, row
        buckets and stacked dispatches) without re-appending anything.
        With ``attach=False`` (shadow mode) the store is untouched —
        this is the canary gate's side-by-side scoring of a candidate
        against the incumbent's attached scores. ``attach=True``
        overwrites the stored scores (the rollback repair path).
        Scores are bit-identical to what the original flushes computed
        for the same parameters: each row's score depends only on its
        own chain's receptive field, which this gather reproduces."""
        with self._lock:
            if len(self.store) == 0 or first_id >= self.store.next_id:
                return {}
            p = self.params if params is None else params
            requests = self._assemble_requests(first_id)
            results, _ = self._dispatch_requests(p, requests,
                                                 attach=attach)
            return results

    # -------------------------------------------------------------- stats
    @property
    def trace_count(self) -> int:
        return self.scorer.trace_count

    @property
    def stats(self) -> obs_metrics.StatsDict:
        return {
            "requests_served": self._requests_served,
            "rows_scored": self._rows_scored,
            "flushes": self._flushes,
            "dispatches": self._dispatches,
            "shadow_dispatches": self._shadow_dispatches,
            "scorer_retries": self._scorer_retries,
            "param_swaps": self._swaps,
            "warm_dispatches": self._warm_dispatches,
            "quarantined_nonfinite": self._quarantined_nonfinite,
            "quarantined_unknown_type": self._quarantined_unknown_type,
            "quarantined_rows": (self._quarantined_nonfinite
                                 + self._quarantined_unknown_type),
            "traces": self.scorer.trace_count,
            "devices": self.scorer.n_devices,
            "store_rows": len(self.store),
            "wall_s": self._wall_s,
            "requests_per_s": (self._requests_served
                               / max(self._wall_s, 1e-9)),
        }
