"""Model management plane: versioned checkpoints, canary gates,
zero-downtime promote/rollback on the live fleet service.

A Perona deployment is long-lived: the ingestion daemon streams
telemetry for weeks while the model it scores with ages. This module
closes the loop between the drift analytics (which *detect* that the
fleet has moved away from the fingerprinted baseline) and the trainer
(which can produce a fresh model from the durable store history) — by
making the scoring parameters a *managed, versioned artifact* instead
of a constructor argument.

Two layers:

- :class:`ModelRegistry` — versioned parameter checkpoints on top of
  :class:`repro.checkpointing.manager.CheckpointManager` (atomic
  ``step_<v>.npz`` writes, keep-last-K GC with the incumbent and its
  predecessor pinned) plus a crash-safe ``registry.json`` (tmp file +
  ``os.replace``, the same durability idiom as ``store.atomic_savez``)
  recording each version's source, lifecycle status
  (candidate -> canary -> incumbent / rejected / rolled_back ->
  retired), tags and canary verdict.

- :class:`ModelPlane` — the live controller. It hooks the
  :class:`~repro.fleet.ingest.IngestionDaemon`'s flush boundary and
  drives a three-phase lifecycle:

  *canary*: a submitted candidate is shadow-scored side by side with
  the incumbent on the daemon's real micro-batches
  (``service.rescore(first_id, params=candidate)`` — the exact flush
  path, store untouched) and gated on score divergence vs the
  incumbent's attached scores, NaN/Inf checks over every output head,
  false-positive rate on known-clean nodes, and a latency budget
  against the service's per-flush wall-clock histogram. The verdict is
  recorded in the registry either way.

  *promote*: the candidate's sharded programs are warmed through every
  stacked shape seen so far (``service.warm``) *before*
  ``service.swap_params`` flips the reference under the service lock —
  the swap lands at a flush boundary, in-flight submissions are never
  dropped or double-scored, and the first post-swap flush pays no
  compile.

  *watch*: for a bounded number of flushes after the swap, the plane
  monitors the candidate's live output (NaN/Inf, or flush-mean anomaly
  regressing past the steady-state EWMA baseline plus a MAD-derived
  noise floor — the same :class:`~repro.fleet.drift.EwmaMean` +
  ``obs.regress`` noise machinery as the perf gate). A regression
  triggers automatic rollback: parameters swap back, every row scored
  by the bad candidate is re-scored with the incumbent through the
  flush path (``rescore(attach=True)``) so the store ends bit-identical
  to a run that never promoted, and the in-flight flush's results are
  repaired in place before the daemon folds them into drift state.

  *steady*: flush-mean anomaly folds into the health baseline, and the
  drift analytics are polled — nodes degrading for
  ``drift_flag_flushes`` consecutive flushes trigger one
  retrain-on-store-history -> canary -> promote episode
  (``retrain_fn``, defaulting to ``build_graphs`` + ``train_perona``
  over the stored frame).

Every transition is observable: ``modelplane.*`` counters in the
metrics registry and ``CAT_PLANE`` tracer instants (canary_start /
canary_pass / canary_fail / promote / rollback / retrain) in the
daemon's clock domain, so promote/rollback markers line up with flush
spans on the exported timeline.
"""

from __future__ import annotations

import collections
import dataclasses
import json
import os
import time
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence, Union

import numpy as np

from repro.checkpointing.manager import CheckpointManager
from repro.fleet.drift import EwmaMean, degrading_nodes
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.regress import series_noise_pct

STATUS_CANDIDATE = "candidate"
STATUS_CANARY = "canary"
STATUS_REJECTED = "rejected"
STATUS_INCUMBENT = "incumbent"
STATUS_ROLLED_BACK = "rolled_back"
STATUS_RETIRED = "retired"

PHASE_STEADY = "steady"
PHASE_CANARY = "canary"
PHASE_WATCH = "watch"


class ModelRegistry:
    """Versioned parameter store with a crash-safe JSON index.

    Checkpoints live under ``<dir>/checkpoints`` (one ``step_<v>.npz``
    per version via :class:`CheckpointManager`, synchronous writes so a
    returned version id is always durable); lifecycle state lives in
    ``<dir>/registry.json``, rewritten atomically on every mutation.
    The current incumbent and its predecessor are pinned against
    keep-last GC — rollback must always find both on disk."""

    def __init__(self, directory, keep_last: int = 8):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.manager = CheckpointManager(
            self.dir / "checkpoints", keep_last=keep_last,
            async_save=False)
        self.path = self.dir / "registry.json"
        if self.path.exists():
            self._state = json.loads(self.path.read_text())
        else:
            self._state = {"versions": {}, "incumbent": None,
                           "previous": None, "next_version": 1}
        self._repin()

    # ------------------------------------------------------- persistence
    def _write(self) -> None:
        tmp = self.dir / ".tmp_registry.json"
        tmp.write_text(json.dumps(self._state, indent=2,
                                  sort_keys=True))
        os.replace(tmp, self.path)

    def _repin(self) -> None:
        self.manager.pinned = {
            v for v in (self._state["incumbent"],
                        self._state["previous"]) if v is not None}

    def _entry(self, vid: int) -> Dict:
        try:
            return self._state["versions"][str(int(vid))]
        except KeyError:
            raise KeyError(f"unknown model version {vid}") from None

    # ------------------------------------------------------------ writes
    def save_version(self, params, *, source: str = "manual",
                     extra: Optional[Dict] = None) -> int:
        """Checkpoint ``params`` as a new version (status: candidate);
        returns the version id. The write is synchronous and atomic —
        when this returns, the version is durable."""
        vid = int(self._state["next_version"])
        self._state["next_version"] = vid + 1
        self.manager.save(vid, params,
                          extra={"source": source, **(extra or {})})
        self._state["versions"][str(vid)] = {
            "version": vid, "source": source,
            "status": STATUS_CANDIDATE, "tags": [], "verdict": None,
            "extra": dict(extra or {})}
        self._write()
        return vid

    def set_status(self, vid: int, status: str) -> None:
        self._entry(vid)["status"] = status
        self._write()

    def tag(self, vid: int, tag: str) -> None:
        tags = self._entry(vid)["tags"]
        if tag not in tags:
            tags.append(tag)
            self._write()

    def record_verdict(self, vid: int, verdict: Dict) -> None:
        """Attach a canary verdict (criteria + pass/fail) to a
        version — the audit trail of why a candidate was (not)
        promoted."""
        self._entry(vid)["verdict"] = verdict
        self._write()

    def set_incumbent(self, vid: int) -> None:
        """Make ``vid`` the incumbent; the old incumbent becomes
        ``previous`` (status retired) and both are pinned against
        checkpoint GC."""
        self._entry(vid)  # must exist
        old = self._state["incumbent"]
        if old is not None and int(old) != int(vid):
            self._state["previous"] = int(old)
            self._entry(old)["status"] = STATUS_RETIRED
        self._state["incumbent"] = int(vid)
        self._entry(vid)["status"] = STATUS_INCUMBENT
        self._repin()
        self._write()

    # ------------------------------------------------------------- reads
    @property
    def incumbent(self) -> Optional[int]:
        v = self._state["incumbent"]
        return None if v is None else int(v)

    @property
    def previous(self) -> Optional[int]:
        v = self._state["previous"]
        return None if v is None else int(v)

    def entry(self, vid: int) -> Dict:
        return dict(self._entry(vid))

    def list_versions(self) -> List[Dict]:
        return [dict(e) for _, e in sorted(
            self._state["versions"].items(), key=lambda kv: int(kv[0]))]

    def load_version(self, template, vid: Optional[int] = None):
        """Restore a version's parameters into the structure of
        ``template`` (default: the incumbent)."""
        if vid is None:
            vid = self.incumbent
        if vid is None:
            raise RuntimeError("registry has no incumbent to load")
        tree, _ = self.manager.restore(template, step=int(vid))
        if tree is None:
            raise FileNotFoundError(
                f"checkpoint for version {vid} not on disk")
        return tree


class ModelPlane:
    """Live model lifecycle controller over a
    :class:`~repro.fleet.service.FleetScoringService` (and optionally
    the :class:`~repro.fleet.ingest.IngestionDaemon` that drives it).
    See the module docstring for the canary -> promote -> watch ->
    steady lifecycle."""

    def __init__(self, service,
                 registry: Union[ModelRegistry, str, "os.PathLike"], *,
                 daemon=None,
                 canary_flushes: int = 2,
                 watch_flushes: int = 3,
                 divergence_budget: float = 1e-3,
                 fp_budget: float = 0.25,
                 fp_threshold: float = 0.5,
                 latency_budget: float = 3.0,
                 health_alpha: float = 0.3,
                 health_window: int = 64,
                 min_health_shift: float = 0.15,
                 drift_flag_flushes: int = 3,
                 drift_ewma_threshold: float = 0.5,
                 drift_min_scored: int = 3,
                 retrain_fn: Optional[Callable] = None,
                 retrain_epochs: int = 40,
                 retrain_seed: int = 0,
                 clean_nodes: Optional[Sequence[str]] = None):
        self.service = service
        self.registry = (registry if isinstance(registry, ModelRegistry)
                         else ModelRegistry(registry))
        self.canary_flushes = canary_flushes
        self.watch_flushes = watch_flushes
        self.divergence_budget = divergence_budget
        self.fp_budget = fp_budget
        self.fp_threshold = fp_threshold
        self.latency_budget = latency_budget
        self.min_health_shift = min_health_shift
        self.drift_flag_flushes = drift_flag_flushes
        self.drift_ewma_threshold = drift_ewma_threshold
        self.drift_min_scored = drift_min_scored
        self.retrain_fn = retrain_fn
        self.retrain_epochs = retrain_epochs
        self.retrain_seed = retrain_seed
        self.clean_nodes = (None if clean_nodes is None
                            else set(clean_nodes))

        self.phase = PHASE_STEADY
        self._incumbent_params = None
        self._candidate: Optional[Dict] = None  # canary in flight
        self._watch: Optional[Dict] = None  # post-promote watch
        self._health = EwmaMean(health_alpha)
        self._health_values: collections.deque = collections.deque(
            maxlen=health_window)
        self._flag_streak = 0
        self._retrained_episode = False

        self._promotions = 0
        self._rollbacks = 0
        self._canary_pass = 0
        self._canary_fail = 0
        self._retrains = 0
        self._shadow_flushes = 0
        self._repaired_rows = 0
        reg = obs_metrics.registry()
        self._m_promotions = reg.counter("modelplane.promotions")
        self._m_rollbacks = reg.counter("modelplane.rollbacks")
        self._m_canary = {
            "pass": reg.counter("modelplane.canary", verdict="pass"),
            "fail": reg.counter("modelplane.canary", verdict="fail")}
        self._m_retrains = reg.counter("modelplane.retrains")
        self._m_shadow = reg.counter("modelplane.shadow_flushes")
        self._m_repaired = reg.counter("modelplane.repaired_rows")

        self.daemon = None
        self.tracer = obs_trace.tracer()
        if daemon is not None:
            self.attach(daemon)

    # -------------------------------------------------------------- wiring
    def attach(self, daemon) -> None:
        """Hook the daemon's flush boundary; plane instants move into
        the daemon's clock domain so they line up with flush spans on
        the exported timeline."""
        self.daemon = daemon
        self.tracer = daemon.tracer
        daemon.add_flush_hook(self.on_flush)

    def _instant(self, name: str,
                 args: Optional[Dict[str, object]] = None) -> None:
        ts = self.daemon.now if self.daemon is not None else None
        self.tracer.instant(name, obs_trace.CAT_PLANE, args=args,
                            ts=ts)

    # ---------------------------------------------------------- lifecycle
    def bootstrap(self, params=None, *,
                  source: str = "bootstrap") -> int:
        """Register the service's current parameters (or ``params``)
        as version 1 / the incumbent. Call once before streaming."""
        if params is None:
            params = self.service.params
        vid = self.registry.save_version(params, source=source)
        self.registry.set_incumbent(vid)
        if params is not self.service.params:
            self.service.swap_params(params)
        self._incumbent_params = params
        return vid

    def submit_candidate(self, params, *, source: str = "manual",
                         extra: Optional[Dict] = None) -> int:
        """Checkpoint ``params`` as a new version and start its canary
        on the next flushes. One candidate at a time: raises if a
        canary or post-promote watch is already in flight."""
        vid = self.registry.save_version(params, source=source,
                                         extra=extra)
        self._begin_canary(vid, params)
        return vid

    def promote(self, vid: int, *, force: bool = False) -> int:
        """Promote a registered version. Without ``force`` the version
        (re-)enters the canary gate and promotes only on a pass; with
        ``force`` it skips straight past the gate to the swap — the
        post-promote watch still applies, so a bad forced promote is
        rolled back automatically."""
        params = self._params_for(vid)
        if force:
            if self._watch is not None:
                self._commit_watch()
            if self._candidate is not None:
                self.registry.set_status(self._candidate["vid"],
                                         STATUS_CANDIDATE)
                self._candidate = None
                self.phase = PHASE_STEADY
            self._do_promote(vid, params, forced=True)
        else:
            self._begin_canary(vid, params)
        return vid

    def rollback(self) -> Optional[int]:
        """Manual rollback. During a post-promote watch this behaves
        exactly like an automatic health rollback (store repaired);
        otherwise the registry's ``previous`` version is restored and
        swapped in. Returns the version rolled back to."""
        if self._watch is not None:
            vid = self._watch["old_vid"]
            self._rollback_watch({}, reason="manual")
            return vid
        prev = self.registry.previous
        if prev is None:
            raise RuntimeError("no previous version to roll back to")
        cur = self.registry.incumbent
        params = self.registry.load_version(self.service.params, prev)
        self.service.warm(params)
        self.service.swap_params(params)
        self.registry.set_incumbent(prev)
        if cur is not None:
            self.registry.set_status(cur, STATUS_ROLLED_BACK)
        self._incumbent_params = params
        self._rollbacks += 1
        self._m_rollbacks.inc()
        self._instant("modelplane.rollback",
                      args={"version": cur, "to": prev,
                            "reason": "manual"})
        return prev

    def _params_for(self, vid: int):
        if self._candidate is not None and self._candidate["vid"] == vid:
            return self._candidate["params"]
        return self.registry.load_version(self.service.params, vid)

    def _begin_canary(self, vid: int, params) -> None:
        if self.phase != PHASE_STEADY:
            raise RuntimeError(
                f"cannot start a canary while in phase {self.phase!r}")
        self.registry.set_status(vid, STATUS_CANARY)
        self._candidate = {
            "vid": vid, "params": params, "flushes": 0,
            "div_max": 0.0, "div_sum": 0.0, "div_n": 0,
            "nonfinite": 0, "fp": 0, "fp_n": 0, "lat_max": 0.0}
        self.phase = PHASE_CANARY
        self._instant("modelplane.canary_start",
                      args={"version": vid})

    # -------------------------------------------------------- flush hook
    def on_flush(self, results: Dict[str, object],
                 trigger: str) -> None:
        """Daemon flush hook — runs under the daemon lock after
        scoring, *before* results are folded into drift state, so a
        rollback can repair the flush's results in place."""
        if not results:
            return
        if self.phase == PHASE_CANARY:
            self._canary_step(results)
            # these results were scored by the incumbent either way
            self._fold_health(results)
        elif self.phase == PHASE_WATCH:
            self._watch_step(results)
        else:
            self._fold_health(results)
            self._check_drift()

    # ------------------------------------------------------------- canary
    def _canary_step(self, results) -> None:
        c = self._candidate
        row_mins = [int(r.row_ids.min()) for r in results.values()
                    if len(r.row_ids)]
        if not row_mins:
            return
        first_id = min(row_mins)
        t0 = time.perf_counter()
        shadow = self.service.rescore(first_id, params=c["params"],
                                      attach=False)
        shadow_wall = time.perf_counter() - t0
        self._shadow_flushes += 1
        self._m_shadow.inc()
        clean = self._clean_set(results)
        for node, cur in results.items():
            sh = shadow.get(node)
            if sh is None or len(cur.row_ids) == 0:
                continue
            sel = np.isin(sh.row_ids, cur.row_ids)
            prob = np.asarray(sh.anomaly_prob, np.float64)[sel]
            div = np.abs(prob
                         - np.asarray(cur.anomaly_prob, np.float64))
            if len(div):
                # NaN-poisoned divergence counts as maximal, not as
                # silently-ignored
                c["div_max"] = max(
                    c["div_max"],
                    float(np.nanmax(div)) if np.isfinite(div).any()
                    else float("inf"))
                c["div_sum"] += float(np.nansum(div))
                c["div_n"] += int(len(div))
            c["nonfinite"] += int(
                (~np.isfinite(prob)).sum()
                + (~np.isfinite(np.asarray(sh.codes)[sel])).sum()
                + (~np.isfinite(np.asarray(sh.type_logits)[sel])).sum())
            if node in clean and len(prob):
                c["fp"] += int((prob > self.fp_threshold).sum())
                c["fp_n"] += int(len(prob))
        base = self.service._h_flush.quantile(0.5)
        if np.isfinite(base) and base > 0:
            c["lat_max"] = max(c["lat_max"], shadow_wall / base)
        c["flushes"] += 1
        if c["flushes"] >= self.canary_flushes:
            self._finish_canary()

    def _clean_set(self, results) -> set:
        if self.clean_nodes is not None:
            return self.clean_nodes
        if self.daemon is not None:
            flagged = set(degrading_nodes(
                self.daemon.drift.report(),
                ewma_threshold=self.drift_ewma_threshold,
                min_scored=self.drift_min_scored))
            return set(results) - flagged
        return set(results)

    def _finish_canary(self) -> None:
        c, self._candidate = self._candidate, None
        fp_rate = c["fp"] / max(c["fp_n"], 1)
        checks = {
            "divergence": c["div_max"] <= self.divergence_budget,
            "finite": c["nonfinite"] == 0,
            "false_positives": fp_rate <= self.fp_budget,
            "latency": c["lat_max"] <= self.latency_budget,
        }
        verdict = {
            "passed": all(checks.values()),
            "failed_checks": sorted(k for k, ok in checks.items()
                                    if not ok),
            "flushes": c["flushes"],
            "divergence_max": c["div_max"],
            "divergence_mean": c["div_sum"] / max(c["div_n"], 1),
            "nonfinite_outputs": c["nonfinite"],
            "false_positive_rate": fp_rate,
            "latency_ratio_max": c["lat_max"],
        }
        self.registry.record_verdict(c["vid"], verdict)
        if verdict["passed"]:
            self._canary_pass += 1
            self._m_canary["pass"].inc()
            self.phase = PHASE_STEADY  # _do_promote re-enters watch
            self._instant("modelplane.canary_pass",
                          args={"version": c["vid"]})
            self._do_promote(c["vid"], c["params"])
        else:
            self._canary_fail += 1
            self._m_canary["fail"].inc()
            self.registry.set_status(c["vid"], STATUS_REJECTED)
            self.phase = PHASE_STEADY
            self._instant("modelplane.canary_fail",
                          args={"version": c["vid"],
                                "failed": verdict["failed_checks"]})

    # ---------------------------------------------------- promote / watch
    def _do_promote(self, vid: int, params, *,
                    forced: bool = False) -> None:
        old_vid = self.registry.incumbent
        old_params = self._incumbent_params
        if old_params is None:
            old_params = self.service.params
        warmed = self.service.warm(params)  # compile OFF the hot path
        self.service.swap_params(params)
        self.registry.set_incumbent(vid)
        self._watch = {"vid": vid, "params": params,
                       "old_vid": old_vid, "old_params": old_params,
                       "first_id": self.service.store.next_id,
                       "flushes": 0}
        self.phase = PHASE_WATCH
        self._promotions += 1
        self._m_promotions.inc()
        self._instant("modelplane.promote",
                      args={"version": vid, "from": old_vid,
                            "warmed_shapes": warmed,
                            "forced": forced})

    def _watch_step(self, results) -> None:
        w = self._watch
        w["flushes"] += 1
        probs = [np.asarray(r.anomaly_prob, np.float64)
                 for r in results.values() if len(r.anomaly_prob)]
        flat = (np.concatenate(probs) if probs
                else np.empty(0, np.float64))
        nonfinite = bool(len(flat)) and not bool(
            np.isfinite(flat).all())
        mean = float(flat.mean()) if len(flat) else float("nan")
        baseline = self._health.ewma
        regressed = (not nonfinite and baseline is not None
                     and np.isfinite(mean)
                     and mean > baseline + self._health_floor())
        if nonfinite or regressed:
            self._rollback_watch(
                results,
                reason="nonfinite" if nonfinite else "health")
            self._fold_health(results)  # repaired = incumbent-scored
            return
        if w["flushes"] >= self.watch_flushes:
            self._commit_watch()
            self._fold_health(results)
        # mid-watch flushes are compared against the baseline but not
        # folded into it — a slow regression must not normalize itself

    def _health_floor(self) -> float:
        """Absolute allowed shift: the MAD-based robust scatter of the
        recent flush-mean window (``obs.regress`` noise machinery),
        floored at ``min_health_shift``."""
        vals = np.asarray(self._health_values, np.float64)
        floor = 0.0
        if len(vals) >= 2:
            med = float(np.median(vals))
            floor = series_noise_pct(vals) / 100.0 * abs(med)
        return max(floor, self.min_health_shift)

    def _fold_health(self, results) -> None:
        probs = [np.asarray(r.anomaly_prob, np.float64)
                 for r in results.values() if len(r.anomaly_prob)]
        if not probs:
            return
        flat = np.concatenate(probs)
        flat = flat[np.isfinite(flat)]
        if len(flat):
            m = float(flat.mean())
            self._health.update(m)
            self._health_values.append(m)

    def _commit_watch(self) -> None:
        w, self._watch = self._watch, None
        self.phase = PHASE_STEADY
        self._incumbent_params = w["params"]
        # fresh model, fresh drift-retrain episode
        self._flag_streak = 0
        self._retrained_episode = False
        self._instant("modelplane.watch_pass",
                      args={"version": w["vid"],
                            "flushes": w["flushes"]})

    def _rollback_watch(self, results, *, reason: str) -> None:
        w, self._watch = self._watch, None
        old = w["old_params"]
        self.service.swap_params(old)
        # repair: every row the candidate scored is re-scored by the
        # incumbent through the exact flush path; the store ends
        # bit-identical to a run that never promoted
        repaired = self.service.rescore(w["first_id"], params=old,
                                        attach=True)
        n_rep = sum(len(r.row_ids) for r in repaired.values())
        for node, cur in list(results.items()):
            rep = repaired.get(node)
            if rep is None:
                continue
            sel = np.isin(rep.row_ids, cur.row_ids)
            results[node] = dataclasses.replace(
                cur,
                anomaly_prob=np.asarray(rep.anomaly_prob)[sel],
                type_logits=np.asarray(rep.type_logits)[sel],
                codes=np.asarray(rep.codes)[sel],
                row_ids=np.asarray(rep.row_ids)[sel])
        if w["old_vid"] is not None:
            self.registry.set_incumbent(w["old_vid"])
        self.registry.set_status(w["vid"], STATUS_ROLLED_BACK)
        self._incumbent_params = old
        self.phase = PHASE_STEADY
        self._rollbacks += 1
        self._m_rollbacks.inc()
        self._repaired_rows += n_rep
        self._m_repaired.inc(n_rep)
        self._instant("modelplane.rollback",
                      args={"version": w["vid"], "to": w["old_vid"],
                            "reason": reason,
                            "after_flushes": w["flushes"],
                            "repaired_rows": n_rep})

    # ------------------------------------------------------ drift retrain
    def _check_drift(self) -> None:
        report = (self.daemon.drift.report()
                  if self.daemon is not None else {})
        flagged = degrading_nodes(
            report, ewma_threshold=self.drift_ewma_threshold,
            min_scored=self.drift_min_scored)
        if flagged:
            self._flag_streak += 1
        else:
            self._flag_streak = 0
            self._retrained_episode = False
        if (self._flag_streak < self.drift_flag_flushes
                or self._retrained_episode):
            return
        # one retrain episode per sustained degradation: re-arm only
        # after the fleet goes clean (or a promote commits)
        self._retrained_episode = True
        self._retrains += 1
        self._m_retrains.inc()
        nodes = sorted(flagged)
        self._instant("modelplane.retrain", args={"nodes": nodes})
        fn = self.retrain_fn or self._default_retrain
        params = fn(self.service)
        if params is not None:
            self.submit_candidate(params, source="drift-retrain",
                                  extra={"nodes": nodes})

    def _default_retrain(self, service):
        """Retrain on the durable store history (`build_graphs` over
        the stored frame, labels from its stress column)."""
        frame = service.store.frame
        if frame is None or len(frame) < 8:
            return None
        from repro.core.graph_data import build_graphs
        from repro.core.trainer import train_perona
        batch = build_graphs(frame, service.preproc)
        res = train_perona(service.model, batch,
                           epochs=self.retrain_epochs,
                           seed=self.retrain_seed)
        return res.params

    # -------------------------------------------------------------- stats
    def status(self) -> Dict[str, object]:
        reg = self.registry
        if self._candidate is not None:
            candidate = self._candidate["vid"]
        elif self._watch is not None:
            candidate = self._watch["vid"]
        else:
            candidate = None
        return {
            "phase": self.phase,
            "incumbent": reg.incumbent,
            "previous": reg.previous,
            "candidate": candidate,
            "versions": len(reg.list_versions()),
            "promotions": self._promotions,
            "rollbacks": self._rollbacks,
            "canary_pass": self._canary_pass,
            "canary_fail": self._canary_fail,
            "retrains": self._retrains,
            "shadow_flushes": self._shadow_flushes,
            "repaired_rows": self._repaired_rows,
            "health_ewma": (float(self._health.ewma)
                            if self._health.ewma is not None
                            else None),
        }
