"""Append-only columnar fingerprint store (paper §III-C context).

Perona scores a new benchmark execution *against the history of
previous executions of the same node*; Karasu extends that history to
profiling data shared across users. Both need a durable, queryable
store whose context-assembly path is cheap at fleet traffic rates.

``FingerprintStore`` keeps executions in *amortized growable column
buffers* (capacity-doubling preallocated arrays, one per
:class:`BenchmarkFrame` column), parallel per-row arrays for global row
ids and attached scores (anomaly probability + fingerprint codes, NaN
until scored), and an optional per-row *feature cache* (the §III-B
preprocessed columns produced by ``serving.engine.prepare_features``)
so the fleet service never re-runs Python-side preprocessing for
context rows.

Views are pure array gathers over an *incrementally maintained*
per-(machine x benchmark type) chain index: every chain holds its row
indices sorted by (t, row), and an appended chunk merges into only the
chains it touches — in O(chunk) when the chunk's timestamps extend the
chain (the streaming fleet cadence), O(chain) otherwise. Appends never
touch the whole store (the old consolidate-and-rebuild design was
O(total rows) per flush), context reads locate a round's new rows by
``searchsorted`` on the sorted row ids, and per-chain filters touch
only the selected chains; ``bench_fleet`` asserts the amortized
append-round throughput. Vocabulary growth is in-place; only a
*schema* change (a chunk introducing new metric columns) pays a
one-off O(total) column widening.

``save``/``load`` round-trip the whole store through one ``.npz`` file
for durability; saves are atomic (temp file + ``os.replace``), so a
crash mid-save never corrupts the previous snapshot.
"""

from __future__ import annotations

import contextlib
import os
import tempfile
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.fingerprint.frame import (BenchmarkFrame, FrameOrRecords,
                                     as_frame)

FEATURE_KEYS = ("raw", "present", "type_ids", "edge_raw")

_MIN_CAP = 64


def atomic_savez(path: str, **payload) -> None:
    """Crash-safe ``np.savez_compressed``: write to a temp file in the
    target's directory, then ``os.replace`` — a crash mid-save leaves
    the previous snapshot intact instead of a truncated ``.npz``.
    Shared by :meth:`FingerprintStore.save` and the ingestion daemon's
    staging checkpoints."""
    path = os.path.abspath(path)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path),
                               prefix=os.path.basename(path) + ".",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as fh:
            np.savez_compressed(fh, **payload)
        os.replace(tmp, path)
    except BaseException:
        with contextlib.suppress(OSError):
            os.remove(tmp)
        raise


class _IntVec:
    """Growable int64 vector (amortized O(1) append)."""

    __slots__ = ("a", "n")

    def __init__(self, cap: int = 8):
        self.a = np.empty(cap, np.int64)
        self.n = 0

    def view(self) -> np.ndarray:
        return self.a[: self.n]

    def extend(self, vals: np.ndarray) -> None:
        need = self.n + len(vals)
        if need > len(self.a):
            grown = np.empty(max(2 * len(self.a), need), np.int64)
            grown[: self.n] = self.a[: self.n]
            self.a = grown
        self.a[self.n: need] = vals
        self.n = need

    def replace(self, vals: np.ndarray) -> None:
        self.a = np.asarray(vals, np.int64).copy()
        self.n = len(vals)


class FingerprintStore:
    """Append-only columnar store of scored benchmark executions."""

    def __init__(self):
        self._n = 0
        self._cap = 0
        # vocabularies (grow in place; code -> name)
        self._btypes: List[str] = []
        self._bidx: Dict[str, int] = {}
        self._machines: List[str] = []
        self._midx: Dict[str, int] = {}
        self._mtypes: List[str] = []
        self._tidx: Dict[str, int] = {}
        # column schema (append-only union across chunks)
        self._cols: List[Tuple[str, str]] = []  # (name, unit)
        self._cidx: Dict[Tuple[str, str], int] = {}
        self._ncols: List[str] = []
        self._nidx: Dict[str, int] = {}
        # row buffers (capacity _cap, first _n rows live)
        self._type_code = np.empty(0, np.int32)
        self._machine_code = np.empty(0, np.int32)
        self._machine_type_code = np.empty(0, np.int32)
        self._t = np.empty(0, np.float64)
        self._stressed = np.empty(0, bool)
        self._metrics = np.empty((0, 0), np.float64)
        self._metrics_present = np.empty((0, 0), bool)
        self._node_metrics = np.empty((0, 0), np.float64)
        self._node_metrics_present = np.empty((0, 0), bool)
        self._row_id = np.empty(0, np.int64)
        self._anomaly = np.empty(0, np.float32)
        self._codes: Optional[np.ndarray] = None  # (cap, K) once known
        self._features: Optional[Dict[str, np.ndarray]] = None
        self._has_features: Optional[bool] = None  # set on first append
        self._next_id = 0
        # row ids are appended in increasing order, so they stay
        # sorted by row index until a compact reorders rows by time
        self._ids_sorted = True
        # incremental index: machine code -> benchmark code -> row
        # indices sorted by (t, row)
        self._chains: Dict[int, Dict[int, _IntVec]] = {}
        self._frame_cache: Optional[BenchmarkFrame] = None

    # ------------------------------------------------------------- basics
    def __len__(self) -> int:
        return self._n

    @property
    def frame(self) -> Optional[BenchmarkFrame]:
        """The live rows as one columnar frame (None while empty).
        Zero-copy column views; stable object identity between
        mutations."""
        if self._n == 0:
            return None
        if self._frame_cache is None:
            self._frame_cache = BenchmarkFrame(
                benchmark_types=tuple(self._btypes),
                machines=tuple(self._machines),
                machine_types=tuple(self._mtypes),
                metric_names=tuple(c[0] for c in self._cols),
                metric_units=tuple(c[1] for c in self._cols),
                node_metric_names=tuple(self._ncols),
                type_code=self._type_code[: self._n],
                machine_code=self._machine_code[: self._n],
                machine_type_code=self._machine_type_code[: self._n],
                t=self._t[: self._n],
                stressed=self._stressed[: self._n],
                metrics=self._metrics[: self._n],
                metrics_present=self._metrics_present[: self._n],
                node_metrics=self._node_metrics[: self._n],
                node_metrics_present=self._node_metrics_present[
                    : self._n])
        return self._frame_cache

    @property
    def next_id(self) -> int:
        """The global row id the next appended row will receive."""
        return self._next_id

    @property
    def row_id(self) -> np.ndarray:
        """(N,) monotonically increasing global row ids (append order);
        ids survive :meth:`compact`."""
        return self._row_id[: self._n]

    @property
    def anomaly(self) -> np.ndarray:
        """(N,) attached anomaly probabilities (NaN until scored)."""
        return self._anomaly[: self._n]

    @property
    def codes(self) -> Optional[np.ndarray]:
        """(N, K) attached fingerprint codes (NaN rows until scored)."""
        return None if self._codes is None else self._codes[: self._n]

    @property
    def features(self) -> Optional[Dict[str, np.ndarray]]:
        """Cached per-row preprocessed columns (see FEATURE_KEYS)."""
        if self._features is None:
            return None
        return {k: v[: self._n] for k, v in self._features.items()}

    # ----------------------------------------------------------- capacity
    def _grow_rows(self, need: int) -> None:
        if need <= self._cap:
            return
        cap = max(2 * self._cap, need, _MIN_CAP)

        def grow(buf, fill=None):
            out = np.empty((cap,) + buf.shape[1:], buf.dtype)
            out[: self._n] = buf[: self._n]
            if fill is not None:
                out[self._n:] = fill
            return out

        self._type_code = grow(self._type_code)
        self._machine_code = grow(self._machine_code)
        self._machine_type_code = grow(self._machine_type_code)
        self._t = grow(self._t)
        self._stressed = grow(self._stressed)
        self._metrics = grow(self._metrics)
        self._metrics_present = grow(self._metrics_present, fill=False)
        self._node_metrics = grow(self._node_metrics)
        self._node_metrics_present = grow(self._node_metrics_present,
                                          fill=False)
        self._row_id = grow(self._row_id)
        self._anomaly = grow(self._anomaly, fill=np.nan)
        if self._codes is not None:
            self._codes = grow(self._codes, fill=np.nan)
        if self._features is not None:
            self._features = {k: grow(v)
                              for k, v in self._features.items()}
        self._cap = cap

    def _widen_columns(self, n_cols: int, n_ncols: int) -> None:
        """Grow the metric column axes (rare: only when a chunk
        introduces new metric names — an O(total) schema change)."""
        if n_cols > self._metrics.shape[1]:
            for name in ("_metrics", "_metrics_present"):
                buf = getattr(self, name)
                out = np.zeros((self._cap, n_cols), buf.dtype)
                out[: self._n, : buf.shape[1]] = buf[: self._n]
                setattr(self, name, out)
        if n_ncols > self._node_metrics.shape[1]:
            for name in ("_node_metrics", "_node_metrics_present"):
                buf = getattr(self, name)
                out = np.zeros((self._cap, n_ncols), buf.dtype)
                out[: self._n, : buf.shape[1]] = buf[: self._n]
                setattr(self, name, out)

    @staticmethod
    def _intern_one(key, vocab: List, index: Dict) -> int:
        """Get-or-append one key in a (vocab, index) pair."""
        code = index.get(key)
        if code is None:
            code = len(vocab)
            vocab.append(key)
            index[key] = code
        return code

    @classmethod
    def _intern(cls, names, vocab: List[str],
                index: Dict) -> np.ndarray:
        """Map chunk-local names to global codes, growing the
        vocabulary in place; returns the chunk-code -> global-code LUT."""
        lut = np.empty(max(len(names), 1), np.int32)
        for i, name in enumerate(names):
            lut[i] = cls._intern_one(name, vocab, index)
        return lut

    # ------------------------------------------------------------- append
    def append(self, data: FrameOrRecords,
               features: Optional[Dict[str, np.ndarray]] = None,
               anomaly: Optional[np.ndarray] = None,
               codes: Optional[np.ndarray] = None) -> int:
        """Append one chunk of executions; returns the first global row
        id of the chunk (ids are contiguous per chunk)."""
        frame = as_frame(data)
        n = len(frame)
        if n == 0:
            return self._next_id
        if self._has_features is None:
            self._has_features = features is not None
        elif self._has_features != (features is not None):
            raise ValueError(
                "cannot mix feature-cached and plain appends: the "
                "store either caches features for every row or none")
        first = self._next_id

        blut = self._intern(frame.benchmark_types, self._btypes,
                            self._bidx)
        mlut = self._intern(frame.machines, self._machines, self._midx)
        tlut = self._intern(frame.machine_types, self._mtypes,
                            self._tidx)
        ci = np.asarray([self._intern_metric(key) for key
                         in zip(frame.metric_names,
                                frame.metric_units)], np.int64)
        ni = np.asarray([self._intern_col(key) for key
                         in frame.node_metric_names], np.int64)

        self._grow_rows(self._n + n)
        self._widen_columns(len(self._cols), len(self._ncols))

        lo, hi = self._n, self._n + n
        self._type_code[lo:hi] = blut[frame.type_code]
        self._machine_code[lo:hi] = mlut[frame.machine_code]
        self._machine_type_code[lo:hi] = tlut[frame.machine_type_code]
        self._t[lo:hi] = frame.t
        self._stressed[lo:hi] = frame.stressed
        self._metrics[lo:hi] = 0.0
        self._metrics_present[lo:hi] = False
        self._node_metrics[lo:hi] = 0.0
        self._node_metrics_present[lo:hi] = False
        if len(ci):
            self._metrics[lo:hi, ci] = frame.metrics
            self._metrics_present[lo:hi, ci] = frame.metrics_present
        if len(ni):
            self._node_metrics[lo:hi, ni] = frame.node_metrics
            self._node_metrics_present[lo:hi, ni] = \
                frame.node_metrics_present
        self._row_id[lo:hi] = np.arange(first, first + n)
        self._anomaly[lo:hi] = (np.nan if anomaly is None
                                else np.asarray(anomaly, np.float32))
        if codes is not None:
            codes = np.asarray(codes, np.float32)
            if self._codes is None:
                self._codes = np.full((self._cap, codes.shape[1]),
                                      np.nan, np.float32)
            self._codes[lo:hi] = codes
        elif self._codes is not None:
            self._codes[lo:hi] = np.nan
        if features is not None:
            if self._features is None:
                self._features = {}
                for key in FEATURE_KEYS:
                    col = np.asarray(features[key])
                    buf = np.zeros((self._cap,) + col.shape[1:],
                                   col.dtype)
                    self._features[key] = buf
            for key in FEATURE_KEYS:
                self._features[key][lo:hi] = np.asarray(features[key])

        self._merge_into_chains(lo, hi)
        self._n = hi
        self._next_id += n
        self._frame_cache = None
        return first

    def _intern_metric(self, key: Tuple[str, str]) -> int:
        return self._intern_one(key, self._cols, self._cidx)

    def _intern_col(self, key: str) -> int:
        return self._intern_one(key, self._ncols, self._nidx)

    # -------------------------------------------------------------- index
    def _merge_into_chains(self, lo: int, hi: int) -> None:
        """Merge the rows [lo, hi) into their per-chain sorted index:
        O(chunk) when a chunk extends its chains in time (the streaming
        cadence), O(chain) per out-of-order chain otherwise."""
        rows = np.arange(lo, hi, dtype=np.int64)
        key = (self._machine_code[lo:hi].astype(np.int64)
               * max(len(self._btypes), 1)
               + self._type_code[lo:hi])
        order = np.lexsort((rows, self._t[lo:hi], key))
        key_sorted = key[order]
        boundary = np.nonzero(np.diff(key_sorted))[0] + 1
        starts = np.concatenate([[0], boundary])
        ends = np.concatenate([boundary, [hi - lo]])
        nb = max(len(self._btypes), 1)
        for s, e in zip(starts, ends):
            k = int(key_sorted[s])
            m_code, b_code = k // nb, k % nb
            chain = self._chains.setdefault(m_code, {}).get(b_code)
            if chain is None:
                chain = _IntVec()
                self._chains[m_code][b_code] = chain
            new_rows = rows[order[s:e]]
            old = chain.view()
            if (len(old) == 0
                    or self._t[new_rows[0]] >= self._t[old[-1]]):
                chain.extend(new_rows)
            else:
                both = np.concatenate([old, new_rows])
                chain.replace(both[np.lexsort((both, self._t[both]))])

    def _rebuild_chains(self) -> None:
        self._chains = {}
        if self._n:
            self._merge_into_chains(0, self._n)

    # ------------------------------------------------------------ scoring
    def attach(self, idx: np.ndarray, anomaly: np.ndarray,
               codes: Optional[np.ndarray] = None) -> None:
        """Attach scores to rows (by current row *index*, not id)."""
        idx = np.asarray(idx)
        self._anomaly[idx] = np.asarray(anomaly, np.float32)
        if codes is not None:
            codes = np.asarray(codes, np.float32)
            if self._codes is None:
                self._codes = np.full((self._cap, codes.shape[1]),
                                      np.nan, np.float32)
            self._codes[idx] = codes

    # -------------------------------------------------------------- views
    def view(self, node: Optional[str] = None,
             benchmark_type: Optional[str] = None, *,
             t_min: Optional[float] = None,
             t_max: Optional[float] = None,
             before_id: Optional[int] = None,
             newest_per_chain: Optional[int] = None) -> np.ndarray:
        """Row indices (chronological, stable) of the selected
        executions: per-(node x benchmark type) chains filtered to a
        time window and/or rows appended before a given global row id
        (``before_id``, applied before the per-chain ``newest`` cap —
        "history as of that append") and/or the newest K rows per
        chain. Pure array gather — one slice + searchsorted/mask per
        selected chain."""
        if self._n == 0:
            return np.zeros(0, np.int64)
        if node is None:
            m_codes = sorted(self._chains)
        else:
            m_code = self._midx.get(node)
            if m_code is None:
                return np.zeros(0, np.int64)
            m_codes = [m_code]
        b_code = None
        if benchmark_type is not None:
            b_code = self._bidx.get(benchmark_type)
            if b_code is None:
                return np.zeros(0, np.int64)
        parts = []
        for mc in m_codes:
            for bc in sorted(self._chains.get(mc, {})):
                if b_code is not None and bc != b_code:
                    continue
                rows = self._chains[mc][bc].view()
                if t_min is not None or t_max is not None:
                    ts = self._t[rows]
                    lo = 0 if t_min is None else int(
                        np.searchsorted(ts, t_min, "left"))
                    hi = len(rows) if t_max is None else int(
                        np.searchsorted(ts, t_max, "right"))
                    rows = rows[lo:hi]
                if before_id is not None:
                    rows = rows[self._row_id[rows] < before_id]
                if newest_per_chain is not None:
                    rows = rows[max(len(rows) - newest_per_chain, 0):]
                parts.append(rows)
        if not parts:
            return np.zeros(0, np.int64)
        sel = np.concatenate(parts)
        return sel[np.lexsort((sel, self._t[sel]))]

    def context(self, node: str, per_chain: int) -> np.ndarray:
        """Scoring context for ``node``: the newest ``per_chain`` rows
        of each of its benchmark-type chains, chronological."""
        return self.view(node, newest_per_chain=per_chain)

    def context_with_new(self, first_id: int, per_chain: int,
                         node: Optional[str] = None
                         ) -> Tuple[np.ndarray, np.ndarray]:
        """THE scoring-context rule shared by the fleet service, the
        watchdog and the benchmarks: for rows appended at or after
        ``first_id`` ("the round"), assemble the newest ``per_chain``
        rows of every chain *as of before the round* plus every new
        row (of ``node`` only, when given), in chronological (t, row)
        order. Returns (row indices, is-new mask)."""
        if self._n == 0:
            return np.zeros(0, np.int64), np.zeros(0, bool)
        ctx = self.view(node, before_id=first_id,
                        newest_per_chain=per_chain)
        if self._ids_sorted:
            # never-compacted stores keep row_id sorted by row index:
            # the round's rows are a tail slice, found in O(log n)
            start = int(np.searchsorted(self.row_id, first_id, "left"))
            new = np.arange(start, self._n, dtype=np.int64)
        else:
            new = np.nonzero(self.row_id >= first_id)[0]
        if node is not None:
            m_code = self._midx.get(node, -1)
            new = new[self._machine_code[new] == m_code]
        idx = np.concatenate([ctx, new])
        idx = idx[np.lexsort((idx, self._t[idx]))]
        return idx, self._row_id[idx] >= first_id

    # ------------------------------------------------------------ compact
    def _select_inplace(self, idx: np.ndarray) -> None:
        """Rebuild the buffers around a row subset (ids preserved)."""
        n = len(idx)
        for name in ("_type_code", "_machine_code",
                     "_machine_type_code", "_t", "_stressed",
                     "_metrics", "_metrics_present", "_node_metrics",
                     "_node_metrics_present", "_row_id", "_anomaly"):
            setattr(self, name, getattr(self, name)[idx].copy())
        if self._codes is not None:
            self._codes = self._codes[idx].copy()
        if self._features is not None:
            self._features = {k: v[idx].copy()
                              for k, v in self._features.items()}
        self._n = n
        self._cap = n
        self._ids_sorted = bool(np.all(np.diff(self._row_id) >= 0))
        self._rebuild_chains()
        self._frame_cache = None

    def compact(self, per_chain: int) -> None:
        """Drop all but the newest ``per_chain`` rows of every chain
        (row ids are preserved). Bounds memory for long-running owners
        like the watchdog; the fleet service keeps the full history."""
        if self._n == 0:
            return
        self._select_inplace(self.view(newest_per_chain=per_chain))

    def clear(self) -> None:
        self.__init__()

    # ---------------------------------------------------------- save/load
    def save(self, path: str) -> None:
        """Durable one-file snapshot (compressed .npz). The write is
        atomic (:func:`atomic_savez`): a crash mid-save can never leave
        a corrupt or truncated snapshot behind."""
        f = self.frame
        if f is None:
            atomic_savez(path, empty=np.asarray(True),
                         next_id=np.asarray(self._next_id))
            return
        payload = {
            "empty": np.asarray(False),
            "next_id": np.asarray(self._next_id),
            "benchmark_types": np.asarray(f.benchmark_types),
            "machines": np.asarray(f.machines),
            "machine_types": np.asarray(f.machine_types),
            "metric_names": np.asarray(f.metric_names),
            "metric_units": np.asarray(f.metric_units),
            "node_metric_names": np.asarray(f.node_metric_names),
            "type_code": f.type_code, "machine_code": f.machine_code,
            "machine_type_code": f.machine_type_code,
            "t": f.t, "stressed": f.stressed,
            "metrics": f.metrics, "metrics_present": f.metrics_present,
            "node_metrics": f.node_metrics,
            "node_metrics_present": f.node_metrics_present,
            "row_id": self.row_id, "anomaly": self.anomaly,
        }
        if self._codes is not None:
            payload["codes"] = self.codes
        if self._features is not None:
            for k in FEATURE_KEYS:
                payload[f"feat_{k}"] = self.features[k]
        atomic_savez(path, **payload)

    @classmethod
    def load(cls, path: str) -> "FingerprintStore":
        with np.load(path, allow_pickle=False) as z:
            store = cls()
            store._next_id = int(z["next_id"])
            if bool(z["empty"]):
                return store

            def names(key):
                return [str(x) for x in z[key]]

            store._btypes = names("benchmark_types")
            store._machines = names("machines")
            store._mtypes = names("machine_types")
            store._cols = list(zip(names("metric_names"),
                                   names("metric_units")))
            store._ncols = names("node_metric_names")
            store._bidx = {b: i for i, b in enumerate(store._btypes)}
            store._midx = {m: i for i, m in enumerate(store._machines)}
            store._tidx = {m: i for i, m in enumerate(store._mtypes)}
            store._cidx = {c: i for i, c in enumerate(store._cols)}
            store._nidx = {k: i for i, k in enumerate(store._ncols)}
            store._type_code = z["type_code"].copy()
            store._machine_code = z["machine_code"].copy()
            store._machine_type_code = z["machine_type_code"].copy()
            store._t = z["t"].copy()
            store._stressed = z["stressed"].copy()
            store._metrics = z["metrics"].copy()
            store._metrics_present = z["metrics_present"].copy()
            store._node_metrics = z["node_metrics"].copy()
            store._node_metrics_present = \
                z["node_metrics_present"].copy()
            store._row_id = z["row_id"].copy()
            store._anomaly = z["anomaly"].copy()
            if "codes" in z.files:
                store._codes = z["codes"].copy()
            if f"feat_{FEATURE_KEYS[0]}" in z.files:
                store._features = {k: z[f"feat_{k}"].copy()
                                   for k in FEATURE_KEYS}
            store._has_features = store._features is not None
            store._n = store._cap = len(store._t)
            store._ids_sorted = bool(
                np.all(np.diff(store._row_id) >= 0))
            store._rebuild_chains()
            return store
