"""Append-only columnar fingerprint store (paper §III-C context).

Perona scores a new benchmark execution *against the history of
previous executions of the same node*; Karasu extends that history to
profiling data shared across users. Both need a durable, queryable
store whose context-assembly path is cheap at fleet traffic rates.

``FingerprintStore`` keeps executions as :class:`BenchmarkFrame`
chunks (consolidated lazily into one columnar frame), parallel
per-row arrays for global row ids and attached scores (anomaly
probability + fingerprint codes, NaN until scored), and an optional
per-row *feature cache* (the §III-B preprocessed columns produced by
``serving.engine.prepare_features``) so the fleet service never re-runs
Python-side preprocessing for context rows.

Views are pure array gathers: one lexsort over (machine, benchmark
type, t, row) yields contiguous per-chain index ranges, so
``view(node, benchmark_type, t_min=..., newest_per_chain=...)`` is a
slice + ``searchsorted`` per chain — no Python record filtering.
``save``/``load`` round-trip the whole store through one ``.npz`` file
for durability.

Scalability note: appends are O(chunk) until the next read, but the
lazy consolidation + index rebuild each touch the whole store, so an
append-read cadence (one flush per round) costs O(total rows) per
round. Owners that compact (the watchdog) are bounded; a never-
compacted fleet store grows linearly per flush — amortized growable
column buffers + incremental index merge are the known follow-up
(see ROADMAP).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.fingerprint.frame import (BenchmarkFrame, FrameOrRecords,
                                     as_frame, concat_frames)

FEATURE_KEYS = ("raw", "present", "type_ids", "edge_raw")


class FingerprintStore:
    """Append-only columnar store of scored benchmark executions."""

    def __init__(self):
        self._frame: Optional[BenchmarkFrame] = None
        self._row_id = np.zeros(0, np.int64)
        self._anomaly = np.zeros(0, np.float32)
        self._codes: Optional[np.ndarray] = None  # (N, K) once attached
        self._features: Optional[Dict[str, np.ndarray]] = None
        self._pending: List[dict] = []
        self._has_features: Optional[bool] = None  # set on first append
        self._next_id = 0
        self._index = None  # (order, {(m_code, b_code): (start, end)})

    # ------------------------------------------------------------- basics
    def __len__(self) -> int:
        n = 0 if self._frame is None else len(self._frame)
        return n + sum(len(c["frame"]) for c in self._pending)

    @property
    def frame(self) -> Optional[BenchmarkFrame]:
        """The consolidated columnar frame (None while empty)."""
        self._consolidate()
        return self._frame

    @property
    def row_id(self) -> np.ndarray:
        """(N,) monotonically increasing global row ids (append order);
        ids survive :meth:`compact`."""
        self._consolidate()
        return self._row_id

    @property
    def anomaly(self) -> np.ndarray:
        """(N,) attached anomaly probabilities (NaN until scored)."""
        self._consolidate()
        return self._anomaly

    @property
    def codes(self) -> Optional[np.ndarray]:
        """(N, K) attached fingerprint codes (NaN rows until scored)."""
        self._consolidate()
        return self._codes

    @property
    def features(self) -> Optional[Dict[str, np.ndarray]]:
        """Cached per-row preprocessed columns (see FEATURE_KEYS)."""
        self._consolidate()
        return self._features

    # ------------------------------------------------------------- append
    def append(self, data: FrameOrRecords,
               features: Optional[Dict[str, np.ndarray]] = None,
               anomaly: Optional[np.ndarray] = None,
               codes: Optional[np.ndarray] = None) -> int:
        """Append one chunk of executions; returns the first global row
        id of the chunk (ids are contiguous per chunk)."""
        frame = as_frame(data)
        n = len(frame)
        if n == 0:
            return self._next_id
        if self._has_features is None:
            self._has_features = features is not None
        elif self._has_features != (features is not None):
            raise ValueError(
                "cannot mix feature-cached and plain appends: the "
                "store either caches features for every row or none")
        first = self._next_id
        anom = (np.full(n, np.nan, np.float32) if anomaly is None
                else np.asarray(anomaly, np.float32))
        self._pending.append({
            "frame": frame,
            "row_id": np.arange(first, first + n, dtype=np.int64),
            "anomaly": anom,
            "codes": None if codes is None else np.asarray(codes,
                                                           np.float32),
            "features": features,
        })
        self._next_id += n
        self._index = None
        return first

    def _codes_like(self, n: int, k: int) -> np.ndarray:
        return np.full((n, k), np.nan, np.float32)

    def _consolidate(self) -> None:
        if not self._pending:
            return
        chunks = self._pending
        self._pending = []
        frames = ([] if self._frame is None else [self._frame])
        frames += [c["frame"] for c in chunks]
        self._frame = concat_frames(frames)
        self._row_id = np.concatenate(
            [self._row_id] + [c["row_id"] for c in chunks])
        self._anomaly = np.concatenate(
            [self._anomaly] + [c["anomaly"] for c in chunks])
        # codes: adopt K from the first scored chunk, NaN-fill the rest
        ks = [c["codes"].shape[1] for c in chunks
              if c["codes"] is not None]
        k = self._codes.shape[1] if self._codes is not None else (
            ks[0] if ks else None)
        if k is not None:
            parts = [self._codes if self._codes is not None
                     else self._codes_like(len(self._row_id)
                                           - sum(len(c["frame"])
                                                 for c in chunks), k)]
            for c in chunks:
                parts.append(c["codes"] if c["codes"] is not None
                             else self._codes_like(len(c["frame"]), k))
            self._codes = np.concatenate(parts)
        if any(c["features"] is not None for c in chunks):
            feats = self._features
            for c in chunks:
                f = c["features"]
                if feats is None:
                    feats = {key: np.asarray(f[key])
                             for key in FEATURE_KEYS}
                else:
                    feats = {key: np.concatenate(
                        [feats[key], np.asarray(f[key])])
                        for key in FEATURE_KEYS}
            self._features = feats
        self._index = None

    # ------------------------------------------------------------ scoring
    def attach(self, idx: np.ndarray, anomaly: np.ndarray,
               codes: Optional[np.ndarray] = None) -> None:
        """Attach scores to rows (by current row *index*, not id)."""
        self._consolidate()
        idx = np.asarray(idx)
        self._anomaly[idx] = np.asarray(anomaly, np.float32)
        if codes is not None:
            codes = np.asarray(codes, np.float32)
            if self._codes is None:
                self._codes = self._codes_like(len(self._row_id),
                                               codes.shape[1])
            self._codes[idx] = codes

    # -------------------------------------------------------------- views
    def _ensure_index(self):
        self._consolidate()
        if self._index is not None or self._frame is None:
            return
        f = self._frame
        n = len(f)
        n_types = max(len(f.benchmark_types), 1)
        key = f.machine_code.astype(np.int64) * n_types + f.type_code
        order = np.lexsort((np.arange(n), f.t, key))
        key_sorted = key[order]
        boundary = np.ones(n, bool)
        boundary[1:] = key_sorted[1:] != key_sorted[:-1]
        starts = np.where(boundary)[0]
        ends = np.append(starts[1:], n)
        # chains grouped per machine so view(node) touches only that
        # node's chain ranges
        chains: Dict[int, List[Tuple[int, int, int]]] = {}
        for s, e in zip(starts, ends):
            k = int(key_sorted[s])
            chains.setdefault(k // n_types, []).append(
                (k % n_types, int(s), int(e)))
        self._index = (order, chains)

    def _code_of(self, vocab: Tuple[str, ...], name: Optional[str]):
        if name is None:
            return None
        try:
            return vocab.index(name)
        except ValueError:
            return -1  # unknown name -> empty view

    def view(self, node: Optional[str] = None,
             benchmark_type: Optional[str] = None, *,
             t_min: Optional[float] = None,
             t_max: Optional[float] = None,
             before_id: Optional[int] = None,
             newest_per_chain: Optional[int] = None) -> np.ndarray:
        """Row indices (chronological, stable) of the selected
        executions: per-(node x benchmark type) chains filtered to a
        time window and/or rows appended before a given global row id
        (``before_id``, applied before the per-chain ``newest`` cap —
        "history as of that append") and/or the newest K rows per
        chain. Pure array gather — one slice + searchsorted/mask per
        selected chain."""
        self._ensure_index()
        if self._frame is None:
            return np.zeros(0, np.int64)
        f = self._frame
        order, chains = self._index
        m_code = self._code_of(f.machines, node)
        b_code = self._code_of(f.benchmark_types, benchmark_type)
        if m_code == -1 or b_code == -1:
            return np.zeros(0, np.int64)
        if m_code is None:
            selected = [c for per in chains.values() for c in per]
        else:
            selected = chains.get(m_code, [])
        parts = []
        for bc, s, e in selected:
            if b_code is not None and bc != b_code:
                continue
            rows = order[s:e]
            if t_min is not None or t_max is not None:
                ts = f.t[rows]
                lo = 0 if t_min is None else int(
                    np.searchsorted(ts, t_min, "left"))
                hi = len(rows) if t_max is None else int(
                    np.searchsorted(ts, t_max, "right"))
                rows = rows[lo:hi]
            if before_id is not None:
                rows = rows[self._row_id[rows] < before_id]
            if newest_per_chain is not None:
                rows = rows[max(len(rows) - newest_per_chain, 0):]
            parts.append(rows)
        if not parts:
            return np.zeros(0, np.int64)
        sel = np.concatenate(parts)
        return sel[np.lexsort((sel, f.t[sel]))]

    def context(self, node: str, per_chain: int) -> np.ndarray:
        """Scoring context for ``node``: the newest ``per_chain`` rows
        of each of its benchmark-type chains, chronological."""
        return self.view(node, newest_per_chain=per_chain)

    def context_with_new(self, first_id: int, per_chain: int,
                         node: Optional[str] = None
                         ) -> Tuple[np.ndarray, np.ndarray]:
        """THE scoring-context rule shared by the fleet service, the
        watchdog and the benchmarks: for rows appended at or after
        ``first_id`` ("the round"), assemble the newest ``per_chain``
        rows of every chain *as of before the round* plus every new
        row (of ``node`` only, when given), in chronological (t, row)
        order. Returns (row indices, is-new mask)."""
        self._consolidate()
        if self._frame is None:
            return np.zeros(0, np.int64), np.zeros(0, bool)
        ctx = self.view(node, before_id=first_id,
                        newest_per_chain=per_chain)
        new = np.nonzero(self._row_id >= first_id)[0]
        if node is not None:
            m_code = self._code_of(self._frame.machines, node)
            new = new[self._frame.machine_code[new] == m_code]
        idx = np.concatenate([ctx, new])
        idx = idx[np.lexsort((idx, self._frame.t[idx]))]
        return idx, self._row_id[idx] >= first_id

    # ------------------------------------------------------------ compact
    def _select_inplace(self, idx: np.ndarray) -> None:
        self._frame = self._frame.select(idx)
        self._row_id = self._row_id[idx]
        self._anomaly = self._anomaly[idx]
        if self._codes is not None:
            self._codes = self._codes[idx]
        if self._features is not None:
            self._features = {k: v[idx]
                              for k, v in self._features.items()}
        self._index = None

    def compact(self, per_chain: int) -> None:
        """Drop all but the newest ``per_chain`` rows of every chain
        (row ids are preserved). Bounds memory for long-running owners
        like the watchdog; the fleet service keeps the full history."""
        self._consolidate()
        if self._frame is None:
            return
        self._select_inplace(self.view(newest_per_chain=per_chain))

    def clear(self) -> None:
        self.__init__()

    # ---------------------------------------------------------- save/load
    def save(self, path: str) -> None:
        """Durable one-file snapshot (compressed .npz)."""
        self._consolidate()
        f = self._frame
        if f is None:
            np.savez_compressed(path, empty=np.asarray(True),
                                next_id=np.asarray(self._next_id))
            return
        payload = {
            "empty": np.asarray(False),
            "next_id": np.asarray(self._next_id),
            "benchmark_types": np.asarray(f.benchmark_types),
            "machines": np.asarray(f.machines),
            "machine_types": np.asarray(f.machine_types),
            "metric_names": np.asarray(f.metric_names),
            "metric_units": np.asarray(f.metric_units),
            "node_metric_names": np.asarray(f.node_metric_names),
            "type_code": f.type_code, "machine_code": f.machine_code,
            "machine_type_code": f.machine_type_code,
            "t": f.t, "stressed": f.stressed,
            "metrics": f.metrics, "metrics_present": f.metrics_present,
            "node_metrics": f.node_metrics,
            "node_metrics_present": f.node_metrics_present,
            "row_id": self._row_id, "anomaly": self._anomaly,
        }
        if self._codes is not None:
            payload["codes"] = self._codes
        if self._features is not None:
            for k in FEATURE_KEYS:
                payload[f"feat_{k}"] = self._features[k]
        np.savez_compressed(path, **payload)

    @classmethod
    def load(cls, path: str) -> "FingerprintStore":
        with np.load(path, allow_pickle=False) as z:
            store = cls()
            store._next_id = int(z["next_id"])
            if bool(z["empty"]):
                return store

            def names(key):
                return tuple(str(x) for x in z[key])

            store._frame = BenchmarkFrame(
                benchmark_types=names("benchmark_types"),
                machines=names("machines"),
                machine_types=names("machine_types"),
                metric_names=names("metric_names"),
                metric_units=names("metric_units"),
                node_metric_names=names("node_metric_names"),
                type_code=z["type_code"],
                machine_code=z["machine_code"],
                machine_type_code=z["machine_type_code"],
                t=z["t"], stressed=z["stressed"],
                metrics=z["metrics"],
                metrics_present=z["metrics_present"],
                node_metrics=z["node_metrics"],
                node_metrics_present=z["node_metrics_present"])
            store._row_id = z["row_id"]
            store._anomaly = z["anomaly"]
            if "codes" in z.files:
                store._codes = z["codes"]
            if f"feat_{FEATURE_KEYS[0]}" in z.files:
                store._features = {k: z[f"feat_{k}"]
                                   for k in FEATURE_KEYS}
            store._has_features = store._features is not None
            return store
