"""Sharded fleet scoring: one compiled dispatch across all devices.

A fleet re-fingerprinting round is a stack of *independent* per-node
scoring requests (paper §III-C scores each execution only against the
predecessors of its own (node x benchmark type) chain, so request
graphs never cross shard boundaries). ``ShardedScorer`` therefore
partitions the stacked request batch (leading axis R) across a 1-D
``"fleet"`` device mesh with ``jax.experimental.shard_map`` and runs
the *same* pure scoring function as ``serving.FingerprintEngine``
(``make_score_fn``) vmapped over each device's local requests — one
jit-compiled, donation-enabled dispatch per flush, scaling with device
count.

Verifiable on CPU: run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` and compare
against a single-device scorer — the partitioning is along the request
axis only, so the sharded scores are bit-identical
(``tests/test_fleet.py``).
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence

import numpy as np

from repro.common.mesh import (axis_specs, build_mesh, shard_map_1d,
                               shard_size)
from repro.core.model import PeronaModel
from repro.core.preprocess import Preprocessor
from repro.obs.jaxstat import JitSite, instance_site
from repro.serving.engine import ARG_NAMES, make_score_fn


class ShardedScorer:
    """shard_map(vmap(score_fn)) over a 1-D device mesh."""

    def __init__(self, model: PeronaModel, preproc: Preprocessor,
                 devices: Optional[Sequence] = None):
        import jax
        from jax.sharding import PartitionSpec as P

        self.mesh = build_mesh("fleet", devices)
        self.n_devices = self.mesh.devices.size
        # per-instance jit accounting on the obs registry
        self.jit = JitSite(instance_site("fleet.scorer"))

        fn = make_score_fn(model, preproc, on_trace=self.jit.tick)
        vmapped = jax.vmap(fn, in_axes=(None,) + (0,) * len(ARG_NAMES))
        sharded = shard_map_1d(
            vmapped, self.mesh,
            in_specs=axis_specs("fleet", len(ARG_NAMES), n_const=1),
            out_specs=P("fleet"))
        # stacked request buffers are rebuilt per flush: donate them
        self.donate_argnums = tuple(range(1, 1 + len(ARG_NAMES)))
        self._call = jax.jit(sharded,
                             donate_argnums=self.donate_argnums)

    @property
    def trace_count(self) -> int:
        """jit tracings so far (1 per distinct (R, bucket) shape)."""
        return self.jit.count

    def pad_requests(self, n_requests: int) -> int:
        """Power-of-two request-axis size, divisible by the mesh."""
        return shard_size(n_requests, self.n_devices)

    def score_stack(self, params, stack: Dict[str, np.ndarray]
                    ) -> Dict[str, np.ndarray]:
        """Score a stacked request batch: every array in ``stack`` has
        leading axis R (a multiple of the device count; see
        :meth:`pad_requests`) then the per-request padded row bucket.
        Returns numpy outputs with the same leading axes."""
        import jax.numpy as jnp

        from repro.serving.engine import silence_unusable_donation

        r = stack[ARG_NAMES[0]].shape[0]
        if r % self.n_devices:
            raise ValueError(
                f"request axis {r} not divisible by the "
                f"{self.n_devices}-device fleet mesh; pad with "
                "pad_requests() first")
        with silence_unusable_donation(), \
                self.jit.dispatch(
                    "fleet.score_stack",
                    args={"requests": r,
                          "bucket": stack[ARG_NAMES[0]].shape[1]}):
            out = self._call(params,
                             *(jnp.asarray(stack[k])
                               for k in ARG_NAMES))
        return {k: np.asarray(v) for k, v in out.items()}
