"""Long-lived streaming ingestion daemon over the fleet service.

``IngestionDaemon`` turns the closed-loop :class:`FleetScoringService`
into a production pipeline: telemetry events (per-node benchmark
rounds, see :class:`repro.fleet.faults.TelemetryEvent`) arrive by push
(:meth:`push`) or from poll sources, are deduplicated, validated and
staged in a **bounded ring buffer**, and are flushed through the
service on either of two triggers — a time deadline (no staged row
waits longer than ``flush_interval``) or the row threshold
(``flush_rows``, a power of two so flushes land on the service's
pow2 row buckets). Per-flush results fold into an **incremental**
:class:`repro.fleet.drift.RollingDrift` (O(new rows) per flush — no
full-history recompute), so degradation flags are always current.

Backpressure ladder (explicit, counted, in escalation order):

1. **block** — an arrival that would overflow the ring forces an
   immediate flush (the producer blocks until the consumer drains);
   counted in ``blocked_events`` / ``forced_flushes``.
2. **shed oldest per chain** — if the consumer is not allowed to run
   yet (``min_flush_gap`` models scorer capacity), the oldest staged
   rows of every (node x benchmark type) chain are dropped down to the
   largest per-chain depth that fits; newest telemetry survives.
   Counted in ``shed_rows``.
3. **degrade to sampled scoring** — sustained overload (``degrade_after``
   block/shed incidents within one flush window) switches flushes to
   scoring only the newest ``degrade_sample_per_chain`` rows per chain;
   the rest are still appended to the store (durable, usable as
   context) but unscored. ``recover_after`` consecutive clean windows
   exit degraded mode. Counted in ``degraded_flushes`` /
   ``degrade_unscored_rows``.

The daemon runs on an explicit clock. :meth:`run` drives it from an
event list in *virtual time*: the clock advances to each arrival, and
every flush advances it further by the **measured** wall-clock scoring
duration (``service_time_scale``) — so queue latencies (and their p99)
reflect real consumer capacity under the injected arrival process,
reproducibly. :meth:`serve` runs the same loop against the wall clock
in a background thread for live deployments (``launch.serve --daemon``).

Shutdown is crash-safe: :meth:`close` either drains (flushes every
staged row through the scorer) or checkpoints the staging buffer to an
atomically-written ``.npz`` (:func:`repro.fleet.store.atomic_savez`);
:func:`load_staging` restores the checkpoint as events for a fresh
daemon, so no accepted telemetry is ever lost to a restart.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.common.bucketing import next_pow2
from repro.fingerprint.frame import BenchmarkFrame, concat_frames
from repro.fleet.drift import RollingDrift, degrading_nodes
from repro.fleet.faults import TelemetryEvent
from repro.fleet.store import atomic_savez
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.obs.jaxstat import instance_site


@dataclasses.dataclass
class _Staged:
    """One staged telemetry event (rows may shrink under shedding)."""

    uid: int
    node: str
    arrival: float  # event arrival time (queue-latency origin)
    staged_at: float  # time it entered the ring (deadline origin)
    frame: BenchmarkFrame


class IngestionDaemon:
    """Bounded-staging streaming front-end of the fleet service."""

    def __init__(self, service, *,
                 capacity_rows: int = 1024,
                 flush_interval: float = 60.0,
                 flush_rows: Optional[int] = None,
                 min_flush_gap: float = 0.0,
                 degrade_after: int = 3,
                 recover_after: int = 2,
                 degrade_sample_per_chain: int = 1,
                 service_time_scale: float = 1.0,
                 drift_alpha: float = 0.3,
                 dedup_window: int = 4096,
                 max_latencies: int = 100_000,
                 tracer: Optional[obs_trace.Tracer] = None):
        if capacity_rows <= 0:
            raise ValueError("capacity_rows must be positive")
        self.service = service
        self.capacity_rows = capacity_rows
        self.flush_interval = flush_interval
        # row trigger: a power of two <= capacity, aligned with the
        # service's pow2 row buckets so full flushes pad minimally
        self.flush_rows = (next_pow2(max(capacity_rows // 2, 1), 1)
                           if flush_rows is None else flush_rows)
        self.min_flush_gap = min_flush_gap
        self.degrade_after = degrade_after
        self.recover_after = recover_after
        self.degrade_sample_per_chain = degrade_sample_per_chain
        self.service_time_scale = service_time_scale
        self.drift = RollingDrift(alpha=drift_alpha)
        self.now = 0.0
        self._staged: List[_Staged] = []
        self._staged_rows = 0
        self._last_flush = 0.0
        self._seen_uids: set = set()
        self._uid_order: collections.deque = collections.deque(
            maxlen=dedup_window)
        self._next_push_uid = -1  # push() uids count down: no clash
        self._lock = threading.RLock()
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._sources: List[Callable[[float],
                                     Sequence[TelemetryEvent]]] = []
        # flush hooks: called under the lock right after scoring, and
        # BEFORE the results feed rolling drift / the results log —
        # a hook may mutate the results dict in place (the model
        # plane's rollback repair swaps bad-candidate scores for the
        # incumbent's before anything downstream sees them)
        self._flush_hooks: List[Callable[[Dict[str, object], str],
                                         None]] = []
        self._results: Dict[str, List] = {}
        self._closed = False
        self.degraded = False
        self._overload_in_window = 0
        self._clean_windows = 0
        # counters (all exposed via stats())
        self._events_seen = 0
        self._events_accepted = 0
        self._rows_staged_total = 0
        self._duplicates_dropped = 0
        self._blocked_events = 0
        self._forced_flushes = 0
        self._deadline_flushes = 0
        self._row_trigger_flushes = 0
        self._drain_flushes = 0
        self._shed_rows = 0
        self._degraded_flushes = 0
        self._degrade_unscored_rows = 0
        self._degrade_entries = 0
        self._recoveries = 0
        self._flush_failures = 0
        self._peak_staged_rows = 0
        self._flush_wall_s = 0.0
        self._run_wall_s = 0.0
        # --- telemetry plane ---------------------------------------
        # The daemon owns a tracer on its OWN clock (``self.now``):
        # virtual time under run(), wall time under serve() — flush
        # spans and ladder instants line up with the latencies the
        # daemon reports in either mode. Program-logic counters above
        # stay plain ints (they must survive obs.disable()); the
        # registry rows below are observability mirrors, delta-synced
        # at flush boundaries (``_sync_mirrors``) so intake itself
        # never pays per-event registry cost.
        self.site = instance_site("fleet.ingest")
        self.tracer = (tracer if tracer is not None
                       else obs_trace.Tracer(clock=lambda: self.now))
        reg = obs_metrics.registry()
        self._m_events = reg.counter("ingest.events_seen",
                                     daemon=self.site)
        self._m_accepted = reg.counter("ingest.events_accepted",
                                       daemon=self.site)
        self._m_rows = reg.counter("ingest.rows_staged",
                                   daemon=self.site)
        self._m_dups = reg.counter("ingest.duplicates_dropped",
                                   daemon=self.site)
        self._m_flushes = reg.counter("ingest.flushes",
                                      daemon=self.site)
        self._m_ladder = {
            step: reg.counter("ingest.ladder", step=step,
                              daemon=self.site)
            for step in ("block", "shed", "degrade", "recover")}
        # queue latency (arrival -> scoring flush) through the shared
        # streaming histogram: exact np.quantile semantics up to
        # ``max_latencies`` samples (the old deque window), O(1)
        # log-bucket memory beyond
        self._latency = reg.histogram("ingest.queue_latency_s",
                                      exact_limit=max_latencies,
                                      daemon=self.site)

    # ------------------------------------------------------------- intake
    def push(self, frame: BenchmarkFrame, *, now: Optional[float] = None,
             node: str = "", uid: Optional[int] = None) -> bool:
        """Push-mode intake of one telemetry frame; returns False when
        the row was dropped (duplicate) rather than staged. Thread-safe
        (the live-serving producer API)."""
        with self._lock:
            t = self.now if now is None else now
            if uid is None:
                uid = self._next_push_uid
                self._next_push_uid -= 1
            return self.offer(TelemetryEvent(uid=uid, node=node,
                                             arrival=t, frame=frame),
                              now=t)

    def attach_source(self, poll: Callable[[float],
                                           Sequence[TelemetryEvent]]
                      ) -> None:
        """Register a poll source: ``poll(now)`` returns the events
        that arrived since the last poll (drained by :meth:`serve`'s
        loop or an explicit :meth:`poll_sources`)."""
        self._sources.append(poll)

    def poll_sources(self, now: Optional[float] = None) -> int:
        """Drain every attached poll source once; returns the number
        of events offered."""
        with self._lock:
            t = self.now if now is None else now
            n = 0
            for poll in self._sources:
                for ev in poll(t):
                    self.offer(ev, now=max(t, ev.arrival))
                    n += 1
            return n

    def offer(self, event: TelemetryEvent, *,
              now: Optional[float] = None) -> bool:
        """Admit one event: dedup -> validate/quarantine -> stage,
        escalating the backpressure ladder when the ring is full.
        Returns True when (any part of) the event was staged."""
        with self._lock:
            if self._closed:
                raise RuntimeError("daemon is closed")
            if now is not None:
                self.now = max(self.now, now)
            self._events_seen += 1
            if event.uid in self._seen_uids:
                self._duplicates_dropped += 1
                return False
            self._remember_uid(event.uid)
            # validation/quarantine is the service's (shared policy +
            # counters); corrupt rows never enter the ring
            frame = self.service._admit(event.frame)
            if len(frame) == 0:
                return False
            n = len(frame)
            if self._staged_rows + n > self.capacity_rows:
                self._make_room(n)
            if self._staged_rows + n > self.capacity_rows:
                # ladder step 2: shed oldest-per-chain (incoming rows
                # participate — a flood bigger than the ring sheds too)
                frame = self._shed(frame)
                n = len(frame)
                if n == 0:
                    return False
            self._staged.append(_Staged(uid=event.uid, node=event.node,
                                        arrival=event.arrival,
                                        staged_at=self.now,
                                        frame=frame))
            self._staged_rows += n
            self._rows_staged_total += n
            self._events_accepted += 1
            self._peak_staged_rows = max(self._peak_staged_rows,
                                         self._staged_rows)
            return True

    def _sync_mirrors(self) -> None:
        """Fold the plain program-logic counters into their registry
        mirrors (delta since the last sync). Runs at flush boundaries
        only, so per-event intake pays zero registry cost — the <2%
        telemetry-overhead budget ``bench_fleet`` asserts."""
        if not obs_metrics.enabled():
            return
        for mirror, total in (
                (self._m_events, self._events_seen),
                (self._m_accepted, self._events_accepted),
                (self._m_rows, self._rows_staged_total),
                (self._m_dups, self._duplicates_dropped)):
            delta = total - int(mirror.value)
            if delta:
                mirror.add(delta)

    def _remember_uid(self, uid: int) -> None:
        if (self._uid_order.maxlen is not None
                and len(self._uid_order) == self._uid_order.maxlen):
            self._seen_uids.discard(self._uid_order[0])
        self._uid_order.append(uid)
        self._seen_uids.add(uid)

    # -------------------------------------------------------- backpressure
    def _make_room(self, n: int) -> None:
        """Ladder step 1 (block): the producer waits for a flush —
        unless the consumer gap says the scorer is still busy."""
        if self.now - self._last_flush >= self.min_flush_gap:
            self._blocked_events += 1
            self._forced_flushes += 1
            self._m_ladder["block"].inc()
            self.tracer.instant("ladder.block", obs_trace.CAT_LADDER,
                                args={"staged_rows": self._staged_rows,
                                      "incoming": n},
                                ts=self.now)
            self._note_overload()
            self._flush(trigger="forced")

    def _shed(self, incoming: BenchmarkFrame) -> BenchmarkFrame:
        """Drop the oldest staged rows of every (node x benchmark
        type) chain down to the deepest uniform per-chain depth that
        fits ``incoming`` into the ring; the incoming frame itself is
        shed by the same rule if it alone exceeds capacity."""
        self._note_overload()
        # per-row chain keys: (node name, benchmark type name)
        keys: List[Tuple[str, str]] = []
        owners: List[int] = []
        ts: List[float] = []
        all_staged = self._staged + [
            _Staged(uid=0, node="", arrival=self.now,
                    staged_at=self.now, frame=incoming)]
        for i, s in enumerate(all_staged):
            f = s.frame
            node_of_row = (s.node if i < len(self._staged) else None)
            for j in range(len(f)):
                node = (node_of_row if node_of_row
                        else f.machines[f.machine_code[j]])
                keys.append((node, f.benchmark_types[f.type_code[j]]))
                owners.append(i)
                ts.append(float(f.t[j]))
        order = np.lexsort((np.arange(len(ts)), np.asarray(ts)))
        # newest-rank per chain: rank 0 = newest row of its chain
        rank: Dict[Tuple[str, str], int] = {}
        newest_rank = np.empty(len(ts), np.int64)
        for pos in order[::-1]:
            k = keys[pos]
            newest_rank[pos] = rank.get(k, 0)
            rank[k] = newest_rank[pos] + 1
        # deepest uniform per-chain depth that fits the ring
        keep_depth = 0
        for depth in range(1, max(rank.values(), default=0) + 1):
            if int((newest_rank < depth).sum()) <= self.capacity_rows:
                keep_depth = depth
            else:
                break
        keep = newest_rank < max(keep_depth, 1)
        if int(keep.sum()) > self.capacity_rows:
            # even one row per chain exceeds the ring: keep the
            # globally newest rows only
            newest_global = np.zeros(len(ts), bool)
            newest_global[order[-self.capacity_rows:]] = True
            keep &= newest_global
        n_shed = int((~keep).sum())
        self._shed_rows += n_shed
        self._m_ladder["shed"].inc()
        self.tracer.instant("ladder.shed", obs_trace.CAT_LADDER,
                            args={"rows": n_shed}, ts=self.now)
        owners_arr = np.asarray(owners)
        kept_staged: List[_Staged] = []
        rows_after = 0
        out_incoming = incoming.select(np.zeros(0, np.int64))
        for i, s in enumerate(all_staged):
            mask = keep[owners_arr == i]
            if mask.all():
                sub = s.frame
            else:
                sub = s.frame.select(np.nonzero(mask)[0])
            if i < len(self._staged):
                if len(sub):
                    kept_staged.append(
                        dataclasses.replace(s, frame=sub))
                    rows_after += len(sub)
            else:
                out_incoming = sub
        self._staged = kept_staged
        self._staged_rows = rows_after
        return out_incoming

    def _note_overload(self) -> None:
        self._overload_in_window += 1
        self._clean_windows = 0
        if (not self.degraded
                and self._overload_in_window >= self.degrade_after):
            self.degraded = True
            self._degrade_entries += 1
            self._m_ladder["degrade"].inc()
            self.tracer.instant(
                "ladder.degrade", obs_trace.CAT_LADDER,
                args={"overloads": self._overload_in_window},
                ts=self.now)

    # -------------------------------------------------------------- flush
    def _deadline(self) -> Optional[float]:
        if not self._staged:
            return None
        return min(s.staged_at for s in self._staged) \
            + self.flush_interval

    def advance(self, t: float) -> None:
        """Advance the clock to ``t``, firing every deadline flush
        that comes due on the way (the poll/epoch driver)."""
        with self._lock:
            while True:
                deadline = self._deadline()
                if deadline is None or deadline > t:
                    break
                self.now = max(self.now, deadline)
                self._deadline_flushes += 1
                self._end_window()
                self._flush(trigger="deadline")
            self.now = max(self.now, t)

    def _end_window(self) -> None:
        """A flush window closed: decay or clear the overload state
        (hysteresis so degraded mode doesn't flap)."""
        if self._overload_in_window == 0:
            self._clean_windows += 1
            if self.degraded and self._clean_windows >= self.recover_after:
                self.degraded = False
                self._recoveries += 1
                self._m_ladder["recover"].inc()
                self.tracer.instant(
                    "ladder.recover", obs_trace.CAT_LADDER,
                    args={"clean_windows": self._clean_windows},
                    ts=self.now)
        self._overload_in_window = 0

    def flush(self) -> Dict[str, object]:
        """Flush the staging ring through the service now (manual
        trigger); returns the per-node results of this flush."""
        with self._lock:
            return self._flush(trigger="manual")

    def add_flush_hook(self, fn: Callable[[Dict[str, object], str],
                                          None]) -> None:
        """Register a post-scoring hook ``fn(results, trigger)``, run
        under the daemon lock before the results reach rolling drift
        or the results log (so it may repair them in place). The model
        plane's canary/watch state machine attaches here — hooks run
        at every flush boundary, the only place parameter swaps
        happen."""
        self._flush_hooks.append(fn)

    def _flush(self, trigger: str) -> Dict[str, object]:
        staged, self._staged = self._staged, []
        self._staged_rows = 0
        if not staged:
            self._last_flush = self.now
            return {}
        t0 = time.perf_counter()
        start_now = self.now
        n_rows = sum(len(s.frame) for s in staged)
        was_degraded = self.degraded
        self._latency.observe_many(
            [self.now - s.arrival for s in staged])
        staged.sort(key=lambda s: float(s.frame.t.min()))
        try:
            if self.degraded:
                self._degraded_flushes += 1
                results = self._flush_degraded(staged)
            else:
                for s in staged:
                    # pre-validated at intake: don't pay validation
                    # twice
                    if len(s.frame):
                        self.service._pending.append(s.frame)
                results = self.service.flush()
        except Exception as e:  # noqa: BLE001 — pipeline must survive
            # the service already retried transient scorer failures
            # with backoff (``dispatch_retries``); a terminal failure
            # loses this flush's scores, not the pipeline: the rows
            # are already durable in the store (unscored context) and
            # the daemon keeps consuming the stream
            self._flush_failures += 1
            self.tracer.instant("ingest.flush_failed",
                                obs_trace.CAT_LADDER,
                                args={"trigger": trigger,
                                      "rows": n_rows,
                                      "error": type(e).__name__},
                                ts=self.now)
            results = {}
        for hook in self._flush_hooks:
            hook(results, trigger)
        dt = time.perf_counter() - t0
        self._flush_wall_s += dt
        self.now += dt * self.service_time_scale
        self._last_flush = self.now
        self._m_flushes.inc()
        self._sync_mirrors()
        # the span lives in the daemon's clock domain: under run() its
        # duration is the *virtual* service time this flush consumed
        self.tracer.complete("ingest.flush", obs_trace.CAT_HOST,
                             ts=start_now, dur=self.now - start_now,
                             args={"trigger": trigger, "rows": n_rows,
                                   "events": len(staged),
                                   "degraded": was_degraded})
        self.drift.update(self.service.store, results)
        for node, r in results.items():
            self._results.setdefault(node, []).append(r)
        return results

    def _flush_degraded(self, staged: Sequence[_Staged]):
        """Degraded flush: score only the newest
        ``degrade_sample_per_chain`` rows of every (node x type) chain
        in this batch; the remaining rows are appended to the store
        unscored (durable + future context, no scoring cost)."""
        frame = (concat_frames([s.frame for s in staged])
                 if len(staged) > 1 else staged[0].frame)
        key = (frame.machine_code.astype(np.int64)
               * max(len(frame.benchmark_types), 1)
               + frame.type_code)
        order = np.lexsort((np.arange(len(frame)), frame.t))
        rank: Dict[int, int] = {}
        newest_rank = np.empty(len(frame), np.int64)
        for pos in order[::-1]:
            k = int(key[pos])
            newest_rank[pos] = rank.get(k, 0)
            rank[k] = newest_rank[pos] + 1
        sample = newest_rank < self.degrade_sample_per_chain
        rest = np.nonzero(~sample)[0]
        if len(rest):
            self.service.seed_history(frame.select(rest))
            self._degrade_unscored_rows += len(rest)
        sampled = frame.select(np.nonzero(sample)[0])
        if len(sampled) == 0:
            return {}
        self.service._pending.append(sampled)
        return self.service.flush()

    # ---------------------------------------------------------- run loops
    def run(self, events: Sequence[TelemetryEvent], *,
            drain: bool = True) -> Dict[str, List]:
        """Virtual-time event loop: replay ``events`` (arrival order)
        against the measured-service-time clock. Deadline flushes fire
        at their due times between arrivals; the row trigger fires the
        moment staging reaches ``flush_rows``. Returns all per-node
        results accumulated so far."""
        wall0 = time.perf_counter()
        with self._lock:
            for ev in events:
                self.advance(ev.arrival)
                self.offer(ev, now=ev.arrival)
                if self._staged_rows >= self.flush_rows:
                    self._row_trigger_flushes += 1
                    self._end_window()
                    self._flush(trigger="rows")
            if drain and self._staged:
                self._drain_flushes += 1
                self._end_window()
                self._flush(trigger="drain")
        self._run_wall_s += time.perf_counter() - wall0
        return dict(self._results)

    def serve(self, poll_interval: float = 0.05) -> None:
        """Start the wall-clock daemon thread: polls attached sources
        and fires deadline/row-trigger flushes until :meth:`close`."""
        if self._thread is not None:
            raise RuntimeError("daemon thread already running")
        self._stop.clear()
        t_start = time.monotonic()

        def loop():
            while not self._stop.is_set():
                now = time.monotonic() - t_start
                with self._lock:
                    self.poll_sources(now)
                    if self._staged_rows >= self.flush_rows:
                        self._row_trigger_flushes += 1
                        self._end_window()
                        self._flush(trigger="rows")
                    else:
                        self.advance(now)
                self._stop.wait(poll_interval)

        self._thread = threading.Thread(target=loop,
                                        name="perona-ingest",
                                        daemon=True)
        self._thread.start()

    # ----------------------------------------------------------- shutdown
    def close(self, *, drain: bool = True,
              checkpoint: Optional[str] = None) -> Dict[str, object]:
        """Crash-safe shutdown: stop the serve thread (if running),
        then either drain staged rows through the scorer or checkpoint
        them (atomic .npz) for :func:`load_staging`. Safe to call
        twice."""
        if self._thread is not None:
            self._stop.set()
            self._thread.join(timeout=10.0)
            self._thread = None
        with self._lock:
            if self._closed:
                return {}
            results = {}
            if drain and self._staged:
                self._drain_flushes += 1
                results = self._flush(trigger="drain")
            elif checkpoint is not None and self._staged:
                save_staging(checkpoint, self._staged)
                self._staged = []
                self._staged_rows = 0
            self._closed = True
            return results

    # -------------------------------------------------------------- stats
    def results(self) -> Dict[str, List]:
        """All per-node flush results observed so far."""
        return dict(self._results)

    def flagged_nodes(self, ewma_threshold: float = 0.5,
                      min_scored: int = 3) -> List[str]:
        """Nodes whose rolling anomaly EWMA currently exceeds the
        threshold (the daemon-side §III-D degradation flag)."""
        return sorted(degrading_nodes(self.drift.report(),
                                      ewma_threshold=ewma_threshold,
                                      min_scored=min_scored))

    def latency_quantiles(self, qs: Sequence[float] = (0.5, 0.99)
                          ) -> Dict[str, float]:
        """Queue-latency quantiles (seconds between event arrival and
        the flush that scored it), read from the shared streaming
        histogram: exact ``np.quantile`` over the samples while under
        ``max_latencies`` observations, log-bucket estimates beyond."""
        return self._latency.quantiles(qs)

    def stats(self) -> obs_metrics.StatsDict:
        out = {
            "events_seen": self._events_seen,
            "events_accepted": self._events_accepted,
            "rows_staged_total": self._rows_staged_total,
            "staged_rows": self._staged_rows,
            "capacity_rows": self.capacity_rows,
            "peak_staged_rows": self._peak_staged_rows,
            "duplicates_dropped": self._duplicates_dropped,
            "blocked_events": self._blocked_events,
            "forced_flushes": self._forced_flushes,
            "deadline_flushes": self._deadline_flushes,
            "row_trigger_flushes": self._row_trigger_flushes,
            "drain_flushes": self._drain_flushes,
            "shed_rows": self._shed_rows,
            "degraded": self.degraded,
            "degrade_entries": self._degrade_entries,
            "degraded_flushes": self._degraded_flushes,
            "degrade_unscored_rows": self._degrade_unscored_rows,
            "recoveries": self._recoveries,
            "flush_failures": self._flush_failures,
            "scorer_retries": getattr(self.service,
                                      "_scorer_retries", 0),
            "flush_wall_s": self._flush_wall_s,
            "run_wall_s": self._run_wall_s,
            "virtual_now": self.now,
        }
        out.update({f"latency_{k}": v
                    for k, v in self.latency_quantiles().items()})
        out["service"] = self.service.stats
        return out


# --------------------------------------------------------- staging ckpt
def save_staging(path: str, staged: Sequence[_Staged]) -> None:
    """Checkpoint staged (accepted but unflushed) rows to one
    atomically-written .npz: frame columns + per-row event identity
    (uid / node / arrival), so a restart re-offers exactly what was
    in flight."""
    frames = [s.frame for s in staged]
    frame = concat_frames(frames) if len(frames) > 1 else frames[0]
    uid = np.concatenate([np.full(len(s.frame), s.uid, np.int64)
                          for s in staged])
    arrival = np.concatenate(
        [np.full(len(s.frame), s.arrival, np.float64) for s in staged])
    nodes = sum(([s.node] * len(s.frame) for s in staged), [])
    atomic_savez(
        path,
        row_uid=uid, row_arrival=arrival,
        row_node=np.asarray(nodes),
        benchmark_types=np.asarray(frame.benchmark_types),
        machines=np.asarray(frame.machines),
        machine_types=np.asarray(frame.machine_types),
        metric_names=np.asarray(frame.metric_names),
        metric_units=np.asarray(frame.metric_units),
        node_metric_names=np.asarray(frame.node_metric_names),
        type_code=frame.type_code, machine_code=frame.machine_code,
        machine_type_code=frame.machine_type_code,
        t=frame.t, stressed=frame.stressed,
        metrics=frame.metrics, metrics_present=frame.metrics_present,
        node_metrics=frame.node_metrics,
        node_metrics_present=frame.node_metrics_present)


def load_staging(path: str) -> List[TelemetryEvent]:
    """Load a staging checkpoint back into events (grouped by uid, in
    arrival order) — offer them to a fresh daemon to resume exactly
    where the crashed one stopped."""
    with np.load(path, allow_pickle=False) as z:
        def names(key):
            return tuple(str(x) for x in z[key])

        frame = BenchmarkFrame(
            benchmark_types=names("benchmark_types"),
            machines=names("machines"),
            machine_types=names("machine_types"),
            metric_names=names("metric_names"),
            metric_units=names("metric_units"),
            node_metric_names=names("node_metric_names"),
            type_code=z["type_code"], machine_code=z["machine_code"],
            machine_type_code=z["machine_type_code"],
            t=z["t"], stressed=z["stressed"],
            metrics=z["metrics"],
            metrics_present=z["metrics_present"],
            node_metrics=z["node_metrics"],
            node_metrics_present=z["node_metrics_present"])
        uid = z["row_uid"]
        arrival = z["row_arrival"]
        node = [str(x) for x in z["row_node"]]
    events = []
    for u in dict.fromkeys(uid.tolist()):  # first-appearance order
        rows = np.nonzero(uid == u)[0]
        events.append(TelemetryEvent(
            uid=int(u), node=node[rows[0]],
            arrival=float(arrival[rows[0]]),
            frame=frame.select(rows)))
    events.sort(key=lambda e: (e.arrival, e.uid))
    return events
