"""Deterministic fault injection for fleet telemetry streams.

A production fleet never delivers the clean closed-loop rounds the
batch path assumes: nodes stall and flood on recovery, telemetry
arrives late, duplicated or reordered, collectors emit NaN/Inf-poisoned
columns, and re-fingerprinting storms burst-arrive all at once. This
module provides (a) a seeded telemetry *source* that turns the suite
simulator into a stream of per-node :class:`TelemetryEvent` rounds —
including genuinely degraded nodes whose metrics shift through the
same ChaosMesh-style stress response the Perona model was trained on —
and (b) a seeded, composable fault *injector* (:func:`inject_faults`)
that perturbs any such event stream.

Every stochastic decision is a pure function of a
``common.rng.folded_generator`` path ``(seed, STREAM_FAULTS, kind,
uid)``: two injectors with equal plans over equal streams produce
identical faults, independent of call order — which is what lets the
tests assert exact row-level outcomes (dedup keeps the store exact,
quarantine catches every corrupted row) and lets ``bench_fleet``
re-create identical bursty arrival processes across runs.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.common.rng import STREAM_ARRIVALS, STREAM_FAULTS, folded_generator
from repro.fingerprint.frame import BenchmarkFrame

DAY = 86400.0


@dataclasses.dataclass(frozen=True)
class TelemetryEvent:
    """One node's benchmark round in flight to the ingestion daemon.

    ``arrival`` is the time the round reaches the daemon (the ingest
    clock); the telemetry timestamps inside ``frame.t`` are the
    benchmark execution times and live on their own (day-scale) axis —
    a stalled node's rounds keep their original execution timestamps
    while arriving late. Duplicated events share a ``uid``; the daemon
    dedups on it.
    """

    uid: int
    node: str
    arrival: float
    frame: BenchmarkFrame


# ---------------------------------------------------------------- source
def fleet_telemetry(machines: Mapping[str, str], *, rounds: int,
                    runs_per_type: int = 1, seed: int = 0,
                    interval: float = 1.0, jitter: float = 0.0,
                    t0: float = DAY, day: float = DAY,
                    degraded: Optional[Mapping[str, int]] = None
                    ) -> List[TelemetryEvent]:
    """Seeded per-node telemetry stream: ``rounds`` re-fingerprinting
    rounds of every node in ``machines``, one event per (node, round),
    arriving ``interval`` apart (plus per-event exponential ``jitter``).

    Telemetry timestamps start at ``t0`` and advance one ``day`` per
    round (streaming rounds land after any seeded history, the fleet
    cadence). ``degraded`` maps node -> first degraded round: from that
    round on, every one of the node's runs is stressed through the
    tool simulators' stress response — *injected degradation* that the
    trained model can actually detect (paper §III-D), not a synthetic
    label flip.
    """
    from repro.fingerprint.runner import SuiteRunner

    runner = SuiteRunner(seed=seed)
    degraded = dict(degraded or {})
    node_order = sorted(machines)
    events: List[TelemetryEvent] = []
    uid = 0
    for k in range(rounds):
        bad = [n for n, start in degraded.items() if k >= start]
        frame = runner.run_frame(dict(machines),
                                 runs_per_type=runs_per_type,
                                 degraded_machines=bad,
                                 t_offset=t0 + k * day)
        for node in node_order:
            code = frame.machines.index(node)
            sub = frame.select(
                np.nonzero(frame.machine_code == code)[0])
            arrival = k * interval
            if jitter:
                rng = folded_generator(seed, STREAM_ARRIVALS, k, node)
                arrival += float(rng.exponential(jitter))
            events.append(TelemetryEvent(uid=uid, node=node,
                                         arrival=arrival, frame=sub))
            uid += 1
    events.sort(key=lambda e: (e.arrival, e.uid))
    return events


# -------------------------------------------------------------- injector
@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Seeded fault mix applied over a telemetry stream. All rates are
    per-event probabilities; every decision folds ``(seed,
    STREAM_FAULTS, kind, uid)`` so equal plans replay identically."""

    seed: int = 0
    # node dropout: the round is lost entirely
    dropout: float = 0.0
    # node stall: events of `node` with arrival inside [start, end)
    # are held and flood in together at `end` (recovery burst)
    stalls: Tuple[Tuple[str, float, float], ...] = ()
    # delayed rounds: arrival += Exp(delay_scale)
    delay: float = 0.0
    delay_scale: float = 1.0
    # duplicated rounds: a copy with the same uid arrives later
    duplicate: float = 0.0
    duplicate_delay: float = 0.5
    # reordered rounds: arrival -= U(0, reorder_window) (may jump
    # ahead of earlier telemetry)
    reorder: float = 0.0
    reorder_window: float = 1.0
    # corrupted rounds: a subset of rows gets NaN/Inf metric columns
    corrupt: float = 0.0
    corrupt_cols: int = 3
    corrupt_rows: float = 0.6  # fraction of the event's rows (>= 1)
    # burst storms: all arrivals inside a struck window collapse to
    # the window's end and land simultaneously
    burst: float = 0.0
    burst_window: float = 4.0


@dataclasses.dataclass
class FaultLog:
    """Exact record of what the injector did (uids per fault kind) —
    the ground truth the robustness tests assert against."""

    dropped: List[int] = dataclasses.field(default_factory=list)
    stalled: List[int] = dataclasses.field(default_factory=list)
    delayed: List[int] = dataclasses.field(default_factory=list)
    duplicated: List[int] = dataclasses.field(default_factory=list)
    reordered: List[int] = dataclasses.field(default_factory=list)
    corrupted: Dict[int, int] = dataclasses.field(default_factory=dict)
    burst_windows: List[int] = dataclasses.field(default_factory=list)

    @property
    def corrupted_rows(self) -> int:
        return sum(self.corrupted.values())

    def counts(self) -> Dict[str, int]:
        return {"dropped": len(self.dropped),
                "stalled": len(self.stalled),
                "delayed": len(self.delayed),
                "duplicated": len(self.duplicated),
                "reordered": len(self.reordered),
                "corrupted_events": len(self.corrupted),
                "corrupted_rows": self.corrupted_rows,
                "burst_windows": len(self.burst_windows)}


def corrupt_frame(frame: BenchmarkFrame, rng: np.random.Generator,
                  n_cols: int, row_fraction: float
                  ) -> Tuple[BenchmarkFrame, int]:
    """Poison a copy of ``frame``: pick ``n_cols`` present metric
    columns and a row subset (at least one row) and overwrite the
    present cells with NaN/+Inf/-Inf. Returns (frame, corrupted rows).
    Only *present* cells are touched, so validation can see exactly
    the poisoned values a broken collector would emit."""
    n = len(frame)
    if n == 0:
        return frame, 0
    n_rows = max(1, int(round(row_fraction * n)))
    rows = np.sort(rng.choice(n, size=n_rows, replace=False))
    metrics = frame.metrics.copy()
    hit = np.zeros(n, bool)
    present_cols = np.nonzero(frame.metrics_present[rows].any(0))[0]
    cols = rng.choice(present_cols,
                      size=min(n_cols, len(present_cols)),
                      replace=False)
    poison = np.asarray([np.nan, np.inf, -np.inf])
    for c in cols:
        cells = rows[frame.metrics_present[rows, c]]
        metrics[cells, c] = rng.choice(poison, size=len(cells))
        hit[cells] = True
    return dataclasses.replace(frame, metrics=metrics), int(hit.sum())


def inject_faults(events: Sequence[TelemetryEvent], plan: FaultPlan
                  ) -> Tuple[List[TelemetryEvent], FaultLog]:
    """Apply ``plan`` over an event stream; returns the perturbed
    stream (sorted by new arrival) and the exact :class:`FaultLog`.
    Composable: the output is a plain event list, so injectors chain
    and any source (synthetic or recorded) can be perturbed."""
    log = FaultLog()
    out: List[TelemetryEvent] = []
    for ev in events:
        rng = folded_generator(plan.seed, STREAM_FAULTS, "event",
                               ev.uid)
        if plan.dropout and rng.random() < plan.dropout:
            log.dropped.append(ev.uid)
            continue
        arrival = ev.arrival
        frame = ev.frame
        for node, start, end in plan.stalls:
            if ev.node == node and start <= arrival < end:
                arrival = end
                log.stalled.append(ev.uid)
        if plan.delay and rng.random() < plan.delay:
            arrival += float(rng.exponential(plan.delay_scale))
            log.delayed.append(ev.uid)
        if plan.reorder and rng.random() < plan.reorder:
            arrival = max(0.0,
                          arrival - rng.uniform(0, plan.reorder_window))
            log.reordered.append(ev.uid)
        if plan.corrupt and rng.random() < plan.corrupt:
            frame, n_bad = corrupt_frame(frame, rng, plan.corrupt_cols,
                                         plan.corrupt_rows)
            log.corrupted[ev.uid] = n_bad
        out.append(dataclasses.replace(ev, arrival=arrival,
                                       frame=frame))
        if plan.duplicate and rng.random() < plan.duplicate:
            dup_arrival = arrival + float(
                rng.exponential(plan.duplicate_delay))
            out.append(dataclasses.replace(ev, arrival=dup_arrival,
                                           frame=frame))
            log.duplicated.append(ev.uid)
    if plan.burst:
        horizon = max((e.arrival for e in out), default=0.0)
        n_windows = int(horizon / plan.burst_window) + 1
        struck = []
        for w in range(n_windows):
            wrng = folded_generator(plan.seed, STREAM_FAULTS,
                                    "burst", w)
            if wrng.random() < plan.burst:
                struck.append(w)
        if struck:
            struck_set = set(struck)
            log.burst_windows.extend(struck)
            patched = []
            for ev in out:
                w = int(ev.arrival / plan.burst_window)
                if w in struck_set:
                    ev = dataclasses.replace(
                        ev, arrival=(w + 1) * plan.burst_window)
                patched.append(ev)
            out = patched
    out.sort(key=lambda e: (e.arrival, e.uid))
    return out, log
