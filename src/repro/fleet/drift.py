"""Store-backed degradation analytics (paper §III-C / §III-D).

Perona's context-aware scoring flags single anomalous executions; what
an operator acts on is the *trend*: is a node's anomaly probability
drifting up round over round, and on which resource aspect? This
module derives exactly that from the :class:`FingerprintStore` — a
per-node EWMA over the chronological series of attached anomaly
scores, and per-(node x aspect) EWMAs over the §III-D code quality
scores (``core.ranking.code_scores``, aspects via ``ASPECT_OF_TYPE``)
— replacing the watchdog's ad-hoc frame-history bookkeeping with a
queryable analytics layer over durable history.

Only rows with attached scores participate (NaN = never scored);
series are ordered by (t, row id), matching the store's view order.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import numpy as np

from repro.core.ranking import ASPECT_OF_TYPE, code_scores
from repro.fleet.store import FingerprintStore


class EwmaMean:
    """THE drift fold, extracted so every trend consumer shares one
    set of semantics: ``e_0 = x_0``, ``e_i = (1-a) e_{i-1} + a x_i``,
    alongside the lifetime mean (the drift baseline). This is exactly
    the per-node/per-aspect state :class:`RollingDrift` keeps per
    flush, and what ``obs.regress`` folds over benchmark-history
    series — so a perf-gate baseline and a fleet-drift baseline are
    the same computation."""

    __slots__ = ("alpha", "ewma", "total", "n")

    def __init__(self, alpha: float):
        self.alpha = alpha
        self.ewma: Optional[float] = None  # None until first update
        self.total = 0.0
        self.n = 0

    def update(self, v) -> None:
        """Fold one observation (first observation seeds the EWMA)."""
        a = self.alpha
        self.ewma = (v if self.ewma is None
                     else (1.0 - a) * self.ewma + a * v)
        self.total += v
        self.n += 1

    def fold(self, xs) -> "EwmaMean":
        """Fold a whole series (float64, in order); returns self."""
        for v in np.asarray(xs, np.float64):
            self.update(v)
        return self

    @property
    def mean(self) -> float:
        """Lifetime mean of everything folded so far."""
        return self.total / self.n if self.n else float("nan")


def ewma_series(x: np.ndarray, alpha: float) -> np.ndarray:
    """Full exponentially-weighted moving average series:
    e_0 = x_0, e_i = (1-alpha) * e_{i-1} + alpha * x_i."""
    x = np.asarray(x, np.float64)
    out = np.empty_like(x)
    if len(x) == 0:
        return out
    acc = x[0]
    for i, v in enumerate(x):
        acc = (1.0 - alpha) * acc + alpha * v
        out[i] = acc
    out[0] = x[0]
    return out


def ewma_last(x: np.ndarray, alpha: float) -> float:
    """Final EWMA value (the :class:`EwmaMean` fold of
    :func:`ewma_series`, without materializing the series)."""
    return float(EwmaMean(alpha).fold(x).ewma)


@dataclasses.dataclass
class NodeDrift:
    """Degradation summary of one node over its stored history."""

    node: str
    n_scored: int
    anomaly_ewma: float  # current EWMA of anomaly probability
    anomaly_mean: float  # lifetime mean (drift baseline)
    aspect_ewma: Dict[str, float]  # cpu/memory/disk/network quality
    aspect_mean: Dict[str, float]
    last_t: float

    @property
    def drift(self) -> float:
        """EWMA minus lifetime mean: > 0 means anomaly probability is
        trending above the node's own baseline."""
        return self.anomaly_ewma - self.anomaly_mean

    def degraded_aspects(self, rel_drop: float = 0.2) -> Dict[str, float]:
        """Aspects whose current quality EWMA dropped at least
        ``rel_drop`` (fraction) below the lifetime mean."""
        out = {}
        for a, e in self.aspect_ewma.items():
            m = self.aspect_mean[a]
            if m > 0 and (m - e) / m >= rel_drop:
                out[a] = (m - e) / m
        return out


def drift_report(store: FingerprintStore, alpha: float = 0.3,
                 node: Optional[str] = None) -> Dict[str, NodeDrift]:
    """Per-node drift summaries over the stored, scored history."""
    frame = store.frame
    if frame is None:
        return {}
    anomaly = store.anomaly
    scored = ~np.isnan(anomaly)
    codes = store.codes
    has_codes = (np.zeros(len(frame), bool) if codes is None
                 else ~np.isnan(codes).any(axis=1))
    # quality scores only where codes were attached (never-scored rows
    # are filtered out anyway — don't pay code_scores for them)
    quality = np.full(len(frame), np.nan)
    coded_rows = np.nonzero(has_codes)[0]
    if len(coded_rows):
        quality[coded_rows] = code_scores(codes[coded_rows])
    aspect_of_code = {b: ASPECT_OF_TYPE.get(name)
                      for b, name in enumerate(frame.benchmark_types)}

    out: Dict[str, NodeDrift] = {}
    for m_code in np.unique(frame.machine_code[scored]):
        name = frame.machines[m_code]
        if node is not None and name != node:
            continue
        sel = np.nonzero((frame.machine_code == m_code) & scored)[0]
        sel = sel[np.lexsort((store.row_id[sel], frame.t[sel]))]
        series = anomaly[sel].astype(np.float64)
        aspect_ewma: Dict[str, float] = {}
        aspect_mean: Dict[str, float] = {}
        with_codes = sel[has_codes[sel]]
        if len(with_codes):
            aspects = np.asarray(
                [aspect_of_code[b] or ""
                 for b in frame.type_code[with_codes]])
            for a in sorted(set(aspects) - {""}):
                q = quality[with_codes[aspects == a]]
                aspect_ewma[a] = ewma_last(q, alpha)
                aspect_mean[a] = float(q.mean())
        out[name] = NodeDrift(
            node=name, n_scored=len(sel),
            anomaly_ewma=ewma_last(series, alpha),
            anomaly_mean=float(series.mean()),
            aspect_ewma=aspect_ewma, aspect_mean=aspect_mean,
            last_t=float(frame.t[sel[-1]]))
    return out


class RollingDrift:
    """Incremental per-flush drift state: the same per-node anomaly
    EWMA / lifetime mean and per-aspect quality EWMAs as
    :func:`drift_report`, folded forward O(new rows) per flush instead
    of recomputed over the stored history — the long-lived ingestion
    daemon's drift path. When every scored row is fed through
    :meth:`update` in the store's (t, row) order (the streaming
    cadence), :meth:`report` is equal to ``drift_report(store)``
    (asserted in ``tests/test_ingest.py``)."""

    def __init__(self, alpha: float = 0.3):
        self.alpha = alpha
        self._nodes: Dict[str, dict] = {}

    def observe(self, node: str, t_last: float, probs: np.ndarray,
                aspects: Sequence[Optional[str]],
                quality: np.ndarray) -> None:
        """Fold one flush's new scored rows (chronological) for one
        node into the running state. ``aspects``/``quality`` are
        row-aligned with ``probs``; rows with aspect ``None`` update
        only the anomaly series."""
        st = self._nodes.setdefault(
            node, {"acc": EwmaMean(self.alpha), "last_t": t_last,
                   "aspects": {}})
        st["acc"].fold(probs)
        st["last_t"] = max(st["last_t"], t_last)
        for asp, q in zip(aspects, np.asarray(quality, np.float64)):
            if asp is None:
                continue
            st["aspects"].setdefault(
                asp, EwmaMean(self.alpha)).update(q)

    def update(self, store: FingerprintStore, results) -> None:
        """Fold a flush's results (``{node: FleetResult}``) into the
        running state; aspect/quality columns are derived from the
        store rows the results point at (row ids -> benchmark types ->
        aspects, codes -> §III-D quality scores)."""
        frame = store.frame
        if frame is None:
            return
        row_id = store.row_id
        order = None
        if not bool(np.all(np.diff(row_id) >= 0)):
            order = np.argsort(row_id)  # compacted stores only
        for node in sorted(results):
            r = results[node]
            if len(r.row_ids) == 0:
                continue
            if order is None:
                idx = np.searchsorted(row_id, r.row_ids)
            else:
                idx = order[np.searchsorted(row_id[order], r.row_ids)]
            aspects = [ASPECT_OF_TYPE.get(frame.benchmark_types[c])
                       for c in frame.type_code[idx]]
            # float32 codes, like the store keeps them: bit-equal to
            # what drift_report computes over the attached history
            quality = code_scores(np.asarray(r.codes, np.float32))
            self.observe(node, float(frame.t[idx].max()),
                         r.anomaly_prob, aspects, quality)

    def report(self) -> Dict[str, NodeDrift]:
        """Current state as :class:`NodeDrift` summaries (same shape
        as :func:`drift_report`'s)."""
        out: Dict[str, NodeDrift] = {}
        for node, st in self._nodes.items():
            acc = st["acc"]
            if acc.n == 0:
                continue
            out[node] = NodeDrift(
                node=node, n_scored=acc.n,
                anomaly_ewma=float(acc.ewma),
                anomaly_mean=acc.mean,
                aspect_ewma={a: float(s.ewma)
                             for a, s in st["aspects"].items()},
                aspect_mean={a: s.mean
                             for a, s in st["aspects"].items()},
                last_t=st["last_t"])
        return out


def degrading_nodes(report: Dict[str, NodeDrift],
                    ewma_threshold: float = 0.5,
                    min_scored: int = 3) -> Dict[str, NodeDrift]:
    """Nodes whose anomaly EWMA currently exceeds the threshold (with
    at least ``min_scored`` scored executions of history)."""
    return {n: d for n, d in report.items()
            if d.n_scored >= min_scored
            and d.anomaly_ewma >= ewma_threshold}


def degradation_factors(report: Dict[str, NodeDrift],
                        rel_drop: float = 0.2
                        ) -> Dict[str, Dict[str, float]]:
    """Per-node relative quality drops, {node: {aspect: fraction}}:
    each node's aspects whose quality EWMA fell at least ``rel_drop``
    below its lifetime mean. ``optimizer.scenarios.condition_from_drift``
    aggregates these into degraded-fleet search scenarios."""
    out = {}
    for node, d in report.items():
        degraded = d.degraded_aspects(rel_drop)
        if degraded:
            out[node] = degraded
    return out
