"""Chrome trace-event export + schema validation.

:func:`chrome_trace` turns a tracer's recorded spans into the Chrome
trace-event JSON format (the ``{"traceEvents": [...]}`` flavor), which
loads directly in Perfetto (https://ui.perfetto.dev) or
``chrome://tracing``: one process, one named track per originating
thread, complete ``X`` events for spans and ``i`` instants for
markers, span categories preserved in ``cat``.

Timestamps are microseconds relative to the earliest recorded event,
so virtual-clock timelines (the daemon's ``run()``) and wall-clock
timelines render identically. Events are emitted metadata-first and
time-sorted per thread, which makes per-thread ``ts`` monotonicity a
structural guarantee — :func:`validate_chrome_trace` (shared by the
tests and the CI smoke step) checks exactly that, plus phase shapes
(matched ``B``/``E`` or complete ``X``), and stable pid/tid naming
(every referenced track carries ``process_name`` / ``thread_name``
metadata).
"""

from __future__ import annotations

import json
from typing import Dict, List, Optional, Sequence

from repro.obs.trace import (PH_COMPLETE, PH_INSTANT, SpanEvent,
                             Tracer, tracer as _global_tracer)

PID = 1
_ALLOWED_PH = {"X", "B", "E", "i", "I", "M", "C"}


def chrome_trace(events: Optional[Sequence[SpanEvent]] = None, *,
                 tracer: Optional[Tracer] = None,
                 process_name: str = "perona") -> Dict[str, object]:
    """Lower recorded :class:`SpanEvent` s to a Chrome trace dict.

    ``events`` wins when given; otherwise ``tracer`` (default: the
    process-wide tracer) is snapshotted. Thread tracks are numbered in
    first-seen timestamp order — deterministic for a given recording.
    """
    if events is None:
        events = (tracer if tracer is not None
                  else _global_tracer()).events()
    events = sorted(events, key=lambda e: (e.ts, -e.dur))
    origin = events[0].ts if events else 0.0

    # stable tid naming: dense track ids in first-seen order
    track_of: Dict[int, int] = {}
    name_of: Dict[int, str] = {}
    for ev in events:
        if ev.tid not in track_of:
            track_of[ev.tid] = len(track_of)
            name_of[track_of[ev.tid]] = ev.thread
    out: List[Dict[str, object]] = [{
        "ph": "M", "pid": PID, "tid": 0, "name": "process_name",
        "args": {"name": process_name},
    }]
    for tid in sorted(name_of):
        out.append({"ph": "M", "pid": PID, "tid": tid,
                    "name": "thread_name",
                    "args": {"name": name_of[tid]}})
        out.append({"ph": "M", "pid": PID, "tid": tid,
                    "name": "thread_sort_index",
                    "args": {"sort_index": tid}})

    def us(t: float) -> float:
        return round((t - origin) * 1e6, 3)

    # per-track time order (already globally sorted by ts): monotonic
    # ts per tid by construction
    for ev in events:
        rec: Dict[str, object] = {
            "name": ev.name, "cat": ev.cat, "pid": PID,
            "tid": track_of[ev.tid], "ts": us(ev.ts),
        }
        if ev.ph == PH_COMPLETE:
            rec["ph"] = "X"
            rec["dur"] = round(ev.dur * 1e6, 3)
        elif ev.ph == PH_INSTANT:
            rec["ph"] = "i"
            rec["s"] = "t"
        else:
            rec["ph"] = ev.ph
        if ev.args:
            rec["args"] = dict(ev.args)
        out.append(rec)
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str,
                       events: Optional[Sequence[SpanEvent]] = None, *,
                       tracer: Optional[Tracer] = None,
                       process_name: str = "perona"
                       ) -> Dict[str, object]:
    """Export a timeline artifact to ``path``; returns the trace dict."""
    obj = chrome_trace(events, tracer=tracer, process_name=process_name)
    with open(path, "w") as f:
        json.dump(obj, f)
        f.write("\n")
    return obj


def validate_chrome_trace(obj: object) -> Dict[str, int]:
    """Validate Chrome trace-event structure; raises ``ValueError``
    listing every violation, returns summary counts on success.

    Checks: top-level shape; required per-event fields; known phases;
    complete ``X`` events carry a non-negative ``dur``; ``B``/``E``
    begin/end events nest and match by name per (pid, tid); ``ts`` is
    monotonically non-decreasing per (pid, tid) in emission order; and
    every (pid, tid) referenced by a timed event has ``thread_name``
    metadata (and its pid a ``process_name``) — stable track naming.
    """
    errors: List[str] = []
    if not isinstance(obj, dict) or not isinstance(
            obj.get("traceEvents"), list):
        raise ValueError(
            "not a Chrome trace: expected a dict with a "
            "'traceEvents' list")
    events = obj["traceEvents"]
    last_ts: Dict[tuple, float] = {}
    be_stack: Dict[tuple, List[str]] = {}
    named_threads = set()
    named_procs = set()
    used_tracks = set()
    n_spans = 0
    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            errors.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in _ALLOWED_PH:
            errors.append(f"{where}: unknown phase {ph!r}")
            continue
        if "pid" not in ev or "tid" not in ev:
            errors.append(f"{where}: missing pid/tid")
            continue
        track = (ev["pid"], ev["tid"])
        if ph == "M":
            if ev.get("name") == "thread_name":
                named_threads.add(track)
            elif ev.get("name") == "process_name":
                named_procs.add(ev["pid"])
            continue
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            errors.append(f"{where}: missing event name")
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)):
            errors.append(f"{where}: missing numeric ts")
            continue
        used_tracks.add(track)
        if ts < last_ts.get(track, float("-inf")):
            errors.append(
                f"{where}: ts {ts} goes backwards on pid/tid {track} "
                f"(previous {last_ts[track]})")
        last_ts[track] = ts
        if ph == "X":
            n_spans += 1
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(
                    f"{where}: complete event needs dur >= 0, "
                    f"got {dur!r}")
        elif ph == "B":
            be_stack.setdefault(track, []).append(ev.get("name", ""))
            n_spans += 1
        elif ph == "E":
            stack = be_stack.get(track, [])
            if not stack:
                errors.append(
                    f"{where}: E event with no open B on {track}")
            else:
                top = stack.pop()
                name = ev.get("name", top)
                if name and name != top:
                    errors.append(
                        f"{where}: E name {name!r} does not match "
                        f"open B {top!r} on {track}")
    for track, stack in be_stack.items():
        if stack:
            errors.append(
                f"unclosed B events on pid/tid {track}: {stack}")
    for track in sorted(used_tracks):
        if track not in named_threads:
            errors.append(
                f"pid/tid {track} has events but no thread_name "
                "metadata")
        if track[0] not in named_procs:
            errors.append(
                f"pid {track[0]} has events but no process_name "
                "metadata")
    if errors:
        raise ValueError("invalid Chrome trace:\n" +
                         "\n".join(f"  - {e}" for e in errors))
    return {"events": len(events), "spans": n_spans,
            "threads": len(used_tracks)}


def validate_chrome_trace_file(path: str) -> Dict[str, int]:
    """Load + validate a timeline artifact (the CI smoke helper)."""
    with open(path) as f:
        return validate_chrome_trace(json.load(f))
