"""Noise-aware perf-regression detection over benchmark history.

Perona's thesis applied to the repo itself: repeated, comparable
benchmark executions plus the context of previous runs detect
degradation robustly (paper §III); ALOJA showed the value of keeping a
persistent repository of executions and running analytics over it.
This module is the *detect* stage of the record->detect->enforce loop:
``benchmarks/history.py`` records every ``BENCH_*.json`` payload,
:func:`evaluate_series` judges the newest value of each metric against
an EWMA baseline over its history, and :func:`attribute_delta`
explains confirmed regressions by diffing the companion telemetry
snapshots (``MetricsRegistry.snapshot_delta``) — a throughput drop
co-occurring with a ``jax.traces`` increase is a *recompile
regression*, one co-occurring with a quarantine-counter shift is a
*behavior change*, not just "slower".

Three defenses keep the gate honest on noisy runners:

- the baseline is the **same EWMA fold** fleet drift analytics use
  (:class:`repro.fleet.drift.EwmaMean` — ``e_0 = x_0``,
  ``e_i = (1-a) e_{i-1} + a x_i``), so a slow multi-run decline moves
  the baseline with it and only *abrupt* drops clear the threshold;
- the effective threshold widens by a **noise floor** calibrated from
  the series itself (robust MAD-based relative scatter of the
  historical values, scaled) and by any **A/A null measurement** the
  benchmark ships (``bench_fleet``'s ``fleet.daemon.obs.noise_pct``
  row measures two identical code paths against each other — the
  observed same-code gap of that very machine);
- every metric carries a **direction policy** (higher-is-better req/s
  vs lower-is-better p99; counters and config echoes are
  informational), from the bench module's explicit ``POLICIES`` table
  first, name heuristics second.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.fleet.drift import EwmaMean
from repro.obs import metrics

# ------------------------------------------------------------ policies

DIR_HIGHER = "higher"   # bigger is better (throughput, speedups)
DIR_LOWER = "lower"     # smaller is better (latency, wall clock)
DIR_INFO = "info"       # tracked, never gated (counts, config echoes)

#: substring -> direction, first match wins (checked in order; explicit
#: per-module POLICIES tables override all of this)
_HIGHER_TOKENS = ("req_per_s", "requests_per_s", "searches_per_s",
                  "rows_per_s", "per_sec", "throughput", "speedup",
                  "parity", "f1", "accuracy")
_LOWER_TOKENS = ("latency", "p50", "p99", "wall_s", "compile_s",
                 "overhead_pct", "us_per_call", "spec_s", "tables_s")
_INFO_TOKENS = ("noise_pct", "events", "rounds", "rows", "devices",
                "lanes", "traces", "dispatches", "flushes", "count",
                "capacity", "window", "error")


@dataclasses.dataclass(frozen=True)
class MetricPolicy:
    """How one metric is gated: direction, the minimum relative change
    that counts (percent), and how much history a verdict needs."""

    direction: str
    rel_threshold_pct: float = 5.0
    min_history: int = 3


def default_policy(name: str,
                   overrides: Optional[Mapping[str, MetricPolicy]]
                   = None) -> MetricPolicy:
    """Policy for a metric name: explicit override table first (the
    bench module's ``POLICIES``), then name heuristics, then
    informational."""
    if overrides is not None:
        p = overrides.get(name)
        if p is not None:
            return p
    low = name.lower()
    for tok in _INFO_TOKENS:
        if low.endswith(tok):
            return MetricPolicy(DIR_INFO)
    for tok in _HIGHER_TOKENS:
        if tok in low:
            return MetricPolicy(DIR_HIGHER)
    for tok in _LOWER_TOKENS:
        if tok in low:
            return MetricPolicy(DIR_LOWER)
    return MetricPolicy(DIR_INFO)


def policy_table(raw: Mapping[str, object]) -> Dict[str, MetricPolicy]:
    """Normalize a bench module's plain ``POLICIES`` dict — values are
    ``direction`` strings or ``(direction, rel_threshold_pct)`` tuples
    (kept plain so bench modules import nothing at module scope)."""
    out: Dict[str, MetricPolicy] = {}
    for name, spec in raw.items():
        if isinstance(spec, MetricPolicy):
            out[name] = spec
        elif isinstance(spec, str):
            out[name] = MetricPolicy(spec)
        else:
            direction, thr = spec
            out[name] = MetricPolicy(direction,
                                     rel_threshold_pct=float(thr))
    return out


# ---------------------------------------------------------- noise floor

def series_noise_pct(values: Sequence[float],
                     scale: float = 3.0) -> float:
    """Relative noise of a baseline series, in percent: the MAD-based
    robust standard deviation (``1.4826 * MAD``) over the median
    magnitude, scaled to a ~3-sigma band. A/A-identical series measure
    exactly 0; the 20%-regression acceptance case stays far outside
    any plausible floor."""
    v = np.asarray(values, np.float64)
    v = v[np.isfinite(v)]
    if len(v) < 2:
        return 0.0
    med = np.median(v)
    if med == 0.0:
        return 0.0
    mad = np.median(np.abs(v - med))
    return float(scale * 1.4826 * mad / abs(med) * 100.0)


def noise_floor_pct(values: Sequence[float],
                    aa_noise_pct: float = 0.0,
                    scale: float = 3.0) -> float:
    """Effective noise floor for one series: its own robust scatter
    widened by the run's A/A null measurement (when the benchmark
    ships one)."""
    return max(series_noise_pct(values, scale=scale),
               float(aa_noise_pct))


# ------------------------------------------------------------ findings

VERDICT_REGRESSION = "regression"
VERDICT_IMPROVEMENT = "improvement"
VERDICT_OK = "ok"
VERDICT_NO_BASELINE = "no-baseline"
VERDICT_INFO = "info"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One metric's verdict for one evaluated run."""

    module: str
    metric: str
    value: float
    baseline: float          # EWMA over the baseline series (nan if none)
    n_baseline: int
    delta_pct: float         # signed (value - baseline)/|baseline| * 100
    threshold_pct: float     # effective gate threshold after widening
    noise_pct: float         # the floor that widened it
    direction: str
    verdict: str
    attribution: Tuple[str, ...] = ()

    @property
    def regressed(self) -> bool:
        return self.verdict == VERDICT_REGRESSION

    @property
    def label(self) -> str:
        """``module.metric``, without doubling the module prefix the
        bench rows already carry."""
        if self.metric.startswith(self.module + "."):
            return self.metric
        return f"{self.module}.{self.metric}"

    def describe(self) -> str:
        if self.verdict in (VERDICT_INFO, VERDICT_NO_BASELINE):
            return (f"{self.label}: {self.verdict} "
                    f"(value {self.value:g}, "
                    f"history {self.n_baseline})")
        line = (f"{self.label}: {self.verdict} "
                f"{self.delta_pct:+.2f}% vs EWMA baseline "
                f"{self.baseline:g} (n={self.n_baseline}, "
                f"threshold ±{self.threshold_pct:.2f}%, "
                f"direction {self.direction})")
        if self.attribution:
            line += " — " + "; ".join(self.attribution)
        return line


def evaluate_series(module: str, metric: str,
                    baseline_values: Sequence[float], value: float,
                    policy: Optional[MetricPolicy] = None, *,
                    overrides: Optional[Mapping[str, MetricPolicy]]
                    = None,
                    alpha: float = 0.3,
                    aa_noise_pct: float = 0.0) -> Finding:
    """Judge the newest ``value`` of one metric against the EWMA fold
    of its ``baseline_values`` (chronological, oldest first). The
    effective threshold is the policy's relative threshold widened to
    the calibrated noise floor, so a gate over A/A reruns never flags
    and a gate over a noisy series needs a genuinely abrupt change."""
    if policy is None:
        policy = default_policy(metric, overrides)
    vals = np.asarray(baseline_values, np.float64)
    vals = vals[np.isfinite(vals)]
    if policy.direction == DIR_INFO or not np.isfinite(value):
        return Finding(module, metric, float(value), float("nan"),
                       len(vals), 0.0, 0.0, 0.0, DIR_INFO,
                       VERDICT_INFO)
    if len(vals) < policy.min_history:
        return Finding(module, metric, float(value), float("nan"),
                       len(vals), 0.0, 0.0, 0.0, policy.direction,
                       VERDICT_NO_BASELINE)
    baseline = ewma_baseline(vals, alpha)
    noise = noise_floor_pct(vals, aa_noise_pct)
    threshold = max(policy.rel_threshold_pct, noise)
    denom = abs(baseline) if baseline != 0.0 else 1.0
    delta_pct = (float(value) - baseline) / denom * 100.0
    worse = (delta_pct < -threshold if policy.direction == DIR_HIGHER
             else delta_pct > threshold)
    better = (delta_pct > threshold if policy.direction == DIR_HIGHER
              else delta_pct < -threshold)
    verdict = (VERDICT_REGRESSION if worse
               else VERDICT_IMPROVEMENT if better else VERDICT_OK)
    return Finding(module, metric, float(value), baseline,
                   len(vals), delta_pct, threshold, noise,
                   policy.direction, verdict)


def ewma_baseline(values: Sequence[float], alpha: float = 0.3) -> float:
    """The baseline fold — exactly :class:`EwmaMean` (fleet drift's
    semantics): recent runs dominate, one ancient outlier cannot
    poison the comparison."""
    return float(EwmaMean(alpha).fold(
        np.asarray(values, np.float64)).ewma)


# --------------------------------------------------------- attribution

#: counter-name prefix -> human label for the attribution pass, probed
#: in order; the first rule whose summed positive delta fires names
#: the regression class
_ATTRIBUTION_RULES: Tuple[Tuple[str, str], ...] = (
    ("jax.traces", "recompile regression: jax.traces {delta:+d}"),
    ("fleet.quarantined",
     "behavior change: quarantined rows {delta:+d}"),
    ("ingest.ladder",
     "behavior change: backpressure ladder steps {delta:+d}"),
    ("ingest.duplicates_dropped",
     "behavior change: duplicates dropped {delta:+d}"),
    ("jax.dispatches", "behavior change: dispatches {delta:+d}"),
)


def _summed_delta(delta: Mapping[str, Mapping[str, object]],
                  prefix: str) -> float:
    """Net counter delta summed over every labeled instance of a
    metric family (site renumbering between processes cancels out in
    the sum)."""
    total = 0.0
    for key, ent in delta.items():
        name, _ = metrics.parse_key(key)
        if name.startswith(prefix) and ent["kind"] == "counter":
            total += float(ent["delta"] or 0)
    return total


def attribute_delta(delta: Mapping[str, Mapping[str, object]]
                    ) -> Tuple[str, ...]:
    """Classify a telemetry-snapshot diff (the output of
    ``MetricsRegistry.snapshot_delta`` between the baseline run's
    snapshot and the evaluated run's) into regression classes. Both
    snapshots come from runs of the *same* workload, so any net
    positive shift in a diagnostic counter family is a real change of
    behavior, not traffic growth. Empty tuple = nothing diagnostic
    moved (an unattributed slowdown)."""
    labels = []
    for prefix, template in _ATTRIBUTION_RULES:
        d = _summed_delta(delta, prefix)
        if d > 0:
            labels.append(template.format(delta=int(d)))
    compile_d = _summed_delta(delta, "jax.compile_s")
    if compile_d > 0.01 and any("jax.traces" in x for x in labels):
        labels[0] += f" ({compile_d:+.2f}s compile wall)"
    return tuple(labels)


def attribute_snapshots(before: Mapping[str, object],
                        after: Mapping[str, object]) -> Tuple[str, ...]:
    """Convenience: diff two raw snapshots with the process registry's
    type information and classify."""
    return attribute_delta(
        metrics.registry().snapshot_delta(dict(before), dict(after)))
