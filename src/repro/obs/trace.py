"""Span-based tracing with an injectable clock.

A :class:`Tracer` records *complete* spans (name, category, start,
duration, thread) and instant markers into a bounded ring. The clock is
injectable so timelines are honest in both of the repo's time domains:
the process-wide tracer (:func:`tracer`) runs on
``time.perf_counter`` wall time, while the ``IngestionDaemon`` owns a
private tracer whose clock reads the daemon's ``now`` — virtual time
under ``run()`` (arrivals + measured scoring durations), wall time
under ``serve()`` — so queue/flush spans line up with the latencies
the daemon actually reports.

Span categories make host work vs device dispatch explicit:
``CAT_HOST`` for python/numpy table building and staging,
``CAT_DEVICE`` for compiled-dispatch boundaries, ``CAT_LADDER`` for
backpressure-ladder transitions. The timeline exporter
(``repro.obs.timeline``) turns the recorded events into Chrome
trace-event JSON, one track per originating thread.

Recording is a no-op while the plane is disabled
(``obs.disable()``) — the ``span`` context manager yields immediately
without reading the clock.
"""

from __future__ import annotations

import collections
import contextlib
import dataclasses
import threading
import time
from typing import Callable, Dict, Iterator, List, Optional

from repro.obs import metrics

CAT_HOST = "host"
CAT_DEVICE = "device"
CAT_LADDER = "ladder"
CAT_PLANE = "plane"  # model-plane lifecycle (canary/promote/rollback)

#: Chrome trace-event phases used by the recorder.
PH_COMPLETE = "X"
PH_INSTANT = "i"


@dataclasses.dataclass(frozen=True)
class SpanEvent:
    """One recorded event, timestamps in the tracer's clock domain
    (seconds; ``dur`` is 0 for instants)."""

    name: str
    cat: str
    ts: float
    dur: float
    tid: int
    thread: str
    ph: str = PH_COMPLETE
    args: Optional[Dict[str, object]] = None


class Tracer:
    """Bounded-ring span recorder over an injectable clock."""

    def __init__(self, clock: Optional[Callable[[], float]] = None,
                 max_events: int = 200_000):
        self._clock = clock if clock is not None else time.perf_counter
        self._events: collections.deque = collections.deque(
            maxlen=max_events)
        self._lock = threading.Lock()
        self._dropped = 0

    # ---------------------------------------------------------- clock
    def set_clock(self, clock: Callable[[], float]) -> None:
        self._clock = clock

    def now(self) -> float:
        return self._clock()

    # ------------------------------------------------------ recording
    def _record(self, ev: SpanEvent) -> None:
        with self._lock:
            if (self._events.maxlen is not None
                    and len(self._events) == self._events.maxlen):
                self._dropped += 1
            self._events.append(ev)

    @contextlib.contextmanager
    def span(self, name: str, cat: str = CAT_HOST,
             args: Optional[Dict[str, object]] = None) -> Iterator[None]:
        """Record the block as one complete span on the current
        thread. No-op (not even a clock read) when the plane is
        disabled."""
        if not metrics.enabled():
            yield
            return
        t0 = self._clock()
        try:
            yield
        finally:
            t1 = self._clock()
            th = threading.current_thread()
            self._record(SpanEvent(name=name, cat=cat, ts=t0,
                                   dur=max(t1 - t0, 0.0),
                                   tid=th.ident, thread=th.name,
                                   args=args))

    def complete(self, name: str, cat: str, ts: float, dur: float,
                 args: Optional[Dict[str, object]] = None) -> None:
        """Record a complete span with explicit timestamps — for
        callers whose span boundaries live in their own clock domain
        (the daemon's virtual flush windows)."""
        if not metrics.enabled():
            return
        th = threading.current_thread()
        self._record(SpanEvent(name=name, cat=cat, ts=ts,
                               dur=max(dur, 0.0), tid=th.ident,
                               thread=th.name, args=args))

    def instant(self, name: str, cat: str = CAT_HOST,
                args: Optional[Dict[str, object]] = None,
                ts: Optional[float] = None) -> None:
        """Record a zero-duration marker (ladder transitions, faults)."""
        if not metrics.enabled():
            return
        th = threading.current_thread()
        self._record(SpanEvent(name=name, cat=cat,
                               ts=self._clock() if ts is None else ts,
                               dur=0.0, tid=th.ident, thread=th.name,
                               ph=PH_INSTANT, args=args))

    # -------------------------------------------------------- reading
    def events(self) -> List[SpanEvent]:
        """Snapshot copy of the recorded events (recording order)."""
        with self._lock:
            return list(self._events)

    @property
    def dropped(self) -> int:
        """Events evicted from the ring since the last clear."""
        return self._dropped

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self._dropped = 0


_TRACER = Tracer()


def tracer() -> Tracer:
    """The process-wide wall-clock tracer."""
    return _TRACER


def span(name: str, cat: str = CAT_HOST,
         args: Optional[Dict[str, object]] = None):
    """``tracer().span(...)`` shorthand for call sites."""
    return _TRACER.span(name, cat=cat, args=args)
