"""Consolidated JIT accounting behind the metrics registry.

:class:`JitSite` replaces the repo's ad-hoc trace counters (the old
``core.trainer.TraceCount``, ``serving.FingerprintEngine``'s inline
``_trace_count`` and ``fleet.shard.ShardedScorer``'s copy) with one
registry-backed object per dispatch site. Each site owns four labeled
instruments — ``jax.traces`` / ``jax.dispatches`` / ``jax.compile_s``
/ ``jax.run_s`` — so a registry snapshot answers "what retraced, how
often does it dispatch, and where did the compile wall time go" across
the whole process.

The public reads the old counters exposed stay intact: ``tick()``
increments at trace time (call it from inside the traced function, the
established pattern), ``count`` and ``trace_count`` read the tracing
counter, so ``tests/_trace_utils.expect_traces`` works on a
:class:`JitSite` unchanged.

:meth:`JitSite.dispatch` wraps one host-blocking compiled call: it
books the wall time as *compile* when the site's trace counter
advanced inside the call (first call per program signature) and as
*run* otherwise, ticks the dispatch counter, and records a
``CAT_DEVICE`` span on the current thread — which is how per-program
compile/run splits and worker-thread device spans reach the timeline.
"""

from __future__ import annotations

import contextlib
import itertools
import time
from typing import Dict, Iterator, Optional

from repro.obs import metrics, trace

_SITE_SEQ = itertools.count()


def instance_site(prefix: str) -> str:
    """Unique site label for per-instance accounting (``engine/3``) —
    instances of the same class keep distinct registry rows."""
    return f"{prefix}/{next(_SITE_SEQ)}"


class JitSite:
    """Trace/dispatch/compile-time accounting for one jit call site."""

    def __init__(self, site: str,
                 registry: Optional[metrics.MetricsRegistry] = None,
                 tracer: Optional[trace.Tracer] = None):
        reg = registry if registry is not None else metrics.registry()
        self.site = site
        self._tracer = tracer
        self._traces = reg.counter("jax.traces", site=site)
        self._dispatches = reg.counter("jax.dispatches", site=site)
        self._compile_s = reg.counter("jax.compile_s", site=site)
        self._run_s = reg.counter("jax.run_s", site=site)

    # ------------------------------------------------- trace counting
    def tick(self) -> None:
        """Tick the tracing counter — call inside the traced function
        so it fires at trace time only."""
        self._traces.inc()

    @property
    def count(self) -> int:
        return int(self._traces.value)

    @property
    def trace_count(self) -> int:
        return int(self._traces.value)

    # ------------------------------------------------------ dispatch
    @property
    def dispatches(self) -> int:
        return int(self._dispatches.value)

    @property
    def compile_seconds(self) -> float:
        return float(self._compile_s.value)

    @property
    def run_seconds(self) -> float:
        return float(self._run_s.value)

    @contextlib.contextmanager
    def dispatch(self, name: Optional[str] = None,
                 args: Optional[Dict[str, object]] = None
                 ) -> Iterator[None]:
        """Account one host-blocking compiled call (see module doc).
        No-op when the plane is disabled."""
        if not metrics.enabled():
            yield
            return
        before = self._traces.value
        t0 = time.perf_counter()
        try:
            yield
        finally:
            dt = time.perf_counter() - t0
            traced = self._traces.value > before
            (self._compile_s if traced else self._run_s).add(dt)
            self._dispatches.inc()
            tr = self._tracer if self._tracer is not None \
                else trace.tracer()
            span_args = dict(args) if args else {}
            span_args["traced"] = traced
            tr.complete(name if name is not None else self.site,
                        trace.CAT_DEVICE, t0, dt, args=span_args)

    def stats(self) -> metrics.StatsDict:
        return {
            "traces": self.count,
            "dispatches": self.dispatches,
            "compile_s": self.compile_seconds,
            "run_s": self.run_seconds,
        }
