"""Process-wide telemetry plane: metrics, spans, timelines, JIT stats.

The observability substrate every subsystem reports through:

- ``metrics``  — a labeled, thread-safe :class:`MetricsRegistry` of
  counters / gauges / streaming histograms (exact quantiles while the
  sample count is small, log-bucketed beyond that), near-zero-cost
  when the plane is disabled (``obs.disable()``);
- ``trace``    — span-based tracing with an *injectable clock*, so the
  ``IngestionDaemon`` virtual-clock ``run()`` and the wall-clock
  ``serve()`` both produce honest timelines, with explicit span
  categories separating host work from device dispatch;
- ``timeline`` — export of recorded spans to Chrome trace-event JSON
  (loadable in Perfetto / ``chrome://tracing``) plus the schema
  validator shared by tests and the CI smoke step;
- ``jaxstat``  — consolidated JIT accounting (:class:`JitSite`:
  tracings, dispatches, per-program compile/run wall seconds) behind
  the registry, replacing the per-module ad-hoc trace counters while
  keeping their public ``count`` / ``trace_count`` reads;
- ``regress``  — noise-aware perf-regression detection over benchmark
  history series (EWMA baselines sharing fleet drift's fold, noise
  floors calibrated from series scatter + A/A null rows, per-metric
  direction policies, telemetry-snapshot attribution). Imported
  explicitly (``from repro.obs import regress``) because it leans on
  ``repro.fleet`` — the rest of the plane stays dependency-light.

Everything hangs off one process-wide registry (:func:`registry`) and
one process-wide tracer (:func:`tracer`); components that need their
own clock domain (the ingestion daemon's virtual clock) own a private
:class:`Tracer` instead of stamping wall-clock times into the shared
one.
"""

from repro.obs.jaxstat import JitSite, instance_site
from repro.obs.metrics import (Counter, Gauge, Histogram,
                               MetricsRegistry, StatsDict, disable,
                               disabled, enable, enabled, parse_key,
                               registry)
from repro.obs.trace import (CAT_DEVICE, CAT_HOST, CAT_LADDER,
                             CAT_PLANE, SpanEvent, Tracer, span,
                             tracer)
from repro.obs.timeline import (chrome_trace, validate_chrome_trace,
                                validate_chrome_trace_file,
                                write_chrome_trace)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "StatsDict",
    "registry", "enable", "disable", "enabled", "disabled",
    "parse_key",
    "Tracer", "SpanEvent", "tracer", "span",
    "CAT_HOST", "CAT_DEVICE", "CAT_LADDER", "CAT_PLANE",
    "chrome_trace", "write_chrome_trace", "validate_chrome_trace",
    "validate_chrome_trace_file",
    "JitSite", "instance_site",
]
