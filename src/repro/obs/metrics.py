"""Labeled metrics registry: counters, gauges, streaming histograms.

Instruments are keyed by ``(name, sorted labels)`` and created lazily
through :class:`MetricsRegistry` (``registry().counter("x", site="y")``
returns the same object on every call). All mutation paths are
thread-safe — the daemon's producer threads, the pipelined replay's
per-device workers and the serve loop all write into one process-wide
registry.

The whole plane can be switched off (:func:`disable`): every
``inc`` / ``set`` / ``observe`` then returns after a single attribute
check, so instrumented hot paths pay near-zero cost. The enabled-path
cost is one lock acquire per update, which is why instruments sit at
dispatch boundaries (per flush, per stacked dispatch, per block) and
never inside traced code.

Histograms keep an **exact** sample list while small
(``exact_limit``): quantiles are then literally ``np.quantile`` over
the observations (bit-identical to the ad-hoc deque quantiles they
replace). Past the limit the samples fold into base-2 log buckets —
O(1) memory forever after, quantiles accurate to the bucket width
(under 50% relative error, typically far less), with ``count`` /
``sum`` / ``min`` / ``max`` staying exact.
"""

from __future__ import annotations

import contextlib
import math
import threading
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

#: The unified stats-dict shape shared by ``fleet.service`` and
#: ``fleet.ingest`` (counters are ints, wall times floats, nested
#: sub-dicts allowed) — one annotation for every ``stats()`` surface.
StatsDict = Dict[str, object]


class _State:
    enabled = True


_STATE = _State()


def enable() -> None:
    """Turn the telemetry plane on (the default)."""
    _STATE.enabled = True


def disable() -> None:
    """Turn the telemetry plane off: every instrument update and span
    becomes a no-op after one attribute check."""
    _STATE.enabled = False


def enabled() -> bool:
    return _STATE.enabled


@contextlib.contextmanager
def disabled() -> Iterator[None]:
    """Context manager: run the block with the plane disabled (the
    overhead benchmark / tests)."""
    prev = _STATE.enabled
    _STATE.enabled = False
    try:
        yield
    finally:
        _STATE.enabled = prev


LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, object]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _fmt_key(name: str, labels: LabelKey) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={v}" for k, v in labels)
    return f"{name}{{{inner}}}"


def parse_key(key: str) -> Tuple[str, LabelKey]:
    """Inverse of the snapshot key format: ``"name{k=v,k2=v2}"`` ->
    ``("name", (("k", "v"), ("k2", "v2")))``. Label values must not
    contain ``,`` or ``=`` (they never do — see :func:`_label_key`)."""
    if key.endswith("}") and "{" in key:
        name, inner = key[:-1].split("{", 1)
        labels = tuple(tuple(kv.split("=", 1))
                       for kv in inner.split(",")) if inner else ()
        return name, labels  # type: ignore[return-value]
    return key, ()


class Counter:
    """Monotonic labeled counter (int or float increments)."""

    __slots__ = ("name", "labels", "_value", "_lock")

    def __init__(self, name: str, labels: LabelKey = ()):
        self.name = name
        self.labels = labels
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        if not _STATE.enabled:
            return
        with self._lock:
            self._value += n

    # float accumulation (wall seconds); same path, clearer call sites
    add = inc

    @property
    def value(self):
        return self._value

    def snapshot_value(self):
        return self._value


class Gauge:
    """Last-value labeled gauge."""

    __slots__ = ("name", "labels", "_value", "_lock")

    def __init__(self, name: str, labels: LabelKey = ()):
        self.name = name
        self.labels = labels
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        if not _STATE.enabled:
            return
        with self._lock:
            self._value = v

    @property
    def value(self):
        return self._value

    def snapshot_value(self):
        return self._value


class Histogram:
    """Streaming histogram with an exact-quantile small-N path.

    Up to ``exact_limit`` observations are kept verbatim and quantiles
    are ``np.quantile`` over them. Beyond the limit, samples fold into
    base-2 log buckets (exponent of ``math.frexp``); quantiles then
    interpolate the geometric bucket midpoint. ``count``/``sum``/
    ``min``/``max`` are exact in both regimes.
    """

    __slots__ = ("name", "labels", "exact_limit", "_exact", "_buckets",
                 "_count", "_sum", "_min", "_max", "_lock")

    # frexp exponents clamp to this symmetric range; one underflow
    # bucket (index 0) catches zeros and negatives
    _E_LO, _E_HI = -64, 64

    def __init__(self, name: str, labels: LabelKey = (),
                 exact_limit: int = 4096):
        self.name = name
        self.labels = labels
        self.exact_limit = exact_limit
        self._exact: Optional[List[float]] = []
        self._buckets: Optional[np.ndarray] = None
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf
        self._lock = threading.Lock()

    def _bucket_index(self, v: float) -> int:
        if v <= 0.0 or not math.isfinite(v):
            return 0
        e = math.frexp(v)[1]  # v in [2**(e-1), 2**e)
        e = min(max(e, self._E_LO), self._E_HI)
        return e - self._E_LO + 1

    def _fold(self) -> None:
        self._buckets = np.zeros(self._E_HI - self._E_LO + 2, np.int64)
        for v in self._exact:
            self._buckets[self._bucket_index(v)] += 1
        self._exact = None

    def observe(self, v: float) -> None:
        if not _STATE.enabled:
            return
        v = float(v)
        with self._lock:
            self._count += 1
            self._sum += v
            self._min = min(self._min, v)
            self._max = max(self._max, v)
            if self._exact is not None:
                self._exact.append(v)
                if len(self._exact) > self.exact_limit:
                    self._fold()
            else:
                self._buckets[self._bucket_index(v)] += 1

    def observe_many(self, vs) -> None:
        """Batch observe under one lock round-trip (the hot-path form:
        the ingestion daemon records a whole flush's queue latencies
        in one call)."""
        if not _STATE.enabled or not vs:
            return
        with self._lock:
            for v in vs:
                v = float(v)
                self._count += 1
                self._sum += v
                self._min = min(self._min, v)
                self._max = max(self._max, v)
                if self._exact is not None:
                    self._exact.append(v)
                    if len(self._exact) > self.exact_limit:
                        self._fold()
                else:
                    self._buckets[self._bucket_index(v)] += 1

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def exact(self) -> bool:
        """True while quantiles come from the verbatim sample list."""
        return self._exact is not None

    def quantile(self, q: float) -> float:
        with self._lock:
            if self._count == 0:
                return float("nan")
            if self._exact is not None:
                return float(np.quantile(np.asarray(self._exact), q))
            # folded: walk the cumulative counts to the target rank,
            # answer with the bucket's geometric midpoint
            target = q * (self._count - 1)
            cum = 0
            for i, c in enumerate(self._buckets):
                if c == 0:
                    continue
                cum += int(c)
                if cum - 1 >= target:
                    if i == 0:
                        return min(self._min, 0.0)
                    e = i - 1 + self._E_LO  # bucket [2**(e-1), 2**e)
                    return float(math.sqrt(2.0 ** (e - 1) * 2.0 ** e))
            return self._max

    def quantiles(self, qs: Sequence[float]) -> Dict[str, float]:
        return {f"p{int(q * 100)}": self.quantile(q) for q in qs}

    def summary(self) -> Dict[str, float]:
        n = self._count
        return {
            "count": n,
            "sum": self._sum,
            "mean": self._sum / n if n else float("nan"),
            "min": self._min if n else float("nan"),
            "max": self._max if n else float("nan"),
            "p50": self.quantile(0.5),
            "p99": self.quantile(0.99),
        }

    def snapshot_value(self):
        return self.summary()


class MetricsRegistry:
    """Process-wide (or test-local) instrument registry."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[Tuple[str, LabelKey], object] = {}

    def _get(self, cls, name: str, labels: Dict[str, object],
             **kwargs):
        key = (name, _label_key(labels))
        with self._lock:
            inst = self._metrics.get(key)
            if inst is None:
                inst = cls(name, key[1], **kwargs)
                self._metrics[key] = inst
            elif not isinstance(inst, cls):
                raise TypeError(
                    f"metric {_fmt_key(*key)} already registered as "
                    f"{type(inst).__name__}, not {cls.__name__}")
            return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, exact_limit: int = 4096,
                  **labels) -> Histogram:
        return self._get(Histogram, name, labels,
                         exact_limit=exact_limit)

    def snapshot(self, prefix: str = "") -> Dict[str, object]:
        """Flat ``{"name{k=v}": value}`` dict — counters/gauges as
        scalars, histograms as their summary dicts. The diagnostics
        blob benchmarks attach to each ``BENCH_*.json`` payload."""
        with self._lock:
            items = list(self._metrics.items())
        out: Dict[str, object] = {}
        for (name, labels), inst in sorted(items,
                                           key=lambda kv: kv[0]):
            if prefix and not name.startswith(prefix):
                continue
            out[_fmt_key(name, labels)] = inst.snapshot_value()
        return out

    def render(self, prefix: str = "") -> str:
        """Human-readable text dump (the ``serve.py --metrics`` page)."""
        lines = []
        for key, val in self.snapshot(prefix).items():
            if isinstance(val, dict):
                inner = " ".join(
                    f"{k}={v:.6g}" if isinstance(v, float) else f"{k}={v}"
                    for k, v in val.items())
                lines.append(f"{key} {inner}")
            elif isinstance(val, float):
                lines.append(f"{key} {val:.6g}")
            else:
                lines.append(f"{key} {val}")
        return "\n".join(lines)

    def instrument_kind(self, key: str) -> Optional[str]:
        """``"counter"`` / ``"gauge"`` / ``"histogram"`` for a snapshot
        key that resolves to a live instrument, else None (stale keys
        from a historical snapshot)."""
        with self._lock:
            inst = self._metrics.get(parse_key(key))
        if inst is None:
            return None
        return type(inst).__name__.lower()

    def snapshot_delta(self, before: Dict[str, object],
                       after: Dict[str, object]
                       ) -> Dict[str, Dict[str, object]]:
        """Typed diff of two :meth:`snapshot` dicts (``before`` ->
        ``after``): each key maps to ``{"kind", "before", "after",
        "delta"}`` where **counters** diff (missing side counts as 0,
        so instruments created between the snapshots still diff
        cleanly), **gauges** carry the last value (``delta`` is None —
        a gauge is not a rate), and **histogram** summaries diff their
        exact ``count``/``sum`` and carry the last quantiles. Types
        come from the live instrument when the key resolves in this
        registry; for stale keys (snapshots loaded from an old
        ``BENCH_*.json`` in another process) dict values are
        histograms and scalars default to counter semantics — the
        conservative choice for the regression-attribution pass, which
        only acts on positive counter deltas.
        """
        out: Dict[str, Dict[str, object]] = {}
        for key in sorted(set(before) | set(after)):
            b, a = before.get(key), after.get(key)
            kind = self.instrument_kind(key)
            if kind is None:
                kind = ("histogram"
                        if isinstance(a if a is not None else b, dict)
                        else "counter")
            if kind == "histogram":
                bd = b if isinstance(b, dict) else {}
                ad = a if isinstance(a, dict) else {}
                delta = {f: ad.get(f, 0) - bd.get(f, 0)
                         for f in ("count", "sum")}
            elif kind == "gauge":
                delta = None
            else:
                delta = (a or 0) - (b or 0)
            out[key] = {"kind": kind, "before": b, "after": a,
                        "delta": delta}
        return out

    def reset(self) -> None:
        """Drop every instrument (tests/benchmark isolation)."""
        with self._lock:
            self._metrics.clear()


_REGISTRY = MetricsRegistry()


def registry() -> MetricsRegistry:
    """The process-wide registry every subsystem reports into."""
    return _REGISTRY
