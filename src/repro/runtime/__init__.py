"""Distributed runtime: Perona watchdog, fault tolerance, stragglers.

This is where the paper's fingerprinting becomes a first-class training
feature: nodes are ranked before mesh construction, re-fingerprinted
periodically, and degradation detections drive exclusion + elastic
restart from checkpoint (DESIGN.md §2).
"""

from repro.runtime.watchdog import PeronaWatchdog
from repro.runtime.fault import TrainingRuntime, FailureInjector
from repro.runtime.straggler import StragglerMonitor

__all__ = [
    "PeronaWatchdog",
    "TrainingRuntime",
    "FailureInjector",
    "StragglerMonitor",
]
