"""Perona degradation watchdog (paper §III-C applied to a live cluster).

Periodically re-fingerprints cluster nodes with the standardized suite,
pushes the new executions through the trained Perona model, and flags
nodes whose anomaly probability stays above threshold. Following the
paper's discussion of false positives, a flag is only *confirmed* after
``confirm_runs`` consecutive anomalous re-benchmarks — a cheap operation
(each benchmark runs seconds) relative to excluding a healthy node.

The rolling history is held as a columnar :class:`BenchmarkFrame` and
scored through the shared :class:`FingerprintEngine`, so repeated
rounds amortize a single compiled scoring call (shape-bucketed jit)
instead of re-tracing the model every round. A node is flagged in a
round only when a *quorum* of its new executions scores anomalous —
one noisy run cannot flag a healthy node (the seed used the max
probability, which false-positived healthy nodes into exclusion) —
strikes reset on clean rounds, and only confirmed flags
(``confirm_runs`` consecutive anomalous rounds) exclude a node.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.model import PeronaModel
from repro.core.preprocess import Preprocessor
from repro.fingerprint.frame import (BenchmarkFrame, FrameOrRecords,
                                     as_frame, concat_frames)
from repro.fingerprint.records import BenchmarkExecution
from repro.serving.engine import FingerprintEngine


@dataclasses.dataclass
class WatchdogDecision:
    node: str
    anomaly_prob: float  # mean probability over the round's executions
    flag_fraction: float  # share of the round's executions >= threshold
    flagged: bool
    confirmed: bool


class PeronaWatchdog:
    def __init__(self, model: PeronaModel, params, preproc: Preprocessor,
                 threshold: float = 0.5, confirm_runs: int = 2,
                 quorum: float = 1 / 3,
                 engine: Optional[FingerprintEngine] = None,
                 history_per_chain: int = 64):
        self.model = model
        self.params = params
        self.preproc = preproc
        self.threshold = threshold
        self.quorum = quorum
        self.confirm_runs = confirm_runs
        self.history_per_chain = history_per_chain
        self.engine = engine or FingerprintEngine(model, params, preproc)
        self._strikes: Dict[str, int] = {}
        self._frame: Optional[BenchmarkFrame] = None

    # ------------------------------------------------------------- history
    @property
    def history(self) -> List[BenchmarkExecution]:
        """Rolling context as records (compat view of the frame)."""
        return [] if self._frame is None else self._frame.to_records()

    @history.setter
    def history(self, data: FrameOrRecords) -> None:
        self._frame = as_frame(data) if len(data) else None

    @property
    def history_frame(self) -> Optional[BenchmarkFrame]:
        return self._frame

    # ------------------------------------------------------------- observe
    def observe(self, data: FrameOrRecords) -> List[WatchdogDecision]:
        """Score a new fingerprinting round (frame or records from the
        suite runner) in the context of previous rounds."""
        new = as_frame(data)
        n_new = len(new)
        combined = (new if self._frame is None
                    else concat_frames([self._frame, new]))
        first_new = len(combined) - n_new
        keep = self._trim_indices(combined, self.history_per_chain)
        is_new = keep >= first_new
        self._frame = combined.select(keep)

        prob = self.engine.score(self._frame).anomaly_prob

        # per-node quorum over this round's executions
        codes = self._frame.machine_code[is_new]
        probs = prob[is_new]
        decisions = []
        for code in np.unique(codes):
            node = self._frame.machines[code]
            p_runs = probs[codes == code]
            frac = float((p_runs >= self.threshold).mean())
            flagged = frac >= self.quorum
            if flagged:
                self._strikes[node] = self._strikes.get(node, 0) + 1
            else:
                self._strikes[node] = 0
            confirmed = self._strikes[node] >= self.confirm_runs
            decisions.append(WatchdogDecision(
                node=node, anomaly_prob=float(p_runs.mean()),
                flag_fraction=frac, flagged=flagged,
                confirmed=confirmed))
        decisions.sort(key=lambda d: d.node)
        return decisions

    @staticmethod
    def _trim_indices(frame: BenchmarkFrame, keep: int) -> np.ndarray:
        """Indices of the newest ``keep`` rows per (type x machine)
        chain, in global chronological order."""
        n = len(frame)
        key = (frame.type_code.astype(np.int64)
               * max(len(frame.machines), 1) + frame.machine_code)
        order = np.lexsort((np.arange(n), frame.t, key))
        key_sorted = key[order]
        boundary = np.ones(n, bool)
        boundary[1:] = key_sorted[1:] != key_sorted[:-1]
        starts = np.where(boundary)[0]
        lengths = np.diff(np.append(starts, n))
        length_per_row = np.repeat(lengths, lengths)
        pos = np.arange(n) - np.maximum.accumulate(
            np.where(boundary, np.arange(n), 0))
        kept = order[pos >= length_per_row - keep]
        return kept[np.lexsort((kept, frame.t[kept]))]

    def excluded_nodes(self) -> List[str]:
        return [n for n, s in self._strikes.items()
                if s >= self.confirm_runs]
