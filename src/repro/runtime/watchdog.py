"""Perona degradation watchdog (paper §III-C applied to a live cluster).

Periodically re-fingerprints cluster nodes with the standardized suite,
pushes the new executions through the trained Perona model, and flags
nodes whose anomaly probability stays above threshold. Following the
paper's discussion of false positives, a flag is only *confirmed* after
``confirm_runs`` consecutive anomalous re-benchmarks — a cheap operation
(each benchmark runs seconds) relative to excluding a healthy node.

The rolling history lives in a :class:`repro.fleet.FingerprintStore`
(compacted to ``history_per_chain`` rows per (node x benchmark type)
chain after every round when the watchdog owns the store; a shared
service store stays append-only), and the scored rounds feed the
store-backed
drift analytics of :mod:`repro.fleet.drift` — ``drift_report()``
exposes per-node / per-aspect EWMAs over the scored history, and each
decision carries the node's current anomaly EWMA.

Scoring goes through one of two interchangeable paths: the shared
:class:`FingerprintEngine` (one shape-bucketed jit call over the whole
history frame — the default, amortizing a single compile across
rounds), or a :class:`repro.fleet.FleetScoringService` when one is
passed — then the watchdog and the fleet serve entrypoint share one
micro-batched, sharded scoring path *and* one store. A node is flagged
in a round only when a *quorum* of its new executions scores anomalous
— one noisy run cannot flag a healthy node (the seed used the max
probability, which false-positived healthy nodes into exclusion) —
strikes reset on clean rounds, and only confirmed flags
(``confirm_runs`` consecutive anomalous rounds) exclude a node.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.model import PeronaModel
from repro.core.preprocess import Preprocessor
from repro.fingerprint.frame import (BenchmarkFrame, FrameOrRecords,
                                     as_frame)
from repro.fingerprint.records import BenchmarkExecution
from repro.fleet.drift import drift_report
from repro.fleet.store import FingerprintStore
from repro.serving.engine import FingerprintEngine


@dataclasses.dataclass
class WatchdogDecision:
    node: str
    anomaly_prob: float  # mean probability over the round's executions
    flag_fraction: float  # share of the round's executions >= threshold
    flagged: bool
    confirmed: bool
    # running EWMA over the rounds *this watchdog* observed (same
    # recurrence as drift.ewma_series; the full store-backed view —
    # which on a shared store also covers other producers' rounds —
    # is drift_report())
    anomaly_ewma: float = float("nan")


class PeronaWatchdog:
    def __init__(self, model: PeronaModel, params, preproc: Preprocessor,
                 threshold: float = 0.5, confirm_runs: int = 2,
                 quorum: float = 1 / 3,
                 engine: Optional[FingerprintEngine] = None,
                 history_per_chain: int = 64,
                 service=None, drift_alpha: float = 0.3):
        self.model = model
        self.params = params
        self.preproc = preproc
        self.threshold = threshold
        self.quorum = quorum
        self.confirm_runs = confirm_runs
        self.drift_alpha = drift_alpha
        self.service = service
        if service is not None:
            # the service governs scoring context and store lifecycle;
            # reflect its cap so history_per_chain is never silently
            # different from what actually bounds the context
            self.history_per_chain = service.context_per_chain
            self.engine = engine  # unused unless provided explicitly
            self.store = service.store
        else:
            self.history_per_chain = history_per_chain
            self.engine = engine or FingerprintEngine(model, params,
                                                      preproc)
            self.store = FingerprintStore()
        self._strikes: Dict[str, int] = {}
        # running per-node anomaly EWMA, updated incrementally with
        # each round's new scores (O(new rows) per observe; the full
        # store-backed report stays available via drift_report())
        self._ewma: Dict[str, float] = {}

    # ------------------------------------------------------------- history
    @property
    def history(self) -> List[BenchmarkExecution]:
        """Rolling context as records (compat view of the store)."""
        frame = self.store.frame
        return [] if frame is None else frame.to_records()

    @history.setter
    def history(self, data: FrameOrRecords) -> None:
        if self.service is not None:
            # the service's store may hold fleet-wide history owned by
            # other producers — never wipe it as a side effect
            if len(self.store):
                raise ValueError(
                    "the shared service store already holds history; "
                    "seed it through the service (seed_history) or "
                    "attach a fresh FleetScoringService instead")
            if len(data):
                self.service.seed_history(as_frame(data))
        else:
            self.store.clear()
            if len(data):
                self.store.append(as_frame(data))
        self._ewma.clear()

    @property
    def history_frame(self) -> Optional[BenchmarkFrame]:
        return self.store.frame

    # ------------------------------------------------------------- observe
    def observe(self, data: FrameOrRecords) -> List[WatchdogDecision]:
        """Score a new fingerprinting round (frame or records from the
        suite runner) in the context of previous rounds."""
        new = as_frame(data)
        if len(new) == 0:  # nothing observed: no scoring dispatch
            return []
        if self.service is not None:
            # the service's store is shared (and may back fleet-wide
            # drift analytics / durability), so the watchdog does not
            # compact it — scoring context is capped by the service.
            # Drain requests other producers queued first, so this
            # round's quorum judges only the observed executions.
            self.service.flush()
            results = self.service.score_round(new)
            probs_of_node = {node: r.anomaly_prob
                             for node, r in results.items()}
        else:
            # context rule shared with the fleet service
            # (store.context_with_new): the newest history rows per
            # chain *as of before this round*, plus every new
            # execution (all are scored and judged, however their
            # timestamps interleave)
            first_id = self.store.append(new)
            frame = self.store.frame
            sel, is_new = self.store.context_with_new(
                first_id, self.history_per_chain)
            if len(sel) == 0:  # empty round on an empty store
                return []
            res = self.engine.score(frame.select(sel))
            self.store.attach(sel[is_new], res.anomaly_prob[is_new],
                              res.codes[is_new])
            codes = frame.machine_code[sel[is_new]]
            probs = res.anomaly_prob[is_new]
            probs_of_node = {
                frame.machines[c]: probs[codes == c]
                for c in np.unique(codes)}
            self.store.compact(self.history_per_chain)

        decisions = []
        for node in sorted(probs_of_node):
            p_runs = probs_of_node[node]
            frac = float((p_runs >= self.threshold).mean())
            flagged = frac >= self.quorum
            if flagged:
                self._strikes[node] = self._strikes.get(node, 0) + 1
            else:
                self._strikes[node] = 0
            confirmed = self._strikes[node] >= self.confirm_runs
            decisions.append(WatchdogDecision(
                node=node, anomaly_prob=float(p_runs.mean()),
                flag_fraction=frac, flagged=flagged,
                confirmed=confirmed,
                anomaly_ewma=self._update_ewma(node, p_runs)))
        return decisions

    def _update_ewma(self, node: str, probs) -> float:
        """Fold a round's new scores into the node's running EWMA
        (same recurrence as drift.ewma_series, in observation order)."""
        acc = self._ewma.get(node)
        a = self.drift_alpha
        for p in probs:
            acc = float(p) if acc is None else (1 - a) * acc + a * float(p)
        if acc is None:
            return float("nan")
        self._ewma[node] = acc
        return acc

    # --------------------------------------------------------------- drift
    def drift_report(self, alpha: Optional[float] = None):
        """Per-node drift summaries over the stored, scored history
        (see :func:`repro.fleet.drift.drift_report`)."""
        return drift_report(self.store,
                            self.drift_alpha if alpha is None else alpha)

    def excluded_nodes(self) -> List[str]:
        return [n for n, s in self._strikes.items()
                if s >= self.confirm_runs]
