"""Perona degradation watchdog (paper §III-C applied to a live cluster).

Periodically re-fingerprints cluster nodes with the standardized suite,
pushes the new executions through the trained Perona model, and flags
nodes whose anomaly probability stays above threshold. Following the
paper's discussion of false positives, a flag is only *confirmed* after
``confirm_runs`` consecutive anomalous re-benchmarks — a cheap operation
(each benchmark runs seconds) relative to excluding a healthy node.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.graph_data import build_graphs
from repro.core.model import PeronaModel
from repro.core.preprocess import Preprocessor
from repro.core.trainer import batch_to_jnp
from repro.fingerprint.records import BenchmarkExecution


@dataclasses.dataclass
class WatchdogDecision:
    node: str
    anomaly_prob: float
    flagged: bool
    confirmed: bool


class PeronaWatchdog:
    def __init__(self, model: PeronaModel, params, preproc: Preprocessor,
                 threshold: float = 0.5, confirm_runs: int = 2):
        self.model = model
        self.params = params
        self.preproc = preproc
        self.threshold = threshold
        self.confirm_runs = confirm_runs
        self._strikes: Dict[str, int] = {}
        self.history: List[BenchmarkExecution] = []

    def observe(self, records: Sequence[BenchmarkExecution]
                ) -> List[WatchdogDecision]:
        """Score a new fingerprinting round (records from the suite
        runner) in the context of previous rounds."""
        self.history.extend(records)
        # bounded context: keep the last 64 runs per (type, machine)
        self.history = self._trim(self.history)
        batch = build_graphs(self.history, self.preproc)
        import jax

        out = self.model.forward(self.params, batch_to_jnp(batch),
                                 train=False)
        prob = np.asarray(jax.nn.sigmoid(out["anom_logit"]))
        new_ids = {id(r) for r in records}
        decisions = {}
        for i, rec in enumerate(self.history):
            if id(rec) not in new_ids:
                continue
            node = rec.machine
            p = float(prob[i])
            worst = max(p, decisions.get(node, (0.0,))[0]) \
                if node in decisions else p
            decisions[node] = (worst,)
        out_decisions = []
        for node, (p,) in sorted(decisions.items()):
            flagged = p >= self.threshold
            if flagged:
                self._strikes[node] = self._strikes.get(node, 0) + 1
            else:
                self._strikes[node] = 0
            confirmed = self._strikes[node] >= self.confirm_runs
            out_decisions.append(WatchdogDecision(
                node=node, anomaly_prob=p, flagged=flagged,
                confirmed=confirmed))
        return out_decisions

    def _trim(self, records, keep: int = 64):
        buckets: Dict = {}
        for r in records:
            buckets.setdefault((r.benchmark_type, r.machine), []).append(r)
        out = []
        for items in buckets.values():
            items.sort(key=lambda r: r.t)
            out.extend(items[-keep:])
        out.sort(key=lambda r: r.t)
        return out

    def excluded_nodes(self) -> List[str]:
        return [n for n, s in self._strikes.items()
                if s >= self.confirm_runs]
