"""Fault-tolerant training runtime: checkpoint/restart + elastic meshes.

``TrainingRuntime`` owns the step loop of a model on a mesh. Failures
(injected in tests / reported by the platform in production) trigger:

  1. drop to the last durable checkpoint (CheckpointManager),
  2. rebuild the mesh without the failed/excluded hosts (elastic: the
     data axis shrinks; parameters reshard on restore),
  3. resume the data pipeline at the restored step (deterministic
     batch_at(step) -> exactly-once sample delivery).

Straggler events route through the Perona watchdog: fingerprint-confirmed
degradation excludes the node like a failure; unconfirmed events only
log. The same code path is the single-host simulation of the multi-pod
protocol — device-count-independent by construction (tests run it on 1
CPU device with virtual hosts).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.checkpointing.manager import CheckpointManager
from repro.data.tokens import TokenPipeline


class FailureInjector:
    """Deterministic failure schedule: {step: [hosts]}. Each scheduled
    failure fires exactly once (a crashed host stays crashed — the
    restored run must not re-trip on the same step)."""

    def __init__(self, schedule: Optional[Dict[int, Sequence[str]]] = None):
        self.schedule = {int(k): list(v)
                         for k, v in (schedule or {}).items()}

    def check(self, step: int) -> List[str]:
        return self.schedule.pop(step, [])


@dataclasses.dataclass
class RuntimeEvent:
    step: int
    kind: str  # failure | restart | exclusion | straggler
    detail: str


class TrainingRuntime:
    def __init__(self, *, hosts: Sequence[str], train_step: Callable,
                 init_state: Callable[[Sequence[str]], Any],
                 pipeline: TokenPipeline, ckpt: CheckpointManager,
                 checkpoint_every: int = 10,
                 failure_injector: Optional[FailureInjector] = None,
                 watchdog=None, suite_runner=None, machines=None,
                 straggler_monitor=None,
                 host_time_fn: Optional[Callable] = None,
                 fingerprint_every: int = 0):
        self.hosts = list(hosts)
        self.train_step = train_step
        self.init_state = init_state
        self.pipeline = pipeline
        self.ckpt = ckpt
        self.checkpoint_every = checkpoint_every
        self.failures = failure_injector or FailureInjector()
        self.watchdog = watchdog
        self.suite_runner = suite_runner
        self.machines = dict(machines or {})
        self.straggler = straggler_monitor
        self.host_time_fn = host_time_fn
        self.fingerprint_every = fingerprint_every
        self.events: List[RuntimeEvent] = []
        self.restarts = 0

    # ------------------------------------------------------------------ run
    def run(self, total_steps: int) -> Dict[str, Any]:
        state = self.init_state(self.hosts)
        start = 0
        restored, meta = self.ckpt.restore(state)
        if restored is not None:
            state = restored
            start = int(meta["step"]) + 1
            self.events.append(RuntimeEvent(start, "restart",
                                            "resumed from checkpoint"))
        step = start
        losses = []
        while step < total_steps:
            failed = self.failures.check(step)
            if failed:
                self._handle_failure(step, failed)
                state = self.init_state(self.hosts)
                restored, meta = self.ckpt.restore(state)
                if restored is not None:
                    state = restored
                    step = int(meta["step"]) + 1
                else:
                    step = 0
                self.restarts += 1
                continue

            batch = self.pipeline.batch_at(step)
            state, metrics = self.train_step(state, batch, self.hosts)
            losses.append(float(metrics.get("loss", np.nan)))

            if self.straggler is not None and self.host_time_fn is not None:
                times = self.host_time_fn(step, self.hosts)
                for ev in self.straggler.record_step(step, times):
                    self.events.append(RuntimeEvent(
                        step, "straggler", ev.host))
                    self._confirm_and_exclude(step, ev.host)

            if (self.fingerprint_every and self.watchdog is not None
                    and self.suite_runner is not None
                    and step > 0 and step % self.fingerprint_every == 0):
                self._fingerprint_round(step)

            if step % self.checkpoint_every == 0:
                self.ckpt.save(step, state, extra={"hosts": self.hosts})
                self.ckpt.wait()
            step += 1
        return {"state": state, "losses": losses, "events": self.events,
                "final_hosts": list(self.hosts), "restarts": self.restarts}

    # ----------------------------------------------------------- internals
    def _handle_failure(self, step: int, failed: Sequence[str]):
        for h in failed:
            if h in self.hosts:
                self.hosts.remove(h)
                self.events.append(RuntimeEvent(step, "failure", h))

    def _confirm_and_exclude(self, step: int, host: str):
        if self.watchdog is None or self.suite_runner is None:
            return
        mtype = self.machines.get(host)
        if mtype is None:
            return
        confirmed = False
        for _ in range(self.watchdog.confirm_runs):
            records = self.suite_runner.run({host: mtype}, runs_per_type=1,
                                            degraded_machines=[host])
            decisions = self.watchdog.observe(records)
            confirmed = any(d.node == host and d.confirmed
                            for d in decisions)
        if confirmed and host in self.hosts:
            self.hosts.remove(host)
            self.events.append(RuntimeEvent(step, "exclusion", host))

    def _fingerprint_round(self, step: int):
        live = {h: self.machines[h] for h in self.hosts
                if h in self.machines}
        records = self.suite_runner.run(live, runs_per_type=1)
        for d in self.watchdog.observe(records):
            if d.confirmed and d.node in self.hosts:
                self.hosts.remove(d.node)
                self.events.append(RuntimeEvent(step, "exclusion", d.node))
