"""Straggler detection from per-host step timings.

At pod scale the slowest host gates every synchronous collective, so a
persistent straggler is a cluster-wide slowdown. The monitor keeps an
EWMA of per-host step times, flags hosts slower than
``ratio_threshold`` x cluster median for ``patience`` consecutive steps,
and hands the flagged host to the Perona watchdog for confirmation
(fingerprint-confirmed degradation -> exclusion; unconfirmed -> likely
transient interference, keep the node).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class StragglerEvent:
    host: str
    step: int
    ewma_ms: float
    median_ms: float


class StragglerMonitor:
    def __init__(self, ratio_threshold: float = 1.35, patience: int = 5,
                 alpha: float = 0.3):
        self.ratio_threshold = ratio_threshold
        self.patience = patience
        self.alpha = alpha
        self._ewma: Dict[str, float] = {}
        self._strikes: Dict[str, int] = {}
        self.events: List[StragglerEvent] = []

    def record_step(self, step: int, host_times_ms: Dict[str, float]
                    ) -> List[StragglerEvent]:
        for host, t in host_times_ms.items():
            prev = self._ewma.get(host, t)
            self._ewma[host] = (1 - self.alpha) * prev + self.alpha * t
        med = float(np.median(list(self._ewma.values())))
        flagged = []
        for host, ew in self._ewma.items():
            if ew > self.ratio_threshold * med:
                self._strikes[host] = self._strikes.get(host, 0) + 1
            else:
                self._strikes[host] = 0
            if self._strikes[host] >= self.patience:
                ev = StragglerEvent(host=host, step=step, ewma_ms=ew,
                                    median_ms=med)
                flagged.append(ev)
                self.events.append(ev)
                self._strikes[host] = 0  # hand off; reset
        return flagged
