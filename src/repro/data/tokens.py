"""Synthetic token pipeline: deterministic, shardable, restartable.

Batches are pure functions of (seed, step), so checkpoint/restart resumes
the stream exactly (the pipeline "state" is just the step counter — the
property tests assert batch(step) is reproducible across restarts). The
stream has learnable structure: a fixed random successor table with
temperature noise, giving a decreasing LM loss for the end-to-end
training example without any external corpus.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class TokenPipeline:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    structure: float = 0.7  # P(next = successor(prev)); rest uniform

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self._successor = jnp.asarray(
            rng.permutation(self.vocab_size), jnp.int32)

    def batch_at(self, step: int) -> Dict[str, jnp.ndarray]:
        """The batch for a given step (pure; identical across restarts)."""
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        k1, k2, k3 = jax.random.split(key, 3)
        B, S, V = self.global_batch, self.seq_len, self.vocab_size
        first = jax.random.randint(k1, (B, 1), 0, V)
        noise = jax.random.randint(k2, (B, S), 0, V)
        use_succ = jax.random.bernoulli(k3, self.structure, (B, S))

        def step_fn(prev, inp):
            nz, us = inp
            nxt = jnp.where(us, self._successor[prev], nz)
            return nxt, nxt

        _, toks = jax.lax.scan(
            step_fn, first[:, 0],
            (jnp.moveaxis(noise, 1, 0), jnp.moveaxis(use_succ, 1, 0)))
        tokens = jnp.moveaxis(toks, 0, 1)  # (B, S)
        labels = jnp.concatenate(
            [tokens[:, 1:], tokens[:, :1]], axis=1)
        return {"tokens": tokens, "labels": labels}

    def iterate(self, start_step: int = 0) -> Iterator[Dict]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1

    def state_dict(self, step: int) -> Dict:
        return {"seed": self.seed, "step": step}

    @staticmethod
    def restore_step(state: Dict) -> int:
        return int(state["step"])
