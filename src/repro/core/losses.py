"""Perona's five training objectives (paper §III-C/D training notes).

  MSE  — autoencoder reconstruction
  CBFL — class-balanced focal loss [Cui et al. 2019] for outlier
         detection (binary, heavy normal/anomalous imbalance)
  TML  — triplet margin loss [FaceNet] + hard-pair miner for per-type
         clustering of codes (cosine geometry)
  CEL  — cross entropy on the linear benchmark-type probe
  MRL  — margin ranking loss against the p-norm ground truth within each
         type; anomalous codes must rank below the lowest normal code

All losses are masked-mean over valid nodes and combined additively.
Scalar hyperparameters (CBFL gamma/beta) may be python floats or traced
jnp scalars — the vmapped HPO engine passes per-trial values.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def mse_loss(recon, x, valid):
    err = jnp.sum(jnp.square(recon - x), axis=-1) / x.shape[-1]
    return jnp.sum(err * valid) / jnp.maximum(jnp.sum(valid), 1.0)


def class_balanced_focal_loss(logit, label, valid, *, gamma: float = 2.0,
                              beta: float = 0.999):
    """Binary CBFL. logit (N,), label (N,) in {0,1}."""
    # cast first so python-float and traced-scalar beta give identical
    # f32 arithmetic (1 - beta happens in f32 either way)
    beta = jnp.float32(beta)
    label = label.astype(jnp.float32)
    n_pos = jnp.sum(label * valid)
    n_neg = jnp.sum((1 - label) * valid)
    eff = lambda n: (1.0 - jnp.power(beta, jnp.maximum(n, 1.0))) / (1 - beta)
    w_pos = 1.0 / eff(n_pos)
    w_neg = 1.0 / eff(n_neg)
    # normalize weights to sum to 2 (class count), as in the paper's ref
    z = w_pos + w_neg
    w_pos, w_neg = 2 * w_pos / z, 2 * w_neg / z
    p = jax.nn.sigmoid(logit)
    pt = jnp.where(label > 0, p, 1 - p)
    w = jnp.where(label > 0, w_pos, w_neg)
    focal = -w * jnp.power(1 - pt, gamma) * jnp.log(jnp.maximum(pt, 1e-12))
    return jnp.sum(focal * valid) / jnp.maximum(jnp.sum(valid), 1.0)


def cross_entropy_loss(logits, labels, valid):
    logp = jax.nn.log_softmax(logits, -1)
    nll = -jnp.take_along_axis(logp, labels[:, None], -1)[:, 0]
    return jnp.sum(nll * valid) / jnp.maximum(jnp.sum(valid), 1.0)


def triplet_margin_loss(codes, type_id, valid, *, margin: float = 0.3):
    """Cosine-distance TML with a batch-hard miner: per anchor, hardest
    positive (same type, max distance) and hardest negative (other type,
    min distance)."""
    c = codes / jnp.maximum(
        jnp.linalg.norm(codes, axis=-1, keepdims=True), 1e-9)
    sim = c @ c.T  # (N, N)
    dist = 1.0 - sim
    same = (type_id[:, None] == type_id[None, :]) & (valid[:, None] > 0) \
        & (valid[None, :] > 0)
    eye = jnp.eye(codes.shape[0], dtype=bool)
    pos_mask = same & ~eye
    neg_mask = (~same) & (valid[:, None] > 0) & (valid[None, :] > 0)
    hardest_pos = jnp.max(jnp.where(pos_mask, dist, -1.0), axis=1)
    hardest_neg = jnp.min(jnp.where(neg_mask, dist, 4.0), axis=1)
    has_pair = (jnp.any(pos_mask, 1) & jnp.any(neg_mask, 1)).astype(
        jnp.float32) * valid
    loss = jnp.maximum(hardest_pos - hardest_neg + margin, 0.0)
    return jnp.sum(loss * has_pair) / jnp.maximum(jnp.sum(has_pair), 1.0)


def pnorm(codes, p: float = 10.0):
    return jnp.power(
        jnp.sum(jnp.power(jnp.abs(codes) + 1e-12, p), axis=-1), 1.0 / p)


def margin_ranking_loss(codes, norm_gt, type_id, anomaly, valid, *,
                        p: float = 10.0, margin: float = 0.01,
                        anom_margin: float = 0.1):
    """Pairwise ranking of code p-norms against the ground-truth p-norm
    ranking of preprocessed vectors, per benchmark type; anomalous codes
    are pushed below the lowest normal score of their type."""
    s = pnorm(codes, p)  # (N,)
    same = (type_id[:, None] == type_id[None, :])
    vpair = (valid[:, None] > 0) & (valid[None, :] > 0) & same
    normal = (anomaly == 0) & (valid > 0)
    both_normal = vpair & normal[:, None] & normal[None, :]
    y = jnp.sign(norm_gt[:, None] - norm_gt[None, :])
    pair_loss = jnp.maximum(-y * (s[:, None] - s[None, :]) + margin, 0.0)
    pair_loss = jnp.where(both_normal & (y != 0), pair_loss, 0.0)
    n_pairs = jnp.sum((both_normal & (y != 0)).astype(jnp.float32))
    rank_term = jnp.sum(pair_loss) / jnp.maximum(n_pairs, 1.0)

    # anomalous below the lowest normal score of the same type
    min_normal = jnp.min(
        jnp.where(both_normal, s[None, :], jnp.inf), axis=1)  # per anchor
    anom = (anomaly == 1) & (valid > 0)
    # per-type minimum normal score
    big = jnp.where(normal, s, jnp.inf)
    # compute per-node min over same-type normals
    min_same = jnp.min(jnp.where(same & normal[None, :], s[None, :],
                                 jnp.inf), axis=1)
    anom_loss = jnp.where(
        anom & jnp.isfinite(min_same),
        jnp.maximum(s - (min_same - anom_margin), 0.0), 0.0)
    anom_term = jnp.sum(anom_loss) / jnp.maximum(
        jnp.sum(anom.astype(jnp.float32)), 1.0)
    del min_normal, big
    return rank_term + anom_term
