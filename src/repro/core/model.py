"""Perona model: autoencoder + graph aggregation + heads (paper §III-C).

enc/dec follow the Bellamy-style MLP design with a sigmoid decoder head;
``agg`` averages two graph transforms — a TransformerConv-style edge-
attention (fused edge-softmax Pallas kernel on TPU) and a TAGConv-style
hop propagation — preceded by adjacency (edge) dropout and followed by
SELU, alpha-dropout and a final linear transform. The anomaly head
scores sigma(f1(v_agg - v)); a linear probe predicts the benchmark type.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import losses as L
from repro.models import nn


@dataclasses.dataclass(frozen=True)
class PeronaConfig:
    feature_dim: int  # F' (selected metrics + one-hot types)
    edge_dim: int  # A
    n_types: int = 6
    code_dim: int = 32  # K
    hidden: int = 64
    tag_hops: int = 2
    heads: int = 4  # attention heads of the transformer conv
    edge_dropout: float = 0.1
    feature_dropout: float = 0.1
    alpha_dropout: float = 0.05
    use_root_weight: bool = True
    p_norm: float = 10.0
    cbfl_gamma: float = 2.0
    cbfl_beta: float = 0.999
    tml_margin: float = 0.3
    mrl_margin: float = 0.01
    anom_margin: float = 0.1
    loss_weights: Tuple[float, float, float, float, float] = (
        1.0, 1.0, 1.0, 1.0, 1.0)  # mse, cbfl, cel, tml, mrl
    gnn_impl: str = "reference"  # reference | pallas


def _mlp_init(init: nn.Init, dims):
    params = []
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        p, _ = nn.linear_init(init, a, b, (None, None), bias=True)
        params.append(p)
    return params


def _mlp(params, x, final=None):
    for i, p in enumerate(params):
        x = nn.linear(p, x)
        if i + 1 < len(params):
            x = jax.nn.selu(x)
    if final == "sigmoid":
        x = jax.nn.sigmoid(x)
    return x


def perona_init(cfg: PeronaConfig, key) -> Dict[str, Any]:
    init = nn.Init(key, dtype=jnp.float32)
    K, H, A, F = cfg.code_dim, cfg.hidden, cfg.edge_dim, cfg.feature_dim
    p: Dict[str, Any] = {}
    p["enc"] = _mlp_init(init, (F, H, K))
    p["dec"] = _mlp_init(init, (K, H, F))
    # TransformerConv-style params
    for nm in ("wq", "wk", "wv"):
        p[nm], _ = nn.linear_init(init, K, K, (None, None), bias=True)
    p["we_k"], _ = nn.linear_init(init, A, K, (None, None), bias=True)
    p["we_v"], _ = nn.linear_init(init, A, K, (None, None), bias=True)
    # TAGConv-style hop weights
    p["tag"] = [
        nn.linear_init(init, K, K, (None, None), bias=True)[0]
        for _ in range(cfg.tag_hops + 1)
    ]
    if cfg.use_root_weight:
        p["root"], _ = nn.linear_init(init, K, K, (None, None), bias=True)
    p["out"], _ = nn.linear_init(init, K, K, (None, None), bias=True)
    p["f1"] = _mlp_init(init, (K, H, 1))
    p["cls"], _ = nn.linear_init(init, K, cfg.n_types, (None, None),
                                 bias=True)
    return p


# ---------------------------------------------------------------------------
# Graph aggregation
# ---------------------------------------------------------------------------

def _gather_neighbors(codes, nbr):
    """codes (N,K), nbr (N,P) -> (N,P,K) with index -1 mapped to row 0
    (masked later)."""
    idx = jnp.maximum(nbr, 0)
    return codes[idx]


def _transformer_conv(p, cfg, codes, nbr, mask, edge):
    q = nn.linear(p["wq"], codes)  # (N,K)
    nb = _gather_neighbors(codes, nbr)  # (N,P,K)
    k = nn.linear(p["wk"], nb) + nn.linear(p["we_k"], edge)
    v = nn.linear(p["wv"], nb) + nn.linear(p["we_v"], edge)
    K = cfg.code_dim
    hN = cfg.heads
    hd = K // hN
    N, P = mask.shape
    if cfg.gnn_impl == "pallas":
        from repro.kernels.edge_softmax import ops as impl
    else:
        from repro.kernels.edge_softmax import ref as impl
    # both impls take the (N, H, hd) head layout directly: no per-head
    # loop, no (hN*N, P, hd) flattening
    out, _ = impl.edge_softmax_aggregate(
        q.reshape(N, hN, hd), k.reshape(N, P, hN, hd),
        v.reshape(N, P, hN, hd), mask)
    return out.reshape(N, K)


def _tag_conv(p, cfg, codes, nbr, mask):
    """Hop propagation with masked-mean neighbor aggregation."""
    out = nn.linear(p["tag"][0], codes)
    x = codes
    denom = jnp.maximum(jnp.sum(mask, 1, keepdims=True), 1.0)
    for hop in range(1, cfg.tag_hops + 1):
        nb = _gather_neighbors(x, nbr)  # (N,P,K)
        x = jnp.sum(nb * mask[..., None], axis=1) / denom
        out = out + nn.linear(p["tag"][hop], x)
    return out


def aggregate(p, cfg: PeronaConfig, codes, nbr, mask, edge, *, rng=None,
              train: bool = False, edge_dropout=None):
    """The paper's agg: edge dropout -> mean(TransformerConv, TAGConv)
    -> SELU -> alpha dropout -> linear (+root skip).

    ``edge_dropout`` optionally overrides ``cfg.edge_dropout`` with a
    traced scalar (vmapped HPO); when given, dropout is always applied.
    """
    ed = cfg.edge_dropout if edge_dropout is None else edge_dropout
    if train and rng is not None and (edge_dropout is not None
                                      or cfg.edge_dropout > 0):
        rng, sub = jax.random.split(rng)
        keep = jax.random.bernoulli(sub, 1.0 - ed, mask.shape)
        mask = mask & keep
    t_out = _transformer_conv(p, cfg, codes, nbr, mask, edge)
    g_out = _tag_conv(p, cfg, codes, nbr, mask)
    out = 0.5 * (t_out + g_out)
    out = jax.nn.selu(out)
    if train and rng is not None and cfg.alpha_dropout > 0:
        rng, sub = jax.random.split(rng)
        # SELU-preserving alpha dropout
        alpha_p = -1.7580993408473766
        keep = jax.random.bernoulli(sub, 1.0 - cfg.alpha_dropout, out.shape)
        q = 1.0 - cfg.alpha_dropout
        a = (q + alpha_p ** 2 * q * (1 - q)) ** -0.5
        b = -a * alpha_p * (1 - q)
        out = a * jnp.where(keep, out, alpha_p) + b
    out = nn.linear(p["out"], out)
    if cfg.use_root_weight:
        out = out + nn.linear(p["root"], codes)
    return jax.nn.selu(out)


# ---------------------------------------------------------------------------
# End-to-end model
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PeronaModel:
    cfg: PeronaConfig

    def init(self, key):
        return perona_init(self.cfg, key)

    def forward(self, params, batch, *, rng=None, train: bool = False,
                hypers: Optional[Dict] = None):
        """batch: dict with x, nbr, nbr_mask, edge (jnp arrays).

        ``hypers`` optionally carries *traced* scalar hyperparameters
        (``feature_dropout``, ``edge_dropout``) overriding the static
        config fields — this is what lets a vmapped HPO bucket train
        many trials in one compiled program. Dropouts present in
        ``hypers`` are always applied (rates are assumed positive), so
        the rng-split sequence matches the static path for positive
        static rates.

        Returns dict(codes, recon, agg, anom_logit, type_logits).
        """
        hypers = hypers or {}
        x = batch["x"]
        fd = hypers.get("feature_dropout", self.cfg.feature_dropout)
        if train and rng is not None and (
                "feature_dropout" in hypers or self.cfg.feature_dropout > 0):
            rng, sub = jax.random.split(rng)
            keep = jax.random.bernoulli(sub, 1.0 - fd, x.shape)
            x = x * keep / (1.0 - fd)
        codes = _mlp(params["enc"], x)
        recon = _mlp(params["dec"], codes, final="sigmoid")
        agg = aggregate(params, self.cfg, codes, batch["nbr"],
                        batch["nbr_mask"], batch["edge"], rng=rng,
                        train=train,
                        edge_dropout=hypers.get("edge_dropout"))
        anom_logit = _mlp(params["f1"], agg - codes)[:, 0]
        type_logits = nn.linear(params["cls"], codes)
        return {"codes": codes, "recon": recon, "agg": agg,
                "anom_logit": anom_logit, "type_logits": type_logits}

    def loss(self, params, batch, rng, hypers: Optional[Dict] = None):
        """``hypers`` (optional) threads traced scalar hyperparameters
        (dropouts, CBFL gamma/beta) through the loss — see forward()."""
        hypers = hypers or {}
        out = self.forward(params, batch, rng=rng, train=True,
                           hypers=hypers)
        cfg = self.cfg
        valid = batch.get("valid")
        if valid is None:
            valid = jnp.ones(batch["x"].shape[0], jnp.float32)
        w = cfg.loss_weights
        mse = L.mse_loss(out["recon"], batch["x"], valid)
        cbfl = L.class_balanced_focal_loss(
            out["anom_logit"], batch["anomaly"], valid,
            gamma=hypers.get("cbfl_gamma", cfg.cbfl_gamma),
            beta=hypers.get("cbfl_beta", cfg.cbfl_beta))
        cel = L.cross_entropy_loss(out["type_logits"], batch["type_id"],
                                   valid)
        tml = L.triplet_margin_loss(out["codes"], batch["type_id"], valid,
                                    margin=cfg.tml_margin)
        mrl = L.margin_ranking_loss(
            out["codes"], batch["norm_gt"], batch["type_id"],
            batch["anomaly"], valid, p=cfg.p_norm, margin=cfg.mrl_margin,
            anom_margin=cfg.anom_margin)
        total = (w[0] * mse + w[1] * cbfl + w[2] * cel + w[3] * tml
                 + w[4] * mrl)
        return total, {"mse": mse, "cbfl": cbfl, "cel": cel, "tml": tml,
                       "mrl": mrl}
