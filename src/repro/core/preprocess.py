"""Stateful preprocessing of benchmark metrics (paper §III-B).

Ordered steps, all statistics fitted on training executions only:

1. Unification  — convert every recording to a canonical unit per unit
   family (s, MiB, MiB/s, ratio, ...) so recordings of one metric are
   comparable across runs/machines.
2. Selection    — keep metrics with >= 2 distinct historical values and
   dispersion >= threshold (coefficient of variation by default; the
   paper says "standard deviation >= configurable threshold" — CV is the
   scale-free variant, configurable via ``std_mode="abs"``).
3. Orientation  — a metric is maximized if its max is closer to its
   median than its min (stress injection skews the tail of the
   to-be-minimized side); minimized metrics are flipped so that *larger
   is better* for every retained feature.
4. Normalization— min-max to (0,1) (boundaries from training, clipped at
   inference) — matches the sigmoid decoder head.
5. Imputation   — metrics absent for a benchmark type are filled with
   the so-far-observed (training) mean of that metric.
6. Enrichment   — one-hot encoding of the benchmark type is appended.

All stages operate on the columnar :class:`BenchmarkFrame`; record
lists are accepted everywhere and converted on entry, so the historical
record-list API keeps working.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.fingerprint.frame import BenchmarkFrame, FrameOrRecords, as_frame
from repro.fingerprint.records import BenchmarkExecution

# unit -> (canonical family, multiplier)
UNIT_TABLE: Dict[str, Tuple[str, float]] = {
    "s": ("s", 1.0), "ms": ("s", 1e-3), "us": ("s", 1e-6),
    "ns": ("s", 1e-9), "min": ("s", 60.0),
    "bytes": ("MiB", 1.0 / (1024 * 1024)), "KiB": ("MiB", 1.0 / 1024),
    "MiB": ("MiB", 1.0), "GiB": ("MiB", 1024.0), "MB": ("MiB", 0.95367),
    "KiB/s": ("MiB/s", 1.0 / 1024), "MiB/s": ("MiB/s", 1.0),
    "GiB/s": ("MiB/s", 1024.0), "MB/s": ("MiB/s", 0.95367),
    "bps": ("MiB/s", 1.0 / (8 * 1024 * 1024)),
    "Kbps": ("MiB/s", 1e3 / (8 * 1024 * 1024)),
    "Mbps": ("MiB/s", 1e6 / (8 * 1024 * 1024)),
    "Gbps": ("MiB/s", 1e9 / (8 * 1024 * 1024)),
    "%": ("ratio", 0.01), "ratio": ("ratio", 1.0),
    "K/s": ("1/s", 1e3), "iops": ("1/s", 1.0), "ops/s": ("1/s", 1.0),
    "events/s": ("1/s", 1.0), "1/s": ("1/s", 1.0),
    "count": ("count", 1.0), "events": ("count", 1.0), "ops": ("count", 1.0),
}


def unify(value: float, unit: str) -> float:
    family, mult = UNIT_TABLE.get(unit, ("unknown", 1.0))
    del family
    return float(value) * mult


def _unit_multipliers(units: Sequence[str]) -> np.ndarray:
    return np.asarray([UNIT_TABLE.get(u, ("unknown", 1.0))[1]
                       for u in units], np.float64)


def _merged_columns(frame: BenchmarkFrame
                    ) -> Tuple[List[str], np.ndarray, np.ndarray]:
    """Unify units and merge same-name metric columns (a frame keys
    columns by (name, unit); one record reports one unit per name, so at
    most one cell per row is present within a name group).

    Returns (names, values (N, G), present (N, G)); group order is
    first-appearance column order.
    """
    uni = frame.metrics * _unit_multipliers(frame.metric_units)
    pres = frame.metrics_present
    groups: Dict[str, List[int]] = {}
    for i, name in enumerate(frame.metric_names):
        groups.setdefault(name, []).append(i)
    names = list(groups)
    n = len(frame)
    values = np.zeros((n, len(names)), np.float64)
    present = np.zeros((n, len(names)), bool)
    for g, (name, cols) in enumerate(groups.items()):
        if len(cols) == 1:
            values[:, g] = np.where(pres[:, cols[0]], uni[:, cols[0]], 0.0)
            present[:, g] = pres[:, cols[0]]
        else:
            for c in cols:
                sel = pres[:, c]
                values[sel, g] = uni[sel, c]
                present[:, g] |= sel
    return names, values, present


@dataclasses.dataclass
class Preprocessor:
    std_threshold: float = 0.02
    std_mode: str = "cv"  # cv | abs
    p_norm: float = 10.0

    # fitted state
    feature_names: Optional[List[str]] = None
    benchmark_types: Optional[List[str]] = None
    maximize: Optional[np.ndarray] = None  # (F',) bool
    lo: Optional[np.ndarray] = None  # (F',)
    hi: Optional[np.ndarray] = None
    fill_mean: Optional[np.ndarray] = None  # normalized-space means
    raw_feature_count: int = 0
    edge_lo: Optional[np.ndarray] = None
    edge_hi: Optional[np.ndarray] = None
    edge_names: Optional[List[str]] = None

    # ------------------------------------------------------------------ fit
    def fit(self, data: FrameOrRecords) -> "Preprocessor":
        frame = as_frame(data)
        names, values, present = _merged_columns(frame)
        self.raw_feature_count = len(names)
        gidx = {n: i for i, n in enumerate(names)}

        selected = []
        for name in sorted(names):
            arr = values[present[:, gidx[name]], gidx[name]]
            if len(np.unique(np.round(arr, 12))) < 2:
                continue
            std = float(np.std(arr))
            if self.std_mode == "cv":
                denom = max(abs(float(np.mean(arr))), 1e-12)
                disp = std / denom
            else:
                disp = std
            if disp >= self.std_threshold:
                selected.append(name)
        self.feature_names = selected

        F = len(selected)
        self.maximize = np.zeros((F,), bool)
        self.lo = np.zeros((F,))
        self.hi = np.ones((F,))
        for i, name in enumerate(selected):
            arr = values[present[:, gidx[name]], gidx[name]]
            mx, mn, med = float(arr.max()), float(arr.min()), float(
                np.median(arr))
            self.maximize[i] = (mx - med) <= (med - mn)
            self.lo[i] = mn
            self.hi[i] = mx if mx > mn else mn + 1.0

        self.benchmark_types = sorted(
            frame.benchmark_types[c] for c in np.unique(frame.type_code))

        # normalized-space training means per feature, for imputation
        # (reuse the merged columns from selection — no second pass)
        raw, fpresent = self._select_features(frame, (names, values,
                                                      present))
        norm = self._normalize(raw)
        cnt = np.maximum(fpresent.sum(0), 1)
        self.fill_mean = (norm * fpresent).sum(0) / cnt

        # edge-attribute scaler (node metrics during the run)
        ecols = [i for i, n in enumerate(frame.node_metric_names)
                 if frame.node_metrics_present[:, i].any()]
        self.edge_names = sorted(frame.node_metric_names[i] for i in ecols)
        em = self.raw_edges(frame)
        self.edge_lo = em.min(0)
        self.edge_hi = np.where(em.max(0) > em.min(0), em.max(0),
                                em.min(0) + 1.0)
        return self

    # ------------------------------------------------------------ transform
    def raw_features(self, data: FrameOrRecords
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """Unified (pre-normalization) values of the selected features:
        (N, F') values + presence mask. Feature columns missing from the
        frame come back absent (imputed downstream)."""
        frame = as_frame(data)
        return self._select_features(frame, _merged_columns(frame))

    def _select_features(self, frame, merged):
        names, values, present = merged
        gidx = {n: i for i, n in enumerate(names)}
        F = len(self.feature_names)
        raw = np.zeros((len(frame), F))
        fpresent = np.zeros((len(frame), F), bool)
        for i, name in enumerate(self.feature_names):
            g = gidx.get(name)
            if g is None:
                continue
            raw[:, i] = values[:, g]
            fpresent[:, i] = present[:, g]
        return raw, fpresent

    def _normalize(self, raw: np.ndarray) -> np.ndarray:
        norm = (raw - self.lo) / (self.hi - self.lo)
        norm = np.clip(norm, 0.0, 1.0)
        # orientation: flip minimized metrics so larger is always better
        return np.where(self.maximize, norm, 1.0 - norm)

    def type_ids(self, frame: BenchmarkFrame) -> np.ndarray:
        """(N,) int32 indices into the fitted ``benchmark_types``."""
        tindex = {t: i for i, t in enumerate(self.benchmark_types)}
        lut = np.asarray([tindex.get(t, -1) for t in
                          frame.benchmark_types], np.int32)
        ids = lut[frame.type_code]
        if len(ids) and ids.min() < 0:
            bad = frame.benchmark_types[
                int(frame.type_code[np.argmin(ids)])]
            raise KeyError(f"benchmark type {bad!r} was not fitted")
        return ids

    def transform(self, data: FrameOrRecords) -> np.ndarray:
        """Returns x' (N, F' + n_types) in (0,1)."""
        frame = as_frame(data)
        raw, present = self.raw_features(frame)
        norm = self._normalize(raw)
        norm = np.where(present, norm, self.fill_mean)
        onehot = np.zeros((len(frame), len(self.benchmark_types)))
        onehot[np.arange(len(frame)), self.type_ids(frame)] = 1.0
        return np.concatenate([norm, onehot], axis=1)

    def raw_edges(self, data: FrameOrRecords) -> np.ndarray:
        """Raw (unscaled) node-metric matrix in fitted ``edge_names``
        column order; absent gauges are 0 (as in the record path)."""
        frame = as_frame(data)
        nidx = {n: i for i, n in enumerate(frame.node_metric_names)}
        em = np.zeros((len(frame), len(self.edge_names)))
        for j, name in enumerate(self.edge_names):
            c = nidx.get(name)
            if c is None:
                continue
            em[:, j] = np.where(frame.node_metrics_present[:, c],
                                frame.node_metrics[:, c], 0.0)
        return em

    def transform_edges(self, data: FrameOrRecords) -> np.ndarray:
        em = self.raw_edges(data)
        return np.clip((em - self.edge_lo) / (self.edge_hi - self.edge_lo),
                       0.0, 1.0)

    # ---------------------------------------------------------------- info
    @property
    def n_selected(self) -> int:
        return len(self.feature_names or ())

    @property
    def feature_dim(self) -> int:
        return self.n_selected + len(self.benchmark_types or ())

    def type_id(self, r: BenchmarkExecution) -> int:
        return self.benchmark_types.index(r.benchmark_type)

    def groundtruth_norm(self, x: np.ndarray) -> np.ndarray:
        """p-norm (p=10) of preprocessed vectors — the ranking ground
        truth of §III-D (computed on the metric block, sans one-hot)."""
        feats = x[..., : self.n_selected]
        return np.power(
            np.power(np.abs(feats), self.p_norm).sum(-1),
            1.0 / self.p_norm)

    def aspect_slices(self) -> Dict[str, np.ndarray]:
        """Feature indices per resource aspect (cpu/memory/disk/network)."""
        prefix_aspect = {
            "cpu.": "cpu", "mem.": "memory", "fio.": "disk",
            "ioping.": "disk", "qperf.": "network", "iperf3.": "network",
        }
        out: Dict[str, List[int]] = {}
        for i, name in enumerate(self.feature_names):
            for pre, asp in prefix_aspect.items():
                if name.startswith(pre):
                    out.setdefault(asp, []).append(i)
        return {k: np.asarray(v) for k, v in out.items()}
