"""Benchmark-execution graphs (paper §III-C), TPU-friendly dense layout.

Graphs are composed per (benchmark type x compute instance): the
chronologically sorted executions of one type on one machine form a
chain, and each node receives forward edges from its P=3 immediate
predecessors. Edge attributes concatenate the source run's low-level
machine metrics with encodings of the time interval between the pair.

Because the in-degree is fixed, the whole dataset is one dense batch:
  x (N, F'), nbr (N, P) int32 (-1 = missing), edge (N, P, A),
  types/labels/norm ground truth per node — no scatter/gather graphs
(TPU adaptation; DESIGN.md §3).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.preprocess import Preprocessor
from repro.fingerprint.records import BenchmarkExecution

P_PREDECESSORS = 3


@dataclasses.dataclass
class PeronaBatch:
    x: np.ndarray  # (N, F') preprocessed features
    type_id: np.ndarray  # (N,) int32 benchmark type
    anomaly: np.ndarray  # (N,) int32 0/1 ground truth (stress marker)
    nbr: np.ndarray  # (N, P) int32 predecessor indices, -1 missing
    nbr_mask: np.ndarray  # (N, P) bool
    edge: np.ndarray  # (N, P, A) edge attributes in (0,1)
    norm_gt: np.ndarray  # (N,) ranking ground truth (p-norm of x')
    machine: List[str]  # (N,) node names
    chain: np.ndarray  # (N,) int32 chain id (type x machine)

    def __len__(self) -> int:
        return len(self.x)

    def subset(self, idx: np.ndarray) -> "PeronaBatch":
        """Subset *with remapped edges* (edges to excluded nodes are
        dropped)."""
        idx = np.asarray(idx)
        remap = -np.ones(len(self.x), np.int64)
        remap[idx] = np.arange(len(idx))
        nbr = np.where(self.nbr >= 0, remap[self.nbr], -1)[idx]
        return PeronaBatch(
            x=self.x[idx], type_id=self.type_id[idx],
            anomaly=self.anomaly[idx], nbr=nbr.astype(np.int32),
            nbr_mask=nbr >= 0, edge=self.edge[idx],
            norm_gt=self.norm_gt[idx],
            machine=[self.machine[i] for i in idx],
            chain=self.chain[idx])


def _time_encodings(dt: float, t_src: float) -> List[float]:
    hod = (t_src / 3600.0) % 24.0
    return [
        float(np.log1p(dt) / 12.0),
        float(min(dt / 3600.0, 1.0)),
        0.5 + 0.5 * float(np.sin(2 * np.pi * hod / 24)),
        0.5 + 0.5 * float(np.cos(2 * np.pi * hod / 24)),
    ]


def build_graphs(records: Sequence[BenchmarkExecution],
                 preproc: Preprocessor) -> PeronaBatch:
    x = preproc.transform(records)
    edge_feats = preproc.transform_edges(records)
    A = edge_feats.shape[1] + 4
    N = len(records)
    type_id = np.asarray([preproc.type_id(r) for r in records], np.int32)
    anomaly = np.asarray([int(r.stressed) for r in records], np.int32)
    norm_gt = preproc.groundtruth_norm(x)

    chains: Dict[Tuple[str, str], List[int]] = {}
    for i, r in enumerate(records):
        chains.setdefault((r.benchmark_type, r.machine), []).append(i)

    nbr = -np.ones((N, P_PREDECESSORS), np.int32)
    edge = np.zeros((N, P_PREDECESSORS, A), np.float32)
    chain_id = np.zeros((N,), np.int32)
    for cid, (key, idxs) in enumerate(sorted(chains.items())):
        idxs = sorted(idxs, key=lambda i: records[i].t)
        for pos, i in enumerate(idxs):
            chain_id[i] = cid
            preds = idxs[max(0, pos - P_PREDECESSORS):pos]
            for p, j in enumerate(reversed(preds)):
                nbr[i, p] = j
                dt = max(records[i].t - records[j].t, 0.0)
                edge[i, p] = np.concatenate([
                    edge_feats[j],
                    np.asarray(_time_encodings(dt, records[j].t)),
                ])
    return PeronaBatch(
        x=x.astype(np.float32), type_id=type_id, anomaly=anomaly, nbr=nbr,
        nbr_mask=nbr >= 0, edge=edge, norm_gt=norm_gt.astype(np.float32),
        machine=[r.machine for r in records], chain=chain_id)


def chronological_split(records: Sequence[BenchmarkExecution],
                        fractions=(0.6, 0.2, 0.2)):
    """Per-(machine x type) chronological split (every node appears in
    every split — the paper's node-name stratification — while graph
    edges stay causal)."""
    chains: Dict[Tuple[str, str], List[int]] = {}
    for i, r in enumerate(records):
        chains.setdefault((r.benchmark_type, r.machine), []).append(i)
    train, val, test = [], [], []
    for idxs in chains.values():
        idxs = sorted(idxs, key=lambda i: records[i].t)
        n = len(idxs)
        a = int(n * fractions[0])
        b = int(n * (fractions[0] + fractions[1]))
        train += idxs[:a]
        val += idxs[a:b]
        test += idxs[b:]
    pick = lambda ids: [records[i] for i in sorted(ids)]
    return pick(train), pick(val), pick(test)
