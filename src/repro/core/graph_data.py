"""Benchmark-execution graphs (paper §III-C), TPU-friendly dense layout.

Graphs are composed per (benchmark type x compute instance): the
chronologically sorted executions of one type on one machine form a
chain, and each node receives forward edges from its P=3 immediate
predecessors. Edge attributes concatenate the source run's low-level
machine metrics with encodings of the time interval between the pair.

Because the in-degree is fixed, the whole dataset is one dense batch:
  x (N, F'), nbr (N, P) int32 (-1 = missing), edge (N, P, A),
  types/labels/norm ground truth per node — no scatter/gather graphs
(TPU adaptation; DESIGN.md §3).

Construction is columnar: chain membership, predecessor indices and
edge attributes are derived with one lexsort + shifted-array ops over
the :class:`BenchmarkFrame` (record lists are converted on entry).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.preprocess import Preprocessor
from repro.fingerprint.frame import BenchmarkFrame, FrameOrRecords, as_frame
from repro.fingerprint.records import BenchmarkExecution

P_PREDECESSORS = 3


@dataclasses.dataclass
class PeronaBatch:
    x: np.ndarray  # (N, F') preprocessed features
    type_id: np.ndarray  # (N,) int32 benchmark type
    anomaly: np.ndarray  # (N,) int32 0/1 ground truth (stress marker)
    nbr: np.ndarray  # (N, P) int32 predecessor indices, -1 missing
    nbr_mask: np.ndarray  # (N, P) bool
    edge: np.ndarray  # (N, P, A) edge attributes in (0,1)
    norm_gt: np.ndarray  # (N,) ranking ground truth (p-norm of x')
    machine: List[str]  # (N,) node names
    chain: np.ndarray  # (N,) int32 chain id (type x machine)

    def __len__(self) -> int:
        return len(self.x)

    def subset(self, idx: np.ndarray) -> "PeronaBatch":
        """Subset *with remapped edges* (edges to excluded nodes are
        dropped)."""
        idx = np.asarray(idx)
        remap = -np.ones(len(self.x), np.int64)
        remap[idx] = np.arange(len(idx))
        nbr = np.where(self.nbr >= 0, remap[self.nbr], -1)[idx]
        return PeronaBatch(
            x=self.x[idx], type_id=self.type_id[idx],
            anomaly=self.anomaly[idx], nbr=nbr.astype(np.int32),
            nbr_mask=nbr >= 0, edge=self.edge[idx],
            norm_gt=self.norm_gt[idx],
            machine=[self.machine[i] for i in idx],
            chain=self.chain[idx])


def time_encodings(dt: np.ndarray, t_src: np.ndarray) -> np.ndarray:
    """(..., 4) time-interval/hour-of-day encodings, vectorized."""
    dt = np.asarray(dt, np.float64)
    t_src = np.asarray(t_src, np.float64)
    hod = (t_src / 3600.0) % 24.0
    ang = 2 * np.pi * hod / 24
    return np.stack([
        np.log1p(dt) / 12.0,
        np.minimum(dt / 3600.0, 1.0),
        0.5 + 0.5 * np.sin(ang),
        0.5 + 0.5 * np.cos(ang),
    ], axis=-1)


@dataclasses.dataclass
class GraphStructure:
    """Statistics-free graph topology of a frame: predecessor indices
    within each (benchmark type x machine) chain + raw time terms.
    Feature/edge *values* are attached separately (numpy in
    ``build_graphs``, inside the jit in ``serving.FingerprintEngine``).
    """

    nbr: np.ndarray  # (N, P) int32, -1 = missing
    nbr_mask: np.ndarray  # (N, P) bool
    chain: np.ndarray  # (N,) int32 dense chain ids
    dt: np.ndarray  # (N, P) float64 time gap to predecessor (0 if none)
    t_src: np.ndarray  # (N, P) float64 predecessor timestamp (0 if none)


def chain_structure(key: np.ndarray, t: np.ndarray,
                    p: int = P_PREDECESSORS) -> GraphStructure:
    """Core topology derivation from a per-row chain key + timestamps:
    each row's P predecessors are the immediately preceding rows of the
    same chain in stable (t, row) order. ``graph_structure`` wraps this
    for frames; the fleet service calls it directly on store-gathered
    arrays (no intermediate frame)."""
    n = len(key)
    key = np.asarray(key, np.int64)
    t = np.asarray(t, np.float64)
    chain = np.unique(key, return_inverse=True)[1].astype(np.int32)

    # stable (chain, t, row) order; the record path sorts chains by key
    # and chain members chronologically with stable ties
    order = np.lexsort((np.arange(n), t, key))
    key_sorted = key[order]
    boundary = np.ones(n, bool)
    boundary[1:] = key_sorted[1:] != key_sorted[:-1]
    chain_start = np.maximum.accumulate(
        np.where(boundary, np.arange(n), 0))

    nbr = -np.ones((n, p), np.int32)
    dt = np.zeros((n, p), np.float64)
    t_src = np.zeros((n, p), np.float64)
    pos = np.arange(n)
    for q in range(p):
        src = pos - 1 - q
        valid = src >= chain_start
        j = np.where(valid, order[np.maximum(src, 0)], -1)
        rows = order[valid]
        nbr[rows, q] = j[valid]
        jj = j[valid]
        dt[rows, q] = np.maximum(t[rows] - t[jj], 0.0)
        t_src[rows, q] = t[jj]
    return GraphStructure(nbr=nbr, nbr_mask=nbr >= 0, chain=chain,
                          dt=dt, t_src=t_src)


def graph_structure(frame: BenchmarkFrame,
                    p: int = P_PREDECESSORS) -> GraphStructure:
    # chain key ordered like the record path: sorted (type name, machine
    # name) tuples -> ranks of the sorted vocabularies
    bt_rank = np.argsort(np.argsort(frame.benchmark_types))
    m_rank = np.argsort(np.argsort(frame.machines))
    key = (bt_rank[frame.type_code].astype(np.int64)
           * max(len(frame.machines), 1) + m_rank[frame.machine_code])
    return chain_structure(key, frame.t, p)


def build_graphs(data: FrameOrRecords,
                 preproc: Preprocessor) -> PeronaBatch:
    frame = as_frame(data)
    x = preproc.transform(frame)
    edge_feats = preproc.transform_edges(frame)
    n = len(frame)
    a = edge_feats.shape[1] + 4
    type_id = preproc.type_ids(frame)
    anomaly = frame.stressed.astype(np.int32)
    norm_gt = preproc.groundtruth_norm(x)

    gs = graph_structure(frame)
    edge = np.zeros((n, P_PREDECESSORS, a), np.float32)
    src = np.maximum(gs.nbr, 0)
    vals = np.concatenate(
        [edge_feats[src], time_encodings(gs.dt, gs.t_src)], axis=-1)
    edge[:] = np.where(gs.nbr_mask[..., None], vals, 0.0)
    return PeronaBatch(
        x=x.astype(np.float32), type_id=type_id, anomaly=anomaly,
        nbr=gs.nbr, nbr_mask=gs.nbr_mask, edge=edge,
        norm_gt=norm_gt.astype(np.float32),
        machine=frame.machine_names(), chain=gs.chain)


def chronological_split(data: FrameOrRecords, fractions=(0.6, 0.2, 0.2)):
    """Per-(machine x type) chronological split (every node appears in
    every split — the paper's node-name stratification — while graph
    edges stay causal). Frames in, frames out; record lists in, record
    lists out."""
    frame = as_frame(data)
    is_frame = isinstance(data, BenchmarkFrame)
    n = len(frame)
    key = (frame.type_code.astype(np.int64)
           * max(len(frame.machines), 1) + frame.machine_code)
    order = np.lexsort((np.arange(n), frame.t, key))
    key_sorted = key[order]
    boundary = np.ones(n, bool)
    boundary[1:] = key_sorted[1:] != key_sorted[:-1]
    start = np.maximum.accumulate(np.where(boundary, np.arange(n), 0))
    # chain length / position via next-boundary distance
    idx_of_start = np.where(boundary)[0]
    lengths = np.diff(np.append(idx_of_start, n))
    length_per_row = np.repeat(lengths, lengths)
    pos = np.arange(n) - start
    a = (length_per_row * fractions[0]).astype(np.int64)
    b = (length_per_row * (fractions[0] + fractions[1])).astype(np.int64)
    split_sorted = np.where(pos < a, 0, np.where(pos < b, 1, 2))
    split = np.empty(n, np.int64)
    split[order] = split_sorted

    out = []
    for s in range(3):
        idx = np.sort(np.nonzero(split == s)[0])
        sub = frame.select(idx)
        out.append(sub if is_frame else sub.to_records())
    return tuple(out)
