"""Perona core: robust infrastructure fingerprinting (paper §III).

Pipeline: standardized benchmark metrics -> stateful preprocessing
(unify / select / orient / normalize / impute / type-enrich) ->
autoencoder codes -> graph-contextual aggregation over benchmark
execution chains -> anomaly scoring + aspect-based ranking, trained with
the paper's five-task additive loss (MSE + CBFL + TML + CEL + MRL).
"""

from repro.core.preprocess import Preprocessor
from repro.core.model import PeronaModel, PeronaConfig
from repro.core.graph_data import build_graphs, PeronaBatch
from repro.core.ranking import aspect_scores, rank_machines

__all__ = [
    "Preprocessor",
    "PeronaModel",
    "PeronaConfig",
    "build_graphs",
    "PeronaBatch",
    "aspect_scores",
    "rank_machines",
]
