"""Aspect-based resource ranking (paper §III-D application).

A learned code's quality score is its p-norm (p=10); scores aggregate
per (machine x benchmark type), and benchmark types map onto resource
aspects (cpu / memory / disk / network) for fine-granular ranking.
"""

from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

ASPECT_OF_TYPE = {
    "sysbench-cpu": "cpu",
    "sysbench-memory": "memory",
    "fio": "disk",
    "ioping": "disk",
    "qperf": "network",
    "iperf3": "network",
}


def code_scores(codes: np.ndarray, p: float = 10.0) -> np.ndarray:
    return np.power(
        np.power(np.abs(codes) + 1e-12, p).sum(-1), 1.0 / p)


def aspect_scores(codes: np.ndarray, type_names: Sequence[str],
                  machines: Sequence[str], p: float = 10.0
                  ) -> Dict[str, Dict[str, float]]:
    """Returns {machine: {aspect: mean score}}."""
    s = code_scores(codes, p)
    out: Dict[str, Dict[str, List[float]]] = {}
    for score, btype, machine in zip(s, type_names, machines):
        aspect = ASPECT_OF_TYPE[btype]
        out.setdefault(machine, {}).setdefault(aspect, []).append(
            float(score))
    return {m: {a: float(np.mean(v)) for a, v in per.items()}
            for m, per in out.items()}


def rank_machines(scores: Dict[str, Dict[str, float]],
                  aspect: str = None) -> List[str]:
    """Machines ranked best-first by mean (or per-aspect) score."""
    def key(m):
        per = scores[m]
        if aspect is not None:
            return per.get(aspect, 0.0)
        return float(np.mean(list(per.values())))

    return sorted(scores, key=key, reverse=True)


def machine_score_vector(scores: Dict[str, Dict[str, float]],
                         machine: str) -> np.ndarray:
    """(cpu, memory, disk, network) score vector for tuner integration."""
    per = scores.get(machine, {})
    return np.asarray([per.get(a, 0.0)
                       for a in ("cpu", "memory", "disk", "network")])


def machine_score_matrix(scores: Dict[str, Dict[str, float]],
                         machines: Sequence[str]) -> np.ndarray:
    """(len(machines), 4) stacked score vectors — the batched-input
    form consumed by the optimizer's vmapped acquisition weighting."""
    if not len(machines):
        return np.zeros((0, 4))
    return np.stack([machine_score_vector(scores, m) for m in machines])
