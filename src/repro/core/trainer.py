"""Perona training loop (Adam, additive multi-task loss, <=100 epochs).

The paper trains with batch size 16 over the per-(type x instance)
benchmark graphs; the §IV-C acquisition yields 18 such chains, so one
full batch covers the dataset — we train full-batch with jit'd epochs
and early stopping on the validation total loss.

Checkpoint selection uses the validation *outlier F1* (total loss as
tie-break): the five-objective total is a noisy proxy for the anomaly
head, and selecting on it makes the reported outlier quality swing
widely across training seeds. When the validation split has no stressed
runs, F1 is constantly 0 and selection falls back to the loss.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph_data import PeronaBatch
from repro.core.model import PeronaConfig, PeronaModel
from repro.optim.adamw import AdamW


def batch_to_jnp(batch: PeronaBatch) -> Dict[str, jnp.ndarray]:
    return {
        "x": jnp.asarray(batch.x),
        "type_id": jnp.asarray(batch.type_id),
        "anomaly": jnp.asarray(batch.anomaly),
        "nbr": jnp.asarray(batch.nbr),
        "nbr_mask": jnp.asarray(batch.nbr_mask),
        "edge": jnp.asarray(batch.edge),
        "norm_gt": jnp.asarray(batch.norm_gt),
    }


@dataclasses.dataclass
class TrainResult:
    params: dict
    history: list
    best_epoch: int


def train_perona(model: PeronaModel, train_batch: PeronaBatch,
                 val_batch: Optional[PeronaBatch] = None, *,
                 epochs: int = 100, lr: float = 3e-3,
                 weight_decay: float = 1e-4, patience: int = 25,
                 seed: int = 0, verbose: bool = False) -> TrainResult:
    params = model.init(jax.random.PRNGKey(seed))
    opt = AdamW(lr=lr, b2=0.999, weight_decay=weight_decay, clip_norm=5.0)
    state = opt.init(params)
    tb = batch_to_jnp(train_batch)
    vb = batch_to_jnp(val_batch) if val_batch is not None else None

    @jax.jit
    def step(params, state, rng):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(params, tb, rng)
        params, state, om = opt.update(grads, state, params)
        return params, state, loss, metrics

    @jax.jit
    def val_scores(params):
        loss, metrics = model.loss(params, vb, jax.random.PRNGKey(0))
        out = model.forward(params, vb, train=False)
        return loss, out["anom_logit"]

    def f1_outlier(logits, y):
        pred = np.asarray(logits) >= 0.0  # sigmoid(x) >= 0.5
        tp = int(np.sum(pred & (y == 1)))
        fp = int(np.sum(pred & (y == 0)))
        fn = int(np.sum(~pred & (y == 1)))
        prec = tp / max(tp + fp, 1)
        rec = tp / max(tp + fn, 1)
        return 2 * prec * rec / max(prec + rec, 1e-9)

    y_val = (np.asarray(val_batch.anomaly)
             if val_batch is not None else None)
    rng = jax.random.PRNGKey(seed + 1)
    history = []
    loss_best = (np.inf, 0)  # early-stopping tracker (val total loss)
    best = ((-1.0, -np.inf), params, 0)  # selection: (f1, -loss)
    for epoch in range(epochs):
        rng, sub = jax.random.split(rng)
        params, state, loss, metrics = step(params, state, sub)
        entry = {"epoch": epoch, "train_loss": float(loss)}
        if vb is not None:
            vl, logits = val_scores(params)
            vl = float(vl)
            f1 = f1_outlier(logits, y_val)
            entry["val_loss"] = vl
            entry["val_f1_outlier"] = f1
            if (f1, -vl) > best[0]:
                best = ((f1, -vl),
                        jax.tree_util.tree_map(lambda x: x, params),
                        epoch)
            if vl < loss_best[0]:
                loss_best = (vl, epoch)
            elif epoch - loss_best[1] > patience:
                history.append(entry)
                break
        history.append(entry)
        if verbose and epoch % 10 == 0:
            print(entry, {k: round(float(v), 4)
                          for k, v in metrics.items()})
    params = best[1] if vb is not None else params
    return TrainResult(params=params, history=history,
                       best_epoch=best[2] if vb is not None else epochs - 1)


def evaluate(model: PeronaModel, params, batch: PeronaBatch) -> Dict:
    """§IV-C metrics: recon MSE, type accuracy, outlier P/R/F1, weighted
    accuracy."""
    b = batch_to_jnp(batch)
    out = model.forward(params, b, train=False)
    x = np.asarray(b["x"])
    recon = np.asarray(out["recon"])
    mse = float(np.mean((recon - x) ** 2))
    type_pred = np.asarray(jnp.argmax(out["type_logits"], -1))
    type_acc = float(np.mean(type_pred == batch.type_id))
    prob = np.asarray(jax.nn.sigmoid(out["anom_logit"]))
    pred = (prob >= 0.5).astype(int)
    y = batch.anomaly

    def f1(cls):
        tp = int(np.sum((pred == cls) & (y == cls)))
        fp = int(np.sum((pred == cls) & (y != cls)))
        fn = int(np.sum((pred != cls) & (y == cls)))
        prec = tp / max(tp + fp, 1)
        rec = tp / max(tp + fn, 1)
        return 2 * prec * rec / max(prec + rec, 1e-9)

    acc = float(np.mean(pred == y))
    n0, n1 = int(np.sum(y == 0)), int(np.sum(y == 1))
    weighted_acc = float(
        (np.mean(pred[y == 0] == 0) * n0 + np.mean(pred[y == 1] == 1) * n1)
        / max(n0 + n1, 1)) if n1 else acc
    return {
        "mse": mse,
        "type_accuracy": type_acc,
        "f1_normal": f1(0),
        "f1_outlier": f1(1),
        "accuracy": acc,
        "weighted_accuracy": weighted_acc,
        "codes": np.asarray(out["codes"]),
        "anomaly_prob": prob,
    }
