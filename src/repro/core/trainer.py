"""Perona training loop (Adam, additive multi-task loss, <=100 epochs).

The paper trains with batch size 16 over the per-(type x instance)
benchmark graphs; the §IV-C acquisition yields 18 such chains, so one
full batch covers the dataset — we train full-batch.

``train_perona`` is device-resident: the whole epoch loop is a
``jax.lax.scan`` inside ONE jit-compiled call — on-device validation
loss, on-device outlier F1 (jnp confusion counts), on-device
best-checkpoint selection (tree_map + jnp.where on the (f1, -loss)
rank) and early stopping as a masked "stopped" flag in the carry. No
per-epoch host transfers happen; the history arrays come back in a
single device->host fetch after the scan. Scalar hyperparameters
(dropouts, CBFL gamma/beta, lr, weight decay) are threaded through the
model/optimizer as *traced* values, so the same compiled program serves
every trial of an HPO bucket (see ``tuning/hpo.py``), and compiled
trainers are cached across calls per (model config, epochs, patience,
shapes).

The legacy per-epoch host loop is preserved as
``train_perona_reference`` and pinned by a parity test
(``tests/test_trainer_scan.py``), mirroring PR 1's ``run_reference``
pattern.

Checkpoint selection uses the validation *outlier F1* (total loss as
tie-break): the five-objective total is a noisy proxy for the anomaly
head, and selecting on it makes the reported outlier quality swing
widely across training seeds. When the validation split has no stressed
runs, F1 is constantly 0 and selection falls back to the loss.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph_data import PeronaBatch
from repro.core.model import PeronaConfig, PeronaModel
from repro.obs.jaxstat import JitSite
from repro.optim.adamw import AdamW


def batch_to_jnp(batch: PeronaBatch) -> Dict[str, jnp.ndarray]:
    return {
        "x": jnp.asarray(batch.x),
        "type_id": jnp.asarray(batch.type_id),
        "anomaly": jnp.asarray(batch.anomaly),
        "nbr": jnp.asarray(batch.nbr),
        "nbr_mask": jnp.asarray(batch.nbr_mask),
        "edge": jnp.asarray(batch.edge),
        "norm_gt": jnp.asarray(batch.norm_gt),
    }


#: Ticked once per tracing of the scanned trainer (shared by the single
#: trainer and the vmapped HPO buckets). A registry-backed
#: :class:`repro.obs.jaxstat.JitSite`: ``tick()`` runs at trace time
#: only, ``count`` reads the tracing counter, and wrapping the
#: compiled call in ``dispatch()`` splits its wall time into
#: compile-vs-run registry counters.
TRAINER_TRACES = JitSite("core.trainer")


@dataclasses.dataclass
class TrainResult:
    params: dict
    history: list
    best_epoch: int
    stats: Optional[Dict] = None  # device_dispatches / traced (scanned)


def _tree_where(pred, a, b):
    """Scalar-predicate select over matching pytrees."""
    return jax.tree_util.tree_map(lambda x, y: jnp.where(pred, x, y), a, b)


def _f1_outlier(logits, y):
    """On-device outlier F1 from jnp confusion counts.

    Matches the host reference: sigmoid(x) >= 0.5 <=> logit >= 0."""
    pred = logits >= 0.0
    pos = y == 1
    tp = jnp.sum(pred & pos).astype(jnp.float32)
    fp = jnp.sum(pred & ~pos).astype(jnp.float32)
    fn = jnp.sum(~pred & pos).astype(jnp.float32)
    prec = tp / jnp.maximum(tp + fp, 1.0)
    rec = tp / jnp.maximum(tp + fn, 1.0)
    return 2.0 * prec * rec / jnp.maximum(prec + rec, 1e-9)


def model_hypers(cfg: PeronaConfig, lr: float, weight_decay: float) -> Dict:
    """Scalar hypers as traced f32 leaves. Dropout keys are included
    only when the static rate is positive, so the rng-split sequence
    matches the static-config code path exactly."""
    h = {
        "cbfl_gamma": jnp.float32(cfg.cbfl_gamma),
        "cbfl_beta": jnp.float32(cfg.cbfl_beta),
        "lr": jnp.float32(lr),
        "weight_decay": jnp.float32(weight_decay),
    }
    if cfg.feature_dropout > 0:
        h["feature_dropout"] = jnp.float32(cfg.feature_dropout)
    if cfg.edge_dropout > 0:
        h["edge_dropout"] = jnp.float32(cfg.edge_dropout)
    return h


def canonical_model(model: PeronaModel) -> PeronaModel:
    """Model with the traced scalar hypers pinned to canonical values.

    The compiled trainer receives dropouts / CBFL gamma / beta as traced
    inputs, so its program depends only on the *positivity* of the
    dropout rates (a static rng-split branch), not their values. Keying
    the compile cache on this canonical config lets trials that differ
    only in scalar hypers share one executable.
    """
    cfg = model.cfg
    return PeronaModel(dataclasses.replace(
        cfg,
        feature_dropout=0.1 if cfg.feature_dropout > 0 else 0.0,
        edge_dropout=0.1 if cfg.edge_dropout > 0 else 0.0,
        cbfl_gamma=2.0, cbfl_beta=0.999))


@functools.lru_cache(maxsize=64)
def _make_train_fn(model: PeronaModel, epochs: int, patience: int,
                   has_val: bool):
    """Pure scanned training function, suitable for jit and vmap.

    Signature (has_val): f(params0, tb, vb, y_val, hypers, key)
    Signature (no val):  f(params0, tb, hypers, key)

    ``hypers`` is a dict of traced scalars (see ``model_hypers``);
    ``key`` is the epoch-rng key (reference: PRNGKey(seed + 1)).
    """

    def train_val(params0, tb, vb, y_val, hypers, key):
        TRAINER_TRACES.tick()
        opt = AdamW(lr=hypers["lr"], b2=0.999,
                    weight_decay=hypers["weight_decay"], clip_norm=5.0)
        loss_fn = lambda p, b, r: model.loss(p, b, r, hypers=hypers)

        def body(carry, epoch):
            (params, state, rng, best_p, best_f1, best_nl, best_e,
             ls_best, ls_epoch, stopped) = carry
            rng, sub = jax.random.split(rng)
            active = ~stopped
            (tl, _), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, tb, sub)
            new_p, new_s, _ = opt.update(grads, state, params)
            params = _tree_where(active, new_p, params)
            state = _tree_where(active, new_s, state)
            vl, _ = loss_fn(params, vb, jax.random.PRNGKey(0))
            logits = model.forward(params, vb, train=False)["anom_logit"]
            f1 = _f1_outlier(logits, y_val)
            # checkpoint selection: lexicographic (f1, -loss) max
            better = active & ((f1 > best_f1)
                               | ((f1 == best_f1) & (-vl > best_nl)))
            best_p = _tree_where(better, params, best_p)
            best_f1 = jnp.where(better, f1, best_f1)
            best_nl = jnp.where(better, -vl, best_nl)
            best_e = jnp.where(better, epoch, best_e)
            # early stopping on the val total loss ("elif": the patience
            # check only fires on non-improving epochs)
            improved = vl < ls_best
            stop_now = active & ~improved & (epoch - ls_epoch > patience)
            ls_best = jnp.where(active & improved, vl, ls_best)
            ls_epoch = jnp.where(active & improved, epoch, ls_epoch)
            stopped = stopped | stop_now
            carry = (params, state, rng, best_p, best_f1, best_nl,
                     best_e, ls_best, ls_epoch, stopped)
            return carry, (tl, vl, f1, active)

        carry0 = (params0, opt.init(params0), key, params0,
                  jnp.float32(-1.0), jnp.float32(-jnp.inf),
                  jnp.int32(0), jnp.float32(jnp.inf), jnp.int32(0),
                  jnp.bool_(False))
        carry, ys = jax.lax.scan(body, carry0, jnp.arange(epochs))
        return {"params": carry[3], "final_params": carry[0],
                "best_epoch": carry[6], "best_f1": carry[4],
                "best_neg_loss": carry[5], "train_loss": ys[0],
                "val_loss": ys[1], "val_f1": ys[2], "active": ys[3]}

    def train_noval(params0, tb, hypers, key):
        TRAINER_TRACES.tick()
        opt = AdamW(lr=hypers["lr"], b2=0.999,
                    weight_decay=hypers["weight_decay"], clip_norm=5.0)
        loss_fn = lambda p, b, r: model.loss(p, b, r, hypers=hypers)

        def body(carry, epoch):
            params, state, rng = carry
            rng, sub = jax.random.split(rng)
            (tl, _), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, tb, sub)
            params, state, _ = opt.update(grads, state, params)
            return (params, state, rng), tl

        carry, tl = jax.lax.scan(
            body, (params0, opt.init(params0), key), jnp.arange(epochs))
        return {"params": carry[0], "train_loss": tl}

    return train_val if has_val else train_noval


@functools.lru_cache(maxsize=64)
def _jitted_train_fn(model: PeronaModel, epochs: int, patience: int,
                     has_val: bool):
    # the initial params carry is donated: one training run keeps a
    # single live copy of (params, opt state) on device
    return jax.jit(_make_train_fn(model, epochs, patience, has_val),
                   donate_argnums=(0,))


def train_perona(model: PeronaModel, train_batch: PeronaBatch,
                 val_batch: Optional[PeronaBatch] = None, *,
                 epochs: int = 100, lr: float = 3e-3,
                 weight_decay: float = 1e-4, patience: int = 25,
                 seed: int = 0, verbose: bool = False) -> TrainResult:
    """Scanned, device-resident training: one compiled dispatch per run."""
    params0 = model.init(jax.random.PRNGKey(seed))
    tb = batch_to_jnp(train_batch)
    hypers = model_hypers(model.cfg, lr, weight_decay)
    key = jax.random.PRNGKey(seed + 1)
    has_val = val_batch is not None
    fn = _jitted_train_fn(canonical_model(model), epochs, patience,
                          has_val)
    t0 = TRAINER_TRACES.count
    c0, r0 = TRAINER_TRACES.compile_seconds, TRAINER_TRACES.run_seconds
    with TRAINER_TRACES.dispatch(
            "trainer.train",
            args={"epochs": epochs, "has_val": has_val}):
        if has_val:
            vb = batch_to_jnp(val_batch)
            y_val = jnp.asarray(val_batch.anomaly)
            out = fn(params0, tb, vb, y_val, hypers, key)
        else:
            out = fn(params0, tb, hypers, key)
    stats = {"device_dispatches": 1,
             "traced": TRAINER_TRACES.count - t0,
             "compile_s": TRAINER_TRACES.compile_seconds - c0,
             "run_s": TRAINER_TRACES.run_seconds - r0}

    tl = np.asarray(out["train_loss"])
    history = []
    if has_val:
        vl = np.asarray(out["val_loss"])
        f1 = np.asarray(out["val_f1"])
        active = np.asarray(out["active"])
        for e in range(epochs):
            if not active[e]:
                break
            history.append({"epoch": e, "train_loss": float(tl[e]),
                            "val_loss": float(vl[e]),
                            "val_f1_outlier": float(f1[e])})
        params = out["params"]
        best_epoch = int(out["best_epoch"])
    else:
        history = [{"epoch": e, "train_loss": float(tl[e])}
                   for e in range(epochs)]
        params = out["params"]
        best_epoch = epochs - 1
    if verbose:
        for entry in history[::10]:
            print(entry)
    return TrainResult(params=params, history=history,
                       best_epoch=best_epoch, stats=stats)


def train_perona_reference(model: PeronaModel, train_batch: PeronaBatch,
                           val_batch: Optional[PeronaBatch] = None, *,
                           epochs: int = 100, lr: float = 3e-3,
                           weight_decay: float = 1e-4, patience: int = 25,
                           seed: int = 0,
                           verbose: bool = False) -> TrainResult:
    """Legacy host-driven loop: one jitted step dispatch per epoch, val
    scoring and checkpoint selection on host. Kept as the parity oracle
    and the sequential-HPO baseline for ``benchmarks/bench_tuning.py``.
    """
    params = model.init(jax.random.PRNGKey(seed))
    opt = AdamW(lr=lr, b2=0.999, weight_decay=weight_decay, clip_norm=5.0)
    state = opt.init(params)
    tb = batch_to_jnp(train_batch)
    vb = batch_to_jnp(val_batch) if val_batch is not None else None

    @jax.jit
    def step(params, state, rng):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(params, tb, rng)
        params, state, om = opt.update(grads, state, params)
        return params, state, loss, metrics

    @jax.jit
    def val_scores(params):
        loss, metrics = model.loss(params, vb, jax.random.PRNGKey(0))
        out = model.forward(params, vb, train=False)
        return loss, out["anom_logit"]

    def f1_outlier(logits, y):
        pred = np.asarray(logits) >= 0.0  # sigmoid(x) >= 0.5
        tp = int(np.sum(pred & (y == 1)))
        fp = int(np.sum(pred & (y == 0)))
        fn = int(np.sum(~pred & (y == 1)))
        prec = tp / max(tp + fp, 1)
        rec = tp / max(tp + fn, 1)
        return 2 * prec * rec / max(prec + rec, 1e-9)

    y_val = (np.asarray(val_batch.anomaly)
             if val_batch is not None else None)
    rng = jax.random.PRNGKey(seed + 1)
    history = []
    loss_best = (np.inf, 0)  # early-stopping tracker (val total loss)
    best = ((-1.0, -np.inf), params, 0)  # selection: (f1, -loss)
    for epoch in range(epochs):
        rng, sub = jax.random.split(rng)
        params, state, loss, metrics = step(params, state, sub)
        entry = {"epoch": epoch, "train_loss": float(loss)}
        if vb is not None:
            vl, logits = val_scores(params)
            vl = float(vl)
            f1 = f1_outlier(logits, y_val)
            entry["val_loss"] = vl
            entry["val_f1_outlier"] = f1
            if (f1, -vl) > best[0]:
                best = ((f1, -vl), params, epoch)
            if vl < loss_best[0]:
                loss_best = (vl, epoch)
            elif epoch - loss_best[1] > patience:
                history.append(entry)
                break
        history.append(entry)
        if verbose and epoch % 10 == 0:
            print(entry, {k: round(float(v), 4)
                          for k, v in metrics.items()})
    params = best[1] if vb is not None else params
    return TrainResult(params=params, history=history,
                       best_epoch=best[2] if vb is not None else epochs - 1)


def evaluate(model: PeronaModel, params, batch: PeronaBatch) -> Dict:
    """§IV-C metrics: recon MSE, type accuracy, outlier P/R/F1, weighted
    accuracy."""
    b = batch_to_jnp(batch)
    out = model.forward(params, b, train=False)
    x = np.asarray(b["x"])
    recon = np.asarray(out["recon"])
    mse = float(np.mean((recon - x) ** 2))
    type_pred = np.asarray(jnp.argmax(out["type_logits"], -1))
    type_acc = float(np.mean(type_pred == batch.type_id))
    prob = np.asarray(jax.nn.sigmoid(out["anom_logit"]))
    pred = (prob >= 0.5).astype(int)
    y = batch.anomaly

    def f1(cls):
        tp = int(np.sum((pred == cls) & (y == cls)))
        fp = int(np.sum((pred == cls) & (y != cls)))
        fn = int(np.sum((pred != cls) & (y == cls)))
        prec = tp / max(tp + fp, 1)
        rec = tp / max(tp + fn, 1)
        return 2 * prec * rec / max(prec + rec, 1e-9)

    acc = float(np.mean(pred == y))
    n0, n1 = int(np.sum(y == 0)), int(np.sum(y == 1))
    weighted_acc = float(
        (np.mean(pred[y == 0] == 0) * n0 + np.mean(pred[y == 1] == 1) * n1)
        / max(n0 + n1, 1)) if n1 else acc
    return {
        "mse": mse,
        "type_accuracy": type_acc,
        "f1_normal": f1(0),
        "f1_outlier": f1(1),
        "accuracy": acc,
        "weighted_accuracy": weighted_acc,
        "codes": np.asarray(out["codes"]),
        "anomaly_prob": prob,
    }
