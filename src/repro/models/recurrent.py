"""Recurrent layer kinds: Griffin RG-LRU blocks and xLSTM (mLSTM/sLSTM).

TPU adaptation notes (see DESIGN.md §3):
  * RG-LRU uses jax.lax.associative_scan (log-depth) for train/prefill and
    a single fused step for decode; the Pallas kernel in
    repro.kernels.rg_lru implements the blocked linear scan for TPU.
  * mLSTM uses the stabilized *chunkwise* formulation: quadratic
    attention-like compute within chunks (MXU-friendly), linear carry of
    the (head_dim x head_dim) matrix memory across chunks.
  * sLSTM is inherently sequential (recurrent weights); a lax.scan over
    time with a block-diagonal recurrent matrix. Decode is one step.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import nn
from repro.models.config import ModelConfig

SQRT2 = math.sqrt(2.0)


# ---------------------------------------------------------------------------
# Causal depthwise conv1d (shared by rg_lru / xlstm branches)
# ---------------------------------------------------------------------------

def conv1d_init(init: nn.Init, width: int, channels: int):
    w, ws = init.param((width, channels), (None, "model"),
                       scale=nn.fanin_scale(width))
    b, bs = init.param((channels,), ("model",), mode="zeros")
    return {"w": w, "b": b}, {"w": ws, "b": bs}


def conv1d_causal(params, x):
    """x: (B, S, C). y[t] = sum_k w[k] * x[t-k]."""
    w = params["w"].astype(x.dtype)
    width = w.shape[0]
    out = x * w[0]
    for k in range(1, width):
        shifted = jnp.pad(x, ((0, 0), (k, 0), (0, 0)))[:, : x.shape[1]]
        out = out + shifted * w[k]
    return out + params["b"].astype(x.dtype)


def conv1d_decode(params, x_t, conv_cache):
    """x_t: (B, 1, C); conv_cache: (B, width-1, C) most-recent-last."""
    w = params["w"].astype(x_t.dtype)
    width = w.shape[0]
    hist = jnp.concatenate([conv_cache.astype(x_t.dtype), x_t], axis=1)
    out = jnp.einsum("btc,tc->bc", hist, w[::-1])[:, None, :]
    new_cache = hist[:, 1:].astype(conv_cache.dtype)
    return out + params["b"].astype(x_t.dtype), new_cache


# ---------------------------------------------------------------------------
# RG-LRU (RecurrentGemma / Griffin)
# ---------------------------------------------------------------------------

_LRU_C = 8.0  # Griffin's fixed temperature on the recurrence gate


def _block_diag_init(init: nn.Init, n_heads: int, dim: int):
    hd = dim // n_heads
    w, ws = init.param((n_heads, hd, hd), ("model", None, None),
                       scale=nn.fanin_scale(hd))
    b, bs = init.param((dim,), ("model",), mode="zeros")
    return {"w": w, "b": b}, {"w": ws, "b": bs}


def _block_diag_apply(params, x, n_heads: int):
    B, S, C = x.shape
    xh = x.reshape(B, S, n_heads, C // n_heads)
    y = jnp.einsum("bshi,hij->bshj", xh, params["w"].astype(x.dtype))
    return y.reshape(B, S, C) + params["b"].astype(x.dtype)


def rg_lru_init(init: nn.Init, cfg: ModelConfig):
    lw = cfg.lru_width
    params, specs = {}, {}
    # Lambda parametrized so that a = exp(-c*softplus(L)) starts in
    # (0.9, 0.999) as in Griffin: U(0.2, 0.85).
    lam, ls = init.param((lw,), ("model",), mode="lru_lambda")
    params["lambda"] = lam
    specs["lambda"] = ls
    for nm in ("gate_a", "gate_x"):
        p, s = _block_diag_init(init, cfg.n_heads, lw)
        params[nm], specs[nm] = p, s
    return params, specs


def _lru_log_a(params, gate_a):
    """log a_t in float32; gate_a: (B,S,C) pre-sigmoid."""
    softplus_l = jax.nn.softplus(params["lambda"].astype(jnp.float32))
    r = jax.nn.sigmoid(gate_a.astype(jnp.float32))
    return -_LRU_C * softplus_l * r  # (B,S,C), <= 0


def rg_lru_scan(params, cfg: ModelConfig, x, h0=None, impl: str = "reference"):
    """Full-sequence RG-LRU. x: (B,S,C) conv output. Returns (y, h_last)."""
    ga = _block_diag_apply(params["gate_a"], x, cfg.n_heads)
    gx = _block_diag_apply(params["gate_x"], x, cfg.n_heads)
    log_a = _lru_log_a(params, ga)  # (B,S,C) f32
    gated_x = jax.nn.sigmoid(gx.astype(jnp.float32)) * x.astype(jnp.float32)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = beta * gated_x
    if impl == "pallas":
        from repro.kernels.rg_lru import ops as lru_ops

        y, h_last = lru_ops.linear_scan(jnp.exp(log_a), b, h0)
        return y.astype(x.dtype), h_last
    a = jnp.exp(log_a)
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    _, y = jax.lax.associative_scan(combine, (a, b), axis=1)
    return y.astype(x.dtype), y[:, -1].astype(jnp.float32)


def rg_lru_step(params, cfg: ModelConfig, x_t, h):
    """One decode step. x_t: (B,1,C); h: (B,C) f32."""
    ga = _block_diag_apply(params["gate_a"], x_t, cfg.n_heads)
    gx = _block_diag_apply(params["gate_x"], x_t, cfg.n_heads)
    log_a = _lru_log_a(params, ga)[:, 0]  # (B,C)
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    gated = jax.nn.sigmoid(gx.astype(jnp.float32))[:, 0] * x_t.astype(
        jnp.float32)[:, 0]
    h_new = a * h + beta * gated
    return h_new.astype(x_t.dtype)[:, None, :], h_new


def griffin_block_init(init: nn.Init, cfg: ModelConfig):
    """Recurrent block: two branches, conv1d + RG-LRU on one."""
    d, lw = cfg.d_model, cfg.lru_width
    params, specs = {}, {}
    for nm in ("wx", "wy"):
        p, s = nn.linear_init(init, d, lw, (None, "model"))
        params[nm], specs[nm] = p, s
    p, s = conv1d_init(init, cfg.conv1d_width, lw)
    params["conv"], specs["conv"] = p, s
    p, s = rg_lru_init(init, cfg)
    params["lru"], specs["lru"] = p, s
    p, s = nn.linear_init(init, lw, d, ("model", None))
    params["wo"], specs["wo"] = p, s
    return params, specs


def griffin_block(params, cfg: ModelConfig, x, *, mode="train", cache=None,
                  impl: str = "reference"):
    """x: (B,S,D) normed input. cache: {"conv": ..., "h": ...}."""
    gate = jax.nn.gelu(nn.linear(params["wx"], x))
    y = nn.linear(params["wy"], x)
    new_cache = cache
    if mode == "decode":
        y, conv_cache = conv1d_decode(params["conv"], y, cache["conv"])
        y, h = rg_lru_step(params["lru"], cfg, y, cache["h"])
        new_cache = {"conv": conv_cache, "h": h}
    else:
        y = conv1d_causal(params["conv"], y)
        y, h_last = rg_lru_scan(params["lru"], cfg, y, impl=impl)
        if mode == "prefill" and cache is not None:
            tail = y  # conv history = last (width-1) pre-conv inputs
            conv_cache = nn.linear(params["wy"], x)[:, -(cfg.conv1d_width - 1):]
            new_cache = {"conv": conv_cache.astype(cache["conv"].dtype),
                         "h": h_last}
            del tail
    out = nn.linear(params["wo"], y * gate)
    return out, new_cache


def init_griffin_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    return {
        "conv": jnp.zeros((batch, cfg.conv1d_width - 1, cfg.lru_width), dtype),
        "h": jnp.zeros((batch, cfg.lru_width), jnp.float32),
    }


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix-memory block)
# ---------------------------------------------------------------------------

def mlstm_block_init(init: nn.Init, cfg: ModelConfig):
    d = cfg.d_model
    di = 2 * d  # proj_factor 2
    H = cfg.n_heads
    params, specs = {}, {}
    p, s = nn.linear_init(init, d, 2 * di, (None, "model"))
    params["up"], specs["up"] = p, s
    p, s = conv1d_init(init, cfg.conv1d_width, di)
    params["conv"], specs["conv"] = p, s
    for nm in ("wq", "wk"):
        p, s = _block_diag_init(init, H, di)
        params[nm], specs[nm] = p, s
    p, s = _block_diag_init(init, H, di)
    params["wv"], specs["wv"] = p, s
    for nm in ("wi", "wf"):
        p, s = nn.linear_init(init, di, H, (None, None))
        params[nm], specs[nm] = p, s
    p, s = nn.norm_init(init, "rmsnorm", di)  # multi-head norm (grouped)
    params["hnorm"], specs["hnorm"] = p, s
    p, s = nn.linear_init(init, di, d, ("model", None))
    params["down"], specs["down"] = p, s
    return params, specs


def mlstm_chunkwise(q, k, v, log_i, log_f, chunk: int = 256,
                    state=None, impl: str = "reference"):
    """Stabilized chunkwise mLSTM.

    q,k,v: (B,S,H,hd); log_i/log_f: (B,S,H) (f32). Returns (h, state).
    state = (C (B,H,hd,hd), n (B,H,hd), m (B,H)) carried across chunks.
    """
    if impl == "pallas":
        from repro.kernels.mlstm import ops as ml_ops

        return ml_ops.mlstm_chunkwise(q, k, v, log_i, log_f, chunk=chunk,
                                      state=state)
    B, S, H, hd = q.shape
    if S % chunk != 0:
        chunk = S  # small sequences: single chunk
    nc = S // chunk

    def resh(x):
        return jnp.moveaxis(
            x.reshape(B, nc, chunk, *x.shape[2:]), 1, 0)  # (nc,B,chunk,...)

    qs, ks, vs = resh(q), resh(k), resh(v)
    lis, lfs = resh(log_i), resh(log_f)

    if state is None:
        C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, H, hd), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = state

    def body(carry, inp):
        C, n, m = carry
        qc, kc, vc, li, lf = inp  # (B,chunk,H,...)
        qc32 = qc.astype(jnp.float32)
        kc32 = kc.astype(jnp.float32)
        vc32 = vc.astype(jnp.float32)
        b = jnp.cumsum(lf, axis=1)  # (B,chunk,H) cumulative log-forget
        total_f = b[:, -1]  # (B,H)
        # intra-chunk decay: D[i,j] = b_i - b_j + li_j for j <= i
        dmat = (b[:, :, None, :] - b[:, None, :, :]
                + li[:, None, :, :])  # (B,i,j,H)
        causal = jnp.tril(jnp.ones((chunk, chunk), bool))
        dmat = jnp.where(causal[None, :, :, None], dmat, -jnp.inf)
        # inter-chunk contribution decay for queries: b_i + m_prev
        inter_log = b + m[:, None, :]  # (B,i,H)
        m_intra = jnp.max(dmat, axis=2)  # (B,i,H)
        m_new = jnp.maximum(inter_log, m_intra)  # (B,i,H) per-row stabilizer
        dmat_s = jnp.exp(dmat - m_new[:, :, None, :])  # (B,i,j,H)
        inter_s = jnp.exp(inter_log - m_new)  # (B,i,H)

        scores = jnp.einsum("bihd,bjhd->bijh", qc32, kc32)
        intra = jnp.einsum("bijh,bijh,bjhd->bihd", scores, dmat_s, vc32)
        inter = jnp.einsum("bihd,bhde->bihe", qc32, C) * inter_s[..., None]
        num = intra + inter
        den_intra = jnp.einsum("bijh,bijh->bih", scores, dmat_s)
        den_inter = jnp.einsum("bihd,bhd->bih", qc32, n) * inter_s
        den = den_intra + den_inter
        h = num / jnp.maximum(
            jnp.abs(den)[..., None], jnp.exp(-m_new)[..., None])

        # state update for the next chunk
        m_next = jnp.maximum(total_f + m, jnp.max(b + li, axis=1))  # (B,H)
        # decay applied to each key position j: total_f - b_j + li_j
        kdecay = jnp.exp(total_f[:, None] - b + li - m_next[:, None])
        C_next = (jnp.exp(total_f + m - m_next)[..., None, None] * C
                  + jnp.einsum("bjh,bjhd,bjhe->bhde", kdecay, kc32, vc32))
        n_next = (jnp.exp(total_f + m - m_next)[..., None] * n
                  + jnp.einsum("bjh,bjhd->bhd", kdecay, kc32))
        return (C_next, n_next, m_next), h.astype(q.dtype)

    (C, n, m), hs = jax.lax.scan(body, (C0, n0, m0), (qs, ks, vs, lis, lfs))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, H, hd)
    return h, (C, n, m)


def mlstm_step(q, k, v, log_i, log_f, state):
    """Single decode step. q,k,v: (B,1,H,hd); gates (B,1,H)."""
    C, n, m = state
    q32, k32, v32 = (x.astype(jnp.float32)[:, 0] for x in (q, k, v))
    li, lf = log_i[:, 0], log_f[:, 0]  # (B,H)
    m_new = jnp.maximum(lf + m, li)
    fgate = jnp.exp(lf + m - m_new)[..., None, None]
    igate = jnp.exp(li - m_new)[..., None, None]
    C_new = fgate * C + igate * jnp.einsum("bhd,bhe->bhde", k32, v32)
    n_new = fgate[..., 0] * n + igate[..., 0] * k32
    num = jnp.einsum("bhd,bhde->bhe", q32, C_new)
    den = jnp.einsum("bhd,bhd->bh", q32, n_new)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    return h[:, None].astype(q.dtype), (C_new, n_new, m_new)


def mlstm_block(params, cfg: ModelConfig, x, *, mode="train", cache=None,
                impl: str = "reference"):
    B, S, d = x.shape
    di = 2 * d
    H = cfg.n_heads
    hd = di // H
    up = nn.linear(params["up"], x)
    x1, x2 = up[..., :di], up[..., di:]
    new_cache = cache
    if mode == "decode":
        c, conv_cache = conv1d_decode(params["conv"], x1, cache["conv"])
    else:
        c = conv1d_causal(params["conv"], x1)
        conv_cache = None
    c = jax.nn.silu(c)
    q = _block_diag_apply(params["wq"], c, H).reshape(B, S, H, hd)
    k = _block_diag_apply(params["wk"], c, H).reshape(B, S, H, hd) / math.sqrt(hd)
    v = _block_diag_apply(params["wv"], x1, H).reshape(B, S, H, hd)
    log_i = nn.linear(params["wi"], c).astype(jnp.float32)  # (B,S,H)
    log_f = jax.nn.log_sigmoid(
        nn.linear(params["wf"], c).astype(jnp.float32))

    if mode == "decode":
        h, state = mlstm_step(q, k, v, log_i, log_f, cache["state"])
        new_cache = {"conv": conv_cache, "state": state}
    else:
        h, state = mlstm_chunkwise(q, k, v, log_i, log_f, impl=impl)
        if mode == "prefill" and cache is not None:
            new_cache = {
                "conv": x1[:, -(cfg.conv1d_width - 1):].astype(
                    cache["conv"].dtype),
                "state": state,
            }
    h = h.reshape(B, S, di)
    h = nn.apply_norm(params["hnorm"], "rmsnorm", h)
    out = nn.linear(params["down"], h * jax.nn.silu(x2))
    return out, new_cache


def init_mlstm_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    di = 2 * cfg.d_model
    H = cfg.n_heads
    hd = di // H
    return {
        "conv": jnp.zeros((batch, cfg.conv1d_width - 1, di), dtype),
        "state": (
            jnp.zeros((batch, H, hd, hd), jnp.float32),
            jnp.zeros((batch, H, hd), jnp.float32),
            jnp.full((batch, H), -1e30, jnp.float32),
        ),
    }


# ---------------------------------------------------------------------------
# sLSTM (xLSTM scalar-memory block)
# ---------------------------------------------------------------------------

def slstm_block_init(init: nn.Init, cfg: ModelConfig):
    d = cfg.d_model
    H = cfg.n_heads
    params, specs = {}, {}
    p, s = conv1d_init(init, cfg.conv1d_width, d)
    params["conv"], specs["conv"] = p, s
    for nm in ("wz", "wi", "wf", "wo"):
        p, s = nn.linear_init(init, d, d, (None, "model"))
        params[nm], specs[nm] = p, s
    for nm in ("rz", "ri", "rf", "ro"):
        p, s = _block_diag_init(init, H, d)
        params[nm], specs[nm] = p, s
    p, s = nn.norm_init(init, "rmsnorm", d)
    params["hnorm"], specs["hnorm"] = p, s
    dff = (4 * d) // 3
    p, s = nn.mlp_init(init, "geglu", d, dff)
    params["ffn"], specs["ffn"] = p, s
    return params, specs


def _slstm_cell(params, cfg: ModelConfig, zx, ix, fx, ox, state):
    """One timestep. *x: (B,D) pre-activations from the input side."""
    c, n, h, m = state
    H = cfg.n_heads

    def rec(nm, h_):
        return _block_diag_apply(params[nm], h_[:, None, :], H)[:, 0]

    z = jnp.tanh(zx + rec("rz", h))
    o = jax.nn.sigmoid(ox + rec("ro", h))
    log_i = ix + rec("ri", h)
    log_f = jax.nn.log_sigmoid(fx + rec("rf", h))
    m_new = jnp.maximum(log_f + m, log_i)
    i_s = jnp.exp(log_i - m_new)
    f_s = jnp.exp(log_f + m - m_new)
    c_new = f_s * c + i_s * z
    n_new = f_s * n + i_s
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new), h_new


def slstm_block(params, cfg: ModelConfig, x, *, mode="train", cache=None):
    B, S, d = x.shape
    new_cache = cache
    if mode == "decode":
        cx, conv_cache = conv1d_decode(params["conv"], x, cache["conv"])
    else:
        cx = conv1d_causal(params["conv"], x)
        conv_cache = None
    cx = jax.nn.silu(cx)
    zx = nn.linear(params["wz"], x).astype(jnp.float32)
    ox = nn.linear(params["wo"], x).astype(jnp.float32)
    ix = nn.linear(params["wi"], cx).astype(jnp.float32)
    fx = nn.linear(params["wf"], cx).astype(jnp.float32)

    if mode == "decode":
        state = cache["state"]
        state, h = _slstm_cell(params, cfg, zx[:, 0], ix[:, 0], fx[:, 0],
                               ox[:, 0], state)
        hs = h[:, None, :]
        new_cache = {"conv": conv_cache, "state": state}
    else:
        state = (
            jnp.zeros((B, d), jnp.float32),
            jnp.zeros((B, d), jnp.float32),
            jnp.zeros((B, d), jnp.float32),
            jnp.full((B, d), -1e30, jnp.float32),
        )

        def body(st, inp):
            z_, i_, f_, o_ = inp
            st2, h_ = _slstm_cell(params, cfg, z_, i_, f_, o_, st)
            return st2, h_

        state, hs = jax.lax.scan(
            body, state,
            (jnp.moveaxis(zx, 1, 0), jnp.moveaxis(ix, 1, 0),
             jnp.moveaxis(fx, 1, 0), jnp.moveaxis(ox, 1, 0)))
        hs = jnp.moveaxis(hs, 0, 1)
        if mode == "prefill" and cache is not None:
            new_cache = {
                "conv": x[:, -(cfg.conv1d_width - 1):].astype(
                    cache["conv"].dtype),
                "state": state,
            }
    hs = nn.apply_norm(params["hnorm"], "rmsnorm", hs.astype(x.dtype))
    out = hs + nn.apply_mlp(params["ffn"], "geglu", hs)
    return out, new_cache


def init_slstm_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    d = cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.conv1d_width - 1, d), dtype),
        "state": (
            jnp.zeros((batch, d), jnp.float32),
            jnp.zeros((batch, d), jnp.float32),
            jnp.zeros((batch, d), jnp.float32),
            jnp.full((batch, d), -1e30, jnp.float32),
        ),
    }
