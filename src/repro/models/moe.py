"""Mixture-of-Experts feed-forward with GSPMD-friendly dispatch.

Design (GShard/Switch-style, adapted for a (data, model) mesh):

  tokens (B, S, D) — B sharded on "data" — are treated as B groups; each
  group dispatches its own tokens into a per-group expert buffer
  (B, E, C, D) via capacity-limited scatter. Expert weights are sharded
  on "model" (expert parallelism), so the expert einsum partitions the E
  axis; the combine contraction over E induces a single psum over
  "model" — the same collective cost shape as a tensor-parallel FFN.

  Capacity C = ceil(cf * S * top_k / E), rounded up to a multiple of 8.
  Overflowing tokens are dropped (scatter mode "drop"), standard for
  capacity-based TPU MoE; the capacity_factor controls the drop rate.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import nn
from repro.models.config import ModelConfig, MoEConfig


def _capacity(moe: MoEConfig, tokens_per_group: int) -> int:
    c = int(moe.capacity_factor * tokens_per_group * moe.top_k / moe.n_experts)
    return max(8, (c + 7) // 8 * 8)


def moe_init(init: nn.Init, cfg: ModelConfig):
    moe = cfg.moe
    d = cfg.d_model
    params, specs = {}, {}
    w, ws = init.param((d, moe.n_experts), (None, None),
                       scale=nn.fanin_scale(d))
    params["router"] = {"w": w}
    specs["router"] = {"w": ws}
    # experts: gated MLP, stacked on leading expert axis (sharded "model")
    wi, wis = init.param((moe.n_experts, d, 2, moe.expert_d_ff),
                         ("model", None, None, None),
                         scale=nn.fanin_scale(d))
    wo, wos = init.param((moe.n_experts, moe.expert_d_ff, d),
                         ("model", None, None),
                         scale=nn.fanin_scale(moe.expert_d_ff))
    params["experts"] = {"wi": wi, "wo": wo}
    specs["experts"] = {"wi": wis, "wo": wos}
    if moe.n_shared_experts:
        shared_ff = moe.shared_d_ff or moe.n_shared_experts * moe.expert_d_ff
        p, s = nn.mlp_init(init, "swiglu", d, shared_ff)
        params["shared"], specs["shared"] = p, s
    return params, specs


def router_topk(params, moe: MoEConfig, x) -> Tuple[jnp.ndarray, jnp.ndarray,
                                                    jnp.ndarray]:
    """Returns (weights (B,S,K), expert_ids (B,S,K), aux_loss scalar)."""
    logits = jnp.einsum(
        "bsd,de->bse", x.astype(jnp.float32),
        params["router"]["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, -1)
    weights, ids = jax.lax.top_k(probs, moe.top_k)
    weights = weights / jnp.maximum(
        jnp.sum(weights, -1, keepdims=True), 1e-9)
    # load-balancing auxiliary loss (Switch-style)
    E = moe.n_experts
    density = jnp.mean(
        jax.nn.one_hot(ids, E, dtype=jnp.float32).sum(-2), axis=(0, 1))
    density_proxy = jnp.mean(probs, axis=(0, 1))
    aux = jnp.sum(density * density_proxy) * E * moe.router_aux_weight
    return weights, ids, aux


def moe_apply(params, cfg: ModelConfig, x):
    """x: (B, S, D) -> (out (B,S,D), aux_loss)."""
    if cfg.moe.impl == "einsum":
        return moe_apply_einsum(params, cfg, x)
    return moe_apply_scatter(params, cfg, x)


def moe_apply_einsum(params, cfg: ModelConfig, x):
    """GShard-style einsum dispatch with group-local capacity.

    Tokens are reshaped to (G, g, D) groups (G sharded on "data"); the
    dispatch/combine one-hots are (G, g, E, C) built group-locally, so
    the dispatch einsum needs no communication, and the combine einsum
    contracts the "model"-sharded expert axis -> one psum of (G, g, D)
    per layer (the same collective shape as a tensor-parallel FFN).
    """
    moe = cfg.moe
    B, S, D = x.shape
    E, K = moe.n_experts, moe.top_k
    g = min(moe.group_size, S)
    assert (B * S) % g == 0, (B, S, g)
    G = B * S // g
    C = _capacity(moe, g)

    weights, ids, aux = router_topk(params, moe, x)  # (B,S,K)
    xg = x.reshape(G, g, D)
    idg = ids.reshape(G, g, K)
    wg = weights.reshape(G, g, K)

    onehot_e = jax.nn.one_hot(idg, E, dtype=jnp.int32)  # (G,g,K,E)
    # position of each choice within its expert, group-locally
    cum = jnp.cumsum(onehot_e.reshape(G, g * K, E), axis=1).reshape(
        G, g, K, E)
    pos = jnp.sum(cum * onehot_e, axis=-1) - 1  # (G,g,K) in [0, g*K)
    keep = pos < C
    pos = jnp.clip(pos, 0, C - 1)
    onehot_c = jax.nn.one_hot(pos, C, dtype=x.dtype) * keep[..., None]

    dispatch = jnp.einsum("GgKE,GgKC->GgEC", onehot_e.astype(x.dtype),
                          onehot_c)  # (G,g,E,C)
    combine = jnp.einsum("GgKE,GgKC,GgK->GgEC",
                         onehot_e.astype(jnp.float32),
                         onehot_c.astype(jnp.float32),
                         wg.astype(jnp.float32)).astype(x.dtype)

    buf = jnp.einsum("GgD,GgEC->GECD", xg, dispatch)
    buf = nn.constrain(buf, "data", "model", None, None)
    wi = params["experts"]["wi"].astype(x.dtype)
    wo = params["experts"]["wo"].astype(x.dtype)
    h = jnp.einsum("GECD,EDtf->GECtf", buf, wi)
    act = jax.nn.silu(h[..., 0, :]) * h[..., 1, :]
    eout = jnp.einsum("GECf,EfD->GECD", act, wo)
    out = jnp.einsum("GECD,GgEC->GgD", eout, combine)  # psum over model
    out = out.reshape(B, S, D)
    out = nn.constrain(out, "data", None, None)
    if moe.n_shared_experts:
        out = out + nn.apply_mlp(params["shared"], "swiglu", x)
    return out, aux


def moe_apply_scatter(params, cfg: ModelConfig, x):
    """Scatter/gather token routing (paper-faithful GPU-style port)."""
    moe = cfg.moe
    B, S, D = x.shape
    E, K = moe.n_experts, moe.top_k
    C = _capacity(moe, S)

    weights, ids, aux = router_topk(params, moe, x)  # (B,S,K)

    # --- per-group capacity assignment ---------------------------------
    flat_ids = ids.reshape(B, S * K)  # choice order: token-major
    onehot = jax.nn.one_hot(flat_ids, E, dtype=jnp.int32)  # (B, SK, E)
    pos_all = jnp.cumsum(onehot, axis=1) - 1  # (B, SK, E)
    pos = jnp.sum(pos_all * onehot, -1)  # (B, SK) slot within expert
    keep = pos < C
    pos = jnp.where(keep, pos, C)  # C -> dropped via scatter mode "drop"

    # --- dispatch: (B, E, C, D) buffers ---------------------------------
    tok = jnp.repeat(x, K, axis=1)  # (B, SK, D) token per choice
    scatter_idx = jnp.stack(
        [flat_ids, pos], axis=-1)  # (B, SK, 2) -> (E, C)

    def scatter_group(buf_idx, toks):
        buf = jnp.zeros((E, C + 1, D), x.dtype)
        buf = buf.at[buf_idx[:, 0], buf_idx[:, 1]].add(
            toks, mode="drop")
        return buf[:, :C]

    buf = jax.vmap(scatter_group)(scatter_idx, tok)  # (B,E,C,D)
    buf = nn.constrain(buf, "data", "model", None, None)

    # --- expert compute (E sharded on "model") ---------------------------
    wi = params["experts"]["wi"].astype(x.dtype)
    wo = params["experts"]["wo"].astype(x.dtype)
    h = jnp.einsum("becd,edtf->bectf", buf, wi)
    act = jax.nn.silu(h[..., 0, :]) * h[..., 1, :]
    eout = jnp.einsum("becf,efd->becd", act, wo)  # (B,E,C,D)
    eout = nn.constrain(eout, "data", "model", None, None)

    # --- combine: gather back + weighted sum over choices ----------------
    def gather_group(e_out, buf_idx):
        padded = jnp.pad(e_out, ((0, 0), (0, 1), (0, 0)))  # row C = zeros
        return padded[buf_idx[:, 0], buf_idx[:, 1]]  # (SK, D)

    picked = jax.vmap(gather_group)(eout, scatter_idx)  # (B, SK, D)
    picked = picked.reshape(B, S, K, D)
    w = (weights * keep.reshape(B, S, K)).astype(x.dtype)
    out = jnp.einsum("bskd,bsk->bsd", picked, w)
    out = nn.constrain(out, "data", None, None)

    if moe.n_shared_experts:
        out = out + nn.apply_mlp(params["shared"], "swiglu", x)
    return out, aux
