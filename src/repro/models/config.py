"""Unified model configuration covering every assigned architecture.

A model is: [embedding / modality frontend stub] -> head layers (unrolled)
-> scanned pattern body (n_periods x period) -> tail layers (unrolled)
-> final norm -> logits.

Layer kinds:
  "attn"       full (causal) self-attention + MLP
  "local_attn" sliding-window self-attention + MLP
  "rg_lru"     Griffin recurrent block (conv1d + RG-LRU) + MLP
  "mlstm"      xLSTM matrix-memory block (self-contained, no MLP)
  "slstm"      xLSTM scalar-memory block (self-contained, no MLP)
  "moe_attn"   full attention + MoE feed-forward
  "dense_attn" full attention + dense MLP (used for MoE archs' dense head)
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    expert_d_ff: int
    n_shared_experts: int = 0
    shared_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    router_dtype: str = "float32"
    # dispatch implementation:
    #   "scatter" — paper-faithful port of scatter/gather token routing
    #               (combine gathers across the expert-sharded buffer ->
    #               all-gather over "model"; the collective-bound baseline)
    #   "einsum"  — GShard/MaxText-style group-local one-hot dispatch;
    #               the only combine collective is a psum over "model"
    #               (beyond-paper optimization, §Perf)
    impl: str = "scatter"
    group_size: int = 256  # einsum impl: tokens per dispatch group


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention."""

    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | audio | hybrid | vlm | ssm | moe
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # layer pattern: head (unrolled) + body (scanned n_periods times) + tail
    head_pattern: Tuple[str, ...] = ()
    body_pattern: Tuple[str, ...] = ("attn",)
    n_periods: int = 0  # 0 -> n_layers // len(body_pattern)
    tail_pattern: Tuple[str, ...] = ()

    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False
    local_window: int = 1024
    rope_style: str = "rope"  # none | rope | mrope
    rope_theta: float = 10000.0
    attn_logit_softcap: float = 0.0

    # norms / mlp
    norm: str = "rmsnorm"  # rmsnorm | layernorm | nonparametric_ln
    mlp: str = "swiglu"  # swiglu | geglu | gelu
    tie_embeddings: bool = True

    # multipliers (granite)
    embedding_multiplier: float = 1.0
    residual_multiplier: float = 1.0
    attention_multiplier: float = 0.0  # 0 -> 1/sqrt(head_dim)
    logits_scaling: float = 1.0

    # recurrent details
    conv1d_width: int = 4
    lru_width: int = 0  # 0 -> d_model

    # moe / mla
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None

    # encoder-decoder (whisper): encoder stack of n_encoder_layers "attn"
    # (bidirectional) blocks; decoder layers get cross-attention.
    n_encoder_layers: int = 0
    n_audio_frames: int = 1500

    # training
    max_seq: int = 4096
    dtype: str = "bfloat16"
    remat: str = "full"  # none | full | dots

    # implementation switches
    attn_impl: str = "reference"  # reference | pallas
    chunked_ce: int = 0  # >0: vocab-chunked cross-entropy block size
    # scan over body periods (small HLO, fast compile) vs python-unrolled
    # (large HLO; exact cost_analysis — XLA counts while bodies once, so
    # the dry-run roofline pass unrolls)
    scan_layers: bool = True
    # int8 KV cache with per-(token, head) scales: halves decode HBM
    # traffic on the cache read (beyond-paper optimization, §Perf)
    kv_quant: bool = False
    # skip (not just mask) the causal upper triangle in chunked
    # attention; False = paper-faithful mask-only baseline (§Perf)
    causal_skip: bool = True

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.n_periods == 0:
            body = len(self.body_pattern)
            rest = self.n_layers - len(self.head_pattern) - len(self.tail_pattern)
            if rest % body != 0:
                raise ValueError(
                    f"{self.name}: {rest} pattern layers not divisible by "
                    f"period {body}; set head/tail_pattern explicitly"
                )
            object.__setattr__(self, "n_periods", rest // body)
        if self.lru_width == 0:
            object.__setattr__(self, "lru_width", self.d_model)
        n_patterned = (
            len(self.head_pattern)
            + self.n_periods * len(self.body_pattern)
            + len(self.tail_pattern)
        )
        if n_patterned != self.n_layers:
            raise ValueError(
                f"{self.name}: pattern covers {n_patterned} layers, "
                f"config says {self.n_layers}"
            )

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        return (
            self.head_pattern
            + self.body_pattern * self.n_periods
            + self.tail_pattern
        )

    def scaled_down(self, **overrides) -> "ModelConfig":
        """A smoke-test sized variant of the same family (tests only)."""
        small = dict(
            n_layers=len(self.body_pattern)
            + len(self.head_pattern)
            + len(self.tail_pattern),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            head_dim=16,
            d_ff=128 if self.d_ff else 0,
            vocab_size=256,
            n_periods=1,
            local_window=16,
            max_seq=64,
            lru_width=64,
            n_encoder_layers=1 if self.n_encoder_layers else 0,
            n_audio_frames=8,
            chunked_ce=0,
        )
        if self.moe is not None:
            small["moe"] = dataclasses.replace(
                self.moe,
                n_experts=4,
                top_k=2,
                expert_d_ff=32,
                shared_d_ff=32 if self.moe.n_shared_experts else 0,
            )
        if self.mla is not None:
            small["mla"] = MLAConfig(
                kv_lora_rank=32, qk_nope_head_dim=16, qk_rope_head_dim=8,
                v_head_dim=16,
            )
        small.update(overrides)
        return dataclasses.replace(self, **small)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}

# Families whose published config has a sub-quadratic path for 500k decode.
SUBQUADRATIC_FAMILIES = ("hybrid", "ssm")


def shapes_for(config: ModelConfig) -> Tuple[ShapeConfig, ...]:
    """The assigned shape set for an architecture (long_500k gated)."""
    shapes = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if config.family in SUBQUADRATIC_FAMILIES:
        shapes.append(LONG_500K)
    return tuple(shapes)
