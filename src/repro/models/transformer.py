"""Unified LM assembly: embedding -> head/body/tail layer pattern -> logits.

The body pattern is executed with jax.lax.scan over ``n_periods`` stacked
parameter pytrees (one period = one or more layers unrolled inside the
scan body) so the lowered HLO stays small for 16..48-layer models, and a
remat (activation checkpointing) policy is applied per period.

Caches: every layer owns its cache pytree; "xattn" (whisper decoder)
layers additionally own a cross-attention K/V cache filled at prefill.
Body-layer caches carry a leading ``n_periods`` axis and are scanned.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import nn
from repro.models import recurrent as rec
from repro.models.config import ModelConfig


class StackedInit(nn.Init):
    """Init wrapper that prepends an n_periods axis to every parameter."""

    def __init__(self, base: nn.Init, n: int):
        self._base = base
        self.n = n
        self.dtype = base.dtype

    def next_key(self):
        return self._base.next_key()

    def param(self, shape, spec, scale: float = 1.0, mode: str = "normal"):
        return self._base.param((self.n,) + tuple(shape),
                                (None,) + tuple(spec), scale=scale, mode=mode)


# ---------------------------------------------------------------------------
# Single layer init / apply / cache
# ---------------------------------------------------------------------------

def layer_init(init: nn.Init, cfg: ModelConfig, kind: str):
    params, specs = {}, {}
    p, s = nn.norm_init(init, cfg.norm, cfg.d_model)
    params["norm1"], specs["norm1"] = p, s

    if kind in ("attn", "local_attn", "enc_attn", "moe_attn", "dense_attn",
                "xattn"):
        p, s = attn.attention_init(init, cfg)
        params["attn"], specs["attn"] = p, s
    elif kind in ("mla_attn", "mla_moe_attn"):
        p, s = attn.mla_init(init, cfg)
        params["attn"], specs["attn"] = p, s
    elif kind == "rg_lru":
        p, s = rec.griffin_block_init(init, cfg)
        params["mix"], specs["mix"] = p, s
    elif kind == "mlstm":
        p, s = rec.mlstm_block_init(init, cfg)
        params["mix"], specs["mix"] = p, s
        return params, specs  # self-contained block
    elif kind == "slstm":
        p, s = rec.slstm_block_init(init, cfg)
        params["mix"], specs["mix"] = p, s
        return params, specs
    else:  # pragma: no cover
        raise ValueError(kind)

    if kind == "xattn":
        p, s = nn.norm_init(init, cfg.norm, cfg.d_model)
        params["norm_x"], specs["norm_x"] = p, s
        p, s = attn.attention_init(init, cfg)
        params["xattn"], specs["xattn"] = p, s

    p, s = nn.norm_init(init, cfg.norm, cfg.d_model)
    params["norm2"], specs["norm2"] = p, s
    if kind in ("moe_attn", "mla_moe_attn"):
        p, s = moe_lib.moe_init(init, cfg)
        params["moe"], specs["moe"] = p, s
    else:
        p, s = nn.mlp_init(init, cfg.mlp, cfg.d_model, cfg.d_ff)
        params["mlp"], specs["mlp"] = p, s
    return params, specs


def layer_apply(params, cfg: ModelConfig, kind: str, x, positions, *,
                mode: str, cache, enc_out=None):
    """One layer. Returns (x, new_cache, aux_loss).

    ``cache`` is the layer's own cache pytree or a no-cache sentinel dict.
    ``enc_out`` is the encoder output (train/prefill of xattn layers).
    """
    aux = jnp.zeros((), jnp.float32)
    rm = cfg.residual_multiplier
    nocache = cache is None or "__nocache__" in cache
    self_cache = None if nocache else cache.get("self", cache)
    h = nn.apply_norm(params["norm1"], cfg.norm, x)

    if kind in ("attn", "dense_attn", "moe_attn", "xattn", "local_attn"):
        y, self_cache = attn.attention_block(
            params["attn"], cfg, h, positions, local=(kind == "local_attn"),
            mode=mode, cache=self_cache)
    elif kind == "enc_attn":
        y, _ = attn.attention_block_bidirectional(params["attn"], cfg, h,
                                                  positions)
    elif kind in ("mla_attn", "mla_moe_attn"):
        y, self_cache = attn.mla_block(params["attn"], cfg, h, positions,
                                       mode=mode, cache=self_cache)
    elif kind == "rg_lru":
        y, self_cache = rec.griffin_block(params["mix"], cfg, h, mode=mode,
                                          cache=self_cache,
                                          impl=cfg.attn_impl)
    elif kind == "mlstm":
        y, self_cache = rec.mlstm_block(params["mix"], cfg, h, mode=mode,
                                        cache=self_cache, impl=cfg.attn_impl)
        return x + y * rm, _repack(cache, self_cache), aux
    elif kind == "slstm":
        y, self_cache = rec.slstm_block(params["mix"], cfg, h, mode=mode,
                                        cache=self_cache)
        return x + y * rm, _repack(cache, self_cache), aux
    else:  # pragma: no cover
        raise ValueError(kind)

    x = x + y * rm

    cross_cache = None if nocache else cache.get("cross")
    if kind == "xattn":
        hx = nn.apply_norm(params["norm_x"], cfg.norm, x)
        if mode in ("train", "prefill"):
            xkv = attn.encode_cross_kv(params["xattn"], cfg, enc_out)
            if mode == "prefill" and cross_cache is not None:
                cross_cache = jax.tree_util.tree_map(
                    lambda dst, src: src.astype(dst.dtype), cross_cache, xkv)
        else:
            xkv = cross_cache
        x = x + attn.cross_attention_block(params["xattn"], cfg, hx, xkv)

    h2 = nn.apply_norm(params["norm2"], cfg.norm, x)
    if kind in ("moe_attn", "mla_moe_attn"):
        y2, aux = moe_lib.moe_apply(params["moe"], cfg, h2)
    else:
        y2 = nn.apply_mlp(params["mlp"], cfg.mlp, h2)
    x = x + y2 * rm
    x = nn.constrain(x, "data", None, None)

    if nocache:
        new_cache = cache  # pass the sentinel through unchanged
    elif "self" in cache:
        new_cache = dict(cache)
        new_cache["self"] = self_cache
        if cross_cache is not None:
            new_cache["cross"] = cross_cache
    else:
        new_cache = self_cache
    return x, new_cache, aux


def _repack(cache, self_cache):
    if cache is None or "__nocache__" in cache:
        return cache
    if "self" in cache:
        out = dict(cache)
        out["self"] = self_cache
        return out
    return self_cache


NO_CACHE = {"__nocache__": jnp.zeros((1,), jnp.int8)}


def layer_cache(cfg: ModelConfig, kind: str, batch: int, length: int,
                dtype=jnp.bfloat16):
    if kind in ("attn", "dense_attn", "moe_attn"):
        c = attn.init_kv_cache(cfg, batch, length, local=False, dtype=dtype)
    elif kind == "local_attn":
        c = attn.init_kv_cache(cfg, batch, length, local=True, dtype=dtype)
    elif kind in ("mla_attn", "mla_moe_attn"):
        c = attn.init_mla_cache(cfg, batch, length, dtype=dtype)
    elif kind == "rg_lru":
        c = rec.init_griffin_cache(cfg, batch, dtype=dtype)
    elif kind == "mlstm":
        c = rec.init_mlstm_cache(cfg, batch, dtype=dtype)
    elif kind == "slstm":
        c = rec.init_slstm_cache(cfg, batch, dtype=dtype)
    elif kind == "xattn":
        c = {
            "self": attn.init_kv_cache(cfg, batch, length, local=False,
                                       dtype=dtype),
            "cross": {
                "k": jnp.zeros((batch, cfg.n_audio_frames, cfg.n_kv_heads,
                                cfg.head_dim), dtype),
                "v": jnp.zeros((batch, cfg.n_audio_frames, cfg.n_kv_heads,
                                cfg.head_dim), dtype),
            },
        }
    elif kind == "enc_attn":
        c = NO_CACHE
    else:  # pragma: no cover
        raise ValueError(kind)
    return c


# ---------------------------------------------------------------------------
# Whole-model init / cache
# ---------------------------------------------------------------------------

def model_init(cfg: ModelConfig, key, abstract: bool = False
               ) -> Tuple[Dict, Dict]:
    init = nn.Init(key, dtype=jnp.float32, abstract=abstract)
    params: Dict[str, Any] = {}
    specs: Dict[str, Any] = {}

    p, s = nn.embed_init(init, cfg.vocab_size, cfg.d_model)
    params["embed"], specs["embed"] = p, s
    if cfg.rope_style == "learned":
        p, s = init.param((cfg.max_seq, cfg.d_model), (None, None),
                          scale=0.02)
        params["pos_embed"], specs["pos_embed"] = {"table": p}, {"table": s}

    if cfg.n_encoder_layers:
        enc_stack = StackedInit(init, cfg.n_encoder_layers)
        p, s = layer_init(enc_stack, cfg, "enc_attn")
        params["encoder"], specs["encoder"] = p, s
        p, s = nn.norm_init(init, cfg.norm, cfg.d_model)
        params["enc_norm"], specs["enc_norm"] = p, s

    for group, pattern in (("head", cfg.head_pattern),
                           ("tail", cfg.tail_pattern)):
        if pattern:
            ps, ss = [], []
            for kind in pattern:
                p, s = layer_init(init, cfg, kind)
                ps.append(p)
                ss.append(s)
            params[group], specs[group] = ps, ss

    body_init = StackedInit(init, cfg.n_periods)
    ps, ss = [], []
    for kind in cfg.body_pattern:
        p, s = layer_init(body_init, cfg, kind)
        ps.append(p)
        ss.append(s)
    params["body"], specs["body"] = ps, ss

    p, s = nn.norm_init(init, cfg.norm, cfg.d_model)
    params["final_norm"], specs["final_norm"] = p, s
    if not cfg.tie_embeddings:
        p, s = nn.linear_init(init, cfg.d_model, cfg.vocab_size,
                              (None, "model"))
        params["lm_head"], specs["lm_head"] = p, s
    return params, specs


def model_cache(cfg: ModelConfig, batch: int, length: int,
                dtype=jnp.bfloat16):
    cache: Dict[str, Any] = {}
    for group, pattern in (("head", cfg.head_pattern),
                           ("tail", cfg.tail_pattern)):
        if pattern:
            cache[group] = [layer_cache(cfg, k, batch, length, dtype)
                            for k in pattern]
    body = []
    for kind in cfg.body_pattern:
        one = layer_cache(cfg, kind, batch, length, dtype)
        body.append(jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(
                x[None], (cfg.n_periods,) + x.shape).copy(), one))
    cache["body"] = body
    return cache


def no_cache_tree(cfg: ModelConfig):
    """Sentinel cache pytree usable as scan xs when training."""
    cache: Dict[str, Any] = {}
    for group, pattern in (("head", cfg.head_pattern),
                           ("tail", cfg.tail_pattern)):
        if pattern:
            cache[group] = [dict(NO_CACHE) for _ in pattern]
    cache["body"] = [
        {"__nocache__": jnp.zeros((cfg.n_periods, 1), jnp.int8)}
        for _ in cfg.body_pattern
    ]
    return cache


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)


def run_encoder(params, cfg: ModelConfig, frames):
    """Whisper-style encoder over precomputed frame embeddings (the conv
    frontend is a stub per the assignment): frames (B, T, D)."""
    B, T, D = frames.shape
    x = frames + nn.sinusoidal_positions(T, D)[None].astype(frames.dtype)
    positions = jnp.broadcast_to(jnp.arange(T), (B, T))

    def body(carry, layer_params):
        y, _, _ = layer_apply(layer_params, cfg, "enc_attn", carry,
                              positions, mode="train", cache=None)
        return y, None

    body_fn = _remat(body, cfg)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body_fn, x, params["encoder"])
    else:
        for i in range(cfg.n_encoder_layers):
            x, _ = body_fn(x, jax.tree_util.tree_map(
                lambda a: a[i], params["encoder"]))
    return nn.apply_norm(params["enc_norm"], cfg.norm, x)


def forward(params, cfg: ModelConfig, *, tokens=None, embeddings=None,
            positions=None, mode: str = "train", cache=None, enc_out=None,
            skip_unembed: bool = False):
    """Decoder-side forward.

    Returns (logits_or_hidden, new_cache, aux_loss). ``cache`` must be a
    full cache tree (prefill/decode) or None (train).
    """
    dtype = jnp.dtype(cfg.dtype)
    if embeddings is None:
        x = nn.embed(params["embed"], tokens, dtype) * cfg.embedding_multiplier
    else:
        x = embeddings.astype(dtype) * cfg.embedding_multiplier
    B, S = x.shape[:2]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    pos2d = positions[0] if positions.ndim == 3 else positions
    if cfg.rope_style == "learned":
        table = params["pos_embed"]["table"].astype(dtype)
        x = x + jnp.take(table, jnp.clip(pos2d, 0, table.shape[0] - 1),
                         axis=0)
    x = nn.constrain(x, "data", None, None)

    full_cache = cache if cache is not None else no_cache_tree(cfg)
    new_cache: Dict[str, Any] = {}
    aux_total = jnp.zeros((), jnp.float32)

    for group, pattern in (("head", cfg.head_pattern),):
        if not pattern:
            continue
        outs = []
        for lp, kind, c in zip(params[group], pattern, full_cache[group]):
            x, c2, aux = layer_apply(lp, cfg, kind, x, positions, mode=mode,
                                     cache=c, enc_out=enc_out)
            outs.append(c2)
            aux_total = aux_total + aux
        new_cache[group] = outs

    def period_body(carry, xs):
        x, aux_acc = carry
        lps, cs = xs
        new_cs = []
        for i, kind in enumerate(cfg.body_pattern):
            x, c2, aux = layer_apply(lps[i], cfg, kind, x, positions,
                                     mode=mode, cache=cs[i], enc_out=enc_out)
            new_cs.append(c2)
            aux_acc = aux_acc + aux
        return (x, aux_acc), new_cs

    if cfg.scan_layers:
        (x, aux_total), new_body = jax.lax.scan(
            _remat(period_body, cfg), (x, aux_total),
            (params["body"], full_cache["body"]))
    else:
        body_fn = _remat(period_body, cfg)
        outs = []
        carry = (x, aux_total)
        for p in range(cfg.n_periods):
            sl = lambda t: jax.tree_util.tree_map(lambda a: a[p], t)
            carry, new_cs = body_fn(carry, (sl(params["body"]),
                                            sl(full_cache["body"])))
            outs.append(new_cs)
        x, aux_total = carry
        new_body = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *outs) if outs else []
    new_cache["body"] = new_body

    for group, pattern in (("tail", cfg.tail_pattern),):
        if not pattern:
            continue
        outs = []
        for lp, kind, c in zip(params[group], pattern, full_cache[group]):
            x, c2, aux = layer_apply(lp, cfg, kind, x, positions, mode=mode,
                                     cache=c, enc_out=enc_out)
            outs.append(c2)
            aux_total = aux_total + aux
        new_cache[group] = outs

    x = nn.apply_norm(params["final_norm"], cfg.norm, x)
    if skip_unembed:
        return x, (new_cache if cache is not None else None), aux_total
    logits = unembed(params, cfg, x)
    return logits, (new_cache if cache is not None else None), aux_total


def unembed(params, cfg: ModelConfig, x):
    if cfg.tie_embeddings:
        logits = nn.unembed(params["embed"], x)
    else:
        logits = nn.linear(params["lm_head"], x)
    logits = logits / cfg.logits_scaling
    return nn.constrain(logits, "data", None, "model")


# ---------------------------------------------------------------------------
# Losses / steps
# ---------------------------------------------------------------------------

def cross_entropy(logits, labels):
    """Mean CE in f32; logits (..., V), labels (...) int32."""
    logits = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(logits, -1, keepdims=True))
    shifted = logits - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), -1)) + m[..., 0]
    correct = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    return jnp.mean(lse - correct)


def loss_fn(params, cfg: ModelConfig, batch):
    """batch keys: tokens|embeddings, labels, [positions], [frames].

    Returns (total_loss, metrics).
    """
    enc_out = None
    if cfg.n_encoder_layers:
        enc_out = run_encoder(
            params, cfg, batch["frames"].astype(jnp.dtype(cfg.dtype)))
    kwargs = dict(mode="train", cache=None, enc_out=enc_out)
    if "positions" in batch:
        kwargs["positions"] = batch["positions"]
    if "embeddings" in batch:
        kwargs["embeddings"] = batch["embeddings"]
    else:
        kwargs["tokens"] = batch["tokens"]

    labels = batch["labels"]
    S = labels.shape[1]
    if cfg.chunked_ce > 0 and S % cfg.chunked_ce == 0:
        hidden, _, aux = forward(params, cfg, skip_unembed=True, **kwargs)
        C = cfg.chunked_ce
        B = labels.shape[0]
        hc = jnp.moveaxis(hidden.reshape(B, S // C, C, -1), 1, 0)
        yc = jnp.moveaxis(labels.reshape(B, S // C, C), 1, 0)

        def body(acc, xs):
            h_i, y_i = xs
            return acc + cross_entropy(unembed(params, cfg, h_i), y_i), None

        total, _ = jax.lax.scan(jax.checkpoint(body),
                                jnp.zeros((), jnp.float32), (hc, yc))
        ce = total * (C / S)
    else:
        logits, _, aux = forward(params, cfg, **kwargs)
        ce = cross_entropy(logits, labels)
    return ce + aux, {"ce": ce, "aux": aux}


def prefill(params, cfg: ModelConfig, cache, *, tokens=None, embeddings=None,
            positions=None, frames=None):
    """Run the full prompt, fill caches, return (last_logits, cache)."""
    enc_out = None
    if cfg.n_encoder_layers:
        enc_out = run_encoder(
            params, cfg, frames.astype(jnp.dtype(cfg.dtype)))
    hidden, new_cache, _ = forward(
        params, cfg, tokens=tokens, embeddings=embeddings,
        positions=positions, mode="prefill", cache=cache, enc_out=enc_out,
        skip_unembed=True)
    logits = unembed(params, cfg, hidden[:, -1:])
    return logits[:, 0], new_cache


def decode_step(params, cfg: ModelConfig, tokens, pos, cache):
    """One token for every sequence. tokens (B,1); pos (B,) absolute."""
    positions = pos[:, None]
    if cfg.rope_style == "mrope":
        positions = jnp.broadcast_to(positions[None], (3,) + positions.shape)
    logits, new_cache, _ = forward(params, cfg, tokens=tokens,
                                   positions=positions, mode="decode",
                                   cache=cache)
    return logits[:, 0], new_cache
