"""Public model API: build a model bundle from a ModelConfig.

A ``Model`` exposes pure functions (init / loss / prefill / decode /
cache) plus input_specs() producing ShapeDtypeStruct stand-ins for the
dry-run, and the parameter PartitionSpec tree for pjit.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as tfm
from repro.models.config import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ---------------------------------------------------------------- init
    def init(self, key) -> Dict:
        params, _ = tfm.model_init(self.cfg, key)
        return params

    def param_specs(self) -> Dict:
        _, specs = tfm.model_init(self.cfg, None, abstract=True)
        return specs

    def abstract_params(self):
        shapes, _ = tfm.model_init(self.cfg, None, abstract=True)
        return shapes

    # --------------------------------------------------------------- steps
    def loss(self, params, batch) -> Tuple[jnp.ndarray, Dict]:
        return tfm.loss_fn(params, self.cfg, batch)

    def prefill(self, params, cache, **inputs):
        return tfm.prefill(params, self.cfg, cache, **inputs)

    def decode_step(self, params, tokens, pos, cache):
        return tfm.decode_step(params, self.cfg, tokens, pos, cache)

    def init_cache(self, batch: int, length: int, dtype=jnp.bfloat16):
        return tfm.model_cache(self.cfg, batch, length, dtype)

    def abstract_cache(self, batch: int, length: int, dtype=jnp.bfloat16):
        return jax.eval_shape(
            lambda: tfm.model_cache(self.cfg, batch, length, dtype))

    # -------------------------------------------------------------- inputs
    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input of a cell.

        train  -> kwargs for loss(params, batch)
        prefill-> kwargs for prefill(params, cache, **...)
        decode -> (tokens, pos) for decode_step
        """
        cfg = self.cfg
        B = shape.global_batch
        S = shape.seq_len
        i32 = jnp.int32
        f = jnp.dtype(cfg.dtype)
        sds = jax.ShapeDtypeStruct

        def token_batch(seq):
            batch = {"labels": sds((B, seq), i32)}
            if cfg.family == "vlm":
                # stub patch/text frontend: precomputed embeddings + M-RoPE
                batch["embeddings"] = sds((B, seq, cfg.d_model), f)
                batch["positions"] = sds((3, B, seq), i32)
            else:
                batch["tokens"] = sds((B, seq), i32)
            if cfg.family == "audio":
                batch["frames"] = sds((B, cfg.n_audio_frames, cfg.d_model), f)
            return batch

        if shape.kind == "train":
            return {"batch": token_batch(S)}
        if shape.kind == "prefill":
            batch = token_batch(S)
            batch.pop("labels")
            if "embeddings" in batch:
                pass
            else:
                batch["tokens"] = sds((B, S), i32)
            return {"batch": batch,
                    "cache": self.abstract_cache(B, S)}
        # decode
        return {
            "tokens": sds((B, 1), i32),
            "pos": sds((B,), i32),
            "cache": self.abstract_cache(B, S),
        }


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
