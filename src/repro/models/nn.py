"""Minimal functional NN layer library (no flax): params are nested dicts.

Every parameter is created through an ``Init`` recorder which builds, in
parallel with the parameter tree, a PartitionSpec tree used by the
launcher for pjit sharding. Axis name conventions:

  "data"  — batch-parallel axis (also pod-major when multi-pod)
  "model" — tensor/expert-parallel axis
  None    — replicated
"""

from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


class Init:
    """Records a parallel (params, specs) tree as layers declare params.

    With ``abstract=True`` parameters are ShapeDtypeStruct stand-ins (no
    allocation) — used by the dry-run and by param_specs().
    """

    def __init__(self, key: Optional[jax.Array], dtype=jnp.float32,
                 abstract: bool = False):
        self._key = key if key is not None else jax.random.PRNGKey(0)
        self.dtype = dtype
        self.abstract = abstract

    def next_key(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub

    def param(self, shape, spec, scale: float = 1.0, mode: str = "normal"):
        """Create one parameter array and return (array, spec)."""
        pspec = P(*spec) if isinstance(spec, tuple) else spec
        if self.abstract:
            return jax.ShapeDtypeStruct(tuple(shape), self.dtype), pspec
        if mode == "zeros":
            arr = jnp.zeros(shape, self.dtype)
        elif mode == "ones":
            arr = jnp.ones(shape, self.dtype)
        elif mode == "normal":
            arr = jax.random.normal(self.next_key(), shape, self.dtype) * scale
        elif mode == "uniform":
            arr = jax.random.uniform(
                self.next_key(), shape, self.dtype, -scale, scale
            )
        elif mode == "lru_lambda":  # Griffin Lambda init: U(0.2, 0.85)
            arr = jax.random.uniform(
                self.next_key(), shape, self.dtype, 0.2, 0.85
            )
        else:  # pragma: no cover
            raise ValueError(mode)
        return arr, pspec


def fanin_scale(fan_in: int) -> float:
    return 1.0 / math.sqrt(max(fan_in, 1))


# ---------------------------------------------------------------------------
# Linear / embeddings
# ---------------------------------------------------------------------------

def linear_init(init: Init, d_in: int, d_out: int, spec=(None, "model"),
                bias: bool = False, scale: Optional[float] = None):
    scale = fanin_scale(d_in) if scale is None else scale
    w, ws = init.param((d_in, d_out), spec, scale=scale)
    params = {"w": w}
    specs = {"w": ws}
    if bias:
        bspec = (spec[-1],) if isinstance(spec, tuple) else (None,)
        b, bs = init.param((d_out,), bspec, mode="zeros")
        params["b"] = b
        specs["b"] = bs
    return params, specs


def linear(params, x):
    y = x @ params["w"].astype(x.dtype)
    if "b" in params:
        y = y + params["b"].astype(x.dtype)
    return y


def embed_init(init: Init, vocab: int, d_model: int):
    t, ts = init.param((vocab, d_model), ("model", None), scale=1.0)
    return {"table": t}, {"table": ts}


def embed(params, ids, dtype):
    return params["table"].astype(dtype)[ids]


def unembed(params, x):
    """Logits via (tied) embedding table."""
    return x @ params["table"].astype(x.dtype).T


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_init(init: Init, kind: str, dim: int):
    if kind == "rmsnorm":
        s, ss = init.param((dim,), (None,), mode="ones")
        return {"scale": s}, {"scale": ss}
    if kind == "layernorm":
        s, ss = init.param((dim,), (None,), mode="ones")
        b, bs = init.param((dim,), (None,), mode="zeros")
        return {"scale": s, "bias": b}, {"scale": ss, "bias": bs}
    if kind == "nonparametric_ln":
        return {}, {}
    raise ValueError(kind)


def apply_norm(params, kind: str, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
        y = y * params["scale"].astype(jnp.float32)
    else:  # layernorm / nonparametric_ln
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), -1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        if params:
            y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(
                jnp.float32
            )
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_init(init: Init, kind: str, d_model: int, d_ff: int):
    if kind in ("swiglu", "geglu"):
        wi, wis = init.param((d_model, 2, d_ff), (None, None, "model"),
                             scale=fanin_scale(d_model))
        wo, wos = init.param((d_ff, d_model), ("model", None),
                             scale=fanin_scale(d_ff))
        return {"wi": wi, "wo": wo}, {"wi": wis, "wo": wos}
    if kind == "gelu":
        p1, s1 = linear_init(init, d_model, d_ff, (None, "model"), bias=True)
        p2, s2 = linear_init(init, d_ff, d_model, ("model", None), bias=True)
        return {"in": p1, "out": p2}, {"in": s1, "out": s2}
    raise ValueError(kind)


def apply_mlp(params, kind: str, x):
    if kind in ("swiglu", "geglu"):
        wi = params["wi"].astype(x.dtype)
        h = jnp.einsum("...d,dtf->...tf", x, wi)
        gate, up = h[..., 0, :], h[..., 1, :]
        act = jax.nn.silu(gate) if kind == "swiglu" else jax.nn.gelu(gate)
        return (act * up) @ params["wo"].astype(x.dtype)
    h = jax.nn.gelu(linear(params["in"], x))
    return linear(params["out"], h)


# ---------------------------------------------------------------------------
# Rotary embeddings (RoPE and M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)  # (head_dim/2,)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    freqs = rope_freqs(x.shape[-1], theta)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray, theta: float,
                sections: Tuple[int, int, int] = (1, 1, 2)):
    """Qwen2-VL M-RoPE: rotary dims split into temporal/height/width groups.

    x: (batch, seq, heads, head_dim); positions3: (3, batch, seq).
    ``sections`` are relative fractions of head_dim/2 for (t, h, w).
    """
    hd = x.shape[-1]
    half = hd // 2
    total = sum(sections)
    splits = [half * s // total for s in sections]
    splits[-1] = half - sum(splits[:-1])
    freqs = rope_freqs(hd, theta)  # (half,)
    parts, start = [], 0
    for i, n in enumerate(splits):
        pos = positions3[i][..., None].astype(jnp.float32)  # (b, s, 1)
        parts.append(pos * freqs[start:start + n])
        start += n
    ang = jnp.concatenate(parts, -1)  # (b, s, half)
    sin = jnp.sin(ang)[..., None, :]
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, dim: int) -> jnp.ndarray:
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    div = jnp.exp(
        jnp.arange(0, dim, 2, dtype=jnp.float32) * (-math.log(10000.0) / dim)
    )
    pe = jnp.zeros((n, dim), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# ---------------------------------------------------------------------------
# Sharding helpers
# ---------------------------------------------------------------------------

# Logical -> physical axis mapping. The launcher remaps "data" to
# ("pod", "data") on the multi-pod mesh so in-model constraints stay
# consistent with the input shardings (no accidental resharding).
_AXIS_MAP = {"data": "data", "model": "model"}


def set_axis_map(mapping):
    _AXIS_MAP.update(mapping)


def logical_spec(*spec) -> P:
    return P(*[_AXIS_MAP.get(a, a) if isinstance(a, str) else a
               for a in spec])


def constrain(x, *spec):
    """with_sharding_constraint that is a no-op outside a mesh context."""
    try:
        return jax.lax.with_sharding_constraint(x, logical_spec(*spec))
    except (ValueError, RuntimeError, TypeError, AssertionError):
        return x


def shardable(n: int, axis_size: int) -> bool:
    return axis_size > 0 and n % axis_size == 0
