"""Model zoo: unified LM stack covering all assigned architectures."""

from repro.models.config import ModelConfig, MoEConfig, MLAConfig
from repro.models.model_zoo import build_model

__all__ = ["ModelConfig", "MoEConfig", "MLAConfig", "build_model"]
