"""Attention: GQA/MQA, sliding-window, MLA (DeepSeek), cross-attention.

Three compute paths:
  * "full"    — materialize (S, T) scores; used for short sequences/tests.
  * "chunked" — lax.scan over query chunks (memory-efficient attention);
                sliding-window layers slice only a (chunk+window) K span,
                so local attention is O(S * window).
  * "pallas"  — repro.kernels.flash_attention (TPU target; validated in
                interpret mode in tests).

KV caches:
  * full layers   — (B, T_max, KH, hd) K/V written at absolute positions.
  * local layers  — ring buffer (B, W, KH, hd) + slot position array.
  * MLA           — compressed (B, T, kv_lora) + (B, T, rope_dim) cache and
                    an absorbed decode path (the DeepSeek-V2 inference
                    optimization).
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import nn
from repro.models.config import ModelConfig

NEG_INF = -2.3819763e38  # large negative for masking in fp32


# ---------------------------------------------------------------------------
# Parameter initialization
# ---------------------------------------------------------------------------

def attention_init(init: nn.Init, cfg: ModelConfig, cross: bool = False):
    """Standard (non-MLA) attention parameters."""
    d, H, KH, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    # shard kv heads on "model" only if divisible by a typical TP degree;
    # the launcher re-checks divisibility and may replicate instead.
    params, specs = {}, {}

    def proj(name, shape, spec, bias_len=0):
        w, ws = init.param(shape, spec, scale=nn.fanin_scale(shape[0]))
        params[name] = {"w": w}
        specs[name] = {"w": ws}
        if cfg.qkv_bias and bias_len:
            b, bs = init.param((bias_len,), (None,), mode="zeros")
            params[name]["b"] = b
            specs[name]["b"] = bs

    proj("wq", (d, H * hd), (None, "model"), H * hd)
    proj("wk", (d, KH * hd), (None, "model"), KH * hd)
    proj("wv", (d, KH * hd), (None, "model"), KH * hd)
    w, ws = init.param((H * hd, d), ("model", None), scale=nn.fanin_scale(H * hd))
    params["wo"] = {"w": w}
    specs["wo"] = {"w": ws}
    if cfg.qk_norm:
        for nm in ("q_norm", "k_norm"):
            p, s = nn.norm_init(init, "rmsnorm", hd)
            params[nm], specs[nm] = p, s
    return params, specs


def mla_init(init: nn.Init, cfg: ModelConfig):
    """DeepSeek-V2 Multi-head Latent Attention parameters."""
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    params, specs = {}, {}

    def proj(name, shape, spec):
        w, ws = init.param(shape, spec, scale=nn.fanin_scale(shape[0]))
        params[name] = {"w": w}
        specs[name] = {"w": ws}

    proj("wq", (d, H * qk_dim), (None, "model"))
    # joint down-projection: compressed kv + decoupled rope key
    proj("w_dkv", (d, m.kv_lora_rank + m.qk_rope_head_dim), (None, None))
    p, s = nn.norm_init(init, "rmsnorm", m.kv_lora_rank)
    params["kv_norm"], specs["kv_norm"] = p, s
    proj("w_uk", (m.kv_lora_rank, H * m.qk_nope_head_dim), (None, "model"))
    proj("w_uv", (m.kv_lora_rank, H * m.v_head_dim), (None, "model"))
    proj("wo", (H * m.v_head_dim, d), ("model", None))
    return params, specs


# ---------------------------------------------------------------------------
# Core attend: q (B,S,H,hd) x k/v (B,T,KH,hd) with GQA + masking
# ---------------------------------------------------------------------------

def _gqa_scores(q, k, scale):
    B, S, H, hd = q.shape
    KH = k.shape[2]
    G = H // KH
    qg = q.reshape(B, S, KH, G, hd)
    return jnp.einsum("bskgd,btkd->bkgst", qg, k) * scale  # (B,KH,G,S,T)


def _gqa_values(probs, v):
    B, KH, G, S, T = probs.shape
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(B, S, KH * G, -1)


def _softmax(scores, mask, softcap: float):
    s = scores.astype(jnp.float32)
    if softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, -1, keepdims=True)
    e = jnp.exp(s - jax.lax.stop_gradient(m))
    denom = jnp.sum(e, -1, keepdims=True)
    return e / jnp.maximum(denom, 1e-30)


def attend_full(q, k, v, q_pos, k_pos, *, causal: bool, window: int,
                scale: float, softcap: float = 0.0):
    """Materialized-scores attention. positions: (B,S)/(B,T) absolute."""
    scores = _gqa_scores(q, k, scale)  # (B,KH,G,S,T)
    rel = q_pos[:, None, None, :, None] - k_pos[:, None, None, None, :]
    mask = k_pos[:, None, None, None, :] >= 0  # negative pos = invalid slot
    if causal:
        mask &= rel >= 0
    if window > 0:
        mask &= rel < window
    probs = _softmax(scores, mask, softcap)
    return _gqa_values(probs.astype(v.dtype), v)


def attend_chunked(q, k, v, q_pos, k_pos, *, causal: bool, window: int,
                   scale: float, softcap: float = 0.0, chunk: int = 1024,
                   causal_skip: bool = True,
                   max_unrolled_chunks: int = 32):
    """Query-chunked attention (memory-efficient).

    Chunks are *unrolled* (python loop, static shapes) up to
    max_unrolled_chunks — XLA's cost analysis counts while-loop bodies
    once, so an inner scan would hide attention FLOPs from the roofline.
    Per chunk the K span is:
      * sliding-window: the static (chunk + window) slice — O(S*window);
      * causal + causal_skip: the growing static prefix (skips the
        masked upper triangle — halves score/AV FLOPs; §Perf);
      * otherwise: full K (mask only; the paper-faithful baseline path).
    Beyond max_unrolled_chunks a lax.scan with full-K chunks is used.
    """
    B, S, H, hd = q.shape
    if S % chunk != 0:
        return attend_full(q, k, v, q_pos, k_pos, causal=causal,
                           window=window, scale=scale, softcap=softcap)
    n_chunks = S // chunk
    qc = q.reshape(B, n_chunks, chunk, H, hd)
    qp = q_pos.reshape(B, n_chunks, chunk)
    T = k.shape[1]
    use_span = window > 0 and causal and (chunk + window) <= T
    span = chunk + window if use_span else T
    same_seq = T == S

    if n_chunks <= max_unrolled_chunks:
        outs = []
        for i in range(n_chunks):
            if use_span and same_seq:
                lo = max(i * chunk - window, 0)
                hi = (i + 1) * chunk
            elif causal and causal_skip and same_seq:
                lo, hi = 0, (i + 1) * chunk
            else:
                lo, hi = 0, T
            o_i = attend_full(qc[:, i], k[:, lo:hi], v[:, lo:hi],
                              qp[:, i], k_pos[:, lo:hi], causal=causal,
                              window=window, scale=scale, softcap=softcap)
            outs.append(o_i)
        out = jnp.stack(outs, axis=1)
        return out.reshape(B, S, H, v.shape[-1])

    def body(_, inputs):
        i, q_i, qp_i = inputs
        if use_span:
            start = jnp.maximum(i * chunk - window, 0)
            start = jnp.minimum(start, T - span)
            k_i = jax.lax.dynamic_slice_in_dim(k, start, span, 1)
            v_i = jax.lax.dynamic_slice_in_dim(v, start, span, 1)
            kp_i = jax.lax.dynamic_slice_in_dim(k_pos, start, span, 1)
        else:
            k_i, v_i, kp_i = k, v, k_pos
        o_i = attend_full(q_i, k_i, v_i, qp_i, kp_i, causal=causal,
                          window=window, scale=scale, softcap=softcap)
        return None, o_i

    idx = jnp.arange(n_chunks)
    _, out = jax.lax.scan(
        body, None,
        (idx, jnp.moveaxis(qc, 1, 0), jnp.moveaxis(qp, 1, 0)),
    )
    # value head dim may differ from the query head dim (MLA)
    return jnp.moveaxis(out, 0, 1).reshape(B, S, H, v.shape[-1])


def attend(q, k, v, q_pos, k_pos, *, causal, window, scale, softcap=0.0,
           impl: str = "reference", causal_skip: bool = True):
    big = q.shape[1] > 2048
    if impl == "pallas" and q.shape[1] == k.shape[1] and causal:
        from repro.kernels.flash_attention import ops as fa_ops

        return fa_ops.flash_attention(q, k, v, causal=True, window=window,
                                      scale=scale)
    if big:
        return attend_chunked(q, k, v, q_pos, k_pos, causal=causal,
                              window=window, scale=scale, softcap=softcap,
                              causal_skip=causal_skip)
    return attend_full(q, k, v, q_pos, k_pos, causal=causal, window=window,
                       scale=scale, softcap=softcap)


# ---------------------------------------------------------------------------
# Standard attention block (GQA; full or sliding-window; optional cache)
# ---------------------------------------------------------------------------

def init_kv_cache(cfg: ModelConfig, batch: int, length: int, local: bool,
                  dtype=jnp.bfloat16):
    """Cache pytree for one attention layer. With cfg.kv_quant, K/V are
    int8 with per-(slot, head) scales (half the HBM bytes per read)."""
    W = min(cfg.local_window, length) if local else length
    KH, hd = cfg.n_kv_heads, cfg.head_dim
    cache = {
        # absolute position held by each slot; -1 = empty
        "pos": jnp.full((batch, W), -1, jnp.int32),
    }
    if cfg.kv_quant:
        cache.update({
            "k": jnp.zeros((batch, W, KH, hd), jnp.int8),
            "v": jnp.zeros((batch, W, KH, hd), jnp.int8),
            "k_scale": jnp.zeros((batch, W, KH), jnp.bfloat16),
            "v_scale": jnp.zeros((batch, W, KH), jnp.bfloat16),
        })
    else:
        cache.update({
            "k": jnp.zeros((batch, W, KH, hd), dtype),
            "v": jnp.zeros((batch, W, KH, hd), dtype),
        })
    return cache


def _quantize_kv(x):
    """x: (..., hd) -> (int8 values, scale (...,))."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(xf / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.bfloat16)


def _dequantize_kv(q, scale, dtype):
    return (q.astype(jnp.float32)
            * scale.astype(jnp.float32)[..., None]).astype(dtype)


def _project_qkv(params, cfg: ModelConfig, x, positions):
    B, S, _ = x.shape
    H, KH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = nn.linear(params["wq"], x).reshape(B, S, H, hd)
    k = nn.linear(params["wk"], x).reshape(B, S, KH, hd)
    v = nn.linear(params["wv"], x).reshape(B, S, KH, hd)
    if cfg.qk_norm:
        q = nn.apply_norm(params["q_norm"], "rmsnorm", q)
        k = nn.apply_norm(params["k_norm"], "rmsnorm", k)
    if cfg.rope_style == "rope":
        q = nn.apply_rope(q, positions, cfg.rope_theta)
        k = nn.apply_rope(k, positions, cfg.rope_theta)
    elif cfg.rope_style == "mrope":
        # positions: (3, B, S) for mrope models; (B, S) falls back to rope
        if positions.ndim == 3:
            q = nn.apply_mrope(q, positions, cfg.rope_theta)
            k = nn.apply_mrope(k, positions, cfg.rope_theta)
        else:
            q = nn.apply_rope(q, positions, cfg.rope_theta)
            k = nn.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _attn_scale(cfg: ModelConfig) -> float:
    if cfg.attention_multiplier > 0:
        return cfg.attention_multiplier
    return 1.0 / math.sqrt(cfg.head_dim)


def attention_block(params, cfg: ModelConfig, x, positions, *, local: bool,
                    mode: str = "train", cache=None, causal: bool = True):
    """Returns (output, new_cache). positions: (B,S) or (3,B,S) absolute."""
    B, S, _ = x.shape
    pos2d = positions[0] if positions.ndim == 3 else positions
    q, k, v = _project_qkv(params, cfg, x, positions)
    window = cfg.local_window if local else 0
    scale = _attn_scale(cfg)
    new_cache = cache

    quant = cache is not None and "k_scale" in cache
    if mode in ("train", "prefill"):
        out = attend(q, k, v, pos2d, pos2d, causal=causal, window=window,
                     scale=scale, softcap=cfg.attn_logit_softcap,
                     impl=cfg.attn_impl, causal_skip=cfg.causal_skip)
        if mode == "prefill" and cache is not None:
            W = cache["k"].shape[1]
            if W >= S:
                kpad = jnp.pad(k, ((0, 0), (0, W - S), (0, 0), (0, 0)))
                vpad = jnp.pad(v, ((0, 0), (0, W - S), (0, 0), (0, 0)))
                ppad = jnp.pad(pos2d, ((0, 0), (0, W - S)),
                               constant_values=-1)
            else:  # keep last W entries (ring semantics preserved below)
                sl = lambda a: a[:, S - W:]
                kpad, vpad, ppad = sl(k), sl(v), sl(pos2d)
            if quant:
                kq, ks = _quantize_kv(kpad)
                vq, vs = _quantize_kv(vpad)
                new_cache = {"k": kq, "v": vq, "k_scale": ks,
                             "v_scale": vs, "pos": ppad}
            else:
                new_cache = {"k": kpad.astype(cache["k"].dtype),
                             "v": vpad.astype(cache["v"].dtype),
                             "pos": ppad}
    elif mode == "decode":
        assert cache is not None and S == 1
        W = cache["k"].shape[1]
        slot = jnp.mod(pos2d[:, 0], W)  # (B,)
        bidx = jnp.arange(B)
        if quant:
            kq, ks = _quantize_kv(k[:, 0])
            vq, vs = _quantize_kv(v[:, 0])
            new_cache = {
                "k": cache["k"].at[bidx, slot].set(kq),
                "v": cache["v"].at[bidx, slot].set(vq),
                "k_scale": cache["k_scale"].at[bidx, slot].set(ks),
                "v_scale": cache["v_scale"].at[bidx, slot].set(vs),
                "pos": cache["pos"].at[bidx, slot].set(pos2d[:, 0]),
            }
            ck = _dequantize_kv(new_cache["k"], new_cache["k_scale"],
                                q.dtype)
            cv = _dequantize_kv(new_cache["v"], new_cache["v_scale"],
                                q.dtype)
            cp = new_cache["pos"]
        else:
            ck = cache["k"].at[bidx, slot].set(
                k[:, 0].astype(cache["k"].dtype))
            cv = cache["v"].at[bidx, slot].set(
                v[:, 0].astype(cache["v"].dtype))
            cp = cache["pos"].at[bidx, slot].set(pos2d[:, 0])
            new_cache = {"k": ck, "v": cv, "pos": cp}
            ck = ck.astype(q.dtype)
            cv = cv.astype(q.dtype)
        out = attend_full(q, ck, cv, pos2d, cp, causal=True, window=window,
                          scale=scale, softcap=cfg.attn_logit_softcap)
    else:  # pragma: no cover
        raise ValueError(mode)

    out = out.reshape(B, S, cfg.n_heads * cfg.head_dim)
    return nn.linear(params["wo"], out), new_cache


def attention_block_bidirectional(params, cfg: ModelConfig, x, positions):
    """Encoder self-attention (no mask beyond validity, no cache)."""
    return attention_block(params, cfg, x, positions, local=False,
                           mode="train", cache=None, causal=False)


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder)
# ---------------------------------------------------------------------------

def cross_attention_block(params, cfg: ModelConfig, x, enc_kv):
    """enc_kv: dict with precomputed k/v (B,T,KH,hd) from encoder output."""
    B, S, _ = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    q = nn.linear(params["wq"], x).reshape(B, S, H, hd)
    k, v = enc_kv["k"], enc_kv["v"]
    T = k.shape[1]
    q_pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    k_pos = jnp.broadcast_to(jnp.arange(T), (B, T))
    out = attend(q, k.astype(q.dtype), v.astype(q.dtype), q_pos, k_pos,
                 causal=False, window=0, scale=_attn_scale(cfg))
    out = out.reshape(B, S, H * hd)
    return nn.linear(params["wo"], out)


def encode_cross_kv(params, cfg: ModelConfig, enc_out):
    B, T, _ = enc_out.shape
    KH, hd = cfg.n_kv_heads, cfg.head_dim
    k = nn.linear(params["wk"], enc_out).reshape(B, T, KH, hd)
    v = nn.linear(params["wv"], enc_out).reshape(B, T, KH, hd)
    return {"k": k, "v": v}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2) attention block
# ---------------------------------------------------------------------------

def init_mla_cache(cfg: ModelConfig, batch: int, length: int,
                   dtype=jnp.bfloat16):
    m = cfg.mla
    return {
        "ckv": jnp.zeros((batch, length, m.kv_lora_rank), dtype),
        "krope": jnp.zeros((batch, length, m.qk_rope_head_dim), dtype),
        "pos": jnp.full((batch, length), -1, jnp.int32),
    }


def mla_block(params, cfg: ModelConfig, x, positions, *, mode="train",
              cache=None):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.n_heads
    pos2d = positions[0] if positions.ndim == 3 else positions
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    scale = 1.0 / math.sqrt(qk_dim)

    q = nn.linear(params["wq"], x).reshape(B, S, H, qk_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = nn.apply_rope(q_rope, pos2d, cfg.rope_theta)

    dkv = nn.linear(params["w_dkv"], x)  # (B,S,rank+rope)
    ckv, k_rope = jnp.split(dkv, [m.kv_lora_rank], axis=-1)
    ckv = nn.apply_norm(params["kv_norm"], "rmsnorm", ckv)
    k_rope = nn.apply_rope(k_rope[:, :, None, :], pos2d, cfg.rope_theta)[
        :, :, 0, :
    ]

    new_cache = cache
    if mode == "decode":
        assert cache is not None and S == 1
        T = cache["ckv"].shape[1]
        slot = jnp.mod(pos2d[:, 0], T)
        bidx = jnp.arange(B)
        ckv_all = cache["ckv"].at[bidx, slot].set(
            ckv[:, 0].astype(cache["ckv"].dtype))
        krope_all = cache["krope"].at[bidx, slot].set(
            k_rope[:, 0].astype(cache["krope"].dtype))
        pos_all = cache["pos"].at[bidx, slot].set(pos2d[:, 0])
        new_cache = {"ckv": ckv_all, "krope": krope_all, "pos": pos_all}
        # absorbed decode: score = q_nope @ W_uk^T @ ckv + q_rope @ k_rope
        wuk = params["w_uk"]["w"].astype(x.dtype).reshape(
            m.kv_lora_rank, H, m.qk_nope_head_dim)
        q_abs = jnp.einsum("bshd,rhd->bshr", q_nope, wuk)  # (B,1,H,rank)
        sc = jnp.einsum("bshr,btr->bhst", q_abs,
                        ckv_all.astype(x.dtype)) * scale
        sc += jnp.einsum("bshd,btd->bhst", q_rope,
                         krope_all.astype(x.dtype)) * scale
        rel = pos2d[:, None, :, None] - pos_all[:, None, None, :]
        mask = (pos_all[:, None, None, :] >= 0) & (rel >= 0)
        probs = _softmax(sc, mask, 0.0).astype(x.dtype)
        ctx = jnp.einsum("bhst,btr->bshr", probs, ckv_all.astype(x.dtype))
        wuv = params["w_uv"]["w"].astype(x.dtype).reshape(
            m.kv_lora_rank, H, m.v_head_dim)
        out = jnp.einsum("bshr,rhv->bshv", ctx, wuv)
    else:
        if mode == "prefill" and cache is not None:
            T = cache["ckv"].shape[1]
            pad = lambda a: jnp.pad(
                a, ((0, 0), (0, T - S)) + ((0, 0),) * (a.ndim - 2))
            new_cache = {
                "ckv": pad(ckv).astype(cache["ckv"].dtype),
                "krope": pad(k_rope).astype(cache["krope"].dtype),
                "pos": jnp.pad(pos2d, ((0, 0), (0, T - S)),
                               constant_values=-1),
            }
        k_nope = nn.linear(params["w_uk"], ckv).reshape(
            B, S, H, m.qk_nope_head_dim)
        v = nn.linear(params["w_uv"], ckv).reshape(B, S, H, m.v_head_dim)
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (B, S, H, m.qk_rope_head_dim))], -1)
        q_full = jnp.concatenate([q_nope, q_rope], -1)
        out = attend(q_full, k_full, v, pos2d, pos2d, causal=True, window=0,
                     scale=scale, impl=cfg.attn_impl,
                     causal_skip=cfg.causal_skip)
    out = out.reshape(B, S, H * m.v_head_dim)
    return nn.linear(params["wo"], out), new_cache
