"""Checkpointing: atomic async saves, restart, elastic resharding."""

from repro.checkpointing.manager import CheckpointManager

__all__ = ["CheckpointManager"]
