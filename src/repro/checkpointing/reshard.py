"""Elastic resharding: move a checkpointed pytree onto a new mesh.

When the watchdog excludes hosts (or capacity is added), the data axis
shrinks/grows; checkpoints store full host arrays, so restore is just a
device_put with the new shardings — but live state can also be resharded
in place without a disk round trip. Divisibility is revalidated against
the new mesh (a spec that no longer divides falls back to replication,
mirroring repro.launch.sharding.resolve_spec).
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.sharding import resolve_spec


def reshard_tree(tree: Any, spec_tree: Any, new_mesh: Mesh) -> Any:
    """Reshard every leaf to the (resolved) spec on the new mesh."""

    def one(leaf, spec):
        if not isinstance(spec, P):
            spec = P()
        resolved = resolve_spec(new_mesh, spec, leaf.shape)
        return jax.device_put(leaf, NamedSharding(new_mesh, resolved))

    return jax.tree_util.tree_map(one, tree, spec_tree)


def elastic_restore(ckpt_manager, template: Any, spec_tree: Any,
                    new_mesh: Mesh, step=None):
    """CheckpointManager.restore + reshard onto the (possibly different)
    current mesh in one call."""
    restored, meta = ckpt_manager.restore(template, step=step)
    if restored is None:
        return None, None
    return reshard_tree(restored, spec_tree, new_mesh), meta
