"""Checkpoint manager: atomic, optionally async, keep-last-K, restart.

Format: one ``step_<n>.npz`` per checkpoint holding every pytree leaf
under its slash-joined path plus a treedef-independent manifest; a
``LATEST`` file is swapped in atomically after a successful write, so a
crash mid-save never corrupts the restore point (fault-tolerance
invariant exercised by tests/test_checkpoint.py).

Elastic restore: leaves are saved as full (unsharded) host arrays; on
restore they are device_put with the *current* mesh's shardings, so the
cluster size may change between runs (``reshard``).
"""

from __future__ import annotations

import json
import os
import queue
import threading
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.tree import tree_flatten_with_paths


class CheckpointManager:
    def __init__(self, directory, keep_last: int = 3, async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        self.async_save = async_save
        #: steps exempt from keep-last GC (e.g. the model plane pins
        #: the incumbent + previous versions however old they are)
        self.pinned: set = set()
        self._queue: "queue.Queue" = queue.Queue()
        self._worker: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def _raise_pending(self):
        """Surface a failed background write on the *next* call (a
        silently-lost checkpoint is a corrupted restore point waiting
        to happen)."""
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, extra: Optional[Dict] = None):
        """Snapshot to host memory synchronously; write async if
        enabled. Raises any error a previous async write hit."""
        self._raise_pending()
        flat = tree_flatten_with_paths(tree)
        host = {path: np.asarray(leaf) for path, leaf in flat}
        payload = (step, host, dict(extra or {}))
        if self.async_save:
            self._ensure_worker()
            self._queue.put(payload)
        else:
            self._write(*payload)

    def _ensure_worker(self):
        if self._worker is None or not self._worker.is_alive():
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()

    def _drain(self):
        while True:
            item = self._queue.get()
            if item is None:
                return
            try:
                self._write(*item)
            except BaseException as e:  # noqa: BLE001
                self._error = e
            finally:
                self._queue.task_done()

    def _write(self, step: int, host: Dict[str, np.ndarray], extra: Dict):
        tmp = self.dir / f".tmp_step_{step}.npz"
        final = self.dir / f"step_{step}.npz"
        np.savez(tmp, **host)
        os.replace(tmp, final)
        meta = {"step": step, "extra": extra}
        mtmp = self.dir / f".tmp_meta_{step}.json"
        mtmp.write_text(json.dumps(meta))
        os.replace(mtmp, self.dir / f"meta_{step}.json")
        ltmp = self.dir / ".tmp_LATEST"
        ltmp.write_text(str(step))
        os.replace(ltmp, self.dir / "LATEST")
        self._gc()

    def _gc(self):
        steps = sorted(s for s in self.all_steps()
                       if s not in self.pinned)
        for s in steps[: -self.keep_last]:
            for f in (self.dir / f"step_{s}.npz",
                      self.dir / f"meta_{s}.json"):
                try:
                    f.unlink()
                except FileNotFoundError:
                    pass

    def wait(self):
        """Block until pending async saves are on disk (barrier before a
        risky operation, and test determinism)."""
        if self._worker is not None and self._worker.is_alive():
            self._queue.join()
        self._raise_pending()

    def close(self):
        """Stop the async writer (drains queued saves first) and raise
        any pending write error. Safe to call repeatedly."""
        if self._worker is not None and self._worker.is_alive():
            self._queue.join()
            self._queue.put(None)
            self._worker.join(timeout=30.0)
        self._worker = None
        self._raise_pending()

    # --------------------------------------------------------------- restore
    def all_steps(self):
        return [int(p.stem.split("_")[1])
                for p in self.dir.glob("step_*.npz")]

    def latest_step(self) -> Optional[int]:
        latest = self.dir / "LATEST"
        if not latest.exists():
            return None
        step = int(latest.read_text().strip())
        return step if (self.dir / f"step_{step}.npz").exists() else None

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None):
        """Restore into the structure of ``template``. ``shardings`` (a
        matching pytree of NamedSharding) reshards for the current mesh."""
        step = self.latest_step() if step is None else step
        if step is None:
            return None, None
        data = np.load(self.dir / f"step_{step}.npz")
        flat = tree_flatten_with_paths(template)
        leaves = []
        for path, leaf in flat:
            arr = data[path]
            leaves.append(jnp.asarray(arr, getattr(leaf, "dtype", None)))
        treedef = jax.tree_util.tree_structure(template)
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        meta_path = self.dir / f"meta_{step}.json"
        extra = (json.loads(meta_path.read_text())["extra"]
                 if meta_path.exists() else {})
        return tree, {"step": step, **extra}
