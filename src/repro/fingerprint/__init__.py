"""Benchmark substrate: standardized tool suites over simulated machines.

The paper gathers data with Kubestone-driven sysbench / fio / ioping /
qperf / iperf3 runs on K3s clusters; this container has no Kubernetes,
so the suite is *simulated* from calibrated machine profiles with
heteroscedastic noise and ChaosMesh-style stress injection (DESIGN.md
§3). Everything downstream of the raw metric records is faithful.
"""

from repro.fingerprint.records import BenchmarkExecution
from repro.fingerprint.frame import (BenchmarkFrame, as_frame,
                                     concat_frames)
from repro.fingerprint.machines import MACHINE_PROFILES, MachineProfile
from repro.fingerprint.runner import SuiteRunner, BENCHMARK_TYPES

__all__ = [
    "BenchmarkExecution",
    "BenchmarkFrame",
    "as_frame",
    "concat_frames",
    "MachineProfile",
    "MACHINE_PROFILES",
    "SuiteRunner",
    "BENCHMARK_TYPES",
]
