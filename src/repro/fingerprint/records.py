"""Record type for one benchmark execution (one Kubestone job run)."""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple


@dataclasses.dataclass
class BenchmarkExecution:
    benchmark_type: str  # e.g. "sysbench-cpu"
    machine: str  # node name, e.g. "node-1"
    machine_type: str  # e.g. "e2-medium"
    t: float  # wall-clock seconds since experiment start
    metrics: Dict[str, Tuple[float, str]]  # name -> (value, unit)
    node_metrics: Dict[str, float]  # low-level machine metrics during run
    stressed: bool  # ground-truth degradation marker (eval only)

    @property
    def resource_aspect(self) -> str:
        return {
            "sysbench-cpu": "cpu",
            "sysbench-memory": "memory",
            "fio": "disk",
            "ioping": "disk",
            "qperf": "network",
            "iperf3": "network",
        }[self.benchmark_type]
