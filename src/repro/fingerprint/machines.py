"""Machine capability profiles for GCP / AWS instance types.

Scores are relative capability scalars calibrated loosely to public
instance specs (vCPU count/clock, memory bandwidth class, network/disk
tiers). They drive the benchmark-tool simulators; absolute values only
need to be *ordered and proportioned* realistically, since Perona's
pipeline normalizes per metric.
"""

from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass(frozen=True)
class MachineProfile:
    name: str
    cpu: float  # single-thread-ish events/s scale
    memory: float  # memory bandwidth scale (MiB/s)
    disk_iops: float
    disk_lat_us: float
    net_gbps: float
    net_lat_us: float
    noise: float = 0.04  # relative run-to-run variation


MACHINE_PROFILES: Dict[str, MachineProfile] = {
    # GCP (paper §IV-C: e2-medium; §IV-E adds n1/n2/c2-standard-4)
    "e2-medium": MachineProfile("e2-medium", 900, 9500, 15000, 260, 4.0, 110),
    "n1-standard-4": MachineProfile("n1-standard-4", 1050, 11000, 30000, 210,
                                    10.0, 85),
    "n2-standard-4": MachineProfile("n2-standard-4", 1400, 15000, 30000, 190,
                                    10.0, 80),
    "c2-standard-4": MachineProfile("c2-standard-4", 1750, 16500, 30000, 185,
                                    10.0, 75),
    # AWS (paper §IV-D: scout dataset machine families)
    "m4.large": MachineProfile("m4.large", 1000, 10500, 3600, 300, 0.45, 140),
    "m4.xlarge": MachineProfile("m4.xlarge", 1950, 20500, 6000, 280, 0.75,
                                130),
    "m4.2xlarge": MachineProfile("m4.2xlarge", 3800, 40000, 8000, 260, 1.0,
                                 120),
    "c4.large": MachineProfile("c4.large", 1300, 11500, 4000, 290, 0.5, 130),
    "c4.xlarge": MachineProfile("c4.xlarge", 2550, 22500, 6000, 270, 0.75,
                                125),
    "c4.2xlarge": MachineProfile("c4.2xlarge", 5000, 44000, 8000, 250, 1.0,
                                 115),
    "r4.large": MachineProfile("r4.large", 1100, 13000, 3000, 310, 10.0, 100),
    "r4.xlarge": MachineProfile("r4.xlarge", 2150, 25500, 6000, 285, 10.0,
                                95),
    "r4.2xlarge": MachineProfile("r4.2xlarge", 4200, 50000, 8000, 265, 10.0,
                                 90),
}

# ChaosMesh-style stress: multiplicative degradation per resource aspect
# at full severity; actual runs draw severity in (0, 1] and interpolate,
# so mild degradations overlap with run-to-run noise (the regime that
# caps the paper's outlier F1 at 0.75).
STRESS_FACTORS = {
    "cpu": {"cpu": 0.45},
    "memory": {"memory": 0.5, "cpu": 0.85},
    "disk": {"disk_iops": 0.35, "disk_lat_us": 2.8},
    "network": {"net_gbps": 0.4, "net_lat_us": 2.5},
}


def stress_multiplier(full_factor: float, severity: float) -> float:
    """Interpolate a full-severity factor toward 1.0 (no effect)."""
    return 1.0 + severity * (full_factor - 1.0)
