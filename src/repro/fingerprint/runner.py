"""Suite runner: quasi-random scheduling of benchmark executions.

Mirrors the paper's acquisition (§IV-A): per machine, each benchmark
type is executed ``runs_per_type`` times, quasi-randomly spread over the
experiment window; network benchmarks are serialized cluster-wide (only
one in flight); a configurable fraction of runs receives ChaosMesh-style
stress on the benchmarked resource.

Acquisition is *columnar*: ``run_frame`` batches the RNG draws per
(machine type x benchmark type) group — one vectorized draw per metric
column — and materializes a :class:`BenchmarkFrame` directly, instead of
looping records x tools x metrics in Python. ``run`` keeps the
record-list API as a thin conversion wrapper, and ``run_reference`` is
the original per-record loop, retained as the benchmarking baseline
(see ``benchmarks/bench_fingerprint.py``).

``run_frame`` draws are *counter-based* (``common.rng``): every group
pulls from an independent generator keyed by ``(seed, round,
benchmark_type, machine_type)`` and nodes iterate in sorted order, so
a group's values are a pure function of that key path and the group's
membership — never of dict insertion order or of which other machine
types are present. The per-call ``round`` counter keeps streaming
semantics (repeated rounds draw fresh values). ``run_reference``
deliberately keeps the single sequential stream (``self.rng``) — it
is the order-*dependent* baseline the frame path is measured against.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.common.rng import folded_generator
from repro.fingerprint.frame import BenchmarkFrame
from repro.fingerprint.machines import MACHINE_PROFILES
from repro.fingerprint.records import BenchmarkExecution
from repro.fingerprint.tools import EXTRA_CONSTANTS, TOOLS, node_metrics

BENCHMARK_TYPES = tuple(TOOLS)

# fold-in stream tag of the columnar frame draws (bumping it re-rolls
# every run_frame realization without touching run_reference)
_FRAME_STREAM = 1

_ASPECT = {
    "sysbench-cpu": "cpu",
    "sysbench-memory": "memory",
    "fio": "disk",
    "ioping": "disk",
    "qperf": "network",
    "iperf3": "network",
}

# metric column layout per benchmark type: (name, unit) in tool order,
# resolved lazily from one probe draw + the constant echoes
_COLUMNS_CACHE: Dict[str, List[Tuple[str, str]]] = {}


def _columns_of(btype: str) -> List[Tuple[str, str]]:
    cols = _COLUMNS_CACHE.get(btype)
    if cols is None:
        probe = TOOLS[btype](MACHINE_PROFILES["e2-medium"],
                             np.random.default_rng(0), np.zeros(1))
        cols = [(name, unit) for name, (_, unit) in probe.items()]
        cols += [(name, unit)
                 for name, (_, unit) in EXTRA_CONSTANTS[btype].items()]
        _COLUMNS_CACHE[btype] = cols
    return cols


class SuiteRunner:
    def __init__(self, seed: int = 0, duration_s: float = 86400.0):
        self.seed = seed
        self.rng = np.random.default_rng(seed)  # run_reference only
        self.duration_s = duration_s
        self._round = 0  # per-call counter: frame rounds stay distinct

    # ------------------------------------------------------------ columnar
    def run_frame(self, machines: Dict[str, str], runs_per_type: int,
                  stress_fraction: float = 0.0,
                  degraded_machines: Optional[Sequence[str]] = None,
                  t_offset: float = 0.0) -> BenchmarkFrame:
        """Columnar acquisition. ``machines``: {node_name: machine_type}.
        ``degraded_machines`` are permanently degraded (every run
        stressed) — used by the runtime watchdog tests. ``t_offset``
        shifts every timestamp (streaming re-fingerprinting rounds
        happen *after* the history they are scored against)."""
        degraded = set(degraded_machines or ())
        node_names = list(machines)
        mtype_vocab = list(dict.fromkeys(machines.values()))
        node_code = {n: i for i, n in enumerate(node_names)}
        mtype_code = {m: i for i, m in enumerate(mtype_vocab)}

        # global metric column layout (union over benchmark types)
        col_index: Dict[Tuple[str, str], int] = {}
        for btype in BENCHMARK_TYPES:
            for key in _columns_of(btype):
                col_index.setdefault(key, len(col_index))
        node_cols = list(node_metrics(
            MACHINE_PROFILES["e2-medium"], np.random.default_rng(0),
            np.zeros(1), "cpu"))
        ncol_index = {k: i for i, k in enumerate(node_cols)}

        n_nodes = len(node_names)
        N = n_nodes * len(BENCHMARK_TYPES) * runs_per_type
        metrics = np.zeros((N, len(col_index)), np.float64)
        present = np.zeros((N, len(col_index)), bool)
        nmetrics = np.zeros((N, len(node_cols)), np.float64)
        type_code = np.empty(N, np.int32)
        machine_code = np.empty(N, np.int32)
        machine_type_code = np.empty(N, np.int32)
        t = np.empty(N, np.float64)
        stressed_all = np.empty(N, bool)

        # per-call round counter: repeated frame rounds on one runner
        # draw fresh (but order-independent) values
        rnd = self._round
        self._round += 1

        # cluster-wide serialized slots for the network benchmarks: one
        # sorted pool, randomly assigned, so only one network benchmark
        # is in flight at any time; its own fold-in stream, consumed in
        # canonical group order
        n_net = sum(runs_per_type * n_nodes
                    for b in BENCHMARK_TYPES if _ASPECT[b] == "network")
        net_rng = folded_generator(self.seed, rnd, "net-slots",
                                   _FRAME_STREAM)
        net_slots = np.sort(net_rng.uniform(0, self.duration_s, n_net))
        net_order = net_rng.permutation(n_net)
        net_used = 0

        # group rows by (benchmark type x machine type): profile constant
        # within a group, so every metric is one batched draw. Groups
        # iterate in canonical sorted order and each pulls from its own
        # (seed, round, btype, mtype) fold-in generator, so a group's
        # draws never depend on dict insertion order or on which other
        # machine types are present.
        nodes_by_mtype: Dict[str, List[str]] = {}
        for node, mtype in machines.items():
            nodes_by_mtype.setdefault(mtype, []).append(node)
        group_mtypes = sorted(nodes_by_mtype)
        for mtype in group_mtypes:
            nodes_by_mtype[mtype].sort()

        off = 0
        for btype in BENCHMARK_TYPES:
            aspect = _ASPECT[btype]
            bt_code = BENCHMARK_TYPES.index(btype)
            cols = np.asarray([col_index[key] for key in
                               _columns_of(btype)], np.int64)
            n_tool_cols = len(cols) - len(EXTRA_CONSTANTS[btype])
            for mtype in group_mtypes:
                nodes = nodes_by_mtype[mtype]
                profile = MACHINE_PROFILES[mtype]
                grng = folded_generator(self.seed, rnd, btype, mtype,
                                        _FRAME_STREAM)
                R = len(nodes) * runs_per_type
                sl = slice(off, off + R)
                rows_node = np.repeat(
                    np.asarray([node_code[n] for n in nodes], np.int32),
                    runs_per_type)
                if aspect == "network":
                    slots = net_slots[net_order[net_used:net_used + R]]
                    net_used += R
                    t[sl] = slots
                else:
                    t[sl] = grng.uniform(0, self.duration_s, R)
                degraded_mask = np.isin(
                    rows_node,
                    [node_code[n] for n in degraded if n in node_code])
                stressed = degraded_mask | (
                    grng.random(R) < stress_fraction)
                severity = np.where(
                    stressed, grng.uniform(0.15, 1.0, R), 0.0)

                md = TOOLS[btype](profile, grng, severity)
                block = np.empty((R, len(cols)), np.float64)
                for j, (name, (vals, _unit)) in enumerate(md.items()):
                    block[:, j] = vals
                for j, (name, (v, _unit)) in enumerate(
                        EXTRA_CONSTANTS[btype].items()):
                    block[:, n_tool_cols + j] = v
                metrics[sl, cols] = block
                present[sl, cols] = True

                nd = node_metrics(profile, grng, severity, aspect)
                for name, vals in nd.items():
                    nmetrics[sl, ncol_index[name]] = vals

                type_code[sl] = bt_code
                machine_code[sl] = rows_node
                machine_type_code[sl] = mtype_code[mtype]
                stressed_all[sl] = stressed
                off += R

        frame = BenchmarkFrame(
            benchmark_types=BENCHMARK_TYPES,
            machines=tuple(node_names),
            machine_types=tuple(mtype_vocab),
            metric_names=tuple(k[0] for k in col_index),
            metric_units=tuple(k[1] for k in col_index),
            node_metric_names=tuple(node_cols),
            type_code=type_code, machine_code=machine_code,
            machine_type_code=machine_type_code, t=t,
            stressed=stressed_all,
            metrics=metrics, metrics_present=present,
            node_metrics=nmetrics,
            node_metrics_present=np.ones_like(nmetrics, bool))
        if t_offset:
            frame.t += t_offset
        return frame.sort_by_time()

    # ----------------------------------------------------- record wrapper
    def run(self, machines: Dict[str, str], runs_per_type: int,
            stress_fraction: float = 0.0,
            degraded_machines: Optional[Sequence[str]] = None,
            ) -> List[BenchmarkExecution]:
        """Record-list acquisition (conversion wrapper over
        :meth:`run_frame`)."""
        return self.run_frame(machines, runs_per_type, stress_fraction,
                              degraded_machines).to_records()

    # ------------------------------------------------------ seed baseline
    def run_reference(self, machines: Dict[str, str], runs_per_type: int,
                      stress_fraction: float = 0.0,
                      degraded_machines: Optional[Sequence[str]] = None,
                      ) -> List[BenchmarkExecution]:
        """The original per-record triple loop (node x type x run, one
        tool-simulator call per record). Kept as the acquisition
        throughput baseline; statistically equivalent to ``run_frame``
        but draws the RNG stream in a different order."""
        degraded = set(degraded_machines or ())
        records: List[BenchmarkExecution] = []
        net_slots = iter(np.sort(self.rng.uniform(
            0, self.duration_s,
            2 * runs_per_type * max(len(machines), 1) + 8)))
        one = np.ones(1)
        for node, mtype in machines.items():
            profile = MACHINE_PROFILES[mtype]
            for btype in BENCHMARK_TYPES:
                aspect = _ASPECT[btype]
                times = np.sort(self.rng.uniform(0, self.duration_s,
                                                 runs_per_type))
                for t in times:
                    stressed = (node in degraded or
                                bool(self.rng.random() < stress_fraction))
                    severity = (float(self.rng.uniform(0.15, 1.0))
                                if stressed else 0.0) * one
                    if aspect == "network":
                        t = float(next(net_slots))  # serialized slot
                    metrics = {
                        name: (float(vals[0]), unit)
                        for name, (vals, unit) in TOOLS[btype](
                            profile, self.rng, severity).items()
                    }
                    metrics.update(EXTRA_CONSTANTS[btype])
                    records.append(BenchmarkExecution(
                        benchmark_type=btype,
                        machine=node,
                        machine_type=mtype,
                        t=float(t),
                        metrics=metrics,
                        node_metrics={
                            k: float(v[0]) for k, v in node_metrics(
                                profile, self.rng, severity,
                                aspect).items()},
                        stressed=bool(stressed),
                    ))
        records.sort(key=lambda r: r.t)
        return records


def paper_acquisition(seed: int = 0) -> List[BenchmarkExecution]:
    """§IV-C setup: 3 benchmarking nodes (e2-medium), 6 types x 100 runs
    each, 20% stressed -> 1800 executions."""
    return paper_acquisition_frame(seed).to_records()


def paper_acquisition_frame(seed: int = 0) -> BenchmarkFrame:
    """Columnar §IV-C acquisition (same content as
    :func:`paper_acquisition`, no record conversion)."""
    runner = SuiteRunner(seed=seed)
    machines = {f"node-{i}": "e2-medium" for i in range(1, 4)}
    return runner.run_frame(machines, runs_per_type=100,
                            stress_fraction=0.2)
