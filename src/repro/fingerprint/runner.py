"""Suite runner: quasi-random scheduling of benchmark executions.

Mirrors the paper's acquisition (§IV-A): per machine, each benchmark
type is executed ``runs_per_type`` times, quasi-randomly spread over the
experiment window; network benchmarks are serialized cluster-wide (only
one in flight); a configurable fraction of runs receives ChaosMesh-style
stress on the benchmarked resource.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.fingerprint.machines import MACHINE_PROFILES
from repro.fingerprint.records import BenchmarkExecution
from repro.fingerprint.tools import EXTRA_CONSTANTS, TOOLS, node_metrics

BENCHMARK_TYPES = tuple(TOOLS)

_ASPECT = {
    "sysbench-cpu": "cpu",
    "sysbench-memory": "memory",
    "fio": "disk",
    "ioping": "disk",
    "qperf": "network",
    "iperf3": "network",
}


class SuiteRunner:
    def __init__(self, seed: int = 0, duration_s: float = 86400.0):
        self.rng = np.random.default_rng(seed)
        self.duration_s = duration_s

    def run(self, machines: Dict[str, str], runs_per_type: int,
            stress_fraction: float = 0.0,
            degraded_machines: Optional[Sequence[str]] = None,
            ) -> List[BenchmarkExecution]:
        """machines: {node_name: machine_type}. ``degraded_machines`` are
        permanently degraded (every run stressed) — used by the runtime
        watchdog tests."""
        degraded = set(degraded_machines or ())
        records: List[BenchmarkExecution] = []
        net_slots = iter(np.sort(self.rng.uniform(
            0, self.duration_s,
            2 * runs_per_type * max(len(machines), 1) + 8)))
        for node, mtype in machines.items():
            profile = MACHINE_PROFILES[mtype]
            for btype in BENCHMARK_TYPES:
                aspect = _ASPECT[btype]
                times = np.sort(self.rng.uniform(0, self.duration_s,
                                                 runs_per_type))
                for t in times:
                    stressed = (node in degraded or
                                bool(self.rng.random() < stress_fraction))
                    severity = (float(self.rng.uniform(0.15, 1.0))
                                if stressed else 0.0)
                    if aspect == "network":
                        t = float(next(net_slots))  # serialized slot
                    metrics = dict(TOOLS[btype](profile, self.rng, severity))
                    metrics.update(EXTRA_CONSTANTS[btype])
                    records.append(BenchmarkExecution(
                        benchmark_type=btype,
                        machine=node,
                        machine_type=mtype,
                        t=float(t),
                        metrics=metrics,
                        node_metrics=node_metrics(profile, self.rng,
                                                  severity, aspect),
                        stressed=stressed,
                    ))
        records.sort(key=lambda r: r.t)
        return records


def paper_acquisition(seed: int = 0) -> List[BenchmarkExecution]:
    """§IV-C setup: 3 benchmarking nodes (e2-medium), 6 types x 100 runs
    each, 20% stressed -> 1800 executions."""
    runner = SuiteRunner(seed=seed)
    machines = {f"node-{i}": "e2-medium" for i in range(1, 4)}
    return runner.run(machines, runs_per_type=100, stress_fraction=0.2)
