"""Columnar (struct-of-arrays) representation of benchmark executions.

``BenchmarkFrame`` is the canonical in-memory format for Perona's
acquisition and scoring path: per-metric float columns plus int-coded
benchmark type / machine / machine type, timestamps and stress flags.
The record-list format (:class:`BenchmarkExecution`) remains as the
interchange/compat type; ``from_records``/``to_records`` are lossless
converters between the two.

Metric columns are keyed by *(name, unit)* so that mixed-unit
recordings of one metric (e.g. latencies in ``ms`` and ``s``) round-trip
exactly; the preprocessing layer merges same-name columns after unit
unification. Node-metric columns (Prometheus-style gauges sampled
during a run) are keyed by name.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Sequence, Tuple, Union

import numpy as np

from repro.fingerprint.records import BenchmarkExecution


@dataclasses.dataclass
class BenchmarkFrame:
    # vocabularies (code -> name)
    benchmark_types: Tuple[str, ...]
    machines: Tuple[str, ...]
    machine_types: Tuple[str, ...]
    # column keys
    metric_names: Tuple[str, ...]  # (M,) per column
    metric_units: Tuple[str, ...]  # (M,) per column
    node_metric_names: Tuple[str, ...]  # (E,)
    # row arrays
    type_code: np.ndarray  # (N,) int32 into benchmark_types
    machine_code: np.ndarray  # (N,) int32 into machines
    machine_type_code: np.ndarray  # (N,) int32 into machine_types
    t: np.ndarray  # (N,) float64 seconds since experiment start
    stressed: np.ndarray  # (N,) bool ground-truth degradation marker
    # column data
    metrics: np.ndarray  # (N, M) float64 raw (un-unified) values
    metrics_present: np.ndarray  # (N, M) bool
    node_metrics: np.ndarray  # (N, E) float64
    node_metrics_present: np.ndarray  # (N, E) bool

    # ------------------------------------------------------------- basics
    def __len__(self) -> int:
        return int(self.t.shape[0])

    @property
    def n_metrics(self) -> int:
        return len(self.metric_names)

    def machine_names(self) -> List[str]:
        return [self.machines[c] for c in self.machine_code]

    def type_names(self) -> List[str]:
        return [self.benchmark_types[c] for c in self.type_code]

    def select(self, idx: np.ndarray) -> "BenchmarkFrame":
        """Row subset (column layout and vocabularies unchanged)."""
        idx = np.asarray(idx)
        return dataclasses.replace(
            self,
            type_code=self.type_code[idx],
            machine_code=self.machine_code[idx],
            machine_type_code=self.machine_type_code[idx],
            t=self.t[idx], stressed=self.stressed[idx],
            metrics=self.metrics[idx],
            metrics_present=self.metrics_present[idx],
            node_metrics=self.node_metrics[idx],
            node_metrics_present=self.node_metrics_present[idx])

    def sort_by_time(self) -> "BenchmarkFrame":
        """Stable sort of rows by timestamp."""
        return self.select(np.argsort(self.t, kind="stable"))

    # -------------------------------------------------------- converters
    @classmethod
    def from_records(cls, records: Sequence[BenchmarkExecution]
                     ) -> "BenchmarkFrame":
        n = len(records)
        btypes = sorted({r.benchmark_type for r in records})
        machines = sorted({r.machine for r in records})
        mtypes = sorted({r.machine_type for r in records})
        cols = sorted({(name, unit) for r in records
                       for name, (_, unit) in r.metrics.items()})
        ncols = sorted({k for r in records for k in r.node_metrics})
        bidx = {b: i for i, b in enumerate(btypes)}
        midx = {m: i for i, m in enumerate(machines)}
        tidx = {m: i for i, m in enumerate(mtypes)}
        cidx = {c: i for i, c in enumerate(cols)}
        nidx = {k: i for i, k in enumerate(ncols)}

        metrics = np.zeros((n, len(cols)), np.float64)
        present = np.zeros((n, len(cols)), bool)
        nmetrics = np.zeros((n, len(ncols)), np.float64)
        npresent = np.zeros((n, len(ncols)), bool)
        type_code = np.empty(n, np.int32)
        machine_code = np.empty(n, np.int32)
        machine_type_code = np.empty(n, np.int32)
        t = np.empty(n, np.float64)
        stressed = np.empty(n, bool)
        for j, r in enumerate(records):
            type_code[j] = bidx[r.benchmark_type]
            machine_code[j] = midx[r.machine]
            machine_type_code[j] = tidx[r.machine_type]
            t[j] = r.t
            stressed[j] = r.stressed
            for name, (v, unit) in r.metrics.items():
                i = cidx[(name, unit)]
                metrics[j, i] = v
                present[j, i] = True
            for k, v in r.node_metrics.items():
                i = nidx[k]
                nmetrics[j, i] = v
                npresent[j, i] = True
        return cls(
            benchmark_types=tuple(btypes), machines=tuple(machines),
            machine_types=tuple(mtypes),
            metric_names=tuple(c[0] for c in cols),
            metric_units=tuple(c[1] for c in cols),
            node_metric_names=tuple(ncols),
            type_code=type_code, machine_code=machine_code,
            machine_type_code=machine_type_code, t=t, stressed=stressed,
            metrics=metrics, metrics_present=present,
            node_metrics=nmetrics, node_metrics_present=npresent)

    def to_records(self) -> List[BenchmarkExecution]:
        out: List[BenchmarkExecution] = []
        cols = list(zip(self.metric_names, self.metric_units))
        for j in range(len(self)):
            metrics = {
                cols[i][0]: (float(self.metrics[j, i]), cols[i][1])
                for i in np.nonzero(self.metrics_present[j])[0]
            }
            node = {
                self.node_metric_names[i]: float(self.node_metrics[j, i])
                for i in np.nonzero(self.node_metrics_present[j])[0]
            }
            out.append(BenchmarkExecution(
                benchmark_type=self.benchmark_types[self.type_code[j]],
                machine=self.machines[self.machine_code[j]],
                machine_type=self.machine_types[
                    self.machine_type_code[j]],
                t=float(self.t[j]), metrics=metrics, node_metrics=node,
                stressed=bool(self.stressed[j])))
        return out


FrameOrRecords = Union[BenchmarkFrame, Sequence[BenchmarkExecution]]


def as_frame(data: FrameOrRecords) -> BenchmarkFrame:
    if isinstance(data, BenchmarkFrame):
        return data
    return BenchmarkFrame.from_records(data)


def _remap_vocab(vocabs: Iterable[Tuple[str, ...]]
                 ) -> Tuple[Tuple[str, ...], List[np.ndarray]]:
    """Union of vocabularies + per-input code remap LUTs."""
    union: List[str] = []
    seen: Dict[str, int] = {}
    luts = []
    for vocab in vocabs:
        lut = np.empty(max(len(vocab), 1), np.int32)
        for i, name in enumerate(vocab):
            if name not in seen:
                seen[name] = len(union)
                union.append(name)
            lut[i] = seen[name]
        luts.append(lut)
    return tuple(union), luts


def concat_frames(frames: Sequence[BenchmarkFrame]) -> BenchmarkFrame:
    """Row-wise concatenation with column/vocabulary union."""
    frames = [f for f in frames if f is not None]
    assert frames, "concat_frames needs at least one frame"
    if len(frames) == 1:
        return frames[0]

    btypes, blut = _remap_vocab(f.benchmark_types for f in frames)
    machines, mlut = _remap_vocab(f.machines for f in frames)
    mtypes, tlut = _remap_vocab(f.machine_types for f in frames)

    first = frames[0]
    if all(f.metric_names == first.metric_names
           and f.metric_units == first.metric_units
           and f.node_metric_names == first.node_metric_names
           for f in frames[1:]):
        # fast path (the fleet store's append cadence): identical
        # column layout -> plain row concatenation, only vocabulary
        # codes need remapping
        return BenchmarkFrame(
            benchmark_types=btypes, machines=machines,
            machine_types=mtypes,
            metric_names=first.metric_names,
            metric_units=first.metric_units,
            node_metric_names=first.node_metric_names,
            type_code=np.concatenate(
                [bl[f.type_code] for f, bl in zip(frames, blut)]),
            machine_code=np.concatenate(
                [ml[f.machine_code] for f, ml in zip(frames, mlut)]),
            machine_type_code=np.concatenate(
                [tl[f.machine_type_code]
                 for f, tl in zip(frames, tlut)]),
            t=np.concatenate([f.t for f in frames]),
            stressed=np.concatenate([f.stressed for f in frames]),
            metrics=np.concatenate([f.metrics for f in frames]),
            metrics_present=np.concatenate(
                [f.metrics_present for f in frames]),
            node_metrics=np.concatenate(
                [f.node_metrics for f in frames]),
            node_metrics_present=np.concatenate(
                [f.node_metrics_present for f in frames]))

    cols: List[Tuple[str, str]] = []
    cseen: Dict[Tuple[str, str], int] = {}
    ncols: List[str] = []
    nseen: Dict[str, int] = {}
    for f in frames:
        for key in zip(f.metric_names, f.metric_units):
            if key not in cseen:
                cseen[key] = len(cols)
                cols.append(key)
        for key in f.node_metric_names:
            if key not in nseen:
                nseen[key] = len(ncols)
                ncols.append(key)

    n = sum(len(f) for f in frames)
    metrics = np.zeros((n, len(cols)), np.float64)
    present = np.zeros((n, len(cols)), bool)
    nmetrics = np.zeros((n, len(ncols)), np.float64)
    npresent = np.zeros((n, len(ncols)), bool)
    type_code = np.empty(n, np.int32)
    machine_code = np.empty(n, np.int32)
    machine_type_code = np.empty(n, np.int32)
    t = np.empty(n, np.float64)
    stressed = np.empty(n, bool)

    off = 0
    for f, bl, ml, tl in zip(frames, blut, mlut, tlut):
        m = len(f)
        sl = slice(off, off + m)
        ci = np.asarray([cseen[key] for key in
                         zip(f.metric_names, f.metric_units)], np.int64)
        ni = np.asarray([nseen[key] for key in f.node_metric_names],
                        np.int64)
        if len(ci):
            metrics[sl, ci] = f.metrics
            present[sl, ci] = f.metrics_present
        if len(ni):
            nmetrics[sl, ni] = f.node_metrics
            npresent[sl, ni] = f.node_metrics_present
        type_code[sl] = bl[f.type_code]
        machine_code[sl] = ml[f.machine_code]
        machine_type_code[sl] = tl[f.machine_type_code]
        t[sl] = f.t
        stressed[sl] = f.stressed
        off += m
    return BenchmarkFrame(
        benchmark_types=btypes, machines=machines, machine_types=mtypes,
        metric_names=tuple(c[0] for c in cols),
        metric_units=tuple(c[1] for c in cols),
        node_metric_names=tuple(ncols),
        type_code=type_code, machine_code=machine_code,
        machine_type_code=machine_type_code, t=t, stressed=stressed,
        metrics=metrics, metrics_present=present,
        node_metrics=nmetrics, node_metrics_present=npresent)
