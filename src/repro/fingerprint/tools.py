"""Simulators for the six benchmark tools (fixed configuration templates).

Each simulator maps a MachineProfile (+ stress factors + rng) to the
metric dict one tool run would yield after Perona's regex parsing of the
results log. Metric names, unit mixtures (ms/us/s, KiB, MiB, bps/MBps)
and constant config echoes mirror the real tools so the preprocessing
pipeline has real work to do: ~150 unique raw metrics across the suite,
of which only a fraction carries signal (the rest are constants or pure
noise and must be discarded by the selection step).

The simulators are *batched*: ``severity`` is a ``(R,)`` array and every
metric comes back as a ``(R,)`` value array — one RNG draw per metric
column instead of one per run, which is what makes fleet-scale columnar
acquisition cheap. R=1 recovers single-run semantics.

``rng`` may be a ``np.random.Generator``, an int seed, or a fold-in
path tuple (``common.rng.as_generator``): passing e.g. ``(seed, round,
"fio", "e2-medium")`` gives draws that are a pure function of that
path, independent of any other group's draw order.
"""

from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from repro.common.rng import as_generator
from repro.fingerprint.machines import (MachineProfile, STRESS_FACTORS,
                                        stress_multiplier)

Metric = Tuple[np.ndarray, str]


def _noisy(rng, base, rel) -> np.ndarray:
    """base * lognormal noise; base must already be (R,)-shaped."""
    base = np.asarray(base, np.float64)
    return base * np.exp(rng.normal(0.0, rel, base.shape))


def _eff(profile: MachineProfile, severity: np.ndarray, aspect: str
         ) -> Dict[str, np.ndarray]:
    """severity (R,) in [0, 1]: 0 = nominal, 1 = full ChaosMesh stress."""
    r = severity.shape
    eff = {
        "cpu": np.full(r, profile.cpu),
        "memory": np.full(r, profile.memory),
        "disk_iops": np.full(r, profile.disk_iops),
        "disk_lat_us": np.full(r, profile.disk_lat_us),
        "net_gbps": np.full(r, profile.net_gbps),
        "net_lat_us": np.full(r, profile.net_lat_us),
    }
    for key, f in STRESS_FACTORS[aspect].items():
        eff[key] = eff[key] * stress_multiplier(f, severity)
    return eff


def _full(severity: np.ndarray, value: float) -> np.ndarray:
    return np.full(severity.shape, value, np.float64)


def sysbench_cpu(profile, rng, severity) -> Dict[str, Metric]:
    rng = as_generator(rng)
    e = _eff(profile, severity, "cpu")
    n = profile.noise
    c = lambda v: _full(severity, v)
    eps = _noisy(rng, e["cpu"], n)
    total_time = 10.0
    events = eps * total_time
    lat_avg = 1000.0 / eps  # ms per event per thread
    return {
        "cpu.events_per_second": (eps, "events/s"),
        "cpu.total_time": (_noisy(rng, c(total_time), 0.001), "s"),
        "cpu.total_events": (events, "events"),
        "cpu.latency_min": (_noisy(rng, lat_avg * 0.82, n), "ms"),
        "cpu.latency_avg": (_noisy(rng, lat_avg, n * 0.6), "ms"),
        "cpu.latency_max": (_noisy(rng, lat_avg * 3.1, n * 2.2), "ms"),
        "cpu.latency_p95": (_noisy(rng, lat_avg * 1.35, n), "ms"),
        "cpu.latency_sum": (_noisy(rng, lat_avg * events, n * 0.5), "ms"),
        "cpu.threads": (c(1.0), "count"),
        "cpu.prime_limit": (c(10000.0), "count"),
        "cpu.time_limit": (c(10.0), "s"),
        "cpu.events_per_thread": (events, "events"),
        "cpu.fairness_avg": (events, "events"),
        "cpu.fairness_stddev": (_noisy(rng, events * 0.001, 1.0), "events"),
        "cpu.user_pct": (_noisy(rng, c(96.0), 0.01), "%"),
        "cpu.sys_pct": (_noisy(rng, c(2.4), 0.3), "%"),
        "cpu.ctx_switches": (_noisy(rng, c(2200), 0.25), "count"),
        "cpu.migrations": (_noisy(rng, c(14), 0.5), "count"),
        "cpu.cache_miss_ratio": (_noisy(rng, c(0.021), 0.3), "ratio"),
        "cpu.ipc": (_noisy(rng, 1.15 + e["cpu"] / 9000.0, 0.05), "ratio"),
    }


def sysbench_memory(profile, rng, severity) -> Dict[str, Metric]:
    rng = as_generator(rng)
    e = _eff(profile, severity, "memory")
    n = profile.noise
    c = lambda v: _full(severity, v)
    thr = _noisy(rng, e["memory"], n)
    block_kib = 1.0
    ops = thr * 1024.0  # 1 KiB ops per second
    lat_avg = 1e6 / ops
    return {
        "mem.ops_per_second": (ops, "ops/s"),
        "mem.throughput": (thr, "MiB/s"),
        "mem.throughput_gb": (thr / 1024.0, "GiB/s"),
        "mem.transferred": (thr * 10.0, "MiB"),
        "mem.total_time": (_noisy(rng, c(10.0), 0.001), "s"),
        "mem.latency_min": (_noisy(rng, lat_avg * 0.7, n), "us"),
        "mem.latency_avg": (_noisy(rng, lat_avg, n * 0.6), "us"),
        "mem.latency_max": (_noisy(rng, lat_avg * 5.5, n * 2.5), "us"),
        "mem.latency_p95": (_noisy(rng, lat_avg * 1.3, n), "us"),
        "mem.latency_stddev": (_noisy(rng, lat_avg * 0.4, n * 2), "us"),
        "mem.block_size": (c(block_kib), "KiB"),
        "mem.total_size": (c(10240.0), "MiB"),
        "mem.ops_total": (ops * 10.0, "ops"),
        "mem.write_ratio": (c(1.0), "ratio"),
        "mem.numa_nodes": (c(1.0), "count"),
        "mem.page_faults": (_noisy(rng, c(180), 0.4), "count"),
        "mem.tlb_miss_ratio": (_noisy(rng, c(0.004), 0.4), "ratio"),
        "mem.scan_stride": (c(64.0), "bytes"),
    }


def fio(profile, rng, severity) -> Dict[str, Metric]:
    rng = as_generator(rng)
    e = _eff(profile, severity, "disk")
    n = profile.noise
    c = lambda v: _full(severity, v)
    out: Dict[str, Metric] = {}
    for rw, frac in (("read", 1.0), ("write", 0.82)):
        iops = _noisy(rng, e["disk_iops"] * frac, n * 1.3)
        bw_kib = iops * 4.0  # 4 KiB blocks
        lat = _noisy(rng, e["disk_lat_us"] / frac, n * 1.3)
        out.update({
            f"fio.{rw}.iops": (iops, "iops"),
            f"fio.{rw}.bw": (bw_kib, "KiB/s"),
            f"fio.{rw}.bw_mb": (bw_kib / 1024.0, "MiB/s"),
            f"fio.{rw}.lat_min": (_noisy(rng, lat * 0.45, n), "us"),
            f"fio.{rw}.lat_avg": (lat, "us"),
            f"fio.{rw}.lat_max": (_noisy(rng, lat * 40, n * 3), "us"),
            f"fio.{rw}.lat_stddev": (_noisy(rng, lat * 0.8, n * 2), "us"),
            f"fio.{rw}.clat_p50": (_noisy(rng, lat * 0.9, n), "us"),
            f"fio.{rw}.clat_p90": (_noisy(rng, lat * 1.6, n), "us"),
            f"fio.{rw}.clat_p95": (_noisy(rng, lat * 2.0, n), "us"),
            f"fio.{rw}.clat_p99": (_noisy(rng, lat * 4.2, n * 1.5), "us"),
            f"fio.{rw}.clat_p999": (_noisy(rng, lat * 11.0, n * 2), "us"),
            f"fio.{rw}.slat_avg": (_noisy(rng, c(2.4), 0.3), "us"),
            f"fio.{rw}.io_kbytes": (bw_kib * 30.0, "KiB"),
            f"fio.{rw}.runtime": (_noisy(rng, c(30000.0), 0.001), "ms"),
            f"fio.{rw}.total_ios": (iops * 30.0, "count"),
            f"fio.{rw}.drop_ios": (c(0.0), "count"),
            f"fio.{rw}.short_ios": (c(0.0), "count"),
        })
    out.update({
        "fio.jobs": (c(1.0), "count"),
        "fio.bs": (c(4.0), "KiB"),
        "fio.iodepth": (c(32.0), "count"),
        "fio.disk_util": (_noisy(rng, c(97.0), 0.01), "%"),
        "fio.cpu_usr": (_noisy(rng, c(3.2), 0.3), "%"),
        "fio.cpu_sys": (_noisy(rng, c(11.0), 0.3), "%"),
        "fio.ctx": (_noisy(rng, c(61000), 0.2), "count"),
        "fio.majf": (c(0.0), "count"),
        "fio.minf": (_noisy(rng, c(120), 0.5), "count"),
    })
    return out


def ioping(profile, rng, severity) -> Dict[str, Metric]:
    rng = as_generator(rng)
    e = _eff(profile, severity, "disk")
    n = profile.noise
    c = lambda v: _full(severity, v)
    lat = _noisy(rng, e["disk_lat_us"] * 0.8, n * 1.2)
    iops = 1e6 / lat
    return {
        "ioping.requests": (c(100.0), "count"),
        "ioping.total_time": (lat * 100.0 / 1000.0, "ms"),
        "ioping.lat_min": (_noisy(rng, lat * 0.55, n), "us"),
        "ioping.lat_avg": (lat, "us"),
        "ioping.lat_max": (_noisy(rng, lat * 7.0, n * 2.5), "us"),
        "ioping.lat_mdev": (_noisy(rng, lat * 0.6, n * 2), "us"),
        "ioping.iops": (iops, "iops"),
        "ioping.throughput": (iops * 4.0, "KiB/s"),
        "ioping.request_size": (c(4.0), "KiB"),
        "ioping.working_set": (c(256.0), "MiB"),
    }


def qperf(profile, rng, severity) -> Dict[str, Metric]:
    rng = as_generator(rng)
    e = _eff(profile, severity, "network")
    n = profile.noise
    c = lambda v: _full(severity, v)
    bw = _noisy(rng, e["net_gbps"] * 119.2, n)  # MB/s
    lat = _noisy(rng, e["net_lat_us"], n * 1.2)
    return {
        "qperf.tcp_bw": (bw, "MB/s"),
        "qperf.tcp_lat": (lat, "us"),
        "qperf.udp_send_bw": (_noisy(rng, bw * 0.93, n), "MB/s"),
        "qperf.udp_recv_bw": (_noisy(rng, bw * 0.88, n), "MB/s"),
        "qperf.udp_lat": (_noisy(rng, lat * 0.9, n), "us"),
        "qperf.msg_rate": (_noisy(rng, 1e3 / lat * 490, n), "K/s"),
        "qperf.msg_size": (c(64.0), "KiB"),
        "qperf.duration": (c(10.0), "s"),
        "qperf.cpu_util_loc": (_noisy(rng, c(30.0), 0.2), "%"),
        "qperf.cpu_util_rem": (_noisy(rng, c(28.0), 0.2), "%"),
    }


def iperf3(profile, rng, severity) -> Dict[str, Metric]:
    rng = as_generator(rng)
    e = _eff(profile, severity, "network")
    n = profile.noise
    c = lambda v: _full(severity, v)
    bps = _noisy(rng, e["net_gbps"] * 1e9 * 0.94, n)
    rtt = _noisy(rng, e["net_lat_us"] * 2.1, n)
    return {
        "iperf3.sent_bps": (bps, "bps"),
        "iperf3.recv_bps": (_noisy(rng, bps * 0.985, n * 0.3), "bps"),
        "iperf3.sent_bytes": (bps / 8 * 10, "bytes"),
        "iperf3.recv_bytes": (bps / 8 * 9.85, "bytes"),
        "iperf3.retransmits": (
            rng.poisson(3 + 37 * severity).astype(np.float64), "count"),
        "iperf3.jitter": (_noisy(rng, 0.04 + 20.0 / (bps / 1e9 + 1) / 1000,
                                 0.4), "ms"),
        "iperf3.lost_packets": (
            rng.poisson(1 + 24 * severity).astype(np.float64), "count"),
        "iperf3.lost_percent": (_noisy(rng, 0.01 + 0.89 * severity,
                                       0.6), "%"),
        "iperf3.cpu_host": (_noisy(rng, c(24.0), 0.25), "%"),
        "iperf3.cpu_remote": (_noisy(rng, c(21.0), 0.25), "%"),
        "iperf3.duration": (c(10.0), "s"),
        "iperf3.streams": (c(1.0), "count"),
        "iperf3.tcp_mss": (c(1448.0), "bytes"),
        "iperf3.snd_cwnd": (_noisy(rng, bps / 8 * rtt / 1e6 / 1024, 0.3),
                            "KiB"),
        "iperf3.rtt": (rtt / 1000.0, "ms"),
        "iperf3.rtt_var": (_noisy(rng, rtt * 0.2 / 1000.0, 0.5), "ms"),
    }


TOOLS = {
    "sysbench-cpu": sysbench_cpu,
    "sysbench-memory": sysbench_memory,
    "fio": fio,
    "ioping": ioping,
    "qperf": qperf,
    "iperf3": iperf3,
}


def node_metrics(profile, rng, severity, aspect) -> Dict[str, np.ndarray]:
    """Prometheus-style low-level metrics sampled during a run (the GNN
    edge attributes and Arrow's augmentation features). Batched like the
    tool simulators: (R,) severity in, (R,) gauge columns out."""
    rng = as_generator(rng)
    base = {
        "node.cpu_util": 0.35, "node.mem_util": 0.42,
        "node.disk_io_util": 0.18, "node.net_util": 0.12,
        "node.load1": 0.8, "node.psi_cpu": 0.03, "node.psi_io": 0.02,
        "node.ctx_rate": 3200.0,
    }
    bump = {
        "cpu": {"node.cpu_util": 0.92, "node.load1": 3.4,
                "node.psi_cpu": 0.55},
        "memory": {"node.mem_util": 0.93, "node.psi_cpu": 0.2},
        "disk": {"node.disk_io_util": 0.95, "node.psi_io": 0.6},
        "network": {"node.net_util": 0.9},
    }
    out = {}
    for k, v in base.items():
        col = np.full(severity.shape, v, np.float64)
        target = bump[aspect].get(k)
        if target is not None:
            col = col + severity * (target - col)
        out[k] = col * np.exp(rng.normal(0, 0.15, severity.shape))
    return out


# Constant config echoes parsed from tool logs (versions, template knobs).
# They carry no signal and exist to exercise Perona's selection step —
# the real suite yields ~153 raw metrics of which only ~1/3 survive.
EXTRA_CONSTANTS: Dict[str, Dict[str, Tuple[float, str]]] = {
    "sysbench-cpu": {
        "cpu.version": (1.020, "count"), "cpu.luajit": (2.1, "count"),
        "cpu.max_prime_digits": (5.0, "count"),
        "cpu.rate_limit": (0.0, "1/s"), "cpu.warmup": (2.0, "s"),
        "cpu.histogram_buckets": (1024.0, "count"),
    },
    "sysbench-memory": {
        "mem.version": (1.020, "count"), "mem.access_mode": (1.0, "count"),
        "mem.hugepages": (0.0, "count"), "mem.warmup": (2.0, "s"),
        "mem.rate_limit": (0.0, "1/s"),
        "mem.histogram_buckets": (1024.0, "count"),
    },
    "fio": {
        "fio.version": (3.28, "count"), "fio.direct": (1.0, "count"),
        "fio.ramp_time": (5.0, "s"), "fio.size": (1024.0, "MiB"),
        "fio.ioengine_id": (3.0, "count"), "fio.verify": (0.0, "count"),
        "fio.runtime_limit": (30.0, "s"), "fio.thinktime": (0.0, "us"),
        "fio.rwmixread": (55.0, "%"), "fio.fsync": (0.0, "count"),
    },
    "ioping": {
        "ioping.version": (1.2, "count"), "ioping.interval": (0.2, "s"),
        "ioping.direct": (1.0, "count"), "ioping.cached": (0.0, "count"),
        "ioping.warmup_requests": (10.0, "count"),
        "ioping.deadline": (60.0, "s"),
    },
    "qperf": {
        "qperf.version": (0.44, "count"), "qperf.port": (19765.0, "count"),
        "qperf.timeout": (120.0, "s"), "qperf.loc_cpus": (2.0, "count"),
        "qperf.rem_cpus": (2.0, "count"),
    },
    "iperf3": {
        "iperf3.version": (3.9, "count"), "iperf3.port": (5201.0, "count"),
        "iperf3.blksize": (131072.0, "bytes"),
        "iperf3.omit": (2.0, "s"), "iperf3.interval": (1.0, "s"),
        "iperf3.reverse": (0.0, "count"), "iperf3.parallel": (1.0, "count"),
    },
}
