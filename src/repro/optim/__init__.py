"""Optimizers and distributed-optimization tricks (no optax)."""

from repro.optim.adamw import AdamW, OptState
from repro.optim.schedule import cosine_schedule, linear_warmup
from repro.optim.compress import compress_gradients, decompress_gradients

__all__ = [
    "AdamW",
    "OptState",
    "cosine_schedule",
    "linear_warmup",
    "compress_gradients",
    "decompress_gradients",
]
