"""Int8 gradient compression with error feedback.

For DP all-reduce at 1000+ node scale, gradients are quantized to int8
with a per-tensor scale before the cross-pod reduction; the quantization
residual is carried in an error-feedback buffer so the compression is
unbiased over time (EF-SGD). Used by the train step when
``grad_compression=True``: the quantize -> psum -> dequantize pattern
lets XLA run the collective on 1/4 of the bytes on the slow (DCN/pod)
axis.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def _quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_gradients(grads, error_buf=None):
    """Returns ((q_tree, scale_tree), new_error_buf)."""
    if error_buf is None:
        error_buf = jax.tree_util.tree_map(
            lambda g: jnp.zeros_like(g, jnp.float32), grads)
    corrected = jax.tree_util.tree_map(
        lambda g, e: g.astype(jnp.float32) + e, grads, error_buf)
    qs = jax.tree_util.tree_map(_quantize, corrected)
    q_tree = jax.tree_util.tree_map(lambda t: t[0], qs,
                                    is_leaf=lambda t: isinstance(t, tuple))
    s_tree = jax.tree_util.tree_map(lambda t: t[1], qs,
                                    is_leaf=lambda t: isinstance(t, tuple))
    deq = jax.tree_util.tree_map(
        lambda q, s: q.astype(jnp.float32) * s, q_tree, s_tree)
    new_err = jax.tree_util.tree_map(
        lambda c, d: c - d, corrected, deq)
    return (q_tree, s_tree), new_err


def decompress_gradients(q_tree, s_tree):
    return jax.tree_util.tree_map(
        lambda q, s: q.astype(jnp.float32) * s, q_tree, s_tree)
