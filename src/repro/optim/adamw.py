"""AdamW with global-norm clipping and optional ZeRO-1 state sharding.

State is a pytree mirroring params: {"m": tree, "v": tree, "step": scalar}.
``zero1_specs`` derives PartitionSpecs for m/v that additionally shard the
first replicated axis over "data" (ZeRO-1: optimizer state partitioned
across the data-parallel group; XLA inserts the corresponding
reduce-scatter / all-gather pair around the update).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.common.tree import tree_global_norm


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class OptState:
    m: Any
    v: Any
    step: jnp.ndarray


@dataclasses.dataclass(frozen=True)
class AdamW:
    """``lr`` and ``weight_decay`` may be python floats, schedules, or
    *traced* jnp scalars — the vmapped HPO engine builds one AdamW per
    trial inside a compiled program with per-trial values."""

    lr: Callable[[jnp.ndarray], jnp.ndarray] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: Any = jnp.float32

    def init(self, params) -> OptState:
        zeros = lambda t: jax.tree_util.tree_map(
            lambda x: jnp.zeros(x.shape, self.state_dtype), t)
        return OptState(m=zeros(params), v=zeros(params),
                        step=jnp.zeros((), jnp.int32))

    def abstract_state(self, abstract_params) -> OptState:
        sd = lambda t: jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, self.state_dtype), t)
        return OptState(m=sd(abstract_params), v=sd(abstract_params),
                        step=jax.ShapeDtypeStruct((), jnp.int32))

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else self.lr

    def update(self, grads, state: OptState, params):
        """Returns (new_params, new_state, metrics)."""
        gnorm = tree_global_norm(grads)
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))
        step = state.step + 1
        lr = self._lr(step)
        b1c = 1.0 - self.b1 ** step.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** step.astype(jnp.float32)

        # static zero skips the decay op entirely; traced values always
        # apply (a tracer has no truth value at trace time)
        wd = self.weight_decay
        apply_wd = not (isinstance(wd, (int, float)) and wd == 0)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * scale
            m2 = self.b1 * m + (1 - self.b1) * g
            v2 = self.b2 * v + (1 - self.b2) * g * g
            mhat = m2 / b1c
            vhat = v2 / b2c
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if apply_wd and p.ndim >= 2:  # no decay on norms/bias
                delta = delta + wd * p.astype(jnp.float32)
            p2 = p.astype(jnp.float32) - lr * delta
            return (p2.astype(p.dtype), m2.astype(self.state_dtype),
                    v2.astype(self.state_dtype))

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.m)
        flat_v = treedef.flatten_up_to(state.v)
        out = [upd(g, m, v, p)
               for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, OptState(new_m, new_v, step), {"grad_norm": gnorm,
                                                     "lr": lr}


def zero1_specs(param_specs, abstract_params, data_axis: str = "data",
                data_size: int = 0):
    """ZeRO-1: shard optimizer moments over the data axis.

    For each parameter, find the first dimension that is unsharded in its
    PartitionSpec and divisible by the data-axis size, and shard it over
    ``data_axis``. Falls back to the parameter's own spec.
    """

    def one(spec, aps):
        if not isinstance(spec, P):
            spec = P()
        parts = list(spec) + [None] * (len(aps.shape) - len(spec))
        for i, (axis_part, dim) in enumerate(zip(parts, aps.shape)):
            if axis_part is None and data_size and dim % data_size == 0:
                parts[i] = data_axis
                return P(*parts)
        return P(*parts) if parts else P()

    return jax.tree_util.tree_map(
        one, param_specs, abstract_params,
        is_leaf=lambda x: isinstance(x, P))


def opt_state_specs(param_specs, abstract_params, *, zero1: bool = True,
                    data_axis: str = "data", data_size: int = 0) -> OptState:
    mv = (zero1_specs(param_specs, abstract_params, data_axis, data_size)
          if zero1 else param_specs)
    return OptState(m=mv, v=mv, step=P())
