"""Tarema-style node grouping (Bader et al., BigData'21) + §IV-E check.

Tarema groups heterogeneous cluster nodes by microbenchmark similarity
and allocates tasks to groups by resource usage. The paper's experiment
mocks Tarema's group build with Perona fingerprint scores and verifies
the *same node groups* emerge (hence identical workflow makespans).
"""

from __future__ import annotations

from typing import Dict, List, Sequence

import numpy as np

from repro.fingerprint.machines import MACHINE_PROFILES
from repro.tuning.lotaru import microbenchmark_vector, perona_vector


def group_nodes(vectors: Dict[str, np.ndarray], tol: float = 0.2
                ) -> List[List[str]]:
    """Greedy agglomeration on min-max-normalized capability vectors:
    nodes within ``tol`` on every (normalized) aspect share a group.
    Normalization makes raw microbenchmark values and Perona scores
    directly comparable grouping inputs (scale-free)."""
    nodes = sorted(vectors)
    arr = np.stack([vectors[n] for n in nodes]).astype(float)
    lo, hi = arr.min(0), arr.max(0)
    rng = np.where(hi > lo, hi - lo, 1.0)
    norm = {n: (vectors[n] - lo) / rng for n in nodes}
    groups: List[List[str]] = []
    for node in nodes:
        placed = False
        for g in groups:
            if np.all(np.abs(norm[node] - norm[g[0]]) <= tol):
                g.append(node)
                placed = True
                break
        if not placed:
            groups.append([node])
    return [sorted(g) for g in groups]


def groups_from_microbenchmarks(machines: Dict[str, str]) -> List[List[str]]:
    return group_nodes({node: microbenchmark_vector(mt)
                        for node, mt in machines.items()})


def groups_from_perona(machines: Dict[str, str],
                       machine_scores: Dict[str, Dict[str, float]]
                       ) -> List[List[str]]:
    return group_nodes({node: perona_vector(machine_scores, mt)
                        for node, mt in machines.items()})


def same_grouping(a: List[List[str]], b: List[List[str]]) -> bool:
    canon = lambda g: sorted(tuple(x) for x in g)
    return canon(a) == canon(b)
