"""Perona's tuner integration (paper §IV-D).

The acquisition values of CherryPick/Arrow are weighted by a sum of
products: for each resource aspect, (configuration utilization factor) x
(representation-based score of the machine type's fingerprint). Machine
fingerprints come from benchmarking the candidate machine types once
(10 runs/type in the paper) and scoring codes with the p-norm.
"""

from __future__ import annotations

from typing import Dict, Sequence

import numpy as np

from repro.core.ranking import machine_score_vector
from repro.tuning.scout import CloudConfig, ScoutDataset


def normalized_machine_scores(machine_scores: Dict[str, Dict[str, float]]
                              ) -> Dict[str, np.ndarray]:
    """Per-aspect min-max normalization (+0.1 floor) of machine score
    vectors across types — the weighter's precomputation, shared with
    ``optimizer.scenarios`` so batched lanes use bit-identical
    weighting inputs."""
    mats = {m: machine_score_vector(machine_scores, m)
            for m in machine_scores}
    arr = np.stack(list(mats.values()))
    lo, hi = arr.min(0), arr.max(0)
    rng = np.where(hi > lo, hi - lo, 1.0)
    return {m: (v - lo) / rng + 0.1 for m, v in mats.items()}


class PeronaAcquisitionWeighter:
    """Paper §IV-D integration: acquisition values are weighted by a sum
    of products over resource aspects — (the target workload's observed
    utilization of the aspect, from the profiling runs so far) x (the
    machine type's representation-based score for that aspect). A
    cpu-bound workload therefore steers the search toward machine types
    whose *fingerprint* says they are strong on cpu, before ever running
    on them."""

    def __init__(self, dataset: ScoutDataset,
                 machine_scores: Dict[str, Dict[str, float]],
                 strength: float = 0.3, per_dollar: bool = True):
        """strength: interpolation toward the weighted acquisition (the
        weighting is a prior, not a replacement for EI); per_dollar:
        divide scores by the on-demand price — the objective is the
        *cheapest* valid configuration, so the fingerprint prior should
        encode cost-effectiveness, not raw capability."""
        from repro.tuning.scout import PRICES

        self.ds = dataset
        self.scores = machine_scores
        self.strength = strength
        self.per_dollar = per_dollar
        self.prices = PRICES
        # normalize scores across machine types per aspect
        self.norm_scores = normalized_machine_scores(machine_scores)

    def __call__(self, configs: Sequence[CloudConfig],
                 acquisition: np.ndarray, workload: str = None,
                 evaluated: Sequence[CloudConfig] = (),
                 any_valid: bool = True) -> np.ndarray:
        """Two-phase prior (the paper's 'less prone to timeouts ... and
        eventually a more cost-effective configuration'): while NO valid
        configuration is known, weight by raw fingerprint capability for
        the workload's bottleneck resources (find something that meets
        the runtime constraint); once one exists, weight by capability
        per dollar (hunt for the cheapest valid one)."""
        if workload is not None and evaluated:
            util = np.mean([self.ds.low_level_metrics(workload, c)
                            for c in evaluated], axis=0)
        else:
            util = np.ones(4)
        util = util / max(util.sum(), 1e-9)
        weights = []
        for c in configs:
            s = float(np.sum(util * self.norm_scores.get(c.vm_type,
                                                         np.ones(4))))
            if self.per_dollar and any_valid:
                s = s / self.prices[c.vm_type]
            weights.append(s)
        weights = np.asarray(weights)
        weights = weights / max(weights.mean(), 1e-9)
        return acquisition * (1.0 + self.strength * (weights - 1.0))


# canonical raw metric per aspect, for score->capability calibration
_PROXY_METRIC = {
    "cpu": "cpu.events_per_second",
    "memory": "mem.throughput",
    "disk": "fio.read.iops",
    "network": "qperf.tcp_bw",
}


def fingerprint_machine_scores(machine_types, *, seed: int = 0,
                               runs_per_type: int = 10, epochs: int = 60,
                               return_calibration: bool = False):
    """Benchmark each machine type, train Perona on the executions, and
    return {machine_type: {aspect: score}} (the §IV-D '540 executions'
    procedure, one simulated node per type).

    With ``return_calibration=True`` also returns capability proxies
    {machine_type: {aspect: raw value}} from Perona's own benchmark
    records — used to affine-calibrate scores where a downstream method
    (Lotaru) needs capability *ratios*, the paper's "adjusted the
    estimation process" step.
    """
    from repro.core.graph_data import build_graphs, chronological_split
    from repro.core.model import PeronaConfig, PeronaModel
    from repro.core.preprocess import Preprocessor
    from repro.core.ranking import aspect_scores
    from repro.core.trainer import batch_to_jnp, train_perona
    from repro.fingerprint.runner import SuiteRunner

    runner = SuiteRunner(seed=seed)
    machines = {f"{m}-0": m for m in machine_types}
    records = runner.run(machines, runs_per_type=runs_per_type)
    train_r, val_r, _ = chronological_split(records, (0.7, 0.3, 0.0))
    pre = Preprocessor().fit(train_r)
    tb = build_graphs(train_r, pre)
    vb = build_graphs(val_r, pre)
    cfg = PeronaConfig(feature_dim=pre.feature_dim,
                       edge_dim=tb.edge.shape[-1])
    model = PeronaModel(cfg)
    res = train_perona(model, tb, vb, epochs=epochs, seed=seed)
    full = build_graphs(records, pre)
    out = model.forward(res.params, batch_to_jnp(full), train=False)
    codes = np.asarray(out["codes"])
    types = [r.benchmark_type for r in records]
    mtypes = [r.machine_type for r in records]
    scores = aspect_scores(codes, types, mtypes)
    if not return_calibration:
        return scores
    proxies: Dict[str, Dict[str, list]] = {}
    for r in records:
        for aspect, metric in _PROXY_METRIC.items():
            if metric in r.metrics:
                proxies.setdefault(r.machine_type, {}).setdefault(
                    aspect, []).append(float(r.metrics[metric][0]))
    proxy_means = {m: {a: float(np.mean(v)) for a, v in per.items()}
                   for m, per in proxies.items()}
    return scores, proxy_means


def calibrate_scores(scores: Dict[str, Dict[str, float]],
                     proxies: Dict[str, Dict[str, float]]
                     ) -> Dict[str, Dict[str, float]]:
    """Per aspect, least-squares affine map score -> capability proxy
    across machine types. The fit *dampens* score-ranking errors (a
    rank-matching variant was tried and amplified them instead — see
    EXPERIMENTS.md §Reproduction notes), which is what keeps Perona
    slightly behind raw microbenchmarks in the paper's Table III."""
    out: Dict[str, Dict[str, float]] = {m: {} for m in scores}
    aspects = sorted({a for per in scores.values() for a in per})
    for a in aspects:
        ms = [m for m in scores if a in scores[m] and a in proxies.get(m, {})]
        s = np.asarray([scores[m][a] for m in ms])
        p = np.asarray([proxies[m][a] for m in ms])
        if len(ms) >= 2 and np.std(s) > 1e-9:
            A = np.stack([s, np.ones_like(s)], axis=1)
            coef, *_ = np.linalg.lstsq(A, p, rcond=None)
            fit = A @ coef
        else:
            fit = p
        for m, v in zip(ms, fit):
            out[m][a] = float(max(v, 1e-9))
    return out
