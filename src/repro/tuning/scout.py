"""Scout-like dataset simulator (paper §IV-D evaluation substrate).

The real scout dataset (github.com/oxhead/scout) holds 18 big-data
workloads x 69 AWS configurations (scaleout x VM type: m4/c4/r4 in
large/xlarge/2xlarge), one run each = 1242 executions. It is not
available offline, so we simulate it: every workload has latent resource
demands (cpu/mem/disk/network intensity + parallel fraction) and every
configuration has capabilities from the machine profiles; runtime
follows an Amdahl-style model with contention noise. Costs use
us-east-2 on-demand prices.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

import numpy as np

from repro.fingerprint.machines import MACHINE_PROFILES

# USD/hour, AWS on-demand us-east-2 (Ohio)
PRICES = {
    "m4.large": 0.10, "m4.xlarge": 0.20, "m4.2xlarge": 0.40,
    "c4.large": 0.10, "c4.xlarge": 0.199, "c4.2xlarge": 0.398,
    "r4.large": 0.133, "r4.xlarge": 0.266, "r4.2xlarge": 0.532,
}
VM_TYPES = tuple(PRICES)
SCALEOUTS_BY_SIZE = {"large": (8, 10, 12), "xlarge": (4, 6, 8),
                     "2xlarge": (2, 3, 4)}


@dataclasses.dataclass(frozen=True)
class CloudConfig:
    vm_type: str
    count: int

    @property
    def key(self) -> Tuple[str, int]:
        return (self.vm_type, self.count)


def all_configs() -> List[CloudConfig]:
    configs = []
    for vm in VM_TYPES:
        size = vm.split(".")[1]
        for c in SCALEOUTS_BY_SIZE[size]:
            configs.append(CloudConfig(vm, c))
    # 9 VM types x 3 scaleouts = 27; scout uses denser scaleout grids for
    # small sizes — extend to 69 configs (23 per family)
    extra = {"large": (4, 6, 14, 16, 18, 20), "xlarge": (2, 10, 12, 14),
             "2xlarge": (5, 6, 8, 10)}
    seen = {c.key for c in configs}
    for vm in VM_TYPES:
        size = vm.split(".")[1]
        for c in extra[size]:
            cc = CloudConfig(vm, c)
            if cc.key not in seen:
                configs.append(cc)
                seen.add(cc.key)
    configs.sort(key=lambda c: (c.vm_type, c.count))
    return configs


WORKLOAD_NAMES = [
    "spark-pagerank", "spark-kmeans", "spark-sql-join", "spark-sort",
    "spark-wordcount", "spark-lr", "spark-als", "spark-bayes",
    "spark-terasort", "hadoop-grep", "hadoop-wordcount", "hadoop-sort",
    "spark-svm", "spark-pca", "spark-fpgrowth", "spark-graphx-cc",
    "spark-streaming-agg", "spark-decision-tree",
]


@dataclasses.dataclass
class ScoutDataset:
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.configs = all_configs()
        self.workloads = {}
        for name in WORKLOAD_NAMES:
            self.workloads[name] = {
                "cpu_work": float(rng.uniform(2e6, 3e7)),
                "mem_need_gb": float(rng.uniform(2, 28)),
                "disk_work": float(rng.uniform(1e5, 4e6)),
                "net_work": float(rng.uniform(1e2, 4e3)),
                "parallel_frac": float(rng.uniform(0.75, 0.98)),
            }
        self._noise_rng = np.random.default_rng(self.seed + 1)
        self._cache: Dict = {}

    # ------------------------------------------------------------- runtime
    def runtime_s(self, workload: str, config: CloudConfig) -> float:
        key = (workload, config.key)
        if key in self._cache:
            return self._cache[key][0]
        w = self.workloads[workload]
        prof = MACHINE_PROFILES[config.vm_type]
        size = config.vm_type.split(".")[1]
        cores = {"large": 2, "xlarge": 4, "2xlarge": 8}[size]
        mem_gb = {"large": 8, "xlarge": 16, "2xlarge": 32}[size]
        if "c4" in config.vm_type:
            mem_gb //= 2
        if "r4" in config.vm_type:
            mem_gb *= 2  # memory-optimized

        n_cores = cores * config.count
        pf = w["parallel_frac"]
        cpu_t = w["cpu_work"] / prof.cpu * (
            (1 - pf) + pf / n_cores)
        disk_t = w["disk_work"] / prof.disk_iops * 100.0 / config.count
        net_t = (w["net_work"] * (config.count - 1)
                 / max(prof.net_gbps * 100.0, 1.0))
        mem_penalty = 1.0
        if w["mem_need_gb"] > mem_gb * 0.85:  # spilling
            mem_penalty = 1.0 + 2.2 * (
                w["mem_need_gb"] / (mem_gb * 0.85) - 1.0)
        base = (cpu_t + disk_t + net_t) * mem_penalty
        noise = math.exp(self._noise_rng.normal(0, 0.06))
        runtime = float(base * noise)
        self._cache[key] = (runtime,)
        return runtime

    def cost_usd(self, workload: str, config: CloudConfig) -> float:
        rt = self.runtime_s(workload, config)
        return rt / 3600.0 * PRICES[config.vm_type] * config.count

    def low_level_metrics(self, workload: str, config: CloudConfig
                          ) -> np.ndarray:
        """Arrow's augmentation: utilization-style metrics of the run."""
        w = self.workloads[workload]
        prof = MACHINE_PROFILES[config.vm_type]
        size = config.vm_type.split(".")[1]
        cores = {"large": 2, "xlarge": 4, "2xlarge": 8}[size]
        cpu_util = min(1.0, w["cpu_work"] / prof.cpu
                       / max(self.runtime_s(workload, config), 1e-6)
                       / (cores * config.count))
        mem_gb = {"large": 8, "xlarge": 16, "2xlarge": 32}[size]
        mem_util = min(1.5, w["mem_need_gb"] / mem_gb)
        disk_util = min(1.0, w["disk_work"] / prof.disk_iops
                        / max(self.runtime_s(workload, config), 1e-6))
        net_util = min(1.0, w["net_work"] * (config.count - 1)
                       / max(prof.net_gbps * 100.0, 1.0)
                       / max(self.runtime_s(workload, config), 1e-6))
        return np.asarray([cpu_util, mem_util, disk_util, net_util])

    def workload_arrays(self, workload: str):
        """Canonical-order materialization of one workload's tables:
        (runtimes, costs, low-level metrics) over ``self.configs``.
        The first call per (workload, config) pins the contention-noise
        draw (results are cached), so sequential searches and the
        batched replay engine see identical values as long as they
        share one dataset instance and this runs first — which
        ``optimizer.scenarios.build_scenarios`` guarantees by computing
        runtime limits through it."""
        rts = np.asarray([self.runtime_s(workload, c)
                          for c in self.configs])
        costs = np.asarray([self.cost_usd(workload, c)
                            for c in self.configs])
        lows = np.stack([self.low_level_metrics(workload, c)
                         for c in self.configs])
        return rts, costs, lows

    # --------------------------------------------------------------- views
    def config_features(self, config: CloudConfig) -> np.ndarray:
        prof = MACHINE_PROFILES[config.vm_type]
        return np.asarray([
            config.count,
            math.log(prof.cpu), math.log(prof.memory),
            math.log(prof.disk_iops), math.log(prof.net_gbps * 1000),
            PRICES[config.vm_type],
        ])

    def utilization_factors(self, config: CloudConfig) -> np.ndarray:
        """Per-aspect utilization headroom factor of a configuration —
        one term of Perona's acquisition weighting (paper §IV-D)."""
        prof = MACHINE_PROFILES[config.vm_type]
        caps = np.asarray([prof.cpu, prof.memory, prof.disk_iops,
                           prof.net_gbps * 1000])
        ref = np.asarray([5000.0, 50000.0, 8000.0, 10000.0])
        return np.clip(caps / ref, 0.05, 1.0)
