"""Scout-like dataset simulator (paper §IV-D evaluation substrate).

The real scout dataset (github.com/oxhead/scout) holds 18 big-data
workloads x 69 AWS configurations (scaleout x VM type: m4/c4/r4 in
large/xlarge/2xlarge), one run each = 1242 executions. It is not
available offline, so we simulate it: every workload has latent resource
demands (cpu/mem/disk/network intensity + parallel fraction) and every
configuration has capabilities from the machine profiles; runtime
follows an Amdahl-style model with contention noise. Costs use
us-east-2 on-demand prices.

Every stochastic quantity is a *counter-based* draw (``common.rng``):
workload demand vectors are a pure function of ``fold_in(seed,
workload_id, param_id)`` and the contention noise of a (workload,
configuration) cell of ``fold_in(seed, workload_id, config_uid)``.
There is no sequential stream state, so results are independent of
call order and of which consumer (sequential tuner, batched lane
tables, the fused device replay program) asks first. The full grid is
materialized vectorized at construction; off-grid configurations fall
back to the same per-cell fold-in draw.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Tuple

import numpy as np

from repro.common.rng import (STREAM_CONTENTION, STREAM_WORKLOAD_PARAMS,
                              bounded_uniform_grid, lognormal_noise_grid,
                              stream_key)
from repro.fingerprint.machines import MACHINE_PROFILES

# USD/hour, AWS on-demand us-east-2 (Ohio)
PRICES = {
    "m4.large": 0.10, "m4.xlarge": 0.20, "m4.2xlarge": 0.40,
    "c4.large": 0.10, "c4.xlarge": 0.199, "c4.2xlarge": 0.398,
    "r4.large": 0.133, "r4.xlarge": 0.266, "r4.2xlarge": 0.532,
}
VM_TYPES = tuple(PRICES)
SCALEOUTS_BY_SIZE = {"large": (8, 10, 12), "xlarge": (4, 6, 8),
                     "2xlarge": (2, 3, 4)}

#: contention-noise scale: runtime = base * exp(scale * N(0, 1))
CONTENTION_SCALE = 0.06

#: clipping caps of the four low-level utilization metrics
#: (cpu, memory, disk, network) — shared with the device expansion
LOW_CAPS = (1.0, 1.5, 1.0, 1.0)

#: workload latent-demand parameters: (name, low, high) uniform bounds
PARAM_BOUNDS = (
    ("cpu_work", 2e6, 3e7),
    ("mem_need_gb", 2.0, 28.0),
    ("disk_work", 1e5, 4e6),
    ("net_work", 1e2, 4e3),
    ("parallel_frac", 0.75, 0.98),
)

_CORES = {"large": 2, "xlarge": 4, "2xlarge": 8}
_MEM_GB = {"large": 8, "xlarge": 16, "2xlarge": 32}


@dataclasses.dataclass(frozen=True)
class CloudConfig:
    vm_type: str
    count: int

    @property
    def key(self) -> Tuple[str, int]:
        return (self.vm_type, self.count)


def config_uid(config: CloudConfig) -> int:
    """A stable integer uid for a configuration — the fold-in counter
    of its contention-noise draws. ``vm_type_index * 256 + count``
    stays collision-free for any realistic scaleout and is unchanged
    by extending the scaleout grid (new configs get new uids, existing
    draws keep theirs)."""
    return VM_TYPES.index(config.vm_type) * 256 + config.count


def _mem_gb(vm_type: str) -> int:
    size = vm_type.split(".")[1]
    mem = _MEM_GB[size]
    if "c4" in vm_type:
        mem //= 2
    if "r4" in vm_type:
        mem *= 2  # memory-optimized
    return mem


def all_configs() -> List[CloudConfig]:
    configs = []
    for vm in VM_TYPES:
        size = vm.split(".")[1]
        for c in SCALEOUTS_BY_SIZE[size]:
            configs.append(CloudConfig(vm, c))
    # 9 VM types x 3 scaleouts = 27; scout uses denser scaleout grids for
    # small sizes — extend to 69 configs (23 per family)
    extra = {"large": (4, 6, 14, 16, 18, 20), "xlarge": (2, 10, 12, 14),
             "2xlarge": (5, 6, 8, 10)}
    seen = {c.key for c in configs}
    for vm in VM_TYPES:
        size = vm.split(".")[1]
        for c in extra[size]:
            cc = CloudConfig(vm, c)
            if cc.key not in seen:
                configs.append(cc)
                seen.add(cc.key)
    configs.sort(key=lambda c: (c.vm_type, c.count))
    return configs


WORKLOAD_NAMES = [
    "spark-pagerank", "spark-kmeans", "spark-sql-join", "spark-sort",
    "spark-wordcount", "spark-lr", "spark-als", "spark-bayes",
    "spark-terasort", "hadoop-grep", "hadoop-wordcount", "hadoop-sort",
    "spark-svm", "spark-pca", "spark-fpgrowth", "spark-graphx-cc",
    "spark-streaming-agg", "spark-decision-tree",
]


@dataclasses.dataclass(frozen=True)
class ScoutGrid:
    """The fully materialized (workload x config) tables of one
    dataset, plus the deterministic inputs the device replay program
    needs to re-derive the stochastic parts in-program.

    ``runtime == base_runtime * noise`` where ``noise`` is drawn from
    the counter-based contention stream — the replay program receives
    ``base_runtime`` + ``noise_key`` and reproduces ``runtime`` (and
    everything downstream of it) bit-identically on device."""

    base_runtime: np.ndarray  # (W, C) noise-free runtime component
    runtime: np.ndarray  # (W, C) runtimes (seconds)
    cost: np.ndarray  # (W, C) execution cost (USD)
    low_num: np.ndarray  # (W, C, 4) utilization-metric numerators
    lows: np.ndarray  # (W, C, 4) low-level utilization metrics
    x_base: np.ndarray  # (C, 6) config feature vectors
    price: np.ndarray  # (C,) USD/h of the config's machine type
    count: np.ndarray  # (C,) node counts
    config_uid: np.ndarray  # (C,) fold-in uids of the grid configs
    noise_key: np.ndarray  # (2,) uint32 contention stream key


@dataclasses.dataclass
class ScoutDataset:
    seed: int = 0

    def __post_init__(self):
        self.configs = all_configs()
        params_key = stream_key(self.seed, STREAM_WORKLOAD_PARAMS)
        noise_key = stream_key(self.seed, STREAM_CONTENTION)
        lo = np.asarray([b[1] for b in PARAM_BOUNDS])
        hi = np.asarray([b[2] for b in PARAM_BOUNDS])
        params = bounded_uniform_grid(params_key, len(WORKLOAD_NAMES),
                                      lo, hi)
        self.workloads = {
            name: {PARAM_BOUNDS[p][0]: float(params[w, p])
                   for p in range(len(PARAM_BOUNDS))}
            for w, name in enumerate(WORKLOAD_NAMES)}
        self._wid = {name: w for w, name in enumerate(WORKLOAD_NAMES)}
        self._col = {c.key: j for j, c in enumerate(self.configs)}
        self.grid = self._build_grid(params, noise_key)
        self._offgrid_cache: Dict = {}

    # ---------------------------------------------------------- grid
    def _build_grid(self, params: np.ndarray,
                    noise_key: np.ndarray) -> ScoutGrid:
        configs = self.configs
        uids = np.asarray([config_uid(c) for c in configs], np.int32)
        cpu = np.asarray([MACHINE_PROFILES[c.vm_type].cpu
                          for c in configs])
        iops = np.asarray([MACHINE_PROFILES[c.vm_type].disk_iops
                           for c in configs])
        gbps = np.asarray([MACHINE_PROFILES[c.vm_type].net_gbps
                           for c in configs])
        cores = np.asarray([_CORES[c.vm_type.split(".")[1]]
                            for c in configs], np.float64)
        mem_gb = np.asarray([_mem_gb(c.vm_type) for c in configs],
                            np.float64)
        count = np.asarray([c.count for c in configs], np.float64)
        price = np.asarray([PRICES[c.vm_type] for c in configs])

        # (W, 1) params against (C,) config columns -> (W, C) tables,
        # elementwise-identical to the scalar model below
        cpu_work, mem_need, disk_work, net_work, pf = (
            params[:, p:p + 1] for p in range(5))
        n_cores = cores * count
        cpu_t = cpu_work / cpu * ((1 - pf) + pf / n_cores)
        disk_t = disk_work / iops * 100.0 / count
        net_t = net_work * (count - 1) / np.maximum(gbps * 100.0, 1.0)
        threshold = mem_gb * 0.85
        mem_penalty = np.where(
            mem_need > threshold,
            1.0 + 2.2 * (mem_need / threshold - 1.0), 1.0)
        base = (cpu_t + disk_t + net_t) * mem_penalty

        noise = lognormal_noise_grid(noise_key, len(WORKLOAD_NAMES),
                                     uids, CONTENTION_SCALE)
        runtime = base * noise
        cost = runtime / 3600.0 * price * count

        low_num = np.stack([
            cpu_work / cpu / n_cores,
            np.broadcast_to(mem_need / mem_gb, base.shape),
            np.broadcast_to(disk_work / iops, base.shape),
            np.broadcast_to(net_t, base.shape),
        ], axis=-1)
        lows = _lows_from(low_num, runtime)
        x_base = np.stack([self.config_features(c) for c in configs])
        return ScoutGrid(base_runtime=base, runtime=runtime, cost=cost,
                         low_num=low_num, lows=lows, x_base=x_base,
                         price=price, count=count, config_uid=uids,
                         noise_key=noise_key)

    def _offgrid(self, workload: str, config: CloudConfig):
        """Scalar model for configurations outside the 69-config grid —
        the same pure fold-in draw, memoized only as a shortcut."""
        key = (workload, config.key)
        hit = self._offgrid_cache.get(key)
        if hit is not None:
            return hit
        w = self.workloads[workload]
        prof = MACHINE_PROFILES[config.vm_type]
        cores = _CORES[config.vm_type.split(".")[1]]
        mem_gb = _mem_gb(config.vm_type)
        n_cores = cores * config.count
        pf = w["parallel_frac"]
        cpu_t = w["cpu_work"] / prof.cpu * ((1 - pf) + pf / n_cores)
        disk_t = w["disk_work"] / prof.disk_iops * 100.0 / config.count
        net_t = (w["net_work"] * (config.count - 1)
                 / max(prof.net_gbps * 100.0, 1.0))
        mem_penalty = 1.0
        if w["mem_need_gb"] > mem_gb * 0.85:  # spilling
            mem_penalty = 1.0 + 2.2 * (
                w["mem_need_gb"] / (mem_gb * 0.85) - 1.0)
        base = (cpu_t + disk_t + net_t) * mem_penalty
        noise = lognormal_noise_grid(
            self.grid.noise_key, len(WORKLOAD_NAMES),
            np.asarray([config_uid(config)], np.int32),
            CONTENTION_SCALE)[self._wid[workload], 0]
        runtime = float(base * noise)
        low_num = np.asarray([
            w["cpu_work"] / prof.cpu / n_cores,
            w["mem_need_gb"] / mem_gb,
            w["disk_work"] / prof.disk_iops,
            net_t,
        ])
        lows = _lows_from(low_num[None, :], np.asarray([runtime]))[0]
        out = (runtime, lows)
        self._offgrid_cache[key] = out
        return out

    # ------------------------------------------------------------- runtime
    def runtime_s(self, workload: str, config: CloudConfig) -> float:
        col = self._col.get(config.key)
        if col is not None:
            return float(self.grid.runtime[self._wid[workload], col])
        return self._offgrid(workload, config)[0]

    def cost_usd(self, workload: str, config: CloudConfig) -> float:
        rt = self.runtime_s(workload, config)
        return rt / 3600.0 * PRICES[config.vm_type] * config.count

    def low_level_metrics(self, workload: str, config: CloudConfig
                          ) -> np.ndarray:
        """Arrow's augmentation: utilization-style metrics of the run."""
        col = self._col.get(config.key)
        if col is not None:
            return self.grid.lows[self._wid[workload], col].copy()
        return self._offgrid(workload, config)[1].copy()

    def workload_arrays(self, workload: str):
        """Canonical-order materialization of one workload's tables:
        (runtimes, costs, low-level metrics) over ``self.configs``.
        Every value is a pure counter-based draw, so any consumer — in
        any call order, on host or inside the device replay program —
        sees bit-identical tables."""
        w = self._wid[workload]
        return (self.grid.runtime[w].copy(), self.grid.cost[w].copy(),
                self.grid.lows[w].copy())

    # --------------------------------------------------------------- views
    def workload_id(self, workload: str) -> int:
        return self._wid[workload]

    def config_features(self, config: CloudConfig) -> np.ndarray:
        prof = MACHINE_PROFILES[config.vm_type]
        return np.asarray([
            config.count,
            math.log(prof.cpu), math.log(prof.memory),
            math.log(prof.disk_iops), math.log(prof.net_gbps * 1000),
            PRICES[config.vm_type],
        ])

    def utilization_factors(self, config: CloudConfig) -> np.ndarray:
        """Per-aspect utilization headroom factor of a configuration —
        one term of Perona's acquisition weighting (paper §IV-D)."""
        prof = MACHINE_PROFILES[config.vm_type]
        caps = np.asarray([prof.cpu, prof.memory, prof.disk_iops,
                           prof.net_gbps * 1000])
        ref = np.asarray([5000.0, 50000.0, 8000.0, 10000.0])
        return np.clip(caps / ref, 0.05, 1.0)


def _lows_from(low_num: np.ndarray, runtime: np.ndarray) -> np.ndarray:
    """(..., 4) low-level metrics from their numerators + runtimes, in
    the exact op order the device expansion uses (``jnp.minimum(caps,
    num / denom)``), so host and device lows are bit-identical."""
    rtm = np.maximum(runtime, 1e-6)
    denom = np.stack([rtm, np.ones_like(rtm), rtm, rtm], axis=-1)
    return np.minimum(np.asarray(LOW_CAPS), low_num / denom)
