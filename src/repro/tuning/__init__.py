"""Resource-configuration tuning (paper §IV-D/E).

Re-implementations of CherryPick (Bayesian optimization) and Arrow
(augmented BO with low-level metrics), a scout-like dataset simulator
(18 workloads x 69 AWS configs), Perona's acquisition weighting, and the
scientific-workflow integrations (Lotaru runtime prediction, Tarema node
grouping).
"""

from repro.tuning.scout import ScoutDataset
from repro.tuning.cherrypick import CherryPick
from repro.tuning.arrow import Arrow
from repro.tuning.perona_weights import PeronaAcquisitionWeighter

__all__ = [
    "ScoutDataset",
    "CherryPick",
    "Arrow",
    "PeronaAcquisitionWeighter",
]
