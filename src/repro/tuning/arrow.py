"""Arrow re-implementation (Hsu et al., ICDCS'18).

Augmented Bayesian optimization: the GP input of an *evaluated* config is
augmented with low-level metrics observed during its profiling run; for
un-evaluated candidates the low-level block is imputed with the mean of
observed runs. With Perona (paper §IV-D), the low-level metrics are
replaced by the fingerprint scores of the machine type.
"""

from __future__ import annotations

from typing import Callable, Optional

import numpy as np

from repro.tuning.cherrypick import CherryPick, SearchTrace
from repro.tuning.scout import CloudConfig, ScoutDataset


class Arrow(CherryPick):
    name = "arrow"

    def __init__(self, dataset: ScoutDataset, runtime_limit_s: float,
                 low_level_fn: Optional[Callable] = None, **kw):
        super().__init__(dataset, runtime_limit_s, **kw)
        # default low-level source: utilization metrics of the actual run
        self.low_level_fn = low_level_fn
        self._low_cache = {}

    def _low(self, workload: str, config: CloudConfig) -> np.ndarray:
        key = (workload, config.key)
        if key not in self._low_cache:
            if self.low_level_fn is not None:
                self._low_cache[key] = self.low_level_fn(workload, config)
            else:
                self._low_cache[key] = self.ds.low_level_metrics(
                    workload, config)
        return self._low_cache[key]

    def search(self, workload: str) -> SearchTrace:
        self._workload = workload
        self._observed_lows = []
        self._low_cache = {}
        return super().search(workload)

    def _on_evaluate(self, workload: str, config: CloudConfig):
        low = self._low(workload, config)
        self._low_cache[(workload, config.key)] = low
        self._observed_lows.append(low)

    def _features(self, config) -> np.ndarray:
        base = self.ds.config_features(config)
        wl = getattr(self, "_workload", None)
        if wl is None:
            return base
        key = (wl, config.key)
        if key in self._low_cache:
            low = self._low_cache[key]
        elif self.low_level_fn is not None:
            # Perona mode: fingerprint scores exist *before* any run —
            # the machine was benchmarked once, independent of workload
            low = self._low(wl, config)
        elif self._observed_lows:
            low = np.mean(np.stack(self._observed_lows), axis=0)
        else:
            low = np.zeros(4)
        return np.concatenate([base, low])
