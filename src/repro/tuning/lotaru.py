"""Lotaru-style task-runtime prediction for heterogeneous clusters
(Bader et al., SSDBM'22) + the paper's §IV-E substitution experiment.

Lotaru predicts a workflow task's runtime on a target node by profiling
the task locally (small inputs on a local machine) and scaling by an
adjustment factor derived from microbenchmarks of local vs target nodes.
Perona's variant replaces the raw microbenchmark values with fingerprint
scores. Baselines from the Lotaru paper: Naive (mean runtime ratio),
Online-M / Online-P (median/percentile online estimators without
benchmarking).

Evaluation metric: median / P90 / P95 of |pred - actual| / actual over
synthetic workflow tasks with heterogeneous resource profiles (Table III
analogue).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np

from repro.fingerprint.machines import MACHINE_PROFILES


@dataclasses.dataclass
class Task:
    name: str
    cpu_frac: float  # fraction of work bound by cpu
    disk_frac: float
    mem_frac: float
    base_work: float


def make_workflow(rng, n_tasks: int = 24) -> List[Task]:
    tasks = []
    for i in range(n_tasks):
        f = rng.dirichlet([2.0, 1.2, 0.8])
        tasks.append(Task(
            name=f"task-{i}", cpu_frac=float(f[0]), disk_frac=float(f[1]),
            mem_frac=float(f[2]), base_work=float(rng.uniform(50, 900))))
    return tasks


def true_runtime(task: Task, machine_type: str, rng=None) -> float:
    p = MACHINE_PROFILES[machine_type]
    t = task.base_work * (
        task.cpu_frac * 1000.0 / p.cpu
        + task.disk_frac * 15000.0 / p.disk_iops
        + task.mem_frac * 10000.0 / p.memory)
    if rng is not None:
        t *= float(np.exp(rng.normal(0, 0.05)))
    return float(t)


def predict_factor(task: Task, local_vec: np.ndarray, target_vec: np.ndarray
                   ) -> float:
    """Adjustment factor f with pred_target = local_runtime * f.

    Runtime ~ sum_i w_i / cap_i, so f ~ sum_i w_i * cap_local_i /
    cap_target_i with weights = the task's local resource-time fractions
    (Lotaru's local-profile scheme)."""
    w = np.asarray([task.cpu_frac, task.disk_frac, task.mem_frac])
    ratio = np.clip(local_vec, 1e-9, None) / np.clip(target_vec, 1e-9, None)
    return float(np.sum(w * ratio))


def microbenchmark_vector(machine_type: str) -> np.ndarray:
    """Lotaru's raw microbenchmark values (cpu events/s, disk iops,
    memory MiB/s)."""
    p = MACHINE_PROFILES[machine_type]
    return np.asarray([p.cpu, p.disk_iops, p.memory])


def perona_vector(machine_scores: Dict[str, Dict[str, float]],
                  machine_type: str) -> np.ndarray:
    """(cpu, disk, memory) capability vector from Perona fingerprints —
    pass *calibrated* scores (repro.tuning.perona_weights
    .calibrate_scores) when ratios matter (Lotaru)."""
    per = machine_scores[machine_type]
    return np.asarray([per.get("cpu", 1e-9), per.get("disk", 1e-9),
                       per.get("memory", 1e-9)])


def evaluate_predictors(machine_scores: Dict[str, Dict[str, float]],
                        *, local_type: str = "e2-medium",
                        target_types: Sequence[str] = (
                            "n1-standard-4", "n2-standard-4",
                            "c2-standard-4"),
                        n_workflows: int = 8, seed: int = 0
                        ) -> Dict[str, Dict[str, float]]:
    """Table III analogue: error percentiles per method."""
    rng = np.random.default_rng(seed)
    errors: Dict[str, List[float]] = {
        "naive": [], "online_m": [], "online_p": [], "lotaru": [],
        "perona": []}
    for _ in range(n_workflows):
        tasks = make_workflow(rng)
        for task in tasks:
            local_rt = true_runtime(task, local_type, rng)
            history = [true_runtime(t, local_type, rng) for t in tasks[:6]]
            for tgt in target_types:
                actual = true_runtime(task, tgt, rng)
                # Naive: assume same runtime as local
                errors["naive"].append(abs(local_rt - actual) / actual)
                # Online-M/P: median/percentile of unrelated history
                om = float(np.median(history))
                op = float(np.percentile(history, 25))
                errors["online_m"].append(abs(om - actual) / actual)
                errors["online_p"].append(abs(op - actual) / actual)
                # Lotaru: microbenchmark factors
                f = predict_factor(task, microbenchmark_vector(local_type),
                                   microbenchmark_vector(tgt))
                errors["lotaru"].append(abs(local_rt * f - actual) / actual)
                # Perona: fingerprint score factors (calibrated, §IV-E's
                # "adjusted the estimation process")
                fp = predict_factor(
                    task, perona_vector(machine_scores, local_type),
                    perona_vector(machine_scores, tgt))
                errors["perona"].append(
                    abs(local_rt * fp - actual) / actual)
    out = {}
    for k, v in errors.items():
        arr = np.asarray(v)
        out[k] = {"median": float(np.median(arr)),
                  "p90": float(np.percentile(arr, 90)),
                  "p95": float(np.percentile(arr, 95))}
    return out
