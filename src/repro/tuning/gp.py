"""Minimal Gaussian-process regression for Bayesian optimization.

RBF kernel with per-dimension length scales (median heuristic), noise
jitter, exact Cholesky inference — numpy/scipy only, adequate for the
69-point scout search spaces of CherryPick/Arrow.
"""

from __future__ import annotations

import numpy as np
from scipy.linalg import cho_factor, cho_solve
from scipy.stats import norm


class GP:
    def __init__(self, noise: float = 1e-3):
        self.noise = noise
        self.X = None
        self.y = None

    def _scales(self, X):
        if len(X) < 2:
            # single observation: every pairwise distance is zero, the
            # median heuristic is undefined -> unit length scales
            return np.ones(X.shape[-1])
        med = np.median(np.abs(X[:, None, :] - X[None, :, :]), axis=(0, 1))
        return np.where(med > 1e-9, med, 1.0)

    def _k(self, A, B):
        d = (A[:, None, :] - B[None, :, :]) / self.scales
        return np.exp(-0.5 * np.sum(d * d, axis=-1))

    def fit(self, X: np.ndarray, y: np.ndarray):
        self.X = np.asarray(X, float)
        self.y_mean = float(np.mean(y))
        # constant-y guard: a (numerically) zero spread would blow up
        # the standardized targets; fall back to unit std
        std = float(np.std(y))
        self.y_std = std if std > 1e-12 * max(1.0, abs(self.y_mean)) \
            else 1.0
        self.y = (np.asarray(y, float) - self.y_mean) / self.y_std
        self.scales = self._scales(self.X)
        K = self._k(self.X, self.X) + self.noise * np.eye(len(self.X))
        self.chol = cho_factor(K)
        self.alpha = cho_solve(self.chol, self.y)
        return self

    def predict(self, Xs: np.ndarray):
        Ks = self._k(np.asarray(Xs, float), self.X)
        mu = Ks @ self.alpha
        v = cho_solve(self.chol, Ks.T)
        var = np.clip(1.0 - np.sum(Ks * v.T, axis=1), 1e-9, None)
        return (mu * self.y_std + self.y_mean,
                np.sqrt(var) * self.y_std)


def expected_improvement(mu, sigma, best, xi: float = 0.01):
    """EI for *minimization*; non-negative by definition, so the result
    is clipped at 0 (degenerate sigma -> the improvement itself)."""
    imp = best - mu - xi
    z = imp / np.maximum(sigma, 1e-9)
    return np.maximum(imp * norm.cdf(z) + sigma * norm.pdf(z), 0.0)
