"""CherryPick re-implementation (Alipourfard et al., NSDI'17).

Bayesian optimization over cloud configurations: model cost(config) with
a GP, pick the next config by expected improvement, subject to a runtime
constraint; stop when EI/best < threshold or the run budget is used.
The objective is *execution cost*, valid configurations satisfy the
runtime constraint (paper §IV-D setup).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import numpy as np

from repro.tuning.gp import GP, expected_improvement
from repro.tuning.scout import CloudConfig, ScoutDataset


@dataclasses.dataclass
class SearchTrace:
    evaluated: List[CloudConfig]
    costs: List[float]
    runtimes: List[float]
    best_valid_cost: List[float]  # running cheapest-valid after each run
    search_cost: float  # total $ spent profiling


class CherryPick:
    name = "cherrypick"

    def __init__(self, dataset: ScoutDataset, runtime_limit_s: float,
                 max_runs: int = 9, n_init: int = 3, ei_threshold: float = 0.1,
                 seed: int = 0, acquisition_weighter=None):
        self.ds = dataset
        self.limit = runtime_limit_s
        self.max_runs = max_runs
        self.n_init = n_init
        self.ei_threshold = ei_threshold
        self.rng = np.random.default_rng(seed)
        self.weighter = acquisition_weighter

    def _features(self, config) -> np.ndarray:
        return self.ds.config_features(config)

    def _on_evaluate(self, workload: str, config: CloudConfig):
        """Hook for subclasses (Arrow records low-level metrics here)."""

    def search(self, workload: str) -> SearchTrace:
        configs = list(self.ds.configs)
        X = np.stack([self._features(c) for c in configs])
        evaluated, costs, runtimes, best_curve = [], [], [], []
        seen = set()

        def evaluate(c: CloudConfig):
            rt = self.ds.runtime_s(workload, c)
            cost = self.ds.cost_usd(workload, c)
            evaluated.append(c)
            runtimes.append(rt)
            costs.append(cost)
            seen.add(c.key)
            self._on_evaluate(workload, c)
            valid = [co for co, r in zip(costs, runtimes) if r <= self.limit]
            best_curve.append(min(valid) if valid else np.inf)

        # quasi-random init spread over VM families (paper: >=1 run first)
        init_idx = self.rng.choice(len(configs), self.n_init, replace=False)
        for i in init_idx:
            evaluate(configs[i])

        while len(evaluated) < self.max_runs:
            y = np.asarray([
                c if r <= self.limit else c * 5.0  # constraint penalty
                for c, r in zip(costs, runtimes)])
            gp = GP().fit(np.stack([self._features(c) for c in evaluated]),
                          y)
            mu, sigma = gp.predict(X)
            best = float(np.min(y))
            ei = expected_improvement(mu, sigma, best)
            if self.weighter is not None:
                any_valid = any(r <= self.limit for r in runtimes)
                ei = self.weighter(configs, ei, workload=workload,
                                   evaluated=evaluated,
                                   any_valid=any_valid)
            ei = np.asarray([
                e if c.key not in seen else -np.inf
                for c, e in zip(configs, ei)])
            # select on float32-rounded EI: a deterministic tie-break
            # grid. Near-identical configurations (e.g. adjacent
            # scaleouts of one VM type) can tie to within float64 ulps,
            # where backend rounding differences would make the argmax
            # arbitrary; the batched replay engine (optimizer.replay)
            # rounds identically and reproduces these traces exactly.
            ei = ei.astype(np.float32).astype(np.float64)
            if np.max(ei) <= 0:
                break
            if np.max(ei) / max(best, 1e-9) < self.ei_threshold \
                    and len(evaluated) >= self.n_init + 2:
                break
            evaluate(configs[int(np.argmax(ei))])

        return SearchTrace(
            evaluated=evaluated, costs=costs, runtimes=runtimes,
            best_valid_cost=best_curve, search_cost=float(np.sum(costs)))
