"""Hyperparameter search for the Perona model (paper Table II).

The paper samples 100 configurations with Ray Tune + Optuna over:
#attention heads, use-beta, feature dropout, edge dropout, use
root-weight, CBFL gamma/beta, learning rate, weight decay. This module
implements a seeded random search over the same space (quasi-random
sampling; the TPE surrogate is unnecessary at this budget) and returns
the best model under the trainer's checkpoint-selection rank
(validation outlier F1, total loss as tie-break).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.graph_data import PeronaBatch
from repro.core.model import PeronaConfig, PeronaModel
from repro.core.trainer import TrainResult, evaluate, train_perona

# Table II search space
SPACE = {
    "heads": (1, 2, 4, 8),
    "feature_dropout": (0.0, 0.3),  # uniform range
    "edge_dropout": (0.0, 0.3),
    "use_root_weight": (True, False),
    "cbfl_gamma": (0.5, 4.0),
    "cbfl_beta": (0.9, 0.9999),
    "lr": (1e-4, 1e-2),  # log-uniform
    "weight_decay": (1e-6, 1e-3),  # log-uniform
}


@dataclasses.dataclass
class Trial:
    params: Dict
    val_loss: float
    val_f1: float = 0.0
    result: Optional[TrainResult] = None

    @property
    def score(self) -> Tuple[float, float]:
        """Rank key matching train_perona's checkpoint selection:
        max val outlier F1, then min val loss as tie-break."""
        return (self.val_f1, -self.val_loss)


def sample_config(rng: np.random.Generator) -> Dict:
    return {
        "heads": int(rng.choice(SPACE["heads"])),
        "feature_dropout": float(rng.uniform(*SPACE["feature_dropout"])),
        "edge_dropout": float(rng.uniform(*SPACE["edge_dropout"])),
        "use_root_weight": bool(rng.choice(SPACE["use_root_weight"])),
        "cbfl_gamma": float(rng.uniform(*SPACE["cbfl_gamma"])),
        "cbfl_beta": float(1.0 - 10 ** rng.uniform(
            np.log10(1 - SPACE["cbfl_beta"][1]),
            np.log10(1 - SPACE["cbfl_beta"][0]))),
        "lr": float(10 ** rng.uniform(np.log10(SPACE["lr"][0]),
                                      np.log10(SPACE["lr"][1]))),
        "weight_decay": float(10 ** rng.uniform(
            np.log10(SPACE["weight_decay"][0]),
            np.log10(SPACE["weight_decay"][1]))),
    }


def search(base_cfg: PeronaConfig, train_batch: PeronaBatch,
           val_batch: PeronaBatch, *, n_trials: int = 100,
           epochs: int = 60, seed: int = 0, verbose: bool = False
           ) -> Tuple[Trial, List[Trial]]:
    """Returns (best trial with trained result, all trials)."""
    rng = np.random.default_rng(seed)
    trials: List[Trial] = []
    best: Optional[Trial] = None
    for t in range(n_trials):
        hp = sample_config(rng)
        cfg = dataclasses.replace(
            base_cfg,
            heads=hp["heads"],
            feature_dropout=hp["feature_dropout"],
            edge_dropout=hp["edge_dropout"],
            use_root_weight=hp["use_root_weight"],
            cbfl_gamma=hp["cbfl_gamma"],
            cbfl_beta=hp["cbfl_beta"],
        )
        model = PeronaModel(cfg)
        res = train_perona(model, train_batch, val_batch, epochs=epochs,
                           lr=hp["lr"], weight_decay=hp["weight_decay"],
                           seed=seed + t)
        # score the checkpoint train_perona actually kept: the F1-best
        # epoch (loss as tie-break), mirroring its selection rule
        sel = [(h.get("val_f1_outlier", 0.0), -h["val_loss"])
               for h in res.history if "val_loss" in h]
        f1, neg_vl = max(sel) if sel else (0.0, -float("inf"))
        trial = Trial(params=hp, val_loss=-neg_vl, val_f1=f1, result=res)
        trials.append(trial)
        if best is None or trial.score > best.score:
            best = trial
        if verbose:
            print(f"[hpo {t + 1}/{n_trials}] f1={f1:.4f} "
                  f"val={trial.val_loss:.4f} best_f1={best.val_f1:.4f} "
                  f"{hp}")
        # free non-best results to bound memory
        if trial is not best:
            trial.result = None
    return best, trials
