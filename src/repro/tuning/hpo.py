"""Hyperparameter search for the Perona model (paper Table II).

The paper samples 100 configurations with Ray Tune + Optuna over:
#attention heads, use-beta, feature dropout, edge dropout, use
root-weight, CBFL gamma/beta, learning rate, weight decay. This module
implements a seeded random search over the same space (quasi-random
sampling; the TPE surrogate is unnecessary at this budget) and returns
the best model under the trainer's checkpoint-selection rank
(validation outlier F1, total loss as tie-break).

Execution is device-resident: trials are bucketed by the two
*shape/program-changing* hypers (``heads``, ``use_root_weight`` — the
same shape-bucketing idea as ``serving.FingerprintEngine``), the scalar
hypers (dropouts, CBFL gamma/beta, lr, weight decay) are stacked into
arrays, and the scanned trainer (``core.trainer``) is ``jax.vmap``-ed
over each bucket — a 100-trial search executes as <=8 compiled calls
(one per occupied bucket) instead of 100 host-driven training loops.
Bucket batch sizes are padded to powers of two so repeated searches
reuse the compiled programs.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.common.bucketing import next_pow2
from repro.core.graph_data import PeronaBatch
from repro.core.model import PeronaConfig, PeronaModel
from repro.core import trainer as trainer_mod
from repro.core.trainer import TrainResult, batch_to_jnp, train_perona

# Table II search space
SPACE = {
    "heads": (1, 2, 4, 8),
    "feature_dropout": (0.0, 0.3),  # uniform range
    "edge_dropout": (0.0, 0.3),
    "use_root_weight": (True, False),
    "cbfl_gamma": (0.5, 4.0),
    "cbfl_beta": (0.9, 0.9999),
    "lr": (1e-4, 1e-2),  # log-uniform
    "weight_decay": (1e-6, 1e-3),  # log-uniform
}

# scalar (traced) hypers stacked per bucket; heads/use_root_weight are
# static: they change the compiled program, not just its inputs
SCALAR_HYPERS = ("feature_dropout", "edge_dropout", "cbfl_gamma",
                 "cbfl_beta", "lr", "weight_decay")


@dataclasses.dataclass
class Trial:
    params: Dict
    val_loss: float
    val_f1: float = 0.0
    result: Optional[TrainResult] = None

    @property
    def score(self) -> Tuple[float, float]:
        """Rank key matching train_perona's checkpoint selection:
        max val outlier F1, then min val loss as tie-break."""
        return (self.val_f1, -self.val_loss)


@dataclasses.dataclass
class SearchStats:
    """Introspection for the vmapped search (asserted by tests)."""

    n_buckets: int
    bucket_sizes: Dict[Tuple[int, bool], int]
    device_calls: int
    trace_count: int  # scanned-trainer tracings during this search


def sample_config(rng: np.random.Generator) -> Dict:
    return {
        "heads": int(rng.choice(SPACE["heads"])),
        "feature_dropout": float(rng.uniform(*SPACE["feature_dropout"])),
        "edge_dropout": float(rng.uniform(*SPACE["edge_dropout"])),
        "use_root_weight": bool(rng.choice(SPACE["use_root_weight"])),
        "cbfl_gamma": float(rng.uniform(*SPACE["cbfl_gamma"])),
        "cbfl_beta": float(1.0 - 10 ** rng.uniform(
            np.log10(1 - SPACE["cbfl_beta"][1]),
            np.log10(1 - SPACE["cbfl_beta"][0]))),
        "lr": float(10 ** rng.uniform(np.log10(SPACE["lr"][0]),
                                      np.log10(SPACE["lr"][1]))),
        "weight_decay": float(10 ** rng.uniform(
            np.log10(SPACE["weight_decay"][0]),
            np.log10(SPACE["weight_decay"][1]))),
    }


def _bucket_cfg(base_cfg: PeronaConfig, heads: int,
                use_root_weight: bool) -> PeronaConfig:
    return dataclasses.replace(base_cfg, heads=heads,
                               use_root_weight=use_root_weight)


def _trial_cfg(base_cfg: PeronaConfig, hp: Dict) -> PeronaConfig:
    return dataclasses.replace(
        base_cfg, heads=hp["heads"],
        feature_dropout=hp["feature_dropout"],
        edge_dropout=hp["edge_dropout"],
        use_root_weight=hp["use_root_weight"],
        cbfl_gamma=hp["cbfl_gamma"], cbfl_beta=hp["cbfl_beta"])


def _sel_score(history) -> Tuple[float, float]:
    """Score of the checkpoint the trainer actually kept: the F1-best
    epoch (loss as tie-break), mirroring its selection rule."""
    sel = [(h.get("val_f1_outlier", 0.0), -h["val_loss"])
           for h in history if "val_loss" in h]
    return max(sel) if sel else (0.0, -float("inf"))


def search_sequential(base_cfg: PeronaConfig, train_batch: PeronaBatch,
                      val_batch: PeronaBatch, *, n_trials: int = 100,
                      epochs: int = 60, seed: int = 0,
                      patience: int = 25, verbose: bool = False,
                      train_fn: Optional[Callable] = None
                      ) -> Tuple[Trial, List[Trial]]:
    """One host-driven training per trial. ``train_fn`` defaults to the
    scanned trainer; pass ``trainer.train_perona_reference`` for the
    legacy per-epoch loop (the benchmark baseline)."""
    train_fn = train_perona if train_fn is None else train_fn
    rng = np.random.default_rng(seed)
    trials: List[Trial] = []
    best: Optional[Trial] = None
    for t in range(n_trials):
        hp = sample_config(rng)
        model = PeronaModel(_trial_cfg(base_cfg, hp))
        res = train_fn(model, train_batch, val_batch, epochs=epochs,
                       lr=hp["lr"], weight_decay=hp["weight_decay"],
                       patience=patience, seed=seed + t)
        f1, neg_vl = _sel_score(res.history)
        trial = Trial(params=hp, val_loss=-neg_vl, val_f1=f1, result=res)
        trials.append(trial)
        if best is None or trial.score > best.score:
            best = trial
        if verbose:
            print(f"[hpo {t + 1}/{n_trials}] f1={f1:.4f} "
                  f"val={trial.val_loss:.4f} best_f1={best.val_f1:.4f} "
                  f"{hp}")
        # free non-best results to bound memory
        if trial is not best:
            trial.result = None
    return best, trials


def search(base_cfg: PeronaConfig, train_batch: PeronaBatch,
           val_batch: PeronaBatch, *, n_trials: int = 100,
           epochs: int = 60, seed: int = 0, patience: int = 25,
           verbose: bool = False, vmapped: bool = True,
           return_stats: bool = False):
    """Returns (best trial with trained result, all trials) — plus a
    :class:`SearchStats` when ``return_stats`` is set."""
    if not vmapped:
        best, trials = search_sequential(
            base_cfg, train_batch, val_batch, n_trials=n_trials,
            epochs=epochs, seed=seed, patience=patience, verbose=verbose)
        if return_stats:
            return best, trials, None
        return best, trials

    import jax
    import jax.numpy as jnp

    rng = np.random.default_rng(seed)
    hps = [sample_config(rng) for _ in range(n_trials)]
    buckets: Dict[Tuple[int, bool], List[int]] = {}
    for t, hp in enumerate(hps):
        key = (hp["heads"], hp["use_root_weight"])
        buckets.setdefault(key, []).append(t)

    tb = batch_to_jnp(train_batch)
    vb = batch_to_jnp(val_batch)
    y_val = jnp.asarray(val_batch.anomaly)

    traces0 = trainer_mod.TRAINER_TRACES.count
    device_calls = 0
    trials: List[Optional[Trial]] = [None] * n_trials
    # per bucket, keep only the bucket-best trial's checkpoint/history
    # (the stacked per-trial outputs are dropped as soon as the bucket
    # is scored — memory stays O(one model), like the sequential path)
    bucket_best: Dict[Tuple[int, bool], Tuple[int, dict, dict, int]] = {}
    for bkey in sorted(buckets):
        heads, urw = bkey
        idxs = buckets[bkey]
        model = PeronaModel(_bucket_cfg(base_cfg, heads, urw))
        # pad the trial axis to a power of two: repeated searches with
        # similar bucket occupancy reuse one compiled program per bucket
        b2 = next_pow2(len(idxs))
        padded = idxs + [idxs[0]] * (b2 - len(idxs))
        init_keys = jnp.stack(
            [jax.random.PRNGKey(seed + t) for t in padded])
        train_keys = jnp.stack(
            [jax.random.PRNGKey(seed + t + 1) for t in padded])
        params0 = jax.vmap(model.init)(init_keys)
        hypers = {name: jnp.asarray([hps[t][name] for t in padded],
                                    jnp.float32)
                  for name in SCALAR_HYPERS}
        fn = _vmapped_train_fn(model, epochs, patience)
        out = fn(params0, tb, vb, y_val, hypers, train_keys)
        device_calls += 1
        vls = np.asarray(out["val_loss"])
        f1s = np.asarray(out["val_f1"])
        act = np.asarray(out["active"])
        for j, t in enumerate(idxs):
            sel = [(float(f1s[j, e]), -float(vls[j, e]))
                   for e in range(epochs) if act[j, e]]
            f1, neg_vl = max(sel) if sel else (0.0, -float("inf"))
            trials[t] = Trial(params=hps[t], val_loss=-neg_vl, val_f1=f1)
        jb = max(range(len(idxs)), key=lambda j: trials[idxs[j]].score)
        bucket_best[bkey] = (
            idxs[jb],
            jax.tree_util.tree_map(lambda x: x[jb], out["params"]),
            {"train_loss": np.asarray(out["train_loss"][jb]),
             "val_loss": vls[jb], "val_f1": f1s[jb], "active": act[jb]},
            int(out["best_epoch"][jb]))
        del out
        if verbose:
            done = sum(tr is not None for tr in trials)
            print(f"[hpo bucket heads={heads} root={urw}] "
                  f"{len(idxs)} trials ({done}/{n_trials} done)")

    best_t = max(range(n_trials), key=lambda t: trials[t].score)
    best = trials[best_t]
    bkey = (hps[best_t]["heads"], hps[best_t]["use_root_weight"])
    kept_t, best_params, hist, best_epoch = bucket_best[bkey]
    assert kept_t == best_t  # global best is its bucket's best
    history = []
    for e in range(epochs):
        if not hist["active"][e]:
            break
        history.append({"epoch": e,
                        "train_loss": float(hist["train_loss"][e]),
                        "val_loss": float(hist["val_loss"][e]),
                        "val_f1_outlier": float(hist["val_f1"][e])})
    best.result = TrainResult(params=best_params, history=history,
                              best_epoch=best_epoch)

    stats = SearchStats(
        n_buckets=len(buckets),
        bucket_sizes={k: len(v) for k, v in buckets.items()},
        device_calls=device_calls,
        trace_count=trainer_mod.TRAINER_TRACES.count - traces0)
    if return_stats:
        return best, [t for t in trials], stats
    return best, [t for t in trials]


def _vmapped_train_fn(model: PeronaModel, epochs: int, patience: int):
    """One jitted vmapped scanned trainer per (canonical model config,
    epochs, patience); cached so repeated searches skip compilation."""
    return _vmapped_train_fn_canon(trainer_mod.canonical_model(model),
                                   epochs, patience)


@functools.lru_cache(maxsize=64)
def _vmapped_train_fn_canon(canon: PeronaModel, epochs: int,
                            patience: int):
    import jax

    raw = trainer_mod._make_train_fn(canon, epochs, patience, True)
    # the stacked params carry is donated, like the single-run trainer:
    # one live copy of (params, opt state) per bucket
    return jax.jit(jax.vmap(raw, in_axes=(0, None, None, None, 0, 0)),
                   donate_argnums=(0,))
