"""Whisper-small [arXiv:2212.04356]: enc-dec; conv frontend is a STUB —
input_specs() provides precomputed frame embeddings (B, 1500, d_model)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,              # decoder layers; encoder separate below
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    body_pattern=("xattn",),
    n_encoder_layers=12,
    n_audio_frames=1500,
    norm="layernorm",
    mlp="gelu",
    rope_style="learned",
    tie_embeddings=True,
    max_seq=32768,            # assigned shapes exceed whisper's own 448
)
