"""OLMo-1B [arXiv:2402.00838]: dense, non-parametric LayerNorm, tied."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=50304,
    body_pattern=("attn",),
    norm="nonparametric_ln",
    mlp="swiglu",
    rope_style="rope",
    rope_theta=10000.0,
    tie_embeddings=True,
)
