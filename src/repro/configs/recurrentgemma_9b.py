"""RecurrentGemma-9B [arXiv:2402.19427]: Griffin — RG-LRU + local attn,
pattern (recurrent, recurrent, attention); 38 = 12x3 + (r, r) tail; MQA."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    body_pattern=("rg_lru", "rg_lru", "local_attn"),
    n_periods=12,
    tail_pattern=("rg_lru", "rg_lru"),
    local_window=2048,
    lru_width=4096,
    conv1d_width=4,
    norm="rmsnorm",
    mlp="geglu",
    rope_style="rope",
    rope_theta=10000.0,
    tie_embeddings=True,
    chunked_ce=512,
)
