"""Qwen2-VL-7B [arXiv:2409.12191]: M-RoPE; vision frontend is a STUB —
input_specs() provides precomputed patch+text embeddings."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    body_pattern=("attn",),
    qkv_bias=True,
    norm="rmsnorm",
    mlp="swiglu",
    rope_style="mrope",
    rope_theta=1000000.0,
    tie_embeddings=False,
)
