"""Architecture registry: one module per assigned architecture.

Usage: ``from repro.configs import get_config; cfg = get_config("olmo-1b")``.
"""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

ARCHS = (
    "olmo-1b",
    "smollm-135m",
    "qwen2.5-3b",
    "gemma3-4b",
    "whisper-small",
    "recurrentgemma-9b",
    "qwen2-vl-7b",
    "xlstm-1.3b",
    "deepseek-v2-lite-16b",
    "granite-moe-1b-a400m",
)

_MODULES = {name: name.replace("-", "_").replace(".", "_") for name in ARCHS}


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        known = ", ".join(ARCHS)
        raise KeyError(f"unknown arch '{name}'; known: {known}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def all_configs():
    return {name: get_config(name) for name in ARCHS}
