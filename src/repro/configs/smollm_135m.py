"""SmolLM-135M [hf:HuggingFaceTB/SmolLM-135M]: llama-arch small model."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m",
    family="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    d_ff=1536,
    vocab_size=49152,
    body_pattern=("attn",),
    norm="rmsnorm",
    mlp="swiglu",
    rope_style="rope",
    rope_theta=10000.0,
    tie_embeddings=True,
)
