"""Qwen2.5-3B [hf:Qwen/Qwen2.5-3B]: GQA kv=2, QKV bias."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    family="dense",
    n_layers=36,
    d_model=2048,
    n_heads=16,
    n_kv_heads=2,
    d_ff=11008,
    vocab_size=151936,
    body_pattern=("attn",),
    qkv_bias=True,
    norm="rmsnorm",
    mlp="swiglu",
    rope_style="rope",
    rope_theta=1000000.0,
    tie_embeddings=True,
)
