"""Granite-3.0-1B-A400M [hf:ibm-granite/granite-3.0-1b-a400m-base]:
32 experts top-8, granite multipliers."""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    body_pattern=("moe_attn",),
    norm="rmsnorm",
    mlp="swiglu",
    rope_style="rope",
    rope_theta=10000.0,
    tie_embeddings=True,
    embedding_multiplier=12.0,
    residual_multiplier=0.22,
    attention_multiplier=0.0078125,
    logits_scaling=6.0,
    moe=MoEConfig(
        n_experts=32,
        top_k=8,
        expert_d_ff=512,
        capacity_factor=1.25,
    ),
)
