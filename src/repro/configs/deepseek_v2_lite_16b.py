"""DeepSeek-V2-Lite (16B total) [arXiv:2405.04434]: MLA kv_lora=512,
layer 0 dense (d_ff 10944), layers 1..26 MoE 64 routed top-6 + 2 shared.

Note: the assignment line lists both "64e top-6" and "160 routed"; 160
routed is full V2. We implement the bracketed V2-Lite spec (64 routed).
"""

from repro.models.config import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10944,               # dense layer-0 FFN
    vocab_size=102400,
    head_pattern=("mla_attn",),          # dense first layer
    body_pattern=("mla_moe_attn",),
    n_periods=26,
    norm="rmsnorm",
    mlp="swiglu",
    rope_style="rope",
    rope_theta=10000.0,
    tie_embeddings=False,
    moe=MoEConfig(
        n_experts=64,
        top_k=6,
        expert_d_ff=1408,
        n_shared_experts=2,
        shared_d_ff=2816,
        capacity_factor=1.25,
    ),
    mla=MLAConfig(
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
)
