"""Gemma3-4B [hf:google/gemma-3-4b-pt]: 5:1 local:global, 262k vocab,
qk-norm. 34 layers = 5 periods x (5 local + 1 global) + 4 local tail."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    body_pattern=("local_attn",) * 5 + ("attn",),
    n_periods=5,
    tail_pattern=("local_attn",) * 4,
    local_window=1024,
    qk_norm=True,
    norm="rmsnorm",
    mlp="geglu",
    rope_style="rope",
    rope_theta=1000000.0,
    tie_embeddings=True,
    chunked_ce=512,
)
