"""xLSTM-1.3B [arXiv:2405.04517]: xLSTM[7:1] — 7 mLSTM : 1 sLSTM per
period, 48 blocks, d_ff=0 (blocks are self-contained)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    body_pattern=("mlstm",) * 7 + ("slstm",),
    n_periods=6,
    conv1d_width=4,
    norm="rmsnorm",
    mlp="gelu",
    rope_style="none",
    tie_embeddings=True,
)
