"""Pure-jnp oracle for flash_attention (materialized scores)."""

from __future__ import annotations

import math

import jax.numpy as jnp

NEG_INF = -1e30


def attention(q, k, v, *, causal: bool = True, window: int = 0,
              scale: float | None = None):
    """q: (B, H, S, D); k/v: (B, KH, T, D). Returns (B, H, S, D)."""
    B, H, S, D = q.shape
    KH, T = k.shape[1], k.shape[2]
    group = H // KH
    scale = 1.0 / math.sqrt(D) if scale is None else scale
    qf = q.astype(jnp.float32).reshape(B, KH, group, S, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bkgsd,bktd->bkgst", qf, kf) * scale
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, -1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, -1, keepdims=True), 1e-30)
    o = jnp.einsum("bkgst,bktd->bkgsd", p, vf)
    return o.reshape(B, H, S, D).astype(q.dtype)
