"""Public wrapper for the flash attention kernel.

Model-facing layout is (B, S, H, D) (matching repro.models.attention);
the kernel uses (B, H, S, D). Training gradients use a custom_vjp whose
backward recomputes with the reference (flash-backward kernels are a TPU
follow-up; the forward kernel is the inference hot path).

On non-TPU backends the kernel runs in interpret mode (set
``REPRO_PALLAS_INTERPRET=1`` or pass interpret=True), which is how this
repo validates it on CPU.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as K
from repro.kernels.flash_attention import ref


def _interpret_default() -> bool:
    if os.environ.get("REPRO_PALLAS_INTERPRET"):
        return True
    return jax.default_backend() != "tpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash(q, k, v, causal, window, scale, interpret):
    return K.flash_attention_fwd(q, k, v, causal=causal, window=window,
                                 scale=scale, interpret=interpret)


def _flash_fwd(q, k, v, causal, window, scale, interpret):
    out = _flash(q, k, v, causal, window, scale, interpret)
    return out, (q, k, v)


def _flash_bwd(causal, window, scale, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: ref.attention(q_, k_, v_, causal=causal,
                                         window=window, scale=scale),
        q, k, v)
    return vjp(g)


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: float | None = None, interpret: bool | None = None):
    """q: (B, S, H, D); k/v: (B, T, KH, D). Returns (B, S, H, D)."""
    interpret = _interpret_default() if interpret is None else interpret
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = _flash(qt, kt, vt, causal, window, scale, interpret)
    return jnp.swapaxes(out, 1, 2)
