"""FlashAttention-2 style Pallas TPU kernel.

Layout: q (B, H, S, D), k/v (B, KH, T, D). Grid = (B, H, num_q_blocks,
num_kv_blocks); the trailing grid axis is sequential on TPU, so the
online-softmax state (m, l) and the output accumulator live in VMEM
scratch and are carried across kv blocks. Causal and sliding-window
masks are applied blockwise; fully-masked kv blocks are predicated out
with pl.when (TPU grids cannot skip steps, but the MXU work is skipped).

Block sizes default to (512, 512) and are clamped to the sequence
lengths; D is kept whole (hd <= 256 fits VMEM comfortably:
512*256*4B = 0.5 MB per block).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale: float, causal: bool, window: int, bq: int, bkv: int,
                 num_kv_blocks: int):
    qb = pl.program_id(2)
    kb = pl.program_id(3)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qb * bq
    k_start = kb * bkv

    # blockwise reachability: block is live unless fully masked
    live = True
    if causal:
        live = k_start <= q_start + bq - 1
    if window > 0:
        live = jnp.logical_and(
            live, k_start + bkv - 1 >= q_start - window + 1) \
            if not isinstance(live, bool) else \
            (k_start + bkv - 1 >= q_start - window + 1)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (bkv, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bkv)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        mask = jnp.ones((bq, bkv), jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window > 0:
            mask = jnp.logical_and(mask, q_pos - k_pos < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]  # (bq, 1)
        l_prev = l_scr[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)  # (bq, bkv)
        l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(kb == num_kv_blocks - 1)
    def _finalize():
        l = l_scr[...]
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l, 1e-30)).astype(
            o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True, window: int = 0,
                        scale: float | None = None, block_q: int = 512,
                        block_kv: int = 512, interpret: bool = False):
    """q: (B, H, S, D); k/v: (B, KH, T, D) with H % KH == 0."""
    B, H, S, D = q.shape
    KH, T = k.shape[1], k.shape[2]
    assert H % KH == 0, (H, KH)
    group = H // KH
    scale = 1.0 / math.sqrt(D) if scale is None else scale
    bq = min(block_q, S)
    bkv = min(block_kv, T)
    assert S % bq == 0 and T % bkv == 0, (S, bq, T, bkv)
    num_kv_blocks = T // bkv
    grid = (B, H, S // bq, num_kv_blocks)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window, bq=bq,
        bkv=bkv, num_kv_blocks=num_kv_blocks)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bkv, D),
                         lambda b, h, i, j, group=group: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, bkv, D),
                         lambda b, h, i, j, group=group: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
