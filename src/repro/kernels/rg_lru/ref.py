"""Pure-jnp oracle for the RG-LRU linear scan."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def linear_scan(a, b, h0=None):
    """h_t = a_t h_{t-1} + b_t. a/b: (B, S, C). Returns (y, h_last)."""
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    _, y = jax.lax.associative_scan(combine, (a, b), axis=1)
    return y, y[:, -1]
