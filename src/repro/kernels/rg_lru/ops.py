"""Public wrapper for the RG-LRU scan kernel (grad via oracle VJP)."""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels.rg_lru import kernel as K
from repro.kernels.rg_lru import ref


def _interpret_default() -> bool:
    if os.environ.get("REPRO_PALLAS_INTERPRET"):
        return True
    return jax.default_backend() != "tpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _scan(a, b, h0, interpret):
    y, h_last = K.rg_lru_scan(a, b, h0, interpret=interpret)
    return y, h_last


def _scan_fwd(a, b, h0, interpret):
    return _scan(a, b, h0, interpret), (a, b, h0)


def _scan_bwd(interpret, res, g):
    a, b, h0 = res
    _, vjp = jax.vjp(lambda a_, b_, h_: ref.linear_scan(a_, b_, h_),
                     a, b, h0)
    return vjp(g)


_scan.defvjp(_scan_fwd, _scan_bwd)


def linear_scan(a, b, h0=None, interpret: bool | None = None):
    """a, b: (B, S, C); h0 optional (B, C). Returns (y, h_last)."""
    interpret = _interpret_default() if interpret is None else interpret
    if h0 is None:
        h0 = jnp.zeros((a.shape[0], a.shape[2]), jnp.float32)
    return _scan(a.astype(jnp.float32), b.astype(jnp.float32),
                 h0.astype(jnp.float32), interpret)
