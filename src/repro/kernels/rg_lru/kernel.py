"""Blocked linear-scan Pallas kernel for the RG-LRU recurrence.

  h_t = a_t * h_{t-1} + b_t        (elementwise over channels)

TPU adaptation: grid = (B, C//bc, S//bs). The trailing grid axis is
sequential on TPU, so the hidden state h lives in VMEM scratch and is
carried across time blocks; channels are tiled to the VPU lane width
(bc multiple of 128). Within a block the scan is a fori_loop of
elementwise vector ops — the recurrence is memory-bound, and this tiling
streams a/b through VMEM exactly once.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _lru_kernel(a_ref, b_ref, h0_ref, y_ref, hlast_ref, h_scr, *,
                bs: int, num_s_blocks: int):
    sb = pl.program_id(2)

    @pl.when(sb == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)

    def step(t, h):
        a_t = a_ref[0, t, :].astype(jnp.float32)
        b_t = b_ref[0, t, :].astype(jnp.float32)
        h = a_t * h + b_t
        y_ref[0, t, :] = h.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, bs, step, h_scr[...])
    h_scr[...] = h

    @pl.when(sb == num_s_blocks - 1)
    def _final():
        hlast_ref[0] = h.astype(hlast_ref.dtype)


def rg_lru_scan(a, b, h0, *, block_s: int = 256, block_c: int = 512,
                interpret: bool = False):
    """a, b: (B, S, C) f32; h0: (B, C) f32. Returns (y (B,S,C), h_last)."""
    B, S, C = a.shape
    bs = min(block_s, S)
    bc = min(block_c, C)
    assert S % bs == 0 and C % bc == 0, (S, bs, C, bc)
    grid = (B, C // bc, S // bs)

    kernel = functools.partial(_lru_kernel, bs=bs,
                               num_s_blocks=S // bs)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bs, bc), lambda i, j, s: (i, s, j)),
            pl.BlockSpec((1, bs, bc), lambda i, j, s: (i, s, j)),
            pl.BlockSpec((1, bc), lambda i, j, s: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, bs, bc), lambda i, j, s: (i, s, j)),
            pl.BlockSpec((1, bc), lambda i, j, s: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, C), a.dtype),
            jax.ShapeDtypeStruct((B, C), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bc,), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
