from repro.kernels.rg_lru import ops, ref

__all__ = ["ops", "ref"]
