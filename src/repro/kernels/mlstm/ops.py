"""Public wrapper for the chunkwise mLSTM kernel.

Model layout (B, S, H, hd) + gates (B, S, H) is reshaped to the kernel's
(B*H, S, hd). Gradients fall back to the oracle VJP (a fused backward
kernel is TPU follow-up work). Fresh-state calls only — the model passes
state=None during training; carried state is supported via the oracle.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels.mlstm import kernel as K
from repro.kernels.mlstm import ref


def _interpret_default() -> bool:
    if os.environ.get("REPRO_PALLAS_INTERPRET"):
        return True
    return jax.default_backend() != "tpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _mlstm(q, k, v, log_i, log_f, chunk, interpret):
    return K.mlstm_chunkwise(q, k, v, log_i, log_f, chunk=chunk,
                             interpret=interpret)


def _fwd(q, k, v, log_i, log_f, chunk, interpret):
    out = _mlstm(q, k, v, log_i, log_f, chunk, interpret)
    return out, (q, k, v, log_i, log_f)


def _bwd(chunk, interpret, res, g):
    q, k, v, log_i, log_f = res
    _, vjp = jax.vjp(
        lambda *a: ref.mlstm_chunkwise(*a, chunk=chunk), q, k, v, log_i,
        log_f)
    return vjp(g)


_mlstm.defvjp(_fwd, _bwd)


def mlstm_chunkwise(q, k, v, log_i, log_f, *, chunk: int = 64, state=None,
                    interpret: bool | None = None):
    """Model-layout entry: q/k/v (B,S,H,hd); gates (B,S,H).

    Returns (h (B,S,H,hd), state (C (B,H,hd,hd), n (B,H,hd), m (B,H))).
    """
    if state is not None:
        # carried state (prefill continuation): oracle path
        B, S, H, hd = q.shape
        tr = lambda x: jnp.moveaxis(x, 2, 1).reshape(B * H, S, -1)
        trg = lambda x: jnp.moveaxis(x, 2, 1).reshape(B * H, S)
        Cs, ns, ms = state
        st = (Cs.reshape(B * H, hd, hd), ns.reshape(B * H, hd),
              ms.reshape(B * H))
        h, (C, n, m) = ref.mlstm_chunkwise(
            tr(q), tr(k), tr(v), trg(log_i), trg(log_f), chunk=chunk,
            state=st)
        h = jnp.moveaxis(h.reshape(B, H, S, hd), 1, 2)
        return h, (C.reshape(B, H, hd, hd), n.reshape(B, H, hd),
                   m.reshape(B, H))
    interpret = _interpret_default() if interpret is None else interpret
    B, S, H, hd = q.shape
    tr = lambda x: jnp.moveaxis(x, 2, 1).reshape(B * H, S, -1)
    trg = lambda x: jnp.moveaxis(x, 2, 1).reshape(B * H, S)
    h, (C, n, m) = _mlstm(tr(q), tr(k), tr(v),
                          trg(log_i.astype(jnp.float32)),
                          trg(log_f.astype(jnp.float32)), chunk, interpret)
    h = jnp.moveaxis(h.reshape(B, H, S, hd), 1, 2)
    return h, (C.reshape(B, H, hd, hd), n.reshape(B, H, hd),
               m.reshape(B, H))
