"""Chunkwise mLSTM Pallas TPU kernel.

Grid = (BH, n_chunks); the chunk axis is sequential, so the matrix
memory C (hd x hd), normalizer n and stabilizer m live in VMEM scratch
and are carried across chunks. Within a chunk everything is
MXU-friendly: (L x hd) @ (hd x L) score matmul, decay-masked (L x L)
combine, and two (L x hd) matmuls for the intra/inter contributions.

VMEM budget per step (L=64, hd=1024): q/k/v blocks 3*64*1024*4B = 0.8MB,
C scratch 4MB, score/decay (64x64) negligible — fits the ~16MB VMEM of a
v5e core with headroom for double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mlstm_kernel(q_ref, k_ref, v_ref, li_ref, lf_ref,
                  h_ref, cout_ref, nout_ref, mout_ref,
                  c_scr, n_scr, m_scr, *, L: int, num_chunks: int):
    cb = pl.program_id(1)

    @pl.when(cb == 0)
    def _init():
        c_scr[...] = jnp.zeros_like(c_scr)
        n_scr[...] = jnp.zeros_like(n_scr)
        m_scr[...] = jnp.full_like(m_scr, -1e30)

    q = q_ref[0].astype(jnp.float32)  # (L, hd)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    li = li_ref[0].astype(jnp.float32)  # (L,)
    lf = lf_ref[0].astype(jnp.float32)

    C = c_scr[...]
    n = n_scr[...]  # (1, hd)
    m = m_scr[0, 0]

    b = jnp.cumsum(lf)  # (L,)
    total_f = b[L - 1]
    dmat = b[:, None] - b[None, :] + li[None, :]  # (L, L)
    row = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    dmat = jnp.where(col <= row, dmat, -jnp.inf)
    inter_log = b + m  # (L,)
    m_new = jnp.maximum(inter_log, jnp.max(dmat, axis=1))  # (L,)
    dmat_s = jnp.exp(dmat - m_new[:, None])
    inter_s = jnp.exp(inter_log - m_new)  # (L,)

    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    weighted = scores * dmat_s
    intra = jax.lax.dot_general(
        weighted, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    qC = jax.lax.dot_general(
        q, C, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    num = intra + qC * inter_s[:, None]
    den = (jnp.sum(weighted, axis=1)
           + jnp.sum(q * n, axis=1) * inter_s)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[:, None]
    h_ref[0] = h.astype(h_ref.dtype)

    # ---- state update ----------------------------------------------------
    m_next = jnp.maximum(total_f + m, jnp.max(b + li))
    kdecay = jnp.exp(total_f - b + li - m_next)  # (L,)
    decay_C = jnp.exp(total_f + m - m_next)
    kd = k * kdecay[:, None]
    C_next = decay_C * C + jax.lax.dot_general(
        kd, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    n_next = decay_C * n + jnp.sum(kd, axis=0)[None, :]
    c_scr[...] = C_next
    n_scr[...] = n_next
    m_scr[0, 0] = m_next

    @pl.when(cb == num_chunks - 1)
    def _final():
        cout_ref[0] = C_next
        nout_ref[0] = n_next[0]
        mout_ref[0, 0] = m_next


def mlstm_chunkwise(q, k, v, log_i, log_f, *, chunk: int = 64,
                    interpret: bool = False):
    """q/k/v: (BH, S, hd); gates (BH, S) f32. Returns (h, (C, n, m))."""
    BH, S, hd = q.shape
    L = min(chunk, S)
    assert S % L == 0, (S, L)
    num_chunks = S // L
    grid = (BH, num_chunks)

    kernel = functools.partial(_mlstm_kernel, L=L, num_chunks=num_chunks)
    h, C, n, m = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, L, hd), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, L, hd), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, L, hd), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, L), lambda i, c: (i, c)),
            pl.BlockSpec((1, L), lambda i, c: (i, c)),
        ],
        out_specs=[
            pl.BlockSpec((1, L, hd), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, hd, hd), lambda i, c: (i, 0, 0)),
            pl.BlockSpec((1, hd), lambda i, c: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, c: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
            jax.ShapeDtypeStruct((BH, hd, hd), jnp.float32),
            jax.ShapeDtypeStruct((BH, hd), jnp.float32),
            jax.ShapeDtypeStruct((BH, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((hd, hd), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, log_i, log_f)
    return h, (C, n, m[:, 0])
