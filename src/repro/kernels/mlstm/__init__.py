from repro.kernels.mlstm import ops, ref

__all__ = ["ops", "ref"]
