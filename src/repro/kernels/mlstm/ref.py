"""Pure-jnp oracle for the stabilized chunkwise mLSTM cell.

Layout: q/k/v (BH, S, hd); log_i/log_f (BH, S) float32.
State: (C (BH, hd, hd), n (BH, hd), m (BH,)).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def init_state(bh: int, hd: int):
    return (jnp.zeros((bh, hd, hd), jnp.float32),
            jnp.zeros((bh, hd), jnp.float32),
            jnp.full((bh,), -1e30, jnp.float32))


def mlstm_chunkwise(q, k, v, log_i, log_f, chunk: int = 64, state=None):
    BH, S, hd = q.shape
    if S % chunk != 0:
        chunk = S
    nc = S // chunk
    if state is None:
        state = init_state(BH, hd)

    def resh(x):
        return jnp.moveaxis(x.reshape(BH, nc, chunk, *x.shape[2:]), 1, 0)

    qs, ks, vs = resh(q), resh(k), resh(v)
    lis, lfs = resh(log_i.astype(jnp.float32)), resh(log_f.astype(jnp.float32))

    def body(carry, inp):
        C, n, m = carry
        qc, kc, vc, li, lf = inp  # (BH, L, ...)
        qc32, kc32, vc32 = (x.astype(jnp.float32) for x in (qc, kc, vc))
        b = jnp.cumsum(lf, axis=1)  # (BH, L)
        total_f = b[:, -1]  # (BH,)
        dmat = b[:, :, None] - b[:, None, :] + li[:, None, :]  # (BH, i, j)
        causal = jnp.tril(jnp.ones((qc.shape[1], qc.shape[1]), bool))
        dmat = jnp.where(causal[None], dmat, -jnp.inf)
        inter_log = b + m[:, None]  # (BH, i)
        m_new = jnp.maximum(inter_log, jnp.max(dmat, axis=2))
        dmat_s = jnp.exp(dmat - m_new[:, :, None])
        inter_s = jnp.exp(inter_log - m_new)
        scores = jnp.einsum("bid,bjd->bij", qc32, kc32)
        intra = jnp.einsum("bij,bij,bjd->bid", scores, dmat_s, vc32)
        inter = jnp.einsum("bid,bde->bie", qc32, C) * inter_s[..., None]
        num = intra + inter
        den = (jnp.einsum("bij,bij->bi", scores, dmat_s)
               + jnp.einsum("bid,bd->bi", qc32, n) * inter_s)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]

        m_next = jnp.maximum(total_f + m, jnp.max(b + li, axis=1))
        kdecay = jnp.exp(total_f[:, None] - b + li - m_next[:, None])
        decay_C = jnp.exp(total_f + m - m_next)
        C2 = (decay_C[:, None, None] * C
              + jnp.einsum("bj,bjd,bje->bde", kdecay, kc32, vc32))
        n2 = (decay_C[:, None] * n
              + jnp.einsum("bj,bjd->bd", kdecay, kc32))
        return (C2, n2, m_next), h.astype(q.dtype)

    state, hs = jax.lax.scan(body, state, (qs, ks, vs, lis, lfs))
    h = jnp.moveaxis(hs, 0, 1).reshape(BH, S, hd)
    return h, state
