from repro.kernels.edge_softmax import ops, ref

__all__ = ["ops", "ref"]
