"""Public wrapper for edge_softmax (pads N to a block multiple)."""

from __future__ import annotations

import functools
import math
import os

import jax
import jax.numpy as jnp

from repro.kernels.edge_softmax import kernel as K
from repro.kernels.edge_softmax import ref


def _interpret_default() -> bool:
    if os.environ.get("REPRO_PALLAS_INTERPRET"):
        return True
    return jax.default_backend() != "tpu"


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def _agg(q, k, v, mask, scale, interpret):
    bn = 512
    N = q.shape[0]
    pad = (-N) % min(bn, max(N, 1)) if N % min(bn, N or 1) else 0
    # pad to a block multiple of 128 for small graphs
    blk = min(bn, 1 << max(7, (N - 1).bit_length())) if N else 128
    blk = min(blk, bn)
    pad = (-N) % blk
    if pad:
        q = jnp.pad(q, ((0, pad), (0, 0)))
        k = jnp.pad(k, ((0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, pad), (0, 0), (0, 0)))
        mask = jnp.pad(mask, ((0, pad), (0, 0)))
    out, att = K.edge_softmax_aggregate(q, k, v, mask, scale=scale,
                                        block_n=blk, interpret=interpret)
    return out[:N], att[:N]


def _fwd(q, k, v, mask, scale, interpret):
    return _agg(q, k, v, mask, scale, interpret), (q, k, v, mask)


def _bwd(scale, interpret, res, g):
    q, k, v, mask = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: ref.edge_softmax_aggregate(q_, k_, v_, mask,
                                                      scale), q, k, v)
    dq, dk, dv = vjp(g)
    return dq, dk, dv, None


_agg.defvjp(_fwd, _bwd)


def edge_softmax_aggregate(q, k, v, mask, scale=None,
                           interpret: bool | None = None):
    """q: (N,F); k/v: (N,P,F); mask: (N,P). Returns (out (N,F), att)."""
    F = q.shape[-1]
    scale = 1.0 / math.sqrt(F) if scale is None else scale
    interpret = _interpret_default() if interpret is None else interpret
    return _agg(q, k, v, mask.astype(bool), scale, interpret)
