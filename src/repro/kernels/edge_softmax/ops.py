"""Public wrapper for edge_softmax (pads N to a block multiple).

The custom VJP saves the forward's attention weights as residuals, so
the backward pass is three einsums over (g, att, q, k, v) — the softmax
is never recomputed and the reference forward is never re-run.
"""

from __future__ import annotations

import functools
import math
import os

import jax
import jax.numpy as jnp

from repro.common.bucketing import next_pow2
from repro.kernels.edge_softmax import kernel as K

BLOCK_N = 512


def _interpret_default() -> bool:
    if os.environ.get("REPRO_PALLAS_INTERPRET"):
        return True
    return jax.default_backend() != "tpu"


def _block_for(n: int) -> int:
    """Node-axis block: smallest power of two >= n, in [128, BLOCK_N]."""
    return min(BLOCK_N, next_pow2(n, 128))


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def _agg(q, k, v, mask, scale, interpret):
    N = q.shape[0]
    if N == 0:  # empty graph: nothing to launch
        att_shape = (0,) + q.shape[1:-1] + mask.shape[1:]
        return jnp.zeros_like(q), jnp.zeros(att_shape, jnp.float32)
    blk = _block_for(N)
    pad = (-N) % blk
    if pad:
        padw = lambda a: [(0, pad)] + [(0, 0)] * (a.ndim - 1)
        q = jnp.pad(q, padw(q))
        k = jnp.pad(k, padw(k))
        v = jnp.pad(v, padw(v))
        mask = jnp.pad(mask, padw(mask))
    out, att = K.edge_softmax_aggregate(q, k, v, mask, scale=scale,
                                        block_n=blk, interpret=interpret)
    return out[:N], att[:N]


def _fwd(q, k, v, mask, scale, interpret):
    out, att = _agg(q, k, v, mask, scale, interpret)
    return (out, att), (q, k, v, att)


def _bwd(scale, interpret, res, g):
    q, k, v, att = res
    g_out, g_att = g
    gf = g_out.astype(jnp.float32)
    # d(att): from the aggregate output plus any direct att cotangent
    da = jnp.einsum("nhf,nphf->nhp", gf, v.astype(jnp.float32))
    da = da + g_att.astype(jnp.float32)
    # softmax VJP; att is 0 on masked / fully-masked slots, so ds is too
    ds = att * (da - jnp.sum(att * da, axis=-1, keepdims=True))
    dq = scale * jnp.einsum("nhp,nphf->nhf", ds, k.astype(jnp.float32))
    dk = scale * jnp.einsum("nhp,nhf->nphf", ds, q.astype(jnp.float32))
    dv = jnp.einsum("nhp,nhf->nphf", att, gf)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            None)


_agg.defvjp(_fwd, _bwd)


def edge_softmax_aggregate(q, k, v, mask, scale=None,
                           interpret: bool | None = None):
    """Single-head: q (N, F); k/v (N, P, F) -> (out (N, F), att (N, P)).
    Multi-head: q (N, H, hd); k/v (N, P, H, hd) -> (out (N, H, hd),
    att (N, H, P)). mask: (N, P), shared across heads.
    """
    scale = 1.0 / math.sqrt(q.shape[-1]) if scale is None else scale
    interpret = _interpret_default() if interpret is None else interpret
    single = q.ndim == 2
    if single:
        q, k, v = q[:, None, :], k[:, :, None, :], v[:, :, None, :]
    out, att = _agg(q, k, v, mask.astype(bool), scale, interpret)
    if single:
        return out[:, 0, :], att[:, 0, :]
    return out, att
