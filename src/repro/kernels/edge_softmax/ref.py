"""Pure-jnp oracle for fused edge-softmax neighborhood aggregation.

Perona's benchmark-execution graphs have a fixed in-degree (each node
attends to its P=3 chronological predecessors), so messages are laid out
densely as (N, P, F) with a validity mask — no scatter/gather at the
aggregation site (TPU adaptation of PyG's TransformerConv, DESIGN.md §3).

Both a single-head (q (N, F)) and a multi-head (q (N, H, hd)) layout are
supported; the mask is shared across heads.
"""

from __future__ import annotations

import math

import jax.numpy as jnp

NEG_INF = -1e30


def _multi_head(q, k, v, mask, scale):
    """q: (N, H, hd); k/v: (N, P, H, hd); mask: (N, P) bool."""
    s = jnp.einsum("nhf,nphf->nhp", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    m3 = mask[:, None, :]
    s = jnp.where(m3, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m) * m3
    denom = jnp.sum(e, axis=-1, keepdims=True)
    att = e / jnp.maximum(denom, 1e-30)  # (N, H, P)
    out = jnp.einsum("nhp,nphf->nhf", att, v.astype(jnp.float32))
    return out.astype(q.dtype), att


def edge_softmax_aggregate(q, k, v, mask, scale=None):
    """Single-head: q (N, F); k/v (N, P, F) -> (out (N, F), att (N, P)).
    Multi-head: q (N, H, hd); k/v (N, P, H, hd) -> (out (N, H, hd),
    att (N, H, P)). mask: (N, P) bool, shared across heads.

    out[i] = sum_p softmax_p(q_i . k_ip * scale) * v_ip  (masked),
    att[i] the attention weights. Nodes with no valid neighbor get 0.
    """
    scale = 1.0 / math.sqrt(q.shape[-1]) if scale is None else scale
    if q.ndim == 2:
        out, att = _multi_head(q[:, None, :], k[:, :, None, :],
                               v[:, :, None, :], mask, scale)
        return out[:, 0, :], att[:, 0, :]
    return _multi_head(q, k, v, mask, scale)
