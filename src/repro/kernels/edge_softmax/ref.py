"""Pure-jnp oracle for fused edge-softmax neighborhood aggregation.

Perona's benchmark-execution graphs have a fixed in-degree (each node
attends to its P=3 chronological predecessors), so messages are laid out
densely as (N, P, F) with a validity mask — no scatter/gather at the
aggregation site (TPU adaptation of PyG's TransformerConv, DESIGN.md §3).
"""

from __future__ import annotations

import math

import jax.numpy as jnp

NEG_INF = -1e30


def edge_softmax_aggregate(q, k, v, mask, scale=None):
    """q: (N, F); k/v: (N, P, F); mask: (N, P) bool.

    out[i] = sum_p softmax_p(q_i . k_ip * scale) * v_ip  (masked),
    att[i] the attention weights. Nodes with no valid neighbor get 0.
    """
    N, P, F = k.shape
    scale = 1.0 / math.sqrt(F) if scale is None else scale
    s = jnp.einsum("nf,npf->np", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=1, keepdims=True)
    e = jnp.exp(s - m) * mask
    denom = jnp.sum(e, axis=1, keepdims=True)
    att = e / jnp.maximum(denom, 1e-30)
    out = jnp.einsum("np,npf->nf", att, v.astype(jnp.float32))
    return out.astype(q.dtype), att
