"""Fused edge-softmax aggregation Pallas kernel (Perona GNN).

Grid tiles (node blocks x heads); P (in-degree, 3) and hd (per-head
code width) stay whole per block: block VMEM = bn * (2P+1) * hd * 4B
~ 0.5 MB for bn=512, hd=64. The score reduction, masked softmax over P,
and weighted combine are all fused in one VMEM round trip (VPU work; no
MXU needed at hd<=128). The heads axis lives in the grid, so multi-head
attention needs no host-side per-head loop and no (hN*N, P, hd)
reshape/transpose of the operands.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, att_ref, *, scale: float):
    q = q_ref[:, 0, :].astype(jnp.float32)  # (bn, hd)
    k = k_ref[:, :, 0, :].astype(jnp.float32)  # (bn, P, hd)
    v = v_ref[:, :, 0, :].astype(jnp.float32)
    mask = mask_ref[...] != 0  # (bn, P)
    s = jnp.sum(q[:, None, :] * k, axis=-1) * scale  # (bn, P)
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=1, keepdims=True)
    e = jnp.exp(s - m) * mask.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(e, axis=1, keepdims=True), 1e-30)
    att = e / denom
    o_ref[:, 0, :] = jnp.sum(att[:, :, None] * v, axis=1).astype(o_ref.dtype)
    att_ref[:, 0, :] = att.astype(att_ref.dtype)


def edge_softmax_aggregate(q, k, v, mask, *, scale: float,
                           block_n: int = 512, interpret: bool = False):
    """q: (N, H, hd); k/v: (N, P, H, hd); mask: (N, P) (bool or int).

    Returns (out (N, H, hd), att (N, H, P)). The mask is shared across
    heads; each (node-block, head) pair is one grid step.
    """
    N, P, H, hd = k.shape
    bn = min(block_n, N)
    assert N % bn == 0, (N, bn)
    grid = (N // bn, H)
    kernel = functools.partial(_kernel, scale=scale)
    out, att = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, 1, hd), lambda i, h: (i, h, 0)),
            pl.BlockSpec((bn, P, 1, hd), lambda i, h: (i, 0, h, 0)),
            pl.BlockSpec((bn, P, 1, hd), lambda i, h: (i, 0, h, 0)),
            pl.BlockSpec((bn, P), lambda i, h: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn, 1, hd), lambda i, h: (i, h, 0)),
            pl.BlockSpec((bn, 1, P), lambda i, h: (i, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, H, hd), q.dtype),
            jax.ShapeDtypeStruct((N, H, P), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, mask.astype(jnp.int32))
    return out, att
