"""Fused edge-softmax aggregation Pallas kernel (Perona GNN).

Grid tiles the node axis; P (in-degree, 3) and F (code width) stay whole
per block: block VMEM = bn * (P+1) * F * 4B ~ 0.5 MB for bn=512, F=64.
The score reduction, masked softmax over P, and weighted combine are all
fused in one VMEM round trip (VPU work; no MXU needed at F<=128).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, att_ref, *, scale: float):
    q = q_ref[...].astype(jnp.float32)  # (bn, F)
    k = k_ref[...].astype(jnp.float32)  # (bn, P, F)
    v = v_ref[...].astype(jnp.float32)
    mask = mask_ref[...] != 0  # (bn, P)
    s = jnp.sum(q[:, None, :] * k, axis=-1) * scale  # (bn, P)
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=1, keepdims=True)
    e = jnp.exp(s - m) * mask.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(e, axis=1, keepdims=True), 1e-30)
    att = e / denom
    o_ref[...] = jnp.sum(att[:, :, None] * v, axis=1).astype(o_ref.dtype)
    att_ref[...] = att.astype(att_ref.dtype)


def edge_softmax_aggregate(q, k, v, mask, *, scale: float,
                           block_n: int = 512, interpret: bool = False):
    """q: (N, F); k/v: (N, P, F); mask: (N, P) (bool or int)."""
    N, P, F = k.shape
    bn = min(block_n, N)
    assert N % bn == 0, (N, bn)
    grid = (N // bn,)
    kernel = functools.partial(_kernel, scale=scale)
    out, att = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, F), lambda i: (i, 0)),
            pl.BlockSpec((bn, P, F), lambda i: (i, 0, 0)),
            pl.BlockSpec((bn, P, F), lambda i: (i, 0, 0)),
            pl.BlockSpec((bn, P), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn, F), lambda i: (i, 0)),
            pl.BlockSpec((bn, P), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, F), q.dtype),
            jax.ShapeDtypeStruct((N, P), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, mask.astype(jnp.int32))
    return out, att
