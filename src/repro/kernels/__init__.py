"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel subpackage has: kernel.py (pl.pallas_call + BlockSpec VMEM
tiling), ops.py (jit'd public wrapper, custom_vjp where training needs
gradients), ref.py (pure-jnp oracle used by the allclose test sweeps).

Kernels lower for TPU; on this CPU container they are validated in
interpret mode (pl.pallas_call(..., interpret=True)) against ref.py.

Kernels:
  flash_attention — causal / sliding-window / GQA online-softmax attention
  rg_lru          — Griffin RG-LRU blocked linear scan
  mlstm           — xLSTM chunkwise matrix-memory cell
  edge_softmax    — Perona GNN fused edge-softmax + neighborhood aggregation
"""
