import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Dry-run of the PAPER'S OWN model on the production meshes: Perona
# fingerprint training at fleet scale. At 1000+ nodes the fingerprint DB
# is genuinely large (every node x 6 benchmark types x a rolling history
# of executions), so the Perona train step itself must shard: nodes are
# data-parallel over the full mesh; the 3-predecessor neighbor gathers
# stay chain-local and lower to collectives where chains cross shards.
#
#   PYTHONPATH=src python -m repro.launch.dryrun_perona --mesh multi

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.model import PeronaConfig, PeronaModel
from repro.launch import roofline as rl
from repro.launch.mesh import data_axes, make_production_mesh
from repro.optim.adamw import AdamW


# fleet-scale fingerprint batch: 2048 nodes x 6 types x 16-run history
FLEET_N = 2048 * 6 * 16  # 196,608 executions
FEATURE_DIM = 94  # 88 selected metrics + 6 type one-hot (§IV-C fit)
EDGE_DIM = 12


def abstract_batch(n: int):
    sds = jax.ShapeDtypeStruct
    return {
        "x": sds((n, FEATURE_DIM), jnp.float32),
        "type_id": sds((n,), jnp.int32),
        "anomaly": sds((n,), jnp.int32),
        "nbr": sds((n, 3), jnp.int32),
        "nbr_mask": sds((n, 3), jnp.bool_),
        "edge": sds((n, 3, EDGE_DIM), jnp.float32),
        "norm_gt": sds((n,), jnp.float32),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    d_ax = data_axes(mesh) + ("model",)  # pure DP over every axis
    cfg = PeronaConfig(feature_dim=FEATURE_DIM, edge_dim=EDGE_DIM)
    model = PeronaModel(cfg)
    opt = AdamW(lr=3e-3)

    aparams = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    astate = opt.abstract_state(aparams)
    batch = abstract_batch(FLEET_N)
    rep = NamedSharding(mesh, P())
    node_sh = NamedSharding(mesh, P(d_ax))

    def shard_of(leaf):
        return NamedSharding(mesh, P(d_ax, *([None] * (len(leaf.shape) - 1))))

    bshard = jax.tree_util.tree_map(shard_of, batch)
    pshard = jax.tree_util.tree_map(lambda _: rep, aparams)
    oshard = jax.tree_util.tree_map(lambda _: rep, astate)

    def train_step(params, state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(params, batch, jax.random.PRNGKey(0))
        params, state, om = opt.update(grads, state, params)
        return params, state, loss

    record = {"arch": "perona-fingerprint", "shape": f"fleet_{FLEET_N}",
              "mesh": args.mesh, "status": "ok"}
    try:
        t0 = time.time()
        with mesh:
            lowered = jax.jit(
                train_step,
                in_shardings=(pshard, oshard, bshard)).lower(
                    aparams, astate, batch)
            compiled = lowered.compile()
        ca = rl.cost_analysis_dict(compiled)
        coll = rl.collective_bytes(compiled.as_text())
        flops = float(ca.get("flops", 0.0))
        record.update({
            "compile_s": round(time.time() - t0, 2),
            "flops_per_device": flops,
            "bytes_per_device": float(ca.get("bytes accessed", 0.0)),
            "collective_bytes_per_device": coll,
            "roofline": rl.roofline_terms(
                flops, float(ca.get("bytes accessed", 0.0)),
                sum(coll.values())),
        })
    except Exception as e:  # noqa: BLE001
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc(limit=20)

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    path = out / f"perona-fingerprint--fleet--{args.mesh}.json"
    path.write_text(json.dumps(record, indent=2))
    print(json.dumps({k: v for k, v in record.items()
                      if k != "traceback"}, indent=2))
    if record["status"] != "ok":
        print(record.get("traceback", ""))
        raise SystemExit(1)


if __name__ == "__main__":
    main()
