"""Sharding-rule resolution for params, optimizer state, caches, inputs.

Params carry PartitionSpecs from init; this module resolves them against
a concrete mesh (divisibility fallbacks: a dim whose size does not divide
its assigned axis is replicated), derives KV-cache shardings (KV-head
sharding when divisible, sequence sharding otherwise), and batch input
shardings over the (pod, data) axes.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import axis_size, data_axes
from repro.models.config import ModelConfig


def _axis_entry_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, str):
        return axis_size(mesh, entry)
    return axis_size(mesh, tuple(entry))


def resolve_spec(mesh: Mesh, spec: P, shape: Tuple[int, ...]) -> P:
    """Drop sharding on dims that don't divide the assigned axis size."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, entry in zip(shape, parts):
        sz = _axis_entry_size(mesh, entry)
        out.append(entry if (sz > 1 and dim % sz == 0) or sz == 1 else None)
    return P(*out)


def strip_model_axis(spec_tree):
    """Replace every "model" entry with None (DP-only layout: the model
    axis of the mesh is used as extra data parallelism instead of TP —
    the right layout for models too small to shard 16-way)."""

    def one(spec):
        if not isinstance(spec, P):
            return spec
        out = []
        for e in spec:
            if e == "model":
                out.append(None)
            elif isinstance(e, (tuple, list)):
                kept = tuple(a for a in e if a != "model")
                if not kept:
                    out.append(None)
                elif len(kept) == 1:
                    out.append(kept[0])
                else:
                    out.append(kept)
            else:
                out.append(e)
        return P(*out)

    return jax.tree_util.tree_map(one, spec_tree,
                                  is_leaf=lambda x: isinstance(x, P))


def param_shardings(mesh: Mesh, abstract_params, specs):
    def one(aps, spec):
        if not isinstance(spec, P):
            spec = P()
        return NamedSharding(mesh, resolve_spec(mesh, spec, aps.shape))

    return jax.tree_util.tree_map(
        one, abstract_params, specs,
        is_leaf=lambda x: isinstance(x, (P, jax.ShapeDtypeStruct)))


def tree_shardings(mesh: Mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Cache shardings
# ---------------------------------------------------------------------------

def cache_spec_tree(mesh: Mesh, abstract_cache, batch: int,
                    d_ax=None, model_size=None):
    """Heuristic spec per cache leaf based on its shape.

    Rules (post any leading n_periods stacking axis):
      * a dim equal to the global batch size shards over (pod, data);
      * among remaining dims, prefer sharding the largest dim divisible
        by the model-axis size over "model" (KV heads / sequence /
        feature width all resolve naturally);
      * everything else replicates.
    """
    d_ax = data_axes(mesh) if d_ax is None else d_ax
    d_sz = axis_size(mesh, d_ax)
    m_sz = axis_size(mesh, "model") if model_size is None else model_size

    def one(leaf):
        shape = leaf.shape
        parts = [None] * len(shape)
        # batch dim: first dim whose size == batch (skip tiny stacking dims)
        bdim = None
        for i, dim in enumerate(shape):
            if dim == batch:
                bdim = i
                break
        if bdim is not None and d_sz > 1 and batch % d_sz == 0:
            parts[bdim] = d_ax if len(d_ax) > 1 else d_ax[0]
        if m_sz > 1:
            cands = [
                (dim, i) for i, dim in enumerate(shape)
                if i != bdim and parts[i] is None and dim % m_sz == 0
                and dim >= m_sz
            ]
            if cands:
                _, idx = max(cands)
                parts[idx] = "model"
        return P(*parts)

    return jax.tree_util.tree_map(one, abstract_cache)


def cache_shardings(mesh: Mesh, abstract_cache, batch: int, d_ax=None,
                    model_size=None):
    return tree_shardings(mesh, cache_spec_tree(mesh, abstract_cache,
                                                batch, d_ax, model_size))


# ---------------------------------------------------------------------------
# Batch input shardings
# ---------------------------------------------------------------------------

def batch_spec(mesh: Mesh, batch_tree, global_batch: int, d_ax=None):
    d_ax = data_axes(mesh) if d_ax is None else d_ax
    d_sz = axis_size(mesh, d_ax)
    entry = d_ax if len(d_ax) > 1 else d_ax[0]

    def one(leaf):
        shape = leaf.shape
        parts = [None] * len(shape)
        for i, dim in enumerate(shape):
            if dim == global_batch and d_sz > 1 and dim % d_sz == 0:
                parts[i] = entry
                break
        return P(*parts)

    return jax.tree_util.tree_map(one, batch_tree)


def batch_shardings(mesh: Mesh, batch_tree, global_batch: int, d_ax=None):
    return tree_shardings(mesh, batch_spec(mesh, batch_tree, global_batch,
                                           d_ax))
