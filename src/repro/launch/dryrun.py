import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# The two lines above MUST stay the first statements of this module: jax
# locks the device count at first init, and the production meshes need
# 512 placeholder host devices.
#
# Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.
#
#   PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b \
#       --shape train_4k --mesh single --out artifacts/dryrun
#
# Per cell this performs:
#   1. a full-depth SCAN-over-layers compile  -> proves the production
#      config lowers+compiles on the mesh; memory analysis.
#   2. two shallow UNROLLED compiles (1 and 2 body periods) -> exact
#      per-period flops/bytes/collective bytes (XLA cost analysis counts
#      while bodies once, so the scanned module cannot be used for
#      costs); linear extrapolation to full depth.
# --all sweeps the assigned matrix; long_500k cells for non-sub-quadratic
# archs are recorded as skipped (DESIGN.md §4). Multi-pod runs step 1
# only (the roofline table is single-pod by design).

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import ARCHS, get_config
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import lowerable
from repro.models.config import SHAPES_BY_NAME, shapes_for
from repro.models.model_zoo import build_model


def with_depth(cfg, n_periods: int):
    n_layers = (len(cfg.head_pattern) + n_periods * len(cfg.body_pattern)
                + len(cfg.tail_pattern))
    return dataclasses.replace(cfg, n_periods=n_periods, n_layers=n_layers)


def _compile(cfg, shape, mesh, layout: str = "2d", donate: bool = False):
    model = build_model(cfg)
    fn, in_shardings, args = lowerable(model, shape, mesh, layout=layout)
    donate_argnums = (3,) if (donate and shape.kind == "decode") else ()
    t0 = time.time()
    with mesh:
        lowered = jax.jit(fn, in_shardings=in_shardings,
                          donate_argnums=donate_argnums).lower(*args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
    return compiled, round(t1 - t0, 2), round(t2 - t1, 2)


def _costs(compiled):
    ca = rl.cost_analysis_dict(compiled)
    coll = rl.collective_bytes(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll": coll,
    }


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: Path,
             overrides=None, tag: str = "", costs: bool = True,
             layout: str = "2d", donate: bool = False) -> dict:
    cfg = get_config(arch)
    if overrides:
        moe_over = (overrides or {}).pop("moe", None)
        cfg = dataclasses.replace(cfg, **overrides)
        if moe_over:
            cfg = dataclasses.replace(
                cfg, moe=dataclasses.replace(cfg.moe, **moe_over))
    shape = SHAPES_BY_NAME[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))

    record = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "mesh_shape": dict(mesh.shape), "tag": tag, "status": "ok",
        "layout": layout, "donate": donate,
        "overrides": {k: str(v) for k, v in (overrides or {}).items()},
    }
    try:
        # -- step 1: full-depth scan compile (production config) ---------
        compiled, lower_s, compile_s = _compile(
            dataclasses.replace(cfg, scan_layers=True), shape, mesh,
            layout, donate)
        record["lower_s"] = lower_s
        record["compile_s"] = compile_s
        record["memory_analysis"] = _mem_dict(compiled.memory_analysis())
        del compiled

        if costs:
            # -- step 2: shallow unrolled compiles for exact costs -------
            p1, p2 = 1, 2
            c1, *_ = _compile(
                with_depth(dataclasses.replace(cfg, scan_layers=False), p1),
                shape, mesh, layout, donate)
            k1 = _costs(c1)
            del c1
            c2, *_ = _compile(
                with_depth(dataclasses.replace(cfg, scan_layers=False), p2),
                shape, mesh, layout, donate)
            k2 = _costs(c2)
            del c2
            n = cfg.n_periods
            flops = k2["flops"] + (n - p2) * (k2["flops"] - k1["flops"])
            bytes_ = k2["bytes"] + (n - p2) * (k2["bytes"] - k1["bytes"])
            coll = {
                op: int(k2["coll"][op]
                        + (n - p2) * (k2["coll"][op] - k1["coll"][op]))
                for op in k2["coll"]
            }
            terms = rl.roofline_terms(flops, bytes_, sum(coll.values()))
            n_chips = 1
            for v in mesh.shape.values():
                n_chips *= v
            mflops = rl.model_flops(cfg, shape)
            record.update({
                "flops_per_device": flops,
                "bytes_per_device": bytes_,
                "collective_bytes_per_device": coll,
                "collective_bytes_total": sum(coll.values()),
                "roofline": terms,
                "model_flops_global": mflops,
                "model_flops_per_device": mflops / n_chips,
                "useful_flops_ratio": (mflops / n_chips / flops)
                if flops else None,
                "depth_probe": {"p1": k1, "p2": k2, "n_periods": n},
            })
    except Exception as e:  # noqa: BLE001 - record the failure verbatim
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc(limit=20)

    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = f"-{tag}" if tag else ""
    path = out_dir / f"{arch}--{shape_name}--{mesh_kind}{suffix}.json"
    path.write_text(json.dumps(record, indent=2))
    return record


def _mem_dict(mem) -> dict:
    if mem is None:
        return {}
    out = {}
    for field in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes", "peak_memory_in_bytes"):
        if hasattr(mem, field):
            try:
                out[field] = int(getattr(mem, field))
            except (TypeError, ValueError):
                pass
    return out


def cell_matrix():
    cells = []
    for arch in ARCHS:
        cfg = get_config(arch)
        active = {s.name for s in shapes_for(cfg)}
        for sname in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            cells.append((arch, sname, sname in active))
    return cells


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--layout", default="2d", choices=["2d", "dp"])
    ap.add_argument("--donate", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (e.g. remat=dots, "
                         "kv_quant=true, moe.impl=einsum)")
    args = ap.parse_args()
    out_dir = Path(args.out)

    overrides = {}
    for kv in args.set:
        key, val = kv.split("=", 1)
        if val.lower() in ("true", "false"):
            val = val.lower() == "true"
        elif val.isdigit():
            val = int(val)
        if key.startswith("moe."):
            overrides.setdefault("moe", {})[key[4:]] = val
        else:
            overrides[key] = val

    if args.all:
        for arch, sname, active in cell_matrix():
            for mesh_kind in ("single", "multi"):
                suffix = f"-{args.tag}" if args.tag else ""
                path = out_dir / f"{arch}--{sname}--{mesh_kind}{suffix}.json"
                if path.exists():
                    continue
                if not active:
                    out_dir.mkdir(parents=True, exist_ok=True)
                    path.write_text(json.dumps({
                        "arch": arch, "shape": sname, "mesh": mesh_kind,
                        "status": "skipped",
                        "reason": "full-attention arch: no sub-quadratic "
                                  "path for 500k decode (DESIGN.md §4)",
                    }, indent=2))
                    continue
                t0 = time.time()
                rec = run_cell(arch, sname, mesh_kind, out_dir,
                               costs=(mesh_kind == "single"),
                               overrides=dict(overrides) or None,
                               tag=args.tag, layout=args.layout,
                               donate=args.donate)
                print(f"{arch} {sname} {mesh_kind}: {rec['status']} "
                      f"({time.time() - t0:.0f}s)", flush=True)
        return

    rec = run_cell(args.arch, args.shape, args.mesh, out_dir, tag=args.tag,
                   overrides=overrides or None, layout=args.layout,
                   donate=args.donate)
    print(json.dumps({k: v for k, v in rec.items()
                      if k not in ("traceback",)}, indent=2))
    if rec["status"] != "ok":
        print(rec.get("traceback", ""))
        raise SystemExit(1)


if __name__ == "__main__":
    main()
