"""Serving driver: batched prefill + decode with slot-based batching.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m \
        --requests 16 --max-new 32 --scale small

A fixed pool of batch slots serves a request queue continuous-batching
style: finished sequences release their slot, the next request prefills
into it (single-sequence prefill), and all occupied slots decode in
lockstep with one jit'd decode_step per token. The same serve_step is
what the decode_32k / long_500k dry-run cells lower onto the production
meshes.

``--fingerprint`` serves Perona fingerprint scoring instead: rounds of
benchmark executions stream through one shared
:class:`repro.fleet.FleetScoringService` — the watchdog submits
per-node requests, the service coalesces them into shape-bucketed
micro-batches and dispatches one sharded call per flush (the same
scoring path `--fleet` exercises), amortizing one compile across
rounds:

    PYTHONPATH=src python -m repro.launch.serve --fingerprint \
        --rounds 20

``--fleet`` runs the raw fleet service loop (no watchdog): per-node
requests are queued and flushed in micro-batches, and the run reports
requests/s, dispatch counts and the store-backed drift summary. Pair
with ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to see the
request batch sharded across 8 virtual CPU devices:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m repro.launch.serve --fleet \
        --nodes 16 --rounds 10

``--daemon`` runs the streaming ingestion daemon over the same
service: seeded per-node telemetry events (bursty arrivals) stream
through the bounded staging ring with deadline/row-bucket flushes, and
the run reports sustained req/s, p99 queue latency and the rolling
drift flags. Add ``--faults`` to route the stream through the seeded
fault injector (dropout, delays, duplicates, reordering, NaN/Inf
corruption, bursts, one genuinely degraded node) and watch the
backpressure/quarantine counters and the degradation flag:

    PYTHONPATH=src python -m repro.launch.serve --daemon --faults \
        --nodes 6 --rounds 12

``--daemon --modelplane`` additionally runs the model management
plane over the stream: the run bootstraps the trained parameters as
version 1, canaries + hot-promotes an identical candidate mid-stream
(zero-downtime swap at a flush boundary), then force-promotes a
NaN-poisoned candidate and lets the post-promote health watch roll it
back automatically — promote/rollback instants land on the exported
timeline. ``--registry PATH`` persists the version registry;
``--modelplane-cmd {status,list,promote,rollback}`` (with
``--registry``, plus ``--version N`` for promote) performs offline
registry operations and exits:

    PYTHONPATH=src python -m repro.launch.serve --daemon \
        --modelplane --faults --nodes 3 --rounds 6
    PYTHONPATH=src python -m repro.launch.serve \
        --modelplane-cmd list --registry /tmp/perona-registry

Every mode accepts ``--timeline PATH`` (export the run's span
recording as Chrome trace-event JSON — open it in
https://ui.perfetto.dev) and ``--metrics`` (periodic + final text
dump of the process metrics registry; ``--metrics-interval`` seconds
between dumps). ``--daemon`` exports the daemon's own virtual-clock
tracer; the other modes export the process-wide wall-clock tracer.
"""

from __future__ import annotations

import argparse
import dataclasses
import threading
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs import get_config
from repro.models.model_zoo import build_model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new: int
    tokens: Optional[List[int]] = None


class SlotServer:
    """Slot-based continuous batching on top of prefill/decode_step."""

    def __init__(self, model, params, *, n_slots: int = 4,
                 max_len: int = 512):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.cache = model.init_cache(n_slots, max_len)
        self.pos = np.zeros(n_slots, np.int64)
        self.remaining = np.zeros(n_slots, np.int64)
        self.live = np.zeros(n_slots, bool)
        self.request_of_slot: List[Optional[Request]] = [None] * n_slots
        self.last_token = np.zeros(n_slots, np.int64)
        self._decode = jax.jit(model.decode_step)

    def _prefill_slot(self, slot: int, request: Request):
        """Prefill one sequence into one slot via a batched prefill with
        only this slot's row active (slot-wise cache merge)."""
        S = len(request.prompt)
        toks = np.zeros((self.n_slots, S), np.int32)
        toks[slot] = request.prompt
        logits, new_cache = self.model.prefill(
            self.params, self.cache, tokens=jnp.asarray(toks))
        # merge only this slot's rows into the live cache
        self.cache = merge_cache_slot(self.cache, new_cache, slot)
        request.tokens = []
        nxt = int(np.asarray(jnp.argmax(logits[slot])))
        request.tokens.append(nxt)
        self.last_token[slot] = nxt
        self.pos[slot] = S
        self.remaining[slot] = request.max_new - 1
        self.live[slot] = True
        self.request_of_slot[slot] = request

    def step(self):
        toks = jnp.asarray(self.last_token[:, None].astype(np.int32))
        pos = jnp.asarray(self.pos.astype(np.int32))
        logits, self.cache = self._decode(self.params, toks, pos,
                                          self.cache)
        nxt = np.asarray(jnp.argmax(logits, -1))
        for s in range(self.n_slots):
            if not self.live[s]:
                continue
            req = self.request_of_slot[s]
            req.tokens.append(int(nxt[s]))
            self.last_token[s] = int(nxt[s])
            self.pos[s] += 1
            self.remaining[s] -= 1
            if self.remaining[s] <= 0 or self.pos[s] >= self.max_len - 1:
                self.live[s] = False
                self.request_of_slot[s] = None

    def serve(self, requests: List[Request]) -> dict:
        queue = list(requests)
        done: List[Request] = []
        steps = 0
        while queue or self.live.any():
            for s in range(self.n_slots):
                if not self.live[s] and queue:
                    self._prefill_slot(s, queue.pop(0))
            before = [self.request_of_slot[s] for s in range(self.n_slots)]
            self.step()
            steps += 1
            for s, req in enumerate(before):
                if req is not None and self.request_of_slot[s] is None:
                    done.append(req)
        return {"completed": done, "decode_steps": steps}


def merge_cache_slot(cache_old, cache_new, slot: int):
    """Copy only ``slot``'s rows from a freshly prefilled cache into the
    live cache. Batch axis is 0 for head/tail group caches and 1 for
    body caches (leading n_periods stacking axis)."""

    def merge_group(old_tree, new_tree, batch_axis):
        def one(o, n):
            if o.ndim <= batch_axis or o.shape[batch_axis] <= slot:
                return o  # sentinel / non-batched leaf
            sel = (slice(None),) * batch_axis + (slot,)
            return o.at[sel].set(n[sel])

        return jax.tree_util.tree_map(one, old_tree, new_tree)

    out = {}
    for group in cache_old:
        ax = 1 if group == "body" else 0
        out[group] = merge_group(cache_old[group], cache_new[group], ax)
    return out


def _trained_perona(machines, runs_per_type: int, seed: int):
    """Acquire + fit + train one small Perona model for the serving
    loops (shared by --fingerprint and --fleet)."""
    from repro.core.graph_data import build_graphs
    from repro.core.model import PeronaConfig, PeronaModel
    from repro.core.preprocess import Preprocessor
    from repro.core.trainer import train_perona
    from repro.fingerprint.runner import SuiteRunner

    runner = SuiteRunner(seed=seed)
    frame = runner.run_frame(machines, runs_per_type=runs_per_type,
                             stress_fraction=0.2)
    pre = Preprocessor().fit(frame)
    batch = build_graphs(frame, pre)
    cfg = PeronaConfig(feature_dim=pre.feature_dim,
                       edge_dim=batch.edge.shape[-1])
    model = PeronaModel(cfg)
    res = train_perona(model, batch, epochs=40, seed=seed)
    return runner, frame, pre, model, res.params


def serve_fingerprints(rounds: int, runs_per_type: int = 2,
                       seed: int = 0) -> dict:
    """Fingerprint-scoring service loop: train a small Perona model,
    then stream watchdog rounds through one FleetScoringService (the
    watchdog and the fleet entrypoint share this scoring path)."""
    from repro.fleet import FleetScoringService
    from repro.runtime.watchdog import PeronaWatchdog

    machines = {f"serve-{i}": "e2-medium" for i in range(3)}
    runner, frame, pre, model, params = _trained_perona(
        machines, runs_per_type=40, seed=seed)

    service = FleetScoringService(model, params, pre,
                                  context_per_chain=40)
    wd = PeronaWatchdog(model, params, pre, service=service,
                        history_per_chain=40)
    wd.history = frame
    t0 = time.time()
    scored = 0
    for k in range(rounds):
        round_frame = runner.run_frame(machines,
                                       runs_per_type=runs_per_type,
                                       t_offset=(k + 1) * 86400.0)
        wd.observe(round_frame)
        scored += len(round_frame)
    dt = time.time() - t0
    return {"rounds": rounds, "scored": scored, "seconds": dt,
            "traces": service.trace_count,
            "stats": service.stats,
            "excluded": wd.excluded_nodes()}


def serve_fleet(nodes: int = 16, rounds: int = 10,
                runs_per_type: int = 1, seed: int = 0) -> dict:
    """Raw fleet-service loop: per-node requests micro-batched through
    the sharded scoring path, with store-backed drift analytics."""
    from repro.fleet import FleetScoringService, drift_report

    machines = {f"fleet-{i}": "e2-medium" for i in range(nodes)}
    runner, frame, pre, model, params = _trained_perona(
        machines, runs_per_type=10, seed=seed)

    service = FleetScoringService(model, params, pre,
                                  context_per_chain=16)
    service.seed_history(frame)
    t0 = time.time()
    for k in range(rounds):
        round_frame = runner.run_frame(machines,
                                       runs_per_type=runs_per_type,
                                       t_offset=(k + 1) * 86400.0)
        service.score_round(round_frame)
    dt = time.time() - t0
    report = drift_report(service.store)
    worst = max(report.values(), key=lambda d: d.anomaly_ewma,
                default=None)
    return {"rounds": rounds, "seconds": dt, "stats": service.stats,
            "drift_nodes": len(report),
            "worst_node": None if worst is None else
            (worst.node, round(worst.anomaly_ewma, 3))}


def serve_daemon(nodes: int = 6, rounds: int = 12,
                 runs_per_type: int = 1, seed: int = 0,
                 faults: bool = False, modelplane: bool = False,
                 registry_dir: Optional[str] = None) -> dict:
    """Streaming ingestion loop: telemetry events through the bounded
    staging ring of an :class:`repro.fleet.IngestionDaemon`, optionally
    perturbed by the seeded fault injector (``faults=True`` also marks
    one node genuinely degraded halfway through the run). With
    ``modelplane=True`` the run exercises the full model lifecycle on
    the live stream: canary + hot-promote of an identical candidate,
    then a forced promote of a NaN-poisoned candidate that the health
    watch rolls back automatically."""
    from repro.fleet import (FaultPlan, FleetScoringService,
                             IngestionDaemon, ModelPlane,
                             fleet_telemetry, inject_faults)

    machines = {f"fleet-{i}": "e2-medium" for i in range(nodes)}
    _, frame, pre, model, params = _trained_perona(
        machines, runs_per_type=10, seed=seed)

    service = FleetScoringService(model, params, pre,
                                  context_per_chain=16)
    service.seed_history(frame)
    daemon = IngestionDaemon(service, capacity_rows=64 * nodes,
                             flush_interval=0.5,
                             min_flush_gap=0.05)
    plane = None
    if modelplane:
        if registry_dir is None:
            import tempfile
            registry_dir = tempfile.mkdtemp(prefix="perona-registry-")
        # generous health shift: only the NaN candidate below should
        # trip the watch, not the injected degraded node's drift
        plane = ModelPlane(service, registry_dir, daemon=daemon,
                           canary_flushes=1, watch_flushes=3,
                           min_health_shift=0.5)
        plane.bootstrap(params)
    degraded_node = f"fleet-{nodes - 1}"
    events = fleet_telemetry(
        machines, rounds=rounds, runs_per_type=runs_per_type,
        seed=seed + 1, interval=1.0, jitter=0.25,
        degraded={degraded_node: rounds // 2} if faults else None)
    fault_counts = None
    if faults:
        events, log = inject_faults(events, FaultPlan(
            seed=seed + 2, dropout=0.05, delay=0.2, duplicate=0.2,
            reorder=0.2, corrupt=0.15, burst=0.2, burst_window=3.0))
        fault_counts = log.counts()
    if plane is None:
        daemon.run(events)
    else:
        third = max(len(events) // 3, 1)
        daemon.run(events[:third], drain=False)
        # identical params: divergence-free canary -> zero-downtime
        # promote at a flush boundary mid-stream
        plane.submit_candidate(params, source="cli-demo")
        daemon.run(events[third:2 * third], drain=False)
        bad = jax.tree_util.tree_map(
            lambda x: np.asarray(x) * np.nan, params)
        vid_bad = plane.registry.save_version(bad,
                                              source="cli-demo-bad")
        plane.promote(vid_bad, force=True)
        daemon.run(events[2 * third:], drain=True)
    st = daemon.stats()
    return {"rounds": rounds, "stats": st,
            "faults": fault_counts,
            "degraded_node": degraded_node if faults else None,
            "flagged": daemon.flagged_nodes(),
            "modelplane": None if plane is None else plane.status(),
            "registry": registry_dir,
            "versions": (None if plane is None
                         else plane.registry.list_versions()),
            # the daemon's private virtual-clock tracer: --timeline
            # exports THIS recording in daemon mode, so flush spans
            # and ladder instants sit on the same clock as the
            # reported queue latencies
            "tracer": daemon.tracer}


def _start_metrics_dumper(interval: float) -> threading.Event:
    """Background thread printing the metrics registry every
    ``interval`` seconds until the returned event is set."""
    stop = threading.Event()

    def loop():
        while not stop.wait(interval):
            text = obs.registry().render()
            if text:
                print(f"[metrics @ {time.strftime('%H:%M:%S')}]\n"
                      f"{text}", flush=True)

    threading.Thread(target=loop, name="perona-metrics",
                     daemon=True).start()
    return stop


def _export_timeline(path: str,
                     tracer: Optional[obs.Tracer] = None) -> None:
    obs.write_chrome_trace(path, tracer=tracer)
    summary = obs.validate_chrome_trace_file(path)
    print(f"[timeline] wrote {path}: {summary['events']} events, "
          f"{summary['spans']} spans on {summary['threads']} "
          "thread track(s) — load in https://ui.perfetto.dev")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--scale", choices=["full", "small"], default="small")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fingerprint", action="store_true",
                    help="serve Perona fingerprint scoring rounds")
    ap.add_argument("--fleet", action="store_true",
                    help="raw fleet service loop (micro-batched, "
                         "sharded scoring + drift report)")
    ap.add_argument("--daemon", action="store_true",
                    help="streaming ingestion daemon over the fleet "
                         "service (bounded staging, deadline/row "
                         "flushes, rolling drift)")
    ap.add_argument("--faults", action="store_true",
                    help="with --daemon: inject seeded stream faults "
                         "+ one genuinely degraded node")
    ap.add_argument("--modelplane", action="store_true",
                    help="with --daemon: run the model management "
                         "plane demo (canary -> hot promote -> NaN "
                         "candidate -> automatic rollback)")
    ap.add_argument("--registry", metavar="PATH", default=None,
                    help="model registry directory (persisted across "
                         "runs; default: a temp dir)")
    ap.add_argument("--modelplane-cmd", default=None,
                    choices=["status", "list", "promote", "rollback"],
                    help="offline registry operation (requires "
                         "--registry) and exit")
    ap.add_argument("--version", type=int, default=None,
                    help="version id for --modelplane-cmd promote")
    ap.add_argument("--nodes", type=int, default=16,
                    help="fleet size for --fleet")
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--timeline", metavar="PATH", default=None,
                    help="export the run's span recording as Chrome "
                         "trace-event JSON (perfetto-loadable)")
    ap.add_argument("--metrics", action="store_true",
                    help="dump the metrics registry periodically and "
                         "at exit")
    ap.add_argument("--metrics-interval", type=float, default=10.0,
                    help="seconds between --metrics dumps")
    args = ap.parse_args()

    dumper = (_start_metrics_dumper(args.metrics_interval)
              if args.metrics else None)
    try:
        tracer = _run(args)
    finally:
        if dumper is not None:
            dumper.set()
        if args.metrics:
            text = obs.registry().render()
            if text:
                print(f"[metrics final]\n{text}", flush=True)
    if args.timeline:
        _export_timeline(args.timeline, tracer=tracer)


def _modelplane_cmd(args) -> None:
    """Offline registry operations: inspect or re-point the version
    registry without a live service (a daemon started later against
    the same ``--registry`` loads the incumbent this selects)."""
    from repro.fleet import ModelRegistry

    if args.registry is None:
        raise SystemExit("--modelplane-cmd requires --registry PATH")
    reg = ModelRegistry(args.registry)
    cmd = args.modelplane_cmd
    if cmd == "status":
        print(f"[modelplane] incumbent=v{reg.incumbent} "
              f"previous=v{reg.previous} "
              f"versions={len(reg.list_versions())}")
    elif cmd == "list":
        for e in reg.list_versions():
            v = e["verdict"]
            line = (f"  v{e['version']:<3} {e['status']:<12} "
                    f"source={e['source']}")
            if e["tags"]:
                line += f" tags={','.join(e['tags'])}"
            if v is not None:
                line += (" canary="
                         + ("pass" if v["passed"] else
                            "fail:" + ",".join(v["failed_checks"])))
            print(line)
    elif cmd == "promote":
        if args.version is None:
            raise SystemExit("promote requires --version N")
        reg.set_incumbent(args.version)
        print(f"[modelplane] incumbent=v{reg.incumbent} "
              f"(previous=v{reg.previous})")
    elif cmd == "rollback":
        prev = reg.previous
        if prev is None:
            raise SystemExit("no previous version to roll back to")
        cur = reg.incumbent
        reg.set_incumbent(prev)
        if cur is not None:
            reg.set_status(cur, "rolled_back")
        print(f"[modelplane] rolled back v{cur} -> incumbent "
              f"v{reg.incumbent}")


def _run(args) -> Optional[obs.Tracer]:
    """Dispatch one serving mode; returns the tracer whose recording
    ``--timeline`` should export (None -> the process-wide tracer)."""
    if args.modelplane_cmd:
        _modelplane_cmd(args)
        return None

    if args.fingerprint:
        out = serve_fingerprints(args.rounds, seed=args.seed)
        print(f"[serve-fp] {out['rounds']} rounds, {out['scored']} "
              f"executions, {out['seconds']:.2f}s "
              f"({out['scored'] / max(out['seconds'], 1e-9):.0f} exec/s), "
              f"{out['traces']} compiles, excluded={out['excluded']}")
        return None

    if args.daemon:
        out = serve_daemon(args.nodes, args.rounds, seed=args.seed,
                           faults=args.faults,
                           modelplane=args.modelplane,
                           registry_dir=args.registry)
        st = out["stats"]
        svc = st["service"]
        req_s = st["events_seen"] / max(st["run_wall_s"], 1e-9)
        print(f"[serve-daemon] {out['rounds']} rounds, "
              f"{st['events_seen']} events ({st['rows_staged_total']} "
              f"rows), {req_s:.1f} sustained req/s, "
              f"p99 queue latency {st['latency_p99']:.3f}s, "
              f"peak staging {st['peak_staged_rows']}/"
              f"{st['capacity_rows']} rows")
        print(f"[serve-daemon] flushes: {st['deadline_flushes']} "
              f"deadline / {st['row_trigger_flushes']} row-trigger / "
              f"{st['forced_flushes']} forced / "
              f"{st['drain_flushes']} drain; backpressure: "
              f"{st['shed_rows']} shed rows, "
              f"{st['degraded_flushes']} degraded flushes "
              f"({st['degrade_unscored_rows']} sampled-out rows); "
              f"dedup dropped {st['duplicates_dropped']}; "
              f"quarantined {svc['quarantined_rows']} rows")
        if out["faults"] is not None:
            print(f"[serve-daemon] injected faults: {out['faults']}; "
                  f"degraded node {out['degraded_node']} -> "
                  f"flagged={out['flagged']}")
        if out["modelplane"] is not None:
            mp = out["modelplane"]
            print(f"[modelplane] registry={out['registry']} "
                  f"incumbent=v{mp['incumbent']} "
                  f"phase={mp['phase']}; "
                  f"promotions={mp['promotions']} "
                  f"rollbacks={mp['rollbacks']} "
                  f"canary={mp['canary_pass']}/"
                  f"{mp['canary_pass'] + mp['canary_fail']} passed, "
                  f"{mp['shadow_flushes']} shadow flushes, "
                  f"{mp['repaired_rows']} rows repaired")
            for e in out["versions"]:
                print(f"[modelplane]   v{e['version']} "
                      f"{e['status']} ({e['source']})")
        return out["tracer"]

    if args.fleet:
        out = serve_fleet(args.nodes, args.rounds, seed=args.seed)
        s = out["stats"]
        print(f"[serve-fleet] {out['rounds']} rounds, "
              f"{s['requests_served']} requests, {s['rows_scored']} "
              f"rows, {s['dispatches']} dispatches on {s['devices']} "
              f"device(s), {s['traces']} compiles, "
              f"{s['requests_per_s']:.0f} req/s; "
              f"drift tracked for {out['drift_nodes']} nodes, "
              f"worst={out['worst_node']}")
        return None

    cfg = get_config(args.arch)
    if args.scale == "small":
        cfg = cfg.scaled_down(max_seq=args.max_len)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))

    rng = np.random.default_rng(args.seed)
    requests = [
        Request(rid=i,
                prompt=rng.integers(0, cfg.vocab_size,
                                    rng.integers(4, 17)).astype(np.int32),
                max_new=args.max_new)
        for i in range(args.requests)
    ]
    server = SlotServer(model, params, n_slots=args.slots,
                        max_len=args.max_len)
    t0 = time.time()
    with obs.span("slots.serve", args={"requests": len(requests),
                                       "slots": args.slots}):
        out = server.serve(requests)
    dt = time.time() - t0
    n_tokens = sum(len(r.tokens) for r in out["completed"])
    print(f"[serve] {len(out['completed'])} requests, {n_tokens} tokens, "
          f"{out['decode_steps']} decode steps, {dt:.1f}s "
          f"({n_tokens/max(dt,1e-9):.1f} tok/s)")
    return None


if __name__ == "__main__":
    main()
