"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module constant) so that
importing this module never touches jax device state. The single-pod
mesh is 16x16 = 256 chips (TPU v5e pod); multi-pod adds a leading "pod"
axis (2 pods = 512 chips, pod axis mapped onto DCN).
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import numpy as np


def _make_mesh(shape, axes, devices):
    """jax.make_mesh across jax versions: ``axis_types`` exists only on
    newer releases (and older ones default to Auto anyway)."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, devices=devices,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes, devices=devices)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devices = jax.devices()
    if len(devices) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, found {len(devices)}; "
            "the dry-run launcher must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "any jax import")
    return _make_mesh(shape, axes, devices[:need])


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for tests (requires >= n_data*n_model host devices)."""
    need = n_data * n_model
    devices = jax.devices()[:need]
    return _make_mesh((n_data, n_model), ("data", "model"), devices)


def data_axes(mesh) -> Tuple[str, ...]:
    """The batch-parallel axes of a mesh (pod-major when present)."""
    names = mesh.axis_names
    return tuple(a for a in names if a in ("pod", "data"))


def axis_size(mesh, names) -> int:
    if isinstance(names, str):
        names = (names,)
    s = 1
    for n in names:
        if n in mesh.axis_names:
            s *= mesh.shape[n]
    return s
