"""Roofline-term extraction from a compiled dry-run artifact.

Three terms (seconds), per device, TPU v5e constants:

  compute    = HLO_FLOPs / peak_FLOPs            (197 TFLOP/s bf16)
  memory     = HLO_bytes / HBM_bw                (819 GB/s)
  collective = collective_bytes / link_bw        (~50 GB/s per ICI link)

``cost_analysis`` of the partitioned module reports per-device FLOPs and
bytes. Collective bytes are parsed from the post-optimization HLO text:
the summed operand sizes of all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute ops (per-device shard shapes).
"""

from __future__ import annotations

import re
from typing import Dict, List, Tuple

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
LINK_BW = 50e9  # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)
# post-optimization HLO: "%name = f32[8,512,576]{2,1,0} all-gather(%op), ..."
# (operands carry no type annotations, so sizes come from the RESULT
# shape + replica_groups)
_OP_LINE_RE = re.compile(
    r"=\s*((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\])\S*)\s+"
    r"(" + "|".join(_COLLECTIVE_OPS) + r")(-start|-done)?\("
)
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _result_bytes(result: str) -> int:
    return sum(_shape_bytes(m.group(1), m.group(2))
               for m in _SHAPE_RE.finditer(result))


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 2


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """``Compiled.cost_analysis()`` returns a dict on new jax and a
    one-element list of dicts on older releases — normalize to a dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        out: Dict[str, float] = {}
        for entry in ca:
            for k, v in entry.items():
                out[k] = out.get(k, 0.0) + float(v)
        return out
    return dict(ca)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-opcode *wire* bytes per device (ring-model) of collectives.

    all-gather: (g-1)/g * result  received per device
    all-reduce: 2*(g-1)/g * operand (reduce-scatter + all-gather phases)
    reduce-scatter: (g-1)/g * operand  (operand = result * g)
    all-to-all: (g-1)/g * result
    collective-permute: result
    -done ops are skipped (their -start pair is counted).
    """
    out: Dict[str, int] = {op: 0 for op in _COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        m = _OP_LINE_RE.search(line)
        if not m:
            continue
        result, op, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":
            continue
        rb = _result_bytes(result)
        g = _group_size(line)
        if g <= 1:
            continue
        if op == "all-gather":
            wire = rb * (g - 1) // g
        elif op == "all-reduce":
            wire = 2 * rb * (g - 1) // g
        elif op == "reduce-scatter":
            wire = rb * (g - 1)  # operand = result * g
        elif op == "all-to-all":
            wire = rb * (g - 1) // g
        else:  # collective-permute
            wire = rb
        out[op] += wire
    return out


def roofline_terms(flops: float, bytes_accessed: float,
                   coll_bytes: int) -> Dict[str, float]:
    compute = flops / PEAK_FLOPS
    memory = bytes_accessed / HBM_BW
    collective = coll_bytes / LINK_BW
    terms = {"compute_s": compute, "memory_s": memory,
             "collective_s": collective}
    dom = max(terms, key=terms.get)
    terms["bottleneck"] = dom.replace("_s", "")
    total = max(compute, memory, collective)
    terms["step_time_lower_bound_s"] = total
    return terms


def model_flops(cfg, shape) -> float:
    """6*N*D (dense) or 6*N_active*D (MoE) useful-model FLOPs for the cell.

    For decode cells D = global_batch tokens (one step); for train /
    prefill D = global_batch * seq_len. Training counts fwd+bwd (6N);
    inference counts 2N.
    """
    n_active = active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch


def active_params(cfg) -> float:
    """Active (per-token) parameter count from the config, analytically."""
    d, V = cfg.d_model, cfg.vocab_size
    total = V * d  # embedding (tied head counted once for compute)
    if not cfg.tie_embeddings:
        total += V * d
    for kind in cfg.layer_kinds:
        total += _layer_params(cfg, kind, active_only=True)
    return float(total)


def _layer_params(cfg, kind: str, active_only: bool = False) -> float:
    d, hd = cfg.d_model, cfg.head_dim
    H, KH = cfg.n_heads, cfg.n_kv_heads
    p = 0.0
    if kind in ("attn", "local_attn", "enc_attn", "moe_attn", "dense_attn",
                "xattn"):
        p += d * H * hd + 2 * d * KH * hd + H * hd * d
        if kind == "xattn":
            p += d * H * hd + 2 * d * KH * hd + H * hd * d
    elif kind in ("mla_attn", "mla_moe_attn"):
        m = cfg.mla
        qk = m.qk_nope_head_dim + m.qk_rope_head_dim
        p += d * H * qk
        p += d * (m.kv_lora_rank + m.qk_rope_head_dim)
        p += m.kv_lora_rank * H * (m.qk_nope_head_dim + m.v_head_dim)
        p += H * m.v_head_dim * d
    elif kind == "rg_lru":
        lw = cfg.lru_width
        p += 2 * d * lw + lw * d  # branches + out
        p += 4 * lw  # conv
        p += 2 * lw * lw / cfg.n_heads  # block-diag gates
    elif kind == "mlstm":
        di = 2 * d
        p += d * 2 * di + di * d  # up/down
        p += 3 * di * di / cfg.n_heads  # q,k,v block-diag
        p += 2 * di * cfg.n_heads + 4 * di
    elif kind == "slstm":
        p += 4 * d * d + 4 * d * d / cfg.n_heads
        p += (4 * d // 3) * d * 3  # geglu ffn
    if kind in ("moe_attn", "mla_moe_attn"):
        moe = cfg.moe
        per_expert = 3 * d * moe.expert_d_ff
        n_live = moe.top_k if active_only else moe.n_experts
        p += n_live * per_expert
        p += d * moe.n_experts  # router
        if moe.n_shared_experts:
            p += 3 * d * (moe.shared_d_ff or
                          moe.n_shared_experts * moe.expert_d_ff)
    elif kind in ("attn", "local_attn", "enc_attn", "dense_attn", "xattn"):
        mult = 3 if cfg.mlp in ("swiglu", "geglu") else 2
        p += mult * d * cfg.d_ff
    elif kind == "mla_attn":
        p += 3 * d * cfg.d_ff
    return p
