"""Training driver: Perona-aware fault-tolerant LM training.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 200 --batch 8 --seq 256 --scale small

Flow (the production story of DESIGN.md §2):
  1. fingerprint the cluster hosts with the standardized suite and rank
     them (Perona) — degraded hosts are excluded before mesh build;
  2. build the (data, model) mesh from surviving hosts;
  3. run the fault-tolerant step loop (checkpoint/restart, straggler
     monitor routed through the Perona watchdog);
  4. deterministic data pipeline (batch = f(seed, step)) makes restarts
     exactly-once.

On this CPU container the mesh is 1 device and hosts are virtual; the
same driver lowers unchanged onto the production meshes (dry-run proves
it for every assigned architecture).
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from pathlib import Path

import jax
import numpy as np

from repro.checkpointing.manager import CheckpointManager
from repro.configs import get_config
from repro.core.graph_data import build_graphs, chronological_split
from repro.core.model import PeronaConfig, PeronaModel
from repro.core.preprocess import Preprocessor
from repro.core.ranking import aspect_scores, rank_machines
from repro.core.trainer import batch_to_jnp, train_perona
from repro.data.tokens import TokenPipeline
from repro.fingerprint.runner import SuiteRunner
from repro.models.model_zoo import build_model
from repro.optim.adamw import AdamW
from repro.optim.schedule import cosine_schedule
from repro.runtime.fault import FailureInjector, TrainingRuntime
from repro.runtime.straggler import StragglerMonitor
from repro.runtime.watchdog import PeronaWatchdog


def fingerprint_cluster(machines, *, seed=0, epochs=40, runs_per_type=8):
    """Rank cluster nodes with Perona; returns (watchdog, ranked_nodes)."""
    runner = SuiteRunner(seed=seed)
    records = runner.run(machines, runs_per_type=runs_per_type)
    train_r, val_r, _ = chronological_split(records, (0.7, 0.3, 0.0))
    pre = Preprocessor().fit(train_r)
    tb, vb = build_graphs(train_r, pre), build_graphs(val_r, pre)
    pcfg = PeronaConfig(feature_dim=pre.feature_dim,
                        edge_dim=tb.edge.shape[-1])
    pmodel = PeronaModel(pcfg)
    res = train_perona(pmodel, tb, vb, epochs=epochs, seed=seed)
    full = build_graphs(records, pre)
    out = pmodel.forward(res.params, batch_to_jnp(full), train=False)
    scores = aspect_scores(np.asarray(out["codes"]),
                           [r.benchmark_type for r in records],
                           [r.machine for r in records])
    ranked = rank_machines(scores)
    watchdog = PeronaWatchdog(pmodel, res.params, pre)
    watchdog.history = list(records)
    return watchdog, ranked, runner


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--scale", choices=["full", "small"], default="small")
    ap.add_argument("--ckpt-dir", default="artifacts/ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=20)
    ap.add_argument("--hosts", type=int, default=4)
    ap.add_argument("--fail-at", type=int, default=0,
                    help="inject a host failure at this step (0 = none)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.scale == "small":
        cfg = cfg.scaled_down(max_seq=args.seq)
    model = build_model(cfg)

    # --- 1. Perona: fingerprint + rank the cluster ----------------------
    machines = {f"host-{i}": "n2-standard-4" for i in range(args.hosts)}
    t0 = time.time()
    watchdog, ranked, runner = fingerprint_cluster(machines, seed=args.seed)
    print(f"[perona] cluster ranked in {time.time()-t0:.1f}s: {ranked}")

    # --- 2/3. fault-tolerant training loop ------------------------------
    opt = AdamW(lr=cosine_schedule(args.lr, 10, args.steps))
    pipeline = TokenPipeline(cfg.vocab_size, args.seq, args.batch,
                             seed=args.seed)

    def init_state(hosts):
        params = model.init(jax.random.PRNGKey(args.seed))
        return {"params": params, "opt": opt.init(params)}

    @jax.jit
    def _step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.loss, has_aux=True)(params, batch)
        params, opt_state, om = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    def train_step(state, batch, hosts):
        params, opt_state, loss = _step(state["params"], state["opt"],
                                        batch)
        return {"params": params, "opt": opt_state}, {"loss": float(loss)}

    injector = FailureInjector(
        {args.fail_at: ["host-1"]} if args.fail_at else None)
    rt = TrainingRuntime(
        hosts=list(machines), train_step=train_step, init_state=init_state,
        pipeline=pipeline,
        ckpt=CheckpointManager(Path(args.ckpt_dir) / args.arch),
        checkpoint_every=args.checkpoint_every,
        failure_injector=injector, watchdog=watchdog, suite_runner=runner,
        machines=machines, straggler_monitor=StragglerMonitor())
    result = rt.run(args.steps)
    losses = result["losses"]
    print(f"[train] steps={len(losses)} loss {losses[0]:.3f} -> "
          f"{np.mean(losses[-5:]):.3f}; restarts={result['restarts']}; "
          f"hosts={result['final_hosts']}")
    for ev in result["events"]:
        print(f"[event] step={ev.step} {ev.kind}: {ev.detail}")


if __name__ == "__main__":
    main()
