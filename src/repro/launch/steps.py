"""Step factories: train_step / prefill_step / decode_step with shardings.

These are the functions the dry-run lowers and the drivers execute. Each
factory returns (fn, in_shardings, arg_shapes) ready for
``jax.jit(fn, in_shardings=...).lower(*arg_shapes)``.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch import sharding as shd
from repro.launch.mesh import axis_size, data_axes
from repro.models import nn
from repro.models.config import ModelConfig, ShapeConfig
from repro.models.model_zoo import Model, build_model
from repro.optim.adamw import AdamW, OptState, opt_state_specs


def configure_axes(mesh: Mesh, layout: str = "2d"):
    """Map logical axes onto the mesh. layout="dp" folds the model axis
    into data parallelism (for models too small to TP-shard)."""
    d_ax = data_axes(mesh)
    if layout == "dp":
        d_ax = d_ax + ("model",)
        nn.set_axis_map({"data": d_ax, "model": None})
    else:
        nn.set_axis_map({"data": d_ax if len(d_ax) > 1 else d_ax[0],
                         "model": "model"})
    return d_ax


def make_train_step(model: Model, optimizer: AdamW,
                    compute_dtype: Optional[str] = "bfloat16"):
    """compute_dtype="bfloat16": master-weight mixed precision — the
    loss sees bf16 params, so activations AND the implicit data-parallel
    gradient all-reduce run in bf16 (half the wire bytes); the optimizer
    updates the f32 master copies."""
    from repro.common.tree import tree_cast

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            pc = tree_cast(p, jnp.bfloat16) \
                if compute_dtype == "bfloat16" else p
            return model.loss(pc, batch)

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        new_params, new_state, om = optimizer.update(grads, opt_state, params)
        metrics = dict(metrics)
        metrics.update(om)
        metrics["loss"] = loss
        return new_params, new_state, metrics

    return train_step


def make_prefill_step(model: Model):
    def prefill_step(params, cache, batch):
        return model.prefill(params, cache, **batch)

    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, tokens, pos, cache):
        return model.decode_step(params, tokens, pos, cache)

    return decode_step


def lowerable(model: Model, shape: ShapeConfig, mesh: Mesh,
              optimizer: Optional[AdamW] = None, layout: str = "2d",
              donate_cache: bool = False):
    """Build (fn, in_shardings, args[, donate]) for a cell.

    layout="dp" folds the mesh's model axis into data parallelism
    (strip TP from every spec); donate_cache marks the decode cache for
    buffer donation (in-place KV update on TPU).
    """
    d_ax = configure_axes(mesh, layout)
    cfg = model.cfg
    aparams = model.abstract_params()
    pspecs = model.param_specs()
    m_sz = None
    if layout == "dp":
        pspecs = shd.strip_model_axis(pspecs)
        m_sz = 1
    pshard = shd.param_shardings(mesh, aparams, pspecs)
    inputs = model.input_specs(shape)

    if shape.kind == "train":
        optimizer = optimizer or AdamW()
        astate = optimizer.abstract_state(aparams)
        ospecs = opt_state_specs(
            pspecs, aparams, zero1=True,
            data_axis=d_ax if len(d_ax) > 1 else d_ax[0],
            data_size=axis_size(mesh, d_ax))
        oshard = OptState(
            m=shd.param_shardings(mesh, astate.m, ospecs.m),
            v=shd.param_shardings(mesh, astate.v, ospecs.v),
            step=NamedSharding(mesh, P()))
        bshard = shd.batch_shardings(mesh, inputs["batch"],
                                     shape.global_batch, d_ax)
        fn = make_train_step(model, optimizer)
        args = (aparams, astate, inputs["batch"])
        in_shardings = (pshard, oshard, bshard)
        return fn, in_shardings, args

    if shape.kind == "prefill":
        cshard = shd.cache_shardings(mesh, inputs["cache"],
                                     shape.global_batch, d_ax, m_sz)
        bshard = shd.batch_shardings(mesh, inputs["batch"],
                                     shape.global_batch, d_ax)
        fn = make_prefill_step(model)
        args = (aparams, inputs["cache"], inputs["batch"])
        in_shardings = (pshard, cshard, bshard)
        return fn, in_shardings, args

    # decode
    cshard = shd.cache_shardings(mesh, inputs["cache"], shape.global_batch,
                                 d_ax, m_sz)
    tshard = shd.batch_shardings(
        mesh, {"tokens": inputs["tokens"], "pos": inputs["pos"]},
        shape.global_batch, d_ax)
    fn = make_decode_step(model)
    args = (aparams, inputs["tokens"], inputs["pos"], inputs["cache"])
    in_shardings = (pshard, tshard["tokens"], tshard["pos"], cshard)
    return fn, in_shardings, args
