"""Shape-bucketing primitive shared by every pow2-padding site.

One policy, three consumers: ``serving.FingerprintEngine`` (row
buckets), ``tuning.hpo`` (vmapped trial-axis buckets) and
``kernels/edge_softmax`` (node-axis blocks, additionally capped).
"""

from __future__ import annotations


def next_pow2(n: int, floor: int = 1) -> int:
    """Smallest power of two >= max(n, floor). ``floor`` must itself be
    a power of two."""
    return max(floor, 1 << max(n - 1, 0).bit_length())
