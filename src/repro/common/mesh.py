"""1-D device-mesh plumbing shared by every shard_map consumer.

Extracted from ``fleet.shard.ShardedScorer`` so the fleet scorer, the
fleet service's request stacking and the optimizer's sharded replay
engine share one policy:

- :func:`pow2_devices` — the largest power-of-two prefix of a device
  list (a pow2 mesh keeps pow2-padded batch axes evenly divisible);
- :func:`build_mesh` — a 1-D ``jax.sharding.Mesh`` over that prefix;
- :func:`shard_size` — the padded batch-axis length for a mesh: the
  smallest power of two that is >= the row count, >= ``floor`` and
  divisible by the device count;
- :func:`pad_lanes` / :func:`stack_padded` — build
  the padded (donatable) batch buffers;
- :func:`axis_specs` / :func:`shard_map_1d` — version-compatible
  ``shard_map`` wrapping with leading-axis partition specs.

Every consumer partitions along an *independent-rows* axis only
(scoring requests, BO lanes), so sharded outputs are bit-identical to
their single-device counterparts — asserted under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` by
``tests/test_fleet.py`` and ``tests/test_optimizer.py``.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.common.bucketing import next_pow2


def pow2_devices(devices: Optional[Sequence] = None) -> List:
    """Largest power-of-two prefix of ``devices`` (default: all local
    devices)."""
    if devices is None:
        import jax

        devices = jax.devices()
    devices = list(devices)
    n = 1
    while n * 2 <= len(devices):
        n *= 2
    return devices[:n]


def build_mesh(axis: str, devices: Optional[Sequence] = None):
    """1-D mesh named ``axis`` over the pow2 prefix of ``devices``."""
    from jax.sharding import Mesh

    return Mesh(np.asarray(pow2_devices(devices)), (axis,))


def shard_size(n: int, n_devices: int = 1, floor: int = 1) -> int:
    """Padded batch-axis length: smallest power of two >= ``n`` that is
    also >= ``floor`` and divisible by the (pow2) device count."""
    return next_pow2(n, max(floor, n_devices, 1))


def pad_lanes(a: np.ndarray, size: int) -> np.ndarray:
    """Pad axis 0 to ``size`` rows by repeating row 0 — for batch axes
    whose padding must stay numerically well-formed (e.g. GP lane
    tables, where zero rows would produce degenerate kernels). Padded
    rows are masked out / sliced off by the caller."""
    if len(a) == size:
        return a
    reps = np.repeat(a[:1], size - len(a), axis=0)
    return np.concatenate([a, reps], axis=0)


def stack_padded(inputs: Sequence[Dict[str, np.ndarray]],
                 size: int) -> Dict[str, np.ndarray]:
    """Stack per-request input dicts along a new leading axis of
    ``size`` rows (zero rows past ``len(inputs)``) — the donatable
    stacked buffer a sharded dispatch consumes."""
    first = inputs[0]
    out = {k: np.zeros((size,) + v.shape, v.dtype)
           for k, v in first.items()}
    for r, d in enumerate(inputs):
        for k, v in d.items():
            out[k][r] = v
    return out


def axis_specs(axis: str, n_batched: int, n_const: int = 0):
    """``n_const`` replicated specs followed by ``n_batched``
    leading-axis-partitioned specs."""
    from jax.sharding import PartitionSpec as P

    return (P(),) * n_const + (P(axis),) * n_batched


def shard_map_1d(fn, mesh, in_specs, out_specs):
    """Version-compatible ``shard_map``: the stable ``jax.shard_map``
    when available, the experimental module otherwise; replication
    checking disabled where supported (the batched buffers are donated
    and never replicated)."""
    try:  # stable API (newer jax)
        from jax import shard_map
    except ImportError:  # jax <= 0.4/0.5
        from jax.experimental.shard_map import shard_map

    kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    try:
        return shard_map(fn, check_rep=False, **kw)
    except TypeError:  # newer jax dropped/renamed check_rep
        return shard_map(fn, **kw)
