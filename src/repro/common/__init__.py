"""Shared low-level utilities: pytree helpers, registries, logging."""

from repro.common.bucketing import next_pow2
from repro.common.tree import (
    tree_zeros_like,
    tree_add,
    tree_scale,
    tree_global_norm,
    tree_size,
    tree_bytes,
)
from repro.common.registry import Registry

__all__ = [
    "next_pow2",
    "tree_zeros_like",
    "tree_add",
    "tree_scale",
    "tree_global_norm",
    "tree_size",
    "tree_bytes",
    "Registry",
]
