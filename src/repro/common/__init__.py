"""Shared low-level utilities: pytree helpers, registries, logging."""

from repro.common.tree import (
    tree_zeros_like,
    tree_add,
    tree_scale,
    tree_global_norm,
    tree_size,
    tree_bytes,
)
from repro.common.registry import Registry

__all__ = [
    "tree_zeros_like",
    "tree_add",
    "tree_scale",
    "tree_global_norm",
    "tree_size",
    "tree_bytes",
    "Registry",
]
