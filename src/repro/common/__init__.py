"""Shared low-level utilities: pytree helpers, registries, logging,
shape bucketing and 1-D device-mesh plumbing."""

from repro.common.bucketing import next_pow2
from repro.common.mesh import (
    axis_specs,
    build_mesh,
    pad_lanes,
    pow2_devices,
    shard_map_1d,
    shard_size,
    stack_padded,
)
from repro.common.tree import (
    tree_zeros_like,
    tree_add,
    tree_scale,
    tree_global_norm,
    tree_size,
    tree_bytes,
)
from repro.common.registry import Registry

__all__ = [
    "next_pow2",
    "axis_specs",
    "build_mesh",
    "pad_lanes",
    "pow2_devices",
    "shard_map_1d",
    "shard_size",
    "stack_padded",
    "tree_zeros_like",
    "tree_add",
    "tree_scale",
    "tree_global_norm",
    "tree_size",
    "tree_bytes",
    "Registry",
]
