"""Counter-based RNG streams: order-independent, placement-independent
draws shared by the host simulators and the device replay program.

Every stochastic quantity of the scenario stack is a *pure function* of
a fold-in chain over ``jax.random``'s counter-based threefry generator:

    value = f(fold_in(fold_in(root(seed), stream_tag), id0, id1, ...))

No hidden sequential stream state means no call-order dependence: the
draw a (workload, configuration) cell gets is the same whether it is
queried first or last, from the host reference tuner or from inside the
compiled replay program, eagerly or under ``jit``/``vmap``/``shard_map``
(threefry is deterministic across those execution contexts; asserted by
tests/test_seeded_rng.py).

The host-side fingerprint simulators are numpy-based; for them
:func:`folded_generator` derives an independent ``np.random.Generator``
from a hashable path (ints and strings), so per-group draws are a pure
function of ``(seed, round, benchmark_type, machine_type)`` rather than
a position in one shared stream.
"""

from __future__ import annotations

import hashlib
from typing import Tuple, Union

import numpy as np

# fold_in stream tags: one per stochastic quantity, so streams never
# collide even for equal entity ids
# stream tags pick the realization; values are arbitrary but fixed —
# bumping one re-rolls every draw downstream of that stream
STREAM_WORKLOAD_PARAMS = 31  # scout workload latent demand vectors
STREAM_CONTENTION = 32  # scout per-(workload, config) contention noise
STREAM_ARRIVALS = 33  # fleet telemetry arrival-process jitter
STREAM_FAULTS = 34  # fleet fault-injection decisions (fleet.faults)
STREAM_RETRY = 35  # scorer retry-backoff jitter (fleet.service)


def root_key(seed: int):
    """The raw threefry root key for a dataset seed."""
    import jax

    return jax.random.PRNGKey(seed)


def stream_key(seed: int, stream_tag: int):
    """``fold_in(root(seed), stream_tag)`` as a host uint32 array —
    the per-quantity key shipped to device programs."""
    import jax

    return np.asarray(jax.random.fold_in(root_key(seed), stream_tag))


# --------------------------------------------------------------- device
def lognormal_noise_row(key_stream, wid, uids, scale):
    """Contention-noise factors ``exp(scale * N(0,1))`` for one
    workload over a vector of config uids, each drawn from
    ``fold_in(fold_in(key_stream, wid), uid)``.

    Pure jnp — callable on host (eager) and inside jit/vmapped/sharded
    programs with bit-identical float64 results. ``key_stream`` is the
    uint32 stream key, ``wid`` a scalar workload id, ``uids`` an int
    vector of config uids.
    """
    import jax
    import jax.numpy as jnp

    key_w = jax.random.fold_in(key_stream, wid)

    def cell(uid):
        k = jax.random.fold_in(key_w, uid)
        return jnp.exp(scale * jax.random.normal(k, (), jnp.float64))

    return jax.vmap(cell)(uids)


def lognormal_noise_grid(key_stream, n_workloads: int,
                         uids: np.ndarray, scale: float) -> np.ndarray:
    """The full (n_workloads, len(uids)) contention-noise grid, drawn
    on host under x64 — row ``w`` is bit-identical to what
    :func:`lognormal_noise_row` yields for ``wid=w`` inside the
    compiled replay program."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    with enable_x64():
        wids = jnp.arange(n_workloads)
        grid = jax.jit(jax.vmap(
            lambda w: lognormal_noise_row(key_stream, w, uids, scale)
        ))(wids)
        return np.asarray(grid, np.float64)


def bounded_uniform_grid(key_stream, n_rows: int, lo: np.ndarray,
                         hi: np.ndarray) -> np.ndarray:
    """(n_rows, len(lo)) grid of bounded uniforms: cell (r, p) is
    ``lo[p] + (hi[p] - lo[p]) * U(fold_in(fold_in(key, r), p))`` —
    row ``r`` depends only on ``r``, never on how many rows exist."""
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    with enable_x64():
        lo = jnp.asarray(lo, jnp.float64)
        hi = jnp.asarray(hi, jnp.float64)

        def cell(r, p):
            k = jax.random.fold_in(jax.random.fold_in(key_stream, r), p)
            return lo[p] + (hi[p] - lo[p]) * jax.random.uniform(
                k, (), jnp.float64)

        grid = jax.jit(jax.vmap(jax.vmap(
            cell, in_axes=(None, 0)), in_axes=(0, None)))(
                jnp.arange(n_rows), jnp.arange(len(lo)))
        return np.asarray(grid, np.float64)


# ----------------------------------------------------------------- host
PathElem = Union[int, np.integer, str]


def _entropy(x: PathElem) -> int:
    """A path element as SeedSequence entropy: ints pass through,
    strings hash stably (blake2s, platform-independent)."""
    if isinstance(x, (int, np.integer)):
        return int(x) & ((1 << 64) - 1)
    digest = hashlib.blake2s(str(x).encode()).digest()
    return int.from_bytes(digest[:8], "little")


def folded_generator(*path: PathElem) -> np.random.Generator:
    """An independent numpy Generator keyed by a fold-in style path of
    ints/strings — e.g. ``folded_generator(seed, round, btype, mtype)``.
    Equal paths give equal streams; the draw order of *other* paths'
    generators is irrelevant."""
    return np.random.default_rng(
        np.random.SeedSequence([_entropy(x) for x in path]))


def as_generator(rng) -> np.random.Generator:
    """Accept a ``np.random.Generator`` as-is, an int seed, or a
    fold-in path tuple (via :func:`folded_generator`) — lets the
    benchmark-tool simulators take order-independent key paths without
    changing their call signature."""
    if isinstance(rng, np.random.Generator):
        return rng
    if isinstance(rng, (int, np.integer)):
        return np.random.default_rng(int(rng))
    return folded_generator(*tuple(rng))


def path_tuple(*path: PathElem) -> Tuple[PathElem, ...]:
    """Convenience constructor so call sites read as key derivations."""
    return tuple(path)
