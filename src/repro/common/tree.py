"""Pytree arithmetic helpers used across optimizer / checkpoint / runtime.

All helpers are pure functions over pytrees of jnp arrays and are safe to
use inside jit.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_zeros_like(tree):
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def tree_add(a, b):
    return jax.tree_util.tree_map(lambda x, y: x + y, a, b)


def tree_sub(a, b):
    return jax.tree_util.tree_map(lambda x, y: x - y, a, b)


def tree_scale(tree, s):
    return jax.tree_util.tree_map(lambda x: x * s, tree)


def tree_global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        return jnp.asarray(0.0, jnp.float32)
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    return jnp.sqrt(sq)


def tree_size(tree) -> int:
    """Total number of parameters (python int; not jit-safe)."""
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree))


def tree_cast(tree, dtype):
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree,
    )


def tree_flatten_with_paths(tree):
    """Yield (dotted_path, leaf) pairs — used by checkpointing."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        keys = []
        for p in path:
            if hasattr(p, "key"):
                keys.append(str(p.key))
            elif hasattr(p, "idx"):
                keys.append(str(p.idx))
            else:  # pragma: no cover - defensive
                keys.append(str(p))
        out.append(("/".join(keys), leaf))
    return out
