"""End-to-end driver: Perona-aware fault-tolerant LM training.

Quick demo (reduced model, CPU-friendly):

    PYTHONPATH=src python examples/train_lm.py

Full assigned config (what the dry-run proves on the production mesh;
needs accelerators for reasonable wall time):

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --scale full --steps 300 --batch 32 --seq 2048

This wraps repro.launch.train: cluster fingerprinting + ranking, an
injected host failure at step 30, checkpoint/restart and exclusion.
"""

import sys

from repro.launch import train as train_driver

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--arch", "smollm-135m", "--steps", "60",
                "--batch", "4", "--seq", "128", "--fail-at", "30",
                "--checkpoint-every", "10"]
    train_driver.main()
