"""Batched serving example: slot-based continuous batching.

    PYTHONPATH=src python examples/serve_lm.py

Serves 12 requests through 4 slots of a reduced smollm-135m; the same
serve path lowers onto the production meshes for the decode_32k /
long_500k dry-run cells.
"""

import sys

from repro.launch import serve as serve_driver

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--arch", "smollm-135m", "--requests", "12",
                "--max-new", "16", "--slots", "4"]
    serve_driver.main()
