"""Paper §IV-D use case: iterative cloud-configuration optimization with
Perona-weighted acquisition — replayed through the batched BO engine.

The scenario matrix (workload x tuner variant x fleet condition) runs
as parallel vmapped GP lanes — sharded over every available device and
host-pipelined in fixed-size lane blocks (``repro.optimizer``), with
the lane tables *generated inside the compiled program* from
counter-based per-lane seeds (``seeded=True``: the host ships the
compact ``SeededLaneSpec`` instead of materialized tables); every
lane reproduces the sequential CherryPick/Arrow trace exactly, so the
printed results are the paper's comparison at a fraction of the wall
clock (see BENCH_optimizer.json).

    PYTHONPATH=src python examples/resource_tuning.py

Add virtual devices to exercise the mesh on a CPU-only box:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python examples/resource_tuning.py
"""

import time

import numpy as np

from repro.optimizer import (HEALTHY, build_scenarios, drifted_condition,
                             replay_pipelined)
from repro.optimizer.scenarios import VARIANTS
from repro.tuning.perona_weights import fingerprint_machine_scores
from repro.tuning.scout import VM_TYPES, ScoutDataset, WORKLOAD_NAMES


def main():
    ds = ScoutDataset(seed=0)
    print(f"scout-like dataset: {len(ds.workloads)} workloads x "
          f"{len(ds.configs)} configs = "
          f"{len(ds.workloads) * len(ds.configs)} runs")

    print("fingerprinting the 9 AWS machine types (540 executions)...")
    scores = fingerprint_machine_scores(VM_TYPES, runs_per_type=10,
                                        epochs=40)

    # fleet conditions: healthy, plus a degraded fleet derived from
    # the drift analytics of a simulated c4 fleet losing cpu quality
    # (the same condition BENCH_optimizer.json tracks)
    degraded = drifted_condition(
        ("c4.large", "c4.xlarge", "c4.2xlarge"), name="c4-cpu-degraded")

    import jax

    workloads = WORKLOAD_NAMES[:4]
    scens = build_scenarios(ds, workloads=workloads, seeds=(1,),
                            conditions=(HEALTHY, degraded))
    t0 = time.perf_counter()
    traces, stats = replay_pipelined(ds, scens, scores,
                                     block_lanes=16, seeded=True,
                                     devices=jax.devices(),
                                     return_stats=True)
    dt = time.perf_counter() - t0
    print(f"replayed {len(scens)} searches "
          f"({len(workloads)} workloads x {len(VARIANTS)} variants x "
          f"2 fleet conditions) in {dt:.2f}s — "
          f"{stats['blocks']} pipelined blocks of "
          f"{stats['block_lanes']} seeded lanes over "
          f"{len(jax.devices())} device(s)\n")

    by_key = {(s.workload, s.variant, s.condition.name): t
              for s, t in zip(scens, traces)}
    for wl in workloads:
        limit = next(s.limit for s in scens if s.workload == wl)
        print(f"{wl} (runtime limit {limit:.0f}s):")
        for cond in ("healthy", degraded.name):
            for variant in VARIANTS:
                tr = by_key[(wl, variant, cond)]
                best = tr.best_valid_cost[-1]
                cfg = min(
                    ((c, co) for c, co, r in
                     zip(tr.evaluated, tr.costs, tr.runtimes)
                     if r <= limit),
                    key=lambda x: x[1], default=(None, np.inf))[0]
                tag = f"{variant:18s} [{cond}]"
                if cfg is not None:
                    print(f"  {tag:38s} best=${best:.4f} "
                          f"({cfg.vm_type} x{cfg.count} | "
                          f"search ${tr.search_cost:.2f}, "
                          f"{len(tr.evaluated)} runs)")
                else:
                    print(f"  {tag:38s} no valid config found")
        print()


if __name__ == "__main__":
    main()
