"""Paper §IV-D use case: iterative cloud-configuration optimization with
Perona-weighted acquisition (CherryPick / Arrow on the scout-like
dataset).

    PYTHONPATH=src python examples/resource_tuning.py
"""

import numpy as np

from repro.core.ranking import machine_score_vector
from repro.tuning.arrow import Arrow
from repro.tuning.cherrypick import CherryPick
from repro.tuning.perona_weights import (PeronaAcquisitionWeighter,
                                         fingerprint_machine_scores)
from repro.tuning.scout import VM_TYPES, ScoutDataset, WORKLOAD_NAMES


def main():
    ds = ScoutDataset(seed=0)
    print(f"scout-like dataset: {len(ds.workloads)} workloads x "
          f"{len(ds.configs)} configs = "
          f"{len(ds.workloads) * len(ds.configs)} runs")

    print("fingerprinting the 9 AWS machine types (540 executions)...")
    scores = fingerprint_machine_scores(VM_TYPES, runs_per_type=10,
                                        epochs=40)
    weighter = PeronaAcquisitionWeighter(ds, scores)
    low_fn = lambda wl, c: machine_score_vector(scores, c.vm_type)

    for wl in WORKLOAD_NAMES[:4]:
        rts = [ds.runtime_s(wl, c) for c in ds.configs]
        limit = float(np.percentile(rts, 40))
        rows = {}
        rows["cherrypick"] = CherryPick(ds, limit, seed=2).search(wl)
        rows["cherrypick+perona"] = CherryPick(
            ds, limit, seed=2, acquisition_weighter=weighter).search(wl)
        rows["arrow"] = Arrow(ds, limit, seed=2).search(wl)
        rows["arrow+perona"] = Arrow(ds, limit, seed=2,
                                     low_level_fn=low_fn,
                                     acquisition_weighter=weighter
                                     ).search(wl)
        print(f"\n{wl} (runtime limit {limit:.0f}s):")
        for name, tr in rows.items():
            best = tr.best_valid_cost[-1]
            cfg = min(
                ((c, co) for c, co, r in
                 zip(tr.evaluated, tr.costs, tr.runtimes) if r <= limit),
                key=lambda x: x[1], default=(None, float("inf")))[0]
            print(f"  {name:20s} best=${best:.4f} "
                  f"({cfg.vm_type} x{cfg.count} | "
                  f"search ${tr.search_cost:.2f}, "
                  f"{len(tr.evaluated)} runs)" if cfg else
                  f"  {name:20s} no valid config found")


if __name__ == "__main__":
    main()
