"""Quickstart: fingerprint a cluster, learn representations, rank nodes,
and catch a degrading machine — the paper's pipeline end to end.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core.graph_data import build_graphs, chronological_split
from repro.core.model import PeronaConfig, PeronaModel
from repro.core.preprocess import Preprocessor
from repro.core.ranking import aspect_scores, rank_machines
from repro.core.trainer import batch_to_jnp, evaluate, train_perona
from repro.fingerprint.runner import SuiteRunner
from repro.runtime.watchdog import PeronaWatchdog


def main():
    # -- 1. standardized benchmarking of a heterogeneous cluster --------
    runner = SuiteRunner(seed=0)
    machines = {
        "alpha": "e2-medium",
        "bravo": "n1-standard-4",
        "charlie": "n2-standard-4",
        "delta": "c2-standard-4",
    }
    records = runner.run(machines, runs_per_type=40, stress_fraction=0.15)
    print(f"[1] executed {len(records)} benchmark runs "
          f"({len({r.benchmark_type for r in records})} tools x "
          f"{len(machines)} nodes)")

    # -- 2. stateful preprocessing + graphs ------------------------------
    train_r, val_r, test_r = chronological_split(records)
    pre = Preprocessor().fit(train_r)
    print(f"[2] {pre.raw_feature_count} raw metrics -> {pre.n_selected} "
          f"selected (+{len(pre.benchmark_types)} type one-hot)")
    tb, vb, teb = (build_graphs(r, pre) for r in (train_r, val_r, test_r))

    # -- 3. contextual representation learning ---------------------------
    cfg = PeronaConfig(feature_dim=pre.feature_dim,
                       edge_dim=tb.edge.shape[-1])
    model = PeronaModel(cfg)
    res = train_perona(model, tb, vb, epochs=80, seed=0)
    m = evaluate(model, res.params, teb)
    print(f"[3] test: mse={m['mse']:.4f} type_acc={m['type_accuracy']:.2f} "
          f"f1_outlier={m['f1_outlier']:.2f}")

    # -- 4. aspect-based ranking -----------------------------------------
    out = model.forward(res.params, batch_to_jnp(teb), train=False)
    scores = aspect_scores(np.asarray(out["codes"]),
                           [r.benchmark_type for r in test_r],
                           [r.machine for r in test_r])
    print("[4] node ranking (best first):", rank_machines(scores))
    for aspect in ("cpu", "disk", "network"):
        print(f"    {aspect:8s}:", rank_machines(scores, aspect=aspect))

    # -- 5. degradation detection ----------------------------------------
    wd = PeronaWatchdog(model, res.params, pre, confirm_runs=2)
    wd.history = list(records)
    for _ in range(2):
        bad = runner.run({"charlie": "n2-standard-4"}, runs_per_type=1,
                         degraded_machines=["charlie"])
        decisions = wd.observe(bad)
    flagged = [d for d in decisions if d.confirmed]
    print(f"[5] watchdog confirmed degradation on: "
          f"{[d.node for d in flagged]} "
          f"(p={flagged[0].anomaly_prob:.2f})" if flagged else
          "[5] no degradation confirmed")


if __name__ == "__main__":
    main()
