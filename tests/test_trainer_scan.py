"""Scanned device-resident trainer vs the legacy per-epoch loop.

``train_perona`` runs the whole epoch loop as one ``jax.lax.scan``
dispatch (on-device val loss / outlier F1 / checkpoint selection /
early stopping); ``train_perona_reference`` is the pinned legacy loop.
Parity must hold: same best epoch, same history length (early stopping
included), losses and parameters allclose.
"""

import jax
import numpy as np
import pytest
from _trace_utils import expect_traces

from repro.core.graph_data import build_graphs, chronological_split
from repro.core.model import PeronaConfig, PeronaModel
from repro.core.preprocess import Preprocessor
from repro.core.trainer import (TRAINER_TRACES, train_perona,
                                train_perona_reference)
from repro.fingerprint.runner import SuiteRunner


@pytest.fixture(scope="module")
def small_setup():
    runner = SuiteRunner(seed=7)
    machines = {"m0": "e2-medium", "m1": "n2-standard-4"}
    frame = runner.run_frame(machines, runs_per_type=12,
                             stress_fraction=0.2)
    tr, va, _ = chronological_split(frame, (0.7, 0.3, 0.0))
    pre = Preprocessor().fit(tr)
    tb, vb = build_graphs(tr, pre), build_graphs(va, pre)
    cfg = PeronaConfig(feature_dim=pre.feature_dim,
                       edge_dim=tb.edge.shape[-1])
    return PeronaModel(cfg), tb, vb


def _assert_params_close(a, b, atol):
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   atol=atol)


def test_scanned_matches_reference(small_setup):
    model, tb, vb = small_setup
    ref = train_perona_reference(model, tb, vb, epochs=40, seed=3)
    scan = train_perona(model, tb, vb, epochs=40, seed=3)
    assert scan.best_epoch == ref.best_epoch
    assert len(scan.history) == len(ref.history)
    for a, b in zip(ref.history, scan.history):
        assert a["epoch"] == b["epoch"]
        np.testing.assert_allclose(b["train_loss"], a["train_loss"],
                                   atol=2e-3)
        np.testing.assert_allclose(b["val_loss"], a["val_loss"],
                                   atol=2e-3)
        np.testing.assert_allclose(b["val_f1_outlier"],
                                   a["val_f1_outlier"], atol=5e-2)
    # the selected checkpoints are the same epoch's params
    _assert_params_close(ref.params, scan.params, atol=1e-3)


def test_early_stopping_parity(small_setup):
    """The masked stopped-flag must reproduce the reference break
    epoch-for-epoch (history includes the breaking epoch)."""
    model, tb, vb = small_setup
    ref = train_perona_reference(model, tb, vb, epochs=60, patience=0,
                                 seed=0)
    scan = train_perona(model, tb, vb, epochs=60, patience=0, seed=0)
    assert len(ref.history) < 60, "patience must actually trigger"
    assert len(scan.history) == len(ref.history)
    assert scan.best_epoch == ref.best_epoch


def test_no_val_matches_reference(small_setup):
    model, tb, _ = small_setup
    ref = train_perona_reference(model, tb, epochs=10, seed=1)
    scan = train_perona(model, tb, epochs=10, seed=1)
    assert scan.best_epoch == ref.best_epoch == 9
    assert len(scan.history) == len(ref.history) == 10
    _assert_params_close(ref.params, scan.params, atol=1e-4)


def test_single_dispatch_no_per_epoch_host_transfers(small_setup):
    """The whole training run is ONE compiled call: the first run with
    a new shape traces once; further runs (any seed) re-use it, i.e.
    the epoch loop lives on device — zero per-epoch dispatches or
    transfers."""
    model, tb, vb = small_setup
    with expect_traces(TRAINER_TRACES, 1):
        res = train_perona(model, tb, vb, epochs=17, seed=0)
    assert res.stats["device_dispatches"] == 1
    assert res.stats["traced"] == 1
    with expect_traces(TRAINER_TRACES, 0):
        res2 = train_perona(model, tb, vb, epochs=17, seed=5)
        res3 = train_perona(model, tb, vb, epochs=17, seed=6)
    assert res2.stats["device_dispatches"] == 1
    assert res2.stats["traced"] == 0
    assert res3.stats["traced"] == 0


def test_scalar_hypers_do_not_retrace(small_setup):
    """lr / weight decay / dropouts / CBFL gamma+beta are traced
    values: changing them must not trigger a new compile."""
    import dataclasses

    model, tb, vb = small_setup
    train_perona(model, tb, vb, epochs=9, seed=0)  # populate cache
    cfg2 = dataclasses.replace(model.cfg, feature_dropout=0.23,
                               edge_dropout=0.04, cbfl_gamma=1.1,
                               cbfl_beta=0.95)
    with expect_traces(TRAINER_TRACES, 0):
        train_perona(PeronaModel(cfg2), tb, vb, epochs=9, seed=1,
                     lr=1e-4, weight_decay=3e-5)
