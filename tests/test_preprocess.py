"""Unit + property tests for the stateful preprocessing pipeline."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.preprocess import Preprocessor, unify
from repro.fingerprint.records import BenchmarkExecution


def _rec(metrics, btype="sysbench-cpu", machine="n0", t=0.0, stressed=False):
    return BenchmarkExecution(
        benchmark_type=btype, machine=machine, machine_type="e2-medium",
        t=t, metrics=metrics, node_metrics={"node.cpu_util": 0.4},
        stressed=stressed)


def test_unification_units():
    assert unify(1500.0, "ms") == pytest.approx(1.5)
    assert unify(2.0, "GiB") == pytest.approx(2048.0)
    assert unify(8.0, "Mbps") == pytest.approx(8e6 / (8 * 1024 * 1024))
    assert unify(50.0, "%") == pytest.approx(0.5)


def test_unification_makes_mixed_units_comparable():
    # same metric reported in ms and s must unify to one scale
    recs = [_rec({"m.lat": (1500.0, "ms")}, t=i) for i in range(5)]
    recs += [_rec({"m.lat": (1.5 + 0.6 * i, "s")}, t=5 + i)
             for i in range(5)]
    pre = Preprocessor(std_threshold=0.0).fit(recs)
    x = pre.transform(recs)
    assert x.shape[0] == 10
    # values land in the common (0,1) scale
    assert np.all(x >= 0) and np.all(x <= 1)


def test_selection_drops_constants_and_requires_two_values():
    recs = [_rec({"m.const": (42.0, "count"),
                  "m.vary": (float(i), "count")}, t=i) for i in range(10)]
    pre = Preprocessor(std_threshold=0.0).fit(recs)
    assert "m.const" not in pre.feature_names
    assert "m.vary" in pre.feature_names


def test_selection_threshold_drops_low_dispersion():
    rng = np.random.default_rng(0)
    recs = [_rec({"m.tiny": (100.0 + rng.normal(0, 0.01), "count"),
                  "m.big": (100.0 + rng.normal(0, 30.0), "count")}, t=i)
            for i in range(50)]
    pre = Preprocessor(std_threshold=0.02).fit(recs)
    assert "m.tiny" not in pre.feature_names
    assert "m.big" in pre.feature_names


def test_orientation_latency_minimized_throughput_maximized(fitted):
    pre = fitted["pre"]
    for i, name in enumerate(pre.feature_names):
        if name in ("cpu.latency_avg", "ioping.lat_avg"):
            assert not pre.maximize[i], name
        if name in ("cpu.events_per_second", "mem.throughput",
                    "qperf.tcp_bw"):
            assert pre.maximize[i], name


def test_orientation_flip_makes_larger_better(fitted):
    """After preprocessing, stressed runs must score lower on average
    (all retained metrics oriented as larger-is-better)."""
    pre = fitted["pre"]
    recs = fitted["test_records"]
    x = pre.transform(recs)[:, : pre.n_selected]
    stressed = np.asarray([r.stressed for r in recs])
    assert x[~stressed].mean() > x[stressed].mean()


def test_imputation_fills_missing_with_training_mean(fitted):
    pre = fitted["pre"]
    # a cpu benchmark lacks fio metrics; they must be filled, not zero
    rec = fitted["test_records"][0]
    x = pre.transform([rec])[0]
    names = pre.feature_names
    missing = [i for i, n in enumerate(names)
               if not n.startswith(rec.benchmark_type.split("-")[0])
               and n not in {}]
    fio_idx = [i for i, n in enumerate(names) if n.startswith("fio.")]
    if rec.benchmark_type != "fio" and fio_idx:
        assert np.allclose(x[fio_idx], pre.fill_mean[fio_idx])


def test_onehot_enrichment(fitted):
    pre = fitted["pre"]
    x = pre.transform(fitted["test_records"][:10])
    onehot = x[:, pre.n_selected:]
    assert onehot.shape[1] == 6
    assert np.all(onehot.sum(1) == 1.0)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.floats(min_value=0.1, max_value=1e6), min_size=8,
                max_size=32))
def test_transform_bounded_property(values):
    """Property: transformed features always land in [0, 1], even for
    values outside the fitted range."""
    recs = [_rec({"m.v": (v, "count"), "m.w": (v * 2, "count")}, t=i)
            for i, v in enumerate(values)]
    pre = Preprocessor(std_threshold=0.0).fit(recs[: len(recs) // 2])
    if not pre.feature_names:
        return
    x = pre.transform(recs)
    assert np.all(x >= 0.0) and np.all(x <= 1.0)


def test_transform_deterministic(fitted):
    pre = fitted["pre"]
    a = pre.transform(fitted["test_records"][:50])
    b = pre.transform(fitted["test_records"][:50])
    assert np.array_equal(a, b)


def test_aspect_slices_cover_known_prefixes(fitted):
    slices = fitted["pre"].aspect_slices()
    assert set(slices) == {"cpu", "memory", "disk", "network"}
