"""Batched BO replay engine: GP pinned against the scipy reference,
per-seed trace parity with CherryPick/Arrow, Perona-weighting
equivalence, degraded-fleet scenarios, compile amortization, sharded
lane-axis bit parity and the host-pipelined block path."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from _trace_utils import expect_traces

from repro.optimizer import (HEALTHY, FleetCondition, ReplayConfig,
                             REPLAY_TRACES, build_scenarios,
                             condition_from_drift, degrade_scores,
                             lane_spec, lane_tables, reference_search,
                             replay, replay_pipelined,
                             replay_scenarios, replay_seeded,
                             simulate_degraded_fleet,
                             traces_from_result, traces_from_spec)
from repro.tuning.scout import ScoutDataset, VM_TYPES, WORKLOAD_NAMES


@pytest.fixture(scope="module")
def ds():
    return ScoutDataset(seed=0)


@pytest.fixture(scope="module")
def machine_scores():
    """Deterministic fingerprint-score stand-in (scores, not model
    quality, are under test here; the trained path is covered by
    test_tuning)."""
    rng = np.random.default_rng(3)
    return {vm: {a: float(rng.uniform(0.5, 2.0))
                 for a in ("cpu", "memory", "disk", "network")}
            for vm in VM_TYPES}


@pytest.fixture(scope="module")
def degraded_condition():
    report, node_types = simulate_degraded_fleet(
        ("c4.large", "c4.xlarge"), degraded={"c4.large": ("cpu",),
                                             "c4.xlarge": ("cpu",)},
        seed=1)
    return condition_from_drift("c4-cpu", report, node_types)


# ------------------------------------------------------------ GP parity

def test_batched_gp_matches_scipy_reference():
    """Masked padded jnp fit/predict == dense scipy fit/predict."""
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    from repro.optimizer.gp import gp_fit, gp_predict
    from repro.tuning.gp import GP

    rng = np.random.default_rng(0)
    with enable_x64():
        for m in (1, 2, 3, 5, 9):
            X = rng.normal(size=(m, 4))
            y = rng.normal(size=m) * 3.0 + 1.0
            Xs = rng.normal(size=(12, 4))
            ref = GP(noise=1e-3).fit(X, y)
            mu_ref, sd_ref = ref.predict(Xs)

            P = 16
            Xp = np.zeros((P, 4))
            Xp[:m] = X
            yp = np.zeros(P)
            yp[:m] = y
            mask = np.arange(P) < m
            state = gp_fit(jnp.asarray(Xp), jnp.asarray(yp),
                           jnp.asarray(mask), noise=1e-3)
            mu, sd = gp_predict(state, jnp.asarray(Xs))
            np.testing.assert_allclose(np.asarray(mu), mu_ref,
                                       rtol=1e-9, atol=1e-9)
            np.testing.assert_allclose(np.asarray(sd), sd_ref,
                                       rtol=1e-6, atol=1e-8)
            # length scales equal the reference's median heuristic
            np.testing.assert_allclose(np.asarray(state.scales),
                                       ref.scales, rtol=0, atol=0)


def test_batched_ei_matches_numpy():
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    from repro.optimizer.acquire import expected_improvement as ei_jnp
    from repro.tuning.gp import expected_improvement as ei_np

    rng = np.random.default_rng(1)
    mu = rng.normal(size=50)
    sigma = np.abs(rng.normal(size=50)) + 1e-3
    with enable_x64():
        got = np.asarray(ei_jnp(jnp.asarray(mu), jnp.asarray(sigma),
                                0.3))
    ref = ei_np(mu, sigma, 0.3)
    np.testing.assert_allclose(got, ref, rtol=1e-12, atol=1e-15)
    assert np.all(ref >= 0) and np.all(got >= 0)


# --------------------------------------------------------- trace parity

def _assert_trace_equal(seq, bat, scenario):
    label = (scenario.workload, scenario.seed, scenario.variant,
             scenario.condition.name)
    assert [c.key for c in seq.evaluated] == \
        [c.key for c in bat.evaluated], label
    assert seq.best_valid_cost == bat.best_valid_cost, label
    assert seq.costs == bat.costs, label
    assert seq.runtimes == bat.runtimes, label
    assert seq.search_cost == bat.search_cost, label


def test_replay_matches_sequential_traces(ds, machine_scores,
                                          degraded_condition):
    """The acceptance criterion: every lane reproduces its sequential
    numpy search exactly — same evaluated configs, same
    best-valid-cost curve — across variants, seeds and conditions."""
    scens = build_scenarios(
        ds, workloads=WORKLOAD_NAMES[:3], seeds=(0, 1),
        conditions=(HEALTHY, degraded_condition))
    traces = replay_scenarios(ds, scens, machine_scores)
    assert len(traces) == len(scens) == 3 * 2 * 4 * 2
    for sc, bt in zip(scens, traces):
        _assert_trace_equal(reference_search(ds, sc, machine_scores),
                            bt, sc)


def test_perona_lanes_reproduce_weighter_rankings(ds, machine_scores):
    """The pure-array weighting reproduces the sequential
    ``PeronaAcquisitionWeighter`` bit-for-bit on the same inputs."""
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    from repro.core.ranking import machine_score_matrix
    from repro.optimizer.acquire import perona_weight_factors
    from repro.tuning.perona_weights import (PeronaAcquisitionWeighter,
                                             normalized_machine_scores)
    from repro.tuning.scout import PRICES

    weighter = PeronaAcquisitionWeighter(ds, machine_scores)
    wl = WORKLOAD_NAMES[0]
    evaluated = [ds.configs[i] for i in (3, 17, 40)]
    rng = np.random.default_rng(0)
    acq = np.abs(rng.normal(size=len(ds.configs)))
    ref = weighter(ds.configs, acq, workload=wl, evaluated=evaluated,
                   any_valid=True)

    norm = normalized_machine_scores(machine_scores)
    ns = np.stack([norm[c.vm_type] for c in ds.configs])
    prices = np.asarray([PRICES[c.vm_type] for c in ds.configs])
    util = np.mean([ds.low_level_metrics(wl, c) for c in evaluated],
                   axis=0)
    with enable_x64():
        factors = np.asarray(perona_weight_factors(
            jnp.asarray(util), jnp.asarray(ns), jnp.asarray(prices),
            True))
    got = acq * factors
    np.testing.assert_allclose(got, ref, rtol=1e-12)
    np.testing.assert_array_equal(np.argsort(got), np.argsort(ref))
    # the weighter's normalized machine-score vectors are exactly the
    # batched matrix rows (core.ranking batched-input form)
    mats = machine_score_matrix(machine_scores, list(machine_scores))
    assert mats.shape == (len(machine_scores), 4)
    for i, vm in enumerate(machine_scores):
        np.testing.assert_array_equal(
            weighter.norm_scores[vm], norm[vm])


def test_degraded_condition_changes_search(ds, machine_scores,
                                           degraded_condition):
    """Degrading a machine type's fingerprint must actually steer the
    weighted lanes: scores drop for the degraded type and the scenario
    matrix produces at least one different trace vs healthy."""
    degraded = degrade_scores(machine_scores, degraded_condition)
    assert degraded["c4.large"]["cpu"] < machine_scores["c4.large"]["cpu"]
    assert degraded["c4.large"]["memory"] == \
        machine_scores["c4.large"]["memory"]
    healthy = build_scenarios(ds, workloads=WORKLOAD_NAMES[:6],
                              seeds=(0,),
                              variants=("cherrypick+perona",),
                              conditions=(HEALTHY,))
    sick = build_scenarios(ds, workloads=WORKLOAD_NAMES[:6],
                           seeds=(0,),
                           variants=("cherrypick+perona",),
                           conditions=(degraded_condition,))
    t_h = replay_scenarios(ds, healthy, machine_scores)
    t_s = replay_scenarios(ds, sick, machine_scores)
    assert any([c.key for c in a.evaluated] !=
               [c.key for c in b.evaluated]
               for a, b in zip(t_h, t_s))


def test_distinct_conditions_sharing_a_name(ds, machine_scores):
    """Condition tables cache by object, not by name: two different
    conditions named alike must produce different lane tables."""
    cfg = ReplayConfig()
    a = FleetCondition("degraded", {"c4.large": {"cpu": 0.5}})
    b = FleetCondition("degraded", {"r4.large": {"disk": 0.5}})
    scens = build_scenarios(ds, workloads=WORKLOAD_NAMES[:1],
                            seeds=(0,), variants=("cherrypick+perona",),
                            conditions=(a, b))
    tab = lane_tables(ds, scens, machine_scores, cfg)
    assert not np.array_equal(tab.norm_scores[0], tab.norm_scores[1])


def test_replay_compile_amortized(ds, machine_scores):
    """Same lane/slot shapes -> one tracing total (donated-carry scan
    is reused; REPLAY_TRACES is the shared TraceCount pattern)."""
    cfg = ReplayConfig()
    scens = build_scenarios(ds, workloads=WORKLOAD_NAMES[:2],
                            seeds=(0, 1), conditions=(HEALTHY,))
    tab = lane_tables(ds, scens, machine_scores, cfg)
    replay(tab, cfg)  # compile (or reuse an earlier test's program)
    with expect_traces(REPLAY_TRACES, 0):
        r1 = replay(tab, cfg)
        r2 = replay(tab, cfg)
    np.testing.assert_array_equal(r1.chosen, r2.chosen)
    assert r1.dispatches == 1


def _assert_same_traces(ref_traces, got_traces):
    assert len(ref_traces) == len(got_traces)
    for a, b in zip(ref_traces, got_traces):
        assert [c.key for c in a.evaluated] == \
            [c.key for c in b.evaluated]
        assert a.best_valid_cost == b.best_valid_cost


def test_pipelined_matches_unpipelined(ds, machine_scores):
    """Blocked, double-buffered replay is lane-for-lane identical to
    the one-dispatch path (blocks never interact) — in both dispatch
    modes (round-robin per-device placement and sharded blocks)."""
    scens = build_scenarios(ds, workloads=WORKLOAD_NAMES[:2],
                            seeds=(0, 1), conditions=(HEALTHY,))
    ref = replay_scenarios(ds, scens, machine_scores)
    got, stats = replay_pipelined(ds, scens, machine_scores,
                                  block_lanes=8, return_stats=True)
    _assert_same_traces(ref, got)
    assert stats["block_lanes"] == 8
    assert stats["blocks"] == stats["dispatches"] == 2
    assert stats["table_s"] > 0.0
    import jax

    sharded = replay_pipelined(ds, scens, machine_scores,
                               block_lanes=8, devices=jax.devices(),
                               shard_blocks=True)
    _assert_same_traces(ref, sharded)


def test_deferred_condition_resolves_lazily(ds, machine_scores):
    """A DeferredFleetCondition derives its drops on first use inside
    lane_tables (once, cached) and reproduces the eager condition's
    lanes exactly; building the scenario matrix never resolves it."""
    from repro.optimizer import DeferredFleetCondition, resolve_condition

    calls = []
    eager = FleetCondition("deg", {"c4.large": {"cpu": 0.4}})

    def factory():
        calls.append(1)
        return eager

    lazy = DeferredFleetCondition("deg", factory)
    kwargs = dict(workloads=WORKLOAD_NAMES[:1], seeds=(0,),
                  variants=("cherrypick+perona",))
    lazy_scens = build_scenarios(ds, conditions=(lazy,),
                                 condition_major=True, **kwargs)
    assert calls == [] and not lazy.resolved
    cfg = ReplayConfig()
    tab_lazy = lane_tables(ds, lazy_scens, machine_scores, cfg)
    assert calls == [1] and lazy.resolved
    lane_tables(ds, lazy_scens, machine_scores, cfg)
    assert calls == [1]  # cached
    eager_scens = build_scenarios(ds, conditions=(eager,), **kwargs)
    tab_eager = lane_tables(ds, eager_scens, machine_scores, cfg)
    np.testing.assert_array_equal(tab_lazy.norm_scores,
                                  tab_eager.norm_scores)
    assert resolve_condition(lazy).score_drop == eager.score_drop
    assert resolve_condition(eager) is eager


def test_condition_major_order_same_traces(ds, machine_scores):
    """condition_major reorders the matrix but every scenario's trace
    is unchanged (scenario-keyed comparison across orders)."""
    conds = (HEALTHY, FleetCondition("deg", {"r4.large": {"disk": 0.5}}))
    kwargs = dict(workloads=WORKLOAD_NAMES[:2], seeds=(0, 1),
                  conditions=conds)
    a = build_scenarios(ds, **kwargs)
    b = build_scenarios(ds, condition_major=True, **kwargs)
    assert sorted(map(repr, a)) == sorted(map(repr, b)) and a != b
    ta = {repr(s): t for s, t in
          zip(a, replay_scenarios(ds, a, machine_scores))}
    tb = {repr(s): t for s, t in
          zip(b, replay_scenarios(ds, b, machine_scores))}
    for k in ta:
        assert [c.key for c in ta[k].evaluated] == \
            [c.key for c in tb[k].evaluated]
        assert ta[k].best_valid_cost == tb[k].best_valid_cost


def test_pipelined_empty_and_partial_block(ds, machine_scores):
    assert replay_pipelined(ds, [], machine_scores) == []
    scens = build_scenarios(ds, workloads=WORKLOAD_NAMES[:1],
                            seeds=(0,), variants=("cherrypick",),
                            conditions=(HEALTHY,))
    ref = replay_scenarios(ds, scens, machine_scores)
    got = replay_pipelined(ds, scens, machine_scores, block_lanes=8)
    _assert_same_traces(ref, got)


@pytest.mark.slow
def test_trace_amortized_across_lane_counts(ds, machine_scores,
                                            degraded_condition):
    """100-, 200- and 432-lane matrices: the unpipelined path compiles
    one program per pow2 lane bucket (128/256/512) and reuses it, the
    pipelined path reuses ONE fixed-block program across all three
    matrix sizes."""
    cfg = ReplayConfig()
    scens = build_scenarios(ds, seeds=(0, 1, 2),
                            conditions=(HEALTHY, degraded_condition))
    assert len(scens) == 432
    sizes = (100, 200, 432)
    tabs = {n: lane_tables(ds, scens[:n], machine_scores, cfg)
            for n in sizes}
    results = {}
    for n in sizes:  # warm each pow2 bucket (<= 1 tracing per bucket)
        before = REPLAY_TRACES.count
        results[n] = replay(tabs[n], cfg)
        assert REPLAY_TRACES.count - before <= 1
    with expect_traces(REPLAY_TRACES, 0):  # every bucket amortized
        for n in sizes:
            again = replay(tabs[n], cfg)
            np.testing.assert_array_equal(again.chosen,
                                          results[n].chosen)

    # pipelined: fixed 64-lane blocks -> one program for ALL sizes
    replay_pipelined(ds, scens[:100], machine_scores, cfg,
                     block_lanes=64)  # warm the single block shape
    with expect_traces(REPLAY_TRACES, 0):
        for n in (200, 432):
            got = replay_pipelined(ds, scens[:n], machine_scores, cfg,
                                   block_lanes=64)
            _assert_same_traces(
                traces_from_result(tabs[n], results[n], ds.configs),
                got)


# ----------------------------------------------------- seeded replay

def test_seeded_replay_bit_identical_to_host_tables(
        ds, machine_scores, degraded_condition):
    """The in-program table generation (seeded spec, counter-based
    noise re-drawn on device) reproduces the host-materialized lane
    tables' replay bit-for-bit: same selections, same counts, same
    traces — across variants and a degraded condition."""
    cfg = ReplayConfig()
    scens = build_scenarios(
        ds, workloads=WORKLOAD_NAMES[:3], seeds=(0, 1),
        conditions=(HEALTHY, degraded_condition))
    tab = lane_tables(ds, scens, machine_scores, cfg)
    host = replay(tab, cfg)
    spec = lane_spec(ds, scens, machine_scores, cfg)
    seeded = replay_seeded(spec, cfg)
    np.testing.assert_array_equal(host.chosen, seeded.chosen)
    np.testing.assert_array_equal(host.count, seeded.count)
    for a, b in zip(traces_from_result(tab, host, ds.configs),
                    traces_from_spec(spec, seeded, ds.configs)):
        assert [c.key for c in a.evaluated] == \
            [c.key for c in b.evaluated]
        assert a.costs == b.costs and a.runtimes == b.runtimes
        assert a.best_valid_cost == b.best_valid_cost
        assert a.search_cost == b.search_cost


def test_seeded_scenarios_end_to_end(ds, machine_scores):
    """replay_scenarios(seeded=True) matches the host-table path and
    the sequential reference lane-for-lane."""
    scens = build_scenarios(ds, workloads=WORKLOAD_NAMES[:2],
                            seeds=(0,), conditions=(HEALTHY,))
    ref = replay_scenarios(ds, scens, machine_scores)
    got = replay_scenarios(ds, scens, machine_scores, seeded=True)
    _assert_same_traces(ref, got)
    for sc, bt in zip(scens, got):
        _assert_trace_equal(reference_search(ds, sc, machine_scores),
                            bt, sc)


def test_seeded_pipelined_matches_unpipelined(ds, machine_scores):
    scens = build_scenarios(ds, workloads=WORKLOAD_NAMES[:2],
                            seeds=(0, 1), conditions=(HEALTHY,))
    ref = replay_scenarios(ds, scens, machine_scores)
    got, stats = replay_pipelined(ds, scens, machine_scores,
                                  block_lanes=8, seeded=True,
                                  return_stats=True)
    _assert_same_traces(ref, got)
    assert stats["blocks"] == stats["dispatches"] == 2


def test_seeded_replay_compile_amortized(ds, machine_scores):
    """Replays of equally-shaped seeded specs reuse one program, and
    condition counts pad to pow2 so 1- and 2-condition matrices of the
    same lane shape can differ in program only via that padded axis."""
    cfg = ReplayConfig()
    scens = build_scenarios(ds, workloads=WORKLOAD_NAMES[:2],
                            seeds=(0, 1), conditions=(HEALTHY,))
    spec = lane_spec(ds, scens, machine_scores, cfg)
    replay_seeded(spec, cfg)  # compile (or reuse)
    with expect_traces(REPLAY_TRACES, 0):
        r1 = replay_seeded(spec, cfg)
        r2 = replay_seeded(spec, cfg)
    np.testing.assert_array_equal(r1.chosen, r2.chosen)
    assert r1.dispatches == 1


# ------------------------------------------- sharded lane axis (slow)

@pytest.mark.slow
@pytest.mark.multidevice
def test_sharded_replay_bit_identical_subprocess():
    """8 virtual CPU devices: shard_map'd lanes must reproduce the
    single-device scanned replay bit-for-bit on the full 432-lane
    matrix, and the pipelined sharded path must match lane-for-lane."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        import numpy as np
        from repro.optimizer import (HEALTHY, FleetCondition,
                                     ReplayConfig, build_scenarios,
                                     lane_spec, lane_tables, replay,
                                     replay_pipelined, replay_scenarios,
                                     replay_seeded, traces_from_result)
        from repro.tuning.scout import ScoutDataset, VM_TYPES

        assert jax.device_count() == 8
        rng = np.random.default_rng(3)
        scores = {vm: {a: float(rng.uniform(0.5, 2.0))
                       for a in ("cpu", "memory", "disk", "network")}
                  for vm in VM_TYPES}
        ds = ScoutDataset(seed=0)
        cfg = ReplayConfig()
        cond = FleetCondition("deg", {"c4.large": {"cpu": 0.3},
                                      "m4.xlarge": {"memory": 0.4}})
        scens = build_scenarios(ds, seeds=(0, 1, 2),
                                conditions=(HEALTHY, cond))
        assert len(scens) == 432
        tab = lane_tables(ds, scens, scores, cfg)
        single = replay(tab, cfg)
        sharded = replay(tab, cfg, devices=jax.devices())
        assert np.array_equal(single.chosen, sharded.chosen)
        assert np.array_equal(single.count, sharded.count)

        # seeded spec: tables generated inside the sharded program,
        # noise re-drawn per shard from fold-in keys
        spec = lane_spec(ds, scens, scores, cfg)
        seeded = replay_seeded(spec, cfg, devices=jax.devices())
        assert np.array_equal(single.chosen, seeded.chosen)
        assert np.array_equal(single.count, seeded.count)

        ref = traces_from_result(tab, single, ds.configs)
        piped = replay_pipelined(ds, scens, scores, cfg,
                                 block_lanes=64,
                                 devices=jax.devices())
        piped_seeded = replay_pipelined(ds, scens, scores, cfg,
                                        block_lanes=64, seeded=True,
                                        devices=jax.devices())
        for a, b, c in zip(ref, piped, piped_seeded):
            assert [x.key for x in a.evaluated] == \\
                [x.key for x in b.evaluated] == \\
                [x.key for x in c.evaluated]
            assert a.best_valid_cost == b.best_valid_cost \\
                == c.best_valid_cost
        print("OK bit-identical across", jax.device_count(), "devices")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run(
        [sys.executable, "-c", code],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env, capture_output=True, text=True, timeout=420)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "OK bit-identical" in proc.stdout


def test_traces_from_result_fields(ds, machine_scores):
    """Replayed SearchTrace bookkeeping is self-consistent: costs and
    runtimes come from the lane tables, the best-valid curve is the
    running min over valid runs, search_cost sums the costs."""
    cfg = ReplayConfig()
    scens = build_scenarios(ds, workloads=WORKLOAD_NAMES[:1],
                            seeds=(0,), conditions=(HEALTHY,))
    tab = lane_tables(ds, scens, machine_scores, cfg)
    result = replay(tab, cfg)
    traces = traces_from_result(tab, result, ds.configs)
    for sc, tr in zip(scens, traces):
        assert len(tr.evaluated) == len(tr.costs) == len(tr.runtimes) \
            == len(tr.best_valid_cost)
        assert cfg.n_init <= len(tr.evaluated) <= cfg.max_runs
        assert tr.search_cost == float(np.sum(tr.costs))
        running = np.inf
        for cost, rt, best in zip(tr.costs, tr.runtimes,
                                  tr.best_valid_cost):
            if rt <= sc.limit:
                running = min(running, cost)
            assert best == running
        # no config evaluated twice
        keys = [c.key for c in tr.evaluated]
        assert len(keys) == len(set(keys))
