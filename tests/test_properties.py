"""Hypothesis property tests on system invariants."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models import attention as attn
from repro.models import nn


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([32, 64, 128]),
       st.sampled_from([16, 32]))
def test_causal_attention_prefix_invariance(seed, S, hd):
    """Causality: output at position t must not change when the suffix
    tokens (> t) change."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    B, H = 1, 2
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    out = attn.attend_full(q, k, v, pos, pos, causal=True, window=0,
                           scale=0.2)
    # perturb the last quarter of K/V
    cut = 3 * S // 4
    k2 = k.at[:, cut:].add(jax.random.normal(ks[3], (B, S - cut, H, hd)))
    v2 = v.at[:, cut:].add(1.0)
    out2 = attn.attend_full(q, k2, v2, pos, pos, causal=True, window=0,
                            scale=0.2)
    np.testing.assert_allclose(np.asarray(out[:, :cut]),
                               np.asarray(out2[:, :cut]), atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([8, 16, 32]))
def test_window_attention_limits_receptive_field(seed, window):
    """Sliding window: tokens further than `window` back have no
    influence."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    B, S, H, hd = 1, 96, 1, 16
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    out = attn.attend_full(q, k, v, pos, pos, causal=True, window=window,
                           scale=0.25)
    # perturb everything more than `window` before the last position
    t = S - 1
    k2 = k.at[:, : t - window + 1].add(3.0)
    v2 = v.at[:, : t - window + 1].add(3.0)
    out2 = attn.attend_full(q, k2, v2, pos, pos, causal=True,
                            window=window, scale=0.25)
    np.testing.assert_allclose(np.asarray(out[:, t]),
                               np.asarray(out2[:, t]), atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_rope_preserves_norm_and_relativity(seed):
    """RoPE is a rotation (norm-preserving) and attention scores depend
    only on relative positions."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    B, S, H, hd = 1, 8, 1, 32
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    qr = nn.apply_rope(q, pos, 10000.0)
    np.testing.assert_allclose(
        np.asarray(jnp.linalg.norm(qr, axis=-1)),
        np.asarray(jnp.linalg.norm(q, axis=-1)), rtol=1e-5)
    # relative shift invariance: scores(q_i, k_j) == scores at pos+Delta
    shift = 17
    qr2 = nn.apply_rope(q, pos + shift, 10000.0)
    kr = nn.apply_rope(k, pos, 10000.0)
    kr2 = nn.apply_rope(k, pos + shift, 10000.0)
    s1 = jnp.einsum("bshd,bthd->bst", qr, kr)
    s2 = jnp.einsum("bshd,bthd->bst", qr2, kr2)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000))
def test_moe_router_weights_normalized(seed):
    from repro.configs import get_config
    from repro.models import moe as moe_lib

    cfg = get_config("granite-moe-1b-a400m").scaled_down()
    init = nn.Init(jax.random.PRNGKey(seed))
    params, _ = moe_lib.moe_init(init, cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, 8, cfg.d_model))
    w, ids, aux = moe_lib.router_topk(params, cfg.moe, x)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, atol=1e-5)
    assert int(ids.max()) < cfg.moe.n_experts
    assert float(aux) >= 0


@settings(max_examples=60, deadline=None)
@given(st.integers(0, 1 << 20), st.sampled_from([1, 2, 8, 64, 256]))
def test_next_pow2_bounds_and_form(n, floor):
    """next_pow2 returns a power of two >= max(n, floor)."""
    from repro.common.bucketing import next_pow2

    b = next_pow2(n, floor)
    assert b >= n and b >= floor
    assert b & (b - 1) == 0  # power of two
    # tight: halving (while respecting the floor) would undershoot
    assert b == floor or b // 2 < max(n, floor)


@settings(max_examples=60, deadline=None)
@given(st.integers(0, 1 << 20), st.integers(0, 1 << 20),
       st.sampled_from([1, 2, 8, 64, 256]))
def test_next_pow2_monotone(m, n, floor):
    from repro.common.bucketing import next_pow2

    lo, hi = min(m, n), max(m, n)
    assert next_pow2(lo, floor) <= next_pow2(hi, floor)


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 20), st.sampled_from([1, 2, 8, 64, 256]))
def test_next_pow2_idempotent_on_powers_of_two(k, floor):
    """Powers of two at or above the floor are fixed points, and
    re-bucketing a bucket never grows it."""
    from repro.common.bucketing import next_pow2

    p = 1 << k
    if p >= floor:
        assert next_pow2(p, floor) == p
    b = next_pow2(p, floor)
    assert next_pow2(b, floor) == b


@settings(max_examples=60, deadline=None)
@given(st.integers(0, 1 << 20), st.sampled_from([1, 2, 4, 8]),
       st.sampled_from([1, 16, 128]))
def test_shard_size_divisible_pow2(n, n_devices, floor):
    """shard_size: a power of two >= max(n, floor) that every (pow2)
    device mesh divides evenly."""
    from repro.common.mesh import shard_size

    s = shard_size(n, n_devices, floor=floor)
    assert s >= n and s >= floor and s >= n_devices
    assert s & (s - 1) == 0
    assert s % n_devices == 0


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10_000), st.floats(-5.0, 5.0))
def test_expected_improvement_nonnegative(seed, best):
    """EI is an expectation of a nonnegative quantity — it must never
    go negative, including for degenerate (zero/tiny) sigma."""
    from repro.tuning.gp import expected_improvement

    rng = np.random.default_rng(seed)
    mu = rng.normal(scale=3.0, size=32)
    sigma = np.abs(rng.normal(size=32))
    sigma[:4] = 0.0  # degenerate: no posterior uncertainty
    ei = expected_improvement(mu, sigma, best)
    assert np.all(np.isfinite(ei))
    assert np.all(ei >= 0.0)


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 12))
def test_gp_kernel_psd_under_jitter(seed, n):
    """The jittered RBF kernel matrix the GP factorizes must stay
    positive definite — including duplicated rows (rank-deficient
    without the noise term)."""
    from repro.tuning.gp import GP

    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, 3))
    X[-1] = X[0]  # duplicate -> singular kernel without jitter
    gp = GP(noise=1e-3)
    gp.scales = gp._scales(X)
    K = gp._k(X, X) + gp.noise * np.eye(n)
    assert np.linalg.eigvalsh(K).min() > 0
    # and the full fit goes through the Cholesky without blowing up
    gp.fit(X, rng.normal(size=n))
    mu, sd = gp.predict(X)
    assert np.all(np.isfinite(mu)) and np.all(np.isfinite(sd))


def test_gp_degenerate_inputs():
    """Single observation and constant-y fits must stay finite (the
    median length-scale heuristic and standardization guards)."""
    from repro.tuning.gp import GP, expected_improvement

    # single observation: median heuristic undefined -> unit scales
    gp = GP().fit(np.asarray([[1.0, 2.0]]), np.asarray([3.0]))
    np.testing.assert_array_equal(gp.scales, np.ones(2))
    mu, sd = gp.predict(np.asarray([[1.0, 2.0], [5.0, -1.0]]))
    assert np.all(np.isfinite(mu)) and np.all(np.isfinite(sd))
    np.testing.assert_allclose(mu[0], 3.0, atol=1e-2)

    # constant y: zero spread -> unit std, not a division blow-up
    X = np.asarray([[0.0, 0.0], [1.0, 0.5], [2.0, 1.0]])
    gp = GP().fit(X, np.full(3, 0.1))
    assert gp.y_std == 1.0
    mu, sd = gp.predict(X)
    assert np.all(np.isfinite(mu)) and np.all(np.isfinite(sd))
    np.testing.assert_allclose(mu, 0.1, atol=1e-2)
    ei = expected_improvement(mu, sd, best=float(mu.min()))
    assert np.all(ei >= 0.0)


def test_elastic_reshard_roundtrip():
    """reshard_tree re-resolves divisibility on the new mesh and keeps
    values intact (single-device meshes here; multi-device resolution is
    covered by the subprocess test)."""
    from jax.sharding import PartitionSpec as P

    from repro.checkpointing.reshard import reshard_tree
    from repro.launch.mesh import make_debug_mesh

    mesh = make_debug_mesh(1, 1)
    tree = {"w": jnp.arange(64.0).reshape(8, 8), "b": jnp.ones(7)}
    specs = {"w": P(None, "model"), "b": P("model")}  # 7 % 1 ok
    out = reshard_tree(tree, specs, mesh)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.asarray(tree["w"]))
    np.testing.assert_array_equal(np.asarray(out["b"]),
                                  np.asarray(tree["b"]))


def test_hpo_search_space_and_improvement(fitted):
    """A 4-trial random search runs end to end and returns the best
    trial under the checkpoint-selection rank (val outlier F1, loss
    tie-break)."""
    from repro.core.model import PeronaConfig
    from repro.tuning import hpo

    cfg = PeronaConfig(feature_dim=fitted["pre"].feature_dim,
                       edge_dim=fitted["train"].edge.shape[-1])
    best, trials = hpo.search(cfg, fitted["train"], fitted["val"],
                              n_trials=4, epochs=15, seed=0)
    assert len(trials) == 4
    assert best.score == max(t.score for t in trials)
    assert best.result is not None
    for t in trials:
        assert 1 <= t.params["heads"] <= 8
        assert 0 <= t.params["feature_dropout"] <= 0.3
