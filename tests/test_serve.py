"""Slot-based serving: completion, slot reuse, cache isolation."""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.serve import Request, SlotServer
from repro.models.model_zoo import build_model


@pytest.fixture(scope="module")
def served():
    cfg = get_config("smollm-135m").scaled_down(max_seq=64)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _requests(cfg, n, max_new=6, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 8).astype(
                        np.int32),
                    max_new=max_new) for i in range(n)]


def test_all_requests_complete(served):
    cfg, model, params = served
    server = SlotServer(model, params, n_slots=2, max_len=64)
    out = server.serve(_requests(cfg, 5))
    assert len(out["completed"]) == 5
    for r in out["completed"]:
        assert len(r.tokens) == 6


def test_batching_fewer_steps_than_sequential(served):
    cfg, model, params = served
    server = SlotServer(model, params, n_slots=4, max_len=64)
    out = server.serve(_requests(cfg, 4, max_new=10))
    # 4 concurrent requests of 10 tokens ~ 10 lockstep decode steps
    assert out["decode_steps"] <= 14


def test_slot_isolation(served):
    """A request's output must not depend on its co-batched neighbors."""
    cfg, model, params = served
    reqs = _requests(cfg, 3, max_new=5, seed=7)
    solo = SlotServer(model, params, n_slots=1, max_len=64)
    solo_out = solo.serve([Request(0, reqs[0].prompt.copy(), 5)])
    batched = SlotServer(model, params, n_slots=3, max_len=64)
    batched_out = batched.serve([Request(i, r.prompt.copy(), 5)
                                 for i, r in enumerate(reqs)])
    a = solo_out["completed"][0].tokens
    b = next(r for r in batched_out["completed"] if r.rid == 0).tokens
    assert a == b
