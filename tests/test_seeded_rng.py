"""Order-independent scenario RNG: every stochastic quantity is a pure
function of fold-in keys, so query order, dict insertion order and the
eager/jit/vmap boundary can never change a draw. Covers the scout
simulator grid, the counter-based device draws, the columnar suite
runner and the deferred-condition resolve race."""

import threading

import numpy as np
import pytest

from repro.common.rng import (STREAM_CONTENTION, bounded_uniform_grid,
                              folded_generator, lognormal_noise_grid,
                              lognormal_noise_row, stream_key)
from repro.tuning.scout import (VM_TYPES, WORKLOAD_NAMES, ScoutDataset,
                                all_configs, config_uid)


def _scores():
    rng = np.random.default_rng(3)
    return {vm: {a: float(rng.uniform(0.5, 2.0))
                 for a in ("cpu", "memory", "disk", "network")}
            for vm in VM_TYPES}


# ------------------------------------------------- scout order-independence

def test_scout_dataset_call_order_independent():
    """Two fresh datasets queried in opposite orders produce
    bit-identical tables — the draws are keyed by (seed, workload,
    config), not by a shared stream's consumption order."""
    a = ScoutDataset(seed=0)
    b = ScoutDataset(seed=0)
    configs = a.configs
    # a: canonical order; b: reversed workloads, reversed configs,
    # interleaved with scalar queries
    for wl in WORKLOAD_NAMES:
        a.workload_arrays(wl)
    for wl in reversed(WORKLOAD_NAMES):
        b.runtime_s(wl, configs[-1])
        b.low_level_metrics(wl, configs[0])
        b.workload_arrays(wl)
    for wl in WORKLOAD_NAMES:
        rt_a, cost_a, low_a = a.workload_arrays(wl)
        rt_b, cost_b, low_b = b.workload_arrays(wl)
        np.testing.assert_array_equal(rt_a, rt_b)
        np.testing.assert_array_equal(cost_a, cost_b)
        np.testing.assert_array_equal(low_a, low_b)
        for c in (configs[0], configs[7], configs[-1]):
            assert a.runtime_s(wl, c) == b.runtime_s(wl, c)


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=10, deadline=None)
    @given(order=st.permutations(list(range(len(WORKLOAD_NAMES)))),
           interleave=st.lists(
               st.tuples(st.integers(0, len(WORKLOAD_NAMES) - 1),
                         st.integers(0, 68)),
               max_size=6))
    def test_scout_dataset_any_query_order_bit_identical(
            order, interleave):
        """Property form: ANY permutation of workload queries,
        interleaved with arbitrary scalar lookups, yields the same
        tables as canonical-order materialization."""
        ref = ScoutDataset(seed=3)
        for wl in WORKLOAD_NAMES:
            ref.workload_arrays(wl)
        probe = ScoutDataset(seed=3)
        configs = probe.configs
        for w, c in interleave:
            probe.runtime_s(WORKLOAD_NAMES[w], configs[c])
        for i in order:
            probe.workload_arrays(WORKLOAD_NAMES[i])
        for wl in WORKLOAD_NAMES:
            for a, b in zip(ref.workload_arrays(wl),
                            probe.workload_arrays(wl)):
                np.testing.assert_array_equal(a, b)
except ImportError:  # pragma: no cover - hypothesis is optional
    pass


def test_scout_dataset_consumer_order_independent():
    """reference_search-first vs lane_tables-first must see the same
    simulator: the PR 4 parity guarantee no longer needs any shared
    warm-up ordering between the two paths."""
    from repro.optimizer import (HEALTHY, build_scenarios, lane_tables,
                                 reference_search)

    scores = _scores()
    ds_seq = ScoutDataset(seed=0)
    ds_tab = ScoutDataset(seed=0)
    scens = build_scenarios(ds_seq, workloads=WORKLOAD_NAMES[:2],
                            seeds=(0,), conditions=(HEALTHY,))
    # consume ds_seq via the sequential tuner first, ds_tab via the
    # stacked tables first
    ref = reference_search(ds_seq, scens[0], scores)
    scens_tab = build_scenarios(ds_tab, workloads=WORKLOAD_NAMES[:2],
                                seeds=(0,), conditions=(HEALTHY,))
    tab = lane_tables(ds_tab, scens_tab, scores)
    for wl in WORKLOAD_NAMES[:2]:
        rt_a, cost_a, low_a = ds_seq.workload_arrays(wl)
        rt_b, cost_b, low_b = ds_tab.workload_arrays(wl)
        np.testing.assert_array_equal(rt_a, rt_b)
        np.testing.assert_array_equal(cost_a, cost_b)
        np.testing.assert_array_equal(low_a, low_b)
    np.testing.assert_array_equal(
        tab.runtime[0], ds_seq.workload_arrays(WORKLOAD_NAMES[0])[0])
    assert ref.search_cost > 0.0


def test_scout_seeds_differ_and_grid_matches_scalar_path():
    ds0, ds1 = ScoutDataset(seed=0), ScoutDataset(seed=1)
    wl = WORKLOAD_NAMES[0]
    assert not np.array_equal(ds0.workload_arrays(wl)[0],
                              ds1.workload_arrays(wl)[0])
    # scalar accessor returns exactly the grid cell
    for c in (ds0.configs[0], ds0.configs[33]):
        col = [cc.key for cc in ds0.configs].index(c.key)
        assert ds0.runtime_s(wl, c) == ds0.workload_arrays(wl)[0][col]


def test_config_uid_stable_under_grid_extension():
    """uids depend only on (vm_type, count), never on grid position —
    extending the config grid cannot re-key existing draws."""
    configs = all_configs()
    uids = [config_uid(c) for c in configs]
    assert len(set(uids)) == len(uids)
    assert all(u == VM_TYPES.index(c.vm_type) * 256 + c.count
               for u, c in zip(uids, configs))


# --------------------------------------------- counter-based device draws

def test_noise_draws_identical_across_jit_and_vmap():
    """The contention draw for a (workload, config) cell is the same
    number under jit, under jit(vmap), and inside the grid helper —
    the seeded device program's parity rests on this. (The *eager*
    op-by-op path may differ by 1 ulp from the compiled one — erf/exp
    fuse differently — which is why both the host grid and the replay
    program run jitted.)"""
    import jax
    import jax.numpy as jnp
    from jax.experimental import enable_x64

    key = stream_key(0, STREAM_CONTENTION)
    uids = np.asarray([config_uid(c) for c in all_configs()], np.int32)
    grid = lognormal_noise_grid(key, len(WORKLOAD_NAMES), uids, 0.06)
    assert grid.shape == (len(WORKLOAD_NAMES), len(uids))
    assert grid.dtype == np.float64
    with enable_x64():
        k, u = jnp.asarray(key), jnp.asarray(uids)
        row_eager = np.asarray(lognormal_noise_row(k, 3, u, 0.06))
        row_jit = np.asarray(jax.jit(
            lambda k, u: lognormal_noise_row(k, 3, u, 0.06))(k, u))
        rows_vmap_jit = np.asarray(jax.jit(jax.vmap(
            lambda w: lognormal_noise_row(k, w, u, 0.06)))(
            jnp.arange(len(WORKLOAD_NAMES))))
    np.testing.assert_array_equal(row_jit, grid[3])
    np.testing.assert_array_equal(rows_vmap_jit, grid)
    np.testing.assert_allclose(row_eager, grid[3], rtol=1e-15)


def test_bounded_uniform_grid_is_per_cell_keyed():
    key = stream_key(7, 1)
    lo = np.asarray([0.0, 10.0])
    hi = np.asarray([1.0, 20.0])
    g = bounded_uniform_grid(key, 4, lo, hi)
    assert g.shape == (4, 2)
    assert np.all((g >= lo) & (g <= hi))
    # a single row re-derived standalone matches the full grid's row
    np.testing.assert_array_equal(
        bounded_uniform_grid(key, 4, lo, hi)[2], g[2])


def test_folded_generator_path_keyed():
    a = folded_generator(0, 1, "net-slots")
    b = folded_generator(0, 1, "net-slots")
    c = folded_generator(0, 2, "net-slots")
    x = a.uniform(size=5)
    np.testing.assert_array_equal(x, b.uniform(size=5))
    assert not np.array_equal(x, c.uniform(size=5))


# --------------------------------------------------- suite runner frames

def test_run_frame_machine_dict_order_independent():
    """Dict insertion order of the fleet map must not change any draw:
    the per-group generators are keyed by (seed, round, benchmark
    type, machine type) and nodes iterate sorted."""
    from repro.fingerprint.runner import SuiteRunner

    machines = {"b": "n2-standard-4", "a": "e2-medium",
                "c": "n2-standard-4"}
    shuffled = {"a": "e2-medium", "c": "n2-standard-4",
                "b": "n2-standard-4"}
    rec_a = SuiteRunner(seed=0).run(machines, runs_per_type=3,
                                    stress_fraction=0.3)
    rec_b = SuiteRunner(seed=0).run(shuffled, runs_per_type=3,
                                    stress_fraction=0.3)

    def canon(records):
        return sorted((r.machine, r.benchmark_type, r.t, r.stressed,
                       tuple(sorted(r.metrics.items())),
                       tuple(sorted(r.node_metrics.items())))
                      for r in records)

    assert canon(rec_a) == canon(rec_b)


def test_run_frame_rounds_draw_fresh_values():
    from repro.fingerprint.runner import SuiteRunner

    runner = SuiteRunner(seed=0)
    machines = {"a": "e2-medium"}
    f1 = runner.run_frame(machines, runs_per_type=2)
    f2 = runner.run_frame(machines, runs_per_type=2)
    assert not np.array_equal(f1.metrics, f2.metrics)
    # ...but a fresh runner replays round 0 exactly
    g1 = SuiteRunner(seed=0).run_frame(machines, runs_per_type=2)
    np.testing.assert_array_equal(f1.metrics, g1.metrics)


# ------------------------------------------------ deferred-resolve race

def test_deferred_condition_resolves_once_under_concurrency():
    """Concurrent resolvers (the pipelined per-device workers) must
    run the factory exactly once and all observe the same object —
    a second FleetCondition would split the id()-keyed table caches."""
    from repro.optimizer import DeferredFleetCondition, FleetCondition

    calls = []
    gate = threading.Barrier(8)

    def factory():
        calls.append(1)
        return FleetCondition("deg", {"c4.large": {"cpu": 0.4}})

    lazy = DeferredFleetCondition("deg", factory)
    out = [None] * 8

    def resolve(i):
        gate.wait()
        out[i] = lazy.resolve()

    threads = [threading.Thread(target=resolve, args=(i,))
               for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert calls == [1]
    assert all(o is out[0] for o in out)
    assert lazy.resolved


# -------------------------------------------- seeded replay round trips

def test_seeded_spec_is_compact():
    """The seeded spec must stay O(W*C + K*C + L): no array may carry
    both the lane axis and the candidate axis."""
    from repro.optimizer import HEALTHY, build_scenarios, lane_spec

    ds = ScoutDataset(seed=0)
    scens = build_scenarios(ds, workloads=WORKLOAD_NAMES[:4],
                            seeds=(0, 1, 2), conditions=(HEALTHY,))
    spec = lane_spec(ds, scens, _scores())
    n_lanes, n_cand = len(scens), len(ds.configs)
    assert len(spec) == n_lanes
    for name in ("workload_id", "condition_id", "variant_id", "limit"):
        assert getattr(spec, name).shape == (n_lanes,)
    for arr in (spec.base_runtime, spec.low_num, spec.x_base,
                spec.norm_scores, spec.fp_low):
        assert n_lanes not in arr.shape or n_lanes == n_cand
    assert spec.norm_scores.shape == (1, n_cand, 4)


@pytest.mark.slow
def test_seeded_replay_matches_sequential_traces():
    """Acceptance: the in-program-generated tables reproduce the
    sequential scipy searches exactly, across variants, seeds and a
    degraded condition."""
    from repro.optimizer import (HEALTHY, FleetCondition,
                                 build_scenarios, lane_spec,
                                 reference_search, replay_seeded,
                                 traces_from_spec)

    ds = ScoutDataset(seed=0)
    scores = _scores()
    cond = FleetCondition("deg", {"c4.large": {"cpu": 0.3},
                                  "m4.xlarge": {"memory": 0.4}})
    scens = build_scenarios(ds, workloads=WORKLOAD_NAMES[:3],
                            seeds=(0, 1), conditions=(HEALTHY, cond))
    spec = lane_spec(ds, scens, scores)
    traces = traces_from_spec(spec, replay_seeded(spec), ds.configs)
    assert len(traces) == len(scens)
    for sc, bt in zip(scens, traces):
        seq = reference_search(ds, sc, scores)
        assert [c.key for c in seq.evaluated] == \
            [c.key for c in bt.evaluated], sc
        assert seq.best_valid_cost == bt.best_valid_cost, sc
        assert seq.costs == bt.costs and seq.runtimes == bt.runtimes
