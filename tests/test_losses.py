"""Unit + property tests for the five Perona objectives."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import losses as L


def test_mse_zero_on_perfect_recon():
    x = jnp.ones((4, 8)) * 0.5
    v = jnp.ones((4,))
    assert float(L.mse_loss(x, x, v)) == 0.0


def test_cbfl_low_for_confident_correct():
    logit = jnp.asarray([10.0, -10.0, -10.0, -10.0])
    label = jnp.asarray([1, 0, 0, 0])
    v = jnp.ones((4,))
    good = float(L.class_balanced_focal_loss(logit, label, v))
    bad = float(L.class_balanced_focal_loss(-logit, label, v))
    assert good < 1e-3 < bad


def test_cbfl_balances_minority_class():
    """Misclassifying the rare positive must cost more than
    misclassifying one of many negatives."""
    label = jnp.asarray([1] + [0] * 19)
    v = jnp.ones((20,))
    miss_pos = jnp.asarray([-3.0] + [-3.0] * 19)
    miss_neg = jnp.asarray([3.0] + [3.0] + [-3.0] * 18)
    lp = float(L.class_balanced_focal_loss(miss_pos, label, v))
    ln = float(L.class_balanced_focal_loss(miss_neg, label, v))
    assert lp > ln


def test_tml_zero_when_clustered():
    codes = jnp.asarray([[1.0, 0], [1.0, 0.01], [0, 1.0], [0.01, 1.0]])
    types = jnp.asarray([0, 0, 1, 1])
    v = jnp.ones((4,))
    assert float(L.triplet_margin_loss(codes, types, v, margin=0.3)) == 0.0
    mixed = jnp.asarray([0, 1, 0, 1])
    assert float(L.triplet_margin_loss(codes, mixed, v, margin=0.3)) > 0.1


def test_mrl_zero_when_correctly_ranked():
    # codes whose 10-norms already follow the ground truth
    codes = jnp.asarray([[0.1] * 4, [0.5] * 4, [1.0] * 4])
    gt = jnp.asarray([1.0, 2.0, 3.0])
    types = jnp.zeros(3, jnp.int32)
    anom = jnp.zeros(3, jnp.int32)
    v = jnp.ones(3)
    loss = float(L.margin_ranking_loss(codes, gt, types, anom, v))
    assert loss < 1e-4
    # inverted ground truth must be penalized
    loss_bad = float(L.margin_ranking_loss(codes, gt[::-1], types, anom, v))
    assert loss_bad > 0.1


def test_mrl_pushes_anomalies_below_normals():
    codes = jnp.asarray([[0.5] * 4, [1.0] * 4, [2.0] * 4])
    gt = jnp.asarray([1.0, 2.0, 0.5])
    types = jnp.zeros(3, jnp.int32)
    anom = jnp.asarray([0, 0, 1])  # the largest-norm code is anomalous
    v = jnp.ones(3)
    loss = float(L.margin_ranking_loss(codes, gt, types, anom, v))
    assert loss > 0.5  # anomaly ranked above normals -> penalty


def test_pnorm_matches_numpy():
    codes = np.random.default_rng(0).normal(size=(5, 8))
    ours = np.asarray(L.pnorm(jnp.asarray(codes), 10.0))
    ref = np.power(np.power(np.abs(codes) + 1e-12, 10).sum(-1), 0.1)
    np.testing.assert_allclose(ours, ref, rtol=1e-5)


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 12), st.integers(0, 10_000))
def test_losses_nonnegative_property(n, seed):
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(rng.normal(size=(n, 6)))
    types = jnp.asarray(rng.integers(0, 3, n))
    anom = jnp.asarray(rng.integers(0, 2, n))
    gt = jnp.asarray(rng.uniform(0.1, 5.0, n))
    v = jnp.ones(n)
    logit = jnp.asarray(rng.normal(size=n))
    for val in (
        L.triplet_margin_loss(codes, types, v),
        L.margin_ranking_loss(codes, gt, types, anom, v),
        L.class_balanced_focal_loss(logit, anom, v),
        L.mse_loss(jax.nn.sigmoid(codes), jax.nn.sigmoid(codes) * 0.9, v),
    ):
        assert float(val) >= 0.0
        assert np.isfinite(float(val))
