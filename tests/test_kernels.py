"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU).

Each kernel is swept over shapes and dtypes and asserted allclose
against its ref.py oracle, per the deliverable-(c) requirement.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


# --------------------------------------------------------------- flash attn
@pytest.mark.parametrize("B,H,KH,S,D", [
    (1, 2, 2, 128, 64), (2, 4, 2, 256, 64), (1, 4, 1, 128, 128),
    (1, 8, 4, 512, 64), (2, 2, 1, 256, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64),
                                           (False, 0)])
def test_flash_attention_sweep(B, H, KH, S, D, dtype, causal, window):
    from repro.kernels.flash_attention import ops, ref

    ks = jax.random.split(jax.random.PRNGKey(B * S + D), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, KH, D), dtype)
    v = jax.random.normal(ks[2], (B, S, KH, D), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              interpret=True)
    tr = lambda x: jnp.swapaxes(x, 1, 2)
    expect = tr(ref.attention(tr(q), tr(k), tr(v), causal=causal,
                              window=window))
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype])


def test_flash_attention_grad_matches_ref():
    from repro.kernels.flash_attention import ops, ref

    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 64))
    k = jax.random.normal(ks[1], (1, 128, 2, 64))
    v = jax.random.normal(ks[2], (1, 128, 2, 64))

    def f_kernel(q):
        return ops.flash_attention(q, k, v, interpret=True).sum()

    def f_ref(q):
        tr = lambda x: jnp.swapaxes(x, 1, 2)
        return ref.attention(tr(q), tr(k), tr(v)).sum()

    g1 = jax.grad(f_kernel)(q)
    g2 = jax.grad(f_ref)(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-4)


# ------------------------------------------------------------------ rg_lru
@pytest.mark.parametrize("B,S,C", [(2, 64, 128), (1, 256, 512),
                                   (3, 128, 256), (1, 512, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rg_lru_sweep(B, S, C, dtype):
    from repro.kernels.rg_lru import ops, ref

    ks = jax.random.split(jax.random.PRNGKey(S + C), 3)
    a = jax.random.uniform(ks[0], (B, S, C), jnp.float32, 0.5, 0.999)
    b = jax.random.normal(ks[1], (B, S, C), jnp.float32).astype(dtype)
    h0 = jax.random.normal(ks[2], (B, C), jnp.float32)
    y, hl = ops.linear_scan(a, b, h0, interpret=True)
    ye, hle = ref.linear_scan(a, b.astype(jnp.float32), h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ye), atol=3e-5,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(hl), np.asarray(hle), atol=3e-5,
                               rtol=1e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 3), st.integers(1, 6), st.integers(0, 10_000))
def test_rg_lru_matches_sequential_property(B, nsteps, seed):
    """Property: the associative-scan oracle equals a plain python
    recurrence for arbitrary (a, b)."""
    from repro.kernels.rg_lru import ref

    rng = np.random.default_rng(seed)
    S = nsteps * 16
    a = rng.uniform(0.3, 0.99, (B, S, 8)).astype(np.float32)
    b = rng.normal(size=(B, S, 8)).astype(np.float32)
    y, _ = ref.linear_scan(jnp.asarray(a), jnp.asarray(b))
    h = np.zeros((B, 8), np.float32)
    for t in range(S):
        h = a[:, t] * h + b[:, t]
        np.testing.assert_allclose(np.asarray(y[:, t]), h, atol=1e-4)
        if t > 2:
            break  # spot-check the prefix; full check is O(S)


# ------------------------------------------------------------------- mlstm
@pytest.mark.parametrize("BH,S,hd,chunk", [(2, 128, 64, 64), (4, 64, 32, 32),
                                           (1, 256, 128, 64)])
def test_mlstm_kernel_sweep(BH, S, hd, chunk):
    from repro.kernels.mlstm import ref
    from repro.kernels.mlstm.kernel import mlstm_chunkwise as kfn

    ks = jax.random.split(jax.random.PRNGKey(S + hd), 5)
    q = jax.random.normal(ks[0], (BH, S, hd))
    k = jax.random.normal(ks[1], (BH, S, hd)) / jnp.sqrt(hd)
    v = jax.random.normal(ks[2], (BH, S, hd))
    li = jax.random.normal(ks[3], (BH, S)) * 0.5
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (BH, S)) + 2)
    h, (C, n, m) = kfn(q, k, v, li, lf, chunk=chunk, interpret=True)
    he, (Ce, ne, me) = ref.mlstm_chunkwise(q, k, v, li, lf, chunk=chunk)
    np.testing.assert_allclose(np.asarray(h), np.asarray(he), atol=2e-5)
    np.testing.assert_allclose(np.asarray(C), np.asarray(Ce), atol=2e-5)
    np.testing.assert_allclose(np.asarray(m), np.asarray(me), atol=2e-5)


def test_mlstm_chunk_size_invariance():
    """Chunkwise formulation must be exact: results independent of L."""
    from repro.kernels.mlstm import ref

    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    BH, S, hd = 2, 128, 32
    q = jax.random.normal(ks[0], (BH, S, hd))
    k = jax.random.normal(ks[1], (BH, S, hd)) / jnp.sqrt(hd)
    v = jax.random.normal(ks[2], (BH, S, hd))
    li = jax.random.normal(ks[3], (BH, S)) * 0.5
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (BH, S)) + 2)
    h8, _ = ref.mlstm_chunkwise(q, k, v, li, lf, chunk=8)
    h128, _ = ref.mlstm_chunkwise(q, k, v, li, lf, chunk=128)
    np.testing.assert_allclose(np.asarray(h8), np.asarray(h128), atol=1e-4)


def test_mlstm_matches_step_recurrence():
    """Chunkwise == token-by-token recurrent cell (decode path)."""
    from repro.kernels.mlstm import ref
    from repro.models import recurrent as rec

    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    B, S, hd = 2, 64, 16
    q = jax.random.normal(ks[0], (B, S, hd))
    k = jax.random.normal(ks[1], (B, S, hd)) / jnp.sqrt(hd)
    v = jax.random.normal(ks[2], (B, S, hd))
    li = jax.random.normal(ks[3], (B, S)) * 0.5
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, S)) + 2)
    hc, _ = ref.mlstm_chunkwise(q, k, v, li, lf, chunk=16)
    state = (jnp.zeros((B, 1, hd, hd)), jnp.zeros((B, 1, hd)),
             jnp.full((B, 1), -1e30))
    for t in range(4):
        h_t, state = rec.mlstm_step(
            q[:, t:t + 1, None], k[:, t:t + 1, None], v[:, t:t + 1, None],
            li[:, t:t + 1, None], lf[:, t:t + 1, None], state)
        np.testing.assert_allclose(np.asarray(h_t[:, 0, 0]),
                                   np.asarray(hc[:, t]), atol=1e-4)


# ------------------------------------------------------------ edge softmax
@pytest.mark.parametrize("N,P,F", [(100, 3, 32), (512, 3, 64),
                                   (1800, 3, 16), (7, 3, 8), (64, 5, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_edge_softmax_sweep(N, P, F, dtype):
    from repro.kernels.edge_softmax import ops, ref

    ks = jax.random.split(jax.random.PRNGKey(N + F), 4)
    q = jax.random.normal(ks[0], (N, F), dtype)
    k = jax.random.normal(ks[1], (N, P, F), dtype)
    v = jax.random.normal(ks[2], (N, P, F), dtype)
    mask = jax.random.bernoulli(ks[3], 0.8, (N, P))
    out, att = ops.edge_softmax_aggregate(q, k, v, mask, interpret=True)
    oe, ae = ref.edge_softmax_aggregate(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(oe, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])
    np.testing.assert_allclose(np.asarray(att), np.asarray(ae),
                               atol=TOL[dtype])


def test_edge_softmax_fully_masked_rows_zero():
    from repro.kernels.edge_softmax import ops

    q = jnp.ones((8, 16))
    k = jnp.ones((8, 3, 16))
    v = jnp.ones((8, 3, 16))
    mask = jnp.zeros((8, 3), bool)
    out, att = ops.edge_softmax_aggregate(q, k, v, mask, interpret=True)
    assert float(jnp.abs(out).max()) == 0.0
    assert float(jnp.abs(att).max()) == 0.0


def test_edge_softmax_attention_sums_to_one():
    from repro.kernels.edge_softmax import ref

    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (32, 8))
    k = jax.random.normal(ks[1], (32, 3, 8))
    v = jax.random.normal(ks[2], (32, 3, 8))
    mask = jnp.ones((32, 3), bool)
    _, att = ref.edge_softmax_aggregate(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(att.sum(1)), 1.0, atol=1e-5)


@pytest.mark.parametrize("N,H,hd", [(100, 4, 16), (64, 2, 32),
                                    (200, 8, 8)])
def test_edge_softmax_multi_head_matches_per_head_loop(N, H, hd):
    """The heads grid axis must equal running the single-head kernel
    once per head (the old host-side loop)."""
    from repro.kernels.edge_softmax import ops, ref

    ks = jax.random.split(jax.random.PRNGKey(N + H), 4)
    q = jax.random.normal(ks[0], (N, H, hd))
    k = jax.random.normal(ks[1], (N, 3, H, hd))
    v = jax.random.normal(ks[2], (N, 3, H, hd))
    mask = jax.random.bernoulli(ks[3], 0.8, (N, 3))
    out, att = ops.edge_softmax_aggregate(q, k, v, mask, interpret=True)
    assert out.shape == (N, H, hd) and att.shape == (N, H, 3)
    for h in range(H):
        oh, ah = ref.edge_softmax_aggregate(q[:, h], k[:, :, h],
                                            v[:, :, h], mask)
        np.testing.assert_allclose(np.asarray(out[:, h]), np.asarray(oh),
                                   atol=2e-5)
        np.testing.assert_allclose(np.asarray(att[:, h]), np.asarray(ah),
                                   atol=2e-5)


def test_edge_softmax_grad_reuses_forward_residuals():
    """custom_vjp backward (attention residuals, no reference re-run)
    vs jax.vjp through the reference oracle — including a cotangent on
    the attention output."""
    from repro.kernels.edge_softmax import ops, ref

    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    N, H, hd = 50, 4, 8
    q = jax.random.normal(ks[0], (N, H, hd))
    k = jax.random.normal(ks[1], (N, 3, H, hd))
    v = jax.random.normal(ks[2], (N, 3, H, hd))
    mask = jax.random.bernoulli(ks[3], 0.7, (N, 3))

    def f(mod, interp):
        def inner(q, k, v):
            kw = {"interpret": True} if interp else {}
            o, a = mod.edge_softmax_aggregate(q, k, v, mask, **kw)
            return (o * jnp.arange(hd)).sum() + 0.3 * (a ** 2).sum()
        return inner

    g1 = jax.grad(f(ops, True), argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f(ref, False), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-5)


def test_edge_softmax_empty_graph():
    """N=0 regression: the padding used to divide by zero."""
    from repro.kernels.edge_softmax import ops

    q = jnp.zeros((0, 8))
    k = jnp.zeros((0, 3, 8))
    v = jnp.zeros((0, 3, 8))
    mask = jnp.zeros((0, 3), bool)
    out, att = ops.edge_softmax_aggregate(q, k, v, mask, interpret=True)
    assert out.shape == (0, 8)
    assert att.shape == (0, 3)


@pytest.mark.parametrize("heads", [1, 4])
def test_model_pallas_gnn_matches_reference(heads):
    """End-to-end PeronaModel parity of gnn_impl=pallas (heads in the
    kernel grid) vs the reference impl, value and gradient."""
    import dataclasses

    from repro.core.model import PeronaConfig, PeronaModel

    N, F, A = 40, 20, 7
    cfg = PeronaConfig(feature_dim=F, edge_dim=A, heads=heads)
    model_ref = PeronaModel(cfg)
    model_pal = PeronaModel(dataclasses.replace(cfg, gnn_impl="pallas"))
    params = model_ref.init(jax.random.PRNGKey(0))
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    batch = {
        "x": jax.random.uniform(ks[0], (N, F)),
        "nbr": jnp.tile(jnp.arange(N)[:, None] - 1, (1, 3)),
        "nbr_mask": jax.random.bernoulli(ks[1], 0.8, (N, 3)),
        "edge": jax.random.uniform(ks[2], (N, 3, A)),
    }
    o1 = model_ref.forward(params, batch, train=False)
    o2 = model_pal.forward(params, batch, train=False)
    np.testing.assert_allclose(np.asarray(o1["agg"]),
                               np.asarray(o2["agg"]), atol=2e-5)
    np.testing.assert_allclose(np.asarray(o1["anom_logit"]),
                               np.asarray(o2["anom_logit"]), atol=2e-5)

    def s(model):
        return lambda p: model.forward(p, batch,
                                       train=False)["anom_logit"].sum()

    g1 = jax.grad(s(model_ref))(params)
    g2 = jax.grad(s(model_pal))(params)
    for a, b in zip(jax.tree_util.tree_leaves(g1),
                    jax.tree_util.tree_leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=3e-5)


@pytest.mark.parametrize("N", [129, 513])
def test_edge_softmax_just_above_block_boundary(N):
    """N one past a block multiple exercises the pad-to-block path."""
    from repro.kernels.edge_softmax import ops, ref

    ks = jax.random.split(jax.random.PRNGKey(N), 4)
    q = jax.random.normal(ks[0], (N, 8))
    k = jax.random.normal(ks[1], (N, 3, 8))
    v = jax.random.normal(ks[2], (N, 3, 8))
    mask = jax.random.bernoulli(ks[3], 0.8, (N, 3))
    out, att = ops.edge_softmax_aggregate(q, k, v, mask, interpret=True)
    oe, ae = ref.edge_softmax_aggregate(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(oe), atol=2e-5)
    np.testing.assert_allclose(np.asarray(att), np.asarray(ae), atol=2e-5)
