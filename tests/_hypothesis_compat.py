"""Optional-hypothesis shim for the property-test modules.

``hypothesis`` is a dev-only dependency (declared in pyproject.toml).
When it is unavailable the suite must still *collect* and run every
non-property test, so this module exports drop-in ``given``/``settings``/
``st`` substitutes that mark property tests as skipped instead of
erroring at import time.
"""

try:  # pragma: no cover - exercised implicitly by the test modules
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # pragma: no cover
    import pytest

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Placeholder: accepts any strategy-construction call chain."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

        def map(self, fn):
            return self

        def filter(self, fn):
            return self

    class _Strategies:
        def __getattr__(self, name):
            return _Strategy()

    st = _Strategies()

    def given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed")(fn)

        return deco

    def settings(*args, **kwargs):
        def deco(fn):
            return fn

        return deco
