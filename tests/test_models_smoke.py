"""Per-architecture smoke tests: reduced configs, one train step + one
prefill/decode round on CPU, asserting shapes and finiteness, plus
prefill->decode consistency against the full forward pass."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models.model_zoo import build_model


def _batch(cfg, B=2, S=32, seed=1):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    batch = {"labels": jax.random.randint(k2, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["embeddings"] = jax.random.normal(
            k1, (B, S, cfg.d_model), jnp.bfloat16)
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S), (3, B, S)).astype(jnp.int32)
    else:
        batch["tokens"] = jax.random.randint(k1, (B, S), 0, cfg.vocab_size)
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            k1, (B, cfg.n_audio_frames, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    cfg = get_config(arch).scaled_down()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)

    def loss_fn(p):
        loss, metrics = model.loss(p, batch)
        return loss

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(jnp.square(g)))
                for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_smoke(arch):
    cfg = get_config(arch).scaled_down()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 32
    batch = _batch(cfg, B, S)
    cache = model.init_cache(B, S + 8)
    kw = {k: v for k, v in batch.items() if k != "labels"}
    logits, cache = model.prefill(params, cache, **kw)
    assert logits.shape == (B, cfg.vocab_size)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for i in range(3):
        pos = jnp.full((B,), S + i, jnp.int32)
        logits, cache = model.decode_step(params, tok, pos, cache)
        assert logits.shape == (B, cfg.vocab_size)
        assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)


@pytest.mark.parametrize("arch", ["smollm-135m", "recurrentgemma-9b",
                                  "xlstm-1.3b", "gemma3-4b",
                                  "deepseek-v2-lite-16b"])
def test_decode_consistent_with_forward(arch):
    """decode_step after prefill must reproduce the full forward logits
    at the same position (KV-cache/state correctness)."""
    from repro.models import transformer as tfm

    cfg = get_config(arch).scaled_down()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(5), (B, S + 1), 0,
                                cfg.vocab_size)
    # full forward over S+1 tokens: logits at position S
    logits_full, _, _ = tfm.forward(params, cfg, tokens=tokens)
    # prefill S tokens, then decode token S
    cache = model.init_cache(B, S + 4)
    lp, cache = model.prefill(params, cache, tokens=tokens[:, :S])
    np.testing.assert_allclose(
        np.asarray(lp, np.float32),
        np.asarray(logits_full[:, S - 1], np.float32), atol=0.3, rtol=0.1)
    ld, cache = model.decode_step(
        params, tokens[:, S:S + 1], jnp.full((B,), S, jnp.int32), cache)
    np.testing.assert_allclose(
        np.asarray(ld, np.float32),
        np.asarray(logits_full[:, S], np.float32), atol=0.3, rtol=0.1)


def test_scan_and_unrolled_forward_agree():
    """scan_layers=True/False are the same math; in f32 they agree to
    float tolerance (bf16 differs only by fusion-order rounding)."""
    import dataclasses

    from repro.models import transformer as tfm

    cfg = dataclasses.replace(
        get_config("gemma3-4b").scaled_down(),
        n_periods=2, dtype="float32",
        n_layers=len(get_config("gemma3-4b").body_pattern) * 2
        + len(get_config("gemma3-4b").tail_pattern))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                cfg.vocab_size)
    l1, _, _ = tfm.forward(params, cfg, tokens=tokens)
    cfg2 = dataclasses.replace(cfg, scan_layers=False)
    l2, _, _ = tfm.forward(params, cfg2, tokens=tokens)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=2e-4)


def test_chunked_ce_matches_full():
    import dataclasses

    from repro.models import transformer as tfm

    cfg = get_config("smollm-135m").scaled_down()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, 2, 32)
    full, _ = tfm.loss_fn(params, cfg, batch)
    cfg2 = dataclasses.replace(cfg, chunked_ce=8)
    chunked, _ = tfm.loss_fn(params, cfg2, batch)
    np.testing.assert_allclose(float(full), float(chunked), rtol=1e-3)
