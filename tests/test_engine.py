"""FingerprintEngine: jit'd scoring parity + compile-amortization."""

import jax
import numpy as np
import pytest
from _trace_utils import expect_traces

from repro.core.graph_data import build_graphs
from repro.core.model import PeronaConfig, PeronaModel
from repro.core.preprocess import Preprocessor
from repro.core.trainer import batch_to_jnp
from repro.fingerprint.runner import SuiteRunner
from repro.runtime.watchdog import PeronaWatchdog
from repro.serving.engine import FingerprintEngine, bucket_size


@pytest.fixture(scope="module")
def small_setup():
    runner = SuiteRunner(seed=7)
    machines = {"m0": "e2-medium", "m1": "n2-standard-4"}
    frame = runner.run_frame(machines, runs_per_type=10,
                             stress_fraction=0.2)
    pre = Preprocessor().fit(frame)
    batch = build_graphs(frame, pre)
    cfg = PeronaConfig(feature_dim=pre.feature_dim,
                       edge_dim=batch.edge.shape[-1])
    model = PeronaModel(cfg)
    params = model.init(jax.random.PRNGKey(0))  # untrained: scoring only
    return runner, machines, frame, pre, model, params


def test_bucket_size():
    assert bucket_size(1) == 64
    assert bucket_size(64) == 64
    assert bucket_size(65) == 128
    assert bucket_size(1800) == 2048


def test_engine_matches_reference_scoring(small_setup):
    _, _, frame, pre, model, params = small_setup
    engine = FingerprintEngine(model, params, pre)
    res = engine.score(frame)

    batch = build_graphs(frame, pre)
    out = model.forward(params, batch_to_jnp(batch), train=False)
    ref_prob = np.asarray(jax.nn.sigmoid(out["anom_logit"]))
    ref_codes = np.asarray(out["codes"])
    assert res.anomaly_prob.shape == ref_prob.shape
    np.testing.assert_allclose(res.anomaly_prob, ref_prob, atol=2e-4)
    np.testing.assert_allclose(res.codes, ref_codes, atol=2e-3)


def test_engine_accepts_records(small_setup):
    _, _, frame, pre, model, params = small_setup
    engine = FingerprintEngine(model, params, pre)
    a = engine.score(frame)
    b = engine.score(frame.to_records())
    np.testing.assert_allclose(a.anomaly_prob, b.anomaly_prob, atol=1e-6)


def test_engine_compiles_once_per_bucket(small_setup):
    runner, machines, frame, pre, model, params = small_setup
    engine = FingerprintEngine(model, params, pre)
    assert engine.trace_count == 0
    with expect_traces(engine, 1):
        r1 = engine.score(frame)  # 120 rows -> bucket 128
    with expect_traces(engine, 0):
        engine.score(frame)
        # a different round with the same bucket: no new trace
        other = runner.run_frame(machines, runs_per_type=9)  # 108 rows
        assert bucket_size(len(other)) == r1.n_padded
        engine.score(other)
    # crossing a bucket boundary traces exactly once more
    with expect_traces(engine, 1):
        bigger = runner.run_frame(machines, runs_per_type=20)  # 240 rows
        engine.score(bigger)


def test_watchdog_rounds_amortize_one_compile(small_setup):
    """Repeated watchdog rounds with a bounded history must reuse one
    compiled scoring call (the regression the engine exists for)."""
    runner, machines, frame, pre, model, params = small_setup
    wd = PeronaWatchdog(model, params, pre, history_per_chain=10)
    wd.history = frame
    for _ in range(4):
        # history is at the per-chain cap -> constant size -> one bucket
        recs = runner.run_frame({"m0": "e2-medium"}, runs_per_type=2)
        decisions = wd.observe(recs)
        assert [d.node for d in decisions] == ["m0"]
    assert wd.engine.trace_count == 1


def test_watchdog_history_trim(small_setup):
    runner, machines, frame, pre, model, params = small_setup
    wd = PeronaWatchdog(model, params, pre, history_per_chain=4)
    wd.history = frame
    wd.observe(runner.run_frame(machines, runs_per_type=1))
    hist = wd.history_frame
    # every (type, machine) chain trimmed to <= 4 newest runs
    key = (hist.type_code.astype(np.int64) * len(hist.machines)
           + hist.machine_code)
    _, counts = np.unique(key, return_counts=True)
    assert counts.max() <= 4
    # chronological order maintained
    assert np.all(np.diff(hist.t) >= 0)
