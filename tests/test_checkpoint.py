"""Checkpoint manager: atomicity, restart, GC, async, data determinism."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpointing.manager import CheckpointManager
from repro.data.tokens import TokenPipeline


def _state(x: float):
    return {"params": {"w": jnp.full((4, 4), x)},
            "opt": {"m": jnp.full((4, 4), x / 2)}}


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=False)
    mgr.save(7, _state(3.0), extra={"hosts": ["a", "b"]})
    restored, meta = mgr.restore(_state(0.0))
    assert meta["step"] == 7
    assert meta["hosts"] == ["a", "b"]
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.full((4, 4), 3.0))


def test_latest_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_last=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, _state(float(s)))
    assert mgr.latest_step() == 4
    assert sorted(mgr.all_steps()) == [3, 4]
    restored, meta = mgr.restore(_state(0.0), step=3)
    assert float(np.asarray(restored["params"]["w"])[0, 0]) == 3.0


def test_no_tmp_files_left(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=False)
    mgr.save(1, _state(1.0))
    assert not list(tmp_path.glob(".tmp*"))


def test_async_save_visible_after_wait(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=True)
    mgr.save(5, _state(2.0))
    mgr.wait()
    assert mgr.latest_step() == 5


def test_restore_none_when_empty(tmp_path):
    mgr = CheckpointManager(tmp_path)
    restored, meta = mgr.restore(_state(0.0))
    assert restored is None and meta is None


def test_async_write_error_raises_on_next_save(tmp_path, monkeypatch):
    """A failed background write is not silently lost: the error
    surfaces on the next save() (and only once), and the failed step
    never becomes the restore point."""
    mgr = CheckpointManager(tmp_path, async_save=True)
    mgr.save(1, _state(1.0))
    mgr.wait()

    def boom(step, host, extra):
        raise OSError("disk full")

    monkeypatch.setattr(mgr, "_write", boom)
    mgr.save(2, _state(2.0))  # queues; the worker hits the error
    mgr._queue.join()
    monkeypatch.undo()
    with pytest.raises(OSError, match="disk full"):
        mgr.save(3, _state(3.0))
    assert mgr.latest_step() == 1  # step 2 never landed
    mgr.save(3, _state(3.0))  # error consumed: saves work again
    mgr.wait()
    assert mgr.latest_step() == 3


def test_async_write_error_raises_on_close(tmp_path, monkeypatch):
    """close() drains the queue and re-raises a pending write error;
    a second close is a clean no-op."""
    mgr = CheckpointManager(tmp_path, async_save=True)

    def boom(step, host, extra):
        raise OSError("torn write")

    monkeypatch.setattr(mgr, "_write", boom)
    mgr.save(1, _state(1.0))
    with pytest.raises(OSError, match="torn write"):
        mgr.close()
    mgr.close()  # idempotent once the error was consumed


def test_pinned_steps_survive_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_last=1, async_save=False)
    mgr.save(1, _state(1.0))
    mgr.pinned.add(1)
    for s in (2, 3, 4):
        mgr.save(s, _state(float(s)))
    assert sorted(mgr.all_steps()) == [1, 4]
    restored, _ = mgr.restore(_state(0.0), step=1)
    assert float(np.asarray(restored["params"]["w"])[0, 0]) == 1.0


def test_data_pipeline_deterministic_restart():
    """Exactly-once samples: batch_at(step) identical across 'restarts'."""
    p1 = TokenPipeline(vocab_size=128, seq_len=16, global_batch=4, seed=3)
    ref = [np.asarray(p1.batch_at(s)["tokens"]) for s in range(5)]
    p2 = TokenPipeline(vocab_size=128, seq_len=16, global_batch=4, seed=3)
    for s in (3, 4):  # resume mid-stream
        np.testing.assert_array_equal(
            np.asarray(p2.batch_at(s)["tokens"]), ref[s])


def test_data_pipeline_labels_shifted():
    p = TokenPipeline(vocab_size=128, seq_len=16, global_batch=2, seed=0)
    b = p.batch_at(0)
    np.testing.assert_array_equal(np.asarray(b["labels"][:, :-1]),
                                  np.asarray(b["tokens"][:, 1:]))


def test_data_pipeline_has_learnable_structure():
    p = TokenPipeline(vocab_size=64, seq_len=256, global_batch=8, seed=0,
                      structure=0.8)
    b = p.batch_at(0)
    toks = np.asarray(b["tokens"])
    succ = np.asarray(p._successor)
    hits = np.mean(succ[toks[:, :-1]] == toks[:, 1:])
    assert hits > 0.6  # ~structure fraction follows the successor table
