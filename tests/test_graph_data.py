"""Benchmark-execution graph construction invariants."""

import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.graph_data import P_PREDECESSORS, build_graphs


def test_edges_are_chronological_predecessors(fitted):
    batch = fitted["train"]
    recs = fitted["train_records"]
    for i in range(0, len(batch), 97):
        for p in range(P_PREDECESSORS):
            j = batch.nbr[i, p]
            if j < 0:
                continue
            assert recs[j].t <= recs[i].t
            assert recs[j].benchmark_type == recs[i].benchmark_type
            assert recs[j].machine == recs[i].machine


def test_in_degree_at_most_three(fitted):
    assert fitted["train"].nbr.shape[1] == P_PREDECESSORS
    deg = fitted["train"].nbr_mask.sum(1)
    assert deg.max() <= P_PREDECESSORS
    # chain heads have 0..2 predecessors
    assert (deg == 0).sum() == len({(r.benchmark_type, r.machine)
                                    for r in fitted["train_records"]})


def test_edge_attrs_bounded(fitted):
    e = fitted["train"].edge
    assert np.all(e >= 0.0) and np.all(e <= 1.0 + 1e-6)


def test_subset_remaps_edges(fitted):
    batch = fitted["train"]
    idx = np.arange(0, len(batch), 2)
    sub = batch.subset(idx)
    assert len(sub) == len(idx)
    # all remaining edges point inside the subset
    valid = sub.nbr[sub.nbr_mask]
    assert valid.min() >= 0 and valid.max() < len(sub)


def test_norm_gt_positive(fitted):
    assert np.all(fitted["train"].norm_gt > 0)
