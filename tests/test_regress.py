"""Perf-plane tests: benchmark history store, noise-aware regression
gate, snapshot attribution, and the trend report.

The acceptance contract of the gate (ISSUE 9): an injected >=20%
throughput regression exits nonzero with the offending metric and an
attribution line; an A/A replay of identical runs exits zero (the
false-positive rate is bounded by the calibrated noise floor);
direction policy is respected (p99 increase fails, p99 decrease
passes); a trace-count bump is labeled a recompile.
"""

import json

import numpy as np
import pytest

from benchmarks import gate, report
from benchmarks.history import BenchHistory, parse_value
from repro.fleet.drift import EwmaMean, ewma_last, ewma_series
from repro.obs import regress


def payload(t, values, *, module="fleet", smoke=False,
            git_sha="abc123", dirty=False, device_count=1,
            cpu_cores=2, backend="cpu", metrics=None, error=False):
    """One synthetic BENCH_*.json payload, provenance-stamped like
    benchmarks.run writes them."""
    rows = [{"name": k, "us_per_call": "", "derived": v}
            for k, v in values.items()]
    if error:
        rows.append({"name": f"{module}.ERROR", "us_per_call": "",
                     "derived": "RuntimeError('boom')"})
    return {"module": module, "unix_time": t, "quick": False,
            "smoke": smoke, "git_sha": git_sha, "dirty": dirty,
            "device_count": device_count, "cpu_cores": cpu_cores,
            "backend": backend, "params": {"n": 8},
            "metrics": metrics or {}, "rows": rows}


def fleet_history(rps_series, candidate_rps, *, p99=0.02,
                  candidate_p99=None, traces=4, candidate_traces=None,
                  noise_pct=0.0):
    """History of identical-workload fleet runs ending in a candidate."""
    h = BenchHistory()
    snap = lambda tr: {"jax.traces{site=engine/0}": tr,  # noqa: E731
                       "jax.compile_s{site=engine/0}": tr * 0.5}
    for i, rps in enumerate(rps_series):
        h.append(payload(float(i), {
            "fleet.batched.requests_per_s": f"{rps:.2f}",
            "fleet.daemon.p99_queue_latency_s": f"{p99:.5f}",
            "fleet.daemon.obs.noise_pct": f"{noise_pct:.2f}",
        }, metrics=snap(traces)))
    cand = h.append(payload(float(len(rps_series)), {
        "fleet.batched.requests_per_s": f"{candidate_rps:.2f}",
        "fleet.daemon.p99_queue_latency_s":
            f"{candidate_p99 if candidate_p99 is not None else p99:.5f}",
        "fleet.daemon.obs.noise_pct": f"{noise_pct:.2f}",
    }, metrics=snap(candidate_traces if candidate_traces is not None
                    else traces)))
    return h, cand


def by_metric(findings):
    return {f.metric: f for f in findings}


# ------------------------------------------------------- value parsing

def test_parse_value():
    assert parse_value(5) == 5.0
    assert parse_value(2.5) == 2.5
    assert parse_value("162.0") == 162.0
    assert parse_value("14.3x") == 14.3
    assert parse_value("1.93×") == 1.93
    assert parse_value("432/432") == 1.0
    assert parse_value("30/32") == 30 / 32
    assert parse_value("") is None
    assert parse_value("RuntimeError('x')") is None
    assert parse_value("nan") is None
    assert parse_value(float("inf")) is None
    assert parse_value("0/0") is None


# ------------------------------------------------------- history store

def test_history_round_trip(tmp_path):
    h, cand = fleet_history([100, 101, 99, 100], 80)
    path = str(tmp_path / "hist.npz")
    h.save(path)
    h2 = BenchHistory.load(path)
    assert len(h2) == len(h) == 5
    assert h2.n_samples == h.n_samples
    np.testing.assert_array_equal(
        h2.baseline_series("fleet", "fleet.batched.requests_per_s",
                           before_run=cand),
        h.baseline_series("fleet", "fleet.batched.requests_per_s",
                          before_run=cand))
    assert h2.run_info(cand)["git_sha"] == "abc123"
    assert h2.hardware_key(cand) == (1, 2, "cpu")
    assert h2.snapshot(0)["jax.traces{site=engine/0}"] == 4
    assert h2.params(0) == {"n": 8}


def test_history_smoke_rows_excluded_from_baselines():
    h = BenchHistory()
    for i in range(3):
        h.append(payload(float(i),
                         {"fleet.batched.requests_per_s": "100"}))
    # smoke run with minimal workloads: far slower, must not anchor
    h.append(payload(3.0, {"fleet.batched.requests_per_s": "10"},
                     smoke=True))
    cand = h.append(payload(
        4.0, {"fleet.batched.requests_per_s": "99"}))
    base = h.baseline_series("fleet",
                             "fleet.batched.requests_per_s",
                             before_run=cand)
    assert base.tolist() == [100.0, 100.0, 100.0]
    with_smoke = h.baseline_series("fleet",
                                   "fleet.batched.requests_per_s",
                                   before_run=cand,
                                   include_smoke=True)
    assert with_smoke.tolist() == [100.0, 100.0, 100.0, 10.0]
    # the smoke override argument wins over the payload tag
    h2 = BenchHistory()
    h2.append(payload(0.0, {"x.requests_per_s": "1"}), smoke=True)
    assert h2.run_info(0)["smoke"] is True


def test_history_hardware_matching():
    h = BenchHistory()
    for i in range(3):
        h.append(payload(float(i),
                         {"fleet.batched.requests_per_s": "100"}))
    # a beefier machine's runs must not anchor this machine's baseline
    h.append(payload(3.0, {"fleet.batched.requests_per_s": "900"},
                     cpu_cores=64))
    cand = h.append(payload(
        4.0, {"fleet.batched.requests_per_s": "99"}))
    assert h.baseline_series(
        "fleet", "fleet.batched.requests_per_s",
        before_run=cand).tolist() == [100.0] * 3
    assert len(h.baseline_series(
        "fleet", "fleet.batched.requests_per_s", before_run=cand,
        match_hardware=False)) == 4


def test_history_error_rows_flagged_not_ingested():
    h = BenchHistory()
    run = h.append(payload(0.0, {"fleet.devices": 1}, error=True))
    assert h.run_info(run)["error"] is True
    assert "fleet.ERROR" not in h.metrics_for("fleet")


# ------------------------------------------------------ the EWMA fold

def test_ewma_mean_is_the_drift_fold():
    rng = np.random.default_rng(0)
    xs = rng.normal(10.0, 1.0, size=37)
    acc = EwmaMean(0.3).fold(xs)
    assert float(acc.ewma) == ewma_last(xs, 0.3)
    assert float(acc.ewma) == ewma_series(xs, 0.3)[-1]
    assert acc.mean == pytest.approx(xs.mean())
    assert regress.ewma_baseline(xs, 0.3) == ewma_last(xs, 0.3)


# -------------------------------------------------- gate: acceptance

def test_injected_20pct_regression_flagged_with_attribution():
    h, cand = fleet_history([3200, 3230, 3190, 3210, 3200, 3220],
                            3200 * 0.8, candidate_traces=9)
    findings = by_metric(gate.evaluate_module(h, "fleet", run=cand))
    f = findings["fleet.batched.requests_per_s"]
    assert f.regressed
    assert f.delta_pct < -15.0
    assert any("recompile" in a for a in f.attribution), f.attribution
    failures = gate.gate_verdict(h, {"fleet": list(findings.values())})
    assert any("fleet.batched.requests_per_s" in x for x in failures)
    assert any("recompile" in x for x in failures)


def test_aa_replay_of_identical_runs_passes():
    h, cand = fleet_history([3200.0] * 6, 3200.0)
    findings = gate.evaluate_module(h, "fleet", run=cand)
    assert not any(f.regressed for f in findings)
    assert gate.gate_verdict(h, {"fleet": findings}) == []
    # and the throughput metric really was judged, not skipped
    f = by_metric(findings)["fleet.batched.requests_per_s"]
    assert f.verdict == regress.VERDICT_OK and f.n_baseline == 6


def test_direction_policy_p99():
    # p99 latency increase = regression ...
    h, cand = fleet_history([3200.0] * 6, 3200.0, p99=0.02,
                            candidate_p99=0.03)
    f = by_metric(gate.evaluate_module(h, "fleet", run=cand))[
        "fleet.daemon.p99_queue_latency_s"]
    assert f.regressed and f.direction == regress.DIR_LOWER
    # ... and a decrease passes (improvement, not regression)
    h2, cand2 = fleet_history([3200.0] * 6, 3200.0, p99=0.02,
                              candidate_p99=0.01)
    f2 = by_metric(gate.evaluate_module(h2, "fleet", run=cand2))[
        "fleet.daemon.p99_queue_latency_s"]
    assert f2.verdict == regress.VERDICT_IMPROVEMENT
    assert gate.gate_verdict(
        h2, {"fleet": [f2]}) == []


def test_noise_floor_bounds_false_positives():
    # a series with ~8% swings: a 5%-below-baseline candidate is
    # within the calibrated noise floor and must NOT be flagged ...
    noisy = [3200, 2950, 3420, 3050, 3380, 2980, 3350, 3020]
    h, cand = fleet_history(noisy, np.mean(noisy) * 0.95)
    f = by_metric(gate.evaluate_module(h, "fleet", run=cand))[
        "fleet.batched.requests_per_s"]
    assert f.threshold_pct > 10.0  # widened beyond the policy's 10%
    assert not f.regressed
    # ... while the same candidate against a quiet series is flagged
    quiet = [3200, 3210, 3195, 3205, 3200, 3198, 3207, 3201]
    h2, cand2 = fleet_history(quiet, np.mean(quiet) * 0.85)
    f2 = by_metric(gate.evaluate_module(h2, "fleet", run=cand2))[
        "fleet.batched.requests_per_s"]
    assert f2.regressed


def test_aa_null_row_widens_threshold():
    # the bench's own A/A null measurement (obs.noise_pct row) widens
    # every threshold of that run's module
    h, cand = fleet_history([3200.0] * 6, 3200 * 0.89,
                            noise_pct=12.0)
    f = by_metric(gate.evaluate_module(h, "fleet", run=cand))[
        "fleet.batched.requests_per_s"]
    assert f.threshold_pct == pytest.approx(12.0)
    assert not f.regressed


def test_insufficient_history_never_gates():
    h, cand = fleet_history([3200.0] * 2, 1.0)  # min_history is 3
    findings = gate.evaluate_module(h, "fleet", run=cand)
    assert all(f.verdict in (regress.VERDICT_NO_BASELINE,
                             regress.VERDICT_INFO)
               for f in findings)
    assert gate.gate_verdict(h, {"fleet": findings}) == []


def test_error_row_fails_the_gate():
    h = BenchHistory()
    for i in range(4):
        h.append(payload(float(i), {"fleet.devices": 1}))
    h.append(payload(4.0, {"fleet.devices": 1}, error=True))
    findings = gate.evaluate_history(h)
    failures = gate.gate_verdict(h, findings)
    assert any("ERROR" in x for x in failures)


# ---------------------------------------------------------- policies

def test_default_policy_heuristics():
    assert regress.default_policy(
        "fleet.batched.requests_per_s").direction == regress.DIR_HIGHER
    assert regress.default_policy(
        "optimizer.speedup").direction == regress.DIR_HIGHER
    assert regress.default_policy(
        "fleet.daemon.p99_queue_latency_s").direction == \
        regress.DIR_LOWER
    assert regress.default_policy(
        "fleet.daemon.events").direction == regress.DIR_INFO
    assert regress.default_policy(
        "fleet.store_rows").direction == regress.DIR_INFO
    # explicit override beats the heuristic
    over = regress.policy_table({"fleet.daemon.events":
                                 ("lower", 1.0)})
    p = regress.default_policy("fleet.daemon.events", over)
    assert p.direction == regress.DIR_LOWER
    assert p.rel_threshold_pct == 1.0


def test_bench_modules_declare_policies():
    for module in ("fleet", "optimizer"):
        table = gate.module_policies(module)
        assert table, f"bench_{module} lost its POLICIES table"
        for name, pol in table.items():
            assert isinstance(pol, regress.MetricPolicy), name
    table = gate.module_policies("fleet")
    assert table["fleet.batched.requests_per_s"].direction == \
        regress.DIR_HIGHER
    assert table["fleet.daemon.p99_queue_latency_s"].direction == \
        regress.DIR_LOWER
    # trace parity gates at zero tolerance
    opt = gate.module_policies("optimizer")
    assert opt["optimizer.trace_parity"].rel_threshold_pct == 0.0


def test_trace_parity_drop_fails():
    h = BenchHistory()
    for i in range(4):
        h.append(payload(float(i),
                         {"optimizer.trace_parity": "432/432"},
                         module="optimizer"))
    cand = h.append(payload(4.0,
                            {"optimizer.trace_parity": "430/432"},
                            module="optimizer"))
    f = by_metric(gate.evaluate_module(h, "optimizer", run=cand))[
        "optimizer.trace_parity"]
    assert f.regressed


# ----------------------------------------------------- CLI + report

def test_gate_cli_exit_codes(tmp_path):
    # regression -> 1, with the offending metric on stderr
    h, _ = fleet_history([3200.0] * 6, 3200 * 0.8,
                         candidate_traces=9)
    bad = str(tmp_path / "bad.npz")
    h.save(bad)
    rep = str(tmp_path / "trend.md")
    assert gate.main(["--history", bad, "--report", rep]) == 1
    text = open(rep).read()
    assert "fleet.batched.requests_per_s" in text
    assert "recompile" in text
    assert "**regression**" in text
    # A/A -> 0
    h2, _ = fleet_history([3200.0] * 6, 3200.0)
    good = str(tmp_path / "good.npz")
    h2.save(good)
    assert gate.main(["--history", good, "--report", ""]) == 0
    # --check-schema never enforces verdicts, but validates the file
    assert gate.main(["--history", bad, "--report", "",
                      "--check-schema"]) == 0
    # broken artifact -> 2
    missing = str(tmp_path / "missing.npz")
    assert gate.main(["--history", missing, "--report", ""]) == 2
    garbage = str(tmp_path / "garbage.npz")
    with open(garbage, "w") as f:
        f.write("not an npz")
    assert gate.main(["--history", garbage, "--report", ""]) == 2


def test_trend_report_renders_from_history(tmp_path):
    h, _ = fleet_history([3200, 3230, 3190, 3210], 3200.0)
    findings = gate.evaluate_history(h)
    text = report.trend_report(h, findings)
    assert "## fleet" in text
    assert "fleet.batched.requests_per_s" in text
    assert "abc123" in text  # provenance surfaced
    # sparklines render from the series
    assert any(ch in text for ch in "▁▂▃▄▅▆▇█")
    path = tmp_path / "trend.md"
    report.write_trend_report(str(path), h, findings)
    assert path.read_text() == text


def test_spark():
    assert report.spark([]) == ""
    assert report.spark([1.0, 1.0, 1.0]) == "▄▄▄"
    s = report.spark([0, 1, 2, 3])
    assert s[0] == "▁" and s[-1] == "█"
    assert len(report.spark(list(range(100)), width=16)) == 16


# ----------------------------------------------- run.py integration

def test_run_py_ingests_payloads(tmp_path, monkeypatch):
    """run.py --history appends the written payloads (tagged smoke)
    into the store — exercised through the same BenchHistory calls
    run.main performs, on payload files from disk."""
    p1 = tmp_path / "BENCH_fleet.json"
    p1.write_text(json.dumps(payload(
        1.0, {"fleet.batched.requests_per_s": "100"}, smoke=True)))
    hist_path = str(tmp_path / "BENCH_history.npz")
    hist = BenchHistory.load_or_new(hist_path)
    with open(p1) as f:
        hist.append(json.load(f))
    hist.save(hist_path)
    again = BenchHistory.load_or_new(hist_path)
    assert len(again) == 1
    assert again.run_info(0)["smoke"] is True
    # second ingestion round appends, never rewrites
    with open(p1) as f:
        again.append(json.load(f))
    again.save(hist_path)
    assert len(BenchHistory.load(hist_path)) == 2
