"""Launch-layer units: mesh construction, sharding rules, roofline
parsing, dry-run matrix; plus a subprocess multi-device lower+compile."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch import roofline as rl


def test_collective_bytes_parser():
    hlo = textwrap.dedent("""\
        %ag = f32[8,512,192]{1,0,2} all-gather(%x), channel_id=1, replica_groups=[4,4]<=[16], dimensions={2}
        %ar = f32[8,512,576]{2,1,0} all-reduce(%y), channel_id=4, replica_groups=[4,4]<=[16], to_apply=%add
        %cp = bf16[128,64]{1,0} collective-permute(%z), channel_id=9, source_target_pairs={{0,1}}
        %rs = f32[16,16]{1,0} reduce-scatter(%w), channel_id=5, replica_groups={{0,1,2,3}}, dimensions={0}
    """)
    out = rl.collective_bytes(hlo)
    ag = 8 * 512 * 192 * 4
    assert out["all-gather"] == ag * 3 // 4
    ar = 8 * 512 * 576 * 4
    assert out["all-reduce"] == 2 * ar * 3 // 4
    assert out["collective-permute"] == 128 * 64 * 2
    rs = 16 * 16 * 4
    assert out["reduce-scatter"] == rs * 3


def test_collective_parser_skips_done_ops():
    hlo = ("%s = f32[64]{0} all-gather-start(%x), replica_groups=[2,2]<=[4]\n"
           "%d = f32[64]{0} all-gather-done(%s)\n")
    out = rl.collective_bytes(hlo)
    assert out["all-gather"] == 64 * 4 // 2  # only the -start counted


def test_roofline_terms_pick_dominant():
    t = rl.roofline_terms(flops=197e12, bytes_accessed=819e9 / 2,
                          coll_bytes=0)
    assert t["bottleneck"] == "compute"
    assert abs(t["compute_s"] - 1.0) < 1e-9
    t2 = rl.roofline_terms(flops=1e12, bytes_accessed=819e9 * 2,
                           coll_bytes=0)
    assert t2["bottleneck"] == "memory"


def test_model_flops_moe_counts_active_only():
    from repro.configs import get_config
    from repro.models.config import TRAIN_4K

    dense = rl.active_params(get_config("olmo-1b"))
    assert 1.0e9 < dense < 1.6e9  # ~1.2B incl. embeddings
    moe_active = rl.active_params(get_config("deepseek-v2-lite-16b"))
    assert moe_active < 4.0e9  # ~2.7B active of ~16B total


def test_cell_matrix_covers_assignment():
    from repro.launch.dryrun import cell_matrix

    cells = cell_matrix()
    assert len(cells) == 40  # 10 archs x 4 shapes
    skipped = [(a, s) for a, s, active in cells if not active]
    # long_500k skipped for the 8 non-sub-quadratic archs
    assert len(skipped) == 8
    assert all(s == "long_500k" for _, s in skipped)
    assert not any(a in ("recurrentgemma-9b", "xlstm-1.3b")
                   for a, _ in skipped)


def test_make_production_mesh_requires_devices():
    from repro.launch.mesh import make_production_mesh

    # this test process has 1 device -> must raise with guidance
    with pytest.raises(RuntimeError, match="force_host_platform"):
        make_production_mesh()


@pytest.mark.slow
def test_multi_device_lower_compile_subprocess():
    """Spawn a fresh process with 16 virtual devices and lower+compile a
    scaled arch on a 4x4 mesh — the dry-run path end to end."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
        import jax
        from repro.configs import get_config
        from repro.launch import roofline as rl
        from repro.launch.mesh import make_debug_mesh
        from repro.launch.steps import lowerable
        from repro.models.config import ShapeConfig
        from repro.models.model_zoo import build_model

        cfg = get_config("smollm-135m")
        model = build_model(cfg)
        mesh = make_debug_mesh(4, 4)
        shape = ShapeConfig("t", 512, 32, "train")
        fn, shardings, args = lowerable(model, shape, mesh)
        with mesh:
            compiled = jax.jit(fn, in_shardings=shardings).lower(
                *args).compile()
        ca = rl.cost_analysis_dict(compiled)
        assert ca.get("flops", 0) > 0
        print("OK", int(ca["flops"]))
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", code], cwd=
                          os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))),
                          env=env, capture_output=True, text=True,
                          timeout=420)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "OK" in proc.stdout


def test_dryrun_artifacts_if_present():
    """If the sweep artifacts exist, every runnable cell must be ok and
    every cell file present (40 x 2 meshes)."""
    art = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "artifacts", "dryrun")
    if not os.path.isdir(art):
        pytest.skip("dry-run artifacts not generated")
    files = [f for f in os.listdir(art) if f.endswith(".json")]
    matrix = [f for f in files if not f.startswith("perona-fingerprint")]
    if len(matrix) < 80:
        pytest.skip("sweep incomplete")
    assert len(matrix) == 80  # 10 archs x 4 shapes x 2 meshes
    for f in files:
        rec = json.load(open(os.path.join(art, f)))
        assert rec["status"] in ("ok", "skipped"), (f, rec.get("error"))
