"""Columnar-pipeline parity: the BenchmarkFrame-based acquisition,
preprocessing and graph construction must produce *identical* features,
masks and edges to the per-record path of the seed implementation.

The record-loop reference implementations below are verbatim ports of
the seed's ``Preprocessor.fit/transform`` and ``build_graphs`` (dict +
Python-loop algorithms); the shipped code is columnar, and these tests
pin it to the naive semantics.
"""

import numpy as np
import pytest

from repro.core.graph_data import (P_PREDECESSORS, build_graphs,
                                   chronological_split)
from repro.core.preprocess import Preprocessor, unify
from repro.fingerprint.frame import BenchmarkFrame, concat_frames
from repro.fingerprint.records import BenchmarkExecution
from repro.fingerprint.runner import SuiteRunner, paper_acquisition_frame


# --------------------------------------------------------------------------
# Seed (record-loop) reference implementations
# --------------------------------------------------------------------------

def reference_fit(pre, records):
    """The seed's Preprocessor.fit: dict-of-lists over records."""
    values = {}
    for r in records:
        for name, (v, unit) in r.metrics.items():
            values.setdefault(name, []).append(unify(v, unit))
    raw_feature_count = len(values)

    selected = []
    for name in sorted(values):
        arr = np.asarray(values[name], np.float64)
        if len(np.unique(np.round(arr, 12))) < 2:
            continue
        std = float(np.std(arr))
        if pre.std_mode == "cv":
            denom = max(abs(float(np.mean(arr))), 1e-12)
            disp = std / denom
        else:
            disp = std
        if disp >= pre.std_threshold:
            selected.append(name)

    F = len(selected)
    maximize = np.zeros((F,), bool)
    lo = np.zeros((F,))
    hi = np.ones((F,))
    for i, name in enumerate(selected):
        arr = np.asarray(values[name], np.float64)
        mx, mn, med = float(arr.max()), float(arr.min()), float(
            np.median(arr))
        maximize[i] = (mx - med) <= (med - mn)
        lo[i] = mn
        hi[i] = mx if mx > mn else mn + 1.0

    benchmark_types = sorted({r.benchmark_type for r in records})
    edge_names = sorted({k for r in records for k in r.node_metrics})
    em = np.asarray([[r.node_metrics.get(k, 0.0) for k in edge_names]
                     for r in records])
    edge_lo = em.min(0)
    edge_hi = np.where(em.max(0) > em.min(0), em.max(0), em.min(0) + 1.0)
    return {
        "raw_feature_count": raw_feature_count,
        "feature_names": selected, "maximize": maximize, "lo": lo,
        "hi": hi, "benchmark_types": benchmark_types,
        "edge_names": edge_names, "edge_lo": edge_lo, "edge_hi": edge_hi,
    }


def reference_transform(pre, records):
    """The seed's Preprocessor.transform (uses the fitted pre's stats)."""
    F = len(pre.feature_names)
    idx = {n: i for i, n in enumerate(pre.feature_names)}
    raw = np.zeros((len(records), F))
    present = np.zeros((len(records), F), bool)
    for j, r in enumerate(records):
        for name, (v, unit) in r.metrics.items():
            i = idx.get(name)
            if i is not None:
                raw[j, i] = unify(v, unit)
                present[j, i] = True
    norm = (raw - pre.lo) / (pre.hi - pre.lo)
    norm = np.clip(norm, 0.0, 1.0)
    norm = np.where(pre.maximize, norm, 1.0 - norm)
    norm = np.where(present, norm, pre.fill_mean)
    onehot = np.zeros((len(records), len(pre.benchmark_types)))
    tindex = {t: i for i, t in enumerate(pre.benchmark_types)}
    for j, r in enumerate(records):
        onehot[j, tindex[r.benchmark_type]] = 1.0
    return np.concatenate([norm, onehot], axis=1), present


def reference_build_graphs(records, pre):
    """The seed's build_graphs: per-chain Python loops."""
    x = pre.transform(records)
    em = np.asarray([[r.node_metrics.get(k, 0.0) for k in pre.edge_names]
                     for r in records])
    edge_feats = np.clip(
        (em - pre.edge_lo) / (pre.edge_hi - pre.edge_lo), 0.0, 1.0)
    A = edge_feats.shape[1] + 4
    N = len(records)

    def time_enc(dt, t_src):
        hod = (t_src / 3600.0) % 24.0
        return [
            float(np.log1p(dt) / 12.0),
            float(min(dt / 3600.0, 1.0)),
            0.5 + 0.5 * float(np.sin(2 * np.pi * hod / 24)),
            0.5 + 0.5 * float(np.cos(2 * np.pi * hod / 24)),
        ]

    chains = {}
    for i, r in enumerate(records):
        chains.setdefault((r.benchmark_type, r.machine), []).append(i)
    nbr = -np.ones((N, P_PREDECESSORS), np.int32)
    edge = np.zeros((N, P_PREDECESSORS, A), np.float32)
    chain_id = np.zeros((N,), np.int32)
    for cid, (key, idxs) in enumerate(sorted(chains.items())):
        idxs = sorted(idxs, key=lambda i: records[i].t)
        for pos, i in enumerate(idxs):
            chain_id[i] = cid
            preds = idxs[max(0, pos - P_PREDECESSORS):pos]
            for p, j in enumerate(reversed(preds)):
                nbr[i, p] = j
                dt = max(records[i].t - records[j].t, 0.0)
                edge[i, p] = np.concatenate([
                    edge_feats[j],
                    np.asarray(time_enc(dt, records[j].t))])
    return nbr, edge, chain_id


def reference_split(records, fractions=(0.6, 0.2, 0.2)):
    chains = {}
    for i, r in enumerate(records):
        chains.setdefault((r.benchmark_type, r.machine), []).append(i)
    train, val, test = [], [], []
    for idxs in chains.values():
        idxs = sorted(idxs, key=lambda i: records[i].t)
        n = len(idxs)
        a = int(n * fractions[0])
        b = int(n * (fractions[0] + fractions[1]))
        train += idxs[:a]
        val += idxs[a:b]
        test += idxs[b:]
    pick = lambda ids: [records[i] for i in sorted(ids)]
    return pick(train), pick(val), pick(test)


# --------------------------------------------------------------------------
# Fixtures
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def acq():
    frame = paper_acquisition_frame(seed=0)
    return frame, frame.to_records()


# --------------------------------------------------------------------------
# Round trip + acquisition
# --------------------------------------------------------------------------

def test_frame_record_round_trip_lossless(acq):
    frame, records = acq
    back = BenchmarkFrame.from_records(records)
    again = back.to_records()
    assert again == records  # dataclass equality: exact values + units


def test_run_is_frame_conversion(acq):
    """The record-list API is a view of the columnar acquisition."""
    machines = {f"node-{i}": "e2-medium" for i in range(1, 4)}
    recs = SuiteRunner(seed=0).run(machines, runs_per_type=100,
                                   stress_fraction=0.2)
    assert recs == acq[1]


def test_columnar_acquisition_statistics_match_reference():
    """run_frame and the seed triple loop draw from the same
    distributions (different stream order)."""
    machines = {"a": "e2-medium", "b": "c2-standard-4"}
    frame = SuiteRunner(seed=1).run_frame(machines, runs_per_type=60,
                                          stress_fraction=0.25)
    ref = SuiteRunner(seed=1).run_reference(machines, runs_per_type=60,
                                            stress_fraction=0.25)
    assert len(frame) == len(ref) == 2 * 6 * 60
    assert abs(frame.stressed.mean()
               - np.mean([r.stressed for r in ref])) < 0.1
    recs = frame.to_records()
    for name in ("cpu.events_per_second", "mem.throughput",
                 "fio.read.iops", "ioping.lat_avg", "qperf.tcp_bw",
                 "iperf3.sent_bps"):
        a = np.asarray([r.metrics[name][0] for r in recs
                        if name in r.metrics])
        b = np.asarray([r.metrics[name][0] for r in ref
                        if name in r.metrics])
        assert a.shape == b.shape
        assert abs(np.log(a.mean() / b.mean())) < 0.15, name


def test_network_benchmarks_serialized(acq):
    frame, _ = acq
    net = np.isin(frame.type_code,
                  [frame.benchmark_types.index(b)
                   for b in ("qperf", "iperf3")])
    ts = np.sort(frame.t[net])
    assert len(np.unique(ts)) == len(ts)  # one slot per network run


# --------------------------------------------------------------------------
# Preprocess / graph-build parity (identical arrays)
# --------------------------------------------------------------------------

def test_fit_parity(acq):
    frame, records = acq
    pre = Preprocessor().fit(frame)
    ref = reference_fit(Preprocessor(), records)
    assert pre.raw_feature_count == ref["raw_feature_count"]
    assert pre.feature_names == ref["feature_names"]
    assert np.array_equal(pre.maximize, ref["maximize"])
    assert np.array_equal(pre.lo, ref["lo"])
    assert np.array_equal(pre.hi, ref["hi"])
    assert pre.benchmark_types == ref["benchmark_types"]
    assert pre.edge_names == ref["edge_names"]
    assert np.array_equal(pre.edge_lo, ref["edge_lo"])
    assert np.array_equal(pre.edge_hi, ref["edge_hi"])


def test_transform_parity(acq):
    frame, records = acq
    pre = Preprocessor().fit(frame)
    x_frame = pre.transform(frame)
    x_ref, present_ref = reference_transform(pre, records)
    assert np.array_equal(x_frame, x_ref)
    _, present = pre.raw_features(frame)
    assert np.array_equal(present, present_ref)


def test_build_graphs_parity(acq):
    frame, records = acq
    pre = Preprocessor().fit(frame)
    batch = build_graphs(frame, pre)
    nbr_ref, edge_ref, chain_ref = reference_build_graphs(records, pre)
    assert np.array_equal(batch.nbr, nbr_ref)
    assert np.array_equal(batch.nbr_mask, nbr_ref >= 0)
    assert np.array_equal(batch.chain, chain_ref)
    assert np.array_equal(batch.edge, edge_ref)
    assert batch.machine == [r.machine for r in records]


def test_build_graphs_records_and_frame_agree(acq):
    frame, records = acq
    pre = Preprocessor().fit(records)
    a = build_graphs(records, pre)
    b = build_graphs(frame, pre)
    assert np.array_equal(a.x, b.x)
    assert np.array_equal(a.nbr, b.nbr)
    assert np.array_equal(a.edge, b.edge)
    assert np.array_equal(a.norm_gt, b.norm_gt)


def test_chronological_split_parity(acq):
    frame, records = acq
    ours = chronological_split(records)
    ref = reference_split(records)
    for a, b in zip(ours, ref):
        assert a == b
    # frame in -> frame out, same rows
    frames = chronological_split(frame)
    for fr, b in zip(frames, ref):
        assert isinstance(fr, BenchmarkFrame)
        assert fr.to_records() == b


def test_mixed_unit_columns_merge():
    """One metric reported in two units lands in one unified feature."""

    def rec(v, unit, t):
        return BenchmarkExecution(
            benchmark_type="sysbench-cpu", machine="n0",
            machine_type="e2-medium", t=t,
            metrics={"m.lat": (v, unit), "m.x": (t, "count")},
            node_metrics={"node.cpu_util": 0.4}, stressed=False)

    records = [rec(1500.0 + 100 * i, "ms", float(i)) for i in range(4)]
    records += [rec(1.5 + 0.2 * i, "s", 4.0 + i) for i in range(4)]
    frame = BenchmarkFrame.from_records(records)
    assert frame.n_metrics == 3  # (m.lat, ms), (m.lat, s), (m.x, count)
    pre = Preprocessor(std_threshold=0.0).fit(frame)
    assert "m.lat" in pre.feature_names
    x = pre.transform(frame)
    x_ref, _ = reference_transform(pre, records)
    assert np.array_equal(x, x_ref)
    # and the frame round-trips the original units
    assert frame.to_records() == records


def test_concat_frames_unions_columns():
    r1 = SuiteRunner(seed=5).run_frame({"a": "e2-medium"}, 3)
    r2 = SuiteRunner(seed=6).run_frame({"b": "n2-standard-4"}, 2)
    cat = concat_frames([r1, r2])
    assert len(cat) == len(r1) + len(r2)
    assert set(cat.machines) == {"a", "b"}
    recs = cat.to_records()
    assert recs == r1.to_records() + r2.to_records()
